"""Per-op-kind byte/flop breakdown of a unit dry-run compile — the
"profile" for hillclimbing (we reason from lowered IR, not wall time).

    PYTHONPATH=src python -m benchmarks.hlo_breakdown \
        --arch dbrx-132b --shape train_4k [--layers 2]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import re
from collections import defaultdict

import jax

from repro.configs import get_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.launch import steps as S
from repro.launch.dryrun import _UNIT_OVERRIDES, parallel_config, train_config
from repro.launch.hlo_analysis import _DEF_RE, _shape_bytes
from repro.launch.input_specs import SHAPES, batch_specs, decode_specs
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.parallel.sharding import param_count


def compile_unit(arch, shape, n_pattern_mults=1, mesh_kind="single", cfg_overrides=None):
    cfg = get_config(arch)
    pattern, n_super, tail = M.block_pattern(cfg)
    over = dict(_UNIT_OVERRIDES[shape], unroll_scans=True)
    moe_over = None
    if cfg_overrides:
        moe_over = cfg_overrides.pop("moe_dispatch", None)
        over.update(cfg_overrides)
    cfg_u = dataclasses.replace(cfg, n_layers=n_pattern_mults * len(pattern), **over)
    if moe_over:
        cfg_u = dataclasses.replace(cfg_u, moe=dataclasses.replace(cfg_u.moe, dispatch=moe_over))
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    decls = M.decl_model(get_config(arch))
    pcfg = parallel_config(cfg, mesh, param_count(decls))
    tc = train_config(param_count(decls))
    kind = SHAPES[shape]["kind"]
    decls_u = M.decl_model(cfg_u)
    with jax.set_mesh(mesh):
        if kind == "train":
            step = S.make_train_step(cfg_u, tc)
            st_sh = S.state_shardings(decls_u, pcfg, mesh, tc)
            st_abs = S.abstract_state(decls_u, tc)
            batch_abs = batch_specs(cfg_u, shape, with_labels=True)
            b_sh = S.batch_sharding(cfg_u, mesh, batch_abs)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None), donate_argnums=(0,))
            compiled = jitted.lower(st_abs, batch_abs).compile()
        elif kind == "prefill":
            step = S.make_prefill_step(cfg_u)
            p_sh = S.state_shardings(decls_u, pcfg, mesh, tc).params
            p_abs = S.abstract_state(decls_u, tc).params
            batch_abs = batch_specs(cfg_u, shape, with_labels=False)
            b_sh = S.batch_sharding(cfg_u, mesh, batch_abs)
            compiled = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(p_abs, batch_abs).compile()
        else:
            step = S.make_decode_step(cfg_u)
            p_sh = S.state_shardings(decls_u, pcfg, mesh, tc).params
            p_abs = S.abstract_state(decls_u, tc).params
            cache_abs, token_abs, pos_abs = decode_specs(cfg_u, shape)
            c_sh = S.cache_shardings(cfg_u, mesh, SHAPES[shape]["batch"])
            t_sh = S.batch_sharding(cfg_u, mesh, token_abs)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, None),
                             out_shardings=(None, c_sh), donate_argnums=(1,))
            compiled = jitted.lower(p_abs, cache_abs, token_abs, pos_abs).compile()
    return compiled, cfg_u


def breakdown(hlo_text, top=25, skip_fusion_bodies=True):
    """Sum result bytes by (op, dtype); list the top individual shapes.

    Instructions inside %fused_computation bodies are references into their
    fusion's operands, not separate buffers — skipping them approximates
    real traffic (fusion call sites still count their inputs/outputs).
    """
    by_kind = defaultdict(lambda: [0, 0])
    big = []
    in_fusion = False
    for line in hlo_text.splitlines():
        if skip_fusion_bodies:
            stripped = line.lstrip()
            if stripped.startswith("%fused_") or stripped.startswith("%region_"):
                in_fusion = True
            elif line.startswith("}") or stripped == "}":
                in_fusion = False
                continue
            elif stripped.startswith("ENTRY") or stripped.startswith("%while_body") \
                    or stripped.startswith("%checkpoint") or stripped.startswith("%closed_call"):
                in_fusion = False
            if in_fusion:
                continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(2), m.group(3)
        b = _shape_bytes(shape_str)
        by_kind[op][0] += 1
        by_kind[op][1] += b
        big.append((b, op, shape_str.strip()[:60], line.strip()[:200]))
    return by_kind, sorted(big, reverse=True)[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--layers", type=int, default=1, help="pattern multiples")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--dump", default=None, help="save optimized HLO text here")
    ap.add_argument("--dispatch", default=None,
                    choices=[None, "dense", "sort", "multisplit", "multisplit_ep"])
    args = ap.parse_args()

    over = {"moe_dispatch": args.dispatch} if args.dispatch else None
    compiled, cfg_u = compile_unit(args.arch, args.shape, args.layers,
                                   cfg_overrides=over)
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(compiled.as_text())
    cost = compiled.cost_analysis()
    print(f"# unit: {args.arch} x {args.shape}, n_layers={cfg_u.n_layers}")
    print(f"# per-device flops={cost.get('flops', 0):.4g} "
          f"bytes={cost.get('bytes accessed', 0):.4g}")
    by_kind, big = breakdown(compiled.as_text(), args.top)
    print("\n## result-bytes by op kind (count, GiB)")
    for op, (cnt, b) in sorted(by_kind.items(), key=lambda kv: -kv[1][1])[:20]:
        print(f"{op:28s} {cnt:6d}  {b / 2**30:10.3f} GiB")
    print("\n## largest single results")
    for b, op, shape, line in big:
        meta = ""
        if "op_name=" in line:
            meta = line.split('op_name="', 1)[1].split('"', 1)[0][-70:]
        print(f"{b / 2**30:10.3f} GiB  {op:20s} {shape}  {meta}")


if __name__ == "__main__":
    main()
