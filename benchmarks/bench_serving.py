"""Serving benchmark: sustained QPS at a p99 latency SLO (DESIGN.md §16).

    PYTHONPATH=src:. python benchmarks/bench_serving.py --quick --ci-floor 0.9

Three measurements over the same synthetic request set:

1. **Offline oracle** — a perfect scheduler's throughput lower bound: the
   whole request set greedily packed into batches offline (tighter of
   length-sorted and FIFO token-fill), then every batch launched
   back-to-back through the SAME padded step function the server uses.
   Batch assembly is inside the timed region (the server pays it too), so
   the ratio below compares schedulers, not accounting tricks.
2. **Closed-loop ratio** — the real queue + admission + metrics path in
   the saturation regime, divided by the oracle. ``--ci-floor R`` makes
   this a gate: the continuous-batching machinery may cost at most
   ``(1-R)`` of the perfect scheduler's throughput.
3. **Open-loop SLO probe** — Poisson arrivals at ~70% of oracle capacity
   (or ``--qps``): exact nearest-rank p50/p95/p99 latency, sustained QPS,
   shed count, and PASS/FAIL against ``--slo-ms``.

Every run conservation-checks request accounting (``dropped_by_bug == 0``)
and appends a git-stamped trajectory point to ``BENCH_multisplit.json``.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from benchmarks.common import append_trajectory, row
from repro.serving import (
    ServerLoop, ServingConfig, closed_loop, open_loop, poisson_arrivals,
    synthetic_requests,
)
from repro.serving.request import Request

QUICK_REQUESTS = 10_000
FULL_REQUESTS = 40_000
OPEN_LOOP_LOAD = 0.7      # offered rate as a fraction of oracle capacity
TRIALS = 3                # paired (oracle, closed) trials; ratio = best pair


def _bench_config(quick: bool) -> ServingConfig:
    return ServingConfig(
        num_experts=8,
        capacity=64,
        max_batch_requests=512,
        max_batch_tokens=4096,
        max_wait=0.005,
        max_queue_depth=FULL_REQUESTS + 16,   # closed loop holds the full set
    )


def _greedy_pack(cfg: ServingConfig, reqs: List[np.ndarray],
                 order: List[int]) -> List[List[Request]]:
    batches: List[List[Request]] = []
    cur: List[Request] = []
    tokens = 0
    for i in order:
        r = Request(i, reqs[i], 0.0)
        if cur and (len(cur) >= cfg.max_batch_requests
                    or tokens + r.length > cfg.max_batch_tokens):
            batches.append(cur)
            cur, tokens = [], 0
        cur.append(r)
        tokens += r.length
    if cur:
        batches.append(cur)
    return batches


def offline_oracle(cfg: ServingConfig, reqs: List[np.ndarray]) -> Tuple[float, float]:
    """(wall_s, qps) of the perfect scheduler: the whole request set packed
    offline (the TIGHTER of length-sorted and FIFO token-fill greedy
    packings — sorted groups similar lengths, FIFO fills the token budget
    densely when the request cap would otherwise bind), no queue, no
    deadline, no metrics — just pack + launch."""
    loop = ServerLoop(cfg)            # borrowed for _pack/_jit_step only
    loop.prewarm()
    n = len(reqs)
    batches = min(
        _greedy_pack(cfg, reqs, sorted(range(n), key=lambda i: len(reqs[i]))),
        _greedy_pack(cfg, reqs, list(range(n))),
        key=len,
    )
    t0 = time.monotonic()
    out = None
    for b in batches:                 # assembly INSIDE the timed region
        ids, starts, _ = loop._pack(b)
        out = loop._jit_step(ids, starts)   # async, like the pipelined server
    jax.block_until_ready(out)
    wall = time.monotonic() - t0
    return wall, len(reqs) / wall


def run_serving_slo(
    requests: int = QUICK_REQUESTS,
    *,
    quick: bool = True,
    qps: float | None = None,
    slo_ms: float = 200.0,
    ci_floor: float | None = None,
    seed: int = 0,
    fault_rate: float = 0.0,
    verify: int | None = None,
) -> Dict[str, float]:
    """The full serving benchmark; returns the combined results dict and
    raises SystemExit(1) when a gate (--ci-floor / conservation /
    chaos verify_mismatches) fails.  ``fault_rate > 0`` arms seeded
    dispatch-level fault injection (the CI chaos-smoke mode, DESIGN.md
    §17); ``verify`` arms runtime output verification for the run."""
    from repro.runtime import FaultInjector, resilience as _rz

    _rz.reset_stats()
    if verify is not None:
        _rz.set_verify(verify)
    if fault_rate > 0.0:
        _rz.set_fault_injector(
            FaultInjector(dispatch_rate=fault_rate, seed=seed))

    def _disarm() -> None:
        _rz.set_fault_injector(None)
        if verify is not None:
            _rz.set_verify(None)

    cfg = _bench_config(quick)
    reqs = synthetic_requests(requests, cfg.num_experts, seed=seed)

    # 1+2. oracle vs closed loop, in PAIRED trials: each trial measures both
    # schedulers back-to-back under the same machine conditions and the
    # ratio is the best paired ratio — wall-clock noise on a shared host
    # hits both sides of a pair, so the pairing is what makes a CI floor on
    # the ratio meaningful.
    oracle_qps = closed_qps = ratio = 0.0
    oracle_wall, s_closed = None, None
    for _ in range(TRIALS):
        o_wall, o_qps = offline_oracle(cfg, reqs)
        loop = ServerLoop(cfg)       # fresh queue/metrics; jit cache shared
        loop.prewarm()
        s = closed_loop(loop, reqs)
        if s["dropped_by_bug"] != 0:
            print(f"FAIL: closed loop dropped requests: {s}", file=sys.stderr)
            _disarm()
            raise SystemExit(1)
        c_qps = requests / s["wall_s"]
        if c_qps / o_qps > ratio:
            ratio = c_qps / o_qps
            oracle_wall, oracle_qps = o_wall, o_qps
            s_closed, closed_qps = s, c_qps
    row("serving_oracle", oracle_wall / requests, f"qps={oracle_qps:.0f}")
    row("serving_closed", s_closed["wall_s"] / requests,
        f"qps={closed_qps:.0f} oracle_ratio={ratio:.3f}")

    # 3. open-loop Poisson SLO probe
    offered = qps if qps is not None else OPEN_LOOP_LOAD * oracle_qps
    loop2 = ServerLoop(cfg)
    loop2.prewarm()
    arrivals = poisson_arrivals(requests, offered, seed=seed)
    s_open = open_loop(loop2, reqs, arrivals)
    if s_open["dropped_by_bug"] != 0:
        print(f"FAIL: open loop dropped requests: {s_open}", file=sys.stderr)
        _disarm()
        raise SystemExit(1)
    slo_ok = s_open["latency_p99_ms"] <= slo_ms
    row("serving_open_p99", s_open["latency_p99_ms"] / 1e6,
        f"offered={offered:.0f} sustained={s_open['qps_sustained']:.0f} "
        f"slo={'PASS' if slo_ok else 'FAIL'}")

    degradations = int(s_closed["degradations"] + s_open["degradations"])
    mismatches = int(s_closed["verify_mismatches"] + s_open["verify_mismatches"])
    results = {
        "requests": requests,
        "oracle_qps": oracle_qps,
        "closed_qps": closed_qps,
        "oracle_ratio": ratio,
        "offered_qps": offered,
        "slo_ms": slo_ms,
        "slo_pass": bool(slo_ok),
        "fault_rate": fault_rate,
        "degradations": degradations,
        "verify_mismatches": mismatches,
        "open": s_open,
        "closed": {k: s_closed[k] for k in
                   ("completed", "shed", "failed", "retries", "steps",
                    "degradations", "verify_mismatches",
                    "batch_token_occupancy", "batch_requests_mean")},
    }
    # the machine-parsable line the CI step-summary table is built from
    print(f"SERVING_SUMMARY requests={requests} qps={s_open['qps_sustained']:.0f} "
          f"p50_ms={s_open['latency_p50_ms']:.2f} "
          f"p99_ms={s_open['latency_p99_ms']:.2f} "
          f"shed={int(s_open['shed'])} failed={int(s_open['failed'])} "
          f"degradations={degradations} verify_mismatches={mismatches} "
          f"oracle_ratio={ratio:.3f} slo={'PASS' if slo_ok else 'FAIL'}")
    if fault_rate > 0.0:
        # the chaos-smoke markdown step summary is built from these lines
        for e in _rz.events():
            fields = " ".join(f"{k}={v}" for k, v in e.items() if k != "kind")
            print(f"DEGRADATION_EVENT kind={e['kind']} {fields}")

    append_trajectory(results, n=requests, key_value=False, backend=cfg.backend)
    _disarm()

    if ci_floor is not None and ratio < ci_floor:
        print(f"FAIL: closed-loop/oracle ratio {ratio:.3f} < floor {ci_floor}",
              file=sys.stderr)
        raise SystemExit(1)
    if mismatches > 0:
        # chaos gate: injected DISPATCH faults must degrade, never corrupt —
        # any verified output mismatch is a real bug, not an injected one
        print(f"FAIL: {mismatches} runtime-verification mismatches",
              file=sys.stderr)
        raise SystemExit(1)
    return results


def main(quick: bool = False, argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=quick)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--qps", type=float, default=None,
                    help="open-loop offered rate (default: 0.7 x oracle)")
    ap.add_argument("--slo-ms", type=float, default=200.0)
    ap.add_argument("--ci-floor", type=float, default=None,
                    help="minimum closed-loop/oracle throughput ratio")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="seeded dispatch-fault injection rate (chaos smoke)")
    ap.add_argument("--verify", type=int, default=None, choices=(0, 1, 2),
                    help="runtime verification level for this run")
    args = ap.parse_args(argv)
    n = args.requests or (QUICK_REQUESTS if args.quick else FULL_REQUESTS)
    run_serving_slo(n, quick=args.quick, qps=args.qps, slo_ms=args.slo_ms,
                    ci_floor=args.ci_floor, seed=args.seed,
                    fault_rate=args.fault_rate, verify=args.verify)


if __name__ == "__main__":
    main()
