"""Paper Tables 7/8 analogue: multisplit-based radix sort vs radix size r,
against the platform sort (jax.lax.sort standing in for CUB). Includes the
fused in-kernel digit path (plan layer, DESIGN.md §5) on a reduced shape —
the interpreter makes absolute pallas numbers meaningless on CPU, but the
row proves the zero-label pipeline end-to-end.

Set ``MS_BENCH_N`` (power-of-two exponent) to shrink for CI smoke runs."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import append_trajectory, bench, row
from repro.core.sort import radix_sort, radix_sort_per_pass

N = 1 << int(os.environ.get("MS_BENCH_N", "18"))
N_PALLAS = min(N, 1 << 14)


def run_chained_vs_per_pass_radix(emit_json: bool = True):
    """DESIGN.md §10 measurement: the chained RadixPipeline (tiles resolved
    once, buffers padded once, ping-pong across digit passes) vs the PR-2
    per-pass execution (a full pad/tile/run/slice round trip per pass).
    Appends a trajectory point to BENCH_multisplit.json."""
    rng = np.random.RandomState(0)
    keys = jnp.asarray(rng.randint(0, 2**32, N, dtype=np.uint32))
    vals = jnp.arange(N, dtype=jnp.int32)
    results = {}
    for r in (4, 8):
        chained = jax.jit(lambda k, v, r=r: radix_sort(k, v, radix_bits=r)[0])
        per_pass = jax.jit(lambda k, v, r=r: radix_sort_per_pass(k, v, radix_bits=r)[0])
        t_c = bench(chained, keys, vals)
        t_p = bench(per_pass, keys, vals)
        tag = f"radix/r={r}"
        results[f"{tag}/chained_mpairs_s"] = round(N / t_c / 1e6, 2)
        results[f"{tag}/per_pass_mpairs_s"] = round(N / t_p / 1e6, 2)
        results[f"{tag}/speedup"] = round(t_p / t_c, 3)
        row(f"sort/kv/{tag}/chained-pipeline", t_c, f"{N / t_c / 1e6:.1f} Mpairs/s")
        row(f"sort/kv/{tag}/per-pass-legacy", t_p,
            f"{N / t_p / 1e6:.1f} Mpairs/s ({t_p / t_c:.2f}x slower)")
    if emit_json:
        append_trajectory(results, n=N, key_value=True)
    return results


def main():
    rng = np.random.RandomState(0)
    keys = jnp.asarray(rng.randint(0, 2**32, N, dtype=np.uint32))
    vals = jnp.arange(N, dtype=jnp.int32)

    for r in (4, 5, 6, 7, 8):
        f = jax.jit(lambda k, v, r=r: radix_sort(k, v, radix_bits=r)[0])
        t = bench(f, keys, vals)
        row(f"sort/kv/multisplit-sort/r={r}", t, f"{N / t / 1e6:.1f} Mpairs/s")

    t = bench(jax.jit(lambda k, v: jax.lax.sort((k, v), num_keys=1)[0]), keys, vals)
    row("sort/kv/platform-sort", t, f"{N / t / 1e6:.1f} Mpairs/s")

    for r in (6, 8):
        f = jax.jit(lambda k, r=r: radix_sort(k, radix_bits=r)[0])
        t = bench(f, keys)
        row(f"sort/keys/multisplit-sort/r={r}", t, f"{N / t / 1e6:.1f} Mkeys/s")
    t = bench(jax.jit(jax.lax.sort), keys)
    row("sort/keys/platform-sort", t, f"{N / t / 1e6:.1f} Mkeys/s")

    # Fused in-kernel digit path (no host label array): interpret-mode proof
    # run on a reduced shape; compiled TPU numbers are the deployment story.
    kp = keys[:N_PALLAS]
    f = jax.jit(lambda k: radix_sort(k, radix_bits=8, use_pallas=True, tile=1024)[0])
    t = bench(f, kp, warmup=1, trials=1)
    row("sort/keys/multisplit-sort/r=8/fused-pallas-interpret", t,
        f"{N_PALLAS / t / 1e6:.2f} Mkeys/s (interpret)")

    run_chained_vs_per_pass_radix()


if __name__ == "__main__":
    main()
