"""Paper Table 11 analogue: device-wide histogram (Even + Range scenarios)
vs the platform baseline (jnp.histogram — XLA's native path).

The "ours" rows run the ``counts_only`` partial pipeline (DESIGN.md §10):
prescan + tree-reduce, tiles from the shared heuristic cache — no scan, no
scatter. ``main(emit_json=True)`` appends an even-histogram trajectory point
to BENCH_multisplit.json.
"""

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import append_trajectory, bench, row
from repro.core.histogram import histogram_even, histogram_range

N = 1 << 20
M_SWEEP = (2, 8, 32, 64, 256)
RANGE_M_SWEEP = (8, 64, 256)


def main(emit_json: bool = True):
    rng = np.random.RandomState(0)
    keys = jnp.asarray(rng.uniform(0, 1024.0, N).astype(np.float32))
    results = {}

    for m in M_SWEEP:
        f = jax.jit(lambda k, m=m: histogram_even(k, 0.0, 1024.0, m))
        t = bench(f, keys)
        row(f"histogram/even/m={m}/ours", t, f"{N / t / 1e6:.1f} Melem/s")
        g = jax.jit(lambda k, m=m: jnp.histogram(k, bins=m, range=(0.0, 1024.0))[0])
        t_p = bench(g, keys)
        row(f"histogram/even/m={m}/platform", t_p, f"{N / t_p / 1e6:.1f} Melem/s")
        results[f"even/m={m}/counts_only_melem_s"] = round(N / t / 1e6, 2)
        results[f"even/m={m}/platform_melem_s"] = round(N / t_p / 1e6, 2)
        results[f"even/m={m}/vs_platform"] = round(t_p / t, 3)

    for m in RANGE_M_SWEEP:
        splitters = jnp.asarray(np.sort(rng.uniform(0, 1024.0, m - 1)).astype(np.float32))
        f = jax.jit(lambda k, s=splitters: histogram_range(k, s))
        t = bench(f, keys)
        row(f"histogram/range/m={m}/ours", t, f"{N / t / 1e6:.1f} Melem/s")
        g = jax.jit(lambda k, s=splitters: jnp.histogram(
            k, bins=jnp.concatenate([jnp.asarray([-1e30]), s, jnp.asarray([1e30])]))[0])
        t = bench(g, keys)
        row(f"histogram/range/m={m}/platform", t, f"{N / t / 1e6:.1f} Melem/s")

    if emit_json:
        append_trajectory(results, n=N, key_value=False)
    return results


if __name__ == "__main__":
    main()
