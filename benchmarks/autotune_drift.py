"""Heuristic drift gate (ISSUE 7, DESIGN.md §14): heuristic vs autotuned.

    PYTHONPATH=src:. python benchmarks/autotune_drift.py [--quick]
        [--ci-max 1.25]

PR 6 found two hand-tuned flip points measurably stale; the self-tuning
layer exists so that can't silently happen again. This tracker closes the
loop on the HEURISTICS themselves: for a small (n, m) grid it resolves each
shape twice — once through the untouched heuristics, once through the
memory-only joint autotune search (the heuristic's own choice is always in
the searched grid, so the tuned plan can only tie or win modulo noise) —
and reports the gap ``t_heuristic / t_tuned``.

A gap of 1.0 means the heuristic still picks what measurement picks; the
gap grows as the cost model rots. ``--ci-max X`` exits non-zero when any
grid point's gap exceeds ``X`` — the CI drift gate. Full (non ``--quick``)
runs append the gaps to BENCH_multisplit.json so drift is trended over
commits like every other trajectory metric.
"""

import argparse
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import append_trajectory, row
from repro.core.identifiers import EvenSpec
from repro.core.pipeline import clear_tile_cache, family_decision, make_plan, set_autotune
from repro.core.pipeline import autotune as _at


def run_drift(n: int, m: int, *, method: str = "bms", backend: str = "vmap",
              candidates=(256, 512, 1024, 2048, 4096), trials: int = 3,
              emit_rows: bool = True) -> dict:
    """Gap of one shape class: heuristic-resolved plan vs the joint-search
    winner (tile x family), timed on the same synthetic keys."""
    spec = EvenSpec(0.0, float(1 << 30), m)
    keys = jnp.asarray(
        np.random.RandomState(0).randint(0, 1 << 30, n, dtype=np.uint32)
    )

    prev = _at._CONFIG
    try:
        # 1) resolve through the untouched heuristics
        set_autotune(False, persist=False)
        clear_tile_cache()
        p_h = make_plan(n, m, method=method, backend=backend, bucket_fn=spec)
        fam_h = family_decision(n, m, method, backend)[0]

        # 2) resolve through the measured search, with the heuristic's own
        #    pick in the grid
        grid = tuple(sorted(set(candidates) | {p_h.tile}))
        set_autotune(True, persist=False, trials=trials, candidates=grid)
        clear_tile_cache()
        p_t = make_plan(n, m, method=method, backend=backend, bucket_fn=spec)
        fam_t = family_decision(n, m, method, backend)[0]
    finally:
        _at._CONFIG = prev
        clear_tile_cache()

    # time both AFTER all searching, interleaved: neither side gets the
    # warmed-caches advantage of going second
    run_h = jax.jit(lambda k: p_h(k).keys)
    run_t = jax.jit(lambda k: p_t(k).keys)
    jax.block_until_ready(run_h(keys))
    jax.block_until_ready(run_t(keys))
    ts_h, ts_t = [], []
    for _ in range(max(trials, 3)):
        t0 = time.perf_counter()
        jax.block_until_ready(run_h(keys))
        ts_h.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(run_t(keys))
        ts_t.append(time.perf_counter() - t0)
    t_h, t_t = float(np.median(ts_h)), float(np.median(ts_t))

    gap = t_h / t_t
    tag = f"autotune_drift/n=2^{n.bit_length() - 1}/m={m}"
    out = {
        f"{tag}/heuristic_us": round(t_h * 1e6, 1),
        f"{tag}/tuned_us": round(t_t * 1e6, 1),
        f"{tag}/gap": round(gap, 3),
        f"{tag}/heuristic_plan": f"tile={p_h.tile},family={fam_h}",
        f"{tag}/tuned_plan": f"tile={p_t.tile},family={fam_t}",
    }
    if emit_rows:
        row(f"{tag}/heuristic", t_h,
            f"tile={p_h.tile} family={fam_h}")
        row(f"{tag}/tuned", t_t,
            f"tile={p_t.tile} family={fam_t} gap={gap:.3f}x")
    return out


def main(quick: bool = False, ci_max: float = None) -> int:
    # quick keeps n at 2^16 on purpose: the heuristic flip points were
    # benched there (PR 6), and tiny n makes the gap mostly launch noise
    n = 1 << 16
    trials = 2 if quick else 3
    candidates = (256, 1024) if quick else (256, 512, 1024, 2048, 4096)

    results = {}
    gaps = {}
    for m in (8, 256):
        out = run_drift(n, m, candidates=candidates, trials=trials)
        results.update(out)
        tag = f"autotune_drift/n=2^{n.bit_length() - 1}/m={m}"
        gaps[tag] = out[f"{tag}/gap"]

    worst_tag = max(gaps, key=gaps.get)
    worst = gaps[worst_tag]
    if ci_max is not None and worst > ci_max:
        print(f"# FAIL: heuristic is {worst:.3f}x slower than autotuned at "
              f"{worst_tag} — above the {ci_max:.2f}x drift gate; re-derive "
              f"the heuristic (see tiles.py) or re-bench its flip points",
              file=sys.stderr)
        return 1
    if ci_max is not None:
        print(f"# ok: worst heuristic-vs-tuned gap {worst:.3f}x at "
              f"{worst_tag} (gate {ci_max:.2f}x)")
    if not quick:
        append_trajectory(results, n=n, key_value=False)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small-n smoke (no trajectory append)")
    ap.add_argument("--ci-max", type=float, default=None,
                    help="exit 1 if heuristic > MAX x slower than autotuned")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, ci_max=a.ci_max))
