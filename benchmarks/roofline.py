"""Roofline table generator: reads artifacts/dryrun/*.json and emits the
EXPERIMENTS.md §Roofline table (all three terms, dominant bottleneck,
MODEL_FLOPS ratio, one-line recommendation per cell)."""

import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

SUGGEST = {
    "compute": "raise arithmetic efficiency: fewer remat recomputes, bf16 everywhere",
    "memory": "cut materialized intermediates: fuse attention/dispatch (Pallas), "
              "bf16 intermediates, smaller loss/attn chunks",
    "collective": "re-shard to shrink cross-device traffic: 2D expert sharding, "
                  "reduce-scatter grads, overlap collectives with compute",
}


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def load(mesh="single"):
    rows = []
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def table(mesh="single", out=sys.stdout):
    rows = load(mesh)
    print(f"\n### Roofline — {mesh}-pod mesh "
          f"({'256' if mesh == 'single' else '512'} chips, TPU v5e constants)\n", file=out)
    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL_FLOPS/HLO | note |", file=out)
    print("|---|---|---|---|---|---|---|---|", file=out)
    for r in rows:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                  f"SKIP: {r['reason']} |", file=out)
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                  f"ERROR: {r.get('error', '?')[:60]} |", file=out)
            continue
        if r.get("roofline") is None:
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                  f"compiled OK in {r['compile_s']}s (pod-axis shard proof; "
                  f"terms are single-pod) |", file=out)
            continue
        t = r["roofline"]
        uf = r.get("useful_fraction")
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{r['dominant']}** | {uf:.2f} | {SUGGEST[r['dominant']][:58]} |",
            file=out,
        )


def main():
    import argparse
    import io

    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help="insert tables into EXPERIMENTS.md at the marker")
    args = ap.parse_args()
    if args.write:
        buf = io.StringIO()
        for mesh in ("single", "multi"):
            table(mesh, out=buf)
        exp = Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"
        marker = "<!-- ROOFLINE TABLES INSERTED BY benchmarks/roofline.py -->"
        text = exp.read_text()
        head, _, tail = text.partition(marker)
        # drop any previously inserted tables (up to the next ## heading)
        rest = tail.split("\n## ", 1)
        tail_keep = ("\n## " + rest[1]) if len(rest) > 1 else ""
        exp.write_text(head + marker + "\n" + buf.getvalue() + tail_keep)
        print(f"wrote tables into {exp}")
    else:
        for mesh in ("single", "multi"):
            table(mesh)


if __name__ == "__main__":
    main()
