"""Benchmark harness: one section per paper table.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV. Roofline numbers (the per-arch
dry-run analysis) are produced by ``repro.launch.dryrun`` +
``benchmarks.roofline`` since they need the 512-virtual-device process.
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller sweeps")
    ap.add_argument("--section", action="append",
                    choices=["multisplit", "sort", "histogram", "sssp", "roofline",
                             "roofline-multisplit", "autotune-drift", "serving"])
    args = ap.parse_args()
    sections = args.section or ["multisplit", "sort", "histogram", "sssp",
                                "roofline", "roofline-multisplit",
                                "autotune-drift", "serving"]

    print("name,us_per_call,derived")
    if "multisplit" in sections:
        from benchmarks import bench_multisplit

        if args.quick:
            bench_multisplit.M_SWEEP = (8, 256)
        bench_multisplit.main()
    if "sort" in sections:
        from benchmarks import bench_sort

        bench_sort.main()
    if "histogram" in sections:
        from benchmarks import bench_histogram

        if args.quick:
            bench_histogram.M_SWEEP = (8, 256)
            bench_histogram.RANGE_M_SWEEP = (8, 64)
        bench_histogram.main()
    if "sssp" in sections:
        from benchmarks import bench_sssp

        bench_sssp.main()
    if "roofline" in sections:
        try:
            from benchmarks import roofline

            roofline.main()
        except Exception as e:  # artifacts may not exist yet
            print(f"# roofline table unavailable: {e}", file=sys.stderr)
    if "roofline-multisplit" in sections:
        from benchmarks import roofline_multisplit

        roofline_multisplit.main(quick=args.quick)
    if "autotune-drift" in sections:
        from benchmarks import autotune_drift

        autotune_drift.main(quick=args.quick)
    if "serving" in sections:
        from benchmarks import bench_serving

        bench_serving.main(quick=args.quick)


if __name__ == "__main__":
    main()
