"""Benchmark timing utilities."""

import time

import jax
import numpy as np


def bench(fn, *args, warmup=1, trials=3):
    """Median wall time (s) of a jax function (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def row(name, seconds, derived=""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
