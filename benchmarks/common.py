"""Benchmark timing utilities."""

import json
import subprocess
import time
from pathlib import Path

import jax
import numpy as np

BENCH_JSON = Path(__file__).resolve().parent.parent / "BENCH_multisplit.json"

# The shared exact (interpolation-free, nearest-rank) percentile estimator:
# one implementation for serving metrics and the SLO bench, so a reported
# p99 is an OBSERVED sample, never an interpolated value that no request
# actually experienced.  Defined in repro.serving.metrics (benchmarks depend
# on repro, never the reverse) and re-exported here for benchmark code.
from repro.serving.metrics import percentiles  # noqa: E402,F401


def git_commit() -> str:
    """Short hash of the checked-out commit (with ``-dirty`` when the tree
    has uncommitted changes), so every trajectory point is attributable
    (regressions were previously dated but not attributable).

    Note the run-bench-then-commit workflow: a point measured from a dirty
    tree and committed WITH the code that produced it is stamped
    ``<parent>-dirty`` — the commit that introduced the entry (via
    ``git log -- BENCH_multisplit.json``) is the one containing the
    measured code."""
    try:
        cwd = Path(__file__).resolve().parent
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5, cwd=cwd,
        )
        sha = out.stdout.strip()
        if not sha:
            return "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"],
            capture_output=True, text=True, timeout=5, cwd=cwd,
        ).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def append_trajectory(results: dict, *, n: int, key_value: bool, backend: str = "vmap",
                      path: Path = None) -> None:
    """Append one timestamped, commit-stamped trajectory point to
    BENCH_multisplit.json."""
    path = path or BENCH_JSON
    history = []
    if path.exists():
        history = json.loads(path.read_text())
    history.append({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "commit": git_commit(),
        "n": n,
        "key_value": key_value,
        "host": jax.default_backend(),
        "backend": backend,
        "results": results,
    })
    path.write_text(json.dumps(history, indent=2) + "\n")
    print(f"# trajectory point appended to {path.name}")


def bench(fn, *args, warmup=1, trials=3):
    """Median wall time (s) of a jax function (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def row(name, seconds, derived=""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
