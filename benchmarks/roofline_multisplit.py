"""Multisplit roofline tracker (ISSUE 6): ideal-bytes model vs measured
bandwidth for the three radix-sort execution modes.

    PYTHONPATH=src:. python benchmarks/roofline_multisplit.py [--quick]
        [--ci-floor 1.15]

The paper's multisplit is bandwidth-bound: every {prescan, scan, postscan,
scatter} sweep must at minimum read the keys twice (prescan + postscan),
write them once, round-trip the values when key-value, and round-trip the
L×m tile-histogram matrix. The tracker:

1. probes the machine's PEAK sustainable bandwidth with a large device
   copy (the same probe a GPU roofline would run with a device memcpy);
2. computes the IDEAL bytes of each execution mode from the schedule —
   per-pass and chained move the same ideal bytes over ⌈key_bits/r⌉
   sweeps (chained only removes pad/slice overhead, which is exactly why
   it sits closer to the roofline), the FUSED mode halves the sweep count
   (digit pairs, DESIGN.md §13) at the cost of an L×m² histogram matrix;
3. measures each mode and reports time, effective throughput, and the
   FRACTION OF ROOFLINE = (ideal_bytes / peak_bw) / measured_time.

``--ci-floor X`` exits non-zero when fused throughput < X× chained at the
headline r=8 point — the CI perf-smoke guard (S5). ``--quick`` shrinks n
and skips the trajectory append (smoke sizes must not pollute the
BENCH_multisplit.json history).
"""

import argparse
import math
import sys

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import append_trajectory, bench, row
from repro.core.pipeline import RadixPipeline, radix_pass_pairs, radix_passes
from repro.core.sort import radix_sort, radix_sort_per_pass

KEY_BYTES = 4
KEY_BITS = 32


def probe_peak_bandwidth(nbytes: int = 1 << 26, trials: int = 5) -> float:
    """Peak sustainable device bandwidth (bytes/s) via a large copy: one
    read + one write of ``nbytes``."""
    x = jnp.arange(nbytes // 4, dtype=jnp.uint32)
    copy = jax.jit(lambda a: a + jnp.uint32(1))   # forces a real materialize
    t = bench(copy, x, trials=trials)
    return 2 * nbytes / t


def ideal_sweep_bytes(n: int, m_scan: int, tiles: int, key_value: bool) -> int:
    """Minimum HBM traffic of ONE {prescan, scan, postscan, scatter} sweep:
    keys are read by the prescan and the postscan and written once by the
    scatter; values round-trip once; the L×m histogram matrix is written by
    the prescan and read (post-scan) by the postscan."""
    keys_bytes = 3 * KEY_BYTES * n
    vals_bytes = 2 * KEY_BYTES * n if key_value else 0
    hist_bytes = 2 * KEY_BYTES * tiles * m_scan
    return keys_bytes + vals_bytes + hist_bytes


def ideal_sort_bytes(n: int, radix_bits: int, tile: int, key_value: bool,
                     fused: bool, segments: int = 1) -> int:
    """Ideal bytes of the whole sort under the given schedule."""
    tiles = math.ceil(n / tile)
    total = 0
    if fused:
        schedule = [(s, b) for s, b, _ in radix_pass_pairs(radix_bits, KEY_BITS)]
    else:
        schedule = radix_passes(radix_bits, KEY_BITS)
    for _, bits in schedule:
        total += ideal_sweep_bytes(n, (1 << bits) * segments, tiles, key_value)
    return total


def run(n: int, radix_bits: int, key_value: bool, peak_bw: float,
        trials: int = 3, emit_rows: bool = True) -> dict:
    """Measure per-pass / chained / fused at one (n, r) point and return the
    flat result dict (throughput + fraction-of-roofline per mode)."""
    rng = np.random.RandomState(0)
    keys = jnp.asarray(rng.randint(0, 2**32, n, dtype=np.uint32))
    vals = jnp.arange(n, dtype=jnp.int32) if key_value else None

    pipe_c = RadixPipeline(n, radix_bits=radix_bits, backend="vmap",
                           key_value=key_value)
    pipe_f = RadixPipeline(n, radix_bits=radix_bits, backend="vmap",
                           key_value=key_value, fuse_digits=True)

    def timed(fn):
        if key_value:
            f = jax.jit(lambda k, v: fn(k, v)[0])
            return bench(f, keys, vals, trials=trials)
        f = jax.jit(lambda k: fn(k, None)[0])
        return bench(f, keys, trials=trials)

    t_p = timed(lambda k, v: radix_sort_per_pass(
        k, v, radix_bits=radix_bits, backend="vmap"))
    t_c = timed(lambda k, v: radix_sort(
        k, v, radix_bits=radix_bits, backend="vmap"))
    t_f = timed(lambda k, v: radix_sort(
        k, v, radix_bits=radix_bits, backend="vmap", fuse_digits=True))

    ideal_u = ideal_sort_bytes(n, radix_bits, pipe_c.tile, key_value, False)
    ideal_f = ideal_sort_bytes(n, radix_bits, pipe_f.tile, key_value, True)

    out = {}
    tag = f"roofline/r={radix_bits}"
    for mode, t, ideal in (("per_pass", t_p, ideal_u),
                           ("chained", t_c, ideal_u),
                           ("fused", t_f, ideal_f)):
        frac = (ideal / peak_bw) / t
        out[f"{tag}/{mode}_mkeys_s"] = round(n / t / 1e6, 2)
        out[f"{tag}/{mode}_roofline_frac"] = round(frac, 4)
        if emit_rows:
            row(f"sort/{'kv' if key_value else 'keys'}/{tag}/{mode}", t,
                f"{n / t / 1e6:.1f} Mkeys/s, {100 * frac:.2f}% of roofline")
    out[f"{tag}/fused_vs_chained_speedup"] = round(t_c / t_f, 3)
    out[f"{tag}/fused_sweeps"] = pipe_f.n_sweeps
    out[f"{tag}/chained_sweeps"] = pipe_c.n_sweeps
    if emit_rows:
        row(f"sort/{'kv' if key_value else 'keys'}/{tag}/fused_vs_chained",
            t_f, f"{t_c / t_f:.3f}x chained")
    return out


def main(quick: bool = False, ci_floor: float = None) -> int:
    n = 1 << (16 if quick else 18)
    trials = 2 if quick else 3
    peak_bw = probe_peak_bandwidth()
    print(f"# peak bandwidth probe: {peak_bw / 1e9:.2f} GB/s "
          f"(host={jax.default_backend()})")

    results = {"peak_bw_gb_s": round(peak_bw / 1e9, 2)}
    for bits in ((8,) if quick else (8, 7, 5)):
        results.update(run(n, bits, key_value=not quick, peak_bw=peak_bw,
                           trials=trials))

    headline = results["roofline/r=8/fused_vs_chained_speedup"]
    if ci_floor is not None and headline < ci_floor:
        print(f"# FAIL: fused radix at r=8 is {headline:.3f}x chained, "
              f"below the {ci_floor:.2f}x CI floor", file=sys.stderr)
        return 1
    if ci_floor is not None:
        print(f"# ok: fused radix at r=8 is {headline:.3f}x chained "
              f"(floor {ci_floor:.2f}x)")
    if not quick:
        append_trajectory(results, n=n, key_value=True)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small-n smoke (no trajectory append)")
    ap.add_argument("--ci-floor", type=float, default=None,
                    help="exit 1 if fused < FLOOR x chained at r=8")
    a = ap.parse_args()
    sys.exit(main(quick=a.quick, ci_floor=a.ci_floor))
