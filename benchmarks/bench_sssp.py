"""Paper Table 10 analogue: SSSP — Bellman-Ford vs multisplit delta-stepping
(work saved = edge relaxations; validated against Dijkstra)."""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))

from benchmarks.common import row
from sssp import bellman_ford, delta_stepping_multisplit, dijkstra, make_graph


GRAPHS = {
    "dense-low-diameter": dict(n=4000, avg_deg=24, seed=0),     # rmat-like
    "sparse-mid": dict(n=8000, avg_deg=6, seed=1),
    "road-like": dict(n=8000, avg_deg=3, seed=2),
}


def main():
    for name, kw in GRAPHS.items():
        indptr, dst, w = make_graph(**kw)
        ref = dijkstra(indptr, dst, w, 0, kw["n"])

        t0 = time.perf_counter()
        bf_dist, bf_relax = bellman_ford(indptr, dst, w, 0, kw["n"])
        t_bf = time.perf_counter() - t0

        t0 = time.perf_counter()
        ds_dist, ds_relax, calls = delta_stepping_multisplit(
            indptr, dst, w, 0, kw["n"], delta=150
        )
        t_ds = time.perf_counter() - t0
        import numpy as np

        ok = np.array_equal(np.where(ref > 1e17, ds_dist, ref), ds_dist)
        row(f"sssp/{name}/bellman-ford", t_bf, f"relax={bf_relax}")
        row(f"sssp/{name}/multisplit-delta-stepping", t_ds,
            f"relax={ds_relax};work-saved={bf_relax / max(ds_relax, 1):.2f}x;correct={ok}")


if __name__ == "__main__":
    main()
