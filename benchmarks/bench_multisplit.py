"""Paper Tables 3/4/5 analogue: multisplit throughput vs bucket count, for
DMS / WMS / BMS vs the sort-based baselines (RB-sort, direct key sort), for
key-only and key-value, plus Table 6's input-distribution sensitivity.

Rates are Mkeys/s on THIS host (CPU — relative standings are the
reproduction target; absolute GPU numbers are in the paper)."""

import functools

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench, row
from repro.core.identifiers import delta_buckets
from repro.core.multisplit import multisplit
from repro.core.sort import direct_sort_multisplit, rb_sort_multisplit

N = 1 << 18
M_SWEEP = (2, 8, 32, 128, 256)


def _keys(n=N, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randint(0, 2**30, n, dtype=np.uint32))


def _binomial_keys(m, n=N, seed=0):
    rng = np.random.RandomState(seed)
    ids = rng.binomial(m - 1, 0.5, size=n).astype(np.uint32)
    width = 2**30 // m
    return jnp.asarray(ids * width + rng.randint(0, width, n).astype(np.uint32))


def run(key_value=True):
    keys = _keys()
    vals = jnp.arange(N, dtype=jnp.int32)
    kv = "kv" if key_value else "keys"

    for m in M_SWEEP:
        bf = delta_buckets(m, 2**30)
        for method in ("dms", "wms", "bms"):
            f = jax.jit(functools.partial(
                multisplit, bucket_fn=bf, method=method))
            args = (keys, vals) if key_value else (keys,)
            fn = (lambda k, v: f(k, values=v)) if key_value else (lambda k: f(k))
            t = bench(jax.jit(fn), *args)
            row(f"multisplit/{kv}/m={m}/{method}", t, f"{N / t / 1e6:.1f} Mkeys/s")
        # RB-sort baseline (paper §3.4)
        if key_value:
            rb = jax.jit(lambda k, v: rb_sort_multisplit(k, bf, v).keys)
            t = bench(rb, keys, vals)
        else:
            rb = jax.jit(lambda k: rb_sort_multisplit(k, bf).keys)
            t = bench(rb, keys)
        row(f"multisplit/{kv}/m={m}/rb-sort", t, f"{N / t / 1e6:.1f} Mkeys/s")

    # direct full sort (paper §3.3 / Table 3 reference)
    if key_value:
        t = bench(jax.jit(lambda k, v: direct_sort_multisplit(k, v)[0]), keys, vals)
    else:
        t = bench(jax.jit(lambda k: direct_sort_multisplit(k)[0]), keys)
    row(f"multisplit/{kv}/full-radix-sort-baseline", t, f"{N / t / 1e6:.1f} Mkeys/s")


def run_distributions():
    """Table 6 analogue: uniform vs binomial key distribution, m=256."""
    m = 256
    bf = delta_buckets(m, 2**30)
    f = jax.jit(lambda k: multisplit(k, bf, method="bms").keys)
    for name, keys in (("uniform", _keys()), ("binomial", _binomial_keys(m))):
        t = bench(f, keys)
        row(f"multisplit/dist={name}/m=256/bms", t, f"{N / t / 1e6:.1f} Mkeys/s")


def main():
    run(key_value=False)
    run(key_value=True)
    run_distributions()


if __name__ == "__main__":
    main()
