"""Paper Tables 3/4/5 analogue: multisplit throughput vs bucket count, for
DMS / WMS / BMS vs the sort-based baselines (RB-sort, direct key sort), for
key-only and key-value, plus Table 6's input-distribution sensitivity, plus
the fused-plan vs legacy-unfused pipeline comparison (DESIGN.md §6), which
appends a trajectory point to BENCH_multisplit.json.

Rates are Mkeys/s on THIS host (CPU — relative standings are the
reproduction target; absolute GPU numbers are in the paper).

Set ``MS_BENCH_N`` (power-of-two exponent, e.g. 14) to shrink the problem
for CI smoke runs."""

import functools
import os

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import append_trajectory, bench, row
from repro.core.identifiers import delta_buckets
from repro.core.multisplit import (
    batched_multisplit,
    multisplit,
    multisplit_unfused,
    segmented_multisplit,
)
from repro.core.sort import direct_sort_multisplit, rb_sort_multisplit

N = 1 << int(os.environ.get("MS_BENCH_N", "18"))
M_SWEEP = (2, 8, 32, 128, 256)


def _keys(n=N, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randint(0, 2**30, n, dtype=np.uint32))


def _binomial_keys(m, n=N, seed=0):
    rng = np.random.RandomState(seed)
    ids = rng.binomial(m - 1, 0.5, size=n).astype(np.uint32)
    width = 2**30 // m
    return jnp.asarray(ids * width + rng.randint(0, width, n).astype(np.uint32))


def run(key_value=True):
    keys = _keys()
    vals = jnp.arange(N, dtype=jnp.int32)
    kv = "kv" if key_value else "keys"

    for m in M_SWEEP:
        bf = delta_buckets(m, 2**30)
        for method in ("dms", "wms", "bms"):
            f = jax.jit(functools.partial(
                multisplit, bucket_fn=bf, method=method))
            args = (keys, vals) if key_value else (keys,)
            fn = (lambda k, v: f(k, values=v)) if key_value else (lambda k: f(k))
            t = bench(jax.jit(fn), *args)
            row(f"multisplit/{kv}/m={m}/{method}", t, f"{N / t / 1e6:.1f} Mkeys/s")
        # RB-sort baseline (paper §3.4)
        if key_value:
            rb = jax.jit(lambda k, v: rb_sort_multisplit(k, bf, v).keys)
            t = bench(rb, keys, vals)
        else:
            rb = jax.jit(lambda k: rb_sort_multisplit(k, bf).keys)
            t = bench(rb, keys)
        row(f"multisplit/{kv}/m={m}/rb-sort", t, f"{N / t / 1e6:.1f} Mkeys/s")

    # direct full sort (paper §3.3 / Table 3 reference)
    if key_value:
        t = bench(jax.jit(lambda k, v: direct_sort_multisplit(k, v)[0]), keys, vals)
    else:
        t = bench(jax.jit(lambda k: direct_sort_multisplit(k)[0]), keys)
    row(f"multisplit/{kv}/full-radix-sort-baseline", t, f"{N / t / 1e6:.1f} Mkeys/s")


def run_distributions():
    """Table 6 analogue: uniform vs binomial key distribution, m=256."""
    m = 256
    bf = delta_buckets(m, 2**30)
    f = jax.jit(lambda k: multisplit(k, bf, method="bms").keys)
    for name, keys in (("uniform", _keys()), ("binomial", _binomial_keys(m))):
        t = bench(f, keys)
        row(f"multisplit/dist={name}/m=256/bms", t, f"{N / t / 1e6:.1f} Mkeys/s")


def run_fused_vs_legacy(emit_json: bool = True):
    """The tentpole measurement: the plan's fused single-pass postscan vs the
    legacy three-pass (positions, key reorder, value reorder) orchestration.
    Appends one trajectory point per run to BENCH_multisplit.json."""
    results = {}
    keys = _keys()
    vals = jnp.arange(N, dtype=jnp.int32)
    for m in (32, 256):
        bf = delta_buckets(m, 2**30)
        for method in ("wms", "bms"):
            fused = jax.jit(lambda k, v, bf=bf, me=method: multisplit(
                k, bf, values=v, method=me).keys)
            legacy = jax.jit(lambda k, v, bf=bf, me=method: multisplit_unfused(
                k, bf, values=v, method=me).keys)
            t_f = bench(fused, keys, vals)
            t_l = bench(legacy, keys, vals)
            tag = f"m={m}/{method}"
            results[f"{tag}/fused_mkeys_s"] = round(N / t_f / 1e6, 2)
            results[f"{tag}/legacy_mkeys_s"] = round(N / t_l / 1e6, 2)
            results[f"{tag}/speedup"] = round(t_l / t_f, 3)
            row(f"multisplit/kv/{tag}/fused-plan", t_f, f"{N / t_f / 1e6:.1f} Mkeys/s")
            row(f"multisplit/kv/{tag}/legacy-unfused", t_l,
                f"{N / t_l / 1e6:.1f} Mkeys/s ({t_l / t_f:.2f}x slower)")
    if emit_json:
        append_trajectory(results, n=N, key_value=True)
    return results


def run_batched_vs_host_loop(emit_json: bool = True):
    """DESIGN.md §9 measurement: b independent multisplits as ONE batched
    (and one segmented) plan launch vs the host loop every consumer used to
    write (one flat plan call per row). Appends a trajectory point to
    BENCH_multisplit.json; the acceptance bar is batched >= 1.5x host-loop
    on the vmap backend at b=64, n=4096, m=32."""
    b = int(os.environ.get("MS_BENCH_B", "64"))
    n = 1 << int(os.environ.get("MS_BENCH_BN", "12"))        # 4096 per row
    m = 32
    bf = delta_buckets(m, 2**30)
    rng = np.random.RandomState(0)
    keys2d = jnp.asarray(rng.randint(0, 2**30, (b, n), dtype=np.uint32))
    vals2d = jnp.asarray(rng.randint(0, 2**20, (b, n), dtype=np.int32))
    starts = jnp.arange(b, dtype=jnp.int32) * n              # equal segments

    results = {}
    total = b * n

    batched = jax.jit(lambda k, v: batched_multisplit(k, bf, v, method="bms").keys)
    t_b = bench(batched, keys2d, vals2d)

    seg = jax.jit(
        lambda k, v: segmented_multisplit(k, bf, starts, v, method="bms").keys
    )
    t_s = bench(seg, keys2d.reshape(-1), vals2d.reshape(-1))

    # host-loop baseline: what consumers did before plans had a batch axis —
    # one flat multisplit call per row, op-by-op dispatch (consumers call the
    # module-level multisplit eagerly: data pipeline, host-side routing).
    def host_loop(k2, v2):
        return [multisplit(k2[i], bf, v2[i], method="bms").keys for i in range(b)]

    t_h = bench(host_loop, keys2d, vals2d)

    # second reference point: the loop with the per-row call jitted — only
    # the b-per-step dispatch overhead remains.
    row_f = jax.jit(lambda k, v: multisplit(k, bf, v, method="bms").keys)

    def host_loop_jit(k2, v2):
        return [row_f(k2[i], v2[i]) for i in range(b)]

    t_hj = bench(host_loop_jit, keys2d, vals2d)

    tag = f"b={b}/n={n}/m={m}"
    results[f"{tag}/batched_mkeys_s"] = round(total / t_b / 1e6, 2)
    results[f"{tag}/segmented_mkeys_s"] = round(total / t_s / 1e6, 2)
    results[f"{tag}/host_loop_mkeys_s"] = round(total / t_h / 1e6, 2)
    results[f"{tag}/host_loop_jit_mkeys_s"] = round(total / t_hj / 1e6, 2)
    results[f"{tag}/batched_speedup"] = round(t_h / t_b, 3)
    results[f"{tag}/segmented_speedup"] = round(t_h / t_s, 3)
    results[f"{tag}/batched_speedup_vs_jit_loop"] = round(t_hj / t_b, 3)
    row(f"multisplit/kv/{tag}/batched-plan", t_b, f"{total / t_b / 1e6:.1f} Mkeys/s")
    row(f"multisplit/kv/{tag}/segmented-plan", t_s, f"{total / t_s / 1e6:.1f} Mkeys/s")
    row(f"multisplit/kv/{tag}/host-loop", t_h,
        f"{total / t_h / 1e6:.1f} Mkeys/s ({t_h / t_b:.2f}x slower than batched)")
    row(f"multisplit/kv/{tag}/host-loop-jit", t_hj,
        f"{total / t_hj / 1e6:.1f} Mkeys/s ({t_hj / t_b:.2f}x slower than batched)")
    if emit_json:
        append_trajectory(results, n=total, key_value=True)
    return results


def run_fused_labels_vs_materialized(emit_json: bool = True):
    """ISSUE 4 measurement: in-tile fused labels (hashable specs evaluated
    inside the tile stage / kernels) vs the pre-PR-4 materialized-labels
    execution, which the CallableSpec escape hatch still exercises — the
    full n-sized int32 label array is computed, padded and carried through
    the pipeline.  Flat multisplit at m∈{32,256} plus the chained radix
    sort (BitfieldSpec digits, radix_bits∈{5,8} → m∈{32,256} per pass).
    Appends a trajectory point to BENCH_multisplit.json."""
    from repro import ops
    from repro.core.pipeline import radix_passes

    results = {}
    keys = _keys()
    vals = jnp.arange(N, dtype=jnp.int32)

    for m in (32, 256):
        spec = ops.delta_buckets(m, 2**30)
        # identical math, forced through the materialized-labels path
        opaque = ops.from_fn(spec.emit, m, name=f"opaque-delta{m}")
        fused = jax.jit(lambda k, v, s=spec: ops.multisplit(k, s, v).keys)
        mater = jax.jit(lambda k, v, s=opaque: ops.multisplit(k, s, v).keys)
        t_f = bench(fused, keys, vals)
        t_m = bench(mater, keys, vals)
        tag = f"fused_labels/flat/m={m}"
        results[f"{tag}/fused_mkeys_s"] = round(N / t_f / 1e6, 2)
        results[f"{tag}/materialized_mkeys_s"] = round(N / t_m / 1e6, 2)
        results[f"{tag}/speedup"] = round(t_m / t_f, 3)
        row(f"multisplit/kv/{tag}/fused", t_f, f"{N / t_f / 1e6:.1f} Mkeys/s")
        row(f"multisplit/kv/{tag}/materialized", t_m,
            f"{N / t_m / 1e6:.1f} Mkeys/s ({t_m / t_f:.2f}x slower)")

    for bits, m in ((5, 32), (8, 256)):
        fused_sort = jax.jit(
            lambda k, v, b=bits: ops.radix_sort(k, v, radix_bits=b)[0]
        )

        def materialized_sort(k, v, b=bits):
            # per-pass digit as an opaque callable: labels materialize
            from repro.core.multisplit import multisplit as core_multisplit

            for shift, width in radix_passes(b, 32):
                digit = ops.from_fn(
                    ops.BitfieldSpec(shift, width).emit, 1 << width,
                    name=f"opaque-radix{shift}",
                )
                res = core_multisplit(k, digit, v)
                k, v = res.keys, res.values
            return k

        mater_sort = jax.jit(materialized_sort)
        t_f = bench(fused_sort, keys, vals)
        t_m = bench(mater_sort, keys, vals)
        tag = f"fused_labels/radix/m={m}"
        results[f"{tag}/fused_mkeys_s"] = round(N / t_f / 1e6, 2)
        results[f"{tag}/materialized_mkeys_s"] = round(N / t_m / 1e6, 2)
        results[f"{tag}/speedup"] = round(t_m / t_f, 3)
        row(f"sort/kv/{tag}/fused", t_f, f"{N / t_f / 1e6:.1f} Mkeys/s")
        row(f"sort/kv/{tag}/materialized", t_m,
            f"{N / t_m / 1e6:.1f} Mkeys/s ({t_m / t_f:.2f}x slower)")

    if emit_json:
        append_trajectory(results, n=N, key_value=True)
    return results


def run_packed_vs_onehot(emit_json: bool = True, quick: bool = False):
    """ISSUE 5 measurement: the packed-counter kernel family (bit-packed
    subword counters + two-level rank, DESIGN.md §12) vs the dense one-hot
    family, on the SAME plans — only ``family`` differs, outputs are bitwise
    identical.  Flat key-value multisplit sweeping m ∈ {8, 32, 64, 128, 256}
    plus the chained radix sort at radix_bits ∈ {5, 8}; ``quick=True``
    restricts to the m=256 flat + radix points (the CI perf-smoke floor).
    Appends a commit-stamped trajectory point to BENCH_multisplit.json."""
    from repro.core.sort import radix_sort

    results = {}
    keys = _keys()
    vals = jnp.arange(N, dtype=jnp.int32)

    m_sweep = (256,) if quick else (8, 32, 64, 128, 256)
    for m in m_sweep:
        bf = delta_buckets(m, 2**30)
        timed = {}
        for family in ("packed", "onehot"):
            f = jax.jit(lambda k, v, bf=bf, fam=family: multisplit(
                k, bf, values=v, method="bms", family=fam).keys)
            timed[family] = bench(f, keys, vals)
        tag = f"packed_vs_onehot/flat/m={m}"
        results[f"{tag}/packed_mkeys_s"] = round(N / timed["packed"] / 1e6, 2)
        results[f"{tag}/onehot_mkeys_s"] = round(N / timed["onehot"] / 1e6, 2)
        results[f"{tag}/speedup"] = round(timed["onehot"] / timed["packed"], 3)
        row(f"multisplit/kv/{tag}/packed", timed["packed"],
            f"{N / timed['packed'] / 1e6:.1f} Mkeys/s")
        row(f"multisplit/kv/{tag}/onehot", timed["onehot"],
            f"{N / timed['onehot'] / 1e6:.1f} Mkeys/s "
            f"({timed['onehot'] / timed['packed']:.2f}x slower)")

    bit_sweep = ((8, 256),) if quick else ((5, 32), (8, 256))
    for bits, m in bit_sweep:
        timed = {}
        for family in ("packed", "onehot"):
            f = jax.jit(lambda k, v, b=bits, fam=family: radix_sort(
                k, v, radix_bits=b, family=fam)[0])
            timed[family] = bench(f, keys, vals)
        tag = f"packed_vs_onehot/radix/m={m}"
        results[f"{tag}/packed_mkeys_s"] = round(N / timed["packed"] / 1e6, 2)
        results[f"{tag}/onehot_mkeys_s"] = round(N / timed["onehot"] / 1e6, 2)
        results[f"{tag}/speedup"] = round(timed["onehot"] / timed["packed"], 3)
        row(f"sort/kv/{tag}/packed", timed["packed"],
            f"{N / timed['packed'] / 1e6:.1f} Mkeys/s")
        row(f"sort/kv/{tag}/onehot", timed["onehot"],
            f"{N / timed['onehot'] / 1e6:.1f} Mkeys/s "
            f"({timed['onehot'] / timed['packed']:.2f}x slower)")

    if emit_json:
        append_trajectory(results, n=N, key_value=True)
    return results


def run_oblivious_vs_gather(emit_json: bool = True, quick: bool = False):
    """ISSUE 8 measurement (DESIGN.md §15): the gather-free OBLIVIOUS kernel
    bodies (one-hot selects, 16-bit rank planes, permutation matmuls — the
    only forms Mosaic lowers with ``interpret=False``) vs the legacy gather
    forms, through the SAME pallas entry points in interpret mode, outputs
    bitwise identical.  Points: the packed positions/fused kernels at m=256
    and the fused2 pair kernels at 2r=8, plus the RangeSpec balanced-tree
    emit vs the serialized compare chain at s ∈ {31, 255} (satellite 1).
    ``speedup = t_gather / t_oblivious``; the CI floor asserts the oblivious
    forms cost <= ~1.1x the gather forms even on a host, where gathers are
    native.  Appends a trajectory point to BENCH_multisplit.json."""
    from repro.core.identifiers import BitfieldSpec, RangeSpec
    from repro.kernels import ops as kops

    results = {}
    t = 1024                                   # the oblivious packed tile cap
    n_tiles = max(N // t, 1)
    rng = np.random.RandomState(0)
    m = 256
    ids = jnp.asarray(rng.randint(0, m, (n_tiles, t), dtype=np.int32))
    keys = jnp.asarray(rng.randint(0, 2**30, (n_tiles, t)).astype(np.uint32))
    vals = jnp.arange(n_tiles * t, dtype=jnp.int32).reshape(n_tiles, t)
    g = jnp.asarray(rng.randint(0, 1 << 20, (n_tiles, m), dtype=np.int32))

    def point(tag, fn):
        timed = {}
        for form in ("oblivious", "gather"):
            timed[form] = bench(
                functools.partial(fn, oblivious=(form == "oblivious")))
        results[f"oblivious_vs_gather/{tag}/oblivious_s"] = round(timed["oblivious"], 5)
        results[f"oblivious_vs_gather/{tag}/gather_s"] = round(timed["gather"], 5)
        results[f"oblivious_vs_gather/{tag}/speedup"] = round(
            timed["gather"] / timed["oblivious"], 3)
        row(f"kernels/oblivious_vs_gather/{tag}/oblivious", timed["oblivious"],
            f"{timed['gather'] / timed['oblivious']:.2f}x vs gather")

    point(f"packed_positions/m={m}", lambda oblivious: kops.packed_tile_positions(
        ids, g, num_buckets=m, oblivious=oblivious))
    point(f"packed_fused/m={m}", lambda oblivious: kops.packed_fused_postscan_reorder(
        ids, g, keys, vals, num_buckets=m, oblivious=oblivious)[0])

    pair = BitfieldSpec(0, 8)
    point("fused2_fused/onehot/2r=8",
          lambda oblivious: kops.fused2_fused_postscan_reorder(
              keys, g, vals, spec=pair, split=4, oblivious=oblivious)[0])
    if not quick:
        point("fused2_fused/packed/2r=8",
              lambda oblivious: kops.fused2_fused_postscan_reorder(
                  keys, g, vals, spec=pair, split=4, family="packed",
                  oblivious=oblivious)[0])

    # RangeSpec: balanced-tree emit vs the legacy serialized compare chain
    flat = _keys()
    for s in (31, 255):
        spec = RangeSpec(tuple(int(x) for x in np.sort(
            rng.choice(2**30, size=s, replace=False)).tolist()))
        t_tree = bench(jax.jit(spec.emit_in_kernel), flat)
        t_chain = bench(jax.jit(spec._emit_chain), flat)
        tag = f"oblivious_vs_gather/rangespec/s={s}"
        results[f"{tag}/tree_s"] = round(t_tree, 5)
        results[f"{tag}/chain_s"] = round(t_chain, 5)
        results[f"{tag}/speedup"] = round(t_chain / t_tree, 3)
        row(f"kernels/rangespec/s={s}/tree-emit", t_tree,
            f"{t_chain / t_tree:.2f}x vs chain")

    if emit_json:
        append_trajectory(results, n=N, key_value=True)
    return results


def main(quick: bool = False):
    if quick:
        # smoke sizes must not pollute the full-sweep trajectory history
        run_packed_vs_onehot(quick=True, emit_json=False)
        run_oblivious_vs_gather(quick=True, emit_json=False)
        return
    run(key_value=False)
    run(key_value=True)
    run_distributions()
    run_fused_vs_legacy()
    run_batched_vs_host_loop()
    run_fused_labels_vs_materialized()
    run_packed_vs_onehot()
    run_oblivious_vs_gather()


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--quick", action="store_true",
        help="only the packed-vs-onehot m=256 points (CI perf smoke)",
    )
    main(quick=ap.parse_args().quick)
