"""End-to-end driver: train a ~100M-parameter MoE LM with multisplit
dispatch for a few hundred steps (paper technique inside a real training
loop: data pipeline -> supervisor -> checkpoints -> loss curve).

    PYTHONPATH=src python examples/train_moe.py                # ~25M, quick
    PYTHONPATH=src python examples/train_moe.py --hundred-m    # ~110M, longer
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig, TrainConfig
from repro.data import DataPipeline
from repro.launch import steps as S
from repro.models import model as M
from repro.optim import adamw_init
from repro.parallel.sharding import init_params, param_count
from repro.runtime import Supervisor, TrainLoopConfig


def make_cfg(hundred_m: bool) -> ModelConfig:
    if hundred_m:
        return ModelConfig(
            name="moe-110m", family="moe", n_layers=8, d_model=512, n_heads=8,
            n_kv=8, d_ff=1408, vocab=8192, dtype="float32",
            moe=MoEConfig(num_experts=8, top_k=2, dispatch="multisplit",
                          capacity_factor=1.5),
            attn_chunk=256, loss_chunk=256,
        )
    return ModelConfig(
        name="moe-25m", family="moe", n_layers=4, d_model=256, n_heads=4,
        n_kv=4, d_ff=704, vocab=4096, dtype="float32",
        moe=MoEConfig(num_experts=8, top_k=2, dispatch="multisplit",
                      capacity_factor=1.5),
        attn_chunk=256, loss_chunk=256,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_moe_ckpt")
    args = ap.parse_args()

    cfg = make_cfg(args.hundred_m)
    steps = args.steps or (300 if args.hundred_m else 120)
    tc = TrainConfig(global_batch=args.batch, seq_len=args.seq, lr=1e-3,
                     total_steps=steps, warmup_steps=20)

    decls = M.decl_model(cfg)
    print(f"[train_moe] {cfg.name}: {param_count(decls)/1e6:.1f}M params, "
          f"{cfg.moe.num_experts} experts top-{cfg.moe.top_k}, "
          f"dispatch={cfg.moe.dispatch}, {steps} steps")
    params = init_params(decls, jax.random.PRNGKey(0))
    state = S.TrainState(params=params, opt=adamw_init(params, tc))

    pipe = DataPipeline(vocab=cfg.vocab, seq_len=tc.seq_len, batch_per_host=tc.global_batch)
    train_step = jax.jit(S.make_train_step(cfg, tc), donate_argnums=(0,))
    sup = Supervisor(
        train_step,
        lambda step: jax.tree.map(jnp.asarray, pipe.batch_at(step)),
        TrainLoopConfig(total_steps=steps, checkpoint_every=max(steps // 3, 25),
                        checkpoint_dir=args.ckpt_dir, log_every=10),
    )
    state = sup.run(state)

    losses = [h["loss"] for h in sup.history]
    drops = [h.get("moe_drop_fraction", 0.0) for h in sup.history]
    print(f"[train_moe] loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(drop fraction last: {drops[-1]:.3f})")
    assert losses[-1] < losses[0], "MoE LM failed to learn"
    print("[train_moe] OK")


if __name__ == "__main__":
    main()
