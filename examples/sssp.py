"""Delta-stepping SSSP with multisplit bucketing (paper §7.2).

Reproduces the paper's claim structurally: the Bucketing strategy needs a
fast multisplit to beat Near-Far / Bellman-Ford; we bucket each frontier by
``dist // delta`` with the multisplit primitive and process the lowest
bucket. Validated against a serial Dijkstra oracle, and compared against
Bellman-Ford on total edge relaxations.

    PYTHONPATH=src python examples/sssp.py [--n 20000] [--deg 12]
"""

import argparse
import heapq
import time

import numpy as np
import jax.numpy as jnp

from repro.core.identifiers import from_fn
from repro.core.multisplit import multisplit


def make_graph(n, avg_deg, seed=0, wmax=1000):
    """rmat-flavored random digraph in CSR."""
    rng = np.random.RandomState(seed)
    m = n * avg_deg
    # preferential-ish: square of uniform biases to low ids (rmat-like skew)
    src = (rng.rand(m) ** 2 * n).astype(np.int64)
    dst = (rng.rand(m) ** 2 * n).astype(np.int64)
    w = rng.randint(1, wmax, size=m).astype(np.int64)
    order = np.argsort(src, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    indptr = np.searchsorted(src, np.arange(n + 1))
    return indptr, dst, w


def dijkstra(indptr, dst, w, source, n):
    dist = np.full(n, np.iinfo(np.int64).max, np.int64)
    dist[source] = 0
    pq = [(0, source)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for e in range(indptr[u], indptr[u + 1]):
            v, nd = dst[e], d + w[e]
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return dist


def bellman_ford(indptr, dst, w, source, n):
    """All-edges-every-round baseline; counts relaxations."""
    src = np.repeat(np.arange(n), np.diff(indptr))
    dist = np.full(n, np.iinfo(np.int64).max // 2, np.int64)
    dist[source] = 0
    relaxations = 0
    for _ in range(n):
        nd = dist[src] + w
        # scatter-min relax of every edge
        upd = np.full(n, np.iinfo(np.int64).max // 2, np.int64)
        np.minimum.at(upd, dst, nd)
        relaxations += len(w)
        merged = np.minimum(dist, upd)
        if np.array_equal(merged, dist):
            break
        dist = merged
    return dist, relaxations


def delta_stepping_multisplit(indptr, dst, w, source, n, delta=100, num_buckets=10):
    """Paper §7.2 Bucketing strategy, with OUR multisplit doing the bucketing."""
    INF = np.iinfo(np.int64).max // 2
    dist = np.full(n, INF, np.int64)
    dist[source] = 0
    frontier = np.asarray([source], np.int64)
    relaxations = 0
    ms_calls = 0
    floor = 0
    while frontier.size:
        # classify frontier into `num_buckets` delta-buckets above `floor`
        fd = dist[frontier]
        bucket_of = from_fn(
            lambda u, f=floor, d=delta, m=num_buckets: jnp.clip(
                (u - f) // d, 0, m - 1
            ).astype(jnp.int32),
            num_buckets,
        )
        pad = (-frontier.size) % 64 or 0
        keys = jnp.asarray(np.concatenate([fd, np.full(pad, floor + delta * num_buckets)]))
        vals = jnp.asarray(np.concatenate([frontier, np.full(pad, -1)]).astype(np.int32))
        out = multisplit(keys, bucket_of, vals, method="wms", tile=1024)
        ms_calls += 1
        counts = np.asarray(out.bucket_counts)
        verts_sorted = np.asarray(out.values)
        # process ONLY the lowest non-empty bucket (others return to the pool)
        b0 = int(np.argmax(counts > 0))
        lo = int(np.asarray(out.bucket_starts)[b0])
        active = verts_sorted[lo : lo + counts[b0]]
        active = active[active >= 0]
        rest = np.concatenate([verts_sorted[:lo], verts_sorted[lo + counts[b0]:]])
        rest = rest[rest >= 0].astype(np.int64)

        # relax all out-edges of the active bucket (vectorized)
        starts, ends = indptr[active], indptr[active + 1]
        eidx = np.concatenate([np.arange(s, e) for s, e in zip(starts, ends)]) \
            if active.size else np.empty(0, np.int64)
        relaxations += eidx.size
        if eidx.size:
            u_rep = np.repeat(active, ends - starts)
            nd = dist[u_rep] + w[eidx]
            tgt = dst[eidx]
            upd = np.full(n, INF, np.int64)
            np.minimum.at(upd, tgt, nd)
            improved = np.nonzero(upd < dist)[0]
            dist = np.minimum(dist, upd)
        else:
            improved = np.empty(0, np.int64)
        frontier = np.unique(np.concatenate([rest, improved]))
        if frontier.size:
            floor = int(dist[frontier].min())
    return dist, relaxations, ms_calls


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--deg", type=int, default=12)
    ap.add_argument("--delta", type=int, default=150)
    args = ap.parse_args()

    indptr, dst, w = make_graph(args.n, args.deg)
    print(f"graph: {args.n} vertices, {len(w)} edges")

    t0 = time.time()
    ref = dijkstra(indptr, dst, w, 0, args.n)
    t_dij = time.time() - t0

    t0 = time.time()
    bf_dist, bf_relax = bellman_ford(indptr, dst, w, 0, args.n)
    t_bf = time.time() - t0
    assert np.array_equal(np.where(ref > 1e17, bf_dist, ref), bf_dist), "BF wrong"

    t0 = time.time()
    ds_dist, ds_relax, ms_calls = delta_stepping_multisplit(
        indptr, dst, w, 0, args.n, delta=args.delta
    )
    t_ds = time.time() - t0
    ok = np.array_equal(np.where(ref > 1e17, ds_dist, ref), ds_dist)
    assert ok, "delta-stepping result != Dijkstra"

    print(f"dijkstra (oracle):        {t_dij*1e3:8.1f} ms")
    print(f"bellman-ford:             {t_bf*1e3:8.1f} ms  relaxations={bf_relax:,}")
    print(f"multisplit delta-stepping:{t_ds*1e3:8.1f} ms  relaxations={ds_relax:,} "
          f"(multisplit calls: {ms_calls})")
    print(f"work saved vs Bellman-Ford: {bf_relax / max(ds_relax,1):.2f}x fewer relaxations")
    print("sssp OK")


if __name__ == "__main__":
    main()
