"""Quickstart: the multisplit primitive in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core.identifiers import delta_buckets, from_fn
from repro.core.multisplit import multisplit, segmented_multisplit
from repro.core.sort import radix_sort
from repro.core.histogram import histogram_even

# --- 1. multisplit 256K keys into 32 equal-width buckets (paper §6 setup) ---
keys = jnp.asarray(np.random.RandomState(0).randint(0, 2**30, 1 << 18, dtype=np.uint32))
values = jnp.arange(keys.shape[0], dtype=jnp.int32)           # payload
bf = delta_buckets(32, 2**30)

out = multisplit(keys, bf, values, method="bms")              # {local, global, local}
print(f"bucket starts: {np.asarray(out.bucket_starts)[:6]} ...")
print(f"bucket counts: {np.asarray(out.bucket_counts)[:6]} ...")
assert bool((jnp.diff(bf(out.keys)) >= 0).all()), "bucket-contiguous"

# --- 2. a user-defined bucket function (keys need not be comparable) --------
parity = from_fn(lambda u: (u & 1).astype(jnp.int32), 2, name="parity")
evens_first = multisplit(keys, parity)
print(f"evens: {int(evens_first.bucket_counts[0])}, odds: {int(evens_first.bucket_counts[1])}")

# --- 3. multisplit-based radix sort (paper §7.1) ----------------------------
sorted_keys, sorted_vals = radix_sort(keys, values, radix_bits=8)
assert bool((jnp.diff(sorted_keys.astype(jnp.int64)) >= 0).all())
print(f"radix sort OK: first keys {np.asarray(sorted_keys[:4])}")

# --- 4. segmented routing: many ragged multisplits in ONE call --------------
# Four "requests" of different sizes share one flat buffer; each is bucketed
# independently (per-request counts, per-request stability) in one launch —
# the building block for batched serving (DESIGN.md §9).
segment_starts = jnp.asarray([0, 50_000, 50_000, 180_000], jnp.int32)  # one empty
seg = segmented_multisplit(keys, bf, segment_starts, values)
print(f"per-request bucket counts, shape {seg.bucket_counts.shape}:")
print(f"  request 0 -> {np.asarray(seg.bucket_counts[0, :4])} ...")
print(f"  request 1 (empty) -> {np.asarray(seg.bucket_counts[1, :4])} ...")
assert int(seg.bucket_counts.sum()) == keys.shape[0]
# each request's span is bucket-contiguous on its own
ids0 = bf(seg.keys[:50_000])
assert bool((jnp.diff(ids0) >= 0).all()), "request 0 bucket-contiguous"

# --- 5. device-wide histogram (paper §7.3): a counts_only partial pipeline --
# histogram() runs {prescan, tree-reduce} only — no scan, no scatter — via
# mode="counts_only" (DESIGN.md §10); the same partial pipeline is one call
# away for ANY bucket identifier:
h = histogram_even(keys.astype(jnp.float32), 0.0, float(2**30), 64)
print(f"histogram (64 even bins): min {int(h.min())}, max {int(h.max())}")
counts = multisplit(keys, bf, mode="counts_only").bucket_counts
assert int(counts.sum()) == keys.shape[0]
assert bool((counts == out.bucket_counts).all()), "counts_only == full pipeline"
print(f"counts_only histogram over {bf.name}: {np.asarray(counts[:6])} ...")
print("quickstart OK")
