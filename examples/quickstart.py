"""Quickstart: the transform-native multisplit API (`repro.ops`) in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro import ops

# --- 1. multisplit 256K keys into 32 equal-width buckets (paper §6 setup) ---
# Specs are declarative, HASHABLE values: equal specs share one jit trace,
# and on kernel backends their bucket function is evaluated in-register
# inside the tile kernels (no label array ever exists).
keys = jnp.asarray(np.random.RandomState(0).randint(0, 2**30, 1 << 18, dtype=np.uint32))
values = jnp.arange(keys.shape[0], dtype=jnp.int32)           # payload
spec = ops.delta_buckets(32, 2**30)

out = ops.multisplit(keys, spec, values, method="bms")        # {local, global, local}
print(f"bucket starts: {np.asarray(out.bucket_starts)[:6]} ...")
print(f"bucket counts: {np.asarray(out.bucket_counts)[:6]} ...")
assert bool((jnp.diff(spec(out.keys)) >= 0).all()), "bucket-contiguous"

# --- 2. spec zoo: splitters, radix digits, user escape hatch ----------------
splitters = ops.range_buckets([1 << 20, 1 << 25, 1 << 28])    # sample-sort style
print(f"range{splitters.num_buckets} counts:",
      np.asarray(ops.histogram(keys, splitters)))
parity = ops.from_fn(lambda u: (u & 1).astype(jnp.int32), 2, name="parity")
evens_first = ops.multisplit(keys, parity)                    # CallableSpec: escape hatch
print(f"evens: {int(evens_first.bucket_counts[0])}, odds: {int(evens_first.bucket_counts[1])}")

# --- 3. transforms are first-class ------------------------------------------
# vmap: one BATCHED plan launch for the whole stack (bitwise == per-row loop)
stack = keys[: 8 * 4096].reshape(8, 4096)
per_row_counts = jax.vmap(lambda k: ops.multisplit(k, spec).bucket_counts)(stack)
print(f"vmap'd counts shape: {per_row_counts.shape}")         # (8, 32)

# grad: the key-value multisplit is differentiable in the values — backward
# is the inverse gather of the forward permutation
v = jnp.asarray(np.random.RandomState(1).rand(4096).astype(np.float32))
loss = lambda v: (ops.multisplit_key_value(keys[:4096], v, spec).values ** 2).sum()
g = jax.grad(loss)(v)
assert bool(jnp.allclose(g, 2 * v)), "permutation-equivariant gradient"
print(f"grad through multisplit OK (|g| = {float(jnp.linalg.norm(g)):.2f})")

# --- 4. multisplit-based radix sort (paper §7.1) ----------------------------
# = chained BitfieldSpec passes, digits extracted inside the kernels
sorted_keys, sorted_vals = ops.radix_sort(keys, values, radix_bits=8)
assert bool((sorted_keys[1:] >= sorted_keys[:-1]).all())
print(f"radix sort OK: first keys {np.asarray(sorted_keys[:4])}")

# --- 5. segmented routing: many ragged multisplits in ONE call --------------
# Four "requests" of different sizes share one flat buffer; each is bucketed
# independently (per-request counts, per-request stability) in one launch —
# the building block for batched serving (DESIGN.md §9).
segment_starts = jnp.asarray([0, 50_000, 50_000, 180_000], jnp.int32)  # one empty
seg = ops.segmented_multisplit(keys, spec, segment_starts, values)
print(f"per-request bucket counts, shape {seg.bucket_counts.shape}:")
print(f"  request 0 -> {np.asarray(seg.bucket_counts[0, :4])} ...")
print(f"  request 1 (empty) -> {np.asarray(seg.bucket_counts[1, :4])} ...")
assert int(seg.bucket_counts.sum()) == keys.shape[0]
ids0 = spec(seg.keys[:50_000])
assert bool((jnp.diff(ids0) >= 0).all()), "request 0 bucket-contiguous"

# --- 6. partial pipelines (paper §7.3): counts_only / positions_only --------
h = ops.histogram(keys.astype(jnp.float32), ops.even_buckets(0.0, float(2**30), 64))
print(f"histogram (64 even bins): min {int(h.min())}, max {int(h.max())}")
counts = ops.multisplit(keys, spec, mode="counts_only").bucket_counts
assert bool((counts == out.bucket_counts).all()), "counts_only == full pipeline"
ranks = ops.multisplit(keys, spec, mode="positions_only").permutation
assert int(ranks.shape[0]) == keys.shape[0]

# --- 7. kernel families + autotuning (DESIGN.md §12) ------------------------
# Wide bucket axes auto-select the PACKED subword-counter family (bitwise
# identical to the dense one-hot family, ~flat per-key cost in m); the
# decision — and WHY it was made — is inspectable, and `autotune_tile`
# searches the (tile, family) grid jointly and pins the measured winner.
from repro.core.pipeline import autotune_tile, family_decision, make_plan

wide = make_plan(keys.shape[0], 256, bucket_fn=ops.delta_buckets(256, 2**30))
fam, why = family_decision(keys.shape[0], 256, "bms", "vmap")
print(f"m=256 plan: family={wide.family!r}, tile={wide.tile} ({why})")
tuned = autotune_tile(1 << 14, ops.delta_buckets(256, 2**30),
                      candidates=(1024, 4096), trials=1)
print(f"autotuned (tile, family) for m=256: "
      f"({tuned}, {family_decision(1 << 14, 256, 'bms', 'vmap')[0]!r})")

# --- 8. fused digit pairs (DESIGN.md §13) -----------------------------------
# fuse_digits=True sorts TWO radix digits per HBM round-trip: each tile is
# loaded into VMEM once and multisplit over the combined 2r-bit digit, so a
# 32-bit r=8 sort runs 2 sweeps instead of 4 (~2x chained on the host bench).
# LSD stability makes the fused result bitwise identical to the chained one.
fused_keys, fused_vals = ops.radix_sort(keys, values, radix_bits=8,
                                        fuse_digits=True)
assert bool((fused_keys == sorted_keys).all()), "fused == chained, bitwise"
assert bool((fused_vals == sorted_vals).all())
from repro.core.pipeline import RadixPipeline

pipe = RadixPipeline(keys.shape[0], radix_bits=8, backend="vmap",
                     fuse_digits=True)
print(f"fused r=8 sort: {pipe.n_sweeps} sweeps for {pipe.n_passes} digits, "
      f"stage 0 = {pipe.plans[0].stages()[0]!r}")
# Roofline tracking (ideal bytes vs measured bandwidth, per mode):
#   PYTHONPATH=src:. python benchmarks/roofline_multisplit.py [--quick]

# --- 9. self-tuning (DESIGN.md §14) -----------------------------------------
# Opt in and every per-shape decision (tile, family, fused-pair sub_bits,
# vmap label fusion) resolves by MEASUREMENT on first miss, persisting the
# winners per host (~/.cache/repro-multisplit by default, or
# set_autotune(cache_dir=...)) — the second process pays zero search time.
# Everything stays heuristic until you arm it; REPRO_AUTOTUNE=1 works too.
import tempfile

from repro.core.pipeline import clear_tile_cache

with tempfile.TemporaryDirectory() as d:                # demo: throwaway cache
    ops.set_autotune(True, cache_dir=d, trials=1, candidates=(1024, 4096))
    clear_tile_cache()                                  # force fresh misses
    tuned_plan = make_plan(1 << 14, 256, bucket_fn=ops.delta_buckets(256, 2**30))
    fam, why = family_decision(1 << 14, 256, "bms", "vmap")
    print(f"self-tuned plan: tile={tuned_plan.tile}, family={fam!r}")
    print(f"  reason: {why[:72]}...")
    ops.set_autotune(False)
    clear_tile_cache()
# The heuristic-vs-tuned gap is tracked and CI-gated:
#   PYTHONPATH=src:. python benchmarks/autotune_drift.py --quick --ci-max 1.25

# --- 10. the compiled kernel path (DESIGN.md §15) ---------------------------
# backend="pallas" means COMPILED-WHEN-AVAILABLE: on a TPU host the kernels
# lower under Mosaic (interpret=False) — every body is gather/scatter-free
# by construction (linted: python -m repro.kernels.lint) — and on a
# TPU-less host the same plans fall back to the interpreter automatically.
# backend="pallas-interpret" stays pinned to the interpreter (the debug
# target). Override either way per process with the environment variable:
#   REPRO_INTERPRET=1   force interpretation everywhere (debug on TPU)
#   REPRO_INTERPRET=0   force compiled lowering (e.g. CPU Mosaic tests)
from repro.core.pipeline import get_backend

b = get_backend("pallas")
print(f"pallas: compiled={b.compiled}, interpret-now={b.stages.interpret}")

# --- 11. request-level serving (DESIGN.md §16) ------------------------------
# Continuous batching: many concurrent users' token streams coalesce into
# ONE segmented plan launch per step (admission by RangeSpec length
# bucketing, warm-plan reuse, bounded fault retry, load shedding). The
# exported metrics are exact nearest-rank percentiles + sustained QPS —
# bench: PYTHONPATH=src:. python benchmarks/bench_serving.py --quick
# CLI:   PYTHONPATH=src python -m repro.launch.serve --traffic
from repro.serving import ServerLoop, ServingConfig

loop = ServerLoop(ServingConfig(num_experts=8, capacity=16,
                                max_batch_requests=16, max_batch_tokens=256))
loop.prewarm()                              # compile every shape class now
rng = np.random.RandomState(0)
for n_tok in (5, 0, 17, 3, 9, 12):          # ragged streams, one idle user
    loop.submit(rng.randint(0, 8, size=n_tok).astype(np.int32))
served = loop.drain()                       # graceful flush + final metrics
print(f"serving: completed={served['completed']:.0f} in "
      f"{served['steps']:.0f} step(s), p99="
      f"{served['latency_p99_ms']:.2f}ms, "
      f"occupancy={served['batch_token_occupancy']:.2f}, "
      f"dropped_by_bug={served['dropped_by_bug']:.0f}")
assert served["dropped_by_bug"] == 0        # conservation: always

# --- 12. resilience: degradation ladder + runtime verification (§17) --------
# On real hardware a kernel can fail to lower, run out of VMEM, or answer
# wrong. The dispatch layer classifies failures and degrades gracefully:
# transient -> retry in place; resource -> halve the tile (pinning the
# survivor); persistent -> demote pallas -> pallas-interpret -> vmap ->
# reference, with a persistent circuit breaker quarantining plan classes
# that keep failing. Opt-in runtime verification re-checks outputs against
# the paper's invariants and recovers via the reference oracle on mismatch:
#   REPRO_VERIFY=1   counts conservation + offset monotonicity (O(m))
#   REPRO_VERIFY=2   + true-permutation / bucket-order proof (O(n log n))
#   REPRO_STRICT=1   disable ALL fallback: fail loud with the original error
ops.set_verify(2)                           # or REPRO_VERIFY=2 per process
verified = ops.multisplit(keys, spec, backend="pallas")
ops.set_verify(None)
from repro.runtime import resilience

counters = {k: v for k, v in resilience.stats().items() if v}
print(f"resilience: verified launch OK, counters={counters or '{}'}")
assert resilience.stats()["verify_mismatches"] == 0

print("quickstart OK")
