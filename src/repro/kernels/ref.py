"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the semantic ground truth; the kernels must match it
bit-exactly (integer outputs) across the shape/dtype sweeps in
``tests/test_kernels.py``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def tile_histograms(ids_tiled: Array, num_buckets: int) -> Array:
    """(L, T) int32 bucket ids -> (L, m) int32 per-tile histograms."""
    one_hot = ids_tiled[..., None] == jnp.arange(num_buckets)[None, None, :]
    return one_hot.astype(jnp.int32).sum(axis=1)


def tile_positions(ids_tiled: Array, g: Array, num_buckets: int) -> Array:
    """(L, T) ids + (L, m) global bases -> (L, T) final destinations.

    position = g[tile, id] + (stable rank of the element within its bucket
    inside its tile)  — paper eq. (2) postscan.
    """
    one_hot = (ids_tiled[..., None] == jnp.arange(num_buckets)[None, None, :]).astype(jnp.int32)
    incl = jnp.cumsum(one_hot, axis=1)
    local = (one_hot * (incl - 1)).sum(-1)
    base = (one_hot * g[:, None, :]).sum(-1)
    return (base + local).astype(jnp.int32)


def tile_reorder(
    ids_tiled: Array, keys_tiled: Array, values_tiled: Optional[Array], num_buckets: int
) -> Tuple[Array, Optional[Array], Array]:
    """Stable bucket-major reorder of each tile (paper §4.7).

    Returns (keys_reordered, values_reordered, tile_offset) where
    ``tile_offset[l, t]`` is the within-tile destination of element t.
    """
    m = num_buckets
    one_hot = (ids_tiled[..., None] == jnp.arange(m)[None, None, :]).astype(jnp.int32)
    incl = jnp.cumsum(one_hot, axis=1)
    local = (one_hot * (incl - 1)).sum(-1)
    hist = incl[:, -1, :]
    starts = jnp.cumsum(hist, axis=1) - hist
    dest = (one_hot * starts[:, None, :]).sum(-1) + local

    def scatter_row(dest_row, x_row):
        return jnp.zeros_like(x_row).at[dest_row].set(x_row)

    keys_r = jax.vmap(scatter_row)(dest, keys_tiled)
    values_r = None
    if values_tiled is not None:
        values_r = jax.vmap(scatter_row)(dest, values_tiled)
    return keys_r, values_r, dest.astype(jnp.int32)


def fused_postscan_reorder(
    ids_tiled: Array,
    g: Array,
    keys_tiled: Array,
    values_tiled: Optional[Array],
    num_buckets: int,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Oracle for the fused postscan+reorder kernel: the composition of
    ``tile_positions`` (global destinations) and ``tile_reorder`` applied to
    keys, values AND the destination vector; the element-ordered destination
    map rides along as the fourth output."""
    pos = tile_positions(ids_tiled, g, num_buckets)
    keys_r, values_r, dest = tile_reorder(ids_tiled, keys_tiled, values_tiled, num_buckets)

    def scatter_row(dest_row, x_row):
        return jnp.zeros_like(x_row).at[dest_row].set(x_row)

    pos_r = jax.vmap(scatter_row)(dest, pos)
    return keys_r, values_r, pos_r.astype(jnp.int32), pos.astype(jnp.int32)


def radix_fused_postscan_reorder(
    keys_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    shift: int,
    bits: int,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Oracle for the fused radix postscan: digit extraction + fused reorder."""
    ids = (
        (keys_tiled.astype(jnp.uint32) >> jnp.uint32(shift))
        & jnp.uint32((1 << bits) - 1)
    ).astype(jnp.int32)
    return fused_postscan_reorder(ids, g, keys_tiled, values_tiled, 1 << bits)


def device_histogram(ids_tiled: Array, num_buckets: int) -> Array:
    """(L, T) ids -> (m,) global histogram (paper §7.3, atomic-free)."""
    return tile_histograms(ids_tiled, num_buckets).sum(axis=0)


def radix_tile_histograms(keys_tiled: Array, shift: int, bits: int) -> Array:
    """Fused radix-digit extraction + per-tile histogram (paper §7.1)."""
    ids = ((keys_tiled.astype(jnp.uint32) >> jnp.uint32(shift)) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)
    return tile_histograms(ids, 1 << bits)


def flash_attention_ref(q: Array, k: Array, v: Array, causal: bool = True) -> Array:
    """Naive softmax attention oracle. q/k/v: (BH, S, hd)."""
    import numpy as np

    hd = q.shape[-1]
    s = jnp.einsum("bid,bjd->bij", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(hd)
    if causal:
        n = q.shape[1]
        mask = jnp.tril(jnp.ones((n, n), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bij,bjd->bid", p, v.astype(jnp.float32)).astype(q.dtype)
