"""Fused device-wide histogram kernel (paper §7.3).

The GPU version atomically adds per-block histograms into global memory; the
TPU version exploits the *sequential* Pallas grid on a core: all tiles
accumulate into ONE revisited output block held in VMEM — zero atomics, zero
extra HBM round-trips (DESIGN.md §2). Bucket identification (even / range /
radix digit) is fused into the kernel, mirroring the paper's fused bucket
identifiers (§6 "Bucket identification").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import one_hot_f32 as _one_hot, pad_lanes as _pad_lanes

Array = jnp.ndarray


def _device_hist_kernel(ids_ref, hist_ref, *, m_pad: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[0, :] = jnp.zeros((m_pad,), jnp.int32)

    one_hot = _one_hot(ids_ref[0, :], m_pad)
    hist_ref[0, :] += one_hot.sum(axis=0).astype(jnp.int32)


def device_histogram_pallas(ids_tiled: Array, num_buckets: int, *, interpret: bool = True) -> Array:
    """(L, T) int32 ids -> (m,) global histogram, single revisited block."""
    n_tiles, t = ids_tiled.shape
    m_pad = _pad_lanes(num_buckets)
    out = pl.pallas_call(
        functools.partial(_device_hist_kernel, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (0, 0)),   # revisit: accumulate
        out_shape=jax.ShapeDtypeStruct((1, m_pad), jnp.int32),
        interpret=interpret,
    )(ids_tiled)
    return out[0, :num_buckets]


def _even_ids_kernel(keys_ref, ids_ref, *, lo: float, inv_width: float, m: int):
    x = keys_ref[0, :].astype(jnp.float32)
    ids = jnp.floor((x - lo) * inv_width).astype(jnp.int32)
    ids_ref[0, :] = jnp.clip(ids, 0, m - 1)


def even_bucket_ids_pallas(
    keys_tiled: Array, lo: float, hi: float, num_buckets: int, *, interpret: bool = True
) -> Array:
    """Fused even-bucket identification (f(u) = ⌊(u - lo)/Δ⌋), (L, T) -> (L, T)."""
    n_tiles, t = keys_tiled.shape
    inv_width = num_buckets / (hi - lo)
    return pl.pallas_call(
        functools.partial(_even_ids_kernel, lo=lo, inv_width=inv_width, m=num_buckets),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(keys_tiled)
