"""Fused radix-pass kernel: digit extraction + tile histogram + positions.

One multisplit-sort pass (paper §7.1) needs the bucket identifier
``f_k(u) = (u >> k·r) & (2^r − 1)`` evaluated twice (prescan + postscan).
Fusing the shift/mask into the kernels avoids materializing the label vector
in HBM — the exact overhead the paper's RB-sort baseline pays (§3.4) and its
multisplit avoids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.multisplit_tile import _cumsum_mxu, _one_hot, _pad_lanes

Array = jnp.ndarray


def _digit(keys: Array, shift: int, bits: int) -> Array:
    u = keys.astype(jnp.uint32)
    return ((u >> jnp.uint32(shift)) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)


def _radix_hist_kernel(keys_ref, hist_ref, *, shift: int, bits: int, m_pad: int):
    ids = _digit(keys_ref[0, :], shift, bits)
    hist_ref[0, :] = _one_hot(ids, m_pad).sum(axis=0).astype(jnp.int32)


def radix_tile_histograms_pallas(
    keys_tiled: Array, shift: int, bits: int, *, interpret: bool = True
) -> Array:
    """(L, T) uint32 keys -> (L, 2^bits) per-tile digit histograms (fused)."""
    n_tiles, t = keys_tiled.shape
    m = 1 << bits
    m_pad = _pad_lanes(m)
    out = pl.pallas_call(
        functools.partial(_radix_hist_kernel, shift=shift, bits=bits, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_pad), jnp.int32),
        interpret=interpret,
    )(keys_tiled)
    return out[:, :m]


def _radix_pos_kernel(keys_ref, g_ref, pos_ref, *, shift: int, bits: int, m_pad: int):
    ids = _digit(keys_ref[0, :], shift, bits)
    g = g_ref[0, :].astype(jnp.float32)
    one_hot = _one_hot(ids, m_pad)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)
    base = jax.lax.dot(one_hot, g[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    pos_ref[0, :] = (base + local).astype(jnp.int32)


def radix_tile_positions_pallas(
    keys_tiled: Array, g: Array, shift: int, bits: int, *, interpret: bool = True
) -> Array:
    """Fused postscan for one radix pass: (L, T) keys + (L, m) bases -> (L, T) dests."""
    n_tiles, t = keys_tiled.shape
    m = 1 << bits
    m_pad = _pad_lanes(m)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m].set(g)
    return pl.pallas_call(
        functools.partial(_radix_pos_kernel, shift=shift, bits=bits, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(keys_tiled, g_pad)
