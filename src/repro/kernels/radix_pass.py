"""Fused radix-pass kernels: digit extraction + histogram + postscan+reorder.

One multisplit-sort pass (paper §7.1) needs the bucket identifier
``f_k(u) = (u >> k·r) & (2^r − 1)`` evaluated twice (prescan + postscan).
Fusing the shift/mask into the kernels means the label vector NEVER exists in
HBM — the exact overhead the paper's RB-sort baseline pays (§3.4) and its
multisplit avoids.

Since PR-4 the radix digit is just :class:`~repro.core.identifiers.
BitfieldSpec` and in-kernel label fusion is the GENERIC fused-label
machinery of :mod:`repro.kernels.multisplit_tile` (DESIGN.md §11): every
entry point here is a thin ``BitfieldSpec(shift, bits)`` instantiation of
the corresponding ``spec_*`` kernel, kept under its historical name because
``radix_sort`` predates the general mechanism and benchmarks/tests address
these doors directly.

* ``radix_tile_histograms_pallas``        — prescan: digits + tile histogram.
* ``radix_fused_postscan_reorder_pallas`` — postscan: digits + local ranks +
  global destinations + within-tile digit-major reorder in ONE evaluation.
* ``radix_tile_positions_pallas``         — DMS (no-reorder) postscan.
* ``seg_radix_*``                         — segmented variants: the segment
  id combines with the digit in-register, ``cid = (seg << bits) | digit``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.identifiers import BitfieldSpec
from repro.kernels import multisplit_tile as _mst

Array = jnp.ndarray


def radix_tile_histograms_pallas(
    keys_tiled: Array, shift: int, bits: int, *, interpret: bool = True
) -> Array:
    """(L, T) uint32 keys -> (L, 2^bits) per-tile digit histograms (fused)."""
    return _mst.spec_tile_histograms_pallas(
        keys_tiled, BitfieldSpec(shift, bits), interpret=interpret
    )


def radix_tile_positions_pallas(
    keys_tiled: Array, g: Array, shift: int, bits: int, *, interpret: bool = True
) -> Array:
    """Fused DMS postscan for one radix pass: (L, T) keys + (L, m) bases -> (L, T) dests."""
    return _mst.spec_tile_positions_pallas(
        keys_tiled, g, BitfieldSpec(shift, bits), interpret=interpret
    )


def radix_fused_postscan_reorder_pallas(
    keys_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    shift: int,
    bits: int,
    *,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """(L,T) keys + (L,m) bases [+ (L,T) values]
    -> (keys_r, values_r, pos_r, perm), digit-major within each tile
    (contract of :func:`~repro.kernels.multisplit_tile.
    spec_fused_postscan_reorder_pallas`)."""
    return _mst.spec_fused_postscan_reorder_pallas(
        keys_tiled, g, values_tiled, BitfieldSpec(shift, bits), interpret=interpret
    )


def seg_radix_tile_histograms_pallas(
    keys_tiled: Array, seg_tiled: Array, shift: int, bits: int, num_segments: int,
    *, interpret: bool = True,
) -> Array:
    """(L, T) keys + (L, T) segment ids -> (L, s·2^bits) combined histograms."""
    return _mst.seg_spec_tile_histograms_pallas(
        keys_tiled, seg_tiled, BitfieldSpec(shift, bits), num_segments,
        interpret=interpret,
    )


def seg_radix_tile_positions_pallas(
    keys_tiled: Array, seg_tiled: Array, g: Array, shift: int, bits: int,
    num_segments: int, *, interpret: bool = True,
) -> Array:
    """Segmented DMS radix postscan: combined (seg, digit) destinations."""
    return _mst.seg_spec_tile_positions_pallas(
        keys_tiled, seg_tiled, g, BitfieldSpec(shift, bits), num_segments,
        interpret=interpret,
    )


def seg_radix_fused_postscan_reorder_pallas(
    keys_tiled: Array,
    seg_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    shift: int,
    bits: int,
    num_segments: int,
    *,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Segmented fused radix postscan: (seg, digit)-major within each tile."""
    return _mst.seg_spec_fused_postscan_reorder_pallas(
        keys_tiled, seg_tiled, g, values_tiled, BitfieldSpec(shift, bits),
        num_segments, interpret=interpret,
    )
