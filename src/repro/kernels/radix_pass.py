"""Fused radix-pass kernels: digit extraction + histogram + postscan+reorder.

One multisplit-sort pass (paper §7.1) needs the bucket identifier
``f_k(u) = (u >> k·r) & (2^r − 1)`` evaluated twice (prescan + postscan).
Fusing the shift/mask into the kernels means the label vector NEVER exists in
HBM — the exact overhead the paper's RB-sort baseline pays (§3.4) and its
multisplit avoids. ``radix_sort(use_pallas=True)`` routes every pass through
these two kernels (via :mod:`repro.core.plan`):

* ``radix_tile_histograms_pallas``      — prescan: digits + tile histogram.
* ``radix_fused_postscan_reorder_pallas`` — postscan: digits + local ranks +
  global destinations + within-tile digit-major reorder of keys (and values)
  from ONE one-hot/cumsum evaluation (DESIGN.md §4/§5).
* ``radix_tile_positions_pallas``       — DMS (no-reorder) postscan variant.

Segmented variants (``seg_radix_*``, DESIGN.md §9) additionally take a
per-element segment-id strip and combine ``cid = (seg << bits) | digit``
in-register: one grid launch sorts EVERY segment's digits independently —
the machinery behind ``segmented_radix_sort``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (
    cumsum_mxu as _cumsum_mxu,
    fused_postscan_body,
    one_hot_f32 as _one_hot,
    pad_lanes as _pad_lanes,
)

Array = jnp.ndarray


def _digit(keys: Array, shift: int, bits: int) -> Array:
    u = keys.astype(jnp.uint32)
    return ((u >> jnp.uint32(shift)) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)


def _radix_hist_kernel(keys_ref, hist_ref, *, shift: int, bits: int, m_pad: int):
    ids = _digit(keys_ref[0, :], shift, bits)
    hist_ref[0, :] = _one_hot(ids, m_pad).sum(axis=0).astype(jnp.int32)


def radix_tile_histograms_pallas(
    keys_tiled: Array, shift: int, bits: int, *, interpret: bool = True
) -> Array:
    """(L, T) uint32 keys -> (L, 2^bits) per-tile digit histograms (fused)."""
    n_tiles, t = keys_tiled.shape
    m = 1 << bits
    m_pad = _pad_lanes(m)
    out = pl.pallas_call(
        functools.partial(_radix_hist_kernel, shift=shift, bits=bits, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_pad), jnp.int32),
        interpret=interpret,
    )(keys_tiled)
    return out[:, :m]


def _radix_pos_kernel(keys_ref, g_ref, pos_ref, *, shift: int, bits: int, m_pad: int):
    ids = _digit(keys_ref[0, :], shift, bits)
    g = g_ref[0, :].astype(jnp.float32)
    one_hot = _one_hot(ids, m_pad)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)
    base = jax.lax.dot(one_hot, g[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    pos_ref[0, :] = (base + local).astype(jnp.int32)


def radix_tile_positions_pallas(
    keys_tiled: Array, g: Array, shift: int, bits: int, *, interpret: bool = True
) -> Array:
    """Fused DMS postscan for one radix pass: (L, T) keys + (L, m) bases -> (L, T) dests."""
    n_tiles, t = keys_tiled.shape
    m = 1 << bits
    m_pad = _pad_lanes(m)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m].set(g)
    return pl.pallas_call(
        functools.partial(_radix_pos_kernel, shift=shift, bits=bits, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(keys_tiled, g_pad)


# ---------------------------------------------------------------------------
# Fused WMS/BMS radix postscan: digits + ranks + global dests + reorder in one
# VMEM pass — no label array, no separate reorder passes (DESIGN.md §5).
# ---------------------------------------------------------------------------

def _radix_fused_kernel(*refs, shift: int, bits: int, m_pad: int, has_values: bool):
    if has_values:
        (keys_ref, g_ref, vals_ref,
         keys_out_ref, vals_out_ref, pos_out_ref, perm_out_ref) = refs
    else:
        keys_ref, g_ref, keys_out_ref, pos_out_ref, perm_out_ref = refs
        vals_ref = vals_out_ref = None

    keys = keys_ref[0, :]
    ids = _digit(keys, shift, bits)                         # fused digit extraction
    keys_r, vals_r, pos_r, gpos = fused_postscan_body(
        ids, g_ref[0, :], keys, vals_ref[0, :] if has_values else None, m_pad
    )
    keys_out_ref[0, :] = keys_r
    pos_out_ref[0, :] = pos_r
    perm_out_ref[0, :] = gpos                               # element-ordered perm
    if has_values:
        vals_out_ref[0, :] = vals_r


def radix_fused_postscan_reorder_pallas(
    keys_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    shift: int,
    bits: int,
    *,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """(L,T) keys + (L,m) bases [+ (L,T) values]
    -> (keys_r, values_r, pos_r, perm).

    Digit-major within each tile; ``pos_r`` holds global destinations so the
    caller's scatter is the only remaining data movement of the pass, and
    ``perm`` is the element-ordered destination map (free byproduct).
    """
    n_tiles, t = keys_tiled.shape
    m = 1 << bits
    m_pad = _pad_lanes(m)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m].set(g)
    has_values = values_tiled is not None
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    in_specs = [row, pl.BlockSpec((1, m_pad), lambda i: (i, 0))] + ([row] if has_values else [])
    out_specs = [row] * (4 if has_values else 3)
    out_shape = [jax.ShapeDtypeStruct((n_tiles, t), keys_tiled.dtype)]
    if has_values:
        out_shape.append(jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype))
    out_shape += [
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
    ]
    args = (keys_tiled, g_pad) + ((values_tiled,) if has_values else ())
    out = pl.pallas_call(
        functools.partial(
            _radix_fused_kernel, shift=shift, bits=bits, m_pad=m_pad, has_values=has_values
        ),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if has_values:
        keys_r, vals_r, pos_r, perm = out
        return keys_r, vals_r, pos_r, perm
    keys_r, pos_r, perm = out
    return keys_r, None, pos_r, perm


# ---------------------------------------------------------------------------
# Segmented radix kernels: digit + segment id combined in-register, so one
# grid launch runs an independent radix pass per segment (DESIGN.md §9).
# ---------------------------------------------------------------------------

def _seg_radix_hist_kernel(keys_ref, seg_ref, hist_ref, *, shift: int, bits: int, m_pad: int):
    cid = _digit(keys_ref[0, :], shift, bits) + seg_ref[0, :] * (1 << bits)
    hist_ref[0, :] = _one_hot(cid, m_pad).sum(axis=0).astype(jnp.int32)


def seg_radix_tile_histograms_pallas(
    keys_tiled: Array, seg_tiled: Array, shift: int, bits: int, num_segments: int,
    *, interpret: bool = True,
) -> Array:
    """(L, T) keys + (L, T) segment ids -> (L, s·2^bits) combined histograms."""
    n_tiles, t = keys_tiled.shape
    m_eff = num_segments << bits
    m_pad = _pad_lanes(m_eff)
    out = pl.pallas_call(
        functools.partial(_seg_radix_hist_kernel, shift=shift, bits=bits, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_pad), jnp.int32),
        interpret=interpret,
    )(keys_tiled, seg_tiled)
    return out[:, :m_eff]


def _seg_radix_pos_kernel(keys_ref, seg_ref, g_ref, pos_ref, *, shift: int, bits: int, m_pad: int):
    cid = _digit(keys_ref[0, :], shift, bits) + seg_ref[0, :] * (1 << bits)
    g = g_ref[0, :].astype(jnp.float32)
    one_hot = _one_hot(cid, m_pad)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)
    base = jax.lax.dot(one_hot, g[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    pos_ref[0, :] = (base + local).astype(jnp.int32)


def seg_radix_tile_positions_pallas(
    keys_tiled: Array, seg_tiled: Array, g: Array, shift: int, bits: int,
    num_segments: int, *, interpret: bool = True,
) -> Array:
    """Segmented DMS radix postscan: combined (seg, digit) destinations."""
    n_tiles, t = keys_tiled.shape
    m_eff = num_segments << bits
    m_pad = _pad_lanes(m_eff)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m_eff].set(g)
    return pl.pallas_call(
        functools.partial(_seg_radix_pos_kernel, shift=shift, bits=bits, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(keys_tiled, seg_tiled, g_pad)


def _seg_radix_fused_kernel(*refs, shift: int, bits: int, m_pad: int, has_values: bool):
    if has_values:
        (keys_ref, seg_ref, g_ref, vals_ref,
         keys_out_ref, vals_out_ref, pos_out_ref, perm_out_ref) = refs
    else:
        keys_ref, seg_ref, g_ref, keys_out_ref, pos_out_ref, perm_out_ref = refs
        vals_ref = vals_out_ref = None

    keys = keys_ref[0, :]
    cid = _digit(keys, shift, bits) + seg_ref[0, :] * (1 << bits)
    keys_r, vals_r, pos_r, gpos = fused_postscan_body(
        cid, g_ref[0, :], keys, vals_ref[0, :] if has_values else None, m_pad
    )
    keys_out_ref[0, :] = keys_r
    pos_out_ref[0, :] = pos_r
    perm_out_ref[0, :] = gpos
    if has_values:
        vals_out_ref[0, :] = vals_r


def seg_radix_fused_postscan_reorder_pallas(
    keys_tiled: Array,
    seg_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    shift: int,
    bits: int,
    num_segments: int,
    *,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Segmented fused radix postscan: (seg, digit)-major within each tile;
    contract matches :func:`radix_fused_postscan_reorder_pallas` with the
    bucket axis widened to ``s·2^bits``."""
    n_tiles, t = keys_tiled.shape
    m_eff = num_segments << bits
    m_pad = _pad_lanes(m_eff)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m_eff].set(g)
    has_values = values_tiled is not None
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    in_specs = [row, row, pl.BlockSpec((1, m_pad), lambda i: (i, 0))] + (
        [row] if has_values else []
    )
    out_specs = [row] * (4 if has_values else 3)
    out_shape = [jax.ShapeDtypeStruct((n_tiles, t), keys_tiled.dtype)]
    if has_values:
        out_shape.append(jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype))
    out_shape += [
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
    ]
    args = (keys_tiled, seg_tiled, g_pad) + ((values_tiled,) if has_values else ())
    out = pl.pallas_call(
        functools.partial(
            _seg_radix_fused_kernel, shift=shift, bits=bits, m_pad=m_pad,
            has_values=has_values,
        ),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if has_values:
        keys_r, vals_r, pos_r, perm = out
        return keys_r, vals_r, pos_r, perm
    keys_r, pos_r, perm = out
    return keys_r, None, pos_r, perm
