"""Pallas TPU flash attention — the documented next lever of §Perf.

The roofline analysis (EXPERIMENTS.md §Perf) shows that after the MoE
dispatch fix, every remaining memory bound is dominated by materialized
attention probability tensors (fp32/bf16 (C, C) blocks per pair per layer):
XLA cannot fuse the full online-softmax chain at the graph level. This
kernel keeps q·kᵀ, the softmax state and p·V entirely in VMEM: HBM traffic
collapses to reading q/k/v once and writing o once (the flash-attention
bound), removing the probability tensors from the roofline's memory term.

Grid: one program per (batch·head, q-block). K/V live fully in VMEM per
program (S·hd·2 B ≤ ~2 MB for the assigned shapes at S ≤ 8192; longer
sequences tile K/V with an inner loop). Causal masking via block-local
iota against absolute positions; the inner loop runs only over visible
kv-blocks (dynamic fori bound — legal inside a kernel, and kernel-internal
loops don't distort the graph-level cost analysis since the kernel is
opaque to it).

Validated bit-close against ``ref.flash_attention_ref`` in interpret mode
(this container is CPU-only; TPU v5e is the compile target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = jnp.ndarray

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                  seq_len: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale                 # (block_q, hd)
    hd = q.shape[-1]
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    n_kv = seq_len // block_k
    # visible kv blocks for this q block (causal: up to and including qi's span)
    hi = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k, n_kv) \
        if causal else n_kv

    def body(kj, carry):
        acc, m, l = carry
        k_blk = k_ref[0, pl.ds(kj * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.ds(kj * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
        )                                                    # (block_q, block_k)
        if causal:
            k_pos = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot(p, v_blk, precision=jax.lax.Precision.HIGHEST)
        return acc * corr + pv, m_new, l_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: Array,                    # (BH, S, hd) — batch·heads folded
    k: Array,                    # (BH, S, hd)
    v: Array,                    # (BH, S, hd)
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = True,
) -> Array:
    bh, s, hd = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / np.sqrt(hd)
    grid = (bh, s // block_q)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=block_q, block_k=block_k, seq_len=s,
            causal=causal, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
