"""Shared building blocks for the multisplit Pallas kernels (DESIGN.md §4).

Every kernel in this package is built from a small set of VMEM-resident
primitives, so they live in one module instead of being re-derived per file.

The DENSE one-hot family (DESIGN.md §2):

* :func:`one_hot_f32`   — the paper's binary matrix ``H̄`` (§4.5) built with a
  broadcasted iota compare (no gather, VPU-friendly).
* :func:`cumsum_mxu`    — inclusive column scan as a lower-triangular ones
  matmul: maps the warp-scan of paper Alg. 3 onto the MXU systolic array.
* :func:`exclusive_starts_mxu` — exclusive scan of a histogram row via a
  *strictly* lower-triangular matmul (bucket start offsets).
* :func:`permute_matmul_32` — apply a within-tile permutation to 32-bit words
  as TWO half-word one-hot matmuls (16-bit halves keep fp32 accumulation
  exact) — MXU work instead of a serialized scatter (paper §4.7 reorder).

All integer payloads are carried through fp32 matmuls in exact range
(< 2^24 per half-word / count), which every kernel test checks bit-exactly.

The PACKED subword-counter family (DESIGN.md §12, paper §4.3): the dense
family's per-tile work and VMEM scale as ``T × m`` because every element
materializes a full one-hot row.  The packed family instead privatizes
``k = 32 / bits`` bucket counters per ``uint32`` word — the vectorized
analogue of the paper's packed shared-memory counters — and ranks elements
with a TWO-LEVEL hierarchy: an inclusive scan of packed words inside
``subtile``-row blocks (counts bounded by ``2^bits − 1``, the overflow
guard of :func:`packed_layout`), then one small ``S × m`` exclusive scan
across the blocks.  The scan matrix shrinks from ``T × m`` f32 words to
``T × ⌈m/k⌉`` uint32 words and the quadratic cumsum matmul disappears, so
per-key work is ~flat in the bucket count up to m = 256.  Shared entry
points: :func:`packed_layout`, :func:`packed_local_offsets`,
:func:`packed_counts`, :func:`packed_positions_body`,
:func:`packed_postscan_body` — the SAME jnp bodies are traced inside the
Pallas kernels and vmapped by the jnp emulation backends, which is what
makes the two families bitwise-comparable oracles of each other.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def pad_lanes(m: int) -> int:
    """Pad the bucket axis to a multiple of 128 lanes (min one full lane)."""
    return max(128, ((m + 127) // 128) * 128)


def one_hot_f32(ids: Array, m_pad: int) -> Array:
    """(T,) int32 -> (T, m_pad) f32 one-hot via broadcasted iota (no gather)."""
    t = ids.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, m_pad), 1)
    return (cols == ids[:, None]).astype(jnp.float32)


def cumsum_mxu(x: Array) -> Array:
    """Inclusive column cumsum as a lower-triangular matmul (MXU-native)."""
    t = x.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    tril = (rows >= cols).astype(jnp.float32)
    return jax.lax.dot(tril, x, precision=jax.lax.Precision.HIGHEST)


def exclusive_starts_mxu(hist: Array) -> Array:
    """(m,) f32 histogram -> (m,) exclusive prefix (bucket start offsets)."""
    m = hist.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    strict_tril = (rows > cols).astype(jnp.float32)
    return jax.lax.dot(strict_tril, hist[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]


def permutation_matrix(dest: Array) -> Array:
    """(T,) int32 destinations -> (T, T) f32 P with P[j, i] = (dest_i == j)."""
    t = dest.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    return (rows == dest[None, :]).astype(jnp.float32)


def select_columns(rows: Array, col: Array) -> Array:
    """``rows[i, col[i]]`` WITHOUT a gather: broadcasted-iota compare along
    the static column axis + masked sum (exactly one term survives per row).
    The oblivious, Mosaic-lowerable form of ``take_along_axis(rows, col, 1)``
    — the TPU analogue of the paper's ballot/shuffle lane exchange."""
    t, w = rows.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, w), 1)
    zero = jnp.zeros((), rows.dtype)
    return jnp.where(cols == col[:, None], rows, zero).sum(axis=1)


def pick_row_32(one_hot: Array, row: Array) -> Array:
    """One-hot pick ``row[ids]`` of FULL-RANGE 32-bit entries: the (T, m) f32
    one-hot times the row split into 16-bit halves, one MXU matmul, exact
    (each half < 2^16 ≤ 2^24; mirrors :func:`permute_matmul_32`)."""
    ri = jax.lax.bitcast_convert_type(row, jnp.uint32)
    halves = jnp.stack(
        [(ri & jnp.uint32(0xFFFF)).astype(jnp.float32),
         (ri >> jnp.uint32(16)).astype(jnp.float32)], axis=1
    )                                                       # (m, 2)
    moved = jax.lax.dot(one_hot, halves, precision=jax.lax.Precision.HIGHEST)
    lo = moved[:, 0].astype(jnp.uint32)
    hi = moved[:, 1].astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(lo | (hi << jnp.uint32(16)), row.dtype)


def rank_plane_pack16(rows: Array) -> Array:
    """(S, m) int32 ranks (each < 2^16, guarded by ``packed_layout``) ->
    (S, ceil(m/2)) uint32 LANE-PACKED RANK PLANES: two bucket carries per
    int32 lane, even bucket in the low half-word. Halves the select width
    of the packed family's level-2 carry lookup on the oblivious path."""
    s, m = rows.shape
    u = rows.astype(jnp.uint32)
    if m % 2:
        u = jnp.concatenate([u, jnp.zeros((s, 1), jnp.uint32)], axis=1)
    u = u.reshape(s, -1, 2)
    return u[:, :, 0] | (u[:, :, 1] << jnp.uint32(16))


def fused_postscan_body(ids, g_row, keys, vals, m_pad: int):
    """THE fused postscan+reorder math, shared by the generic and radix
    kernels (they differ only in where ``ids`` comes from): ONE
    one-hot/cumsum evaluation yields local ranks, the tile histogram and
    bucket starts, the within-tile destination, the global destination
    (paper eq. (2)), and the bucket-major permutation of keys/values/
    positions. Returns (keys_r, vals_r_or_None, pos_r, gpos)."""
    t = ids.shape[0]
    one_hot = one_hot_f32(ids, m_pad)                       # THE one-hot (T, m)
    incl = cumsum_mxu(one_hot)                              # THE cumsum
    local = ((incl - 1.0) * one_hot).sum(axis=1)            # (T,) in-bucket rank
    hist = incl[t - 1, :]                                   # (m,) tile histogram
    starts = exclusive_starts_mxu(hist)                     # (m,) tile bucket starts
    pick = lambda row: jax.lax.dot(
        one_hot, row[:, None], precision=jax.lax.Precision.HIGHEST
    )[:, 0]
    dest = (pick(starts) + local).astype(jnp.int32)         # within-tile destination
    gpos = (pick(g_row.astype(jnp.float32)) + local).astype(jnp.int32)  # eq. (2)
    perm = permutation_matrix(dest)
    keys_r = permute_matmul_32(perm, keys)
    pos_r = permute_matmul_32(perm, gpos)
    vals_r = permute_matmul_32(perm, vals) if vals is not None else None
    return keys_r, vals_r, pos_r, gpos


# ---------------------------------------------------------------------------
# Packed subword counters (DESIGN.md §12; paper §4.3's privatized packed
# counters, emulated with shift/mask vector ops).
# ---------------------------------------------------------------------------

DEFAULT_PACKED_BITS = 8      # counter width: k = 32/bits counters per word


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Resolved packed-counter geometry for one tile shape (hashable, so it
    rides as a static kernel/jit parameter like a BucketSpec).

    ``bits`` is the subword counter width, ``k = 32 // bits`` the counters
    per uint32 word, ``w = ceil(m_eff / k)`` the packed words per element
    row, ``subtile`` the level-1 scan span (counts inside one subtile are
    bounded by ``subtile`` ≤ ``2^bits − 1``: the no-overflow invariant), and
    ``n_sub = ceil(tile / subtile)`` the level-2 height."""

    tile: int
    m_eff: int
    bits: int
    k: int
    w: int
    subtile: int
    n_sub: int

    @property
    def lane_mask(self):
        return jnp.uint32((1 << self.bits) - 1)


def packed_layout(
    tile: int,
    m_eff: int,
    bits: int = DEFAULT_PACKED_BITS,
    subtile: Optional[int] = None,
    rank16: bool = False,
) -> PackedLayout:
    """Resolve (and GUARD) the packed-counter geometry for one tile.

    Raises ``ValueError`` for any (tile, bits, subtile) combination that
    could overflow a subword counter — a subtile taller than ``2^bits − 1``
    rows could put more than ``2^bits − 1`` equal bucket ids into one
    counter lane (the adversarial all-one-bucket input), silently wrapping
    it.  The auto subtile is the largest power of two that is provably safe
    (and ≤ 128, one VPU sublane block).

    ``rank16=True`` additionally guards the OBLIVIOUS path's 16-bit
    lane-packed rank planes (:func:`rank_plane_pack16`): two level-2 carries
    share one int32 lane, and a carry can reach ``tile`` on the adversarial
    all-one-bucket input, so tiles taller than ``2^16 − 1`` rows would
    silently wrap a half-word rank."""
    if tile < 1:
        raise ValueError(f"packed layout needs tile >= 1, got {tile}")
    if rank16 and tile > 0xFFFF:
        raise ValueError(
            f"tile={tile} overflows the 16-bit lane-packed rank planes: a "
            f"level-2 carry can reach {tile} > 65535 and two ranks share "
            f"one int32 lane on the oblivious path. Use tile <= 65535 (or "
            f"the gather form, oblivious=False)."
        )
    if m_eff < 1:
        raise ValueError(f"packed layout needs m_eff >= 1, got {m_eff}")
    if bits not in (1, 2, 4, 8, 16):
        raise ValueError(
            f"bits-per-counter must divide 32 and be <= 16, got {bits}"
        )
    cap = (1 << bits) - 1                     # max exact count per lane
    if subtile is None:
        subtile = 1
        while subtile * 2 <= min(tile, cap, 128):
            subtile *= 2
    if subtile < 1:
        raise ValueError(f"subtile must be >= 1, got {subtile}")
    if subtile > cap:
        raise ValueError(
            f"subtile={subtile} overflows {bits}-bit packed counters: a "
            f"single-bucket subtile reaches count {subtile} > {cap} "
            f"(= 2^{bits} - 1). Use a shorter subtile or wider counters."
        )
    k = 32 // bits
    return PackedLayout(
        tile=tile, m_eff=m_eff, bits=bits, k=k, w=-(-m_eff // k),
        subtile=subtile, n_sub=-(-tile // subtile),
    )


def _packed_pad_ids(ids: Array, layout: PackedLayout) -> Tuple[Array, int]:
    """Pad the id strip to a whole number of subtiles with bucket m_eff−1
    (tail pads never change earlier elements' ranks; callers slice/adjust)."""
    t = ids.shape[0]
    n_pad = (-t) % layout.subtile
    if n_pad:
        ids = jnp.concatenate(
            [ids, jnp.full((n_pad,), layout.m_eff - 1, ids.dtype)]
        )
    return ids, n_pad


def packed_encode(ids: Array, layout: PackedLayout) -> Array:
    """(T,) int32 ids -> (T, w) uint32 packed one-hot: element i contributes
    ``1 << (bits * (id mod k))`` to word ``id div k`` (shift/mask emulation
    of the paper's per-warp packed counter update)."""
    t = ids.shape[0]
    q = (ids // layout.k).astype(jnp.int32)
    shift = jnp.uint32(layout.bits) * (ids % layout.k).astype(jnp.uint32)
    unit = jnp.uint32(1) << shift
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, layout.w), 1)
    return jnp.where(cols == q[:, None], unit[:, None], jnp.uint32(0))


def packed_unpack(packed_rows: Array, layout: PackedLayout) -> Array:
    """(R, w) uint32 packed counters -> (R, m_eff) int32 counts."""
    shifts = jnp.uint32(layout.bits) * jnp.arange(layout.k, dtype=jnp.uint32)
    lanes = (packed_rows[:, :, None] >> shifts[None, None, :]) & layout.lane_mask
    return lanes.reshape(packed_rows.shape[0], layout.w * layout.k)[
        :, : layout.m_eff
    ].astype(jnp.int32)


def _packed_state(ids: Array, layout: PackedLayout, oblivious: bool = False):
    """The shared two-level solve: (rank_incl, sub_hist, excl_sub).

    ``rank_incl`` is the 1-based stable rank of each element within its
    (subtile, bucket) cell; ``sub_hist`` the (S, m_eff) per-subtile
    histograms; ``excl_sub`` their exclusive scan over subtiles (the level-2
    carry each element adds to reach its within-tile rank). ``oblivious``
    swaps the per-element packed-word lookup from a gather to a masked
    w-wide lane select (Mosaic-lowerable)."""
    ids, _ = _packed_pad_ids(ids, layout)
    t_pad = ids.shape[0]
    q = (ids // layout.k).astype(jnp.int32)
    shift = jnp.uint32(layout.bits) * (ids % layout.k).astype(jnp.uint32)
    contrib = packed_encode(ids, layout)
    # level 1: inclusive scan of packed words inside each subtile — counts
    # stay <= subtile <= 2^bits - 1, so lanes never carry into each other.
    incl3 = jnp.cumsum(
        contrib.reshape(layout.n_sub, layout.subtile, layout.w), axis=1
    )
    incl = incl3.reshape(t_pad, layout.w)
    if oblivious:
        word = select_columns(incl, q)
    else:
        word = jnp.take_along_axis(incl, q[:, None], axis=1)[:, 0]
    rank_incl = ((word >> shift) & layout.lane_mask).astype(jnp.int32)
    # level 2: unpack ONLY the S subtile totals and scan those — S*m work
    # instead of the dense family's T*m.
    sub_hist = packed_unpack(incl3[:, -1, :], layout)       # (S, m_eff)
    excl_sub = jnp.cumsum(sub_hist, axis=0) - sub_hist
    return rank_incl, sub_hist, excl_sub


def _drop_pad_count(hist: Array, m_eff: int, n_pad: int) -> Array:
    """Subtract the tail-pad count from the LAST bucket without a scatter:
    an iota compare + subtract, bitwise equal to ``hist.at[m-1].add(-n)``."""
    if not n_pad:
        return hist
    last = (jnp.arange(m_eff, dtype=jnp.int32) == m_eff - 1)
    return hist - n_pad * last.astype(hist.dtype)


def packed_local_offsets(
    ids: Array, layout: PackedLayout, oblivious: bool = False
) -> Tuple[Array, Array]:
    """Packed-counter analogue of the dense one-hot local solve: (stable
    0-based in-bucket rank within the tile, tile histogram), bitwise equal
    to ``tile_local_offsets(ids, m_eff)``.

    ``oblivious=True`` (the compiled kernel path) replaces the level-2 carry
    gather ``excl_sub[sub, id]`` with 16-BIT LANE-PACKED RANK PLANES: the
    (S, m_eff) carries are packed two-per-int32-lane, each subtile's plane
    row is broadcast statically to its rows, and the element's word is a
    masked ⌈m/2⌉-wide select — half the select width of an unpacked lookup.
    Exactness requires every carry < 2^16 (tile ≤ 65535; guarded here and
    in ``packed_layout(rank16=True)``)."""
    t = ids.shape[0]
    rank_incl, sub_hist, excl_sub = _packed_state(ids, layout, oblivious=oblivious)
    if oblivious:
        if layout.tile > 0xFFFF:
            raise ValueError(
                f"packed oblivious path: tile={layout.tile} level-2 carries "
                f"do not fit the 16-bit lane-packed rank planes (max 65535); "
                f"resolve the layout with packed_layout(rank16=True)"
            )
        ids_p, _ = _packed_pad_ids(ids, layout)
        planes = rank_plane_pack16(excl_sub)                # (S, ceil(m/2))
        w16 = planes.shape[1]
        per_row = jnp.broadcast_to(
            planes[:, None, :], (layout.n_sub, layout.subtile, w16)
        ).reshape(layout.n_sub * layout.subtile, w16)
        word = select_columns(per_row, (ids_p // 2).astype(jnp.int32))
        carry = (
            (word >> (jnp.uint32(16) * (ids_p % 2).astype(jnp.uint32)))
            & jnp.uint32(0xFFFF)
        ).astype(jnp.int32)
        local = carry[:t] + rank_incl[:t] - 1
    else:
        sub_idx = jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0)[:, 0] // layout.subtile
        local = excl_sub[sub_idx, ids] + rank_incl[:t] - 1
    hist = sub_hist.sum(axis=0)
    n_pad = layout.n_sub * layout.subtile - t
    hist = _drop_pad_count(hist, layout.m_eff, n_pad)       # drop internal pads
    return local.astype(jnp.int32), hist.astype(jnp.int32)


def packed_counts(ids: Array, layout: PackedLayout) -> Array:
    """Histogram-only form: per-subtile packed SUMS (no scan) + one unpack.
    Bitwise equal to the dense tile histogram (and gather-free as-is)."""
    t = ids.shape[0]
    ids, n_pad = _packed_pad_ids(ids, layout)
    contrib = packed_encode(ids, layout)
    sub_tot = contrib.reshape(layout.n_sub, layout.subtile, layout.w).sum(
        axis=1, dtype=jnp.uint32
    )
    hist = packed_unpack(sub_tot, layout).sum(axis=0)
    hist = _drop_pad_count(hist, layout.m_eff, n_pad)
    return hist.astype(jnp.int32)


def packed_positions_body(
    ids: Array, g_row: Array, layout: PackedLayout, oblivious: bool = False
) -> Array:
    """Packed DMS postscan: global destinations, paper eq. (2)."""
    local, _ = packed_local_offsets(ids, layout, oblivious=oblivious)
    if oblivious:
        g_pick = pick_row_32(one_hot_f32(ids, layout.m_eff),
                             g_row.astype(jnp.int32))
        return (g_pick + local).astype(jnp.int32)
    return (g_row.astype(jnp.int32)[ids] + local).astype(jnp.int32)


def packed_postscan_body(
    ids, g_row, keys, vals, layout: PackedLayout, oblivious: bool = False
):
    """THE packed fused postscan+reorder: same contract as
    :func:`fused_postscan_body` — (keys_r, vals_r_or_None, pos_r, gpos) with
    the first three bucket-major within the tile — built on the two-level
    packed rank. The gather form scatters in-tile; the oblivious form picks
    starts/G via ONE m_eff-wide one-hot (16-bit-half matmuls, exact for full
    32-bit globals) and reorders through permutation matmuls — every step a
    select or an MXU contraction, nothing Mosaic refuses to lower."""
    local, hist = packed_local_offsets(ids, layout, oblivious=oblivious)
    starts = (jnp.cumsum(hist) - hist).astype(jnp.int32)
    if oblivious:
        oh = one_hot_f32(ids, layout.m_eff)
        dest = (pick_row_32(oh, starts) + local).astype(jnp.int32)
        gpos = (pick_row_32(oh, g_row.astype(jnp.int32)) + local).astype(jnp.int32)
        perm = permutation_matrix(dest)
        keys_r = permute_matmul_32(perm, keys)
        pos_r = permute_matmul_32(perm, gpos)
        vals_r = permute_matmul_32(perm, vals) if vals is not None else None
        return keys_r, vals_r, pos_r, gpos
    dest = (starts[ids] + local).astype(jnp.int32)          # within-tile destination
    gpos = (g_row.astype(jnp.int32)[ids] + local).astype(jnp.int32)  # eq. (2)
    keys_r = jnp.zeros_like(keys).at[dest].set(keys)
    pos_r = jnp.zeros_like(gpos).at[dest].set(gpos)
    vals_r = jnp.zeros_like(vals).at[dest].set(vals) if vals is not None else None
    return keys_r, vals_r, pos_r, gpos


# ---------------------------------------------------------------------------
# Fused two-digit radix bodies (DESIGN.md §13): TWO digit passes per VMEM
# residency. One tile of keys (and values) is loaded once; the digit-d local
# solve reorders the tile IN VMEM, the digit-(d+1) solve then runs on the
# locally-reordered tile, and the emitted histogram covers the combined
# 2r-bit pair digit — so the global scan layer places elements with a SINGLE
# HBM scatter per digit *pair* instead of per digit. Correctness rests on the
# LSD identity: two chained stable passes over digits (lo, hi) equal ONE
# stable pass over the combined bitfield ``hi·2^r_lo + lo`` — the pair is
# just a ``2r``-bit BitfieldSpec at the tile level.
#
# The same identity applies INSIDE the tile, so the postscan body decomposes
# the 2r-bit in-tile solve all the way down to ``_FUSED2_SUB_BITS``-wide
# sub-digit stages (an in-VMEM LSD sweep: stable stage solve + in-VMEM
# reorder per sub-digit, segment id as the most-significant stage). Narrow
# stages keep every solve plane at T×2^sub instead of T×m — measured ~2×
# cheaper than two m-wide stage solves at r=8 and strictly less VMEM; the
# dense direct solve would need a T×m² one-hot, which never exists (the only
# m²-wide objects are histogram/scan ROWS). Every body below carries BOTH
# forms: the gather/scatter form (``oblivious=False``, the vmap oracle and
# the host fast path) and the oblivious select/matmul form
# (``oblivious=True``, the compiled Mosaic path — DESIGN.md §15), bitwise
# identical by construction and property-tested against each other.
# ---------------------------------------------------------------------------

# In-tile sub-digit stage width of the fused2 LSD sweep. 4 bits = 16-wide
# stage solves: measured fastest on the host bench for BOTH families (2-bit
# stages double the stage count, 8-bit stages quadruple the plane width).
_FUSED2_SUB_BITS = 4


def fused2_split_digits(keys: Array, shift: int, bits_lo: int, bits_hi: int):
    """(lo, hi) digit strips of the pair bitfield at ``shift`` — the same
    arithmetic as ``BitfieldSpec.emit`` on each half, so the fused pair is
    bitwise consistent with the two chained single-digit passes."""
    u = keys.astype(jnp.uint32)
    lo = ((u >> jnp.uint32(shift)) & jnp.uint32((1 << bits_lo) - 1)).astype(jnp.int32)
    hi = ((u >> jnp.uint32(shift + bits_lo))
          & jnp.uint32((1 << bits_hi) - 1)).astype(jnp.int32)
    return lo, hi


def _dense_local_offsets(
    ids: Array, m: int, oblivious: bool = False
) -> Tuple[Array, Array]:
    """Dense int32 one-hot/cumsum local solve: (stable in-bucket rank, tile
    histogram). The jnp form shared by the fused2 stage solves. The
    oblivious form reads the element's own cumsum cell with a masked
    one-hot product instead of ``take_along_axis`` (same int32 math)."""
    t = ids.shape[0]
    one_hot = (ids[:, None] == jnp.arange(m, dtype=jnp.int32)[None, :]).astype(jnp.int32)
    incl = jnp.cumsum(one_hot, axis=0)
    if oblivious:
        local = (incl * one_hot).sum(axis=1) - 1
    else:
        local = jnp.take_along_axis(incl, ids[:, None].astype(jnp.int32), axis=1)[:, 0] - 1
    return local.astype(jnp.int32), incl[t - 1].astype(jnp.int32)


def _fused2_stage_local(
    ids: Array, m: int, family: str, oblivious: bool = False
) -> Tuple[Array, Array]:
    """One m-wide stage solve of the fused pair, in the plan's kernel family."""
    if family == "packed":
        lay = packed_layout(ids.shape[0], m, rank16=oblivious)
        return packed_local_offsets(ids, lay, oblivious=oblivious)
    return _dense_local_offsets(ids, m, oblivious=oblivious)


def _pair_hist2d_shape(bits: int, num_segments: int) -> Tuple[int, int, int]:
    """Factor the (segments × pair) histogram axis for the oblivious
    two-level one-hot contraction: ``cg = row · n_cols + col`` with
    ``n_cols = 2^⌈bits/2⌉`` columns (the pair's low half) and
    ``n_rows = segments · 2^(bits−⌈bits/2⌉)`` rows (segment + high half).
    Keeps the one-hot planes at T×(√m²) each instead of T×m²."""
    col_bits = (bits + 1) // 2
    n_cols = 1 << col_bits
    n_rows = (1 << (bits - col_bits)) * num_segments
    return col_bits, n_rows, n_cols


def fused2_counts_body(
    keys: Array,
    shift: int,
    bits: int,
    seg: Optional[Array] = None,
    num_segments: int = 1,
    oblivious: bool = False,
) -> Array:
    """Per-tile histogram over the combined ``bits``-wide pair digit (the
    fused2 prescan). The gather form is an O(T) scatter-add. The oblivious
    form factors the m²·s-wide axis into (row, column) halves and contracts
    the two one-hots on the MXU — ``histᵀ = oh_rowᵀ · oh_col`` — so the
    planes stay T×√m² each and the counts (< 2^24) are f32-exact. Both are
    order-invariant, hence computed on the UN-reordered tile; bitwise equal
    to the histogram the postscan body derives from its cell counts."""
    m2 = 1 << bits
    u = keys.astype(jnp.uint32)
    pair = ((u >> jnp.uint32(shift)) & jnp.uint32(m2 - 1)).astype(jnp.int32)
    cg = pair if seg is None else seg * m2 + pair
    if not oblivious:
        return jnp.zeros((m2 * num_segments,), jnp.int32).at[cg].add(1)
    col_bits, n_rows, n_cols = _pair_hist2d_shape(bits, num_segments)
    oh_r = one_hot_f32((cg >> col_bits).astype(jnp.int32), n_rows)
    oh_c = one_hot_f32((cg & (n_cols - 1)).astype(jnp.int32), n_cols)
    hist2d = jax.lax.dot(oh_r.T, oh_c, precision=jax.lax.Precision.HIGHEST)
    return hist2d.reshape(-1).astype(jnp.int32)


def fused2_postscan_body(
    keys: Array,
    g_row: Array,
    vals: Optional[Array],
    shift: int,
    split: int,
    bits: int,
    seg: Optional[Array] = None,
    num_segments: int = 1,
    family: str = "onehot",
    sub_bits: Optional[int] = None,
    oblivious: bool = False,
):
    """THE fused two-digit postscan+reorder: same contract as
    :func:`fused_postscan_body` / :func:`packed_postscan_body` —
    (keys_r, vals_r_or_None, pos_r, gpos), the first three combined-bucket-
    major within the tile — but over the ``bits``-wide PAIR digit.

    ``split`` is the schedule-level boundary between the pair's two logical
    digits (it fixes which two chained passes the pair replaces). By the LSD
    identity the RESULT depends only on the combined stable pass, not on how
    the in-tile solve is decomposed — so the body is free to decompose
    further: an in-VMEM LSD sweep over ``_FUSED2_SUB_BITS``-wide sub-digit
    stages (stable stage solve + in-VMEM reorder per stage, segment id as
    the most-significant stage). Each stage's solve plane is T×2^sub instead
    of T×m — measured ~2× cheaper than two ``split``-wide stage solves at
    r=8 — and after the sweep the tile is already (seg, pair)-bucket-major,
    so the stable in-cell rank is just position minus the cell's tile start.
    The caller's single scatter per pair stays bitwise identical to the two
    chained single-digit scatters it replaces.

    ``oblivious=True`` (the compiled kernel path) removes every in-tile
    gather/scatter, bitwise-identically: stage reorders become permutation
    matmuls (segments ride the permutation instead of being gathered by
    ``seg[idx2]``), the m²·s-wide cell histogram becomes the two-level
    one-hot MXU contraction of :func:`fused2_counts_body`, per-cell
    starts/G lookups become row-matmul × column-select picks in 16-bit
    halves (exact for full 32-bit globals), and the final element-order /
    values permutations apply the ONE tracked source permutation (and its
    transpose) as matmuls.
    """
    t = keys.shape[0]
    del split  # decomposition is sub-digit-wide; result is split-invariant
    # per-shape autotuned stage width (DESIGN.md §14), else the measured
    # global default — the RESULT is sub_bits-invariant (LSD identity),
    # only the stage count / plane width trade-off moves
    sb = sub_bits or _FUSED2_SUB_BITS
    m2 = 1 << bits
    idx = jnp.arange(t, dtype=jnp.int32)
    keys2, idx2 = keys, idx
    seg2 = seg if oblivious else None   # oblivious path carries seg in-order

    def _stage(d, m, keys2, idx2, seg2):
        local, hist = _fused2_stage_local(d, m, family, oblivious=oblivious)
        starts = (jnp.cumsum(hist) - hist).astype(jnp.int32)
        if oblivious:
            starts_d = select_columns(jnp.broadcast_to(starts[None, :], (t, m)), d)
            perm = permutation_matrix(starts_d + local)
            keys2 = permute_matmul_32(perm, keys2)
            idx2 = permute_matmul_32(perm, idx2)
            if seg2 is not None:
                seg2 = permute_matmul_32(perm, seg2)
            return keys2, idx2, seg2
        dest = starts[d] + local
        return (jnp.zeros_like(keys2).at[dest].set(keys2),
                jnp.zeros_like(idx2).at[dest].set(idx2), seg2)

    # ---- in-VMEM LSD sweep: sub-digit stages LSB→MSB across the pair bits;
    # values are never moved per stage — idx2 tracks the source slot, so
    # they are picked up once at the end.
    for off in range(0, bits, sb):
        b = min(sb, bits - off)
        m = 1 << b
        d = ((keys2.astype(jnp.uint32) >> jnp.uint32(shift + off))
             & jnp.uint32(m - 1)).astype(jnp.int32)
        keys2, idx2, seg2 = _stage(d, m, keys2, idx2, seg2)
    if seg is not None and num_segments > 1:
        d_seg = seg2 if oblivious else seg[idx2]
        keys2, idx2, seg2 = _stage(d_seg, num_segments, keys2, idx2, seg2)

    # ---- placement: the tile is (seg, pair)-bucket-major, so the stable
    # in-cell rank is position minus the cell's tile start
    pair2 = ((keys2.astype(jnp.uint32) >> jnp.uint32(shift))
             & jnp.uint32(m2 - 1)).astype(jnp.int32)
    if oblivious:
        cg2 = pair2 if seg is None else seg2 * m2 + pair2
        col_bits, n_rows, n_cols = _pair_hist2d_shape(bits, num_segments)
        row2 = (cg2 >> col_bits).astype(jnp.int32)
        col2 = (cg2 & (n_cols - 1)).astype(jnp.int32)
        oh_r = one_hot_f32(row2, n_rows)                    # (T, R)
        oh_c = one_hot_f32(col2, n_cols)                    # (T, C)
        hist2d = jax.lax.dot(oh_r.T, oh_c, precision=jax.lax.Precision.HIGHEST)
        hist_c = hist2d.reshape(-1).astype(jnp.int32)
        starts_t = (jnp.cumsum(hist_c) - hist_c).astype(jnp.int32)

        col_iota = jax.lax.broadcasted_iota(jnp.int32, (t, n_cols), 1)
        col_mask = (col_iota == col2[:, None])

        def _pick2d(flat_vals):
            # flat_vals[cg2] without a gather: one-hot row matmul brings the
            # element's (C,) row slice in, a masked column select finishes;
            # 16-bit halves keep full 32-bit values f32-exact.
            u = jax.lax.bitcast_convert_type(
                flat_vals.reshape(n_rows, n_cols), jnp.uint32)
            lo = jax.lax.dot(oh_r, (u & jnp.uint32(0xFFFF)).astype(jnp.float32),
                             precision=jax.lax.Precision.HIGHEST)
            hi = jax.lax.dot(oh_r, (u >> jnp.uint32(16)).astype(jnp.float32),
                             precision=jax.lax.Precision.HIGHEST)
            lo_s = jnp.where(col_mask, lo, 0.0).sum(axis=1).astype(jnp.uint32)
            hi_s = jnp.where(col_mask, hi, 0.0).sum(axis=1).astype(jnp.uint32)
            return jax.lax.bitcast_convert_type(
                lo_s | (hi_s << jnp.uint32(16)), jnp.int32)

        local_c = idx - _pick2d(starts_t)
        gpos2 = (_pick2d(g_row.astype(jnp.int32)) + local_c).astype(jnp.int32)
        q = permutation_matrix(idx2)         # q[j, i] = (idx2_i == j)
        vals_r = permute_matmul_32(q.T, vals) if vals is not None else None
        gpos = permute_matmul_32(q, gpos2)                  # element-ordered perm
        return keys2, vals_r, gpos2, gpos

    cg2 = pair2 if seg is None else seg[idx2] * m2 + pair2
    hist_c = jnp.zeros((m2 * num_segments,), jnp.int32).at[cg2].add(1)
    starts_t = (jnp.cumsum(hist_c) - hist_c).astype(jnp.int32)
    local_c = idx - starts_t[cg2]
    gpos2 = (g_row.astype(jnp.int32)[cg2] + local_c).astype(jnp.int32)

    vals_r = vals[idx2] if vals is not None else None
    gpos = jnp.zeros_like(gpos2).at[idx2].set(gpos2)        # element-ordered perm
    return keys2, vals_r, gpos2, gpos


def fused2_positions_body(
    keys: Array,
    g_row: Array,
    shift: int,
    split: int,
    bits: int,
    seg: Optional[Array] = None,
    num_segments: int = 1,
    family: str = "onehot",
    sub_bits: Optional[int] = None,
    oblivious: bool = False,
) -> Array:
    """Fused2 DMS postscan: global pair destinations in element order —
    the ``gpos`` byproduct of the full body (the in-VMEM reorder is still
    how the combined rank is derived)."""
    return fused2_postscan_body(
        keys, g_row, None, shift, split, bits, seg=seg,
        num_segments=num_segments, family=family, sub_bits=sub_bits,
        oblivious=oblivious,
    )[3]


def fused2_vmem_bytes(
    tile: int, m_lo: int, num_segments: int = 1, family: str = "onehot",
    key_value: bool = False, m_hi: Optional[int] = None,
    sub_bits: Optional[int] = None, oblivious: bool = False,
) -> int:
    """Working-set model of the DOUBLE-RESIDENT fused2 tile, in bytes: ONE
    sub-digit-wide stage solve plane (reused across the LSD sweep's stages —
    width ``min(2^_FUSED2_SUB_BITS, m)``, or ``num_segments`` if wider), the
    reordered keys/index copies living alongside the originals (+ the values
    gather when key-value), and the m²-wide histogram/scan/starts rows. The
    tile heuristic budgets this instead of the single-digit cost when
    ``digits=2`` (DESIGN.md §13) — the gather form grows only ~linearly in T
    with a SMALL constant, which is what lets fused tiles be much larger
    than single-digit ones (and they must be: a pair's G traffic is L·m²
    words, so the pair only profits when L is small).

    ``oblivious=True`` (the kernel backends' compiled-lowerable bodies)
    additionally charges the T×T permutation planes (one per in-flight
    stage reorder plus the tracked source permutation) and the two-level
    one-hot / pick planes (T×(R + 2C) f32, R·C = m²·s) — the quadratic term
    dominates and pulls the fused2 tile optimum DOWN on kernel backends,
    the opposite shift of the gather form (DESIGN.md §15)."""
    m_hi = m_lo if m_hi is None else m_hi
    m2 = m_lo * m_hi
    stage_w = max(min(1 << (sub_bits or _FUSED2_SUB_BITS), max(m_lo, m_hi)),
                  num_segments)
    if family == "packed":
        lay = packed_layout(tile, stage_w)
        solve = 4 * (2 * tile * lay.w + 3 * lay.n_sub * stage_w)
    else:
        solve = 4 * 2 * tile * pad_lanes(stage_w)
    # keys + keys2 + idx2 + digit strip + dest (+ values, values gather)
    resident = 4 * tile * (5 + (2 if key_value else 0))
    pair_rows = 4 * 3 * m2 * num_segments                   # hist / G row / starts
    total = solve + resident + pair_rows
    if oblivious:
        bits = max(1, (m2 - 1).bit_length())
        _, n_rows, n_cols = _pair_hist2d_shape(bits, num_segments)
        total += 4 * (2 * tile * tile + tile * (n_rows + 2 * n_cols))
    return total


def permute_matmul_32(perm: Array, x: Array) -> Array:
    """Permute a (T,) vector of 32-bit words by the (T, T) matrix ``perm``.

    Bitcasts to uint32 (exact for int32/uint32/float32 payloads), splits into
    16-bit halves so the fp32 MXU accumulation is exact, permutes both halves
    in one matmul, and reassembles.
    """
    xi = jax.lax.bitcast_convert_type(x, jnp.uint32)
    halves = jnp.stack(
        [(xi & jnp.uint32(0xFFFF)).astype(jnp.float32),
         (xi >> jnp.uint32(16)).astype(jnp.float32)], axis=1
    )                                                       # (T, 2)
    moved = jax.lax.dot(perm, halves, precision=jax.lax.Precision.HIGHEST)
    lo = moved[:, 0].astype(jnp.uint32)
    hi = moved[:, 1].astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(lo | (hi << jnp.uint32(16)), x.dtype)
