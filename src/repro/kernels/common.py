"""Shared building blocks for the multisplit Pallas kernels (DESIGN.md §4).

Every kernel in this package is built from the same four VMEM-resident
primitives, so they live in one module instead of being re-derived per file:

* :func:`one_hot_f32`   — the paper's binary matrix ``H̄`` (§4.5) built with a
  broadcasted iota compare (no gather, VPU-friendly).
* :func:`cumsum_mxu`    — inclusive column scan as a lower-triangular ones
  matmul: maps the warp-scan of paper Alg. 3 onto the MXU systolic array.
* :func:`exclusive_starts_mxu` — exclusive scan of a histogram row via a
  *strictly* lower-triangular matmul (bucket start offsets).
* :func:`permute_matmul_32` — apply a within-tile permutation to 32-bit words
  as TWO half-word one-hot matmuls (16-bit halves keep fp32 accumulation
  exact) — MXU work instead of a serialized scatter (paper §4.7 reorder).

All integer payloads are carried through fp32 matmuls in exact range
(< 2^24 per half-word / count), which every kernel test checks bit-exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def pad_lanes(m: int) -> int:
    """Pad the bucket axis to a multiple of 128 lanes (min one full lane)."""
    return max(128, ((m + 127) // 128) * 128)


def one_hot_f32(ids: Array, m_pad: int) -> Array:
    """(T,) int32 -> (T, m_pad) f32 one-hot via broadcasted iota (no gather)."""
    t = ids.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, m_pad), 1)
    return (cols == ids[:, None]).astype(jnp.float32)


def cumsum_mxu(x: Array) -> Array:
    """Inclusive column cumsum as a lower-triangular matmul (MXU-native)."""
    t = x.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    tril = (rows >= cols).astype(jnp.float32)
    return jax.lax.dot(tril, x, precision=jax.lax.Precision.HIGHEST)


def exclusive_starts_mxu(hist: Array) -> Array:
    """(m,) f32 histogram -> (m,) exclusive prefix (bucket start offsets)."""
    m = hist.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    strict_tril = (rows > cols).astype(jnp.float32)
    return jax.lax.dot(strict_tril, hist[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]


def permutation_matrix(dest: Array) -> Array:
    """(T,) int32 destinations -> (T, T) f32 P with P[j, i] = (dest_i == j)."""
    t = dest.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    return (rows == dest[None, :]).astype(jnp.float32)


def fused_postscan_body(ids, g_row, keys, vals, m_pad: int):
    """THE fused postscan+reorder math, shared by the generic and radix
    kernels (they differ only in where ``ids`` comes from): ONE
    one-hot/cumsum evaluation yields local ranks, the tile histogram and
    bucket starts, the within-tile destination, the global destination
    (paper eq. (2)), and the bucket-major permutation of keys/values/
    positions. Returns (keys_r, vals_r_or_None, pos_r, gpos)."""
    t = ids.shape[0]
    one_hot = one_hot_f32(ids, m_pad)                       # THE one-hot (T, m)
    incl = cumsum_mxu(one_hot)                              # THE cumsum
    local = ((incl - 1.0) * one_hot).sum(axis=1)            # (T,) in-bucket rank
    hist = incl[t - 1, :]                                   # (m,) tile histogram
    starts = exclusive_starts_mxu(hist)                     # (m,) tile bucket starts
    pick = lambda row: jax.lax.dot(
        one_hot, row[:, None], precision=jax.lax.Precision.HIGHEST
    )[:, 0]
    dest = (pick(starts) + local).astype(jnp.int32)         # within-tile destination
    gpos = (pick(g_row.astype(jnp.float32)) + local).astype(jnp.int32)  # eq. (2)
    perm = permutation_matrix(dest)
    keys_r = permute_matmul_32(perm, keys)
    pos_r = permute_matmul_32(perm, gpos)
    vals_r = permute_matmul_32(perm, vals) if vals is not None else None
    return keys_r, vals_r, pos_r, gpos


def permute_matmul_32(perm: Array, x: Array) -> Array:
    """Permute a (T,) vector of 32-bit words by the (T, T) matrix ``perm``.

    Bitcasts to uint32 (exact for int32/uint32/float32 payloads), splits into
    16-bit halves so the fp32 MXU accumulation is exact, permutes both halves
    in one matmul, and reassembles.
    """
    xi = jax.lax.bitcast_convert_type(x, jnp.uint32)
    halves = jnp.stack(
        [(xi & jnp.uint32(0xFFFF)).astype(jnp.float32),
         (xi >> jnp.uint32(16)).astype(jnp.float32)], axis=1
    )                                                       # (T, 2)
    moved = jax.lax.dot(perm, halves, precision=jax.lax.Precision.HIGHEST)
    lo = moved[:, 0].astype(jnp.uint32)
    hi = moved[:, 1].astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(lo | (hi << jnp.uint32(16)), x.dtype)
