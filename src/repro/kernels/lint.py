"""Jaxpr lint: prove the compiled kernel path is gather/scatter-free.

Mosaic (the TPU Pallas compiler, ``interpret=False``) does not lower
in-kernel gathers (``x[ids]``, ``take_along_axis``), scatters
(``.at[ids].set/add``) or tensor-indexed dynamic slices.  DESIGN.md §15
replaces every such access in the tile stage bodies with oblivious,
lane-parallel forms (masked one-hot selects, 16-bit rank planes,
permutation matmuls).  This module is the *proof obligation*: it traces
every Pallas kernel entry point exactly as the pipeline invokes it
(oblivious defaults), walks the jaxpr recursively, and asserts that no
forbidden primitive appears INSIDE any ``pallas_call`` body.

Tracing is execution-free and identical for ``interpret=True`` and the
compiled path — the jaxpr is the same program Mosaic would receive — so
the lint runs on any host, no TPU required.  Gathers OUTSIDE kernels
(host-side padding, the vmap oracle stages) are deliberately not flagged:
XLA lowers them fine and they are the fast host path.

Run as a module for the CI step::

    python -m repro.kernels.lint          # report + exit 1 on violation
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.4.x exposes the stable aliases here
    from jax.extend import core as _core
except ImportError:  # pragma: no cover - older jax
    from jax import core as _core  # type: ignore

from repro.core.identifiers import BitfieldSpec, EvenSpec, RangeSpec
from repro.kernels import multisplit_tile as _mst
from repro.kernels import radix_pass as _rp

# Primitives Mosaic cannot lower inside a TPU kernel body. ``cumsum`` and
# iota/broadcast compares are NOT here — they are the allowed oblivious
# vocabulary (DESIGN.md §15).
FORBIDDEN_PRIMITIVES = frozenset(
    {"gather", "scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max"}
)
# dynamic_slice / dynamic_update_slice are forbidden only when a start
# operand is a tensor (rank > 0): scalar-start slices are static layout
# arithmetic, tensor starts are a gather in disguise.
_DYNAMIC_SLICE = {"dynamic_slice": 1, "dynamic_update_slice": 2}


@dataclasses.dataclass(frozen=True)
class LintResult:
    """Lint verdict for one kernel entry point."""

    name: str
    pallas_calls: int                 # pallas_call eqns seen in the trace
    kernel_primitives: Tuple[str, ...]  # sorted primitive names inside kernels
    violations: Tuple[str, ...]       # forbidden primitives found inside

    @property
    def ok(self) -> bool:
        return not self.violations and self.pallas_calls > 0


def _sub_jaxprs(params) -> List:
    """Every Jaxpr/ClosedJaxpr nested in an eqn's params (any structure)."""
    found = []

    def visit(v):
        if isinstance(v, _core.ClosedJaxpr):
            found.append(v.jaxpr)
        elif isinstance(v, _core.Jaxpr):
            found.append(v)
        elif isinstance(v, (list, tuple)):
            for x in v:
                visit(x)
        elif isinstance(v, dict):
            for x in v.values():
                visit(x)

    for v in params.values():
        visit(v)
    return found


def _walk(jaxpr, inside: bool, prims: set, violations: list, counter: list) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        is_pallas = name == "pallas_call"
        if is_pallas:
            counter[0] += 1
        if inside:
            prims.add(name)
            if name in FORBIDDEN_PRIMITIVES:
                violations.append(name)
            elif name in _DYNAMIC_SLICE:
                starts = eqn.invars[_DYNAMIC_SLICE[name]:]
                if any(getattr(v, "aval", None) is not None and v.aval.ndim > 0
                       for v in starts):
                    violations.append(f"{name}[tensor-start]")
        for sub in _sub_jaxprs(eqn.params):
            _walk(sub, inside or is_pallas, prims, violations, counter)


def lint_fn(fn: Callable, *args, name: str = "<fn>") -> LintResult:
    """Trace ``fn(*args)`` and lint every pallas_call body in the jaxpr."""
    closed = jax.make_jaxpr(fn)(*args)
    prims: set = set()
    violations: list = []
    counter = [0]
    _walk(closed.jaxpr, False, prims, violations, counter)
    return LintResult(
        name=name,
        pallas_calls=counter[0],
        kernel_primitives=tuple(sorted(prims)),
        violations=tuple(sorted(set(violations))),
    )


# ---------------------------------------------------------------------------
# Canonical entry-point registry: every Pallas door the pipeline dispatches
# through, traced with the shapes/flags the plan layer actually uses and the
# oblivious (compiled-path) defaults. Each value is a zero-arg thunk
# returning a LintResult, so registry construction stays trace-free.
# ---------------------------------------------------------------------------

_L, _T, _M = 2, 256, 16


def _ids():
    return jnp.zeros((_L, _T), jnp.int32)


def _keys():
    return jnp.zeros((_L, _T), jnp.uint32)


def _seg():
    return jnp.zeros((_L, _T), jnp.int32)


def _g(m):
    return jnp.zeros((_L, m), jnp.int32)


def _range_spec(s: int) -> RangeSpec:
    return RangeSpec(tuple(np.arange(1, s + 1, dtype=np.uint32) * 7))


def kernel_entry_points() -> Dict[str, Callable[[], LintResult]]:
    spec4 = BitfieldSpec(0, 4)
    even = EvenSpec(0.0, 1024.0, _M)
    pair = BitfieldSpec(0, 8)          # fused2 combined pair digit, m = 256
    ep: Dict[str, Callable[[], LintResult]] = {}

    def add(name, fn, *args):
        ep[name] = lambda: lint_fn(fn, *args, name=name)

    # dense strip kernels
    add("dense/histograms", lambda i: _mst.tile_histograms_pallas(i, _M), _ids())
    add("dense/positions",
        lambda i, g: _mst.tile_positions_pallas(i, g, _M), _ids(), _g(_M))
    add("dense/fused_kv",
        lambda i, g, k, v: _mst.fused_postscan_reorder_pallas(i, g, k, v, _M),
        _ids(), _g(_M), _keys(), _keys())
    add("dense/reorder",
        lambda i, k, v: _mst.tile_reorder_pallas(i, k, v, _M),
        _ids(), _keys(), _keys())

    # segmented strip kernels (cid = seg*m + bucket in-register)
    add("seg/histograms",
        lambda i, s: _mst.seg_tile_histograms_pallas(i, s, _M, 2),
        _ids(), _seg())
    add("seg/positions",
        lambda i, s, g: _mst.seg_tile_positions_pallas(i, s, g, _M, 2),
        _ids(), _seg(), _g(2 * _M))
    add("seg/fused_kv",
        lambda i, s, g, k, v: _mst.seg_fused_postscan_reorder_pallas(
            i, s, g, k, v, _M, 2),
        _ids(), _seg(), _g(2 * _M), _keys(), _keys())

    # fused-label (spec) kernels — bitfield, even and range-tree labels
    add("spec/histograms",
        lambda k: _mst.spec_tile_histograms_pallas(k, spec4), _keys())
    add("spec/positions",
        lambda k, g: _mst.spec_tile_positions_pallas(k, g, spec4),
        _keys(), _g(_M))
    add("spec/fused_kv",
        lambda k, g, v: _mst.spec_fused_postscan_reorder_pallas(k, g, v, spec4),
        _keys(), _g(_M), _keys())
    add("spec/bucket_ids_even",
        lambda k: _mst.spec_bucket_ids_pallas(k.astype(jnp.float32), even),
        _keys())
    add("spec/positions_range31",
        lambda k, g: _mst.spec_tile_positions_pallas(k, g, _range_spec(31)),
        _keys(), _g(32))
    add("spec/bucket_ids_range255",
        lambda k: _mst.spec_bucket_ids_pallas(k, _range_spec(255)), _keys())

    # segmented fused-label kernels
    add("seg_spec/histograms",
        lambda k, s: _mst.seg_spec_tile_histograms_pallas(k, s, spec4, 2),
        _keys(), _seg())
    add("seg_spec/positions",
        lambda k, s, g: _mst.seg_spec_tile_positions_pallas(k, s, g, spec4, 2),
        _keys(), _seg(), _g(2 * _M))
    add("seg_spec/fused_kv",
        lambda k, s, g, v: _mst.seg_spec_fused_postscan_reorder_pallas(
            k, s, g, v, spec4, 2),
        _keys(), _seg(), _g(2 * _M), _keys())

    # packed family (rank planes; histograms kernel is family-shared)
    add("packed/histograms",
        lambda i: _mst.packed_tile_histograms_pallas(i, _M), _ids())
    add("packed/positions",
        lambda i, g: _mst.packed_tile_positions_pallas(i, g, _M),
        _ids(), _g(_M))
    add("packed/positions_seg_spec",
        lambda k, s, g: _mst.packed_tile_positions_pallas(
            k, g, 0, spec=spec4, seg_tiled=s, num_segments=2),
        _keys(), _seg(), _g(2 * _M))
    add("packed/fused_kv",
        lambda i, g, k, v: _mst.packed_fused_postscan_reorder_pallas(
            i, g, k, v, num_buckets=_M),
        _ids(), _g(_M), _keys(), _keys())
    add("packed/fused_kv_seg_spec",
        lambda k, s, g, v: _mst.packed_fused_postscan_reorder_pallas(
            k, g, values_tiled=v, spec=spec4, seg_tiled=s, num_segments=2),
        _keys(), _seg(), _g(2 * _M), _keys())

    # fused two-digit family (pair digit, both stage families)
    add("fused2/histograms",
        lambda k: _mst.fused2_tile_histograms_pallas(k, pair), _keys())
    add("fused2/histograms_seg",
        lambda k, s: _mst.fused2_tile_histograms_pallas(
            k, pair, seg_tiled=s, num_segments=2),
        _keys(), _seg())
    add("fused2/positions_onehot",
        lambda k, g: _mst.fused2_tile_positions_pallas(k, g, pair, 4),
        _keys(), _g(256))
    add("fused2/positions_packed",
        lambda k, g: _mst.fused2_tile_positions_pallas(
            k, g, pair, 4, family="packed"),
        _keys(), _g(256))
    add("fused2/fused_kv_onehot_seg",
        lambda k, s, g, v: _mst.fused2_fused_postscan_reorder_pallas(
            k, g, v, spec=pair, split=4, seg_tiled=s, num_segments=2),
        _keys(), _seg(), _g(512), _keys())
    add("fused2/fused_kv_packed",
        lambda k, g, v: _mst.fused2_fused_postscan_reorder_pallas(
            k, g, v, spec=pair, split=4, family="packed"),
        _keys(), _g(256), _keys())

    # radix doors (thin BitfieldSpec wrappers — linted as dispatched)
    add("radix/histograms",
        lambda k: _rp.radix_tile_histograms_pallas(k, 8, 4), _keys())
    add("radix/fused_kv",
        lambda k, g, v: _rp.radix_fused_postscan_reorder_pallas(k, g, v, 8, 4),
        _keys(), _g(_M), _keys())
    add("seg_radix/fused_kv",
        lambda k, s, g, v: _rp.seg_radix_fused_postscan_reorder_pallas(
            k, s, g, v, 8, 4, 2),
        _keys(), _seg(), _g(2 * _M), _keys())

    return ep


@functools.lru_cache(maxsize=1)
def lint_kernels() -> Tuple[LintResult, ...]:
    """Lint every registered entry point; cached (tracing is pure)."""
    return tuple(thunk() for thunk in kernel_entry_points().values())


def lint_report() -> str:
    """Markdown table of per-entry-point lint verdicts (CI step summary)."""
    lines = [
        "| entry point | pallas_calls | verdict | in-kernel primitives |",
        "|---|---|---|---|",
    ]
    for r in lint_kernels():
        verdict = "OK" if r.ok else "FORBIDDEN: " + ", ".join(r.violations)
        lines.append(
            f"| `{r.name}` | {r.pallas_calls} | {verdict} | "
            f"{', '.join(r.kernel_primitives)} |"
        )
    return "\n".join(lines)


def main() -> int:
    results = lint_kernels()
    print(lint_report())
    bad = [r for r in results if not r.ok]
    print()
    print(f"{len(results)} entry points linted, {len(bad)} violations")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
