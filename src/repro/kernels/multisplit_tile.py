"""Pallas TPU kernels for the multisplit direct solve (paper §4.5, §5.5).

One grid program processes one tile (the paper's subproblem): a VMEM-resident
strip of bucket ids. The GPU ballot/popc machinery is replaced by a one-hot
matrix in VMEM reduced/scanned with MXU-friendly dense ops (DESIGN.md §2);
the shared primitives live in :mod:`repro.kernels.common`.

Kernels:

* ``tile_histograms_pallas``       — prescan direct solve (paper Alg. 2).
* ``tile_positions_pallas``        — postscan for DMS (no reorder): final
                                     destinations only (paper eq. (2)).
* ``fused_postscan_reorder_pallas``— THE WMS/BMS postscan (DESIGN.md §4):
                                     local ranks, global destinations AND the
                                     within-tile bucket-major reorder of keys,
                                     values and destinations from a single
                                     one-hot/cumsum evaluation. This is the
                                     only postscan/reorder entry point of the
                                     fused pipeline — it replaces the three
                                     separate postscan/reorder-keys/
                                     reorder-values passes of the legacy host
                                     orchestration.
* ``tile_reorder_pallas``          — standalone reorder, kept as the unfused
                                     baseline for kernel tests and the
                                     fused-vs-legacy benchmark.

Segmented variants (``seg_*``, DESIGN.md §9): identical math, but each tile
additionally carries a per-element SEGMENT id strip. The kernel combines
``cid = seg * m + bucket`` in-register, so the one-hot/cumsum pass ranks
every element within its own (segment, bucket) cell — many independent
ragged multisplits per grid launch, no host-side combined-id array and no
per-segment relaunch.

Fused-label variants (``spec_*``, DESIGN.md §11): the kernels take the KEY
strip plus a hashable :class:`~repro.core.identifiers.BucketSpec` (a static
kernel parameter) and evaluate ``spec.emit_in_kernel(keys)`` *inside* the
kernel —
bucket ids live only in registers/VMEM, exactly the paper's warp-private
bucket computation, for EVERY declarative spec (delta, range/splitter,
even, identity, radix bitfield), not just the radix digit. The n-sized
label array of the pre-PR-4 pipeline never exists for these specs; the
radix kernels in :mod:`repro.kernels.radix_pass` are now thin
``BitfieldSpec`` instantiations of this machinery.

Packed-counter variants (``packed_*``, DESIGN.md §12): the second KERNEL
FAMILY. Same stage contracts as the dense kernels above, but the local
solve uses bit-packed subword counters + two-level (subtile -> tile)
ranking (paper §4.3) instead of the T×m one-hot/cumsum, so per-key work
and VMEM stay ~flat in the bucket count. One generic kernel per stage
covers all four dense shapes ({ids | fused-spec labels} × {flat |
segmented}); family selection is a plan axis resolved by
:func:`repro.core.pipeline.tiles.resolve_kernel_family`.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (
    cumsum_mxu as _cumsum_mxu,
    exclusive_starts_mxu,
    fused2_counts_body,
    fused2_positions_body,
    fused2_postscan_body,
    fused_postscan_body,
    one_hot_f32 as _one_hot,
    packed_counts,
    packed_layout,
    packed_positions_body,
    packed_postscan_body,
    pad_lanes as _pad_lanes,
    permutation_matrix,
    permute_matmul_32,
)

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Kernel 1: per-tile histograms (the prescan direct solve)
# ---------------------------------------------------------------------------

def _histogram_kernel(ids_ref, hist_ref, *, m_pad: int):
    ids = ids_ref[0, :]
    one_hot = _one_hot(ids, m_pad)
    hist_ref[0, :] = one_hot.sum(axis=0).astype(jnp.int32)


def tile_histograms_pallas(ids_tiled: Array, num_buckets: int, *, interpret: bool = True) -> Array:
    """(L, T) int32 ids -> (L, m) int32 histograms."""
    n_tiles, t = ids_tiled.shape
    m_pad = _pad_lanes(num_buckets)
    out = pl.pallas_call(
        functools.partial(_histogram_kernel, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_pad), jnp.int32),
        interpret=interpret,
    )(ids_tiled)
    return out[:, :num_buckets]


# ---------------------------------------------------------------------------
# Kernel 2: per-tile final positions (the DMS postscan direct solve)
# ---------------------------------------------------------------------------

def _positions_kernel(ids_ref, g_ref, pos_ref, *, m_pad: int):
    ids = ids_ref[0, :]
    g = g_ref[0, :].astype(jnp.float32)
    one_hot = _one_hot(ids, m_pad)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)          # rank within bucket
    base = jax.lax.dot(one_hot, g[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    pos_ref[0, :] = (base + local).astype(jnp.int32)


def tile_positions_pallas(
    ids_tiled: Array, g: Array, num_buckets: int, *, interpret: bool = True
) -> Array:
    """(L, T) ids + (L, m) bases -> (L, T) destinations (paper eq. (2))."""
    n_tiles, t = ids_tiled.shape
    m_pad = _pad_lanes(num_buckets)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :num_buckets].set(g)
    return pl.pallas_call(
        functools.partial(_positions_kernel, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(ids_tiled, g_pad)


# ---------------------------------------------------------------------------
# Kernel 3 (THE fused WMS/BMS postscan): one one-hot/cumsum evaluation per
# tile yields local ranks, global destinations, and the bucket-major reorder
# of keys, values and destinations (paper §4.5 + §4.7 in one VMEM pass).
# ---------------------------------------------------------------------------

def _fused_postscan_kernel(*refs, m_pad: int, has_values: bool):
    if has_values:
        (ids_ref, g_ref, keys_ref, vals_ref,
         keys_out_ref, vals_out_ref, pos_out_ref, perm_out_ref) = refs
    else:
        ids_ref, g_ref, keys_ref, keys_out_ref, pos_out_ref, perm_out_ref = refs
        vals_ref = vals_out_ref = None

    keys_r, vals_r, pos_r, gpos = fused_postscan_body(
        ids_ref[0, :], g_ref[0, :], keys_ref[0, :],
        vals_ref[0, :] if has_values else None, m_pad,
    )
    keys_out_ref[0, :] = keys_r
    pos_out_ref[0, :] = pos_r
    perm_out_ref[0, :] = gpos                               # element-ordered perm
    if has_values:
        vals_out_ref[0, :] = vals_r


def fused_postscan_reorder_pallas(
    ids_tiled: Array,
    g: Array,
    keys_tiled: Array,
    values_tiled: Optional[Array],
    num_buckets: int,
    *,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Fused postscan+reorder: (L,T) ids, (L,m) bases, (L,T) keys [+values]
    -> (keys_r, values_r, positions_r, perm), the first three bucket-major
    within each tile and ``perm`` in original element order.

    ``positions_r[l, j]`` is the GLOBAL destination of the reordered element
    at tile slot ``j`` — the caller's scatter is the only remaining data
    movement (contiguous per-bucket runs; paper §4.7 coalescing).
    ``perm[l, i]`` is the global destination of INPUT element i (eq. (2)) —
    a free byproduct of the same one-hot/cumsum evaluation.
    """
    n_tiles, t = ids_tiled.shape
    m_pad = _pad_lanes(num_buckets)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :num_buckets].set(g)
    has_values = values_tiled is not None
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    in_specs = [row, pl.BlockSpec((1, m_pad), lambda i: (i, 0)), row] + ([row] if has_values else [])
    out_specs = [row] * (4 if has_values else 3)
    out_shape = [jax.ShapeDtypeStruct((n_tiles, t), keys_tiled.dtype)]
    if has_values:
        out_shape.append(jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype))
    out_shape += [
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
    ]
    args = (ids_tiled, g_pad, keys_tiled) + ((values_tiled,) if has_values else ())
    out = pl.pallas_call(
        functools.partial(_fused_postscan_kernel, m_pad=m_pad, has_values=has_values),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if has_values:
        keys_r, vals_r, pos_r, perm = out
        return keys_r, vals_r, pos_r, perm
    keys_r, pos_r, perm = out
    return keys_r, None, pos_r, perm


# ---------------------------------------------------------------------------
# Segmented kernels: the segment id rides THROUGH the one-hot/cumsum pass as
# the high part of the combined bucket id cid = seg*m + bucket (DESIGN.md §9).
# ---------------------------------------------------------------------------

def _seg_histogram_kernel(ids_ref, seg_ref, hist_ref, *, m: int, m_pad: int):
    cid = ids_ref[0, :] + seg_ref[0, :] * m                 # in-register combine
    hist_ref[0, :] = _one_hot(cid, m_pad).sum(axis=0).astype(jnp.int32)


def seg_tile_histograms_pallas(
    ids_tiled: Array, seg_tiled: Array, num_buckets: int, num_segments: int,
    *, interpret: bool = True,
) -> Array:
    """(L, T) bucket ids + (L, T) segment ids -> (L, s*m) combined histograms."""
    n_tiles, t = ids_tiled.shape
    m_eff = num_buckets * num_segments
    m_pad = _pad_lanes(m_eff)
    out = pl.pallas_call(
        functools.partial(_seg_histogram_kernel, m=num_buckets, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_pad), jnp.int32),
        interpret=interpret,
    )(ids_tiled, seg_tiled)
    return out[:, :m_eff]


def _seg_positions_kernel(ids_ref, seg_ref, g_ref, pos_ref, *, m: int, m_pad: int):
    cid = ids_ref[0, :] + seg_ref[0, :] * m
    g = g_ref[0, :].astype(jnp.float32)
    one_hot = _one_hot(cid, m_pad)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)
    base = jax.lax.dot(one_hot, g[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    pos_ref[0, :] = (base + local).astype(jnp.int32)


def seg_tile_positions_pallas(
    ids_tiled: Array, seg_tiled: Array, g: Array, num_buckets: int, num_segments: int,
    *, interpret: bool = True,
) -> Array:
    """Segmented DMS postscan: combined (seg, bucket) destinations, eq. (2)."""
    n_tiles, t = ids_tiled.shape
    m_eff = num_buckets * num_segments
    m_pad = _pad_lanes(m_eff)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m_eff].set(g)
    return pl.pallas_call(
        functools.partial(_seg_positions_kernel, m=num_buckets, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(ids_tiled, seg_tiled, g_pad)


def _seg_fused_postscan_kernel(*refs, m: int, m_pad: int, has_values: bool):
    if has_values:
        (ids_ref, seg_ref, g_ref, keys_ref, vals_ref,
         keys_out_ref, vals_out_ref, pos_out_ref, perm_out_ref) = refs
    else:
        (ids_ref, seg_ref, g_ref, keys_ref,
         keys_out_ref, pos_out_ref, perm_out_ref) = refs
        vals_ref = vals_out_ref = None

    cid = ids_ref[0, :] + seg_ref[0, :] * m                 # in-register combine
    keys_r, vals_r, pos_r, gpos = fused_postscan_body(
        cid, g_ref[0, :], keys_ref[0, :],
        vals_ref[0, :] if has_values else None, m_pad,
    )
    keys_out_ref[0, :] = keys_r
    pos_out_ref[0, :] = pos_r
    perm_out_ref[0, :] = gpos
    if has_values:
        vals_out_ref[0, :] = vals_r


def seg_fused_postscan_reorder_pallas(
    ids_tiled: Array,
    seg_tiled: Array,
    g: Array,
    keys_tiled: Array,
    values_tiled: Optional[Array],
    num_buckets: int,
    num_segments: int,
    *,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Segmented fused postscan+reorder: per-tile (segment, bucket)-major
    reorder + global destinations from ONE one-hot/cumsum evaluation over the
    combined id. Output contract matches :func:`fused_postscan_reorder_pallas`
    with the bucket axis widened to ``s*m``."""
    n_tiles, t = ids_tiled.shape
    m_eff = num_buckets * num_segments
    m_pad = _pad_lanes(m_eff)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m_eff].set(g)
    has_values = values_tiled is not None
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    in_specs = [row, row, pl.BlockSpec((1, m_pad), lambda i: (i, 0)), row] + (
        [row] if has_values else []
    )
    out_specs = [row] * (4 if has_values else 3)
    out_shape = [jax.ShapeDtypeStruct((n_tiles, t), keys_tiled.dtype)]
    if has_values:
        out_shape.append(jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype))
    out_shape += [
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
    ]
    args = (ids_tiled, seg_tiled, g_pad, keys_tiled) + (
        (values_tiled,) if has_values else ()
    )
    out = pl.pallas_call(
        functools.partial(
            _seg_fused_postscan_kernel, m=num_buckets, m_pad=m_pad, has_values=has_values
        ),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if has_values:
        keys_r, vals_r, pos_r, perm = out
        return keys_r, vals_r, pos_r, perm
    keys_r, pos_r, perm = out
    return keys_r, None, pos_r, perm


# ---------------------------------------------------------------------------
# Fused-label kernels (DESIGN.md §11): bucket ids computed IN-REGISTER from a
# declarative BucketSpec — the generic form of the radix kernels. ``spec`` is
# a static kernel parameter (hashable, so the jit'd wrappers cache across
# equal spec instances); ``spec.emit`` is plain vectorized jnp traced into
# the kernel body. No label strip enters or leaves the kernel.
# ---------------------------------------------------------------------------

def _spec_hist_kernel(keys_ref, hist_ref, *, spec, m_pad: int):
    ids = spec.emit_in_kernel(keys_ref[0, :])               # in-register labels
    hist_ref[0, :] = _one_hot(ids, m_pad).sum(axis=0).astype(jnp.int32)


def spec_tile_histograms_pallas(
    keys_tiled: Array, spec, *, interpret: bool = True
) -> Array:
    """(L, T) keys -> (L, m) per-tile histograms; labels fused in-kernel."""
    n_tiles, t = keys_tiled.shape
    m = spec.num_buckets
    m_pad = _pad_lanes(m)
    out = pl.pallas_call(
        functools.partial(_spec_hist_kernel, spec=spec, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_pad), jnp.int32),
        interpret=interpret,
    )(keys_tiled)
    return out[:, :m]


def _spec_positions_kernel(keys_ref, g_ref, pos_ref, *, spec, m_pad: int):
    ids = spec.emit_in_kernel(keys_ref[0, :])
    g = g_ref[0, :].astype(jnp.float32)
    one_hot = _one_hot(ids, m_pad)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)
    base = jax.lax.dot(one_hot, g[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    pos_ref[0, :] = (base + local).astype(jnp.int32)


def spec_tile_positions_pallas(
    keys_tiled: Array, g: Array, spec, *, interpret: bool = True
) -> Array:
    """Fused-label DMS postscan: (L, T) keys + (L, m) bases -> (L, T) dests."""
    n_tiles, t = keys_tiled.shape
    m = spec.num_buckets
    m_pad = _pad_lanes(m)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m].set(g)
    return pl.pallas_call(
        functools.partial(_spec_positions_kernel, spec=spec, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(keys_tiled, g_pad)


def _spec_ids_kernel(keys_ref, ids_ref, *, spec):
    ids_ref[0, :] = spec.emit_in_kernel(keys_ref[0, :]).astype(jnp.int32)


def spec_bucket_ids_pallas(
    keys_tiled: Array, spec, *, interpret: bool = True
) -> Array:
    """(L, T) keys -> (L, T) int32 bucket ids: ``spec.emit_in_kernel``
    evaluated per tile. The generic materialized-label entry point — any
    declarative BucketSpec, same plan/tile machinery as every other kernel
    (replaces the seed-era fixed-even-spec kernel in histogram_tile.py)."""
    n_tiles, t = keys_tiled.shape
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_spec_ids_kernel, spec=spec),
        grid=(n_tiles,),
        in_specs=[row],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(keys_tiled)


def _spec_fused_postscan_kernel(*refs, spec, m_pad: int, has_values: bool):
    if has_values:
        (keys_ref, g_ref, vals_ref,
         keys_out_ref, vals_out_ref, pos_out_ref, perm_out_ref) = refs
    else:
        keys_ref, g_ref, keys_out_ref, pos_out_ref, perm_out_ref = refs
        vals_ref = vals_out_ref = None

    keys = keys_ref[0, :]
    ids = spec.emit_in_kernel(keys)                         # in-register labels
    keys_r, vals_r, pos_r, gpos = fused_postscan_body(
        ids, g_ref[0, :], keys, vals_ref[0, :] if has_values else None, m_pad
    )
    keys_out_ref[0, :] = keys_r
    pos_out_ref[0, :] = pos_r
    perm_out_ref[0, :] = gpos                               # element-ordered perm
    if has_values:
        vals_out_ref[0, :] = vals_r


def spec_fused_postscan_reorder_pallas(
    keys_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    spec,
    *,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Fused-label WMS/BMS postscan: contract of
    :func:`fused_postscan_reorder_pallas` with the label strip replaced by
    in-kernel ``spec.emit`` evaluation."""
    n_tiles, t = keys_tiled.shape
    m = spec.num_buckets
    m_pad = _pad_lanes(m)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m].set(g)
    has_values = values_tiled is not None
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    in_specs = [row, pl.BlockSpec((1, m_pad), lambda i: (i, 0))] + ([row] if has_values else [])
    out_specs = [row] * (4 if has_values else 3)
    out_shape = [jax.ShapeDtypeStruct((n_tiles, t), keys_tiled.dtype)]
    if has_values:
        out_shape.append(jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype))
    out_shape += [
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
    ]
    args = (keys_tiled, g_pad) + ((values_tiled,) if has_values else ())
    out = pl.pallas_call(
        functools.partial(
            _spec_fused_postscan_kernel, spec=spec, m_pad=m_pad, has_values=has_values
        ),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if has_values:
        keys_r, vals_r, pos_r, perm = out
        return keys_r, vals_r, pos_r, perm
    keys_r, pos_r, perm = out
    return keys_r, None, pos_r, perm


# -- segmented fused-label kernels: cid = seg*m + spec.emit(keys), both parts
# computed in-register (DESIGN.md §9 x §11).

def _seg_spec_hist_kernel(keys_ref, seg_ref, hist_ref, *, spec, m_pad: int):
    cid = spec.emit_in_kernel(keys_ref[0, :]) + seg_ref[0, :] * spec.num_buckets
    hist_ref[0, :] = _one_hot(cid, m_pad).sum(axis=0).astype(jnp.int32)


def seg_spec_tile_histograms_pallas(
    keys_tiled: Array, seg_tiled: Array, spec, num_segments: int,
    *, interpret: bool = True,
) -> Array:
    """(L, T) keys + (L, T) segment ids -> (L, s*m) combined histograms."""
    n_tiles, t = keys_tiled.shape
    m_eff = spec.num_buckets * num_segments
    m_pad = _pad_lanes(m_eff)
    out = pl.pallas_call(
        functools.partial(_seg_spec_hist_kernel, spec=spec, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_pad), jnp.int32),
        interpret=interpret,
    )(keys_tiled, seg_tiled)
    return out[:, :m_eff]


def _seg_spec_positions_kernel(keys_ref, seg_ref, g_ref, pos_ref, *, spec, m_pad: int):
    cid = spec.emit_in_kernel(keys_ref[0, :]) + seg_ref[0, :] * spec.num_buckets
    g = g_ref[0, :].astype(jnp.float32)
    one_hot = _one_hot(cid, m_pad)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)
    base = jax.lax.dot(one_hot, g[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    pos_ref[0, :] = (base + local).astype(jnp.int32)


def seg_spec_tile_positions_pallas(
    keys_tiled: Array, seg_tiled: Array, g: Array, spec, num_segments: int,
    *, interpret: bool = True,
) -> Array:
    """Segmented fused-label DMS postscan: (seg, bucket) dests, eq. (2)."""
    n_tiles, t = keys_tiled.shape
    m_eff = spec.num_buckets * num_segments
    m_pad = _pad_lanes(m_eff)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m_eff].set(g)
    return pl.pallas_call(
        functools.partial(_seg_spec_positions_kernel, spec=spec, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(keys_tiled, seg_tiled, g_pad)


def _seg_spec_fused_postscan_kernel(*refs, spec, m_pad: int, has_values: bool):
    if has_values:
        (keys_ref, seg_ref, g_ref, vals_ref,
         keys_out_ref, vals_out_ref, pos_out_ref, perm_out_ref) = refs
    else:
        keys_ref, seg_ref, g_ref, keys_out_ref, pos_out_ref, perm_out_ref = refs
        vals_ref = vals_out_ref = None

    keys = keys_ref[0, :]
    cid = spec.emit_in_kernel(keys) + seg_ref[0, :] * spec.num_buckets
    keys_r, vals_r, pos_r, gpos = fused_postscan_body(
        cid, g_ref[0, :], keys, vals_ref[0, :] if has_values else None, m_pad
    )
    keys_out_ref[0, :] = keys_r
    pos_out_ref[0, :] = pos_r
    perm_out_ref[0, :] = gpos
    if has_values:
        vals_out_ref[0, :] = vals_r


def seg_spec_fused_postscan_reorder_pallas(
    keys_tiled: Array,
    seg_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    spec,
    num_segments: int,
    *,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Segmented fused-label postscan+reorder: contract of
    :func:`seg_fused_postscan_reorder_pallas` with in-kernel labels."""
    n_tiles, t = keys_tiled.shape
    m_eff = spec.num_buckets * num_segments
    m_pad = _pad_lanes(m_eff)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :m_eff].set(g)
    has_values = values_tiled is not None
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    in_specs = [row, row, pl.BlockSpec((1, m_pad), lambda i: (i, 0))] + (
        [row] if has_values else []
    )
    out_specs = [row] * (4 if has_values else 3)
    out_shape = [jax.ShapeDtypeStruct((n_tiles, t), keys_tiled.dtype)]
    if has_values:
        out_shape.append(jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype))
    out_shape += [
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
    ]
    args = (keys_tiled, seg_tiled, g_pad) + ((values_tiled,) if has_values else ())
    out = pl.pallas_call(
        functools.partial(
            _seg_spec_fused_postscan_kernel, spec=spec, m_pad=m_pad,
            has_values=has_values,
        ),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if has_values:
        keys_r, vals_r, pos_r, perm = out
        return keys_r, vals_r, pos_r, perm
    keys_r, pos_r, perm = out
    return keys_r, None, pos_r, perm


# ---------------------------------------------------------------------------
# PACKED kernel family (DESIGN.md §12): subword bucket counters packed k per
# uint32 word + two-level (subtile -> tile) ranking, replacing the T×m
# one-hot/cumsum of every kernel above. ONE generic kernel per pipeline
# stage covers all four dense shapes — {ids strip | in-register spec labels}
# × {flat | segmented} — selected by static flags, so the packed family has
# exactly three entry points (histograms / positions / fused reorder).
# ---------------------------------------------------------------------------

def _packed_ids(x, seg_ref, *, spec, m: int):
    """The combined bucket id strip of one tile, computed in-register:
    ``spec.emit_in_kernel`` when label-fused, plus the segment high part."""
    ids = spec.emit_in_kernel(x) if spec is not None else x
    if seg_ref is not None:
        ids = ids + seg_ref[0, :] * m
    return ids


def _packed_hist_kernel(*refs, spec, m: int, has_seg: bool, layout):
    if has_seg:
        x_ref, seg_ref, hist_ref = refs
    else:
        (x_ref, hist_ref), seg_ref = refs, None
    ids = _packed_ids(x_ref[0, :], seg_ref, spec=spec, m=m)
    hist_ref[0, :] = packed_counts(ids, layout)


def packed_tile_histograms_pallas(
    tiled: Array,
    num_buckets: int,
    *,
    spec=None,
    seg_tiled: Optional[Array] = None,
    num_segments: int = 1,
    bits: Optional[int] = None,
    subtile: Optional[int] = None,
    interpret: bool = True,
) -> Array:
    """Packed prescan: (L, T) ids (or keys when ``spec`` fuses labels)
    [+ (L, T) segment ids] -> (L, s*m) int32 histograms. Contract of
    :func:`tile_histograms_pallas` / its seg/spec variants, one entry."""
    n_tiles, t = tiled.shape
    m = spec.num_buckets if spec is not None else num_buckets
    m_eff = m * num_segments
    layout = packed_layout(t, m_eff, **_layout_kw(bits, subtile))
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    has_seg = seg_tiled is not None
    return pl.pallas_call(
        functools.partial(
            _packed_hist_kernel, spec=spec, m=m, has_seg=has_seg, layout=layout
        ),
        grid=(n_tiles,),
        in_specs=[row] * (2 if has_seg else 1),
        out_specs=pl.BlockSpec((1, m_eff), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_eff), jnp.int32),
        interpret=interpret,
    )(*((tiled, seg_tiled) if has_seg else (tiled,)))


def _packed_positions_kernel(*refs, spec, m: int, has_seg: bool, layout,
                             oblivious: bool):
    if has_seg:
        x_ref, seg_ref, g_ref, pos_ref = refs
    else:
        (x_ref, g_ref, pos_ref), seg_ref = refs, None
    ids = _packed_ids(x_ref[0, :], seg_ref, spec=spec, m=m)
    pos_ref[0, :] = packed_positions_body(
        ids, g_ref[0, :], layout, oblivious=oblivious
    )


def packed_tile_positions_pallas(
    tiled: Array,
    g: Array,
    num_buckets: int,
    *,
    spec=None,
    seg_tiled: Optional[Array] = None,
    num_segments: int = 1,
    bits: Optional[int] = None,
    subtile: Optional[int] = None,
    oblivious: bool = True,
    interpret: bool = True,
) -> Array:
    """Packed DMS postscan: (L, T) ids/keys + (L, s*m) bases -> (L, T)
    destinations (paper eq. (2)); two-level packed rank, no one-hot.
    ``oblivious`` (default) traces the gather-free rank-plane body that
    lowers under Mosaic; ``oblivious=False`` keeps the gather form."""
    n_tiles, t = tiled.shape
    m = spec.num_buckets if spec is not None else num_buckets
    m_eff = m * num_segments
    layout = packed_layout(t, m_eff, rank16=oblivious, **_layout_kw(bits, subtile))
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    grow = pl.BlockSpec((1, m_eff), lambda i: (i, 0))
    has_seg = seg_tiled is not None
    in_specs = [row, row, grow] if has_seg else [row, grow]
    args = (tiled, seg_tiled, g) if has_seg else (tiled, g)
    return pl.pallas_call(
        functools.partial(
            _packed_positions_kernel, spec=spec, m=m, has_seg=has_seg,
            layout=layout, oblivious=oblivious,
        ),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(*args)


def _packed_fused_kernel(
    *refs, spec, m: int, has_seg: bool, has_keys: bool, has_values: bool,
    layout, oblivious: bool,
):
    refs = list(refs)
    x_ref = refs.pop(0)
    seg_ref = refs.pop(0) if has_seg else None
    g_ref = refs.pop(0)
    keys_ref = refs.pop(0) if has_keys else x_ref
    vals_ref = refs.pop(0) if has_values else None
    if has_values:
        keys_out_ref, vals_out_ref, pos_out_ref, perm_out_ref = refs
    else:
        (keys_out_ref, pos_out_ref, perm_out_ref), vals_out_ref = refs, None

    ids = _packed_ids(x_ref[0, :], seg_ref, spec=spec, m=m)
    keys_r, vals_r, pos_r, gpos = packed_postscan_body(
        ids, g_ref[0, :], keys_ref[0, :],
        vals_ref[0, :] if has_values else None, layout, oblivious=oblivious,
    )
    keys_out_ref[0, :] = keys_r
    pos_out_ref[0, :] = pos_r
    perm_out_ref[0, :] = gpos                               # element-ordered perm
    if has_values:
        vals_out_ref[0, :] = vals_r


def packed_fused_postscan_reorder_pallas(
    tiled: Array,
    g: Array,
    keys_tiled: Optional[Array] = None,
    values_tiled: Optional[Array] = None,
    *,
    spec=None,
    num_buckets: Optional[int] = None,
    seg_tiled: Optional[Array] = None,
    num_segments: int = 1,
    bits: Optional[int] = None,
    subtile: Optional[int] = None,
    oblivious: bool = True,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Packed WMS/BMS postscan+reorder: the output contract of
    :func:`fused_postscan_reorder_pallas` (and its seg/spec variants) from
    ONE two-level packed-rank evaluation per tile.

    ``tiled`` is the id strip (with ``keys_tiled`` alongside) or, when
    ``spec`` is given, the key strip itself (labels in-register; no separate
    keys input). ``oblivious`` (default) traces the gather-free select/
    permutation-matmul body (DESIGN.md §15); ``oblivious=False`` keeps the
    gather/scatter form."""
    n_tiles, t = tiled.shape
    m = spec.num_buckets if spec is not None else num_buckets
    m_eff = m * num_segments
    layout = packed_layout(t, m_eff, rank16=oblivious, **_layout_kw(bits, subtile))
    has_seg = seg_tiled is not None
    has_keys = keys_tiled is not None
    has_values = values_tiled is not None
    key_src = keys_tiled if has_keys else tiled
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    grow = pl.BlockSpec((1, m_eff), lambda i: (i, 0))
    in_specs = [row] + ([row] if has_seg else []) + [grow] + (
        [row] if has_keys else []) + ([row] if has_values else [])
    args = ((tiled,) + ((seg_tiled,) if has_seg else ()) + (g,)
            + ((keys_tiled,) if has_keys else ())
            + ((values_tiled,) if has_values else ()))
    out_specs = [row] * (4 if has_values else 3)
    out_shape = [jax.ShapeDtypeStruct((n_tiles, t), key_src.dtype)]
    if has_values:
        out_shape.append(jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype))
    out_shape += [
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
    ]
    out = pl.pallas_call(
        functools.partial(
            _packed_fused_kernel, spec=spec, m=m, has_seg=has_seg,
            has_keys=has_keys, has_values=has_values, layout=layout,
            oblivious=oblivious,
        ),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if has_values:
        keys_r, vals_r, pos_r, perm = out
        return keys_r, vals_r, pos_r, perm
    keys_r, pos_r, perm = out
    return keys_r, None, pos_r, perm


# ---------------------------------------------------------------------------
# FUSED TWO-DIGIT kernels (DESIGN.md §13): one grid program runs TWO radix
# digit passes per VMEM residency — digit-d solve, in-VMEM reorder, digit-
# (d+1) solve on the reordered tile — and emits the combined 2r-bit pair
# histogram, so the caller scatters through HBM once per digit PAIR. The
# pair digit is a static BitfieldSpec (shift, 2r) with ``split`` marking the
# low-digit width; ``family`` selects the m-wide stage-solve family (dense
# one-hot or packed subword counters). Inherently label-fused: the kernels
# take KEY strips only. Like the packed family, three generic entry points
# cover {flat | segmented} × {keys-only | key-value}.
# ---------------------------------------------------------------------------

def _fused2_hist_kernel(*refs, shift: int, bits: int, num_segments: int,
                        has_seg: bool, oblivious: bool):
    if has_seg:
        keys_ref, seg_ref, hist_ref = refs
    else:
        (keys_ref, hist_ref), seg_ref = refs, None
    hist_ref[0, :] = fused2_counts_body(
        keys_ref[0, :], shift, bits,
        seg=seg_ref[0, :] if has_seg else None, num_segments=num_segments,
        oblivious=oblivious,
    )


def fused2_tile_histograms_pallas(
    keys_tiled: Array,
    spec,
    *,
    seg_tiled: Optional[Array] = None,
    num_segments: int = 1,
    oblivious: bool = True,
    interpret: bool = True,
) -> Array:
    """Fused2 prescan: (L, T) keys [+ (L, T) segment ids] -> (L, s·m²)
    combined pair histograms. ``oblivious`` (default) contracts two
    half-width one-hots on the MXU (Mosaic-lowerable); ``oblivious=False``
    keeps the O(T) in-kernel scatter-add. The m²-wide one-hot never exists
    on either path."""
    n_tiles, t = keys_tiled.shape
    m_eff = spec.num_buckets * num_segments
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    has_seg = seg_tiled is not None
    return pl.pallas_call(
        functools.partial(
            _fused2_hist_kernel, shift=spec.shift, bits=spec.bits,
            num_segments=num_segments, has_seg=has_seg, oblivious=oblivious,
        ),
        grid=(n_tiles,),
        in_specs=[row] * (2 if has_seg else 1),
        out_specs=pl.BlockSpec((1, m_eff), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_eff), jnp.int32),
        interpret=interpret,
    )(*((keys_tiled, seg_tiled) if has_seg else (keys_tiled,)))


def _fused2_positions_kernel(*refs, shift: int, split: int, bits: int,
                             num_segments: int, family: str,
                             sub_bits: Optional[int], has_seg: bool,
                             oblivious: bool):
    if has_seg:
        keys_ref, seg_ref, g_ref, pos_ref = refs
    else:
        (keys_ref, g_ref, pos_ref), seg_ref = refs, None
    pos_ref[0, :] = fused2_positions_body(
        keys_ref[0, :], g_ref[0, :], shift, split, bits,
        seg=seg_ref[0, :] if has_seg else None, num_segments=num_segments,
        family=family, sub_bits=sub_bits, oblivious=oblivious,
    )


def fused2_tile_positions_pallas(
    keys_tiled: Array,
    g: Array,
    spec,
    split: int,
    *,
    seg_tiled: Optional[Array] = None,
    num_segments: int = 1,
    family: str = "onehot",
    sub_bits: Optional[int] = None,
    oblivious: bool = True,
    interpret: bool = True,
) -> Array:
    """Fused2 DMS postscan: (L, T) keys + (L, s·m²) pair bases -> (L, T)
    element-ordered global pair destinations (paper eq. (2) over the
    combined digit)."""
    n_tiles, t = keys_tiled.shape
    m_eff = spec.num_buckets * num_segments
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    grow = pl.BlockSpec((1, m_eff), lambda i: (i, 0))
    has_seg = seg_tiled is not None
    in_specs = [row, row, grow] if has_seg else [row, grow]
    args = (keys_tiled, seg_tiled, g) if has_seg else (keys_tiled, g)
    return pl.pallas_call(
        functools.partial(
            _fused2_positions_kernel, shift=spec.shift, split=split,
            bits=spec.bits, num_segments=num_segments, family=family,
            sub_bits=sub_bits, has_seg=has_seg, oblivious=oblivious,
        ),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(*args)


def _fused2_fused_kernel(*refs, shift: int, split: int, bits: int,
                         num_segments: int, family: str,
                         sub_bits: Optional[int], has_seg: bool,
                         has_values: bool, oblivious: bool):
    refs = list(refs)
    keys_ref = refs.pop(0)
    seg_ref = refs.pop(0) if has_seg else None
    g_ref = refs.pop(0)
    vals_ref = refs.pop(0) if has_values else None
    if has_values:
        keys_out_ref, vals_out_ref, pos_out_ref, perm_out_ref = refs
    else:
        (keys_out_ref, pos_out_ref, perm_out_ref), vals_out_ref = refs, None

    keys_r, vals_r, pos_r, gpos = fused2_postscan_body(
        keys_ref[0, :], g_ref[0, :],
        vals_ref[0, :] if has_values else None, shift, split, bits,
        seg=seg_ref[0, :] if has_seg else None, num_segments=num_segments,
        family=family, sub_bits=sub_bits, oblivious=oblivious,
    )
    keys_out_ref[0, :] = keys_r
    pos_out_ref[0, :] = pos_r
    perm_out_ref[0, :] = gpos                               # element-ordered perm
    if has_values:
        vals_out_ref[0, :] = vals_r


def fused2_fused_postscan_reorder_pallas(
    keys_tiled: Array,
    g: Array,
    values_tiled: Optional[Array] = None,
    *,
    spec,
    split: int,
    seg_tiled: Optional[Array] = None,
    num_segments: int = 1,
    family: str = "onehot",
    sub_bits: Optional[int] = None,
    oblivious: bool = True,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """THE fused two-digit postscan+reorder: output contract of
    :func:`fused_postscan_reorder_pallas` over the combined pair digit —
    both digit solves and the intermediate reorder stay in VMEM; the
    caller's single scatter per PAIR is the only HBM round trip.
    ``oblivious`` (default) traces the gather-free stage-permutation body
    of DESIGN.md §15; ``oblivious=False`` keeps the gather/scatter form."""
    n_tiles, t = keys_tiled.shape
    m_eff = spec.num_buckets * num_segments
    has_seg = seg_tiled is not None
    has_values = values_tiled is not None
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    grow = pl.BlockSpec((1, m_eff), lambda i: (i, 0))
    in_specs = ([row] + ([row] if has_seg else []) + [grow]
                + ([row] if has_values else []))
    args = ((keys_tiled,) + ((seg_tiled,) if has_seg else ()) + (g,)
            + ((values_tiled,) if has_values else ()))
    out_specs = [row] * (4 if has_values else 3)
    out_shape = [jax.ShapeDtypeStruct((n_tiles, t), keys_tiled.dtype)]
    if has_values:
        out_shape.append(jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype))
    out_shape += [
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
    ]
    out = pl.pallas_call(
        functools.partial(
            _fused2_fused_kernel, shift=spec.shift, split=split,
            bits=spec.bits, num_segments=num_segments, family=family,
            sub_bits=sub_bits, has_seg=has_seg, has_values=has_values,
            oblivious=oblivious,
        ),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if has_values:
        keys_r, vals_r, pos_r, perm = out
        return keys_r, vals_r, pos_r, perm
    keys_r, pos_r, perm = out
    return keys_r, None, pos_r, perm


def _layout_kw(bits: Optional[int], subtile: Optional[int]) -> dict:
    kw = {}
    if bits is not None:
        kw["bits"] = bits
    if subtile is not None:
        kw["subtile"] = subtile
    return kw


# ---------------------------------------------------------------------------
# Kernel 4: standalone tile reorder — unfused baseline (tests + benchmarks)
# ---------------------------------------------------------------------------

def _reorder_kernel(ids_ref, keys_ref, vals_ref, keys_out_ref, vals_out_ref, dest_ref, *, m_pad: int):
    ids = ids_ref[0, :]
    t = ids.shape[0]
    one_hot = _one_hot(ids, m_pad)                          # (T, m)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)            # (T,)
    hist = incl[t - 1, :]                                   # (m,)
    starts = exclusive_starts_mxu(hist)
    base = jax.lax.dot(one_hot, starts[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    dest = (base + local).astype(jnp.int32)                 # within-tile destination
    dest_ref[0, :] = dest

    perm = permutation_matrix(dest)
    keys_out_ref[0, :] = permute_matmul_32(perm, keys_ref[0, :])
    vals_out_ref[0, :] = permute_matmul_32(perm, vals_ref[0, :])


def tile_reorder_pallas(
    ids_tiled: Array,
    keys_tiled: Array,
    values_tiled: Array,
    num_buckets: int,
    *,
    interpret: bool = True,
):
    """Stable within-tile bucket-major reorder of (keys, values) + dest map."""
    n_tiles, t = ids_tiled.shape
    m_pad = _pad_lanes(num_buckets)
    keys_r, vals_r, dest = pl.pallas_call(
        functools.partial(_reorder_kernel, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, t), keys_tiled.dtype),
            jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype),
            jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        ],
        interpret=interpret,
    )(ids_tiled, keys_tiled, values_tiled)
    return keys_r, vals_r, dest
