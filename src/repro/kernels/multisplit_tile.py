"""Pallas TPU kernels for the multisplit direct solve (paper §4.5, §5.5).

One grid program processes one tile (the paper's subproblem): a VMEM-resident
strip of bucket ids. The GPU ballot/popc machinery is replaced by a one-hot
matrix in VMEM reduced/scanned with MXU-friendly dense ops (DESIGN.md §2):

* histogram  = column-sum of the one-hot matrix H̄      (paper Alg. 2)
* local rank = exclusive column-cumsum of H̄, read out
               at each element's own bucket             (paper Alg. 3)
* cumsum is computed as `tril @ H̄` — a lower-triangular ones matmul that
  maps onto the MXU systolic array instead of a sequential scan.
* reorder applies the within-tile permutation as TWO half-word one-hot
  matmuls (keys split into 16-bit halves so fp32 accumulation is exact),
  again MXU work instead of a serialized scatter (paper §4.7 reorder).

All kernels use explicit BlockSpecs with VMEM tiling; the bucket axis is
padded to a multiple of 128 lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _pad_lanes(m: int) -> int:
    return max(128, ((m + 127) // 128) * 128)


def _one_hot(ids: Array, m_pad: int) -> Array:
    """(T,) int32 -> (T, m_pad) f32 one-hot via broadcasted iota (no gather)."""
    t = ids.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, m_pad), 1)
    return (cols == ids[:, None]).astype(jnp.float32)


def _cumsum_mxu(x: Array) -> Array:
    """Inclusive column cumsum as a lower-triangular matmul (MXU-native)."""
    t = x.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    tril = (rows >= cols).astype(jnp.float32)
    return jax.lax.dot(tril, x, precision=jax.lax.Precision.HIGHEST)


# ---------------------------------------------------------------------------
# Kernel 1: per-tile histograms (the prescan direct solve)
# ---------------------------------------------------------------------------

def _histogram_kernel(ids_ref, hist_ref, *, m_pad: int):
    ids = ids_ref[0, :]
    one_hot = _one_hot(ids, m_pad)
    hist_ref[0, :] = one_hot.sum(axis=0).astype(jnp.int32)


def tile_histograms_pallas(ids_tiled: Array, num_buckets: int, *, interpret: bool = True) -> Array:
    """(L, T) int32 ids -> (L, m) int32 histograms."""
    n_tiles, t = ids_tiled.shape
    m_pad = _pad_lanes(num_buckets)
    out = pl.pallas_call(
        functools.partial(_histogram_kernel, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, m_pad), jnp.int32),
        interpret=interpret,
    )(ids_tiled)
    return out[:, :num_buckets]


# ---------------------------------------------------------------------------
# Kernel 2: per-tile final positions (the postscan direct solve)
# ---------------------------------------------------------------------------

def _positions_kernel(ids_ref, g_ref, pos_ref, *, m_pad: int):
    ids = ids_ref[0, :]
    g = g_ref[0, :].astype(jnp.float32)
    one_hot = _one_hot(ids, m_pad)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)          # rank within bucket
    base = jax.lax.dot(one_hot, g[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    pos_ref[0, :] = (base + local).astype(jnp.int32)


def tile_positions_pallas(
    ids_tiled: Array, g: Array, num_buckets: int, *, interpret: bool = True
) -> Array:
    """(L, T) ids + (L, m) bases -> (L, T) destinations (paper eq. (2))."""
    n_tiles, t = ids_tiled.shape
    m_pad = _pad_lanes(num_buckets)
    g_pad = jnp.zeros((n_tiles, m_pad), g.dtype).at[:, :num_buckets].set(g)
    return pl.pallas_call(
        functools.partial(_positions_kernel, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        interpret=interpret,
    )(ids_tiled, g_pad)


# ---------------------------------------------------------------------------
# Kernel 3: fused tile reorder (WMS/BMS §4.7): local multisplit of the tile
# ---------------------------------------------------------------------------

def _reorder_kernel(ids_ref, keys_ref, vals_ref, keys_out_ref, vals_out_ref, dest_ref, *, m_pad: int):
    ids = ids_ref[0, :]
    t = ids.shape[0]
    one_hot = _one_hot(ids, m_pad)                          # (T, m)
    incl = _cumsum_mxu(one_hot)
    local = ((incl - 1.0) * one_hot).sum(axis=1)            # (T,)
    hist = incl[t - 1, :]                                   # (m,)
    # exclusive scan of the tile histogram: starts[b] = sum_{b'<b} hist[b']
    cols = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 0)
    strict_tril = (rows > cols).astype(jnp.float32)
    starts = jax.lax.dot(strict_tril, hist[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    base = jax.lax.dot(one_hot, starts[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    dest = (base + local).astype(jnp.int32)                 # within-tile destination
    dest_ref[0, :] = dest

    # Apply the permutation as a one-hot matmul; split 32-bit words into
    # 16-bit halves so fp32 accumulation is exact.
    rows_t = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    perm = (rows_t == dest[None, :]).astype(jnp.float32)    # perm[j, i] = (dest_i == j)

    def permute32(x):
        xi = x.astype(jnp.uint32)
        halves = jnp.stack(
            [(xi & jnp.uint32(0xFFFF)).astype(jnp.float32),
             (xi >> jnp.uint32(16)).astype(jnp.float32)], axis=1
        )                                                   # (T, 2)
        moved = jax.lax.dot(perm, halves, precision=jax.lax.Precision.HIGHEST)
        lo = moved[:, 0].astype(jnp.uint32)
        hi = moved[:, 1].astype(jnp.uint32)
        return (lo | (hi << jnp.uint32(16))).astype(x.dtype)

    keys_out_ref[0, :] = permute32(keys_ref[0, :])
    vals_out_ref[0, :] = permute32(vals_ref[0, :])


def tile_reorder_pallas(
    ids_tiled: Array,
    keys_tiled: Array,
    values_tiled: Array,
    num_buckets: int,
    *,
    interpret: bool = True,
):
    """Stable within-tile bucket-major reorder of (keys, values) + dest map."""
    n_tiles, t = ids_tiled.shape
    m_pad = _pad_lanes(num_buckets)
    keys_r, vals_r, dest = pl.pallas_call(
        functools.partial(_reorder_kernel, m_pad=m_pad),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, t), keys_tiled.dtype),
            jax.ShapeDtypeStruct((n_tiles, t), values_tiled.dtype),
            jax.ShapeDtypeStruct((n_tiles, t), jnp.int32),
        ],
        interpret=interpret,
    )(ids_tiled, keys_tiled, values_tiled)
    return keys_r, vals_r, dest
