"""Jit'd public wrappers around the Pallas kernels.

``interpret=True`` executes kernel bodies in Python on CPU;
``interpret=False`` compiles for TPU via Mosaic. Since the oblivious-body
PR (DESIGN.md §15) every kernel body is gather/scatter-free by default, so
the compiled path is the NORMAL path on TPU hardware: callers resolve the
flag per backend with :func:`resolve_interpret` (compiled when a TPU is
attached, interpreted otherwise, ``REPRO_INTERPRET`` overriding both). The
wrappers are the only entry points the rest of the framework uses.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.identifiers import EvenSpec
from repro.kernels import multisplit_tile as _mst
from repro.kernels import radix_pass as _radix

Array = jnp.ndarray


@functools.lru_cache(maxsize=1)
def _tpu_available() -> bool:
    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except RuntimeError:                    # no backend at all
        return False


# Unrecognized REPRO_INTERPRET values already warned about (one warning per
# distinct value per process — a typo'd env var must not spam every launch).
_WARNED_INTERPRET: set = set()


def resolve_interpret(compiled: bool) -> bool:
    """The per-backend ``interpret`` flag (DESIGN.md §15).

    ``REPRO_INTERPRET=1`` forces interpret mode everywhere (the debug
    escape hatch); ``REPRO_INTERPRET=0`` forces compiled lowering (CI for
    the Mosaic path on TPU runners). Unset, a ``compiled``-capable backend
    lowers compiled exactly when a TPU is attached — this container has
    none, so the default stays bitwise-identical interpret execution.
    Unrecognized values are treated as unset, with a one-time warning —
    a typo'd ``REPRO_INTERPRET=ture`` silently compiling (or not) is
    exactly the confusion the variable exists to remove."""
    env = os.environ.get("REPRO_INTERPRET", "").strip().lower()
    if env in ("1", "true", "yes"):
        return True
    if env in ("0", "false", "no"):
        return False
    if env and env not in _WARNED_INTERPRET:
        _WARNED_INTERPRET.add(env)
        import warnings

        warnings.warn(
            f"unrecognized REPRO_INTERPRET value {env!r}; accepted values are "
            f"1/true/yes (force interpret), 0/false/no (force compiled), or "
            f"unset (auto-detect: compiled when a TPU is attached) — "
            f"treating as unset",
            RuntimeWarning, stacklevel=2,
        )
    return not (compiled and _tpu_available())


@functools.partial(jax.jit, static_argnames=("num_buckets", "interpret"))
def tile_histograms(ids_tiled: Array, num_buckets: int, interpret: bool = True) -> Array:
    return _mst.tile_histograms_pallas(ids_tiled, num_buckets, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_buckets", "interpret"))
def tile_positions(ids_tiled: Array, g: Array, num_buckets: int, interpret: bool = True) -> Array:
    return _mst.tile_positions_pallas(ids_tiled, g, num_buckets, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_buckets", "interpret"))
def tile_reorder(
    ids_tiled: Array,
    keys_tiled: Array,
    values_tiled: Array,
    num_buckets: int,
    interpret: bool = True,
) -> Tuple[Array, Array, Array]:
    return _mst.tile_reorder_pallas(
        ids_tiled, keys_tiled, values_tiled, num_buckets, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("num_buckets", "interpret"))
def fused_postscan_reorder(
    ids_tiled: Array,
    g: Array,
    keys_tiled: Array,
    values_tiled: Optional[Array],
    num_buckets: int,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """THE fused WMS/BMS postscan entry point (see multisplit_tile)."""
    return _mst.fused_postscan_reorder_pallas(
        ids_tiled, g, keys_tiled, values_tiled, num_buckets, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("shift", "bits", "interpret"))
def radix_fused_postscan_reorder(
    keys_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    shift: int,
    bits: int,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """THE fused radix postscan entry point: digits never leave the kernel."""
    return _radix.radix_fused_postscan_reorder_pallas(
        keys_tiled, g, values_tiled, shift, bits, interpret=interpret
    )


# -- fused-label entry points (DESIGN.md §11): bucket ids computed in-kernel
# from a hashable BucketSpec. ``spec`` is a STATIC jit argument — equal spec
# instances (value-hashable dataclasses) share one trace/compilation, which
# is what kills the per-identifier-instance retrace of the closure era.

@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def spec_tile_histograms(keys_tiled: Array, spec, interpret: bool = True) -> Array:
    return _mst.spec_tile_histograms_pallas(keys_tiled, spec, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def spec_tile_positions(
    keys_tiled: Array, g: Array, spec, interpret: bool = True
) -> Array:
    return _mst.spec_tile_positions_pallas(keys_tiled, g, spec, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def spec_fused_postscan_reorder(
    keys_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    spec,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """THE fused-label WMS/BMS postscan entry point (see multisplit_tile)."""
    return _mst.spec_fused_postscan_reorder_pallas(
        keys_tiled, g, values_tiled, spec, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("spec", "num_segments", "interpret"))
def seg_spec_tile_histograms(
    keys_tiled: Array, seg_tiled: Array, spec, num_segments: int,
    interpret: bool = True,
) -> Array:
    return _mst.seg_spec_tile_histograms_pallas(
        keys_tiled, seg_tiled, spec, num_segments, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("spec", "num_segments", "interpret"))
def seg_spec_tile_positions(
    keys_tiled: Array, seg_tiled: Array, g: Array, spec, num_segments: int,
    interpret: bool = True,
) -> Array:
    return _mst.seg_spec_tile_positions_pallas(
        keys_tiled, seg_tiled, g, spec, num_segments, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("spec", "num_segments", "interpret"))
def seg_spec_fused_postscan_reorder(
    keys_tiled: Array,
    seg_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    spec,
    num_segments: int,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """THE segmented fused-label postscan entry point (labels AND segment id
    combined in-register; see multisplit_tile)."""
    return _mst.seg_spec_fused_postscan_reorder_pallas(
        keys_tiled, seg_tiled, g, values_tiled, spec, num_segments,
        interpret=interpret,
    )


# -- packed-counter family entry points (DESIGN.md §12): ONE wrapper per
# pipeline stage covers {ids strip | fused spec labels} × {flat | segmented}.
# ``spec``/counts/segments/layout knobs are static; equal hashable specs and
# layouts share one trace, exactly like the dense spec wrappers above.

@functools.partial(jax.jit, static_argnames=(
    "num_buckets", "spec", "num_segments", "bits", "subtile", "interpret"))
def packed_tile_histograms(
    tiled: Array,
    seg_tiled: Optional[Array] = None,
    *,
    num_buckets: Optional[int] = None,
    spec=None,
    num_segments: int = 1,
    bits: Optional[int] = None,
    subtile: Optional[int] = None,
    interpret: bool = True,
) -> Array:
    """THE packed prescan entry point (see multisplit_tile)."""
    return _mst.packed_tile_histograms_pallas(
        tiled, num_buckets if spec is None else spec.num_buckets, spec=spec,
        seg_tiled=seg_tiled, num_segments=num_segments, bits=bits,
        subtile=subtile, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=(
    "num_buckets", "spec", "num_segments", "bits", "subtile", "oblivious",
    "interpret"))
def packed_tile_positions(
    tiled: Array,
    g: Array,
    seg_tiled: Optional[Array] = None,
    *,
    num_buckets: Optional[int] = None,
    spec=None,
    num_segments: int = 1,
    bits: Optional[int] = None,
    subtile: Optional[int] = None,
    oblivious: bool = True,
    interpret: bool = True,
) -> Array:
    """THE packed DMS postscan entry point (see multisplit_tile)."""
    return _mst.packed_tile_positions_pallas(
        tiled, g, num_buckets if spec is None else spec.num_buckets,
        spec=spec, seg_tiled=seg_tiled, num_segments=num_segments, bits=bits,
        subtile=subtile, oblivious=oblivious, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=(
    "num_buckets", "spec", "num_segments", "bits", "subtile", "oblivious",
    "interpret"))
def packed_fused_postscan_reorder(
    tiled: Array,
    g: Array,
    keys_tiled: Optional[Array] = None,
    values_tiled: Optional[Array] = None,
    seg_tiled: Optional[Array] = None,
    *,
    num_buckets: Optional[int] = None,
    spec=None,
    num_segments: int = 1,
    bits: Optional[int] = None,
    subtile: Optional[int] = None,
    oblivious: bool = True,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """THE packed WMS/BMS postscan+reorder entry point (see multisplit_tile)."""
    return _mst.packed_fused_postscan_reorder_pallas(
        tiled, g, keys_tiled, values_tiled, spec=spec,
        num_buckets=num_buckets, seg_tiled=seg_tiled,
        num_segments=num_segments, bits=bits, subtile=subtile,
        oblivious=oblivious, interpret=interpret,
    )


# -- fused two-digit entry points (DESIGN.md §13): TWO radix digit passes per
# VMEM residency. ``spec`` is the combined 2r-bit pair BitfieldSpec and
# ``split`` the low-digit width — both static, like every pair-schedule knob,
# so all tiles of all pair passes with equal (spec, split, config) share one
# trace. ONE wrapper per stage covers {flat | segmented} × {keys | key-value}.

@functools.partial(jax.jit, static_argnames=(
    "spec", "num_segments", "oblivious", "interpret"))
def fused2_tile_histograms(
    keys_tiled: Array,
    seg_tiled: Optional[Array] = None,
    *,
    spec,
    num_segments: int = 1,
    oblivious: bool = True,
    interpret: bool = True,
) -> Array:
    """THE fused2 prescan entry point (see multisplit_tile)."""
    return _mst.fused2_tile_histograms_pallas(
        keys_tiled, spec, seg_tiled=seg_tiled, num_segments=num_segments,
        oblivious=oblivious, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=(
    "spec", "split", "num_segments", "family", "sub_bits", "oblivious",
    "interpret"))
def fused2_tile_positions(
    keys_tiled: Array,
    g: Array,
    seg_tiled: Optional[Array] = None,
    *,
    spec,
    split: int,
    num_segments: int = 1,
    family: str = "onehot",
    sub_bits: Optional[int] = None,
    oblivious: bool = True,
    interpret: bool = True,
) -> Array:
    """THE fused2 DMS postscan entry point (see multisplit_tile)."""
    return _mst.fused2_tile_positions_pallas(
        keys_tiled, g, spec, split, seg_tiled=seg_tiled,
        num_segments=num_segments, family=family, sub_bits=sub_bits,
        oblivious=oblivious, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=(
    "spec", "split", "num_segments", "family", "sub_bits", "oblivious",
    "interpret"))
def fused2_fused_postscan_reorder(
    keys_tiled: Array,
    g: Array,
    values_tiled: Optional[Array] = None,
    seg_tiled: Optional[Array] = None,
    *,
    spec,
    split: int,
    num_segments: int = 1,
    family: str = "onehot",
    sub_bits: Optional[int] = None,
    oblivious: bool = True,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """THE fused two-digit postscan+reorder entry point (see multisplit_tile)."""
    return _mst.fused2_fused_postscan_reorder_pallas(
        keys_tiled, g, values_tiled, spec=spec, split=split,
        seg_tiled=seg_tiled, num_segments=num_segments, family=family,
        sub_bits=sub_bits, oblivious=oblivious, interpret=interpret,
    )


# -- segmented entry points (DESIGN.md §9): segment id rides in-kernel ------

@functools.partial(jax.jit, static_argnames=("num_buckets", "num_segments", "interpret"))
def seg_tile_histograms(
    ids_tiled: Array, seg_tiled: Array, num_buckets: int, num_segments: int,
    interpret: bool = True,
) -> Array:
    return _mst.seg_tile_histograms_pallas(
        ids_tiled, seg_tiled, num_buckets, num_segments, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("num_buckets", "num_segments", "interpret"))
def seg_tile_positions(
    ids_tiled: Array, seg_tiled: Array, g: Array, num_buckets: int, num_segments: int,
    interpret: bool = True,
) -> Array:
    return _mst.seg_tile_positions_pallas(
        ids_tiled, seg_tiled, g, num_buckets, num_segments, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("num_buckets", "num_segments", "interpret"))
def seg_fused_postscan_reorder(
    ids_tiled: Array,
    seg_tiled: Array,
    g: Array,
    keys_tiled: Array,
    values_tiled: Optional[Array],
    num_buckets: int,
    num_segments: int,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """THE segmented WMS/BMS postscan entry point (see multisplit_tile)."""
    return _mst.seg_fused_postscan_reorder_pallas(
        ids_tiled, seg_tiled, g, keys_tiled, values_tiled, num_buckets,
        num_segments, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("shift", "bits", "num_segments", "interpret"))
def seg_radix_tile_histograms(
    keys_tiled: Array, seg_tiled: Array, shift: int, bits: int, num_segments: int,
    interpret: bool = True,
) -> Array:
    return _radix.seg_radix_tile_histograms_pallas(
        keys_tiled, seg_tiled, shift, bits, num_segments, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("shift", "bits", "num_segments", "interpret"))
def seg_radix_tile_positions(
    keys_tiled: Array, seg_tiled: Array, g: Array, shift: int, bits: int,
    num_segments: int, interpret: bool = True,
) -> Array:
    return _radix.seg_radix_tile_positions_pallas(
        keys_tiled, seg_tiled, g, shift, bits, num_segments, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("shift", "bits", "num_segments", "interpret"))
def seg_radix_fused_postscan_reorder(
    keys_tiled: Array,
    seg_tiled: Array,
    g: Array,
    values_tiled: Optional[Array],
    shift: int,
    bits: int,
    num_segments: int,
    interpret: bool = True,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """THE segmented fused radix postscan entry point (digits never leave
    the kernel; the segment id rides with them)."""
    return _radix.seg_radix_fused_postscan_reorder_pallas(
        keys_tiled, seg_tiled, g, values_tiled, shift, bits, num_segments,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("num_buckets", "interpret"))
def device_histogram(ids_tiled: Array, num_buckets: int, interpret: bool = True) -> Array:
    """(L, T) ids -> (m,) device-wide histogram: the generic per-tile
    prescan kernel reduced over tiles (replaces the seed-era revisited-block
    kernel in histogram_tile.py — same result, one kernel family)."""
    return _mst.tile_histograms_pallas(
        ids_tiled, num_buckets, interpret=interpret
    ).sum(axis=0)


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def spec_bucket_ids(keys_tiled: Array, spec, interpret: bool = True) -> Array:
    """(L, T) keys -> (L, T) int32 bucket ids for ANY declarative spec
    (the generic materialized-label entry point)."""
    return _mst.spec_bucket_ids_pallas(keys_tiled, spec, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("lo", "hi", "num_buckets", "interpret"))
def even_bucket_ids(
    keys_tiled: Array, lo: float, hi: float, num_buckets: int, interpret: bool = True
) -> Array:
    """Even-bucket identification via the generic spec-ids kernel (the
    fixed-function even kernel of histogram_tile.py, subsumed)."""
    return _mst.spec_bucket_ids_pallas(
        keys_tiled, EvenSpec(float(lo), float(hi), num_buckets),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("shift", "bits", "interpret"))
def radix_tile_histograms(keys_tiled: Array, shift: int, bits: int, interpret: bool = True) -> Array:
    return _radix.radix_tile_histograms_pallas(keys_tiled, shift, bits, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("shift", "bits", "interpret"))
def radix_tile_positions(
    keys_tiled: Array, g: Array, shift: int, bits: int, interpret: bool = True
) -> Array:
    return _radix.radix_tile_positions_pallas(keys_tiled, g, shift, bits, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal=True, block_q=256, block_k=256, interpret=True):
    from repro.kernels.flash_attention import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=interpret
    )
