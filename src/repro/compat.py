"""Version-guarded shims for jax APIs that moved between releases.

The codebase targets the modern jax mesh API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``jax.shard_map(check_vma=...)``,
``jax.make_mesh(axis_types=...)``); the pinned toolchain ships jax 0.4.37
where those names do not exist yet. Everything version-dependent funnels
through this one module:

* library code imports :func:`get_abstract_mesh` / :func:`shard_map`
  directly, and
* :func:`install` (run on ``import repro``) backfills the missing public
  names onto ``jax`` / ``jax.sharding`` so tests and scripts written
  against the modern API run unchanged on the old runtime.

On a new-enough jax every shim is a straight pass-through.
"""

from __future__ import annotations

import contextlib
import enum
import functools
import inspect

import jax

__all__ = [
    "get_abstract_mesh",
    "set_mesh",
    "shard_map",
    "make_mesh",
    "install",
]


def get_abstract_mesh():
    """The mesh of the current mesh context (abstract on new jax).

    Falls back to the physical mesh recorded by ``with mesh:`` /
    ``pxla.thread_resources`` on jax < 0.5, which behaves identically for
    the two uses we have: reading ``axis_names`` and ``shape`` during
    tracing. Returns an empty mesh outside any context.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None and not isinstance(fn, _AbstractMeshShim):
        return fn()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


class _AbstractMeshShim:
    """Marker-carrying callable installed as jax.sharding.get_abstract_mesh."""

    def __call__(self):
        return get_abstract_mesh()


def set_mesh(mesh):
    """``jax.set_mesh`` when available; else the Mesh's own context manager."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None and fn is not set_mesh:
        return fn(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def shard_map(f, *, mesh, in_specs, out_specs, **kw):
    """``jax.shard_map`` with ``check_vma`` mapped to old ``check_rep``."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None and fn is not shard_map:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    params = inspect.signature(_sm).parameters
    if "check_vma" in kw and "check_vma" not in params:
        kw["check_rep"] = kw.pop("check_vma")
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates ``axis_types`` on old jax (dropped:
    pre-sharding-in-types jax treats every axis as Auto anyway)."""
    base = getattr(jax, "_compat_orig_make_mesh", jax.make_mesh)
    if "axis_types" in inspect.signature(base).parameters:
        return base(axis_shapes, axis_names, axis_types=axis_types, devices=devices)
    return base(axis_shapes, axis_names, devices=devices)


def axis_size(axis_name) -> int:
    """Static size of a mapped mesh axis (``jax.lax.axis_size`` on new jax)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None and fn is not axis_size:
        return fn(axis_name)
    from jax._src import core as _core

    frame = _core.axis_frame(axis_name)
    return frame if isinstance(frame, int) else frame.size


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to a dict (old jax: list[dict])."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def install() -> None:
    """Backfill missing public jax names (idempotent, version-guarded)."""
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = set_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = axis_size
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = _AbstractMeshShim()
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        if not hasattr(jax, "_compat_orig_make_mesh"):
            jax._compat_orig_make_mesh = jax.make_mesh
        jax.make_mesh = make_mesh
    for name, old in [
        ("flatten_with_path", "tree_flatten_with_path"),
        ("map_with_path", "tree_map_with_path"),
        ("leaves_with_path", "tree_leaves_with_path"),
    ]:
        if not hasattr(jax.tree, name) and hasattr(jax.tree_util, old):
            setattr(jax.tree, name, getattr(jax.tree_util, old))
