"""MultisplitPlan: the one execution engine behind every multisplit consumer.

The paper's model (§4.1) is {local prescan} -> {one global scan} ->
{local postscan + scatter}. Historically each consumer (``core.multisplit``,
``core.sort``, ``core.distributed``) re-assembled that pipeline by hand and
the host orchestration re-evaluated the per-tile one-hot/cumsum up to three
times (postscan positions, key reorder, value reorder). The plan layer makes
"one fused VMEM pass per tile" the architecture (DESIGN.md §3):

* :func:`make_plan` resolves ``(n, m, method, key-only/key-value, backend)``
  into a :class:`MultisplitPlan` — a staged pipeline whose postscan stage is
  a SINGLE fused evaluation per tile (kernel or jnp), and whose tile size
  (paper Table 1's subproblem-size knob) comes from a per-shape
  heuristic/autotune cache owned by this module.
* backends: ``reference`` (O(n·m) direct eq. (1) eval), ``vmap`` (tiled jnp,
  fused per-tile closure), ``pallas-interpret`` (Pallas kernels interpreted
  on CPU), ``pallas`` (compiled for TPU).
* radix plans (:func:`make_radix_plan`) fuse digit extraction into the
  kernels: ``radix_sort(use_pallas=True)`` never materializes a label array
  in HBM — exactly the §3.4 RB-sort overhead the paper's multisplit avoids.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.identifiers import BucketIdentifier
from repro.kernels.common import pad_lanes as _pad_lanes

Array = jnp.ndarray

BACKENDS = ("reference", "vmap", "pallas-interpret", "pallas")

# Tile sizes: "warp" tiles vs "block" tiles (paper Table 1 sizing knob —
# larger subproblem => narrower global scan matrix H, heavier local solve).
WMS_TILE = 1024
BMS_TILE = 4096

# VMEM budget for the heuristic (f32 working set of the fused postscan:
# one-hot (T·m̄) + tril/permutation (T·T) + two reorder operands).
_VMEM_BUDGET_BYTES = 8 << 20
_MIN_TILE = 256


class MultisplitResult(NamedTuple):
    keys: Array                    # permuted keys, bucket-major, stable
    values: Optional[Array]        # permuted values (None for key-only)
    bucket_starts: Array           # (m,) start index of each bucket
    bucket_counts: Array           # (m,) histogram
    permutation: Array             # (n,) dest position of input element i


def resolve_backend(
    use_pallas: bool = False, interpret: bool = True, backend: Optional[str] = None
) -> str:
    """Map the legacy ``(use_pallas, interpret)`` knobs onto a backend name."""
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
        return backend
    if not use_pallas:
        return "vmap"
    return "pallas-interpret" if interpret else "pallas"


# ---------------------------------------------------------------------------
# Tile sizing: per-shape heuristic + small autotune cache (paper Table 1)
# ---------------------------------------------------------------------------

_TILE_CACHE: Dict[Tuple[int, int, str, bool, str], int] = {}


def _heuristic_tile(n: int, m: int, method: str, backend: str) -> int:
    base = WMS_TILE if method in ("dms", "wms") else BMS_TILE
    tile = base
    if backend.startswith("pallas"):
        m_pad = _pad_lanes(m)
        # fused postscan working set, f32 words
        cost = lambda t: 4 * (3 * t * m_pad + t * t)
        while tile > _MIN_TILE and cost(tile) > _VMEM_BUDGET_BYTES:
            tile //= 2
    if n < tile:
        # tiny input: one tile, padded to the next power of two (>= 128 lanes)
        tile = max(128, 1 << max(n - 1, 0).bit_length())
    return tile


def resolve_tile(
    n: int, m: int, method: str, key_value: bool, backend: str, requested: Optional[int] = None
) -> int:
    """Tile height for one subproblem; cached per shape, overridable."""
    if requested is not None:
        return requested
    key = (n, m, method, key_value, backend)
    tile = _TILE_CACHE.get(key)
    if tile is None:
        tile = _heuristic_tile(n, m, method, backend)
        _TILE_CACHE[key] = tile
    return tile


def clear_tile_cache() -> None:
    _TILE_CACHE.clear()


def autotune_tile(
    n: int,
    bucket_fn: BucketIdentifier,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    candidates: Tuple[int, ...] = (256, 512, 1024, 2048, 4096),
    trials: int = 3,
    seed: int = 0,
) -> int:
    """Time the candidate tile sizes on synthetic uniform keys and pin the
    winner in the per-shape cache. Returns the chosen tile."""
    import numpy as np

    rng = np.random.RandomState(seed)
    keys = jnp.asarray(rng.randint(0, 2**30, n, dtype=np.uint32))
    values = jnp.arange(n, dtype=jnp.int32) if key_value else None
    best, best_t = None, None
    for tile in candidates:
        if tile > max(n, _MIN_TILE):
            continue
        plan = make_plan(
            n, bucket_fn.num_buckets, method=method, key_value=key_value,
            backend=backend, tile=tile, bucket_fn=bucket_fn,
        )
        run = jax.jit(lambda k, v: plan(k, v).keys) if key_value else jax.jit(
            lambda k: plan(k).keys
        )
        args = (keys, values) if key_value else (keys,)
        jax.block_until_ready(run(*args))                    # compile
        ts = []
        for _ in range(trials):
            t0 = time.perf_counter()
            jax.block_until_ready(run(*args))
            ts.append(time.perf_counter() - t0)
        t = min(ts)
        if best is None or t < best:
            best, best_t = t, tile
    if best_t is not None:
        _TILE_CACHE[(n, bucket_fn.num_buckets, method, key_value, backend)] = best_t
    return best_t if best_t is not None else resolve_tile(
        n, bucket_fn.num_buckets, method, key_value, backend
    )


# ---------------------------------------------------------------------------
# Shared tiling / scan helpers (the ONE global operation lives here)
# ---------------------------------------------------------------------------

def pad_to_tiles(x: Array, tile: int, fill) -> Tuple[Array, int]:
    n = x.shape[0]
    n_pad = (-n) % tile
    if n_pad:
        x = jnp.concatenate([x, jnp.full((n_pad,) + x.shape[1:], fill, x.dtype)])
    return x, n_pad


def global_scan(hist_per_tile: Array) -> Array:
    """Exclusive scan over the row-vectorized (bucket-major) H (paper §4.1).

    ``hist_per_tile`` is (L, m); returns G (L, m): global base of
    (tile l, bucket b).
    """
    h_t = hist_per_tile.T                                  # (m, L) bucket-major
    flat = h_t.reshape(-1)
    g = jnp.concatenate([jnp.zeros((1,), flat.dtype), jnp.cumsum(flat)[:-1]])
    return g.reshape(h_t.shape).T                          # back to (L, m)


def tile_local_offsets(ids: Array, m: int) -> Tuple[Array, Array]:
    """One one-hot/cumsum evaluation over one tile: (stable in-bucket rank,
    tile histogram) — paper Alg. 3 without ballots. Canonical definition;
    ``core.multisplit`` re-exports it."""
    one_hot = (ids[:, None] == jnp.arange(m)[None, :]).astype(jnp.int32)
    incl = jnp.cumsum(one_hot, axis=0)
    local = incl[jnp.arange(ids.shape[0]), ids] - 1
    return local.astype(jnp.int32), incl[-1]


_tile_local_offsets = tile_local_offsets


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultisplitPlan:
    """A resolved multisplit pipeline for one problem shape.

    Frozen and hashable-by-identity: build via :func:`make_plan` /
    :func:`make_radix_plan`, call with concrete arrays. ``radix`` carries the
    (shift, bits) of a fused digit identifier — when set with a pallas
    backend, bucket ids are extracted inside the kernels and never exist as a
    host/HBM array.
    """

    n: int
    num_buckets: int
    method: str                     # dms | wms | bms
    key_value: bool
    backend: str
    tile: int
    radix: Optional[Tuple[int, int]] = None        # (shift, bits)
    bucket_fn: Optional[BucketIdentifier] = None

    # -- introspection -----------------------------------------------------
    def stages(self) -> Tuple[str, ...]:
        """Human/test-readable pipeline description."""
        kernel = self.backend.startswith("pallas")
        fused_id = self.radix is not None and kernel
        pre = ("prescan:radix-fused-kernel" if fused_id
               else "prescan:kernel" if kernel else "prescan:vmap")
        if self.method == "dms":
            post = ("postscan:radix-positions-kernel" if fused_id
                    else "postscan:positions-kernel" if kernel else "postscan:positions-vmap")
        else:
            post = ("postscan:radix-fused-reorder-kernel" if fused_id
                    else "postscan:fused-reorder-kernel" if kernel
                    else "postscan:fused-reorder-vmap")
        if self.backend == "reference":
            return ("direct-solve:reference",)
        return (pre, "scan:global", post, "scatter:bucket-major")

    # -- helpers -----------------------------------------------------------
    def _interpret(self) -> bool:
        return self.backend != "pallas"

    def _ids_fn(self) -> BucketIdentifier:
        if self.bucket_fn is not None:
            return self.bucket_fn
        if self.radix is None:
            raise ValueError("plan has neither bucket_fn nor radix spec")
        shift, bits = self.radix
        mask = (1 << bits) - 1
        return BucketIdentifier(
            lambda u: ((u.astype(jnp.uint32) >> jnp.uint32(shift)) & jnp.uint32(mask)).astype(jnp.int32),
            1 << bits,
            name=f"radix[{shift}:{shift + bits}]",
        )

    # -- stage 1: prescan --------------------------------------------------
    def prescan(self, keys_tiled: Array, ids_tiled: Optional[Array]) -> Array:
        m = self.num_buckets
        if self.backend.startswith("pallas"):
            from repro.kernels import ops as kops

            if self.radix is not None:
                shift, bits = self.radix
                return kops.radix_tile_histograms(
                    keys_tiled, shift, bits, interpret=self._interpret()
                )
            return kops.tile_histograms(ids_tiled, m, interpret=self._interpret())
        return jax.vmap(lambda t: _tile_local_offsets(t, m)[1])(ids_tiled)

    # -- stage 3: fused postscan (+ reorder for wms/bms) -------------------
    def postscan(
        self,
        g: Array,
        keys_tiled: Array,
        ids_tiled: Optional[Array],
        vals_tiled: Optional[Array],
    ) -> Tuple[Array, Optional[Array], Array, Array]:
        """Returns (scatter_src_keys, scatter_src_vals, scatter_pos, perm).

        For wms/bms the sources are bucket-major within each tile and the
        positions permuted to match — ONE one-hot/cumsum evaluation per tile
        (the fused kernel / fused closure is the only postscan entry point).
        ``perm`` is the element-ordered destination map (paper eq. (2)), a
        free byproduct of the same evaluation.
        """
        m = self.num_buckets
        pallas = self.backend.startswith("pallas")
        if self.method == "dms":
            if pallas:
                from repro.kernels import ops as kops

                if self.radix is not None:
                    shift, bits = self.radix
                    pos = kops.radix_tile_positions(
                        keys_tiled, g, shift, bits, interpret=self._interpret()
                    )
                else:
                    pos = kops.tile_positions(ids_tiled, g, m, interpret=self._interpret())
            else:
                def one_tile(ids, g_tile):
                    local, _ = _tile_local_offsets(ids, m)
                    return g_tile[ids] + local

                pos = jax.vmap(one_tile)(ids_tiled, g)
            return keys_tiled, vals_tiled, pos, pos

        if pallas:
            from repro.kernels import ops as kops

            if self.radix is not None:
                shift, bits = self.radix
                return kops.radix_fused_postscan_reorder(
                    keys_tiled, g, vals_tiled, shift, bits, interpret=self._interpret()
                )
            return kops.fused_postscan_reorder(
                ids_tiled, g, keys_tiled, vals_tiled, m, interpret=self._interpret()
            )

        # vmap backend: the SAME fusion as the kernel — local ranks, tile
        # starts, tile destination and global destination all from one
        # one-hot/cumsum evaluation, then one gather-free scatter per array.
        def fused_tile(ids, g_tile, keys_t, vals_t):
            local, hist = _tile_local_offsets(ids, m)
            starts = (jnp.cumsum(hist) - hist).astype(jnp.int32)
            dest = starts[ids] + local
            pos = (g_tile[ids] + local).astype(jnp.int32)
            keys_r = jnp.zeros_like(keys_t).at[dest].set(keys_t)
            pos_r = jnp.zeros_like(pos).at[dest].set(pos)
            if vals_t is None:
                return keys_r, pos_r, pos
            vals_r = jnp.zeros_like(vals_t).at[dest].set(vals_t)
            return keys_r, vals_r, pos_r, pos

        if vals_tiled is None:
            keys_r, pos_r, perm = jax.vmap(lambda i, gt, kt: fused_tile(i, gt, kt, None))(
                ids_tiled, g, keys_tiled
            )
            return keys_r, None, pos_r, perm
        keys_r, vals_r, pos_r, perm = jax.vmap(fused_tile)(ids_tiled, g, keys_tiled, vals_tiled)
        return keys_r, vals_r, pos_r, perm

    # -- full pipeline -----------------------------------------------------
    def __call__(self, keys: Array, values: Optional[Array] = None) -> MultisplitResult:
        if (values is not None) != self.key_value:
            raise ValueError(
                f"plan resolved for key_value={self.key_value} but called with "
                f"values={'present' if values is not None else 'absent'}"
            )
        if keys.shape[0] != self.n:
            raise ValueError(f"plan resolved for n={self.n}, got n={keys.shape[0]}")
        m = self.num_buckets

        if self.backend == "reference":
            return _direct_solve_reference(keys, self._ids_fn(), values)

        if self.backend.startswith("pallas") and keys.dtype.itemsize != 4:
            raise ValueError(
                f"pallas backends require 32-bit keys (got {keys.dtype}); "
                "use backend='vmap' for other widths"
            )

        fused_id = self.radix is not None and self.backend.startswith("pallas")
        n = self.n

        # ---- tiling. Pads ride in bucket m-1 at the very tail, so they land
        # after every real element and are sliced off below. For fused radix
        # plans the pad key is all-ones: its digit is m-1 in EVERY pass.
        if fused_id:
            pad_key = (1 << 32) - 1 if keys.dtype == jnp.uint32 else -1
            keys_p, _ = pad_to_tiles(keys, self.tile, pad_key)
            keys_tiled = keys_p.reshape(-1, self.tile)
            ids_tiled = None
        else:
            ids = self._ids_fn()(keys)
            ids_p, _ = pad_to_tiles(ids, self.tile, m - 1)
            ids_tiled = ids_p.reshape(-1, self.tile)
            keys_p, _ = pad_to_tiles(keys, self.tile, 0)
            keys_tiled = keys_p.reshape(-1, self.tile)
        n_total = keys_tiled.size
        vals_tiled = None
        if values is not None:
            vals_p, _ = pad_to_tiles(values, self.tile, 0)
            vals_tiled = vals_p.reshape(-1, self.tile)

        # ---- the three stages
        hist = self.prescan(keys_tiled, ids_tiled)
        g = global_scan(hist)
        src_keys, src_vals, pos, perm_tiled = self.postscan(g, keys_tiled, ids_tiled, vals_tiled)

        # ---- global scatter (contiguous per-bucket runs for wms/bms)
        scatter_pos = pos.reshape(-1)
        keys_out = (
            jnp.zeros((n_total,), keys.dtype).at[scatter_pos].set(src_keys.reshape(-1))[:n]
        )
        values_out = None
        if values is not None:
            values_out = (
                jnp.zeros((n_total,) + values.shape[1:], values.dtype)
                .at[scatter_pos]
                .set(src_vals.reshape(-1))[:n]
            )

        counts = hist.sum(axis=0).astype(jnp.int32)
        counts = counts.at[m - 1].add(n - n_total)           # drop pad sentinels
        starts = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
        )
        return MultisplitResult(
            keys_out, values_out, starts, counts, perm_tiled.reshape(-1)[:n]
        )


def _direct_solve_reference(
    keys: Array, bucket_fn: BucketIdentifier, values: Optional[Array]
) -> MultisplitResult:
    """O(n·m) direct evaluation of paper eq. (1): the oracle backend."""
    m = bucket_fn.num_buckets
    ids = bucket_fn(keys)
    local, hist = _tile_local_offsets(ids, m)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(hist)[:-1].astype(jnp.int32)]
    )
    perm = starts[ids] + local
    keys_out = jnp.zeros_like(keys).at[perm].set(keys)
    values_out = None
    if values is not None:
        values_out = jnp.zeros_like(values).at[perm].set(values)
    return MultisplitResult(keys_out, values_out, starts, hist.astype(jnp.int32), perm)


def make_plan(
    n: int,
    num_buckets: int,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    tile: Optional[int] = None,
    bucket_fn: Optional[BucketIdentifier] = None,
) -> MultisplitPlan:
    """Resolve (n, m, method, key-value-ness, backend) into a staged plan."""
    if method not in ("dms", "wms", "bms"):
        raise ValueError(f"unknown multisplit method {method!r}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    resolved_tile = resolve_tile(n, num_buckets, method, key_value, backend, tile)
    return MultisplitPlan(
        n=n, num_buckets=num_buckets, method=method, key_value=key_value,
        backend=backend, tile=resolved_tile, bucket_fn=bucket_fn,
    )


def make_radix_plan(
    n: int,
    shift: int,
    bits: int,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    tile: Optional[int] = None,
) -> MultisplitPlan:
    """A plan whose bucket identifier is the radix digit (shift, bits) —
    fused into the kernels on pallas backends (no label array in HBM)."""
    if method not in ("dms", "wms", "bms"):
        raise ValueError(f"unknown multisplit method {method!r}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    m = 1 << bits
    resolved_tile = resolve_tile(n, m, method, key_value, backend, tile)
    return MultisplitPlan(
        n=n, num_buckets=m, method=method, key_value=key_value,
        backend=backend, tile=resolved_tile, radix=(shift, bits),
    )
