"""MultisplitPlan: the one execution engine behind every multisplit consumer.

The paper's model (§4.1) is {local prescan} -> {one global scan} ->
{local postscan + scatter}. Historically each consumer (``core.multisplit``,
``core.sort``, ``core.distributed``) re-assembled that pipeline by hand and
the host orchestration re-evaluated the per-tile one-hot/cumsum up to three
times (postscan positions, key reorder, value reorder). The plan layer makes
"one fused VMEM pass per tile" the architecture (DESIGN.md §3):

* :func:`make_plan` resolves ``(n, m, method, key-only/key-value, backend)``
  into a :class:`MultisplitPlan` — a staged pipeline whose postscan stage is
  a SINGLE fused evaluation per tile (kernel or jnp), and whose tile size
  (paper Table 1's subproblem-size knob) comes from a per-shape
  heuristic/autotune cache owned by this module.
* backends: ``reference`` (O(n·m) direct eq. (1) eval), ``vmap`` (tiled jnp,
  fused per-tile closure), ``pallas-interpret`` (Pallas kernels interpreted
  on CPU), ``pallas`` (compiled for TPU).
* radix plans (:func:`make_radix_plan`) fuse digit extraction into the
  kernels: ``radix_sort(use_pallas=True)`` never materializes a label array
  in HBM — exactly the §3.4 RB-sort overhead the paper's multisplit avoids.

Beyond the paper's single flat problem, a plan natively executes MANY
independent multisplits in one launch (DESIGN.md §9):

* **batched** (``batch=b``): inputs carry a leading ``(b, n)`` axis; every
  row is an independent multisplit. Rows are tiled independently (each tile
  belongs to exactly one row), so ONE kernel grid of ``b x tiles_per_row``
  programs covers the whole batch; only the global scan and the final
  scatter are per-row (a vmap over closed-form jnp, no kernel relaunch).
* **segmented** (``segments=s``): a flat ``(n,)`` input plus a ragged
  ``segment_starts`` (s,) boundary vector; every segment is an independent
  multisplit. The segment id rides THROUGH the one-hot/cumsum pass as the
  high part of a combined bucket id ``seg * m + bucket`` (fused inside the
  kernels on pallas backends), so segments of any raggedness — including
  empty ones — cost one launch total, not one launch per segment.

Both modes return per-row / per-segment ``(b|s, m)`` counts and starts and a
row/segment-LOCAL permutation, bitwise identical to running the same rows or
segments through independent flat plans.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.identifiers import BucketIdentifier
from repro.kernels.common import pad_lanes as _pad_lanes

Array = jnp.ndarray

BACKENDS = ("reference", "vmap", "pallas-interpret", "pallas")

# Tile sizes: "warp" tiles vs "block" tiles (paper Table 1 sizing knob —
# larger subproblem => narrower global scan matrix H, heavier local solve).
WMS_TILE = 1024
BMS_TILE = 4096

# VMEM budget for the heuristic (f32 working set of the fused postscan:
# one-hot (T·m̄) + tril/permutation (T·T) + two reorder operands).
_VMEM_BUDGET_BYTES = 8 << 20
_MIN_TILE = 256


class MultisplitResult(NamedTuple):
    """Flat plans: shapes as commented. Batched plans prepend a ``b`` axis to
    ``keys``/``values``/``permutation`` and return ``(b, m)`` starts/counts.
    Segmented plans keep flat ``(n,)`` data arrays (segments occupy their
    input spans) and return ``(s, m)`` segment-LOCAL starts/counts plus a
    segment-local permutation."""

    keys: Array                    # permuted keys, bucket-major, stable
    values: Optional[Array]        # permuted values (None for key-only)
    bucket_starts: Array           # (m,) start index of each bucket
    bucket_counts: Array           # (m,) histogram
    permutation: Array             # (n,) dest position of input element i


def resolve_backend(
    use_pallas: bool = False, interpret: bool = True, backend: Optional[str] = None
) -> str:
    """Map the legacy ``(use_pallas, interpret)`` knobs onto a backend name."""
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
        return backend
    if not use_pallas:
        return "vmap"
    return "pallas-interpret" if interpret else "pallas"


def segment_ids_from_starts(segment_starts: Array, n: int) -> Array:
    """(s,) ascending start offsets (``starts[0] == 0``) -> (n,) segment id
    per element. Consecutive equal starts denote empty segments (they own no
    elements); the last segment ends at ``n``."""
    pos = jnp.arange(n, dtype=jnp.int32)
    seg = jnp.searchsorted(segment_starts.astype(jnp.int32), pos, side="right") - 1
    return seg.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Tile sizing: per-shape heuristic + small autotune cache (paper Table 1)
# ---------------------------------------------------------------------------

_TILE_CACHE: Dict[Tuple[int, int, str, bool, str], int] = {}


def _heuristic_tile(n: int, m: int, method: str, backend: str) -> int:
    base = WMS_TILE if method in ("dms", "wms") else BMS_TILE
    tile = base
    if backend.startswith("pallas"):
        m_pad = _pad_lanes(m)
        # fused postscan working set, f32 words
        cost = lambda t: 4 * (3 * t * m_pad + t * t)
        while tile > _MIN_TILE and cost(tile) > _VMEM_BUDGET_BYTES:
            tile //= 2
    if n < tile:
        # tiny input: one tile, padded to the next power of two (>= 128 lanes)
        tile = max(128, 1 << max(n - 1, 0).bit_length())
    return tile


def resolve_tile(
    n: int, m: int, method: str, key_value: bool, backend: str, requested: Optional[int] = None
) -> int:
    """Tile height for one subproblem; cached per shape, overridable.

    An explicit ``requested`` tile is returned verbatim and deliberately
    NEVER written into the cache: a one-off override must not change what
    later same-shape calls resolve to (regression-tested)."""
    if requested is not None:
        return requested
    key = (n, m, method, key_value, backend)
    tile = _TILE_CACHE.get(key)
    if tile is None:
        tile = _heuristic_tile(n, m, method, backend)
        _TILE_CACHE[key] = tile
    return tile


def clear_tile_cache() -> None:
    _TILE_CACHE.clear()


def autotune_tile(
    n: int,
    bucket_fn: BucketIdentifier,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    candidates: Tuple[int, ...] = (256, 512, 1024, 2048, 4096),
    trials: int = 3,
    seed: int = 0,
) -> int:
    """Time the candidate tile sizes on synthetic uniform keys and pin the
    winner in the per-shape cache. Returns the chosen tile."""
    import numpy as np

    rng = np.random.RandomState(seed)
    keys = jnp.asarray(rng.randint(0, 2**30, n, dtype=np.uint32))
    values = jnp.arange(n, dtype=jnp.int32) if key_value else None
    best, best_t = None, None
    for tile in candidates:
        if tile > max(n, _MIN_TILE):
            continue
        plan = make_plan(
            n, bucket_fn.num_buckets, method=method, key_value=key_value,
            backend=backend, tile=tile, bucket_fn=bucket_fn,
        )
        run = jax.jit(lambda k, v: plan(k, v).keys) if key_value else jax.jit(
            lambda k: plan(k).keys
        )
        args = (keys, values) if key_value else (keys,)
        jax.block_until_ready(run(*args))                    # compile
        ts = []
        for _ in range(trials):
            t0 = time.perf_counter()
            jax.block_until_ready(run(*args))
            ts.append(time.perf_counter() - t0)
        t = min(ts)
        if best is None or t < best:
            best, best_t = t, tile
    if best_t is not None:
        _TILE_CACHE[(n, bucket_fn.num_buckets, method, key_value, backend)] = best_t
    return best_t if best_t is not None else resolve_tile(
        n, bucket_fn.num_buckets, method, key_value, backend
    )


# ---------------------------------------------------------------------------
# Shared tiling / scan helpers (the ONE global operation lives here)
# ---------------------------------------------------------------------------

def pad_to_tiles(x: Array, tile: int, fill) -> Tuple[Array, int]:
    n = x.shape[0]
    n_pad = (-n) % tile
    if n_pad:
        x = jnp.concatenate([x, jnp.full((n_pad,) + x.shape[1:], fill, x.dtype)])
    return x, n_pad


def global_scan(hist_per_tile: Array) -> Array:
    """Exclusive scan over the row-vectorized (bucket-major) H (paper §4.1).

    ``hist_per_tile`` is (L, m); returns G (L, m): global base of
    (tile l, bucket b).
    """
    h_t = hist_per_tile.T                                  # (m, L) bucket-major
    flat = h_t.reshape(-1)
    g = jnp.concatenate([jnp.zeros((1,), flat.dtype), jnp.cumsum(flat)[:-1]])
    return g.reshape(h_t.shape).T                          # back to (L, m)


def tile_local_offsets(ids: Array, m: int) -> Tuple[Array, Array]:
    """One one-hot/cumsum evaluation over one tile: (stable in-bucket rank,
    tile histogram) — paper Alg. 3 without ballots. Canonical definition;
    ``core.multisplit`` re-exports it."""
    one_hot = (ids[:, None] == jnp.arange(m)[None, :]).astype(jnp.int32)
    incl = jnp.cumsum(one_hot, axis=0)
    local = incl[jnp.arange(ids.shape[0]), ids] - 1
    return local.astype(jnp.int32), incl[-1]


_tile_local_offsets = tile_local_offsets


def _seg_tile_local(ids: Array, segs: Array, m: int) -> Array:
    """Segmented stable in-bucket rank within one tile: an m-wide cumsum with
    a per-segment CARRY subtraction instead of an s·m-wide one-hot — O(T·m)
    regardless of the segment count (DESIGN.md §9). Relies on elements being
    segment-sorted within the tile (the input is segment-contiguous)."""
    t = ids.shape[0]
    one_hot = (ids[:, None] == jnp.arange(m)[None, :]).astype(jnp.int32)
    incl = jnp.cumsum(one_hot, axis=0)
    excl = jnp.concatenate([jnp.zeros((1, m), incl.dtype), incl[:-1]], axis=0)
    first = jnp.searchsorted(segs, segs, side="left")       # first row of my segment
    carry = excl[first, ids]                                # my bucket, before my segment
    local = incl[jnp.arange(t), ids] - carry - 1
    return local.astype(jnp.int32)


def _exclusive_rows(counts: Array) -> Array:
    """Exclusive prefix along the last axis: bucket start offsets."""
    return (jnp.cumsum(counts, axis=-1) - counts).astype(jnp.int32)


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultisplitPlan:
    """A resolved multisplit pipeline for one problem shape.

    Frozen and hashable-by-identity: build via :func:`make_plan` /
    :func:`make_radix_plan`, call with concrete arrays. ``radix`` carries the
    (shift, bits) of a fused digit identifier — when set with a pallas
    backend, bucket ids are extracted inside the kernels and never exist as a
    host/HBM array.

    ``batch``/``segments`` (mutually exclusive) select the batched or
    segmented layout (module docstring / DESIGN.md §9): ``batch=b`` expects
    ``(b, n)`` inputs; ``segments=s`` expects flat ``(n,)`` inputs plus a
    ``segment_starts`` call argument of shape ``(s,)``.
    """

    n: int
    num_buckets: int
    method: str                     # dms | wms | bms
    key_value: bool
    backend: str
    tile: int
    radix: Optional[Tuple[int, int]] = None        # (shift, bits)
    bucket_fn: Optional[BucketIdentifier] = None
    batch: Optional[int] = None                    # leading (b, n) axis
    segments: Optional[int] = None                 # ragged segments over (n,)

    # -- introspection -----------------------------------------------------
    def stages(self) -> Tuple[str, ...]:
        """Human/test-readable pipeline description."""
        kernel = self.backend.startswith("pallas")
        fused_id = self.radix is not None and kernel
        pre = ("prescan:radix-fused-kernel" if fused_id
               else "prescan:kernel" if kernel else "prescan:vmap")
        if self.method == "dms":
            post = ("postscan:radix-positions-kernel" if fused_id
                    else "postscan:positions-kernel" if kernel else "postscan:positions-vmap")
        else:
            post = ("postscan:radix-fused-reorder-kernel" if fused_id
                    else "postscan:fused-reorder-kernel" if kernel
                    else "postscan:fused-reorder-vmap")
        if self.backend == "reference":
            base = ("direct-solve:reference",)
        else:
            base = (pre, "scan:global", post, "scatter:bucket-major")
        if self.batch is not None:
            return (f"layout:batched[{self.batch}]",) + base
        if self.segments is not None:
            return (f"layout:segmented[{self.segments}]",) + base
        return base

    # -- helpers -----------------------------------------------------------
    def _interpret(self) -> bool:
        return self.backend != "pallas"

    def _ids_fn(self) -> BucketIdentifier:
        if self.bucket_fn is not None:
            return self.bucket_fn
        if self.radix is None:
            raise ValueError("plan has neither bucket_fn nor radix spec")
        shift, bits = self.radix
        mask = (1 << bits) - 1
        return BucketIdentifier(
            lambda u: ((u.astype(jnp.uint32) >> jnp.uint32(shift)) & jnp.uint32(mask)).astype(jnp.int32),
            1 << bits,
            name=f"radix[{shift}:{shift + bits}]",
        )

    def _m_eff(self) -> int:
        """Width of the one-hot/scan: ``s*m`` for segmented plans, else m."""
        return self.num_buckets * (self.segments or 1)

    # -- stage 1: prescan --------------------------------------------------
    def prescan(
        self, keys_tiled: Array, ids_tiled: Optional[Array],
        seg_tiled: Optional[Array] = None,
    ) -> Array:
        m, s = self.num_buckets, self.segments
        if self.backend.startswith("pallas"):
            from repro.kernels import ops as kops

            if self.radix is not None:
                shift, bits = self.radix
                if seg_tiled is not None:
                    return kops.seg_radix_tile_histograms(
                        keys_tiled, seg_tiled, shift, bits, s, interpret=self._interpret()
                    )
                return kops.radix_tile_histograms(
                    keys_tiled, shift, bits, interpret=self._interpret()
                )
            if seg_tiled is not None:
                return kops.seg_tile_histograms(
                    ids_tiled, seg_tiled, m, s, interpret=self._interpret()
                )
            return kops.tile_histograms(ids_tiled, m, interpret=self._interpret())
        if seg_tiled is not None:
            # combined (seg, bucket) histogram via scatter-add: O(T + s·m)
            # per tile instead of an s·m-wide one-hot (DESIGN.md §9)
            m_eff = self._m_eff()
            cid = (seg_tiled * m + ids_tiled).astype(jnp.int32)
            return jax.vmap(
                lambda c: jnp.zeros((m_eff,), jnp.int32).at[c].add(1)
            )(cid)
        return jax.vmap(lambda t: _tile_local_offsets(t, m)[1])(ids_tiled)

    # -- stage 3: fused postscan (+ reorder for wms/bms) -------------------
    def postscan(
        self,
        g: Array,
        keys_tiled: Array,
        ids_tiled: Optional[Array],
        vals_tiled: Optional[Array],
        seg_tiled: Optional[Array] = None,
    ) -> Tuple[Array, Optional[Array], Array, Array]:
        """Returns (scatter_src_keys, scatter_src_vals, scatter_pos, perm).

        For wms/bms the sources are bucket-major within each tile and the
        positions permuted to match — ONE one-hot/cumsum evaluation per tile
        (the fused kernel / fused closure is the only postscan entry point).
        ``perm`` is the element-ordered destination map (paper eq. (2)), a
        free byproduct of the same evaluation. With ``seg_tiled`` the segment
        id rides through the evaluation as the high part of the combined
        bucket id (in-kernel on pallas backends).
        """
        m, s = self.num_buckets, self.segments
        m_eff = self._m_eff()
        pallas = self.backend.startswith("pallas")
        if self.method == "dms":
            if pallas:
                from repro.kernels import ops as kops

                if self.radix is not None:
                    shift, bits = self.radix
                    if seg_tiled is not None:
                        pos = kops.seg_radix_tile_positions(
                            keys_tiled, seg_tiled, g, shift, bits, s,
                            interpret=self._interpret(),
                        )
                    else:
                        pos = kops.radix_tile_positions(
                            keys_tiled, g, shift, bits, interpret=self._interpret()
                        )
                elif seg_tiled is not None:
                    pos = kops.seg_tile_positions(
                        ids_tiled, seg_tiled, g, m, s, interpret=self._interpret()
                    )
                else:
                    pos = kops.tile_positions(ids_tiled, g, m, interpret=self._interpret())
            elif seg_tiled is not None:
                def one_tile_seg(ids, segs, g_tile):
                    local = _seg_tile_local(ids, segs, m)
                    return g_tile[(segs * m + ids).astype(jnp.int32)] + local

                pos = jax.vmap(one_tile_seg)(ids_tiled, seg_tiled, g)
            else:
                def one_tile(ids, g_tile):
                    local, _ = _tile_local_offsets(ids, m)
                    return g_tile[ids] + local

                pos = jax.vmap(one_tile)(ids_tiled, g)
            return keys_tiled, vals_tiled, pos, pos

        if pallas:
            from repro.kernels import ops as kops

            if self.radix is not None:
                shift, bits = self.radix
                if seg_tiled is not None:
                    return kops.seg_radix_fused_postscan_reorder(
                        keys_tiled, seg_tiled, g, vals_tiled, shift, bits, s,
                        interpret=self._interpret(),
                    )
                return kops.radix_fused_postscan_reorder(
                    keys_tiled, g, vals_tiled, shift, bits, interpret=self._interpret()
                )
            if seg_tiled is not None:
                return kops.seg_fused_postscan_reorder(
                    ids_tiled, seg_tiled, g, keys_tiled, vals_tiled, m, s,
                    interpret=self._interpret(),
                )
            return kops.fused_postscan_reorder(
                ids_tiled, g, keys_tiled, vals_tiled, m, interpret=self._interpret()
            )

        # vmap backend: the SAME fusion as the kernel — local ranks, tile
        # starts, tile destination and global destination all from one
        # one-hot/cumsum evaluation, then one gather-free scatter per array.
        # Segmented tiles swap the one-hot/cumsum for its segmented-carry
        # form + a scatter-add histogram, keeping the pass O(T·m) instead of
        # O(T·s·m) (DESIGN.md §9).
        def fused_tile(ids, segs, g_tile, keys_t, vals_t):
            if segs is None:
                local, hist = _tile_local_offsets(ids, m)
                cid = ids
            else:
                local = _seg_tile_local(ids, segs, m)
                cid = (segs * m + ids).astype(jnp.int32)
                hist = jnp.zeros((m_eff,), jnp.int32).at[cid].add(1)
            starts = (jnp.cumsum(hist) - hist).astype(jnp.int32)
            dest = starts[cid] + local
            pos = (g_tile[cid] + local).astype(jnp.int32)
            keys_r = jnp.zeros_like(keys_t).at[dest].set(keys_t)
            pos_r = jnp.zeros_like(pos).at[dest].set(pos)
            if vals_t is None:
                return keys_r, pos_r, pos
            vals_r = jnp.zeros_like(vals_t).at[dest].set(vals_t)
            return keys_r, vals_r, pos_r, pos

        if seg_tiled is None:
            if vals_tiled is None:
                keys_r, pos_r, perm = jax.vmap(
                    lambda i, gt, kt: fused_tile(i, None, gt, kt, None)
                )(ids_tiled, g, keys_tiled)
                return keys_r, None, pos_r, perm
            keys_r, vals_r, pos_r, perm = jax.vmap(
                lambda i, gt, kt, vt: fused_tile(i, None, gt, kt, vt)
            )(ids_tiled, g, keys_tiled, vals_tiled)
            return keys_r, vals_r, pos_r, perm
        if vals_tiled is None:
            keys_r, pos_r, perm = jax.vmap(
                lambda i, sg, gt, kt: fused_tile(i, sg, gt, kt, None)
            )(ids_tiled, seg_tiled, g, keys_tiled)
            return keys_r, None, pos_r, perm
        keys_r, vals_r, pos_r, perm = jax.vmap(fused_tile)(
            ids_tiled, seg_tiled, g, keys_tiled, vals_tiled
        )
        return keys_r, vals_r, pos_r, perm

    # -- layout-specific drivers -------------------------------------------
    def _empty_result(self, keys: Array, values: Optional[Array]) -> MultisplitResult:
        """n == 0: every output is empty/zero in the layout's shapes."""
        m = self.num_buckets
        if self.batch is not None:
            shape_cm = (self.batch, m)
            perm = jnp.zeros((self.batch, 0), jnp.int32)
        elif self.segments is not None:
            shape_cm = (self.segments, m)
            perm = jnp.zeros((0,), jnp.int32)
        else:
            shape_cm = (m,)
            perm = jnp.zeros((0,), jnp.int32)
        zeros = jnp.zeros(shape_cm, jnp.int32)
        return MultisplitResult(keys, values, zeros, zeros, perm)

    def _pad_key(self, dtype) -> int:
        """Fused-radix pad sentinel: all-ones key — digit m-1 in EVERY pass."""
        return (1 << 32) - 1 if dtype == jnp.uint32 else -1

    def _call_batched(self, keys: Array, values: Optional[Array]) -> MultisplitResult:
        b, n, m = self.batch, self.n, self.num_buckets
        if keys.shape != (b, n):
            raise ValueError(f"batched plan resolved for shape {(b, n)}, got {keys.shape}")
        if values is not None and values.shape != (b, n):
            raise ValueError(
                f"batched plans require values of shape {(b, n)}, got {values.shape}"
            )
        if n == 0:
            return self._empty_result(keys, values)

        if self.backend == "reference":
            ids_fn = self._ids_fn()
            solve = lambda k, v: _direct_solve_ids(k, ids_fn(k), m, v)
            if values is None:
                return jax.vmap(lambda k: solve(k, None))(keys)
            return jax.vmap(solve)(keys, values)

        if self.backend.startswith("pallas") and keys.dtype.itemsize != 4:
            raise ValueError(
                f"pallas backends require 32-bit keys (got {keys.dtype}); "
                "use backend='vmap' for other widths"
            )

        fused_id = self.radix is not None and self.backend.startswith("pallas")
        tile = self.tile
        l_b = -(-n // tile)                       # tiles per batch row
        n_row = l_b * tile

        def pad_rows(x, fill):
            if n_row == n:
                return x
            return jnp.pad(
                x, ((0, 0), (0, n_row - n)), constant_values=jnp.asarray(fill, x.dtype)
            )

        # Per-row tiling: each tile belongs to exactly ONE batch row, so a
        # single kernel grid of b*l_b programs covers the whole batch.
        if fused_id:
            keys_tiled = pad_rows(keys, self._pad_key(keys.dtype)).reshape(b * l_b, tile)
            ids_tiled = None
        else:
            ids = self._ids_fn()(keys)
            ids_tiled = pad_rows(ids, m - 1).reshape(b * l_b, tile)
            keys_tiled = pad_rows(keys, 0).reshape(b * l_b, tile)
        vals_tiled = None
        if values is not None:
            vals_tiled = pad_rows(values, 0).reshape(b * l_b, tile)

        hist = self.prescan(keys_tiled, ids_tiled)               # (b*l_b, m)
        # the global scan is PER ROW: each batch row is its own multisplit
        g = jax.vmap(global_scan)(hist.reshape(b, l_b, m)).reshape(b * l_b, m)
        src_keys, src_vals, pos, perm_tiled = self.postscan(g, keys_tiled, ids_tiled, vals_tiled)

        pos_rows = pos.reshape(b, n_row)
        scat = lambda p, src: jnp.zeros((n_row,), src.dtype).at[p].set(src)
        keys_out = jax.vmap(scat)(pos_rows, src_keys.reshape(b, n_row))[:, :n]
        values_out = None
        if values is not None:
            values_out = jax.vmap(scat)(pos_rows, src_vals.reshape(b, n_row))[:, :n]

        counts = hist.reshape(b, l_b, m).sum(axis=1).astype(jnp.int32)
        counts = counts.at[:, m - 1].add(n - n_row)              # drop pad sentinels
        return MultisplitResult(
            keys_out, values_out, _exclusive_rows(counts), counts,
            perm_tiled.reshape(b, n_row)[:, :n],
        )

    # -- full pipeline -----------------------------------------------------
    def __call__(
        self,
        keys: Array,
        values: Optional[Array] = None,
        segment_starts: Optional[Array] = None,
    ) -> MultisplitResult:
        if (values is not None) != self.key_value:
            raise ValueError(
                f"plan resolved for key_value={self.key_value} but called with "
                f"values={'present' if values is not None else 'absent'}"
            )
        if self.segments is None and segment_starts is not None:
            raise ValueError("plan is not segmented; segment_starts not accepted")

        if self.batch is not None:
            return self._call_batched(keys, values)

        if keys.shape[0] != self.n:
            raise ValueError(f"plan resolved for n={self.n}, got n={keys.shape[0]}")
        m, s = self.num_buckets, self.segments
        m_eff = self._m_eff()

        seg_ids = None
        if s is not None:
            if segment_starts is None:
                raise ValueError("segmented plan requires segment_starts")
            segment_starts = jnp.asarray(segment_starts, jnp.int32)
            if segment_starts.shape != (s,):
                raise ValueError(
                    f"plan resolved for {s} segments, got segment_starts shape "
                    f"{segment_starts.shape}"
                )
            seg_ids = segment_ids_from_starts(segment_starts, self.n)

        if self.n == 0:
            return self._empty_result(keys, values)

        if self.backend == "reference":
            ids = self._ids_fn()(keys)
            if s is None:
                return _direct_solve_ids(keys, ids, m, values)
            res = _direct_solve_ids(keys, (seg_ids * m + ids).astype(jnp.int32), m_eff, values)
            counts = res.bucket_counts.reshape(s, m)
            return MultisplitResult(
                res.keys, res.values, _exclusive_rows(counts), counts,
                res.permutation - segment_starts[seg_ids],
            )

        if self.backend.startswith("pallas") and keys.dtype.itemsize != 4:
            raise ValueError(
                f"pallas backends require 32-bit keys (got {keys.dtype}); "
                "use backend='vmap' for other widths"
            )

        fused_id = self.radix is not None and self.backend.startswith("pallas")
        n = self.n

        # ---- tiling. Pads ride in (segment s-1,) bucket m-1 at the very
        # tail, so they land after every real element and are sliced off
        # below. For fused radix plans the pad key is all-ones: its digit is
        # m-1 in EVERY pass.
        if fused_id:
            keys_p, _ = pad_to_tiles(keys, self.tile, self._pad_key(keys.dtype))
            keys_tiled = keys_p.reshape(-1, self.tile)
            ids_tiled = None
        else:
            ids = self._ids_fn()(keys)
            ids_p, _ = pad_to_tiles(ids, self.tile, m - 1)
            ids_tiled = ids_p.reshape(-1, self.tile)
            keys_p, _ = pad_to_tiles(keys, self.tile, 0)
            keys_tiled = keys_p.reshape(-1, self.tile)
        seg_tiled = None
        if s is not None:
            seg_p, _ = pad_to_tiles(seg_ids, self.tile, s - 1)
            seg_tiled = seg_p.reshape(-1, self.tile)
        n_total = keys_tiled.size
        vals_tiled = None
        if values is not None:
            vals_p, _ = pad_to_tiles(values, self.tile, 0)
            vals_tiled = vals_p.reshape(-1, self.tile)

        # ---- the three stages
        hist = self.prescan(keys_tiled, ids_tiled, seg_tiled)
        g = global_scan(hist)
        src_keys, src_vals, pos, perm_tiled = self.postscan(
            g, keys_tiled, ids_tiled, vals_tiled, seg_tiled
        )

        # ---- global scatter (contiguous per-bucket runs for wms/bms).
        # For segmented plans the combined (seg, bucket)-major order IS the
        # segment-concatenated per-segment bucket-major order, so the same
        # flat scatter lands every segment in its input span.
        scatter_pos = pos.reshape(-1)
        keys_out = (
            jnp.zeros((n_total,), keys.dtype).at[scatter_pos].set(src_keys.reshape(-1))[:n]
        )
        values_out = None
        if values is not None:
            values_out = (
                jnp.zeros((n_total,) + values.shape[1:], values.dtype)
                .at[scatter_pos]
                .set(src_vals.reshape(-1))[:n]
            )

        counts = hist.sum(axis=0).astype(jnp.int32)
        counts = counts.at[m_eff - 1].add(n - n_total)           # drop pad sentinels
        perm = perm_tiled.reshape(-1)[:n]
        if s is not None:
            counts = counts.reshape(s, m)
            return MultisplitResult(
                keys_out, values_out, _exclusive_rows(counts), counts,
                perm - segment_starts[seg_ids],                  # segment-LOCAL
            )
        starts = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
        )
        return MultisplitResult(keys_out, values_out, starts, counts, perm)


def _direct_solve_ids(
    keys: Array, ids: Array, m: int, values: Optional[Array]
) -> MultisplitResult:
    """O(n·m) direct evaluation of paper eq. (1) on precomputed bucket ids."""
    if keys.shape[0] == 0:
        zeros = jnp.zeros((m,), jnp.int32)
        return MultisplitResult(keys, values, zeros, zeros, jnp.zeros((0,), jnp.int32))
    local, hist = _tile_local_offsets(ids, m)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(hist)[:-1].astype(jnp.int32)]
    )
    perm = starts[ids] + local
    keys_out = jnp.zeros_like(keys).at[perm].set(keys)
    values_out = None
    if values is not None:
        values_out = jnp.zeros_like(values).at[perm].set(values)
    return MultisplitResult(keys_out, values_out, starts, hist.astype(jnp.int32), perm)


def _direct_solve_reference(
    keys: Array, bucket_fn: BucketIdentifier, values: Optional[Array]
) -> MultisplitResult:
    """O(n·m) direct evaluation of paper eq. (1): the oracle backend."""
    return _direct_solve_ids(keys, bucket_fn(keys), bucket_fn.num_buckets, values)


def _validate_layout(batch: Optional[int], segments: Optional[int]) -> None:
    if batch is not None and segments is not None:
        raise ValueError("batch and segments are mutually exclusive plan layouts")
    if batch is not None and batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if segments is not None and segments < 1:
        raise ValueError(f"segments must be >= 1, got {segments}")


def make_plan(
    n: int,
    num_buckets: int,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    tile: Optional[int] = None,
    bucket_fn: Optional[BucketIdentifier] = None,
    batch: Optional[int] = None,
    segments: Optional[int] = None,
) -> MultisplitPlan:
    """Resolve (n, m, method, key-value-ness, backend) into a staged plan.

    ``batch=b`` resolves a batched plan over ``(b, n)`` inputs; ``segments=s``
    a segmented plan over flat ``(n,)`` inputs with an ``(s,)``
    ``segment_starts`` call argument (mutually exclusive)."""
    if method not in ("dms", "wms", "bms"):
        raise ValueError(f"unknown multisplit method {method!r}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    _validate_layout(batch, segments)
    m_eff = num_buckets * (segments or 1)
    resolved_tile = resolve_tile(n, m_eff, method, key_value, backend, tile)
    return MultisplitPlan(
        n=n, num_buckets=num_buckets, method=method, key_value=key_value,
        backend=backend, tile=resolved_tile, bucket_fn=bucket_fn,
        batch=batch, segments=segments,
    )


def make_radix_plan(
    n: int,
    shift: int,
    bits: int,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    tile: Optional[int] = None,
    batch: Optional[int] = None,
    segments: Optional[int] = None,
) -> MultisplitPlan:
    """A plan whose bucket identifier is the radix digit (shift, bits) —
    fused into the kernels on pallas backends (no label array in HBM)."""
    if method not in ("dms", "wms", "bms"):
        raise ValueError(f"unknown multisplit method {method!r}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    _validate_layout(batch, segments)
    m = 1 << bits
    m_eff = m * (segments or 1)
    resolved_tile = resolve_tile(n, m_eff, method, key_value, backend, tile)
    return MultisplitPlan(
        n=n, num_buckets=m, method=method, key_value=key_value,
        backend=backend, tile=resolved_tile, radix=(shift, bits),
        batch=batch, segments=segments,
    )


def make_batched_plan(batch: int, n: int, num_buckets: int, **kw) -> MultisplitPlan:
    """Batched plan over ``(batch, n)`` inputs: one launch for all rows."""
    return make_plan(n, num_buckets, batch=batch, **kw)


def make_segmented_plan(n: int, num_segments: int, num_buckets: int, **kw) -> MultisplitPlan:
    """Segmented plan over flat ``(n,)`` inputs with ``num_segments`` ragged
    segments (call with ``segment_starts=``): one launch for all segments."""
    return make_plan(n, num_buckets, segments=num_segments, **kw)


def make_segmented_radix_plan(
    n: int, num_segments: int, shift: int, bits: int, **kw
) -> MultisplitPlan:
    """Segmented radix plan: one fused digit pass over all segments."""
    return make_radix_plan(n, shift, bits, segments=num_segments, **kw)
