"""Compatibility shim: the plan layer now lives in :mod:`repro.core.pipeline`.

PR-1/PR-2 grew ``core/plan.py`` into an 802-line monolith owning tiling,
backend dispatch, tile sizing and every layout driver. PR-3 split it into the
stage-graph pipeline package (DESIGN.md §10):

* stage primitives        -> ``repro.core.pipeline.stages``
* backend registry        -> ``repro.core.pipeline.registry``
* tile heuristic/autotune -> ``repro.core.pipeline.tiles``
* PipelineSpec + plans    -> ``repro.core.pipeline.spec``
* chained radix passes    -> ``repro.core.pipeline.radix``

Every public (and test-visible private) symbol keeps importing from here —
``from repro.core.plan import make_plan`` etc. stays valid, warning-free, and
backed by the exact same objects (the tile cache below IS the package's
cache, not a copy). New code should import :mod:`repro.core.pipeline`.
"""

from __future__ import annotations

from repro.core.pipeline.radix import RadixPipeline, radix_passes
from repro.core.pipeline.registry import (
    BACKENDS,
    Backend,
    available_backends,
    backend_names,
    get_backend,
    register_backend,
    resolve_backend,
)
from repro.core.pipeline.spec import (
    MODES,
    MultisplitPlan,
    PipelineSpec,
    Stage,
    make_batched_plan,
    make_plan,
    make_radix_plan,
    make_segmented_plan,
    make_segmented_radix_plan,
)
from repro.core.pipeline.stages import (
    MultisplitResult,
    direct_counts,
    exclusive_rows,
    global_scan,
    pad_rows,
    pad_to_tiles,
    segment_ids_from_starts,
    tile_local_offsets,
)
from repro.core.pipeline.stages import direct_solve_ids as _direct_solve_ids
from repro.core.pipeline.stages import direct_solve_reference as _direct_solve_reference
from repro.core.pipeline.stages import exclusive_rows as _exclusive_rows
from repro.core.pipeline.stages import seg_tile_local as _seg_tile_local
from repro.core.pipeline.stages import tile_local_offsets as _tile_local_offsets
from repro.core.pipeline.tiles import (
    _FAMILY_CACHE,
    _MIN_TILE,
    _TILE_CACHE,
    _VMEM_BUDGET_BYTES,
    BMS_TILE,
    FAMILIES,
    WMS_TILE,
    _heuristic_tile,
    autotune_tile,
    clear_tile_cache,
    family_decision,
    family_decisions,
    resolve_kernel_family,
    resolve_tile,
)

__all__ = [
    "BACKENDS", "BMS_TILE", "FAMILIES", "MODES", "MultisplitPlan",
    "MultisplitResult", "PipelineSpec", "RadixPipeline", "Stage", "WMS_TILE",
    "autotune_tile", "available_backends", "backend_names",
    "clear_tile_cache", "direct_counts", "exclusive_rows", "family_decision",
    "family_decisions", "get_backend", "global_scan", "make_batched_plan",
    "make_plan", "make_radix_plan", "make_segmented_plan",
    "make_segmented_radix_plan", "pad_rows", "pad_to_tiles", "radix_passes",
    "register_backend", "resolve_backend", "resolve_kernel_family",
    "resolve_tile", "segment_ids_from_starts", "tile_local_offsets",
]
