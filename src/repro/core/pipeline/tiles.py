"""Tile sizing + kernel-family selection: per-shape heuristics and a small
autotune cache (paper Table 1).

The tile height is the paper's subproblem-size knob: larger subproblems
narrow the global scan matrix H but deepen the local solve. Since the
packed-counter family (DESIGN.md §12) the local solve has a second knob —
the KERNEL FAMILY:

* ``"onehot"`` — the dense T×m one-hot/cumsum direct solve (DESIGN.md §2);
  per-key work and VMEM linear in the bucket count.
* ``"packed"`` — bit-packed subword counters with two-level (subtile→tile)
  ranking (paper §4.3); per-key work ~flat in the bucket count.

One module owns the heuristics, the caches, and the timing-based autotuner
so EVERY consumer — flat, batched, segmented plans and the chained radix
pipeline — resolves (tile, family) through the same door.  Family decisions
are memoized WITH the reason they were made (:func:`family_decision`), so a
surprising plan can always be interrogated.

Since the self-tuning layer (DESIGN.md §14,
:mod:`repro.core.pipeline.autotune`) a cache MISS can resolve through
measurement instead of the heuristic: when autotuning is opted in
(``repro.ops.set_autotune(True)`` / ``REPRO_AUTOTUNE=1``), the miss first
consults a persistent on-disk cache keyed by (host fingerprint, backend,
shape class) and otherwise runs the joint timing search, pinning AND
persisting the winner.  The heuristics remain the default — and the drift
gate (``benchmarks/autotune_drift.py``) measures how far they rot.
"""

from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.identifiers import BucketSpec
from repro.kernels.common import pad_lanes as _pad_lanes

# "warp" tiles vs "block" tiles (paper Table 1 sizing knob).
WMS_TILE = 1024
BMS_TILE = 4096

# VMEM budget for the heuristic (working set of the fused postscan).
_VMEM_BUDGET_BYTES = 8 << 20
_MIN_TILE = 256

# Kernel families (DESIGN.md §12). The family heuristic switches to packed
# counters once the bucket axis is wide enough that the dense one-hot
# dominates the tile working set. The flip point is the MEASURED host-bench
# crossover (BENCH_multisplit.json packed_vs_onehot sweep re-run at
# n ∈ {2^18, 2^20}, key-value flat multisplit): packed already wins at m=8
# (1.12–1.25×) and only ties at m=4 — the original 64 was a working-set
# argument that left the whole 8 ≤ m < 64 band on the slower family.
FAMILIES = ("onehot", "packed")
PACKED_MIN_BUCKETS = 8

# digits=1: (n, m_eff, method, key_value, backend);
# digits=2: (n, m_eff, method, key_value, backend, 2, stage_m) — stage_m IS
# part of the fused-pair footprint (_fused2_cost_bytes depends on it), so
# two pair schedules with equal combined m but different digit_split must
# not share a tile entry (regression-tested).
_TILE_CACHE: Dict[Tuple, int] = {}
# digits=1: (n, m_eff, method, backend); digits=2 appends the digits slot —
# fused-pair stage solves are stage_m-wide, and their decisions must never
# collide with genuine digits=1 plans of m == stage_m (regression-tested).
# Values are (family, reason): reasons are recorded so autotune/heuristic
# choices stay explainable after the fact.
_FAMILY_CACHE: Dict[Tuple, Tuple[str, str]] = {}
# (n, m_eff, method, key_value, backend, stage_m) -> in-tile sub-digit stage
# width of the fused2 LSD sweep. ONLY the autotuner writes here; on a miss
# the measured global default (_FUSED2_SUB_BITS) applies.
_SUB_BITS_CACHE: Dict[Tuple, int] = {}


def _family_key(n: int, m: int, method: str, backend: str, digits: int) -> Tuple:
    base = (n, m, method, backend)
    return base if digits == 1 else base + (digits,)


def _tile_key(n: int, m: int, method: str, key_value: bool, backend: str,
              digits: int, stage_m: Optional[int]) -> Tuple:
    base = (n, m, method, key_value, backend)
    if digits == 1:
        return base
    return base + (digits, stage_m or max(1, int(m ** 0.5)))


def _family_cost_bytes(t: int, m: int, family: str,
                       oblivious: bool = False) -> int:
    """Per-tile working set of the fused postscan kernel, in bytes.

    onehot: one-hot + its cumsum (2·T·m̄ f32) + the triangular-scan and
    permutation matrices (2·T² f32) + ~8 T-vectors. The pre-PR-5 model
    under-counted this (it charged one T·m̄ plane and no cumsum output),
    which is why large-m tiles blew past the budget in practice.  (The
    dense body was always gather-free, so its model has no oblivious term.)

    packed: the (T, ⌈m/k⌉) packed contribution + inclusive-scan planes, the
    small S×m level-2 scan, and ~8 T-vectors — near-flat in m.
    ``oblivious=True`` (kernel backends, DESIGN.md §15) additionally charges
    the T×T reorder permutation plane and the T×m one-hot the starts/G
    picks contract against — the quadratic term pulls the packed tile
    optimum DOWN on kernel backends, while the vmap gather form keeps its
    near-flat profile.
    """
    if family == "packed":
        from repro.kernels.common import packed_layout

        lay = packed_layout(t, m)
        base = 4 * (2 * t * lay.w + 3 * lay.n_sub * m + 8 * t)
        if oblivious:
            base += 4 * (t * t + 2 * t * m)
        return base
    m_pad = _pad_lanes(m)
    return 4 * (2 * t * m_pad + 2 * t * t + 8 * t)


def _fused2_cost_bytes(t: int, m: int, stage_m: int, family: str,
                       key_value: bool, oblivious: bool = False) -> int:
    """Per-tile working set of the fused TWO-digit postscan (DESIGN.md §13):
    the double-resident tile model of
    :func:`repro.kernels.common.fused2_vmem_bytes` — the sub-digit LSD
    sweep's reused stage plane plus the ``m``-wide combined pair rows
    (+ the oblivious permutation/pick planes on kernel backends, §15)."""
    from repro.kernels.common import fused2_vmem_bytes

    return fused2_vmem_bytes(
        t, stage_m, family=family, key_value=key_value,
        m_hi=max(1, m // stage_m), oblivious=oblivious,
    )


def _heuristic_tile(
    n: int, m: int, method: str, backend: str, family: str = "onehot",
    digits: int = 1, stage_m: Optional[int] = None, key_value: bool = False,
) -> int:
    from repro.core.pipeline.registry import get_backend

    base = WMS_TILE if method in ("dms", "wms") else BMS_TILE
    tile = base
    # kernel backends trace the oblivious bodies (DESIGN.md §15), so only
    # they carry the oblivious VMEM terms; vmap keeps the gather profile
    obl = get_backend(backend).uses_kernels
    if digits == 2:
        cost = lambda t: _fused2_cost_bytes(
            t, m, stage_m or max(1, int(m ** 0.5)), family, key_value,
            oblivious=obl,
        )
        # A fused pair's global-scan traffic is L·m² words (L = tile count),
        # so pairs only profit when L is SMALL — grow the tile toward the
        # VMEM budget (the sub-digit LSD working set is ~linear in T with a
        # small constant) instead of shrinking from the single-digit base.
        while tile * 2 <= max(n, base) and cost(tile * 2) <= _VMEM_BUDGET_BYTES:
            tile *= 2
        while tile > _MIN_TILE and cost(tile) > _VMEM_BUDGET_BYTES:
            tile //= 2
    else:
        cost = lambda t: _family_cost_bytes(t, m, family, oblivious=obl)
        if obl:
            while tile > _MIN_TILE and cost(tile) > _VMEM_BUDGET_BYTES:
                tile //= 2
    if n < tile:
        # tiny input: one tile, padded to the next power of two (>= 128 lanes)
        tile = max(128, 1 << max(n - 1, 0).bit_length())
    return tile


def _heuristic_family(n: int, m: int, method: str, backend: str) -> Tuple[str, str]:
    from repro.core.pipeline.registry import get_backend

    be = get_backend(backend)
    if not be.tiled:
        return "onehot", "untiled direct-solve backend: no tile local solve"
    if "packed" not in be.families:
        return "onehot", f"backend {backend!r} advertises no packed support"
    if m >= PACKED_MIN_BUCKETS:
        return "packed", (
            f"m_eff={m} >= {PACKED_MIN_BUCKETS}: packed subword counters keep "
            f"the local solve ~flat in the bucket count (DESIGN.md §12)"
        )
    return "onehot", (
        f"m_eff={m} < {PACKED_MIN_BUCKETS}: the dense one-hot local solve is "
        f"cheaper at narrow bucket axes"
    )


def resolve_kernel_family(
    n: int, m: int, method: str, backend: str, requested: Optional[str] = None,
    digits: int = 1, key_value: bool = False, pair_m: Optional[int] = None,
) -> str:
    """Kernel family for one subproblem shape; cached per shape WITH the
    reason it was chosen (:func:`family_decision`), overridable.

    ``digits=2`` keys the decision separately (fused-pair stage solves are
    ``stage_m``-wide; ``m`` here IS the stage width) so autotuning a flat
    shape never re-families a fused-pair plan of ``m == stage_m`` or vice
    versa.  ``key_value``/``pair_m`` are HINTS for the autotune-on-miss
    layer (what to measure), never part of the cache key.

    An explicit ``requested`` family is validated against the backend's
    ``families`` capability and returned verbatim — and, like an explicit
    tile, deliberately NEVER cached: a one-off override must not change
    what later same-shape plans resolve to."""
    from repro.core.pipeline.registry import get_backend

    be = get_backend(backend)
    if requested is not None:
        if requested not in FAMILIES:
            raise ValueError(
                f"unknown kernel family {requested!r}; expected one of {FAMILIES}"
            )
        if be.tiled and requested not in be.families:
            raise ValueError(
                f"backend {backend!r} supports kernel families {be.families}, "
                f"not {requested!r}"
            )
        return requested
    key = _family_key(n, m, method, backend, digits)
    hit = _FAMILY_CACHE.get(key)
    if hit is None:
        from repro.core.pipeline import autotune as _at

        _at.maybe_tune_family(
            n, m, method, backend, digits=digits, key_value=key_value,
            pair_m=pair_m,
        )
        hit = _FAMILY_CACHE.get(key)          # the search pins on success
    if hit is None:
        hit = _heuristic_family(n, m, method, backend)
        _FAMILY_CACHE[key] = hit
    return hit[0]


def family_decision(
    n: int, m: int, method: str, backend: str, digits: int = 1
) -> Tuple[str, str]:
    """(family, reason) for one shape — resolving (and memoizing) it first
    if needed. The reason says whether the heuristic or the autotuner chose,
    and why."""
    resolve_kernel_family(n, m, method, backend, digits=digits)
    return _FAMILY_CACHE[_family_key(n, m, method, backend, digits)]


def family_decisions() -> Dict[Tuple[int, int, str, str], Tuple[str, str]]:
    """Snapshot of every (shape -> (family, reason)) decision so far."""
    return dict(_FAMILY_CACHE)


def resolve_tile(
    n: int,
    m: int,
    method: str,
    key_value: bool,
    backend: str,
    requested: Optional[int] = None,
    family: Optional[str] = None,
    digits: int = 1,
    stage_m: Optional[int] = None,
) -> int:
    """Tile height for one subproblem; cached per shape, overridable.

    ``digits=2`` selects the fused two-digit footprint (DESIGN.md §13): the
    cache gains a digits slot (the single-digit key shape is unchanged) and
    the heuristic charges the DOUBLE-resident tile — two ``stage_m``-wide
    stage solves plus the m-wide pair rows — instead of one m-wide solve.

    The cache key is purely the spec VALUE shape — ``(n, m_eff, method,
    key_value, backend)``, with ``m_eff`` derived from the (hashable)
    bucket spec — never a spec/identifier object id, so equal spec
    instances share one entry and the cache cannot grow per instance
    (regression-tested).  The kernel family the shape auto-resolves to is a
    deterministic function of the same key, so it needs no extra key slot;
    a plan resolved with an EXPLICIT off-heuristic family computes its tile
    under that family's cost model without touching the cache.

    An explicit ``requested`` tile is returned verbatim and deliberately
    NEVER written into the cache: a one-off override must not change what
    later same-shape calls resolve to (regression-tested)."""
    if requested is not None:
        return requested
    kw = dict(digits=digits, stage_m=stage_m, key_value=key_value)
    fam_m = m if digits == 1 else (stage_m or max(1, int(m ** 0.5)))
    auto_family = resolve_kernel_family(
        n, fam_m, method, backend, digits=digits, key_value=key_value,
        pair_m=None if digits == 1 else m,
    )
    fam = auto_family if family is None else family
    if fam != auto_family:
        return _heuristic_tile(n, m, method, backend, family=fam, **kw)
    key = _tile_key(n, m, method, key_value, backend, digits, stage_m)
    tile = _TILE_CACHE.get(key)
    if tile is None:
        from repro.core.pipeline import autotune as _at

        _at.maybe_tune_tile(
            n, m, method, key_value, backend, digits=digits, stage_m=stage_m,
            family=fam,
        )
        tile = _TILE_CACHE.get(key)           # the search pins on success
    if tile is None:
        tile = _heuristic_tile(n, m, method, backend, family=fam, **kw)
        _TILE_CACHE[key] = tile
    return tile


def resolve_sub_bits(
    n: int,
    m: int,
    method: str,
    key_value: bool,
    backend: str,
    stage_m: int,
    requested: Optional[int] = None,
) -> Optional[int]:
    """In-tile sub-digit stage width for a fused-pair plan (DESIGN.md §13):
    the autotuned per-shape width if one was measured (or persisted on
    disk), else ``None`` — the kernels then fall back to the measured
    global default ``_FUSED2_SUB_BITS``. ``m`` is the pair's combined scan
    width (``m_eff``); ``stage_m`` the stage-solve width."""
    if requested is not None:
        return requested
    key = (n, m, method, key_value, backend, stage_m)
    hit = _SUB_BITS_CACHE.get(key)
    if hit is None:
        from repro.core.pipeline import autotune as _at

        _at.maybe_tune_sub_bits(n, m, method, key_value, backend, stage_m)
        hit = _SUB_BITS_CACHE.get(key)
    return hit


def pin_tile(n: int, m: int, method: str, key_value: bool, backend: str,
             tile: int, *, digits: int = 1,
             stage_m: Optional[int] = None) -> None:
    """Pin one tile in the per-shape cache — the degradation ladder's door
    (DESIGN.md §17): when halve-and-retry survives a
    :class:`~repro.runtime.resilience.KernelResourceError`, the survivor is
    pinned here so the shape class never re-learns the OOM the hard way.
    (An EXPLICIT user tile stays uncached — :func:`resolve_tile`'s rule is
    about one-off overrides; a measured resource limit is a shape fact.)"""
    _TILE_CACHE[_tile_key(n, m, method, key_value, backend, digits, stage_m)] \
        = int(tile)


def clear_tile_cache(disk: bool = False) -> None:
    """Drop every memoized tile, family, sub-bits AND label-fusion decision.

    Also drops the lazily-loaded snapshots of the persistent autotune cache
    and the resilience quarantine sidecar, so the next miss re-reads the
    files — i.e. a plain ``clear_tile_cache()`` simulates a fresh process
    against warm cache files (quarantined plan classes SURVIVE the reload,
    DESIGN.md §17).  ``disk=True`` additionally deletes both on-disk
    layers."""
    from repro.core.pipeline import autotune as _at
    from repro.core.pipeline import spec as _spec
    from repro.runtime import resilience as _rz

    _TILE_CACHE.clear()
    _FAMILY_CACHE.clear()
    _SUB_BITS_CACHE.clear()
    _spec._FUSION_CACHE.clear()
    if disk:
        _at.clear_disk()
        _rz.clear_quarantine(disk=True)
    else:
        _at.drop_loaded()
        _rz.drop_loaded()


def autotune_tile(
    n: int,
    bucket_fn: BucketSpec,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    candidates: Tuple[int, ...] = (256, 512, 1024, 2048, 4096),
    families: Optional[Tuple[str, ...]] = None,
    trials: int = 3,
    seed: int = 0,
    segments: Optional[int] = None,
    batch: Optional[int] = None,
) -> int:
    """Time the candidate (tile, family) grid on synthetic uniform keys and
    pin BOTH winners in the per-shape caches (the family with an
    ``autotuned`` reason naming the measured best), persisting them through
    the autotune disk layer when it is active (DESIGN.md §14). Returns the
    chosen tile; read the family via :func:`family_decision`.

    ``segments=s`` / ``batch=b`` (mutually exclusive) measure the segmented
    or batched layout instead of the flat one — the segmented search pins
    the ``m_eff = s·m`` shape class its plans actually resolve through; the
    batched search times ``b`` rows over the same per-row shape class."""
    import numpy as np

    from repro.core.pipeline import autotune as _at
    from repro.core.pipeline.registry import get_backend
    from repro.core.pipeline.spec import make_plan

    be = get_backend(backend)
    if families is None:
        families = be.families if be.tiled else ("onehot",)
    m_eff = bucket_fn.num_buckets * (segments or 1)
    for fam in families:
        resolve_kernel_family(n, m_eff, method, backend, fam)

    rng = np.random.RandomState(seed)
    shape = (n,) if batch is None else (batch, n)
    keys = jnp.asarray(rng.randint(0, 2**30, shape, dtype=np.uint32))
    values = (jnp.arange(keys.size, dtype=jnp.int32).reshape(shape)
              if key_value else None)
    seg_starts = None
    if segments is not None:
        seg_starts = (jnp.arange(segments, dtype=jnp.int32) * n) // segments
    best, best_t, best_f = None, None, None
    for tile in candidates:
        if tile > max(n, _MIN_TILE):
            continue
        for fam in families:
            plan = make_plan(
                n, bucket_fn.num_buckets, method=method, key_value=key_value,
                backend=backend, tile=tile, bucket_fn=bucket_fn, family=fam,
                segments=segments, batch=batch,
            )
            if segments is not None:
                run = (jax.jit(lambda k, v, p=plan: p(k, v, segment_starts=seg_starts).keys)
                       if key_value else
                       jax.jit(lambda k, p=plan: p(k, segment_starts=seg_starts).keys))
            else:
                run = (jax.jit(lambda k, v, p=plan: p(k, v).keys) if key_value
                       else jax.jit(lambda k, p=plan: p(k).keys))
            args = (keys, values) if key_value else (keys,)
            jax.block_until_ready(run(*args))                # compile
            ts = []
            for _ in range(trials):
                t0 = time.perf_counter()
                jax.block_until_ready(run(*args))
                ts.append(time.perf_counter() - t0)
            t = min(ts)
            if best is None or t < best:
                best, best_t, best_f = t, tile, fam
    if best_t is not None:
        tkey = (n, m_eff, method, key_value, backend)
        _TILE_CACHE[tkey] = best_t
        # The family decision is shared by both key-value variants of the
        # shape, but only THIS variant's tile was measured under the new
        # family — drop the other variant's entry so it re-resolves under
        # the pinned family's cost model instead of keeping a tile sized
        # for the old one (regression-tested).
        _TILE_CACHE.pop((n, m_eff, method, not key_value, backend), None)
        fkey = (n, m_eff, method, backend)
        _FAMILY_CACHE[fkey] = (best_f, (
            f"autotuned over tiles={candidates} x families={tuple(families)}: "
            f"({best_t}, {best_f!r}) won at {best:.3e}s"
        ))
        _at.record("tile", tkey, best_t)
        _at.record("family", fkey, best_f)
    return best_t if best_t is not None else resolve_tile(
        n, bucket_fn.num_buckets * (segments or 1), method, key_value, backend
    )
