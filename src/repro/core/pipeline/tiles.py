"""Tile sizing: per-shape heuristic + small autotune cache (paper Table 1).

The tile height is the paper's subproblem-size knob: larger subproblems
narrow the global scan matrix H but deepen the local solve. One module owns
the heuristic, the cache, and the timing-based autotuner so EVERY consumer —
flat, batched, segmented plans and the chained radix pipeline — resolves
tiles through the same door (no more private ``HIST_TILE``-style constants
scattered around the tree).
"""

from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.identifiers import BucketSpec
from repro.kernels.common import pad_lanes as _pad_lanes

# "warp" tiles vs "block" tiles (paper Table 1 sizing knob).
WMS_TILE = 1024
BMS_TILE = 4096

# VMEM budget for the heuristic (f32 working set of the fused postscan:
# one-hot (T·m̄) + tril/permutation (T·T) + two reorder operands).
_VMEM_BUDGET_BYTES = 8 << 20
_MIN_TILE = 256

_TILE_CACHE: Dict[Tuple[int, int, str, bool, str], int] = {}


def _heuristic_tile(n: int, m: int, method: str, backend: str) -> int:
    from repro.core.pipeline.registry import get_backend

    base = WMS_TILE if method in ("dms", "wms") else BMS_TILE
    tile = base
    if get_backend(backend).uses_kernels:
        m_pad = _pad_lanes(m)
        # fused postscan working set, f32 words
        cost = lambda t: 4 * (3 * t * m_pad + t * t)
        while tile > _MIN_TILE and cost(tile) > _VMEM_BUDGET_BYTES:
            tile //= 2
    if n < tile:
        # tiny input: one tile, padded to the next power of two (>= 128 lanes)
        tile = max(128, 1 << max(n - 1, 0).bit_length())
    return tile


def resolve_tile(
    n: int, m: int, method: str, key_value: bool, backend: str, requested: Optional[int] = None
) -> int:
    """Tile height for one subproblem; cached per shape, overridable.

    The cache key is purely the spec VALUE shape — ``(n, m_eff, method,
    key_value, backend)``, with ``m_eff`` derived from the (hashable)
    bucket spec — never a spec/identifier object id, so equal spec
    instances share one entry and the cache cannot grow per instance
    (regression-tested).

    An explicit ``requested`` tile is returned verbatim and deliberately
    NEVER written into the cache: a one-off override must not change what
    later same-shape calls resolve to (regression-tested)."""
    if requested is not None:
        return requested
    key = (n, m, method, key_value, backend)
    tile = _TILE_CACHE.get(key)
    if tile is None:
        tile = _heuristic_tile(n, m, method, backend)
        _TILE_CACHE[key] = tile
    return tile


def clear_tile_cache() -> None:
    _TILE_CACHE.clear()


def autotune_tile(
    n: int,
    bucket_fn: BucketSpec,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    candidates: Tuple[int, ...] = (256, 512, 1024, 2048, 4096),
    trials: int = 3,
    seed: int = 0,
) -> int:
    """Time the candidate tile sizes on synthetic uniform keys and pin the
    winner in the per-shape cache. Returns the chosen tile."""
    import numpy as np

    from repro.core.pipeline.spec import make_plan

    rng = np.random.RandomState(seed)
    keys = jnp.asarray(rng.randint(0, 2**30, n, dtype=np.uint32))
    values = jnp.arange(n, dtype=jnp.int32) if key_value else None
    best, best_t = None, None
    for tile in candidates:
        if tile > max(n, _MIN_TILE):
            continue
        plan = make_plan(
            n, bucket_fn.num_buckets, method=method, key_value=key_value,
            backend=backend, tile=tile, bucket_fn=bucket_fn,
        )
        run = jax.jit(lambda k, v: plan(k, v).keys) if key_value else jax.jit(
            lambda k: plan(k).keys
        )
        args = (keys, values) if key_value else (keys,)
        jax.block_until_ready(run(*args))                    # compile
        ts = []
        for _ in range(trials):
            t0 = time.perf_counter()
            jax.block_until_ready(run(*args))
            ts.append(time.perf_counter() - t0)
        t = min(ts)
        if best is None or t < best:
            best, best_t = t, tile
    if best_t is not None:
        _TILE_CACHE[(n, bucket_fn.num_buckets, method, key_value, backend)] = best_t
    return best_t if best_t is not None else resolve_tile(
        n, bucket_fn.num_buckets, method, key_value, backend
    )
