"""Stage primitives of the multisplit pipeline (paper §4.1).

Every multisplit variant in the paper factors into

    {local prescan} -> {one global scan} -> {local postscan (+ reorder)}

and its applications are *partial or iterated* instances of that pipeline:
the §7.3 histogram is prescan + reduce (no scan, no scatter), the §7.1 radix
sort is the full pipeline iterated over digit passes.  This module owns the
layout/stage *primitives* — padding/tiling, the global scan, the one-hot
local solve and its segmented-carry form, and the O(n·m) direct solve — as
free functions with no backend or dispatch logic.  Backend-specific stage
implementations live in :mod:`repro.core.pipeline.registry`; the stage graph
that composes them lives in :mod:`repro.core.pipeline.spec`.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.identifiers import BucketSpec

Array = jnp.ndarray


class MultisplitResult(NamedTuple):
    """Flat plans: shapes as commented. Batched plans prepend a ``b`` axis to
    ``keys``/``values``/``permutation`` and return ``(b, m)`` starts/counts.
    Segmented plans keep flat ``(n,)`` data arrays (segments occupy their
    input spans) and return ``(s, m)`` segment-LOCAL starts/counts plus a
    segment-local permutation.  Partial pipelines return ``None`` for the
    fields their stage graph never computes: ``counts_only`` fills only
    ``bucket_starts``/``bucket_counts``; ``positions_only`` additionally
    fills ``permutation``."""

    keys: Optional[Array]          # permuted keys, bucket-major, stable
    values: Optional[Array]        # permuted values (None for key-only)
    bucket_starts: Array           # (m,) start index of each bucket
    bucket_counts: Array           # (m,) histogram
    permutation: Optional[Array]   # (n,) dest position of input element i


def segment_ids_from_starts(segment_starts: Array, n: int) -> Array:
    """(s,) ascending start offsets (``starts[0] == 0``) -> (n,) segment id
    per element. Consecutive equal starts denote empty segments (they own no
    elements); the last segment ends at ``n``."""
    pos = jnp.arange(n, dtype=jnp.int32)
    seg = jnp.searchsorted(segment_starts.astype(jnp.int32), pos, side="right") - 1
    return seg.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Layout: padding / tiling
# ---------------------------------------------------------------------------

def pad_to_tiles(x: Array, tile: int, fill) -> Tuple[Array, int]:
    n = x.shape[0]
    n_pad = (-n) % tile
    if n_pad:
        x = jnp.concatenate([x, jnp.full((n_pad,) + x.shape[1:], fill, x.dtype)])
    return x, n_pad


def pad_rows(x: Array, n_row: int, fill) -> Array:
    """Pad every row of a ``(b, n)`` array out to ``n_row`` columns."""
    n = x.shape[1]
    if n_row == n:
        return x
    return jnp.pad(
        x, ((0, 0), (0, n_row - n)), constant_values=jnp.asarray(fill, x.dtype)
    )


# ---------------------------------------------------------------------------
# The ONE global operation
# ---------------------------------------------------------------------------

def global_scan(hist_per_tile: Array) -> Array:
    """Exclusive scan over the row-vectorized (bucket-major) H (paper §4.1).

    ``hist_per_tile`` is (L, m); returns G (L, m): global base of
    (tile l, bucket b).
    """
    h_t = hist_per_tile.T                                  # (m, L) bucket-major
    flat = h_t.reshape(-1)
    g = jnp.concatenate([jnp.zeros((1,), flat.dtype), jnp.cumsum(flat)[:-1]])
    return g.reshape(h_t.shape).T                          # back to (L, m)


# ---------------------------------------------------------------------------
# Local solves (paper §4.5 one-hot form; DESIGN.md §2)
# ---------------------------------------------------------------------------

def tile_local_offsets(ids: Array, m: int) -> Tuple[Array, Array]:
    """One one-hot/cumsum evaluation over one tile: (stable in-bucket rank,
    tile histogram) — paper Alg. 3 without ballots. Canonical definition;
    ``core.multisplit`` re-exports it."""
    one_hot = (ids[:, None] == jnp.arange(m)[None, :]).astype(jnp.int32)
    incl = jnp.cumsum(one_hot, axis=0)
    local = incl[jnp.arange(ids.shape[0]), ids] - 1
    return local.astype(jnp.int32), incl[-1]


def seg_tile_local(ids: Array, segs: Array, m: int) -> Array:
    """Segmented stable in-bucket rank within one tile: an m-wide cumsum with
    a per-segment CARRY subtraction instead of an s·m-wide one-hot — O(T·m)
    regardless of the segment count (DESIGN.md §9). Relies on elements being
    segment-sorted within the tile (the input is segment-contiguous)."""
    t = ids.shape[0]
    one_hot = (ids[:, None] == jnp.arange(m)[None, :]).astype(jnp.int32)
    incl = jnp.cumsum(one_hot, axis=0)
    excl = jnp.concatenate([jnp.zeros((1, m), incl.dtype), incl[:-1]], axis=0)
    first = jnp.searchsorted(segs, segs, side="left")       # first row of my segment
    carry = excl[first, ids]                                # my bucket, before my segment
    local = incl[jnp.arange(t), ids] - carry - 1
    return local.astype(jnp.int32)


def exclusive_rows(counts: Array) -> Array:
    """Exclusive prefix along the last axis: bucket start offsets."""
    return (jnp.cumsum(counts, axis=-1) - counts).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Packed-counter local solve (DESIGN.md §12): the lane-packed jnp emulation
# of the packed KERNEL family. Same two-level subword-counter math as
# :mod:`repro.kernels.common` (it IS that module's body, re-exported here as
# a stage primitive), so the jnp backends are a bitwise oracle for the
# packed kernels exactly as `tile_local_offsets` is for the dense ones.
# ---------------------------------------------------------------------------

def packed_tile_local_offsets(ids: Array, m: int) -> Tuple[Array, Array]:
    """Packed analogue of :func:`tile_local_offsets`: (stable in-bucket
    rank, tile histogram) from k-per-word subword counters + a two-level
    subtile scan — bitwise identical, ~flat per-key work in ``m``.

    Deliberately the GATHER form (``oblivious=False``, DESIGN.md §15): XLA
    gathers are the fast host/vmap path, the vmap oracle must stay free of
    the oblivious tile-size constraints, and the bitwise identity of the two
    forms is what the kernel property tests assert."""
    from repro.kernels.common import packed_layout, packed_local_offsets

    return packed_local_offsets(
        ids, packed_layout(ids.shape[0], m), oblivious=False
    )


# ---------------------------------------------------------------------------
# Fused two-digit stage primitives (DESIGN.md §13): the jnp re-exports of the
# fused2 kernel bodies in :mod:`repro.kernels.common` — TWO radix digit
# solves per tile residency over the combined 2r-bit pair digit. Re-exported
# here (like the packed solve above) so the vmap backend executes the SAME
# body the Pallas kernels run, making the fused path bitwise-testable
# against chained single-digit passes on every backend.
# ---------------------------------------------------------------------------

def fused2_tile_counts(
    keys: Array, shift: int, bits: int,
    seg: Optional[Array] = None, num_segments: int = 1,
) -> Array:
    """Per-tile histogram over the combined pair digit (the O(T)
    scatter-add gather form — the vmap/host fast path; DESIGN.md §15)."""
    from repro.kernels.common import fused2_counts_body

    return fused2_counts_body(
        keys, shift, bits, seg=seg, num_segments=num_segments,
        oblivious=False,
    )


def fused2_tile_postscan(
    keys: Array, g_row: Array, vals: Optional[Array],
    shift: int, split: int, bits: int,
    seg: Optional[Array] = None, num_segments: int = 1,
    family: str = "onehot", sub_bits: Optional[int] = None,
):
    """Per-tile fused two-digit postscan+reorder: digit-``d`` solve, stable
    in-tile reorder, digit-``d+1`` solve on the reordered tile; returns the
    ``(keys_r, vals_r, pos_r, perm)`` contract of the fused reorder stage.
    Gather form (``oblivious=False``): the vmap oracle path, free of the
    oblivious tile constraints (DESIGN.md §15)."""
    from repro.kernels.common import fused2_postscan_body

    return fused2_postscan_body(
        keys, g_row, vals, shift, split, bits,
        seg=seg, num_segments=num_segments, family=family, sub_bits=sub_bits,
        oblivious=False,
    )


def packed_direct_solve_ids(
    keys: Array, ids: Array, m: int, values: Optional[Array]
) -> MultisplitResult:
    """Packed-family direct solve (one subproblem == whole input): the
    reference backend's lane-packed oracle, bitwise equal to
    :func:`direct_solve_ids`."""
    return _direct_solve_with(packed_tile_local_offsets, keys, ids, m, values)


# ---------------------------------------------------------------------------
# Direct solve (the reference oracle: one subproblem == whole input)
# ---------------------------------------------------------------------------

def _direct_solve_with(
    local_offsets, keys: Array, ids: Array, m: int, values: Optional[Array]
) -> MultisplitResult:
    """Direct evaluation of paper eq. (1) on precomputed bucket ids, with
    the local solve supplied by the kernel family (dense or packed)."""
    if keys.shape[0] == 0:
        zeros = jnp.zeros((m,), jnp.int32)
        return MultisplitResult(keys, values, zeros, zeros, jnp.zeros((0,), jnp.int32))
    local, hist = local_offsets(ids, m)
    starts = exclusive_rows(hist)
    perm = starts[ids] + local
    keys_out = jnp.zeros_like(keys).at[perm].set(keys)
    values_out = None
    if values is not None:
        values_out = jnp.zeros_like(values).at[perm].set(values)
    return MultisplitResult(keys_out, values_out, starts, hist.astype(jnp.int32), perm)


def direct_solve_ids(
    keys: Array, ids: Array, m: int, values: Optional[Array]
) -> MultisplitResult:
    """O(n·m) direct evaluation of paper eq. (1) on precomputed bucket ids."""
    return _direct_solve_with(tile_local_offsets, keys, ids, m, values)


def direct_solve_reference(
    keys: Array, bucket_fn: BucketSpec, values: Optional[Array]
) -> MultisplitResult:
    """O(n·m) direct evaluation of paper eq. (1): the oracle backend."""
    return direct_solve_ids(keys, bucket_fn(keys), bucket_fn.num_buckets, values)


def direct_counts(ids: Array, m: int) -> Array:
    """Histogram of bucket (or combined seg·m+bucket) ids via scatter-add:
    the counts_only form of the direct solve."""
    return jnp.zeros((m,), jnp.int32).at[ids].add(1)
