"""RadixPipeline: chained LSD digit passes on resident buffers (paper §7.1).

The PR-2 ``radix_sort`` rebuilt the full pipeline front door every pass:
re-resolve the tile, re-pad the keys to a tile multiple, re-tile, run, slice
the pad tail off — ⌈key_bits/r⌉ times. Chaining removes the round trip:

* tiles are resolved ONCE (the widest pass keys the heuristic/autotune
  cache) and every per-pass plan shares them;
* the keys/values buffers are padded ONCE with the all-ones sentinel key —
  its digit is m−1 in EVERY pass, so after each pass's stable scatter the
  pads land back at the tail and the next pass can consume the padded
  buffer as-is (ping-pong: each pass scatters into a fresh buffer that
  becomes the next pass's input; under jit XLA aliases the pair);
* each pass is one :meth:`MultisplitPlan.run_tiled` sweep — prescan, scan,
  postscan, scatter on pre-tiled buffers, no layout stage;
* the pad tail is sliced off ONCE, after the last pass.

Works for flat, batched (``batch=b``: per-row passes, one grid per pass) and
segmented (``segments=s``: the position-keyed ``seg_tiled`` buffer is
computed once — segment membership is invariant across passes) layouts, on
every registered backend. The untiled reference backend simply iterates the
direct solve (it never pads, so there is nothing to chain).
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

import jax.numpy as jnp

from repro.core.pipeline import stages as _st
from repro.core.pipeline.registry import get_backend
from repro.core.pipeline.spec import make_radix_plan
from repro.core.pipeline.tiles import resolve_kernel_family, resolve_tile

Array = jnp.ndarray


def radix_passes(radix_bits: int, key_bits: int) -> List[Tuple[int, int]]:
    """The (shift, bits) schedule of an LSD radix sort; the final pass may
    cover fewer bits (e.g. r=7 over 32-bit keys: 4 passes of 7 + one of 4)."""
    n_pass = math.ceil(key_bits / radix_bits)
    return [
        (k * radix_bits, min(radix_bits, key_bits - k * radix_bits))
        for k in range(n_pass)
    ]


# Pair width ceiling for the fused schedule: a pair's combined digit is the
# scan axis (m = 2^bits), and 16 bits (m = 65536) is where the G matrix and
# pair histograms stop paying for the saved scatter.
MAX_PAIR_BITS = 16


def radix_pass_pairs(
    radix_bits: int, key_bits: int, max_pair_bits: int = MAX_PAIR_BITS
) -> List[Tuple[int, int, Optional[int]]]:
    """The fused-pair schedule (DESIGN.md §13): adjacent single-digit passes
    of :func:`radix_passes` greedily merged into ``(shift, bits, split)``
    entries — ``split`` is the LOW digit's width inside the pair, ``None``
    marks an unpaired single pass (the trailing odd digit, or a pass whose
    pair would exceed ``max_pair_bits``).

    By LSD stability, running the pair as ONE stable pass over the combined
    ``bits``-wide digit is bitwise identical to the two chained passes it
    replaces; e.g. r=8 over 32-bit keys → ``[(0, 16, 8), (16, 16, 8)]``
    (two sweeps instead of four), r=7 → two 14-bit pairs + a single 4-bit
    trailing pass, r=5 → three 10-bit pairs + a single 2-bit pass. Uneven
    trailing pairs (last digit narrower) fuse too: r=4 over 30-bit keys ends
    in ``(24, 6, 4)``.
    """
    passes = radix_passes(radix_bits, key_bits)
    out: List[Tuple[int, int, Optional[int]]] = []
    i = 0
    while i < len(passes):
        if i + 1 < len(passes):
            (s_a, b_a), (_, b_b) = passes[i], passes[i + 1]
            if b_a + b_b <= max_pair_bits:
                out.append((s_a, b_a + b_b, b_a))
                i += 2
                continue
        shift, bits = passes[i]
        out.append((shift, bits, None))
        i += 1
    return out


class RadixPipeline:
    """A resolved ⌈key_bits/r⌉-pass radix sort over one problem shape.

    Build once (tiles resolved, one plan per digit pass), call with concrete
    arrays. Layouts follow the plan layer: flat ``(n,)`` keys, batched
    ``(b, n)`` rows (``batch=b``), or ragged segments over flat keys
    (``segments=s`` + a ``segment_starts`` call argument).
    """

    def __init__(
        self,
        n: int,
        *,
        radix_bits: int = 8,
        key_bits: int = 32,
        method: str = "bms",
        key_value: bool = False,
        backend: str = "vmap",
        tile: Optional[int] = None,
        batch: Optional[int] = None,
        segments: Optional[int] = None,
        family: Optional[str] = None,
        fuse_digits: bool = False,
        sub_bits: Optional[int] = None,
    ):
        self.n = n
        self.key_value = key_value
        self.backend = backend
        self.batch = batch
        self.segments = segments
        self.fuse_digits = fuse_digits
        self.passes = radix_passes(radix_bits, key_bits)
        s = segments or 1
        be = get_backend(backend)
        fused_stage = be.tiled and be.fuses_digits
        if fuse_digits and fused_stage:
            # Fused-pair schedule (DESIGN.md §13): each pair is ONE sweep
            # over the combined 2r-bit digit, which the tile stage decomposes
            # into two r-wide solves around an in-VMEM reorder (digit_split).
            # Backends without the capability (the untiled reference oracle:
            # no HBM scatter to save, and a pair-wide direct solve would be
            # O(n·m²)) keep the single-digit schedule — fuse_digits changes
            # execution cost only, never the result, on every backend.
            self.schedule = radix_pass_pairs(radix_bits, key_bits)
            shift0, bits0, split0 = self.schedule[0]
            m_eff = (1 << bits0) * s
            stage_m = (1 << (split0 or bits0)) * s
            # digits=2 keys the family decision separately from genuine
            # digits=1 plans of m == stage_m: a fused-pair pin must never
            # re-family a flat plan, or vice versa (regression-tested).
            self.family = resolve_kernel_family(
                n, stage_m, method, backend, family, digits=2,
                key_value=key_value, pair_m=m_eff,
            )
            self.tile = resolve_tile(
                n, m_eff, method, key_value, backend, tile, family=self.family,
                digits=2, stage_m=stage_m,
            )
            self.plans = tuple(
                make_radix_plan(
                    n, shift, bits, method=method, key_value=key_value,
                    backend=backend, tile=self.tile, batch=batch,
                    segments=segments, family=self.family, digit_split=split,
                    sub_bits=sub_bits,
                )
                for shift, bits, split in self.schedule
            )
        else:
            self.schedule = [(sh, b, None) for sh, b in self.passes]
            # ONE (tile, kernel family) for every pass, keyed by the widest
            # digit (first pass) — narrower final passes reuse them.
            m_eff = (1 << self.passes[0][1]) * s
            self.family = resolve_kernel_family(n, m_eff, method, backend, family)
            self.tile = resolve_tile(
                n, m_eff, method, key_value, backend, tile, family=self.family
            )
            self.plans = tuple(
                make_radix_plan(
                    n, shift, bits, method=method, key_value=key_value,
                    backend=backend, tile=self.tile, batch=batch, segments=segments,
                    family=self.family,
                )
                for shift, bits in self.passes
            )

    @property
    def n_passes(self) -> int:
        """Logical single-digit passes (⌈key_bits/r⌉) — schedule-invariant;
        the number of HBM sweeps actually run is :attr:`n_sweeps`."""
        return len(self.passes)

    @property
    def n_sweeps(self) -> int:
        """Executed {prescan, scan, postscan, scatter} sweeps: one per
        schedule entry — under ``fuse_digits`` a pair counts ONCE."""
        return len(self.plans)

    def __call__(
        self,
        keys: Array,
        values: Optional[Array] = None,
        segment_starts=None,
    ) -> Tuple[Array, Optional[Array]]:
        if (values is not None) != self.key_value:
            raise ValueError(
                f"radix pipeline resolved for key_value={self.key_value} but "
                f"called with values={'present' if values is not None else 'absent'}"
            )
        if not jnp.issubdtype(keys.dtype, jnp.integer):
            # reject BEFORE any pass runs: the BitfieldSpec digit of a float
            # key is a value conversion (not a bit pattern) and the float
            # pad lane has no all-ones digit — the old path corrupted it
            raise TypeError(
                f"radix sort requires integer keys, got {keys.dtype}; "
                f"reinterpret the buffer (e.g. jax.lax.bitcast_convert_type) "
                f"to uint32 first"
            )
        if self.batch is not None:
            return self._call_batched(keys, values)
        n = self.n
        if keys.shape[0] != n:
            raise ValueError(f"radix pipeline resolved for n={n}, got n={keys.shape[0]}")

        seg = None
        if self.segments is not None:
            if segment_starts is None:
                raise ValueError("segmented radix pipeline requires segment_starts")
            seg = jnp.asarray(segment_starts, jnp.int32)
            if seg.shape != (self.segments,):
                raise ValueError(
                    f"pipeline resolved for {self.segments} segments, got "
                    f"segment_starts shape {seg.shape}"
                )
        elif segment_starts is not None:
            raise ValueError("pipeline is not segmented; segment_starts not accepted")

        if n == 0:
            return keys, values

        be = get_backend(self.backend)
        if not be.tiled:
            # the oracle never tiles: iterate the direct solve per pass
            for plan in self.plans:
                res = plan(keys, values, segment_starts=seg)
                keys, values = res.keys, res.values
            return keys, values

        be.check_keys(keys)
        tile = self.tile
        # ---- pad ONCE: sentinel keys sort to the tail in every pass
        keys_pad, _ = _st.pad_to_tiles(keys, tile, self.plans[0].pad_key(keys.dtype))
        vals_pad = None
        if values is not None:
            vals_pad, _ = _st.pad_to_tiles(values, tile, 0)
        seg_tiled = None
        if seg is not None:
            # position-keyed and pass-invariant: elements never cross
            # segment boundaries, so one seg buffer drives all passes
            seg_ids = _st.segment_ids_from_starts(seg, n)
            seg_p, _ = _st.pad_to_tiles(seg_ids, tile, self.segments - 1)
            seg_tiled = seg_p.reshape(-1, tile)

        # ---- chained passes on resident buffers (reshape views are free).
        # On label-fusing backends each pass's BitfieldSpec digit is computed
        # inside the tile stage (in-register in the kernels) — zero label
        # traffic; only non-fusing backends materialize the digit strip.
        for plan in self.plans:
            keys_tiled = keys_pad.reshape(-1, tile)
            vals_tiled = vals_pad.reshape(-1, tile) if vals_pad is not None else None
            ids_tiled = None
            if not plan.label_fusion(keys_pad):
                ids_tiled = plan._host_labels(keys_pad).reshape(-1, tile)
            keys_pad, vals_pad, _, _ = plan.run_tiled(
                keys_tiled, ids_tiled, vals_tiled, seg_tiled
            )

        # ---- slice the pad tail off ONCE
        return keys_pad[:n], (vals_pad[:n] if values is not None else None)

    def _call_batched(
        self, keys: Array, values: Optional[Array]
    ) -> Tuple[Array, Optional[Array]]:
        b, n = self.batch, self.n
        if keys.shape != (b, n):
            raise ValueError(
                f"batched radix pipeline resolved for shape {(b, n)}, got {keys.shape}"
            )
        if n == 0:
            return keys, values

        be = get_backend(self.backend)
        if not be.tiled:
            for plan in self.plans:
                res = plan(keys, values)
                keys, values = res.keys, res.values
            return keys, values

        be.check_keys(keys)
        tile = self.tile
        l_b = -(-n // tile)
        n_row = l_b * tile
        keys_pad = _st.pad_rows(keys, n_row, self.plans[0].pad_key(keys.dtype))
        vals_pad = _st.pad_rows(values, n_row, 0) if values is not None else None

        for plan in self.plans:
            keys_tiled = keys_pad.reshape(b * l_b, tile)
            vals_tiled = vals_pad.reshape(b * l_b, tile) if vals_pad is not None else None
            ids_tiled = None
            if not plan.label_fusion(keys_pad):
                ids_tiled = plan._host_labels(keys_pad).reshape(b * l_b, tile)
            keys_pad, vals_pad, _, _ = plan.run_tiled(
                keys_tiled, ids_tiled, vals_tiled, rows=b
            )

        return keys_pad[:, :n], (vals_pad[:, :n] if values is not None else None)
