"""The self-tuning layer (DESIGN.md §14): autotune-on-first-miss + a
persistent on-disk cache for every tuned decision.

PR 6 proved the hand-tuned flip points rot (two were measurably stale until
re-benched by hand).  This module closes the loop:

* **Opt-in.** ``repro.ops.set_autotune(True)`` (or ``REPRO_AUTOTUNE=1`` in
  the environment) arms the layer; by default every resolver keeps its
  heuristic and this module is inert — no timing, no disk I/O.
* **On-first-miss hooks.** When armed, a miss in ``_TILE_CACHE`` /
  ``_FAMILY_CACHE`` / ``_SUB_BITS_CACHE`` / ``_FUSION_CACHE`` first consults
  the persistent cache and otherwise runs the matching timing search
  (:func:`~repro.core.pipeline.tiles.autotune_tile` for the joint
  (tile, family) grid, :func:`autotune_fused2` for the fused-pair
  (tile, family, sub_bits) grid, :func:`autotune_label_fusion` for the vmap
  materialize-vs-fuse choice), pinning AND persisting the winner.
  Coherence rule: the FAMILY miss runs the JOINT search (family + tile pinned
  together); the TILE miss searches tiles constrained to the already-pinned
  family — so one ``make_plan`` can never mix a heuristic family with a tile
  tuned for a different one.
* **Persistence.** A single JSON file (atomic replace via tempfile +
  ``os.replace``, lazily loaded, best-effort — I/O failure never breaks a
  plan) keyed by ``(host fingerprint, kind, shape-class key)``; the
  shape-class key IS the in-memory cache key, so disk and memory can never
  disagree about identity.  ``SCHEMA_VERSION`` is embedded in the file; a
  bump (or any corruption) makes old files load as empty — clean heuristic
  fallback, never an error.
* **Search scope.** Timing searches need CONCRETE shapes: they never run
  under a jax trace (the label-fusion hook defers under tracing) and never
  reenter themselves (``_IN_SEARCH``).  Hook-triggered searches measure the
  flat shape class as a proxy for segmented/batched plans of equal scan
  width; :func:`~repro.core.pipeline.tiles.autotune_tile` accepts explicit
  ``segments=`` / ``batch=`` arguments to measure those layouts directly.

The heuristic-vs-tuned gap is tracked by ``benchmarks/autotune_drift.py``
and gated in CI, so the cost model can never silently rot again.
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import tempfile
import time
from pathlib import Path
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

SCHEMA_VERSION = 1

_ENV_FLAG = "REPRO_AUTOTUNE"
_ENV_DIR = "REPRO_AUTOTUNE_DIR"


def _env_enabled() -> bool:
    return os.environ.get(_ENV_FLAG, "").strip().lower() in ("1", "true", "on")


@dataclasses.dataclass(frozen=True)
class AutotuneConfig:
    """The armed/disarmed state of the self-tuning layer.

    ``persist=None`` means "follow ``enabled``": the disk layer is active
    exactly when autotuning is — set ``persist=False`` to tune in memory
    only, or ``True`` to read/write the disk cache even while the on-miss
    searches stay off."""

    enabled: bool = False
    cache_dir: Optional[str] = None
    persist: Optional[bool] = None
    trials: int = 3
    candidates: Tuple[int, ...] = (256, 512, 1024, 2048, 4096)


_CONFIG = AutotuneConfig(enabled=_env_enabled())

# Reentrancy latch: the searches build plans/run resolvers themselves; while
# one is measuring, every hook is inert so candidate plans resolve through
# their EXPLICIT (tile, family, sub_bits) arguments only.
_IN_SEARCH = False

# Lazily-loaded snapshot of the disk file ({key_str: value}), or None when
# not yet read (drop_loaded() resets to None to simulate a fresh process).
_LOADED: Optional[dict] = None

_FINGERPRINT: Optional[str] = None


def set_autotune(enabled=None, *, cache_dir=None, persist=None, trials=None,
                 candidates=None):
    """Arm/disarm autotune-on-first-miss and configure the persistent cache.

    Every argument left ``None`` keeps its current value; returns the new
    :class:`AutotuneConfig` snapshot.  ``enabled=True`` makes cache misses
    in the (tile, family, sub_bits, label-fusion) resolvers consult the
    on-disk cache and otherwise run the timing search (DESIGN.md §14);
    ``cache_dir`` overrides where the JSON cache lives (default:
    ``$REPRO_AUTOTUNE_DIR`` or ``~/.cache/repro-multisplit``); ``trials`` /
    ``candidates`` bound the hook-triggered searches."""
    global _CONFIG, _LOADED
    kw = {}
    if enabled is not None:
        kw["enabled"] = bool(enabled)
    if cache_dir is not None:
        kw["cache_dir"] = str(cache_dir)
        _LOADED = None                      # re-read from the new location
    if persist is not None:
        kw["persist"] = bool(persist)
    if trials is not None:
        kw["trials"] = int(trials)
    if candidates is not None:
        kw["candidates"] = tuple(int(c) for c in candidates)
    _CONFIG = dataclasses.replace(_CONFIG, **kw)
    return _CONFIG


def autotune_status() -> dict:
    """Introspection: the active config, cache path, and entry count."""
    ent = _entries() if _persist_active() else {}
    return {
        "config": _CONFIG,
        "cache_path": str(cache_path()),
        "disk_entries": len(ent),
        "fingerprint": host_fingerprint(),
    }


def active() -> bool:
    """True when a miss may trigger a timing search right now."""
    return _CONFIG.enabled and not _IN_SEARCH


def armed() -> bool:
    """True when autotuning is opted in at all — even mid-search.  Cache-fill
    sites that would otherwise pin a HEURISTIC consult this to defer instead
    (an uncached heuristic answer keeps the shape measurable later)."""
    return _CONFIG.enabled


def _persist_active() -> bool:
    if _CONFIG.persist is not None:
        return _CONFIG.persist
    return _CONFIG.enabled


def host_fingerprint() -> str:
    """Stable per-host/per-accelerator identity for disk cache keys: tuned
    tiles are machine facts, not repo facts."""
    global _FINGERPRINT
    if _FINGERPRINT is None:
        try:
            dev = jax.devices()[0]
            accel = f"{dev.platform}-{dev.device_kind}"
        except Exception:                   # pragma: no cover - no backend
            accel = "unknown"
        raw = f"{platform.machine()}-{accel}"
        _FINGERPRINT = raw.replace(" ", "_").replace("|", "_")
    return _FINGERPRINT


def cache_path() -> Path:
    base = _CONFIG.cache_dir or os.environ.get(_ENV_DIR) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-multisplit"
    )
    return Path(base) / "multisplit_autotune.json"


def _key_str(kind: str, mem_key: Tuple) -> str:
    """Disk key = fingerprint | kind | the in-memory cache key, verbatim —
    disk and memory can never disagree about a shape class's identity."""
    parts = "|".join(str(x) for x in mem_key)
    return f"{host_fingerprint()}|{kind}|{parts}"


def _entries() -> dict:
    """The lazily-loaded disk snapshot. Missing / unreadable / corrupt /
    stale-version files all load as EMPTY — heuristic fallback, never an
    error (regression-tested)."""
    global _LOADED
    if _LOADED is None:
        _LOADED = {}
        try:
            with open(cache_path()) as f:
                raw = json.load(f)
            if (isinstance(raw, dict)
                    and raw.get("version") == SCHEMA_VERSION
                    and isinstance(raw.get("entries"), dict)):
                _LOADED = dict(raw["entries"])
        except (OSError, ValueError):
            pass
    return _LOADED


def _flush(entries: dict) -> None:
    """Atomic write: tempfile in the target dir + ``os.replace`` — a reader
    never observes a torn file. Best-effort: an unwritable dir silently
    degrades to memory-only tuning."""
    path = cache_path()
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), prefix=".autotune-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": SCHEMA_VERSION, "entries": entries},
                          f, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
    except OSError:
        pass


def record(kind: str, mem_key: Tuple, value) -> None:
    """Persist one tuned decision (no-op while the disk layer is off)."""
    if not _persist_active():
        return
    ent = _entries()
    ent[_key_str(kind, mem_key)] = value
    _flush(ent)


def lookup(kind: str, mem_key: Tuple):
    """Read one persisted decision, or None (disk layer off / no entry)."""
    if not _persist_active():
        return None
    return _entries().get(_key_str(kind, mem_key))


def drop_loaded() -> None:
    """Forget the in-process snapshot; the next lookup re-reads the file
    (what a fresh process would see)."""
    global _LOADED
    _LOADED = None


def clear_disk() -> None:
    """Delete the on-disk cache layer (and the loaded snapshot)."""
    global _LOADED
    _LOADED = {}
    try:
        os.remove(cache_path())
    except OSError:
        pass


_DISK_REASON = "autotuned (persistent cache hit)"


# ---------------------------------------------------------------------------
# On-first-miss hooks (called by the resolvers in tiles.py / spec.py)
# ---------------------------------------------------------------------------

def _pair_geometry(pair_m: int, stage_m: int) -> Optional[Tuple[int, int]]:
    """(bits, split) of a fused pair from the hook's (pair_m, stage_m)
    hints, or None when the widths aren't pure powers of two (segmented
    multiples): then the measured search has no derivable schedule and the
    heuristic stands."""
    if pair_m <= 0 or stage_m <= 0:
        return None
    if pair_m & (pair_m - 1) or stage_m & (stage_m - 1):
        return None
    bits = pair_m.bit_length() - 1
    split = stage_m.bit_length() - 1
    if not 0 < split < bits:
        return None
    return bits, split


def maybe_tune_family(
    n: int, m: int, method: str, backend: str, *,
    digits: int = 1, key_value: bool = False, pair_m: Optional[int] = None,
) -> None:
    """Family-cache miss: disk hit pins the family; otherwise run the JOINT
    search so the family and its tile are pinned together (never a heuristic
    family with a tuned tile for another)."""
    global _IN_SEARCH
    if not _CONFIG.enabled or _IN_SEARCH:
        return
    from repro.core.pipeline import tiles as _t
    from repro.core.pipeline.registry import get_backend

    fkey = _t._family_key(n, m, method, backend, digits)
    fam = lookup("family", fkey)
    if fam is not None:
        _t._FAMILY_CACHE[fkey] = (str(fam), _DISK_REASON)
        return
    if not get_backend(backend).tiled:
        return                              # untiled oracle: nothing to tune
    _IN_SEARCH = True
    try:
        if digits == 1:
            from repro.core.identifiers import EvenSpec

            _t.autotune_tile(
                n, EvenSpec(0.0, float(1 << 30), m), method=method,
                key_value=key_value, backend=backend,
                candidates=_CONFIG.candidates, trials=_CONFIG.trials,
            )
        else:
            geom = _pair_geometry(pair_m or 0, m)
            if geom is None:
                return
            bits, split = geom
            autotune_fused2(
                n, 0, bits, split, method=method, key_value=key_value,
                backend=backend, trials=_CONFIG.trials,
            )
    finally:
        _IN_SEARCH = False


def maybe_tune_tile(
    n: int, m: int, method: str, key_value: bool, backend: str, *,
    digits: int = 1, stage_m: Optional[int] = None,
    family: Optional[str] = None,
) -> None:
    """Tile-cache miss (family already resolved): disk hit pins the tile;
    otherwise search tiles CONSTRAINED to the resolved family."""
    global _IN_SEARCH
    if not _CONFIG.enabled or _IN_SEARCH:
        return
    from repro.core.pipeline import tiles as _t
    from repro.core.pipeline.registry import get_backend

    tkey = _t._tile_key(n, m, method, key_value, backend, digits, stage_m)
    tile = lookup("tile", tkey)
    if tile is not None:
        _t._TILE_CACHE[tkey] = int(tile)
        return
    if not get_backend(backend).tiled:
        return
    families = None if family is None else (family,)
    _IN_SEARCH = True
    try:
        if digits == 1:
            from repro.core.identifiers import EvenSpec

            _t.autotune_tile(
                n, EvenSpec(0.0, float(1 << 30), m), method=method,
                key_value=key_value, backend=backend, families=families,
                candidates=_CONFIG.candidates, trials=_CONFIG.trials,
            )
        else:
            geom = _pair_geometry(m, stage_m or 0)
            if geom is None:
                return
            bits, split = geom
            autotune_fused2(
                n, 0, bits, split, method=method, key_value=key_value,
                backend=backend, families=families, trials=_CONFIG.trials,
            )
    finally:
        _IN_SEARCH = False


def maybe_tune_sub_bits(
    n: int, m: int, method: str, key_value: bool, backend: str, stage_m: int,
) -> None:
    """Sub-bits miss: disk-only — the fused-pair joint search
    (:func:`autotune_fused2`, reached through the family/tile hooks) is what
    MEASURES sub_bits; this hook only rehydrates a persisted pin."""
    if not _CONFIG.enabled:
        return
    from repro.core.pipeline import tiles as _t

    key = (n, m, method, key_value, backend, stage_m)
    val = lookup("sub_bits", key)
    if val is not None:
        _t._SUB_BITS_CACHE[key] = int(val)


def maybe_tune_fusion(spec):
    """Label-fusion miss on the generic vmap path: disk hit, else time the
    materialize-vs-fuse pair on synthetic keys of the plan's own shape.
    Returns the pinned ``(fused?, reason)`` or None (disarmed / in-search /
    underivable). Caller guarantees keys are NOT traced."""
    global _IN_SEARCH
    if not _CONFIG.enabled or _IN_SEARCH:
        return None
    from repro.core.pipeline import spec as _sp

    key = (spec.backend, type(spec.bucket_fn).__name__, spec.m_eff)
    val = lookup("fusion", key)
    if val is not None:
        hit = (bool(val), _DISK_REASON)
        _sp._FUSION_CACHE[key] = hit
        return hit
    _IN_SEARCH = True
    try:
        hit = autotune_label_fusion(spec, trials=_CONFIG.trials)
    finally:
        _IN_SEARCH = False
    return hit


# ---------------------------------------------------------------------------
# The measured searches for the PR-7 axes (label fusion, fused-pair grid)
# ---------------------------------------------------------------------------

def _time_once(fn, args, trials: int) -> float:
    jax.block_until_ready(fn(*args))        # compile
    ts = []
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _synthetic_call(spec, seed: int = 0):
    """(jitted runner, concrete args) exercising one plan end to end."""
    import numpy as np

    rng = np.random.RandomState(seed)
    shape = (spec.batch, spec.n) if spec.batch is not None else (spec.n,)
    keys = jnp.asarray(rng.randint(0, 1 << 30, shape, dtype=np.uint32))
    args = [keys]
    if spec.key_value:
        args.append(jnp.arange(keys.size, dtype=jnp.int32).reshape(shape))
    if spec.segments is not None:
        starts = (jnp.arange(spec.segments, dtype=jnp.int32) * spec.n
                  ) // spec.segments
        run = jax.jit(lambda *a: spec(*a, segment_starts=starts).keys
                      if spec.mode == "reorder"
                      else spec(*a, segment_starts=starts).bucket_counts)
    else:
        run = jax.jit(lambda *a: spec(*a).keys if spec.mode == "reorder"
                      else spec(*a).bucket_counts)
    return run, tuple(args)


def autotune_label_fusion(spec, *, trials: int = 3, seed: int = 0):
    """Time the plan with label fusion forced ON vs OFF (by pre-pinning the
    fusion cache around two runs), pin + persist the winner with the losing
    time in the reason. Returns the pinned ``(fused?, reason)``."""
    from repro.core.pipeline import spec as _sp

    bf = spec.bucket_fn
    if bf is None or not bf.fusable:
        return None
    key = (spec.backend, type(bf).__name__, spec.m_eff)
    times = {}
    try:
        for fused in (True, False):
            _sp._FUSION_CACHE[key] = (fused, "autotune probe")
            run, args = _synthetic_call(spec, seed=seed)
            times[fused] = _time_once(run, args, trials)
    finally:
        _sp._FUSION_CACHE.pop(key, None)
    win = times[True] <= times[False]
    hit = (win, (
        f"autotuned: fused {times[True]:.3e}s vs materialized "
        f"{times[False]:.3e}s at m_eff={spec.m_eff} on {spec.backend!r}"
    ))
    _sp._FUSION_CACHE[key] = hit
    record("fusion", key, bool(win))
    return hit


def autotune_fused2(
    n: int,
    shift: int,
    bits: int,
    split: int,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    candidates: Tuple[int, ...] = (1024, 2048, 4096, 8192),
    families: Optional[Tuple[str, ...]] = None,
    sub_bits_candidates: Tuple[int, ...] = (2, 4, 8),
    trials: int = 3,
    seed: int = 0,
) -> Optional[Tuple[int, str, int]]:
    """Joint (tile, family, sub_bits) timing search over ONE fused-pair
    radix sweep (DESIGN.md §13/§14): the pair footprint axes the digits=1
    search cannot see. Pins the digits=2 tile/family entries and the
    per-shape sub-bits width, persists all three, and returns the winning
    ``(tile, family, sub_bits)`` (None when nothing ran)."""
    import numpy as np

    from repro.core.pipeline import tiles as _t
    from repro.core.pipeline.registry import get_backend
    from repro.core.pipeline.spec import make_radix_plan

    be = get_backend(backend)
    if not be.tiled or not be.fuses_digits:
        return None
    if families is None:
        families = be.families
    m2 = 1 << bits
    stage_m = 1 << split
    rng = np.random.RandomState(seed)
    keys = jnp.asarray(rng.randint(0, 1 << 31, n, dtype=np.uint32))
    values = jnp.arange(n, dtype=jnp.int32) if key_value else None
    args = (keys, values) if key_value else (keys,)
    best = None
    for tile in candidates:
        if tile > max(n, _t._MIN_TILE):
            continue
        for fam in families:
            for sb in sub_bits_candidates:
                if not 0 < sb <= bits:
                    continue
                plan = make_radix_plan(
                    n, shift, bits, method=method, key_value=key_value,
                    backend=backend, tile=tile, family=fam,
                    digit_split=split, sub_bits=sb,
                )
                run = (jax.jit(lambda k, v, p=plan: p(k, v).keys) if key_value
                       else jax.jit(lambda k, p=plan: p(k).keys))
                t = _time_once(run, args, trials)
                if best is None or t < best[0]:
                    best = (t, tile, fam, sb)
    if best is None:
        return None
    t_best, tile_b, fam_b, sb_b = best
    tkey = _t._tile_key(n, m2, method, key_value, backend, 2, stage_m)
    _t._TILE_CACHE[tkey] = tile_b
    _t._TILE_CACHE.pop(
        _t._tile_key(n, m2, method, not key_value, backend, 2, stage_m), None
    )
    fkey = _t._family_key(n, stage_m, method, backend, 2)
    _t._FAMILY_CACHE[fkey] = (fam_b, (
        f"autotuned over fused-pair grid tiles={tuple(candidates)} x "
        f"families={tuple(families)} x sub_bits={tuple(sub_bits_candidates)}: "
        f"({tile_b}, {fam_b!r}, {sb_b}) won at {t_best:.3e}s"
    ))
    sbkey = (n, m2, method, key_value, backend, stage_m)
    _t._SUB_BITS_CACHE[sbkey] = sb_b
    record("tile", tkey, tile_b)
    record("family", fkey, fam_b)
    record("sub_bits", sbkey, sb_b)
    return tile_b, fam_b, sb_b
