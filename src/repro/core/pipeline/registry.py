"""Declarative backend registry for the multisplit pipeline.

PR-1/PR-2 dispatched over {reference, vmap, pallas-interpret, pallas} with
``if backend.startswith("pallas") ... else ...`` chains inlined into every
stage method of the plan. This module replaces those chains with data: a
:class:`Backend` descriptor per execution target, registered once, looked up
by name. A backend bundles

* capability flags (``tiled``, ``fuses_radix``, ``key_itemsize``) that the
  stage graph consults instead of string-matching the backend name, and
* a :class:`StageImpl` — the backend's implementations of the three local
  pipeline stages (prescan / postscan-positions / postscan-reorder) over
  pre-tiled buffers.

Adding an execution target (e.g. a Triton port, or a compiled-CPU pallas
variant) is one ``register_backend`` call; nothing in the stage graph, the
consumers, or the chained radix pipeline changes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.pipeline import stages as _st

Array = jnp.ndarray


class StageImpl:
    """Backend implementations of the local pipeline stages.

    All methods operate on PRE-TILED ``(L, tile)`` buffers. ``spec`` is the
    resolved :class:`~repro.core.pipeline.spec.PipelineSpec`; the segmented
    layout is selected by ``seg_tiled is not None`` and the fused radix
    identifier by ``spec.radix`` (digits never exist host-side on kernel
    backends).
    """

    def prescan(self, spec, keys_tiled, ids_tiled, seg_tiled) -> Array:
        raise NotImplementedError

    def positions(self, spec, g, keys_tiled, ids_tiled, seg_tiled) -> Array:
        raise NotImplementedError

    def reorder(self, spec, g, keys_tiled, ids_tiled, vals_tiled, seg_tiled):
        raise NotImplementedError


class KernelStages(StageImpl):
    """Pallas kernel stages (interpreted on CPU or compiled for TPU).

    One fused VMEM pass per tile; segment ids ride inside the kernels
    (DESIGN.md §4, §5, §9). ``ids_tiled is None`` selects the fused-label
    path (DESIGN.md §11): bucket ids are computed IN-KERNEL from the plan's
    hashable :class:`~repro.core.identifiers.BucketSpec` (the radix digit is
    just ``BitfieldSpec``), so no label strip exists outside the kernel.
    Only :class:`~repro.core.identifiers.CallableSpec` plans feed the
    kernels precomputed ``ids_tiled``.

    ``compiled=True`` marks the Mosaic-lowering target: its ``interpret``
    flag is RESOLVED per call (DESIGN.md §15) — compiled when a TPU is
    attached, interpreted otherwise, ``REPRO_INTERPRET`` overriding both —
    so ``backend="pallas"`` means compiled-when-available while
    ``backend="pallas-interpret"`` stays the pinned debug target.
    """

    def __init__(self, compiled: bool = False):
        self.compiled = compiled

    @property
    def interpret(self) -> bool:
        from repro.kernels import ops as kops

        return kops.resolve_interpret(self.compiled)

    def prescan(self, spec, keys_tiled, ids_tiled, seg_tiled):
        from repro.kernels import ops as kops

        m, s = spec.num_buckets, spec.segments
        if spec.digit_split is not None:         # fused two-digit pair (§13)
            return kops.fused2_tile_histograms(
                keys_tiled, seg_tiled, spec=spec.bucket_fn,
                num_segments=s or 1, interpret=self.interpret,
            )
        if spec.family == "packed":              # packed-counter family (§12)
            return kops.packed_tile_histograms(
                keys_tiled if ids_tiled is None else ids_tiled, seg_tiled,
                num_buckets=m,
                spec=spec.bucket_fn if ids_tiled is None else None,
                num_segments=s or 1, interpret=self.interpret,
            )
        if ids_tiled is None:                    # fused labels in-kernel
            if seg_tiled is not None:
                return kops.seg_spec_tile_histograms(
                    keys_tiled, seg_tiled, spec.bucket_fn, s, interpret=self.interpret
                )
            return kops.spec_tile_histograms(
                keys_tiled, spec.bucket_fn, interpret=self.interpret
            )
        if seg_tiled is not None:
            return kops.seg_tile_histograms(
                ids_tiled, seg_tiled, m, s, interpret=self.interpret
            )
        return kops.tile_histograms(ids_tiled, m, interpret=self.interpret)

    def positions(self, spec, g, keys_tiled, ids_tiled, seg_tiled):
        from repro.kernels import ops as kops

        m, s = spec.num_buckets, spec.segments
        if spec.digit_split is not None:         # fused two-digit pair (§13)
            return kops.fused2_tile_positions(
                keys_tiled, g, seg_tiled, spec=spec.bucket_fn,
                split=spec.digit_split, num_segments=s or 1,
                family=spec.family, sub_bits=spec.sub_bits,
                interpret=self.interpret,
            )
        if spec.family == "packed":              # packed-counter family (§12)
            return kops.packed_tile_positions(
                keys_tiled if ids_tiled is None else ids_tiled, g, seg_tiled,
                num_buckets=m,
                spec=spec.bucket_fn if ids_tiled is None else None,
                num_segments=s or 1, interpret=self.interpret,
            )
        if ids_tiled is None:                    # fused labels in-kernel
            if seg_tiled is not None:
                return kops.seg_spec_tile_positions(
                    keys_tiled, seg_tiled, g, spec.bucket_fn, s,
                    interpret=self.interpret,
                )
            return kops.spec_tile_positions(
                keys_tiled, g, spec.bucket_fn, interpret=self.interpret
            )
        if seg_tiled is not None:
            return kops.seg_tile_positions(
                ids_tiled, seg_tiled, g, m, s, interpret=self.interpret
            )
        return kops.tile_positions(ids_tiled, g, m, interpret=self.interpret)

    def reorder(self, spec, g, keys_tiled, ids_tiled, vals_tiled, seg_tiled):
        from repro.kernels import ops as kops

        m, s = spec.num_buckets, spec.segments
        if spec.digit_split is not None:         # fused two-digit pair (§13)
            return kops.fused2_fused_postscan_reorder(
                keys_tiled, g, vals_tiled, seg_tiled, spec=spec.bucket_fn,
                split=spec.digit_split, num_segments=s or 1,
                family=spec.family, sub_bits=spec.sub_bits,
                interpret=self.interpret,
            )
        if spec.family == "packed":              # packed-counter family (§12)
            fused = ids_tiled is None
            return kops.packed_fused_postscan_reorder(
                keys_tiled if fused else ids_tiled, g,
                keys_tiled=None if fused else keys_tiled,
                values_tiled=vals_tiled, seg_tiled=seg_tiled,
                num_buckets=m, spec=spec.bucket_fn if fused else None,
                num_segments=s or 1, interpret=self.interpret,
            )
        if ids_tiled is None:                    # fused labels in-kernel
            if seg_tiled is not None:
                return kops.seg_spec_fused_postscan_reorder(
                    keys_tiled, seg_tiled, g, vals_tiled, spec.bucket_fn, s,
                    interpret=self.interpret,
                )
            return kops.spec_fused_postscan_reorder(
                keys_tiled, g, vals_tiled, spec.bucket_fn, interpret=self.interpret
            )
        if seg_tiled is not None:
            return kops.seg_fused_postscan_reorder(
                ids_tiled, seg_tiled, g, keys_tiled, vals_tiled, m, s,
                interpret=self.interpret,
            )
        return kops.fused_postscan_reorder(
            ids_tiled, g, keys_tiled, vals_tiled, m, interpret=self.interpret
        )


class VmapStages(StageImpl):
    """Tiled jnp stages: the SAME fusion as the kernels — local ranks, tile
    starts, tile destination and global destination all from one
    one-hot/cumsum evaluation per tile. Segmented tiles swap the one-hot for
    its segmented-carry form + a scatter-add histogram, keeping the pass
    O(T·m) instead of O(T·s·m) (DESIGN.md §9).

    Fused-label plans (``ids_tiled is None``, DESIGN.md §11) derive the tile
    label strip from ``spec.bucket_fn.emit`` INSIDE the vmapped stage — the
    labels are an XLA-fused intermediate of the per-tile computation, never
    a host/plan-layer array (bitwise identical to the ids path).
    """

    @staticmethod
    def _tile_ids(spec, keys_tiled, ids_tiled):
        if ids_tiled is not None:
            return ids_tiled
        return jax.vmap(spec.bucket_fn.emit)(keys_tiled)

    @staticmethod
    def _local_offsets(spec, ids, m):
        """Per-tile local solve of the plan's kernel family: dense one-hot
        cumsum, or the lane-packed two-level rank (bitwise identical)."""
        if spec.family == "packed":
            return _st.packed_tile_local_offsets(ids, m)
        return _st.tile_local_offsets(ids, m)

    @staticmethod
    def _fused2_kw(spec):
        bf = spec.bucket_fn
        return dict(shift=bf.shift, split=spec.digit_split, bits=bf.bits,
                    num_segments=spec.segments or 1, family=spec.family,
                    sub_bits=spec.sub_bits)

    def prescan(self, spec, keys_tiled, ids_tiled, seg_tiled):
        m = spec.num_buckets
        if spec.digit_split is not None:         # fused two-digit pair (§13)
            bf, s = spec.bucket_fn, spec.segments or 1
            if seg_tiled is not None:
                return jax.vmap(lambda k, sg: _st.fused2_tile_counts(
                    k, bf.shift, bf.bits, seg=sg, num_segments=s
                ))(keys_tiled, seg_tiled)
            return jax.vmap(lambda k: _st.fused2_tile_counts(
                k, bf.shift, bf.bits
            ))(keys_tiled)
        ids_tiled = self._tile_ids(spec, keys_tiled, ids_tiled)
        if seg_tiled is not None:
            m_eff = spec.m_eff
            cid = (seg_tiled * m + ids_tiled).astype(jnp.int32)
            if spec.family == "packed" and spec.mode != "counts_only":
                # same expression the packed postscan evaluates, so XLA CSEs
                # the two stages under one jit; for counts_only (no postscan
                # follows) the O(T) scatter-add below stays the cheapest form
                return jax.vmap(
                    lambda c: _st.packed_tile_local_offsets(c, m_eff)[1]
                )(cid)
            return jax.vmap(lambda c: _st.direct_counts(c, m_eff))(cid)
        if spec.mode == "counts_only":
            # histogram path: an O(T) scatter-add per tile — the O(T·m)
            # one-hot below buys nothing when no postscan follows
            return jax.vmap(lambda t: _st.direct_counts(t, m))(ids_tiled)
        return jax.vmap(lambda t: self._local_offsets(spec, t, m)[1])(ids_tiled)

    def positions(self, spec, g, keys_tiled, ids_tiled, seg_tiled):
        m = spec.num_buckets
        if spec.digit_split is not None:         # fused two-digit pair (§13)
            kw = self._fused2_kw(spec)
            if seg_tiled is not None:
                return jax.vmap(lambda k, sg, gt: _st.fused2_tile_postscan(
                    k, gt, None, seg=sg, **kw
                )[3])(keys_tiled, seg_tiled, g)
            return jax.vmap(lambda k, gt: _st.fused2_tile_postscan(
                k, gt, None, **kw
            )[3])(keys_tiled, g)
        ids_tiled = self._tile_ids(spec, keys_tiled, ids_tiled)
        if seg_tiled is not None:
            m_eff = spec.m_eff

            def one_tile_seg(ids, segs, g_tile):
                cid = (segs * m + ids).astype(jnp.int32)
                if spec.family == "packed":
                    local = _st.packed_tile_local_offsets(cid, m_eff)[0]
                else:
                    local = _st.seg_tile_local(ids, segs, m)
                return g_tile[cid] + local

            return jax.vmap(one_tile_seg)(ids_tiled, seg_tiled, g)

        def one_tile(ids, g_tile):
            local, _ = self._local_offsets(spec, ids, m)
            return g_tile[ids] + local

        return jax.vmap(one_tile)(ids_tiled, g)

    def reorder(self, spec, g, keys_tiled, ids_tiled, vals_tiled, seg_tiled):
        m, m_eff = spec.num_buckets, spec.m_eff
        if spec.digit_split is not None:         # fused two-digit pair (§13)
            kw = self._fused2_kw(spec)

            def fused2_tile(k, sg, gt, vt):
                keys_r, vals_r, pos_r, perm = _st.fused2_tile_postscan(
                    k, gt, vt, seg=sg, **kw
                )
                if vt is None:
                    return keys_r, pos_r, perm
                return keys_r, vals_r, pos_r, perm

            if vals_tiled is None:
                keys_r, pos_r, perm = jax.vmap(
                    lambda k, gt: fused2_tile(k, None, gt, None)
                )(keys_tiled, g) if seg_tiled is None else jax.vmap(
                    lambda k, sg, gt: fused2_tile(k, sg, gt, None)
                )(keys_tiled, seg_tiled, g)
                return keys_r, None, pos_r, perm
            if seg_tiled is None:
                return jax.vmap(
                    lambda k, gt, vt: fused2_tile(k, None, gt, vt)
                )(keys_tiled, g, vals_tiled)
            return jax.vmap(fused2_tile)(keys_tiled, seg_tiled, g, vals_tiled)
        ids_tiled = self._tile_ids(spec, keys_tiled, ids_tiled)

        def fused_tile(ids, segs, g_tile, keys_t, vals_t):
            if segs is None:
                local, hist = self._local_offsets(spec, ids, m)
                cid = ids
            elif spec.family == "packed":
                cid = (segs * m + ids).astype(jnp.int32)
                local, hist = _st.packed_tile_local_offsets(cid, m_eff)
            else:
                local = _st.seg_tile_local(ids, segs, m)
                cid = (segs * m + ids).astype(jnp.int32)
                hist = _st.direct_counts(cid, m_eff)
            starts = (jnp.cumsum(hist) - hist).astype(jnp.int32)
            dest = starts[cid] + local
            pos = (g_tile[cid] + local).astype(jnp.int32)
            keys_r = jnp.zeros_like(keys_t).at[dest].set(keys_t)
            pos_r = jnp.zeros_like(pos).at[dest].set(pos)
            if vals_t is None:
                return keys_r, pos_r, pos
            vals_r = jnp.zeros_like(vals_t).at[dest].set(vals_t)
            return keys_r, vals_r, pos_r, pos

        if seg_tiled is None:
            if vals_tiled is None:
                keys_r, pos_r, perm = jax.vmap(
                    lambda i, gt, kt: fused_tile(i, None, gt, kt, None)
                )(ids_tiled, g, keys_tiled)
                return keys_r, None, pos_r, perm
            keys_r, vals_r, pos_r, perm = jax.vmap(
                lambda i, gt, kt, vt: fused_tile(i, None, gt, kt, vt)
            )(ids_tiled, g, keys_tiled, vals_tiled)
            return keys_r, vals_r, pos_r, perm
        if vals_tiled is None:
            keys_r, pos_r, perm = jax.vmap(
                lambda i, sg, gt, kt: fused_tile(i, sg, gt, kt, None)
            )(ids_tiled, seg_tiled, g, keys_tiled)
            return keys_r, None, pos_r, perm
        keys_r, vals_r, pos_r, perm = jax.vmap(fused_tile)(
            ids_tiled, seg_tiled, g, keys_tiled, vals_tiled
        )
        return keys_r, vals_r, pos_r, perm


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered execution target for the pipeline stage graph.

    ``tiled=False`` marks a direct-solve backend (no tiling, no scan — the
    O(n·m) oracle); ``stages`` is then unused. ``fuses_labels`` advertises
    fused-label execution (DESIGN.md §11): any fusable
    :class:`~repro.core.identifiers.BucketSpec` is evaluated inside the
    backend's tile stage and never materialized as a plan-layer label array.
    ``fuses_radix`` is the pre-PR-4 kernel-only flag (in-KERNEL digit
    extraction), kept for introspection compat; ``fuses_digits`` advertises
    the fused TWO-digit radix stage (DESIGN.md §13: both digit solves and
    the intermediate reorder happen per tile residency, dispatched when the
    plan carries a ``digit_split``); ``key_itemsize`` restricts
    key width (pallas kernels are 32-bit-lane programs). ``families`` lists
    the kernel families (DESIGN.md §12) the backend's stages implement;
    :func:`~repro.core.pipeline.tiles.resolve_kernel_family` validates
    explicit requests against it and auto-resolves within it.
    ``tunable_axes`` names the knobs the self-tuning layer (DESIGN.md §14)
    may search for this backend: ``"tile"`` / ``"family"`` / ``"sub_bits"``
    (the fused-pair in-tile stage width) / ``"fusion"`` (the vmap
    materialize-vs-fuse label choice — kernel backends always fuse, so it
    is not an axis there). The untiled oracle has none.
    ``compiled`` advertises Mosaic lowering capability (DESIGN.md §15): the
    backend's kernel bodies are gather/scatter-free (jaxpr-linted) and its
    ``interpret`` flag resolves per call — compiled on TPU hardware,
    interpreted on hosts, ``REPRO_INTERPRET`` overriding.
    """

    name: str
    description: str
    stages: Optional[StageImpl] = None
    tiled: bool = True
    uses_kernels: bool = False
    compiled: bool = False
    fuses_radix: bool = False
    fuses_labels: bool = False
    fuses_digits: bool = False
    key_itemsize: Optional[int] = None
    families: Tuple[str, ...] = ("onehot",)
    tunable_axes: Tuple[str, ...] = ()

    def check_keys(self, keys: Array) -> None:
        if self.key_itemsize is not None and keys.dtype.itemsize != self.key_itemsize:
            raise ValueError(
                f"backend {self.name!r} requires {8 * self.key_itemsize}-bit keys "
                f"(got {keys.dtype}); use backend='vmap' for other widths"
            )


_REGISTRY: dict = {}


def register_backend(backend: Backend) -> Backend:
    if backend.name in _REGISTRY:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {backend_names()}"
        ) from None


def available_backends() -> Tuple[Backend, ...]:
    return tuple(_REGISTRY.values())


def backend_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


register_backend(Backend(
    name="reference",
    description="O(n·m) direct evaluation of paper eq. (1); the oracle",
    tiled=False,
    families=("onehot", "packed"),   # packed: the lane-packed direct oracle
))
register_backend(Backend(
    name="vmap",
    description="tiled jnp stages, fused per-tile closure",
    stages=VmapStages(),
    fuses_labels=True,
    fuses_digits=True,
    families=("onehot", "packed"),
    tunable_axes=("tile", "family", "fusion", "sub_bits"),
))
register_backend(Backend(
    name="pallas-interpret",
    description="Pallas kernels interpreted on CPU (pinned debug target)",
    stages=KernelStages(compiled=False),
    uses_kernels=True,
    fuses_radix=True,
    fuses_labels=True,
    fuses_digits=True,
    key_itemsize=4,
    families=("onehot", "packed"),
    tunable_axes=("tile", "family", "sub_bits"),
))
register_backend(Backend(
    name="pallas",
    description="Pallas kernels, Mosaic-compiled when a TPU is attached",
    stages=KernelStages(compiled=True),
    uses_kernels=True,
    compiled=True,
    fuses_radix=True,
    fuses_labels=True,
    fuses_digits=True,
    key_itemsize=4,
    families=("onehot", "packed"),
    tunable_axes=("tile", "family", "sub_bits"),
))

# Compatibility tuple: the registered names, reference first (PR-1 order).
BACKENDS = backend_names()


def capability_summary() -> dict:
    """Registry + resilience state in one introspection dict (the CI
    registry step-summary unit, DESIGN.md §17): per-backend capability
    flags plus the runtime-verification level, strict-mode state, demotion
    order, quarantined plan-class count, and the degradation/verification
    counters."""
    from repro.runtime import resilience as _rz

    backends = {}
    for b in available_backends():
        backends[b.name] = {
            "description": b.description,
            "caps": [k for k in ("tiled", "uses_kernels", "fuses_radix",
                                 "fuses_digits", "compiled") if getattr(b, k)],
            "families": list(b.families),
            "digits": [1, 2] if b.fuses_digits else [1],
            "tunable": list(b.tunable_axes),
            "demotes_to": _rz.demote(b.name),
        }
    return {
        "backends": backends,
        "resilience": {
            "verify": _rz.verify_level(),
            "strict": _rz.strict(),
            "demotion_order": list(_rz.DEMOTION_ORDER),
            "breaker_threshold": _rz.BREAKER_THRESHOLD,
            "quarantined": len(_rz.quarantine_snapshot()),
            "counters": _rz.stats(),
        },
    }


def resolve_backend(
    use_pallas: bool = False, interpret: bool = True, backend: Optional[str] = None
) -> str:
    """Map the legacy ``(use_pallas, interpret)`` knobs onto a backend name."""
    if backend is not None:
        return get_backend(backend).name
    if not use_pallas:
        return "vmap"
    return "pallas-interpret" if interpret else "pallas"
