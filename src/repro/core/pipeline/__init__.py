"""The multisplit stage-graph pipeline package (DESIGN.md §10).

The paper's model (§4.1) factors every multisplit variant into
{local prescan} → {one global scan} → {local postscan}; its applications are
partial or iterated instances of that pipeline (histogram = prescan+reduce,
radix sort = the full pipeline per digit pass). This package makes that
structure explicit:

* :mod:`~repro.core.pipeline.stages`   — layout/scan/local-solve primitives.
* :mod:`~repro.core.pipeline.registry` — the declarative backend registry
  ({reference, vmap, pallas-interpret, pallas}); each backend contributes
  capability flags + stage implementations, no if/elif dispatch.
* :mod:`~repro.core.pipeline.tiles`    — the one tile heuristic/autotune
  cache every consumer resolves through.
* :mod:`~repro.core.pipeline.spec`     — :class:`PipelineSpec` (declarative,
  incl. partial ``counts_only``/``positions_only`` modes and flat/batched/
  segmented layouts) and the executable :class:`MultisplitPlan`.
* :mod:`~repro.core.pipeline.radix`    — :class:`RadixPipeline`: chained
  digit passes on resident padded buffers (pad/tile once per sort).

``repro.core.plan`` remains a compatibility shim re-exporting this package.
"""

from repro.core.pipeline.autotune import (
    AutotuneConfig,
    autotune_fused2,
    autotune_label_fusion,
    autotune_status,
    set_autotune,
)
from repro.core.pipeline.radix import RadixPipeline, radix_pass_pairs, radix_passes
from repro.core.pipeline.registry import (
    BACKENDS,
    Backend,
    KernelStages,
    StageImpl,
    VmapStages,
    available_backends,
    backend_names,
    get_backend,
    register_backend,
    resolve_backend,
)
from repro.core.pipeline.spec import (
    MODES,
    VMAP_FUSION_MAX_BUCKETS,
    MultisplitPlan,
    PipelineSpec,
    Stage,
    fusion_decision,
    fusion_decisions,
    make_batched_plan,
    make_plan,
    make_radix_plan,
    make_segmented_plan,
    make_segmented_radix_plan,
)
from repro.core.pipeline.stages import (
    MultisplitResult,
    direct_counts,
    direct_solve_ids,
    direct_solve_reference,
    exclusive_rows,
    global_scan,
    packed_direct_solve_ids,
    packed_tile_local_offsets,
    pad_rows,
    pad_to_tiles,
    seg_tile_local,
    segment_ids_from_starts,
    tile_local_offsets,
)
from repro.core.pipeline.tiles import (
    BMS_TILE,
    FAMILIES,
    WMS_TILE,
    autotune_tile,
    clear_tile_cache,
    family_decision,
    family_decisions,
    resolve_kernel_family,
    resolve_sub_bits,
    resolve_tile,
)

__all__ = [
    "AutotuneConfig",
    "BACKENDS", "BMS_TILE", "Backend", "FAMILIES", "KernelStages", "MODES",
    "MultisplitPlan", "MultisplitResult", "PipelineSpec", "RadixPipeline",
    "Stage", "StageImpl", "VMAP_FUSION_MAX_BUCKETS", "VmapStages", "WMS_TILE",
    "autotune_fused2", "autotune_label_fusion", "autotune_status",
    "autotune_tile", "available_backends", "backend_names",
    "clear_tile_cache", "direct_counts", "direct_solve_ids",
    "direct_solve_reference", "exclusive_rows", "family_decision",
    "family_decisions", "fusion_decision", "fusion_decisions",
    "get_backend", "global_scan",
    "make_batched_plan", "make_plan", "make_radix_plan",
    "make_segmented_plan", "make_segmented_radix_plan",
    "packed_direct_solve_ids", "packed_tile_local_offsets", "pad_rows",
    "pad_to_tiles", "radix_pass_pairs", "radix_passes", "register_backend",
    "resolve_backend",
    "resolve_kernel_family", "resolve_sub_bits", "resolve_tile",
    "seg_tile_local", "segment_ids_from_starts", "set_autotune",
    "tile_local_offsets",
]
