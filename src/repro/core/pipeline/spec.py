"""PipelineSpec + the executable plan: the stage graph of the multisplit
pipeline (paper §4.1), with partial-pipeline modes.

A :class:`PipelineSpec` declares WHAT to run — problem shape, method, layout
(flat / batched / segmented), backend name, and ``mode``:

* ``mode="reorder"`` (default): the full {prescan, scan, postscan+reorder,
  scatter} pipeline — stable bucket-major output.
* ``mode="counts_only"``: {prescan, tree-reduce} — the paper's §7.3
  device-wide histogram. No scan, no scatter, no output permutation.
* ``mode="positions_only"``: {prescan, scan, postscan-positions} — the
  eq. (2) destination map WITHOUT materializing reordered keys (what MoE
  dispatch and length-bucketing consume).

:class:`MultisplitPlan` executes a spec by composing the stage
implementations of the registered backend
(:mod:`repro.core.pipeline.registry`) over the layout primitives of
:mod:`repro.core.pipeline.stages`. Its :meth:`MultisplitPlan.run_tiled` runs
one full sweep over PRE-TILED buffers — the unit the chained radix pipeline
(:mod:`repro.core.pipeline.radix`) iterates without re-padding per pass.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.identifiers import BitfieldSpec, BucketSpec, as_spec
from repro.core.pipeline import stages as _st
from repro.core.pipeline.registry import get_backend
from repro.core.pipeline.stages import MultisplitResult
from repro.core.pipeline.tiles import (
    resolve_kernel_family,
    resolve_sub_bits,
    resolve_tile,
)

Array = jnp.ndarray

MODES = ("reorder", "counts_only", "positions_only")

# Fused-label ceiling for NON-kernel (vmap-emulation) backends. The vmap
# stage implementations re-evaluate the bucket spec in EVERY tile stage
# (prescan and postscan), so wide scans pay the spec twice while the
# materialized path pays it once plus the n-sized label traffic. Measured
# host-bench crossover (BENCH_multisplit.json fused_labels sweep re-run at
# n ∈ {2^18, 2^20}, key-value flat): fused wins up to m=256 (1.03–1.06×)
# and loses from m=512 (0.95–0.97×). Kernel backends fuse in-register and
# always win; the radix BitfieldSpec is a shift-and-mask and always wins
# (measured 1.10× at m=256) — neither consults this ceiling.
VMAP_FUSION_MAX_BUCKETS = 512

# (backend, spec kind, m_eff) -> (fused?, reason) — recorded so a surprising
# execution path can be interrogated, mirroring tiles.family_decision.
_FUSION_CACHE: dict = {}


def fusion_decision(backend: str, spec_kind: str, m_eff: int):
    """(fused?, reason) recorded for one (backend, spec-kind, m_eff) shape by
    :meth:`PipelineSpec.label_fusion`, or None if that shape never decided."""
    return _FUSION_CACHE.get((backend, spec_kind, m_eff))


def fusion_decisions() -> dict:
    """Snapshot of every recorded label-fusion decision so far."""
    return dict(_FUSION_CACHE)


class Stage(NamedTuple):
    """One node of a spec's stage graph: ``name`` is the pipeline role
    (layout / prescan / scan / postscan / reduce / scatter / direct-solve),
    ``impl`` the resolved implementation tag."""

    name: str
    impl: str


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """A declarative multisplit pipeline for one problem shape.

    Frozen and hashable BY VALUE (since PR-4 ``bucket_fn`` holds a hashable
    :class:`~repro.core.identifiers.BucketSpec`, so two plans resolved from
    equal specs are equal — jit caches keyed on a plan never retrace across
    identifier instances).  Build via :func:`make_plan` /
    :func:`make_radix_plan`; the latter sets ``bucket_fn`` to the
    :class:`~repro.core.identifiers.BitfieldSpec` digit.

    Label fusion (DESIGN.md §11) is decided per call by
    :meth:`label_fusion`: on fusing backends every fusable (non-callable)
    spec is evaluated INSIDE the tile stage — in-register in the pallas
    kernels — and the n-sized label array never exists.  Only
    :class:`~repro.core.identifiers.CallableSpec` plans materialize labels,
    through the single :meth:`_host_labels` door.

    ``batch``/``segments`` (mutually exclusive) select the batched or
    segmented layout (DESIGN.md §9): ``batch=b`` expects ``(b, n)`` inputs;
    ``segments=s`` expects flat ``(n,)`` inputs plus a ``segment_starts``
    call argument of shape ``(s,)``. ``mode`` selects how much of the
    pipeline runs (module docstring / DESIGN.md §10).

    ``family`` (DESIGN.md §12) selects the KERNEL FAMILY of the local
    solve — ``"onehot"`` (dense T×m one-hot/cumsum) or ``"packed"``
    (bit-packed subword counters, two-level rank). Resolved by
    :func:`~repro.core.pipeline.tiles.resolve_kernel_family` at
    :func:`make_plan` time, so it is a concrete hashable plan field: equal
    specs keep hashing equal and jit caches keyed on a plan never retrace
    across family-equal resolutions. The two families are bitwise-identical
    (property-tested); the field changes execution cost only.

    ``digit_split`` (DESIGN.md §13) marks a FUSED TWO-DIGIT radix plan: the
    bucket spec is the combined ``2r``-bit pair
    :class:`~repro.core.identifiers.BitfieldSpec` and ``digit_split`` the
    low-digit width ``r``, so the tile stage runs the digit-``d`` solve, a
    stable in-VMEM reorder, and the digit-``d+1`` solve per residency —
    bitwise identical to the plain ``2r``-bit plan (the LSD identity:
    two chained stable passes == one stable pass by the combined digit),
    but with ``r``-wide local solves instead of an ``m²``-wide one.
    """

    n: int
    num_buckets: int
    method: str                     # dms | wms | bms
    key_value: bool
    backend: str
    tile: int
    bucket_fn: Optional[BucketSpec] = None
    batch: Optional[int] = None                    # leading (b, n) axis
    segments: Optional[int] = None                 # ragged segments over (n,)
    mode: str = "reorder"
    family: str = "onehot"
    digit_split: Optional[int] = None              # fused pair low-digit width
    # In-tile sub-digit stage width of the fused-pair LSD sweep (DESIGN.md
    # §13/§14): None = the measured global default (_FUSED2_SUB_BITS); an
    # autotuned per-shape width otherwise. Always None on digits=1 plans.
    sub_bits: Optional[int] = None

    # -- resolved properties ----------------------------------------------
    @property
    def m_eff(self) -> int:
        """Width of the one-hot/scan: ``s*m`` for segmented plans, else m."""
        return self.num_buckets * (self.segments or 1)

    @property
    def radix(self) -> Optional[Tuple[int, int]]:
        """(shift, bits) when the spec is the radix digit, else None (the
        pre-PR-4 introspection surface; the digit is just a BitfieldSpec)."""
        if isinstance(self.bucket_fn, BitfieldSpec):
            return (self.bucket_fn.shift, self.bucket_fn.bits)
        return None

    def ids_fn(self) -> BucketSpec:
        if self.bucket_fn is None:
            raise ValueError("plan has no bucket spec")
        return self.bucket_fn

    @property
    def layout(self) -> str:
        """flat | batched | segmented — the spec's input layout name."""
        if self.segments is not None:
            return "segmented"
        return "batched" if self.batch is not None else "flat"

    def plan_class(self) -> Tuple:
        """The (spec, shape, layout, mode) identity the resilience layer's
        circuit breaker and quarantine key on (DESIGN.md §17; the backend
        slot is added by the ladder per rung).  Built from the bucket
        spec's stable NAME, never an object id — quarantine entries are
        per-host facts that must mean the same thing across processes."""
        bf = self.bucket_fn
        spec_name = "ids" if bf is None else getattr(
            bf, "name", type(bf).__name__)
        shape = (self.n,) if self.batch is None else (self.batch, self.n)
        return (spec_name, shape, self.num_buckets, self.segments,
                self.method, self.key_value, self.mode)

    def fused_radix(self) -> bool:
        """True when the digit is extracted inside the kernels (no host ids).
        Pre-PR-4 introspection surface; :meth:`label_fusion` is the general
        call-time decision."""
        return self.radix is not None and get_backend(self.backend).fuses_radix

    def label_fusion(self, keys: Array) -> bool:
        """Whether THIS call computes bucket ids inside the tile stage
        (DESIGN.md §11): requires a fusable (non-callable) spec, a
        label-fusing tiled backend, and — on kernel backends — keys of the
        kernel lane width.  When False the plan materializes labels through
        :meth:`_host_labels` (the pre-PR-4 behavior, kept for CallableSpec
        and off-width keys in partial modes).

        Eligible shapes then consult a MEASURED cost decision (recorded with
        its reason — :func:`fusion_decision`): vmap-emulation backends
        re-evaluate the spec per stage, so generic fusable specs materialize
        once the scan width reaches ``VMAP_FUSION_MAX_BUCKETS``; kernel
        backends (in-register labels) and the radix
        :class:`~repro.core.identifiers.BitfieldSpec` (a shift-and-mask,
        and the chained radix pipeline's zero-label-traffic guarantee)
        always fuse."""
        bf = self.bucket_fn
        if bf is None or not bf.fusable:
            return False
        be = get_backend(self.backend)
        if not be.tiled or not be.fuses_labels:
            return False
        if be.key_itemsize is not None and keys.dtype.itemsize != be.key_itemsize:
            return False
        if self.digit_split is not None:
            return True               # fused2 kernels take the KEY strip only
        key = (self.backend, type(bf).__name__, self.m_eff)
        hit = _FUSION_CACHE.get(key)
        if hit is None:
            if isinstance(bf, BitfieldSpec):
                hit = (True, (
                    "radix BitfieldSpec: digit extraction is a shift-and-mask "
                    "(measured 1.10x over materialized at m=256) and chained "
                    "radix guarantees zero label traffic"
                ))
            elif be.uses_kernels:
                hit = (True, "kernel backend: labels are computed in-register")
            else:
                # the only MEASURED branch: when autotuning is armed
                # (DESIGN.md §14), time materialize-vs-fuse for this shape
                # instead of trusting the VMAP_FUSION_MAX_BUCKETS heuristic
                from repro.core.pipeline import autotune as _at

                traced = isinstance(keys, jax.core.Tracer)
                if not traced:
                    hit = _at.maybe_tune_fusion(self)    # pins on success
                if hit is None:
                    fuse = self.m_eff < VMAP_FUSION_MAX_BUCKETS
                    if _at.armed() and (traced or _at._IN_SEARCH):
                        # armed but under a trace (timing impossible here) or
                        # inside another axis's timing search (pinning the
                        # heuristic now would block measuring this shape
                        # later): use it WITHOUT caching — a later eager
                        # call can still measure this shape
                        return fuse
                    hit = (True, (
                        f"m_eff={self.m_eff} < {VMAP_FUSION_MAX_BUCKETS}: "
                        f"in-stage labels beat the n-sized label round trip "
                        f"at this width (measured 1.03-1.06x up to m=256)"
                    )) if fuse else (False, (
                        f"m_eff={self.m_eff} >= {VMAP_FUSION_MAX_BUCKETS}: "
                        f"vmap stages re-evaluate the spec per stage, "
                        f"measured slower than one materialized label pass "
                        f"at this width (0.95-0.97x at m=512)"
                    ))
            _FUSION_CACHE[key] = hit
        return hit[0]

    def _host_labels(self, keys: Array) -> Array:
        """THE single label-materialization door of the tiled layout stage.
        Non-callable specs on fusing backends never pass through here
        (monkeypatch-asserted in tests/test_ops_transforms.py)."""
        return self.ids_fn()(keys)

    def pad_key(self, dtype):
        """Fused-label pad sentinel: a key whose bucket is m-1 (for the
        radix BitfieldSpec: the all-ones key, digit m-1 in EVERY pass, so
        chained passes keep pads at the tail without re-padding)."""
        if self.bucket_fn is not None:
            return self.bucket_fn.pad_key(dtype)
        return (1 << 32) - 1 if dtype == jnp.uint32 else -1

    # -- introspection -----------------------------------------------------
    def stages(self) -> Tuple[str, ...]:
        """Human/test-readable pipeline description (``name:impl`` strings).

        Fused-label stages assume lane-width-compatible keys (the call-time
        fallback for off-width keys in partial modes is not shape-visible
        here); the radix BitfieldSpec keeps its historical ``radix-fused``
        spelling. Packed-family plans (DESIGN.md §12) carry a ``-packed``
        suffix on the local-solve stages."""
        be = get_backend(self.backend)
        kernel = be.uses_kernels
        if self.digit_split is not None and be.tiled:
            # fused two-digit pair plans (§13): one stage tag family, the
            # kernel-ness suffix mirrors the single-digit spellings
            eng = "kernel" if kernel else "vmap"
            fam = f"-{self.family}"
            pre = f"prescan:fused2-pair-{eng}"
            positions = f"postscan:fused2-pair-positions-{eng}{fam}"
            post = (positions if self.method == "dms"
                    else f"postscan:fused2-pair-reorder-{eng}{fam}")
            if self.mode == "counts_only":
                base = (pre, "reduce:counts")
            elif self.mode == "positions_only":
                base = (pre, "scan:global", positions)
            else:
                base = (pre, "scan:global", post, "scatter:bucket-major")
            if self.batch is not None:
                return (f"layout:batched[{self.batch}]",) + base
            if self.segments is not None:
                return (f"layout:segmented[{self.segments}]",) + base
            return base
        fusable = (self.bucket_fn is not None and self.bucket_fn.fusable
                   and be.fuses_labels)
        fused_id = kernel and fusable
        radix_id = fused_id and self.radix is not None
        fam = "-packed" if (be.tiled and self.family == "packed") else ""
        # the vmap counts_only prescan is a plain scatter-add histogram on
        # EITHER family (no local rank is ever computed), so it carries no
        # family tag; the kernel backends do run the packed hist kernel
        pre_fam = fam if (kernel or self.mode != "counts_only") else ""
        pre = ("prescan:radix-fused-kernel" if radix_id
               else "prescan:fused-label-kernel" if fused_id
               else "prescan:kernel" if kernel else "prescan:vmap") + pre_fam
        positions = ("postscan:radix-positions-kernel" if radix_id
                     else "postscan:fused-label-positions-kernel" if fused_id
                     else "postscan:positions-kernel" if kernel
                     else "postscan:positions-vmap") + fam
        if self.method == "dms":
            post = positions
        else:
            post = ("postscan:radix-fused-reorder-kernel" if radix_id
                    else "postscan:fused-label-reorder-kernel" if fused_id
                    else "postscan:fused-reorder-kernel" if kernel
                    else "postscan:fused-reorder-vmap") + fam
        if not be.tiled:
            base = ("direct-solve:reference",)
        elif self.mode == "counts_only":
            base = (pre, "reduce:counts")
        elif self.mode == "positions_only":
            base = (pre, "scan:global", positions)
        else:
            base = (pre, "scan:global", post, "scatter:bucket-major")
        if self.batch is not None:
            return (f"layout:batched[{self.batch}]",) + base
        if self.segments is not None:
            return (f"layout:segmented[{self.segments}]",) + base
        return base

    def stage_graph(self) -> Tuple[Stage, ...]:
        """The stage descriptions as structured nodes."""
        out = []
        for s in self.stages():
            name, _, impl = s.partition(":")
            out.append(Stage(name, impl))
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class MultisplitPlan(PipelineSpec):
    """An executable :class:`PipelineSpec`: call with concrete arrays."""

    # -- stage entry points (delegating to the registered backend) ---------
    def prescan(
        self, keys_tiled: Optional[Array], ids_tiled: Optional[Array],
        seg_tiled: Optional[Array] = None,
    ) -> Array:
        """Stage 1: per-tile (combined) bucket histograms -> H (L, m_eff)."""
        return get_backend(self.backend).stages.prescan(
            self, keys_tiled, ids_tiled, seg_tiled
        )

    def postscan(
        self,
        g: Array,
        keys_tiled: Array,
        ids_tiled: Optional[Array],
        vals_tiled: Optional[Array],
        seg_tiled: Optional[Array] = None,
    ) -> Tuple[Array, Optional[Array], Array, Array]:
        """Stage 3: returns (scatter_src_keys, scatter_src_vals, scatter_pos,
        perm).

        For wms/bms the sources are bucket-major within each tile and the
        positions permuted to match — ONE one-hot/cumsum evaluation per tile
        (the fused kernel / fused closure is the only postscan entry point).
        ``perm`` is the element-ordered destination map (paper eq. (2)), a
        free byproduct of the same evaluation. With ``seg_tiled`` the segment
        id rides through the evaluation as the high part of the combined
        bucket id (in-kernel on kernel backends).
        """
        impl = get_backend(self.backend).stages
        if self.method == "dms":
            pos = impl.positions(self, g, keys_tiled, ids_tiled, seg_tiled)
            return keys_tiled, vals_tiled, pos, pos
        return impl.reorder(self, g, keys_tiled, ids_tiled, vals_tiled, seg_tiled)

    # -- the resident-buffer sweep (the chained-radix building block) ------
    def run_tiled(
        self,
        keys_tiled: Array,
        ids_tiled: Optional[Array] = None,
        vals_tiled: Optional[Array] = None,
        seg_tiled: Optional[Array] = None,
        rows: Optional[int] = None,
    ) -> Tuple[Array, Optional[Array], Array, Array]:
        """One full {prescan, scan, postscan, scatter} sweep over PRE-TILED
        buffers. No padding is performed and no tail is sliced off: returns
        ``(keys_pad, vals_pad, hist, perm_tiled)`` at the full padded length
        (``(b, n_row)`` rows when ``rows=b`` — batched layout with a per-row
        scan/scatter). :class:`~repro.core.pipeline.radix.RadixPipeline`
        iterates this on resident ping-pong buffers, one call per digit
        pass."""
        hist = self.prescan(keys_tiled, ids_tiled, seg_tiled)
        if rows is None:
            g = _st.global_scan(hist)
        else:
            l_b = hist.shape[0] // rows
            g = jax.vmap(_st.global_scan)(
                hist.reshape(rows, l_b, hist.shape[-1])
            ).reshape(hist.shape)
        src_keys, src_vals, pos, perm_tiled = self.postscan(
            g, keys_tiled, ids_tiled, vals_tiled, seg_tiled
        )
        if rows is None:
            n_total = keys_tiled.size
            scatter_pos = pos.reshape(-1)
            keys_pad = (
                jnp.zeros((n_total,), keys_tiled.dtype)
                .at[scatter_pos].set(src_keys.reshape(-1))
            )
            vals_pad = None
            if vals_tiled is not None:
                vals_pad = (
                    jnp.zeros((n_total,), vals_tiled.dtype)
                    .at[scatter_pos].set(src_vals.reshape(-1))
                )
            return keys_pad, vals_pad, hist, perm_tiled
        n_row = keys_tiled.size // rows
        pos_rows = pos.reshape(rows, n_row)
        scat = lambda p, src: jnp.zeros((n_row,), src.dtype).at[p].set(src)
        keys_pad = jax.vmap(scat)(pos_rows, src_keys.reshape(rows, n_row))
        vals_pad = None
        if vals_tiled is not None:
            vals_pad = jax.vmap(scat)(pos_rows, src_vals.reshape(rows, n_row))
        return keys_pad, vals_pad, hist, perm_tiled

    # -- layout helpers ----------------------------------------------------
    def _empty_result(self, keys: Array, values: Optional[Array]) -> MultisplitResult:
        """n == 0: every output is empty/zero in the layout's shapes."""
        m = self.num_buckets
        if self.batch is not None:
            shape_cm = (self.batch, m)
            perm = jnp.zeros((self.batch, 0), jnp.int32)
        elif self.segments is not None:
            shape_cm = (self.segments, m)
            perm = jnp.zeros((0,), jnp.int32)
        else:
            shape_cm = (m,)
            perm = jnp.zeros((0,), jnp.int32)
        zeros = jnp.zeros(shape_cm, jnp.int32)
        if self.mode == "counts_only":
            return MultisplitResult(None, None, zeros, zeros, None)
        if self.mode == "positions_only":
            return MultisplitResult(None, None, zeros, zeros, perm)
        return MultisplitResult(keys, values, zeros, zeros, perm)

    def _check_key_width(self, keys: Array) -> None:
        """Kernel backends are 32-bit-lane programs; keys unconditionally
        enter kernels only when the pipeline reorders them. In the partial
        modes, off-width keys simply disable label fusion (labels
        materialize host-side and kernels see nothing but int32 ids)."""
        if self.mode == "reorder":
            get_backend(self.backend).check_keys(keys)

    # -- batched driver ----------------------------------------------------
    def _call_batched(self, keys: Array, values: Optional[Array]) -> MultisplitResult:
        b, n, m = self.batch, self.n, self.num_buckets
        if keys.shape != (b, n):
            raise ValueError(f"batched plan resolved for shape {(b, n)}, got {keys.shape}")
        if values is not None and values.shape != (b, n):
            raise ValueError(
                f"batched plans require values of shape {(b, n)}, got {values.shape}"
            )
        if n == 0:
            return self._empty_result(keys, values)

        be = get_backend(self.backend)
        if not be.tiled:
            ids_fn = self.ids_fn()
            if self.mode == "counts_only":
                counts = jax.vmap(lambda k: _st.direct_counts(ids_fn(k), m))(keys)
                return MultisplitResult(
                    None, None, _st.exclusive_rows(counts), counts, None
                )
            direct = self._direct_solve_ids
            solve = lambda k, v: direct(k, ids_fn(k), m, v)
            if values is None:
                res = jax.vmap(lambda k: solve(k, None))(keys)
            else:
                res = jax.vmap(solve)(keys, values)
            if self.mode == "positions_only":
                return MultisplitResult(
                    None, None, res.bucket_starts, res.bucket_counts, res.permutation
                )
            return res

        self._check_key_width(keys)
        fused = self.label_fusion(keys)
        tile = self.tile
        l_b = -(-n // tile)                       # tiles per batch row
        n_row = l_b * tile

        # Per-row tiling: each tile belongs to exactly ONE batch row, so a
        # single kernel grid of b*l_b programs covers the whole batch.
        if fused:
            keys_tiled = _st.pad_rows(
                keys, n_row, self.pad_key(keys.dtype)
            ).reshape(b * l_b, tile)
            ids_tiled = None
        else:
            ids = self._host_labels(keys)
            ids_tiled = _st.pad_rows(ids, n_row, m - 1).reshape(b * l_b, tile)
            if self.mode != "reorder":
                keys_tiled = None            # partial modes consume only ids
            else:
                keys_tiled = _st.pad_rows(keys, n_row, 0).reshape(b * l_b, tile)
        vals_tiled = None
        if values is not None:
            vals_tiled = _st.pad_rows(values, n_row, 0).reshape(b * l_b, tile)

        if self.mode == "counts_only":
            hist = self.prescan(keys_tiled, ids_tiled)
            counts = hist.reshape(b, l_b, m).sum(axis=1).astype(jnp.int32)
            counts = counts.at[:, m - 1].add(n - n_row)          # drop pad sentinels
            return MultisplitResult(None, None, _st.exclusive_rows(counts), counts, None)

        if self.mode == "positions_only":
            hist = self.prescan(keys_tiled, ids_tiled)
            g = jax.vmap(_st.global_scan)(hist.reshape(b, l_b, m)).reshape(b * l_b, m)
            pos = get_backend(self.backend).stages.positions(
                self, g, keys_tiled, ids_tiled, None
            )
            counts = hist.reshape(b, l_b, m).sum(axis=1).astype(jnp.int32)
            counts = counts.at[:, m - 1].add(n - n_row)
            return MultisplitResult(
                None, None, _st.exclusive_rows(counts), counts,
                pos.reshape(b, n_row)[:, :n],
            )

        keys_rows, vals_rows, hist, perm_tiled = self.run_tiled(
            keys_tiled, ids_tiled, vals_tiled, rows=b
        )
        keys_out = keys_rows[:, :n]
        values_out = vals_rows[:, :n] if values is not None else None
        counts = hist.reshape(b, l_b, m).sum(axis=1).astype(jnp.int32)
        counts = counts.at[:, m - 1].add(n - n_row)              # drop pad sentinels
        return MultisplitResult(
            keys_out, values_out, _st.exclusive_rows(counts), counts,
            perm_tiled.reshape(b, n_row)[:, :n],
        )

    # -- full pipeline -----------------------------------------------------
    def __call__(
        self,
        keys: Array,
        values: Optional[Array] = None,
        segment_starts: Optional[Array] = None,
    ) -> MultisplitResult:
        if (values is not None) != self.key_value:
            raise ValueError(
                f"plan resolved for key_value={self.key_value} but called with "
                f"values={'present' if values is not None else 'absent'}"
            )
        if self.segments is None and segment_starts is not None:
            raise ValueError("plan is not segmented; segment_starts not accepted")

        if self.batch is not None:
            return self._call_batched(keys, values)

        if keys.shape[0] != self.n:
            raise ValueError(f"plan resolved for n={self.n}, got n={keys.shape[0]}")
        m, s = self.num_buckets, self.segments
        m_eff = self.m_eff

        seg_ids = None
        if s is not None:
            if segment_starts is None:
                raise ValueError("segmented plan requires segment_starts")
            segment_starts = jnp.asarray(segment_starts, jnp.int32)
            if segment_starts.shape != (s,):
                raise ValueError(
                    f"plan resolved for {s} segments, got segment_starts shape "
                    f"{segment_starts.shape}"
                )
            seg_ids = _st.segment_ids_from_starts(segment_starts, self.n)

        if self.n == 0:
            return self._empty_result(keys, values)

        be = get_backend(self.backend)
        if not be.tiled:
            return self._call_direct(keys, values, seg_ids, segment_starts)

        self._check_key_width(keys)
        fused = self.label_fusion(keys)
        n = self.n

        # ---- layout stage. Pads ride in (segment s-1,) bucket m-1 at the
        # very tail, so they land after every real element and are sliced off
        # below. Fused-label plans pad with the spec's pad key (bucket m-1 by
        # construction; for the radix digit: the all-ones key, digit m-1 in
        # EVERY pass).
        if fused:
            keys_p, _ = _st.pad_to_tiles(keys, self.tile, self.pad_key(keys.dtype))
            keys_tiled = keys_p.reshape(-1, self.tile)
            ids_tiled = None
        else:
            ids = self._host_labels(keys)
            ids_p, _ = _st.pad_to_tiles(ids, self.tile, m - 1)
            ids_tiled = ids_p.reshape(-1, self.tile)
            if self.mode != "reorder":
                keys_tiled = None            # partial modes consume only ids
            else:
                keys_p, _ = _st.pad_to_tiles(keys, self.tile, 0)
                keys_tiled = keys_p.reshape(-1, self.tile)
        seg_tiled = None
        if s is not None:
            seg_p, _ = _st.pad_to_tiles(seg_ids, self.tile, s - 1)
            seg_tiled = seg_p.reshape(-1, self.tile)
        n_total = keys_tiled.size if keys_tiled is not None else ids_tiled.size
        vals_tiled = None
        if values is not None:
            vals_p, _ = _st.pad_to_tiles(values, self.tile, 0)
            vals_tiled = vals_p.reshape(-1, self.tile)

        def finalize_counts(hist):
            counts = hist.sum(axis=0).astype(jnp.int32)
            return counts.at[m_eff - 1].add(n - n_total)         # drop pad sentinels

        # ---- partial pipelines: counts_only / positions_only
        if self.mode == "counts_only":
            counts = finalize_counts(self.prescan(keys_tiled, ids_tiled, seg_tiled))
            if s is not None:
                counts = counts.reshape(s, m)
            return MultisplitResult(None, None, _st.exclusive_rows(counts), counts, None)

        if self.mode == "positions_only":
            hist = self.prescan(keys_tiled, ids_tiled, seg_tiled)
            g = _st.global_scan(hist)
            pos = be.stages.positions(self, g, keys_tiled, ids_tiled, seg_tiled)
            counts = finalize_counts(hist)
            perm = pos.reshape(-1)[:n].astype(jnp.int32)
            if s is not None:
                counts = counts.reshape(s, m)
                perm = perm - segment_starts[seg_ids]            # segment-LOCAL
            return MultisplitResult(None, None, _st.exclusive_rows(counts), counts, perm)

        # ---- full pipeline: the resident-buffer sweep + tail slice.
        # For segmented plans the combined (seg, bucket)-major order IS the
        # segment-concatenated per-segment bucket-major order, so the same
        # flat scatter lands every segment in its input span.
        keys_pad, vals_pad, hist, perm_tiled = self.run_tiled(
            keys_tiled, ids_tiled, vals_tiled, seg_tiled
        )
        keys_out = keys_pad[:n]
        values_out = vals_pad[:n] if values is not None else None
        counts = finalize_counts(hist)
        perm = perm_tiled.reshape(-1)[:n]
        if s is not None:
            counts = counts.reshape(s, m)
            return MultisplitResult(
                keys_out, values_out, _st.exclusive_rows(counts), counts,
                perm - segment_starts[seg_ids],                  # segment-LOCAL
            )
        return MultisplitResult(
            keys_out, values_out, _st.exclusive_rows(counts), counts, perm
        )

    # -- direct-solve driver (the untiled oracle backend) ------------------
    @property
    def _direct_solve_ids(self):
        """The family's direct solve: dense one-hot, or the lane-packed
        oracle (bitwise identical, DESIGN.md §12)."""
        if self.family == "packed":
            return _st.packed_direct_solve_ids
        return _st.direct_solve_ids

    def _call_direct(
        self, keys, values, seg_ids, segment_starts
    ) -> MultisplitResult:
        m, s = self.num_buckets, self.segments
        ids = self.ids_fn()(keys)
        if s is None:
            if self.mode == "counts_only":
                counts = _st.direct_counts(ids, m)
                return MultisplitResult(
                    None, None, _st.exclusive_rows(counts), counts, None
                )
            res = self._direct_solve_ids(keys, ids, m, values)
            if self.mode == "positions_only":
                return MultisplitResult(
                    None, None, res.bucket_starts, res.bucket_counts, res.permutation
                )
            return res
        cid = (seg_ids * m + ids).astype(jnp.int32)
        if self.mode == "counts_only":
            counts = _st.direct_counts(cid, self.m_eff).reshape(s, m)
            return MultisplitResult(None, None, _st.exclusive_rows(counts), counts, None)
        res = self._direct_solve_ids(keys, cid, self.m_eff, values)
        counts = res.bucket_counts.reshape(s, m)
        perm = res.permutation - segment_starts[seg_ids]
        if self.mode == "positions_only":
            return MultisplitResult(None, None, _st.exclusive_rows(counts), counts, perm)
        return MultisplitResult(
            res.keys, res.values, _st.exclusive_rows(counts), counts, perm
        )


def _validate_layout(batch: Optional[int], segments: Optional[int]) -> None:
    if batch is not None and segments is not None:
        raise ValueError("batch and segments are mutually exclusive plan layouts")
    if batch is not None and batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if segments is not None and segments < 1:
        raise ValueError(f"segments must be >= 1, got {segments}")


def _validate_common(method: str, backend: str, mode: str, key_value: bool) -> None:
    if method not in ("dms", "wms", "bms"):
        raise ValueError(f"unknown multisplit method {method!r}")
    get_backend(backend)                  # raises ValueError on unknown names
    if mode not in MODES:
        raise ValueError(f"unknown pipeline mode {mode!r}; expected one of {MODES}")
    if mode != "reorder" and key_value:
        raise ValueError(
            f"mode={mode!r} never touches values; resolve with key_value=False"
        )


def _validate_digit_split(
    digit_split: Optional[int], bucket_fn, backend: str
) -> None:
    if digit_split is None:
        return
    from repro.core.pipeline.registry import get_backend as _gb

    be = _gb(backend)
    if not be.tiled or not be.fuses_digits:
        raise ValueError(
            f"backend {backend!r} does not fuse digit pairs (fuses_digits="
            f"False); run the pair as a plain combined-digit plan instead"
        )
    if not isinstance(bucket_fn, BitfieldSpec):
        raise ValueError(
            "digit_split requires the combined-pair BitfieldSpec bucket_fn "
            f"(got {type(bucket_fn).__name__})"
        )
    if not 0 < digit_split < bucket_fn.bits:
        raise ValueError(
            f"digit_split must split the pair strictly (0 < split < bits); "
            f"got split={digit_split}, bits={bucket_fn.bits}"
        )


def make_plan(
    n: int,
    num_buckets: int,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    tile: Optional[int] = None,
    bucket_fn: Optional[BucketSpec] = None,
    batch: Optional[int] = None,
    segments: Optional[int] = None,
    mode: str = "reorder",
    family: Optional[str] = None,
    digit_split: Optional[int] = None,
    sub_bits: Optional[int] = None,
) -> MultisplitPlan:
    """Resolve (n, m, method, key-value-ness, backend, mode) into a staged
    plan.

    ``bucket_fn`` is a :class:`~repro.core.identifiers.BucketSpec` (the
    :class:`~repro.core.identifiers.BucketIdentifier` shim is one); fusable
    specs run label-fused on fusing backends (DESIGN.md §11).  ``batch=b``
    resolves a batched plan over ``(b, n)`` inputs; ``segments=s`` a
    segmented plan over flat ``(n,)`` inputs with an ``(s,)``
    ``segment_starts`` call argument (mutually exclusive). ``mode`` selects a
    partial pipeline (``counts_only`` / ``positions_only``) or the full
    reorder (module docstring). ``family`` pins the kernel family
    (``"onehot"`` / ``"packed"``, DESIGN.md §12); ``None`` auto-resolves it
    per shape through the cached heuristic/autotune decision."""
    _validate_common(method, backend, mode, key_value)
    _validate_layout(batch, segments)
    if bucket_fn is not None:
        bucket_fn = as_spec(bucket_fn)
    _validate_digit_split(digit_split, bucket_fn, backend)
    m_eff = num_buckets * (segments or 1)
    digits = 1 if digit_split is None else 2
    # the fused-pair local solves are digit_split-wide, not m-wide: family
    # (and tile VMEM cost) follow the STAGE width, the scan width stays m_eff
    fam_m = m_eff if digit_split is None else (1 << digit_split) * (segments or 1)
    resolved_family = resolve_kernel_family(
        n, fam_m, method, backend, family, digits=digits, key_value=key_value,
        pair_m=None if digit_split is None else m_eff,
    )
    resolved_tile = resolve_tile(
        n, m_eff, method, key_value, backend, tile, family=resolved_family,
        digits=digits, stage_m=None if digit_split is None else fam_m,
    )
    resolved_sub = None
    if digit_split is not None:
        resolved_sub = resolve_sub_bits(
            n, m_eff, method, key_value, backend, fam_m, requested=sub_bits
        )
    return MultisplitPlan(
        n=n, num_buckets=num_buckets, method=method, key_value=key_value,
        backend=backend, tile=resolved_tile, bucket_fn=bucket_fn,
        batch=batch, segments=segments, mode=mode, family=resolved_family,
        digit_split=digit_split, sub_bits=resolved_sub,
    )


def make_radix_plan(
    n: int,
    shift: int,
    bits: int,
    *,
    method: str = "bms",
    key_value: bool = False,
    backend: str = "vmap",
    tile: Optional[int] = None,
    batch: Optional[int] = None,
    segments: Optional[int] = None,
    mode: str = "reorder",
    family: Optional[str] = None,
    digit_split: Optional[int] = None,
    sub_bits: Optional[int] = None,
) -> MultisplitPlan:
    """A plan whose bucket spec is the radix digit
    :class:`~repro.core.identifiers.BitfieldSpec`(shift, bits) — label-fused
    into the tile stage on fusing backends (in-register in the kernels; no
    label array anywhere).  ``digit_split=r`` marks ``bits`` as a fused
    TWO-digit pair (low digit ``r`` bits wide, DESIGN.md §13); ``sub_bits``
    pins the pair's in-tile sub-digit stage width (None auto-resolves it,
    DESIGN.md §14)."""
    return make_plan(
        n, 1 << bits, method=method, key_value=key_value, backend=backend,
        tile=tile, bucket_fn=BitfieldSpec(shift, bits), batch=batch,
        segments=segments, mode=mode, family=family, digit_split=digit_split,
        sub_bits=sub_bits,
    )


def make_batched_plan(batch: int, n: int, num_buckets: int, **kw) -> MultisplitPlan:
    """Batched plan over ``(batch, n)`` inputs: one launch for all rows."""
    return make_plan(n, num_buckets, batch=batch, **kw)


def make_segmented_plan(n: int, num_segments: int, num_buckets: int, **kw) -> MultisplitPlan:
    """Segmented plan over flat ``(n,)`` inputs with ``num_segments`` ragged
    segments (call with ``segment_starts=``): one launch for all segments."""
    return make_plan(n, num_buckets, segments=num_segments, **kw)


def make_segmented_radix_plan(
    n: int, num_segments: int, shift: int, bits: int, **kw
) -> MultisplitPlan:
    """Segmented radix plan: one fused digit pass over all segments."""
    return make_radix_plan(n, shift, bits, segments=num_segments, **kw)
