"""Bucket specs (paper §3.1, §6 "Bucket identification") — declarative,
hashable, transform-native.

The paper's defining feature is that *the function that categorizes an
element into a bucket is provided by the programmer*.  PR-1..3 carried that
function as an opaque closure (``BucketIdentifier.fn``), which every backend
had to evaluate into a full n-sized label array before the pipeline started
— the exact "more expensive data movements" overhead the paper charges the
sort-based baselines with (§3.4) — and which defeated jit caching (closures
hash by identity, so every identifier instance retraced).

This module replaces the closure-first identifier with a hierarchy of
declarative :class:`BucketSpec` dataclasses:

* **hashable / comparable by value** — two ``delta_buckets(32)`` calls
  produce EQUAL specs, so jit caches, the kernel-wrapper jit cache and the
  ``repro.ops`` op cache all hit instead of retracing;
* **pytree-registered as static leaves** — a spec passed through ``jit`` /
  ``vmap`` / ``grad`` rides in the treedef (no tracer, no retrace, no
  batching axis), which is what makes the ``repro.ops`` transform rules
  possible;
* **fusable** — every non-callable spec exposes :meth:`BucketSpec.emit`
  written in plain vectorized jnp, which the tile kernels evaluate
  *in-register inside the kernel* (``kernels/multisplit_tile.py``); the
  n-sized label array never exists for these specs.  The paper's radix digit
  is just :class:`BitfieldSpec`, its splitter buckets :class:`RangeSpec`
  (cf. GPU sample sort, arXiv:0909.5649).

:class:`CallableSpec` remains the escape hatch for arbitrary user functions
(the paper's "prime vs composite"); it is the only spec backends must
materialize labels for.  :class:`BucketIdentifier` survives as a deprecation
shim (an alias subclass of :class:`CallableSpec`) so pre-PR-4 imports and
constructions keep working unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


def _register_static(cls):
    """Register a frozen spec dataclass as a LEAFLESS pytree: the whole spec
    rides in the treedef (hashed/compared by value), so jit keys on it like a
    static argument and vmap/grad pass it through untouched."""
    jax.tree_util.register_pytree_node(cls, lambda s: ((), s), lambda s, _: s)
    return cls


class BucketSpec:
    """Base class: a declarative bucket identifier ``emit(keys) -> ids``.

    Concrete specs are frozen dataclasses (value-hashable).  ``fusable``
    marks specs whose :meth:`emit` is plain vectorized jnp safe to trace
    inside a tile kernel; :meth:`pad_key` returns a key value whose bucket is
    ``num_buckets - 1`` (layout pads ride in the last bucket and are sliced
    off the output tail).
    """

    fusable: bool = True
    # concrete specs provide ``num_buckets`` (field or property) and ``name``
    # (field or property); the base deliberately declares neither so frozen
    # dataclass subclasses can use plain fields.

    def emit(self, keys: Array) -> Array:
        """int32 bucket ids in ``[0, num_buckets)``; shape-preserving."""
        raise NotImplementedError

    def emit_in_kernel(self, keys: Array) -> Array:
        """:meth:`emit` as traced INSIDE a tile kernel.  Defaults to
        ``emit``; specs whose host-side form uses ops a pallas kernel cannot
        lower (or captured constant arrays) override this with an
        equivalent vector-op form."""
        return self.emit(keys)

    def pad_key(self, dtype):
        """A key value that lands in bucket ``num_buckets - 1``: the dtype
        maximum (every spec here is monotone and clamps its top bucket)."""
        dtype = jnp.dtype(dtype)
        if jnp.issubdtype(dtype, jnp.unsignedinteger):
            return (1 << (8 * dtype.itemsize)) - 1
        if jnp.issubdtype(dtype, jnp.floating):
            return float(jnp.finfo(dtype).max)
        return int(jnp.iinfo(dtype).max)

    # identifiers have always been callable (``bf(keys)``); keep it.
    def __call__(self, keys: Array) -> Array:
        return self.emit(keys)


@_register_static
@dataclasses.dataclass(frozen=True)
class DeltaSpec(BucketSpec):
    """Equal-width buckets over the key domain: ``f(u) = u // delta``
    (paper §6), clamped into range so any key ≥ key_max lands in the last
    bucket (this also makes the all-ones pad key safe)."""

    num_buckets: int
    key_max: int = 2**30

    @property
    def delta(self) -> int:
        return max(1, self.key_max // self.num_buckets)

    def emit(self, keys: Array) -> Array:
        ids = keys.astype(jnp.uint32) // jnp.uint32(self.delta)
        return jnp.minimum(ids, self.num_buckets - 1).astype(jnp.int32)

    @property
    def name(self) -> str:
        return f"delta{self.num_buckets}"


@_register_static
@dataclasses.dataclass(frozen=True)
class IdentitySpec(BucketSpec):
    """Keys are already bucket ids: ``f(u) = u`` (paper §7.1)."""

    num_buckets: int

    def emit(self, keys: Array) -> Array:
        return keys.astype(jnp.int32)

    def pad_key(self, dtype):
        return self.num_buckets - 1                # all-ones would leave range

    @property
    def name(self) -> str:
        return f"identity{self.num_buckets}"


@_register_static
@dataclasses.dataclass(frozen=True)
class BitfieldSpec(BucketSpec):
    """``f(u) = (u >> shift) & (2^bits - 1)`` — one LSD radix-sort digit
    (paper §7.1).  The chained :class:`~repro.core.pipeline.radix.
    RadixPipeline` is one BitfieldSpec plan per pass; the all-ones pad key
    has digit ``m - 1`` in EVERY pass, which is what lets the chained sort
    pad once."""

    shift: int
    bits: int

    @property
    def num_buckets(self) -> int:
        return 1 << self.bits

    def emit(self, keys: Array) -> Array:
        self._check_integer(keys.dtype)
        u = keys.astype(jnp.uint32)
        mask = jnp.uint32((1 << self.bits) - 1)
        return ((u >> jnp.uint32(self.shift)) & mask).astype(jnp.int32)

    @staticmethod
    def _check_integer(dtype) -> None:
        """Digits are BIT FIELDS of the key word; ``astype`` on a float key
        is a VALUE conversion, and the float pad lane has no all-ones digit
        pattern (``pad_key`` used to return ``-1``, i.e. ``-1.0``, which is
        NOT digit m-1 — it silently corrupted the pad lane)."""
        if not jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
            raise TypeError(
                f"radix digit buckets (BitfieldSpec) require integer keys, got "
                f"{jnp.dtype(dtype)}; bitfield digits of float keys are value "
                f"conversions, not bit patterns — reinterpret the buffer "
                f"(e.g. jax.lax.bitcast_convert_type) to uint32 first"
            )

    def pad_key(self, dtype):
        """The ALL-ONES bit pattern (not the signed max): its digit is m-1
        in every pass, the chained-radix pad invariant.  Raises
        :class:`TypeError` for float dtypes (see :meth:`_check_integer`)."""
        dtype = jnp.dtype(dtype)
        self._check_integer(dtype)
        if jnp.issubdtype(dtype, jnp.unsignedinteger):
            return (1 << (8 * dtype.itemsize)) - 1
        return -1

    @property
    def name(self) -> str:
        return f"radix[{self.shift}:{self.shift + self.bits}]"


@_register_static
@dataclasses.dataclass(frozen=True)
class RangeSpec(BucketSpec):
    """Splitter buckets (paper §7.3 "Range Histogram"; the sample-sort
    bucket function of arXiv:0909.5649): key u lands in bucket j s.t.
    ``splitters[j-1] <= u < splitters[j]``, ``m = len(splitters) + 1``.

    Splitters are canonicalized to a SORTED tuple at construction (unsorted
    splitters silently produced wrong buckets pre-PR-4) and compared in the
    KEY dtype at emit time, so uint32 keys above the last splitter — up to
    the dtype max — never wrap through a signed promotion.
    """

    splitters: Tuple

    def __post_init__(self):
        sp = np.asarray(self.splitters)
        if sp.ndim != 1:
            raise ValueError(f"splitters must be 1-D, got shape {sp.shape}")
        if np.isnan(sp.astype(np.float64)).any():
            raise ValueError("splitters must not contain NaN")
        object.__setattr__(self, "splitters", tuple(np.sort(sp).tolist()))

    @property
    def num_buckets(self) -> int:
        return len(self.splitters) + 1

    def _compare_plane(self, key_dtype):
        """(compare_dtype, splitter_values): integer keys with integral
        splitters compare in the KEY dtype (no promotion, so uint32 keys up
        to the dtype max never wrap through a signed intermediate; splitters
        outside the key dtype's range are REJECTED — they would make the
        last bucket unreachable, breaking the pad-in-bucket-m-1 layout
        invariant); anything involving fractional splitters or float keys
        compares in float."""
        integral = all(float(s) == int(s) for s in self.splitters)
        if jnp.issubdtype(key_dtype, jnp.integer) and integral:
            info = jnp.iinfo(key_dtype)
            for s in self.splitters:
                if not info.min <= int(s) <= info.max:
                    raise ValueError(
                        f"splitter {s} is out of range for {np.dtype(key_dtype)} "
                        f"keys [{info.min}, {info.max}]"
                    )
            return np.dtype(key_dtype), [int(s) for s in self.splitters]
        plane = key_dtype if jnp.issubdtype(key_dtype, jnp.floating) else jnp.float32
        return np.dtype(plane), [float(s) for s in self.splitters]

    def emit(self, keys: Array) -> Array:
        if not self.splitters:
            return jnp.zeros(keys.shape, jnp.int32)
        # O(n log s) binary search in the compare plane (splitters are
        # canonically sorted); side="right" = count of splitters <= u.
        plane, vals = self._compare_plane(keys.dtype)
        return jnp.searchsorted(
            jnp.asarray(vals, plane), keys.astype(plane), side="right"
        ).astype(jnp.int32)

    def emit_in_kernel(self, keys: Array) -> Array:
        if not self.splitters:
            return jnp.zeros(keys.shape, jnp.int32)
        # In-kernel form of emit.  A pallas kernel can neither lower
        # searchsorted nor capture a constant splitter ARRAY — only scalars
        # fold — so each splitter enters as one PLANE-dtype scalar compare
        # (a raw Python int would weak-type to int32 and overflow for
        # splitters above 2^31).  The bucket id is the POPCOUNT of those
        # compares; summing them pairwise as a balanced binary tree keeps
        # the dependency depth at O(log s) vector adds (vs the O(s) serial
        # chain of ``_emit_chain``), which is what unblocks large splitter
        # counts (s = 255+, the sample-sort regime) — a per-element binary
        # SEARCH over the splitter domain is impossible without a gather or
        # a captured array, and would cost O(s) selects per probe anyway.
        plane, vals = self._compare_plane(keys.dtype)
        kc = keys.astype(plane)
        parts = [
            (kc >= np.asarray(s, plane)[()]).astype(jnp.int32) for s in vals
        ]
        while len(parts) > 1:
            nxt = [a + b for a, b in zip(parts[0::2], parts[1::2])]
            if len(parts) % 2:
                nxt.append(parts[-1])
            parts = nxt
        return parts[0]

    def _emit_chain(self, keys: Array) -> Array:
        """Pre-tree serialized compare chain (O(s) dependency depth), kept
        as the equivalence/bench baseline for :meth:`emit_in_kernel`."""
        if not self.splitters:
            return jnp.zeros(keys.shape, jnp.int32)
        plane, vals = self._compare_plane(keys.dtype)
        kc = keys.astype(plane)
        ids = jnp.zeros(keys.shape, jnp.int32)
        for s in vals:
            ids = ids + (kc >= np.asarray(s, plane)[()]).astype(jnp.int32)
        return ids

    @property
    def name(self) -> str:
        return f"range{self.num_buckets}"


@_register_static
@dataclasses.dataclass(frozen=True)
class EvenSpec(BucketSpec):
    """Evenly spaced float buckets (paper §7.3 "Even Histogram")."""

    lo: float
    hi: float
    num_buckets: int

    def emit(self, keys: Array) -> Array:
        width = (self.hi - self.lo) / self.num_buckets
        ids = jnp.floor((keys - self.lo) / width)
        # clip in FLOAT domain: the +inf/fmax pad key must land in the last
        # bucket, and float->int conversion of out-of-range values is
        # platform-defined.
        ids = jnp.clip(ids, 0, self.num_buckets - 1)
        # NaN keys survive both floor and clip (clip(NaN) is NaN), and
        # NaN->int conversion is platform-defined (observed: bucket 0).
        # Route them deterministically into the LAST bucket, matching the
        # +inf pad sentinel.  ``ids != ids`` is the NaN test that stays a
        # plain vector compare in-kernel and is False on integer keys.
        ids = jnp.where(ids != ids, self.num_buckets - 1, ids)
        return ids.astype(jnp.int32)

    @property
    def name(self) -> str:
        return f"even{self.num_buckets}"


@_register_static
@dataclasses.dataclass(frozen=True)
class CallableSpec(BucketSpec):
    """Escape hatch: an arbitrary user function (the paper's "prime vs
    composite" etc.).  Not fusable — backends materialize its labels
    host-side — and hashed by function identity, so distinct closures
    retrace (use a declarative spec to share traces)."""

    fn: Callable[[Array], Array]
    num_buckets: int
    name: str = "custom"

    fusable = False

    def emit(self, keys: Array) -> Array:
        return self.fn(keys).astype(jnp.int32)

    def pad_key(self, dtype):
        # the base-class contract (pad lands in bucket m-1) cannot be
        # guaranteed for an arbitrary fn; the layout pads CallableSpec plans
        # on the LABEL side (ids padded with m-1), never on the key side.
        raise NotImplementedError(
            f"no pad key exists for the arbitrary bucket function {self.name!r}; "
            "callable specs pad labels (not keys)"
        )


class BucketIdentifier(CallableSpec):
    """Deprecated pre-PR-4 alias of :class:`CallableSpec`.

    Kept so ``from repro.core.identifiers import BucketIdentifier`` and
    ``BucketIdentifier(fn, m, name)`` keep working (warning-clean); new code
    should construct a declarative spec (or :class:`CallableSpec`)."""


_register_static(BucketIdentifier)


def delta_buckets(num_buckets: int, key_max: int = 2**30) -> DeltaSpec:
    """Equal-width buckets over the key domain: ``f(u) = u // delta`` (§6)."""
    return DeltaSpec(num_buckets, key_max)


def identity_buckets(num_buckets: int) -> IdentitySpec:
    """Keys are already bucket ids: ``f(u) = u`` (paper §7.1)."""
    return IdentitySpec(num_buckets)


def radix_buckets(pass_idx: int, radix_bits: int) -> BitfieldSpec:
    """``f_k(u) = (u >> k*r) & (2^r - 1)`` — one LSD radix digit (§7.1)."""
    return BitfieldSpec(pass_idx * radix_bits, radix_bits)


def range_buckets(splitters) -> RangeSpec:
    """Arbitrary splitter buckets (paper §7.3 "Range Histogram").

    ``splitters`` may be a sequence or array; it is validated and SORTED
    into the spec (``m = len(splitters) + 1``)."""
    sp = np.asarray(splitters)
    return RangeSpec(tuple(sp.tolist()))


def even_buckets(lo: float, hi: float, num_buckets: int) -> EvenSpec:
    """Evenly spaced float buckets (paper §7.3 "Even Histogram")."""
    return EvenSpec(float(lo), float(hi), num_buckets)


def from_fn(fn: Callable[[Array], Array], num_buckets: int, name: str = "user") -> CallableSpec:
    """Wrap an arbitrary user function (the paper's "prime vs composite")."""
    return CallableSpec(fn, num_buckets, name=name)


def as_spec(spec) -> BucketSpec:
    """Coerce a user-supplied identifier into a :class:`BucketSpec`.

    Accepts any spec (including the :class:`BucketIdentifier` shim) as-is;
    a bare callable is wrapped iff it carries a ``num_buckets`` attribute.
    """
    if isinstance(spec, BucketSpec):
        return spec
    if callable(spec) and hasattr(spec, "num_buckets"):
        return CallableSpec(spec, int(spec.num_buckets))
    raise TypeError(
        f"expected a BucketSpec (see repro.core.identifiers), got {spec!r}"
    )
