"""Bucket identifiers (paper §3.1, §6 "Bucket identification").

A bucket identifier is any jnp-traceable function ``keys -> bucket_ids``
with ``0 <= bucket_id < m``.  The paper's three benchmark identifiers are
provided (delta, identity, range/splitter), plus the radix identifier used
to build the multisplit radix sort (§7.1) and a generic ``from_fn`` wrapper.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class BucketIdentifier:
    """A named bucket identifier: ``fn(keys) -> int32 bucket ids in [0, m)``."""

    fn: Callable[[Array], Array]
    num_buckets: int
    name: str = "custom"

    def __call__(self, keys: Array) -> Array:
        ids = self.fn(keys)
        return ids.astype(jnp.int32)


def delta_buckets(num_buckets: int, key_max: int = 2**30) -> BucketIdentifier:
    """Equal-width buckets over the key domain: ``f(u) = u // delta`` (paper §6)."""
    delta = max(1, key_max // num_buckets)

    def fn(keys: Array) -> Array:
        ids = keys.astype(jnp.uint32) // jnp.uint32(delta)
        return jnp.minimum(ids, num_buckets - 1).astype(jnp.int32)

    return BucketIdentifier(fn, num_buckets, name=f"delta{num_buckets}")


def identity_buckets(num_buckets: int) -> BucketIdentifier:
    """Keys are already bucket ids: ``f(u) = u`` (paper §7.1)."""
    return BucketIdentifier(
        lambda keys: keys.astype(jnp.int32), num_buckets, name=f"identity{num_buckets}"
    )


def radix_buckets(pass_idx: int, radix_bits: int) -> BucketIdentifier:
    """``f_k(u) = (u >> k*r) & (2^r - 1)`` — one LSD radix-sort digit (paper §7.1)."""
    shift = pass_idx * radix_bits
    mask = (1 << radix_bits) - 1

    def fn(keys: Array) -> Array:
        u = keys.astype(jnp.uint32)
        return ((u >> jnp.uint32(shift)) & jnp.uint32(mask)).astype(jnp.int32)

    return BucketIdentifier(fn, 1 << radix_bits, name=f"radix[{shift}:{shift + radix_bits}]")


def range_buckets(splitters: Array) -> BucketIdentifier:
    """Arbitrary splitter buckets via binary search (paper §7.3 "Range Histogram").

    ``m = len(splitters) + 1``; key u lands in bucket j s.t.
    ``splitters[j-1] <= u < splitters[j]``.
    """
    splitters = jnp.asarray(splitters)
    m = int(splitters.shape[0]) + 1

    def fn(keys: Array) -> Array:
        return jnp.searchsorted(splitters, keys, side="right").astype(jnp.int32)

    return BucketIdentifier(fn, m, name=f"range{m}")


def even_buckets(lo: float, hi: float, num_buckets: int) -> BucketIdentifier:
    """Evenly spaced float buckets (paper §7.3 "Even Histogram")."""
    width = (hi - lo) / num_buckets

    def fn(keys: Array) -> Array:
        ids = jnp.floor((keys - lo) / width).astype(jnp.int32)
        return jnp.clip(ids, 0, num_buckets - 1)

    return BucketIdentifier(fn, num_buckets, name=f"even{num_buckets}")


def from_fn(fn: Callable[[Array], Array], num_buckets: int, name: str = "user") -> BucketIdentifier:
    """Wrap an arbitrary user function (the paper's "prime vs composite" etc.)."""
    return BucketIdentifier(fn, num_buckets, name=name)
