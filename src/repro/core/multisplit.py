"""The multisplit primitive (paper §3–§5), TPU-native.

Structure follows the paper's parallel model exactly (§4.1):

    {local prescan} -> {one global scan} -> {local postscan + scatter}

* prescan:   per-tile bucket histograms -> the ``m x L`` matrix ``H``.
* scan:      ONE exclusive prefix-sum over the row-vectorized ``H``
             (bucket-major), giving ``G[b, l]`` = #elements in earlier
             buckets anywhere + #elements of bucket ``b`` in earlier tiles.
* postscan:  per-tile local offsets (stable rank within bucket inside the
             tile), final position ``p(i) = G[b, tile] + local_offset``
             (paper eq. (2)); for WMS/BMS the tile is reordered bucket-major
             first (paper §4.7) so the global scatter writes contiguous
             per-bucket runs.

Hardware adaptation (see DESIGN.md §2): the warp-ballot direct solve is
replaced by a one-hot matrix direct solve over a VMEM-resident tile — the
same binary matrix ``H̄`` of paper §4.5, built with vector compares instead
of ``__ballot`` and reduced/scanned with MXU/VPU ops instead of ``__popc``.

Execution is owned by :mod:`repro.core.pipeline` (DESIGN.md §3, §10):
``multisplit`` resolves a :class:`repro.core.pipeline.MultisplitPlan`
through the backend registry and runs it, so the
postscan + reorder is ONE fused evaluation per tile on every backend. The
pre-plan three-pass host orchestration survives only as
:func:`multisplit_unfused`, the fused-vs-legacy benchmark baseline.

Three variants map to the paper's three implementations:

* ``method="dms"``  — no reorder (Direct Multisplit).
* ``method="wms"``  — tile-local reorder, small tiles (Warp-level MS).
* ``method="bms"``  — tile-local reorder, large tiles (Block-level MS).

Beyond the paper's single flat problem, :func:`batched_multisplit` and
:func:`segmented_multisplit` run MANY independent multisplits (per batch
row / per ragged segment) in one plan launch (DESIGN.md §9).

NOTE (PR-4): :mod:`repro.ops` is the STABLE public facade over this module
— transform-native (``jax.vmap`` dispatches onto the batched plan, the
key-value op is differentiable) and built on hashable
:class:`~repro.core.identifiers.BucketSpec` values.  New consumers should
import ``repro.ops``; this module remains the execution layer.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.identifiers import BucketSpec
from repro.core.pipeline import (        # re-exported for consumers/tests
    BMS_TILE,
    MultisplitResult,
    WMS_TILE,
    global_scan,
    make_batched_plan,
    make_plan,
    make_segmented_plan,
    pad_to_tiles as _pad_to_tiles,
    resolve_backend,
    segment_ids_from_starts,
    tile_local_offsets,
)

Array = jnp.ndarray

__all__ = [
    "WMS_TILE", "BMS_TILE", "MultisplitResult", "global_scan",
    "tile_histogram", "tile_local_offsets", "multisplit_ref", "multisplit",
    "batched_multisplit", "segmented_multisplit", "segment_ids_from_starts",
    "multisplit_unfused", "prescan", "postscan_positions",
]


# ---------------------------------------------------------------------------
# Direct solve on one tile (paper §4.5, adapted per DESIGN.md §2)
# ---------------------------------------------------------------------------

def tile_histogram(bucket_ids: Array, num_buckets: int) -> Array:
    """Histogram of one tile: column-sum of the one-hot matrix H̄ (m,)."""
    one_hot = (bucket_ids[:, None] == jnp.arange(num_buckets)[None, :]).astype(jnp.int32)
    return one_hot.sum(axis=0)


# tile_local_offsets (stable in-bucket rank + tile histogram, paper Alg. 3
# without ballots) is defined once in repro.core.pipeline and re-exported
# above.


# ---------------------------------------------------------------------------
# Reference oracle: paper eq. (1), single subproblem == whole input
# ---------------------------------------------------------------------------

def multisplit_ref(
    keys: Array,
    bucket_fn: BucketSpec,
    values: Optional[Array] = None,
) -> MultisplitResult:
    """O(n·m) direct evaluation of eq. (1). Oracle for everything else."""
    from repro.core.pipeline import direct_solve_reference

    return direct_solve_reference(keys, bucket_fn, values)


# ---------------------------------------------------------------------------
# Tiled stage helpers (kept public: histogram.py & tests build on them)
# ---------------------------------------------------------------------------

def prescan(ids_tiled: Array, num_buckets: int) -> Array:
    """Local stage 1: per-tile histograms -> H with shape (L, m)."""
    return jax.vmap(lambda t: tile_histogram(t, num_buckets))(ids_tiled)


def postscan_positions(ids_tiled: Array, g: Array, num_buckets: int) -> Array:
    """Local stage 2 (unfused form): per-element destination, eq. (2)."""

    def one_tile(ids, g_tile):
        local, _ = tile_local_offsets(ids, num_buckets)
        return g_tile[ids] + local

    return jax.vmap(one_tile)(ids_tiled, g)


# ---------------------------------------------------------------------------
# The multisplit entry point: resolve a plan, run it
# ---------------------------------------------------------------------------

def multisplit(
    keys: Array,
    bucket_fn: BucketSpec,
    values: Optional[Array] = None,
    *,
    method: str = "bms",
    tile: Optional[int] = None,
    use_pallas: bool = False,
    interpret: bool = True,
    backend: Optional[str] = None,
    mode: str = "reorder",
    family: Optional[str] = None,
) -> MultisplitResult:
    """Stable multisplit of ``keys`` (and optional ``values``) into buckets.

    ``method``: "dms" (no tile reorder), "wms" (reorder, small tiles),
    "bms" (reorder, large tiles). All three produce identical output
    (paper §4.7: the reorder changes data movement, not the result); they
    differ in the width L of the global scan and in scatter contiguity.

    ``backend`` (overrides ``use_pallas``/``interpret``): "reference",
    "vmap", "pallas-interpret", or "pallas" — registered in
    :mod:`repro.core.pipeline.registry`.

    ``mode`` selects a partial pipeline (DESIGN.md §10): ``counts_only``
    (prescan + reduce — the §7.3 histogram; only starts/counts are
    computed) or ``positions_only`` (the eq. (2) permutation without
    materializing reordered keys). Both are key-only.

    ``family`` pins the kernel family of the local solve (``"onehot"`` /
    ``"packed"``, DESIGN.md §12); ``None`` auto-resolves it per shape.
    Families are bitwise identical — the knob changes cost, not results.
    """
    plan = make_plan(
        keys.shape[0],
        bucket_fn.num_buckets,
        method=method,
        key_value=values is not None,
        backend=resolve_backend(use_pallas, interpret, backend),
        tile=tile,
        bucket_fn=bucket_fn,
        mode=mode,
        family=family,
    )
    return plan(keys, values)


# ---------------------------------------------------------------------------
# Batched / segmented entry points (DESIGN.md §9): many independent
# multisplits in ONE plan launch instead of a host loop over subproblems.
# ---------------------------------------------------------------------------

def batched_multisplit(
    keys: Array,
    bucket_fn: BucketSpec,
    values: Optional[Array] = None,
    *,
    method: str = "bms",
    tile: Optional[int] = None,
    use_pallas: bool = False,
    interpret: bool = True,
    backend: Optional[str] = None,
    mode: str = "reorder",
    family: Optional[str] = None,
) -> MultisplitResult:
    """Multisplit every row of ``keys`` (b, n) independently in one launch.

    Bitwise identical to calling :func:`multisplit` on each row: returns
    (b, n) keys/values/permutation and (b, m) per-row starts/counts.
    ``mode`` selects a partial pipeline as in :func:`multisplit`.
    """
    if keys.ndim != 2:
        raise ValueError(f"batched_multisplit expects (b, n) keys, got {keys.shape}")
    b, n = keys.shape
    plan = make_batched_plan(
        b, n, bucket_fn.num_buckets,
        method=method,
        key_value=values is not None,
        backend=resolve_backend(use_pallas, interpret, backend),
        tile=tile,
        bucket_fn=bucket_fn,
        mode=mode,
        family=family,
    )
    return plan(keys, values)


def _empty_segmented_result(
    keys: Array, values: Optional[Array], m: int, mode: str
) -> MultisplitResult:
    """The s == 0 (zero-request step) result: (0, m) counts/starts and empty
    data arrays, consistent with the s >= 1 shapes. A continuous-batching
    step with no admitted requests hits this constantly (ISSUE 9 S1); it
    used to be a ValueError from the plan layout validator."""
    if keys.shape[0] != 0:
        raise ValueError(
            f"segment_starts is empty but keys has {keys.shape[0]} elements; "
            f"0 segments can only own 0 keys"
        )
    zeros = jnp.zeros((0, m), jnp.int32)
    perm = jnp.zeros((0,), jnp.int32)
    if mode == "counts_only":
        return MultisplitResult(None, None, zeros, zeros, None)
    if mode == "positions_only":
        return MultisplitResult(None, None, zeros, zeros, perm)
    return MultisplitResult(keys, values, zeros, zeros, perm)


def segmented_multisplit(
    keys: Array,
    bucket_fn: BucketSpec,
    segment_starts,
    values: Optional[Array] = None,
    *,
    method: str = "bms",
    tile: Optional[int] = None,
    use_pallas: bool = False,
    interpret: bool = True,
    backend: Optional[str] = None,
    mode: str = "reorder",
    family: Optional[str] = None,
) -> MultisplitResult:
    """Multisplit every ragged segment of flat ``keys`` independently in one
    launch. ``segment_starts`` is an (s,) ascending vector of start offsets
    with ``segment_starts[0] == 0``; segment i spans
    ``[segment_starts[i], segment_starts[i+1])`` (the last ends at n) and
    empty segments are allowed.

    Bitwise identical to slicing out each segment and calling
    :func:`multisplit` on it: each segment keeps its input span in the
    output, ``bucket_starts``/``bucket_counts`` are (s, m) segment-local,
    and ``permutation`` is segment-local. ``mode`` selects a partial
    pipeline as in :func:`multisplit`.

    ``s == 0`` (no segments at all — a zero-request serving step) is legal
    with empty ``keys`` and returns (0, m) counts/starts and empty data
    arrays (the :mod:`repro.ops` facade short-circuits identically).
    """
    seg = jnp.asarray(segment_starts, jnp.int32)
    if seg.shape[0] == 0:
        return _empty_segmented_result(
            keys, values, bucket_fn.num_buckets, mode
        )
    plan = make_segmented_plan(
        keys.shape[0], int(seg.shape[0]), bucket_fn.num_buckets,
        method=method,
        key_value=values is not None,
        backend=resolve_backend(use_pallas, interpret, backend),
        tile=tile,
        bucket_fn=bucket_fn,
        mode=mode,
        family=family,
    )
    return plan(keys, values, segment_starts=seg)


# ---------------------------------------------------------------------------
# Legacy three-pass pipeline — benchmark baseline ONLY (DESIGN.md §6).
# The postscan/reorder work here evaluates the one-hot/cumsum up to three
# times per tile (positions, key reorder, value reorder); kept verbatim so
# benchmarks/bench_multisplit.py can measure what the fused plan removed.
# ---------------------------------------------------------------------------

def multisplit_unfused(
    keys: Array,
    bucket_fn: BucketSpec,
    values: Optional[Array] = None,
    *,
    method: str = "bms",
    tile: Optional[int] = None,
) -> MultisplitResult:
    """Pre-plan host orchestration (3 one-hot/cumsum passes per tile)."""
    if method not in ("dms", "wms", "bms"):
        raise ValueError(f"unknown multisplit method {method!r}")
    if tile is None:
        tile = WMS_TILE if method in ("dms", "wms") else BMS_TILE
    m = bucket_fn.num_buckets
    n = keys.shape[0]

    ids = bucket_fn(keys)
    ids_p, _ = _pad_to_tiles(ids, tile, m - 1)
    n_total = ids_p.shape[0]
    ids_tiled = ids_p.reshape(-1, tile)

    hist = prescan(ids_tiled, m)
    g = global_scan(hist)
    pos_tiled = postscan_positions(ids_tiled, g, m)          # pass 1
    perm_full = pos_tiled.reshape(-1)

    if method in ("wms", "bms"):
        def reorder_tile(ids_t, keys_t, pos_t):              # pass 2
            local, h = tile_local_offsets(ids_t, m)
            starts = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32), jnp.cumsum(h)[:-1].astype(jnp.int32)]
            )
            tile_pos = starts[ids_t] + local
            keys_r = jnp.zeros_like(keys_t).at[tile_pos].set(keys_t)
            pos_r = jnp.zeros_like(pos_t).at[tile_pos].set(pos_t)
            return keys_r, pos_r

        keys_p, _ = _pad_to_tiles(keys, tile, 0)
        keys_tiled = keys_p.reshape(-1, tile)
        keys_r, pos_r = jax.vmap(reorder_tile)(ids_tiled, keys_tiled, pos_tiled)
        scatter_src_keys = keys_r.reshape(-1)
        scatter_pos = pos_r.reshape(-1)
        if values is not None:
            vals_p, _ = _pad_to_tiles(values, tile, 0)
            vals_tiled = vals_p.reshape(-1, tile)

            def reorder_vals(ids_t, vals_t):                 # pass 3
                local, h = tile_local_offsets(ids_t, m)
                starts = jnp.concatenate(
                    [jnp.zeros((1,), jnp.int32), jnp.cumsum(h)[:-1].astype(jnp.int32)]
                )
                tile_pos = starts[ids_t] + local
                return jnp.zeros_like(vals_t).at[tile_pos].set(vals_t)

            vals_r = jax.vmap(reorder_vals)(ids_tiled, vals_tiled)
            scatter_src_vals = vals_r.reshape(-1)
    else:
        keys_p, _ = _pad_to_tiles(keys, tile, 0)
        scatter_src_keys = keys_p
        scatter_pos = perm_full
        if values is not None:
            vals_p, _ = _pad_to_tiles(values, tile, 0)
            scatter_src_vals = vals_p

    keys_out = jnp.zeros((n_total,), keys.dtype).at[scatter_pos].set(scatter_src_keys)[:n]
    values_out = None
    if values is not None:
        values_out = (
            jnp.zeros((n_total,) + values.shape[1:], values.dtype)
            .at[scatter_pos]
            .set(scatter_src_vals)[:n]
        )

    counts = hist.sum(axis=0).astype(jnp.int32)
    counts = counts.at[m - 1].add(n - n_total)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
    )
    return MultisplitResult(keys_out, values_out, starts, counts, perm_full[:n])
