"""The multisplit primitive (paper §3–§5), TPU-native.

Structure follows the paper's parallel model exactly (§4.1):

    {local prescan} -> {one global scan} -> {local postscan + scatter}

* prescan:   per-tile bucket histograms -> the ``m x L`` matrix ``H``.
* scan:      ONE exclusive prefix-sum over the row-vectorized ``H``
             (bucket-major), giving ``G[b, l]`` = #elements in earlier
             buckets anywhere + #elements of bucket ``b`` in earlier tiles.
* postscan:  per-tile local offsets (stable rank within bucket inside the
             tile), final position ``p(i) = G[b, tile] + local_offset``
             (paper eq. (2)); optionally reorder the tile bucket-major
             first (paper §4.7) so the global scatter writes contiguous
             per-bucket runs.

Hardware adaptation (see DESIGN.md §2): the warp-ballot direct solve is
replaced by a one-hot matrix direct solve over a VMEM-resident tile — the
same binary matrix ``H̄`` of paper §4.5, built with vector compares instead
of ``__ballot`` and reduced/scanned with MXU/VPU ops instead of ``__popc``.

Three variants map to the paper's three implementations:

* ``method="dms"``  — no reorder (Direct Multisplit).
* ``method="wms"``  — tile-local reorder, small tiles (Warp-level MS).
* ``method="bms"``  — tile-local reorder, large tiles (Block-level MS).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.identifiers import BucketIdentifier

Array = jnp.ndarray

# Tile sizes: "warp" tiles vs "block" tiles. On TPU these are VMEM tile
# heights; BMS tiles are N_warp x larger, exactly the paper's Table 1 sizing
# knob (larger subproblem => narrower global scan matrix H).
WMS_TILE = 1024
BMS_TILE = 4096


class MultisplitResult(NamedTuple):
    keys: Array                    # permuted keys, bucket-major, stable
    values: Optional[Array]        # permuted values (None for key-only)
    bucket_starts: Array           # (m,) start index of each bucket
    bucket_counts: Array           # (m,) histogram
    permutation: Array             # (n,) dest position of input element i


# ---------------------------------------------------------------------------
# Direct solve on one tile (paper §4.5, adapted per DESIGN.md §2)
# ---------------------------------------------------------------------------

def tile_histogram(bucket_ids: Array, num_buckets: int) -> Array:
    """Histogram of one tile: column-sum of the one-hot matrix H̄ (m,)."""
    one_hot = (bucket_ids[:, None] == jnp.arange(num_buckets)[None, :]).astype(jnp.int32)
    return one_hot.sum(axis=0)


def tile_local_offsets(bucket_ids: Array, num_buckets: int) -> Tuple[Array, Array]:
    """Stable in-bucket rank of each element of one tile + tile histogram.

    Exclusive column cumsum of H̄ picked out at each element's own bucket —
    paper Alg. 3 without ballots.
    """
    one_hot = (bucket_ids[:, None] == jnp.arange(num_buckets)[None, :]).astype(jnp.int32)
    incl = jnp.cumsum(one_hot, axis=0)
    local = incl[jnp.arange(bucket_ids.shape[0]), bucket_ids] - 1
    return local.astype(jnp.int32), incl[-1]


# ---------------------------------------------------------------------------
# Reference oracle: paper eq. (1), single subproblem == whole input
# ---------------------------------------------------------------------------

def multisplit_ref(
    keys: Array,
    bucket_fn: BucketIdentifier,
    values: Optional[Array] = None,
) -> MultisplitResult:
    """O(n·m) direct evaluation of eq. (1). Oracle for everything else."""
    m = bucket_fn.num_buckets
    ids = bucket_fn(keys)
    local, hist = tile_local_offsets(ids, m)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(hist)[:-1].astype(jnp.int32)])
    perm = starts[ids] + local
    keys_out = jnp.zeros_like(keys).at[perm].set(keys)
    values_out = None
    if values is not None:
        values_out = jnp.zeros_like(values).at[perm].set(values)
    return MultisplitResult(keys_out, values_out, starts, hist.astype(jnp.int32), perm)


# ---------------------------------------------------------------------------
# Tiled multisplit: {prescan, scan, postscan}
# ---------------------------------------------------------------------------

def _pad_to_tiles(x: Array, tile: int, fill) -> Tuple[Array, int]:
    n = x.shape[0]
    n_pad = (-n) % tile
    if n_pad:
        x = jnp.concatenate([x, jnp.full((n_pad,) + x.shape[1:], fill, x.dtype)])
    return x, n_pad


def prescan(ids_tiled: Array, num_buckets: int) -> Array:
    """Local stage 1: per-tile histograms -> H with shape (L, m)."""
    return jax.vmap(lambda t: tile_histogram(t, num_buckets))(ids_tiled)


def global_scan(hist_per_tile: Array) -> Array:
    """The ONE global operation: exclusive scan over row-vectorized H.

    ``hist_per_tile`` is (L, m); the paper scans H (m, L) in bucket-major
    (row-vectorized) order, so we scan the transpose, flattened.
    Returns G with shape (L, m): global base for (tile l, bucket b).
    """
    h_t = hist_per_tile.T                                  # (m, L) bucket-major
    flat = h_t.reshape(-1)
    g = jnp.concatenate([jnp.zeros((1,), flat.dtype), jnp.cumsum(flat)[:-1]])
    return g.reshape(h_t.shape).T                          # back to (L, m)


def postscan_positions(ids_tiled: Array, g: Array, num_buckets: int) -> Array:
    """Local stage 2: per-element final destination, eq. (2). (L, T) -> (L, T)."""

    def one_tile(ids, g_tile):
        local, _ = tile_local_offsets(ids, num_buckets)
        return g_tile[ids] + local

    return jax.vmap(one_tile)(ids_tiled, g)


def multisplit(
    keys: Array,
    bucket_fn: BucketIdentifier,
    values: Optional[Array] = None,
    *,
    method: str = "bms",
    tile: Optional[int] = None,
    use_pallas: bool = False,
    interpret: bool = True,
) -> MultisplitResult:
    """Stable multisplit of ``keys`` (and optional ``values``) into buckets.

    ``method``: "dms" (no tile reorder), "wms" (reorder, small tiles),
    "bms" (reorder, large tiles). All three produce identical output
    (paper §4.7: the reorder changes data movement, not the result); they
    differ in the width L of the global scan and in scatter contiguity.

    ``use_pallas`` routes the tile direct solve through the Pallas TPU
    kernels in ``repro.kernels`` (interpret mode on CPU).
    """
    if method not in ("dms", "wms", "bms"):
        raise ValueError(f"unknown multisplit method {method!r}")
    if tile is None:
        tile = WMS_TILE if method in ("dms", "wms") else BMS_TILE
    m = bucket_fn.num_buckets
    n = keys.shape[0]

    ids = bucket_fn(keys)
    # Pad the tail tile with bucket m-1 sentinels: they land at the very end
    # of the output (stability keeps real m-1 keys ahead of pads? no — pads
    # come AFTER all real elements of bucket m-1 only if appended last, which
    # they are: tiles are processed in order and pads sit in the final tile's
    # tail). We slice them off before returning.
    ids_p, _ = _pad_to_tiles(ids, tile, m - 1)
    n_total = ids_p.shape[0]
    ids_tiled = ids_p.reshape(-1, tile)

    if use_pallas:
        from repro.kernels import ops as kops

        hist = kops.tile_histograms(ids_tiled, m, interpret=interpret)
    else:
        hist = prescan(ids_tiled, m)

    g = global_scan(hist)

    if use_pallas:
        from repro.kernels import ops as kops

        pos_tiled = kops.tile_positions(ids_tiled, g, m, interpret=interpret)
    else:
        pos_tiled = postscan_positions(ids_tiled, g, m)

    perm_full = pos_tiled.reshape(-1)

    if method in ("wms", "bms"):
        # Tile-local reorder (paper §4.7): stable bucket-major sort *within*
        # each tile before the global scatter. Final result identical; on
        # TPU the scatter then moves per-bucket-contiguous runs (coalesced
        # DMA / single-segment ragged all-to-all — DESIGN.md §2).
        def reorder_tile(ids_t, keys_t, pos_t):
            local, h = tile_local_offsets(ids_t, m)
            starts = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32), jnp.cumsum(h)[:-1].astype(jnp.int32)]
            )
            tile_pos = starts[ids_t] + local
            keys_r = jnp.zeros_like(keys_t).at[tile_pos].set(keys_t)
            pos_r = jnp.zeros_like(pos_t).at[tile_pos].set(pos_t)
            return keys_r, pos_r

        keys_p, _ = _pad_to_tiles(keys, tile, 0)
        keys_tiled = keys_p.reshape(-1, tile)
        keys_r, pos_r = jax.vmap(reorder_tile)(ids_tiled, keys_tiled, pos_tiled)
        scatter_src_keys = keys_r.reshape(-1)
        scatter_pos = pos_r.reshape(-1)
        if values is not None:
            vals_p, _ = _pad_to_tiles(values, tile, 0)
            vals_tiled = vals_p.reshape(-1, tile)

            def reorder_vals(ids_t, vals_t):
                local, h = tile_local_offsets(ids_t, m)
                starts = jnp.concatenate(
                    [jnp.zeros((1,), jnp.int32), jnp.cumsum(h)[:-1].astype(jnp.int32)]
                )
                tile_pos = starts[ids_t] + local
                return jnp.zeros_like(vals_t).at[tile_pos].set(vals_t)

            vals_r = jax.vmap(reorder_vals)(ids_tiled, vals_tiled)
            scatter_src_vals = vals_r.reshape(-1)
    else:
        keys_p, _ = _pad_to_tiles(keys, tile, 0)
        scatter_src_keys = keys_p
        scatter_pos = perm_full
        if values is not None:
            vals_p, _ = _pad_to_tiles(values, tile, 0)
            scatter_src_vals = vals_p

    keys_out = jnp.zeros((n_total,), keys.dtype).at[scatter_pos].set(scatter_src_keys)[:n]
    values_out = None
    if values is not None:
        values_out = (
            jnp.zeros((n_total,) + values.shape[1:], values.dtype)
            .at[scatter_pos]
            .set(scatter_src_vals)[:n]
        )

    counts = hist.sum(axis=0).astype(jnp.int32)
    # Remove padded sentinels from the last bucket's count.
    counts = counts.at[m - 1].add(n - n_total)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
    )
    return MultisplitResult(keys_out, values_out, starts, counts, perm_full[:n])
