"""Device-level multisplit: the paper's {local, global, local} model lifted
onto a JAX mesh axis (DESIGN.md §2, §7).

Hierarchy (paper §4.4, one more level than the GPU version):

    tile (VMEM direct solve)  ->  chip (grid accumulation)
        ->  device axis (THIS module: one tiny collective + ragged a2a)

Key property (paper §4.7 lifted to ICI): after each device *locally
reorders* its shard bucket-major, the map ``local index -> global output
position`` is strictly increasing. Hence the data each device must send to
any given peer is ONE contiguous run of its local buffer — i.e., the local
reorder turns a random inter-device scatter into a single-segment
``ragged_all_to_all``. Without the reorder (DMS), per-peer sends are
scattered and the collective degenerates to a dense gather/scatter; this is
the paper's coalescing argument, with "DRAM burst" replaced by "ICI DMA".
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map as _shard_map
from repro.core import multisplit as ms
from repro.core.identifiers import BucketSpec
from repro.core.pipeline import MultisplitResult, make_plan, resolve_backend

Array = jnp.ndarray


def multisplit_all_shards(
    keys: Array,
    bucket_fn: BucketSpec,
    values: Optional[Array] = None,
    *,
    method: str = "bms",
    use_pallas: bool = False,
    backend: Optional[str] = None,
    tile: Optional[int] = None,
) -> MultisplitResult:
    """The device-level pipeline with the LOCAL stage as ONE batched plan.

    ``keys`` is the (D, n_shard) stack of all shards. Stage 1 runs every
    shard's bucket-major reorder + histogram in a single batched plan launch
    (DESIGN.md §9) — the host-side analogue of ``multisplit_sharded``'s
    per-device local stage, with the D-way host loop (or D separate plan
    calls) collapsed into one grid. Stage 2 is the closed-form global scan
    over the (D, m) histogram matrix H — the same math ``_send_plan``
    computes from the all-gathered H, evaluated directly since every shard
    is host-visible here. Output is the global stable bucket-major
    multisplit of the concatenated shards (bitwise identical to
    ``multisplit_ref`` on ``keys.reshape(-1)``), with the element-ordered
    permutation in flat global coordinates.

    Use this as the single-process path for multi-shard data (benchmarks,
    verification, one-host serving); the collective version below is its
    mesh-distributed twin.
    """
    d_num, n_shard = keys.shape
    plan = make_plan(
        n_shard,
        bucket_fn.num_buckets,
        method=method,
        key_value=values is not None,
        backend=resolve_backend(use_pallas, True, backend),
        tile=tile,
        bucket_fn=bucket_fn,
        batch=d_num,
    )
    local = plan(keys, values)                               # ONE launch, D shards
    hist = local.bucket_counts                               # (D, m) == H
    totals = hist.sum(axis=0).astype(jnp.int32)              # (m,)
    g_flat = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(totals)[:-1].astype(jnp.int32)]
    )
    c_excl = (jnp.cumsum(hist, axis=0) - hist).astype(jnp.int32)     # (D, m)

    # Reordered local slot j of shard d -> global position: the local buffer
    # is bucket-major, so bucket-of-slot comes from the local histogram and
    # the map is strictly increasing per (shard, bucket) run (paper §4.7).
    lidx = jnp.arange(n_shard, dtype=jnp.int32)
    lids = jax.vmap(
        lambda c: jnp.searchsorted(c, lidx, side="right").astype(jnp.int32)
    )(jnp.cumsum(hist, axis=1))                              # (D, n_shard)
    rank = lidx[None, :] - jnp.take_along_axis(local.bucket_starts, lids, axis=1)
    pos = g_flat[lids] + jnp.take_along_axis(c_excl, lids, axis=1) + rank

    n_total = d_num * n_shard
    keys_out = jnp.zeros((n_total,), keys.dtype).at[pos.reshape(-1)].set(
        local.keys.reshape(-1)
    )
    values_out = None
    if values is not None:
        values_out = jnp.zeros((n_total,), values.dtype).at[pos.reshape(-1)].set(
            local.values.reshape(-1)
        )

    # element-ordered permutation of the ORIGINAL (D, n_shard) input
    ids = bucket_fn(keys)                                    # (D, n_shard)
    rank_in = local.permutation - jnp.take_along_axis(local.bucket_starts, ids, axis=1)
    perm = g_flat[ids] + jnp.take_along_axis(c_excl, ids, axis=1) + rank_in

    return MultisplitResult(keys_out, values_out, g_flat, totals, perm.reshape(-1))


def _local_plan(
    keys: Array,
    bucket_fn: BucketSpec,
    values,
    method: str,
    use_pallas: bool,
    backend,
    tile,
):
    """The per-device local stage IS a multisplit plan (DESIGN.md §3/§7):
    the device shard is one subproblem of the same {prescan, scan, postscan}
    pipeline that tiles are — so it is built from the shared plan layer
    instead of re-assembling ``ms.multisplit`` internals."""
    plan = make_plan(
        keys.shape[0],
        bucket_fn.num_buckets,
        method=method,
        key_value=values is not None,
        backend=resolve_backend(use_pallas, True, backend),
        tile=tile,
        bucket_fn=bucket_fn,
    )
    return plan(keys, values)


class ShardedMultisplitResult(NamedTuple):
    keys: Array                 # this device's shard of the global bucket-major output
    values: Optional[Array]
    bucket_starts: Array        # (m,) GLOBAL bucket start positions (replicated)
    bucket_counts: Array        # (m,) GLOBAL histogram (replicated)


def _send_plan(hist_all: Array, n_dev: int):
    """Compute the ragged_all_to_all plan from the gathered histogram.

    ``hist_all``: (D, m) per-device bucket counts — the paper's matrix H with
    L = D columns. Everything below is O(D·m + D²) scalar work, computed
    redundantly on every device (recompute-over-communicate, paper §5.3).
    Returns the full (D_src, D_dst) matrices so caller can slice both its
    sender row and its receiver column.
    """
    d_num, m = hist_all.shape
    totals = hist_all.sum(axis=0)                            # (m,)
    g_flat = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(totals)[:-1].astype(jnp.int32)])
    # C[b, s]: count of bucket b on devices < s  (exclusive scan over devices)
    c_excl = jnp.cumsum(hist_all, axis=0) - hist_all         # (D, m)
    run_start = g_flat[None, :] + c_excl                     # (D, m) global start of (s, b) run
    run_len = hist_all                                       # (D, m)

    # count of device s's elements with global position < X, per boundary X
    bounds = jnp.arange(d_num + 1, dtype=jnp.int32) * n_dev  # (D+1,)
    below = jnp.clip(
        bounds[None, :, None] - run_start[:, None, :], 0, run_len[:, None, :]
    ).sum(-1)                                                # (D, D+1)
    send_matrix = (below[:, 1:] - below[:, :-1]).astype(jnp.int32)   # (D_src, D_dst)
    input_offsets_all = below[:, :-1].astype(jnp.int32)              # (D_src, D_dst)
    return input_offsets_all, send_matrix, g_flat, totals


def _expand(mask, ndim):
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def _transport_dense_positions(buf, positions, in_off, send, axis_name):
    """Position-carrying dense transport (XLA:CPU-compilable fallback).

    Each source's run for destination d is one contiguous local segment
    (guaranteed by the local reorder); we pad each segment to the shard size,
    ship (data, global position) with a dense ``all_to_all``, and the
    receiver scatters by position. Correct for any interleaving at the
    destination — used on CPU and as the DMS (no-ragged-possible) baseline.
    """
    n_dev = buf.shape[0]
    d_num = send.shape[0]
    idx = jnp.arange(n_dev, dtype=jnp.int32)
    gidx = jnp.clip(in_off[:, None] + idx[None, :], 0, n_dev - 1)      # (D, n_dev)
    send_mask = idx[None, :] < send[:, None]

    def pack(x, fill):
        g = x[gidx.reshape(-1)].reshape((d_num, n_dev) + x.shape[1:])
        return jnp.where(_expand(send_mask, x.ndim), g, fill)

    send_buf = pack(buf, 0)
    send_pos = pack(positions, -1)
    recv_buf = jax.lax.all_to_all(send_buf, axis_name, split_axis=0, concat_axis=0)
    recv_pos = jax.lax.all_to_all(send_pos, axis_name, split_axis=0, concat_axis=0)
    my_idx = jax.lax.axis_index(axis_name)
    local_pos = recv_pos.reshape(-1) - my_idx * n_dev
    local_pos = jnp.where(recv_pos.reshape(-1) < 0, n_dev, local_pos)  # pads -> dropped
    out = jnp.zeros((n_dev,) + buf.shape[1:], buf.dtype)
    return out.at[local_pos].set(recv_buf.reshape((-1,) + buf.shape[1:]), mode="drop")


def multisplit_sharded(
    keys: Array,
    bucket_fn: BucketSpec,
    values: Optional[Array] = None,
    *,
    axis_name: str,
    method: str = "bms",
    use_pallas: bool = False,
    backend: Optional[str] = None,
    tile: Optional[int] = None,
    transport: str = "dense",
) -> ShardedMultisplitResult:
    """Exact global stable multisplit across a mesh axis.

    Must be called inside ``shard_map`` over ``axis_name``; ``keys`` is this
    device's equal-size shard. Output: shard ``d`` of the result holds global
    positions ``[d*n_dev, (d+1)*n_dev)`` of the bucket-major output.

    ``transport="dense"`` ships (data, position) pairs with a padded dense
    ``all_to_all`` (XLA:CPU-compilable). ``transport="ragged"`` (TPU target)
    composes two single-segment ``ragged_all_to_all`` hops: a bucket-sharded
    hop (see :func:`multisplit_bucket_sharded`) followed by an equal-shard
    rebalance — each hop's per-peer payload is one contiguous run, which is
    exactly the paper's reorder-for-coalescing property lifted to ICI.
    """
    n_dev = keys.shape[0]
    my_idx = jax.lax.axis_index(axis_name)

    # ---- local stage: reorder shard bucket-major, get local histogram ----
    local = _local_plan(keys, bucket_fn, values, method, use_pallas, backend, tile)

    # ---- global stage: ONE tiny collective over H (D, m) + replicated scan ----
    hist_all = jax.lax.all_gather(local.bucket_counts, axis_name)    # (D, m)
    in_off_all, send_all, g_flat, totals = _send_plan(hist_all, n_dev)
    in_off = in_off_all[my_idx]
    send = send_all[my_idx]

    # global output position of each local (reordered) element: strictly
    # increasing in local index (bucket-major local x bucket-major global)
    m = bucket_fn.num_buckets
    local_starts = jnp.cumsum(local.bucket_counts) - local.bucket_counts   # (m,)
    c_excl = (jnp.cumsum(hist_all, axis=0) - hist_all)[my_idx]             # (m,)
    lidx = jnp.arange(n_dev, dtype=jnp.int32)
    lids = jnp.searchsorted(jnp.cumsum(local.bucket_counts), lidx, side="right").astype(jnp.int32)
    rank_in_bucket = lidx - local_starts[lids]
    positions = g_flat[lids] + c_excl[lids] + rank_in_bucket               # (n_dev,)

    move = lambda buf: _transport_dense_positions(buf, positions, in_off, send, axis_name)
    keys_out = move(local.keys)
    values_out = move(local.values) if values is not None else None
    return ShardedMultisplitResult(keys_out, values_out, g_flat, totals.astype(jnp.int32))


class BucketShardedResult(NamedTuple):
    keys: Array                 # (capacity,) this device's bucket-group elements, bucket-major
    values: Optional[Array]
    count: Array                # (1,) number of valid elements in this shard
    group_counts: Array         # (m/D,) per-bucket counts within my group
    bucket_counts: Array        # (m,) GLOBAL histogram (replicated)


def multisplit_bucket_sharded(
    keys: Array,
    bucket_fn: BucketSpec,
    values: Optional[Array] = None,
    *,
    axis_name: str,
    capacity: int,
    method: str = "bms",
    use_pallas: bool = False,
    backend: Optional[str] = None,
    tile: Optional[int] = None,
    transport: str = "dense",
) -> BucketShardedResult:
    """Bucket-sharded multisplit: device ``d`` receives all elements of
    buckets ``[d*m/D, (d+1)*m/D)``, bucket-major, padded to ``capacity``.

    This is the MoE expert-dispatch layout. Per (src, dst) peer the payload
    is ONE contiguous run of the source's reordered buffer AND one contiguous
    run of the receiver's buffer (src-major layout) — so the TPU transport is
    a single ``ragged_all_to_all``. A final LOCAL multisplit restores
    bucket-major order: local -> global -> local, the paper's model verbatim.

    Elements beyond ``capacity`` are dropped (standard MoE semantics);
    ``count`` reports the true load so callers can monitor drops.
    """
    d_num = jax.lax.axis_size(axis_name)
    my_idx = jax.lax.axis_index(axis_name)
    m = bucket_fn.num_buckets
    if m % d_num != 0:
        raise ValueError(f"num_buckets {m} must divide over axis size {d_num}")
    mb = m // d_num
    n_dev = keys.shape[0]

    # local stage
    local = _local_plan(keys, bucket_fn, values, method, use_pallas, backend, tile)
    hist_all = jax.lax.all_gather(local.bucket_counts, axis_name)      # (D, m)

    group = hist_all.reshape(d_num, d_num, mb)                          # (src, dstgroup, mb)
    send_matrix = group.sum(-1).astype(jnp.int32)                       # (src, dst)
    local_starts = (jnp.cumsum(local.bucket_counts) - local.bucket_counts).astype(jnp.int32)
    in_off = local_starts[jnp.arange(d_num) * mb]                       # (dst,) my run starts
    send = send_matrix[my_idx]                                          # (dst,)
    recv = send_matrix[:, my_idx]                                       # (src,)
    out_off = (jnp.cumsum(recv) - recv).astype(jnp.int32)               # src-major receiver layout
    # ragged_all_to_all wants sender-side knowledge of where its chunk lands
    # on each receiver: cumulative sizes of lower-indexed sources there.
    send_out_off = (jnp.cumsum(send_matrix, axis=0) - send_matrix)[my_idx]  # (dst,)

    if transport == "ragged":
        def move(buf):
            out = jnp.zeros((capacity,) + buf.shape[1:], buf.dtype)
            return jax.lax.ragged_all_to_all(
                buf, out, in_off, send, send_out_off, recv, axis_name=axis_name
            )
    else:
        def move(buf):
            idx = jnp.arange(n_dev, dtype=jnp.int32)
            gidx = jnp.clip(in_off[:, None] + idx[None, :], 0, n_dev - 1)
            mask = idx[None, :] < send[:, None]
            packed = jnp.where(
                _expand(mask, buf.ndim),
                buf[gidx.reshape(-1)].reshape((d_num, n_dev) + buf.shape[1:]),
                0,
            )
            recv_buf = jax.lax.all_to_all(packed, axis_name, split_axis=0, concat_axis=0)
            recv_buf = recv_buf.reshape((d_num, n_dev) + buf.shape[1:])
            pos = out_off[:, None] + idx[None, :]
            pos = jnp.where(idx[None, :] < recv[:, None], pos, capacity)  # pads dropped
            out = jnp.zeros((capacity,) + buf.shape[1:], buf.dtype)
            return out.at[jnp.clip(pos, 0, capacity).reshape(-1)].set(
                recv_buf.reshape((-1,) + buf.shape[1:]), mode="drop"
            )

    keys_rx = move(local.keys)
    vals_rx = move(local.values) if values is not None else None

    # final local stage: src-major -> bucket-major within my group.
    # Received buffer is a concatenation of per-src bucket-major chunks; a
    # local multisplit on (bucket id within group) restores global order.
    lo = my_idx * mb
    sub_ids = jnp.clip(bucket_fn(keys_rx) - lo, 0, mb - 1)
    valid = jnp.arange(capacity) < jnp.minimum(recv.sum(), capacity)
    sub_ids = jnp.where(valid, sub_ids, mb - 1)  # pads ride in the last sub-bucket
    sub_local, sub_hist = ms.tile_local_offsets(sub_ids, mb)
    sub_starts = (jnp.cumsum(sub_hist) - sub_hist).astype(jnp.int32)
    dest = sub_starts[sub_ids] + sub_local
    keys_out = jnp.zeros_like(keys_rx).at[dest].set(keys_rx)
    vals_out = None
    if vals_rx is not None:
        vals_out = jnp.zeros_like(vals_rx).at[dest].set(vals_rx)

    group_counts = hist_all.sum(0).reshape(d_num, mb)[my_idx].astype(jnp.int32)
    return BucketShardedResult(
        keys_out, vals_out, jnp.minimum(recv.sum(), capacity)[None],
        group_counts, hist_all.sum(0).astype(jnp.int32),
    )


def make_multisplit_sharded(
    bucket_fn: BucketSpec, mesh, axis_name: str, key_value: bool = False, **kw
):
    """Convenience: wrap ``multisplit_sharded`` in shard_map over one axis."""
    from jax.sharding import PartitionSpec as P

    if key_value:
        def fn(keys, values):
            return multisplit_sharded(keys, bucket_fn, values, axis_name=axis_name, **kw)

        in_specs = (P(axis_name), P(axis_name))
    else:
        def fn(keys):
            return multisplit_sharded(keys, bucket_fn, axis_name=axis_name, **kw)

        in_specs = (P(axis_name),)

    out_specs = ShardedMultisplitResult(
        P(axis_name), P(axis_name) if key_value else None, P(), P()
    )
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
