"""Multisplit-based radix sort (paper §7.1) and the sort-based baselines (§3).

* ``radix_sort``           — LSD radix sort built from iterated multisplit
                             with identity-bit buckets ``f_k``; the paper's
                             "multisplit-sort". Executes as a CHAINED
                             :class:`~repro.core.pipeline.radix.RadixPipeline`
                             (DESIGN.md §10): tiles resolved once, buffers
                             padded once, ping-pong across digit passes.
* ``radix_sort_per_pass``  — the PR-2 execution (one full plan round trip —
                             pad, tile, run, slice — per digit pass). Kept
                             verbatim as the chained-vs-per-pass benchmark
                             baseline and bitwise-equivalence witness.
* ``rb_sort_multisplit``   — the paper's *reduced-bit sort* baseline (§3.4):
                             multisplit implemented by sorting ⌈log m⌉-bit
                             labels with the platform sort primitive
                             (``jax.lax.sort`` standing in for CUB).
* ``direct_sort_multisplit`` — the §3.3 baseline: a full key sort, valid
                             only for monotone bucket identifiers, and
                             non-stable as a multisplit.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import multisplit as ms
from repro.core.identifiers import BucketSpec
from repro.core.pipeline import (
    RadixPipeline,
    make_radix_plan,
    make_segmented_radix_plan,
    radix_passes,
    resolve_backend,
)

Array = jnp.ndarray


def radix_sort(
    keys: Array,
    values: Optional[Array] = None,
    *,
    radix_bits: int = 8,
    key_bits: int = 32,
    method: str = "bms",
    use_pallas: bool = False,
    interpret: bool = True,
    backend: Optional[str] = None,
    tile: Optional[int] = None,
    family: Optional[str] = None,
    fuse_digits: bool = False,
) -> Tuple[Array, Optional[Array]]:
    """Sort uint32 keys with ⌈key_bits/radix_bits⌉ multisplit passes (§7.1).

    Stable. ``radix_bits=8`` means each pass is a 256-bucket multisplit —
    the paper's large-m regime; Table 8 sweeps r in [4, 8].

    Executes as ONE chained :class:`~repro.core.pipeline.radix.RadixPipeline`
    (DESIGN.md §10): tiles are resolved once, the keys/values buffers are
    padded once with the all-ones sentinel (digit m−1 in every pass) and
    stay resident across all digit passes — no per-pass re-pad/re-tile/slice.
    On kernel backends the digit ``f_k(u) = (u >> k·r) & (2^r − 1)`` is
    extracted INSIDE the fused kernels, so no label array is ever
    materialized host-side — the §3.4 RB-sort overhead the paper's
    multisplit-sort avoids (DESIGN.md §5).

    2-D ``(b, n)`` keys sort every row independently through BATCHED radix
    plans (DESIGN.md §9): still one kernel launch per pass, covering all
    rows. Bitwise identical to :func:`radix_sort_per_pass`.

    ``fuse_digits=True`` (DESIGN.md §13) runs adjacent digit passes as FUSED
    PAIRS: one sweep per pair — two digit solves around an in-VMEM reorder
    per tile residency, one HBM scatter per pair instead of per digit
    (r=8 → 2 sweeps instead of 4, plus a trailing single pass for odd
    schedules). Bitwise identical to the unfused sort on every backend.
    """
    resolved = resolve_backend(use_pallas, interpret, backend)
    if keys.ndim == 2:
        batch, n = keys.shape
    else:
        batch, n = None, keys.shape[0]
    pipe = RadixPipeline(
        n,
        radix_bits=radix_bits,
        key_bits=key_bits,
        method=method,
        key_value=values is not None,
        backend=resolved,
        tile=tile,
        batch=batch,
        family=family,
        fuse_digits=fuse_digits,
    )
    return pipe(keys, values)


def segmented_radix_sort(
    keys: Array,
    segment_starts,
    values: Optional[Array] = None,
    *,
    radix_bits: int = 8,
    key_bits: int = 32,
    method: str = "bms",
    use_pallas: bool = False,
    interpret: bool = True,
    backend: Optional[str] = None,
    tile: Optional[int] = None,
    family: Optional[str] = None,
    fuse_digits: bool = False,
) -> Tuple[Array, Optional[Array]]:
    """Sort every ragged segment of flat uint32 ``keys`` independently, in
    ONE chained sequence of ⌈key_bits/radix_bits⌉ segmented multisplit
    passes (DESIGN.md §9/§10) — not one pass sequence per segment.

    ``segment_starts`` is the (s,) ascending start-offset vector of
    :func:`repro.core.multisplit.segmented_multisplit`. Segment membership
    is invariant across passes (elements never cross segment boundaries), so
    the chained pipeline computes the position-keyed segment buffer once and
    keeps it — with the padded keys/values — resident for all passes.
    Stable; bitwise identical to slicing out each segment and running
    :func:`radix_sort` on it.
    """
    resolved = resolve_backend(use_pallas, interpret, backend)
    seg = jnp.asarray(segment_starts, jnp.int32)
    pipe = RadixPipeline(
        keys.shape[0],
        radix_bits=radix_bits,
        key_bits=key_bits,
        method=method,
        key_value=values is not None,
        backend=resolved,
        tile=tile,
        segments=int(seg.shape[0]),
        family=family,
        fuse_digits=fuse_digits,
    )
    return pipe(keys, values, segment_starts=seg)


def radix_sort_per_pass(
    keys: Array,
    values: Optional[Array] = None,
    *,
    radix_bits: int = 8,
    key_bits: int = 32,
    method: str = "bms",
    backend: str = "vmap",
    tile: Optional[int] = None,
    segment_starts=None,
) -> Tuple[Array, Optional[Array]]:
    """The PR-2 radix sort: one full plan round trip PER digit pass.

    Every pass re-resolves a plan and re-enters the generic pipeline front
    door, which re-pads the (already pad-free) buffers to a tile multiple,
    re-tiles them, and slices the tail back off — ⌈key_bits/r⌉ times. Kept
    verbatim as the benchmark baseline for the chained
    :class:`~repro.core.pipeline.radix.RadixPipeline` (which pads/tiles
    exactly once) and as its bitwise-equivalence witness in the tests.
    Handles the same flat / batched / segmented layouts.
    """
    if keys.ndim == 2:
        batch, n = keys.shape
    else:
        batch, n = None, keys.shape[0]
    seg = None
    if segment_starts is not None:
        seg = jnp.asarray(segment_starts, jnp.int32)
    for shift, bits in radix_passes(radix_bits, key_bits):
        if seg is not None:
            plan = make_segmented_radix_plan(
                n, int(seg.shape[0]), shift, bits, method=method,
                key_value=values is not None, backend=backend, tile=tile,
            )
            res = plan(keys, values, segment_starts=seg)
        else:
            plan = make_radix_plan(
                n, shift, bits, method=method, key_value=values is not None,
                backend=backend, tile=tile, batch=batch,
            )
            res = plan(keys, values)
        keys = res.keys
        values = res.values
    return keys, values


def rb_sort_multisplit(
    keys: Array,
    bucket_fn: BucketSpec,
    values: Optional[Array] = None,
) -> ms.MultisplitResult:
    """Reduced-bit-sort baseline (§3.4): sort (label, payload) by label.

    Key-only: sort (label, key) pairs. Key-value: pack key+value into the
    payload (the paper packs into a 64-bit word; ``jax.lax.sort`` natively
    sorts multiple operands, which is the same trick without the pack).
    """
    m = bucket_fn.num_buckets
    labels = bucket_fn(keys)
    if values is None:
        labels_s, keys_s = jax.lax.sort((labels, keys), num_keys=1, is_stable=True)
        values_s = None
    else:
        labels_s, keys_s, values_s = jax.lax.sort(
            (labels, keys, values), num_keys=1, is_stable=True
        )
    one_hot = (labels_s[:, None] == jnp.arange(m)[None, :]).astype(jnp.int32)
    counts = one_hot.sum(axis=0)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
    )
    perm = jnp.zeros_like(labels).at[jnp.argsort(labels, stable=True)].set(
        jnp.arange(labels.shape[0], dtype=jnp.int32)
    )
    return ms.MultisplitResult(keys_s, values_s, starts, counts.astype(jnp.int32), perm)


def direct_sort_multisplit(
    keys: Array, values: Optional[Array] = None
) -> Tuple[Array, Optional[Array]]:
    """§3.3 baseline: full sort of the keys themselves (monotone buckets only)."""
    if values is None:
        return jax.lax.sort(keys), None
    keys_s, values_s = jax.lax.sort((keys, values), num_keys=1)
    return keys_s, values_s
