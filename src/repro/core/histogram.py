"""Device-wide histogram built from the multisplit prescan (paper §7.3).

The paper reuses the pre-scan stage (tile histograms) and sums across
subproblems instead of scanning — on TPU the "atomic add into the global
array" becomes a tree reduction over the per-tile histogram matrix (no
atomics; DESIGN.md §2). This is exactly a ``counts_only`` partial pipeline
(DESIGN.md §10): {prescan, reduce}, no scan, no scatter — so ``histogram``
is a thin wrapper over one :func:`repro.core.pipeline.make_plan` call. Tile
sizes come from the shared heuristic/autotune cache (the old hardcoded
per-module tile constant and private plan-layer reach are gone).
``histogram_even`` / ``histogram_range`` mirror CUB's HistogramEven /
HistogramRange used as the paper's comparison.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.identifiers import BucketSpec, even_buckets, range_buckets
from repro.core.pipeline import make_plan, resolve_backend

Array = jnp.ndarray


def histogram(
    keys: Array,
    bucket_fn: BucketSpec,
    *,
    tile: Optional[int] = None,
    use_pallas: bool = False,
    interpret: bool = True,
    backend: Optional[str] = None,
) -> Array:
    """Global bucket counts: a ``counts_only`` pipeline (prescan + reduce).

    ``tile=None`` resolves through the shared per-shape heuristic/autotune
    cache — the same tile every other consumer of this shape gets.
    """
    plan = make_plan(
        keys.shape[0],
        bucket_fn.num_buckets,
        method="bms",
        backend=resolve_backend(use_pallas, interpret, backend),
        tile=tile,
        bucket_fn=bucket_fn,
        mode="counts_only",
    )
    return plan(keys).bucket_counts


def histogram_even(
    keys: Array, lo: float, hi: float, num_buckets: int, **kw
) -> Array:
    """Evenly spaced bins (paper §7.3 scenario 1)."""
    return histogram(keys, even_buckets(lo, hi, num_buckets), **kw)


def histogram_range(keys: Array, splitters: Array, **kw) -> Array:
    """Arbitrary splitter bins via binary search (paper §7.3 scenario 2)."""
    return histogram(keys, range_buckets(splitters), **kw)
