"""Device-wide histogram built from the multisplit prescan (paper §7.3).

The paper reuses the pre-scan stage (tile histograms) and sums across
subproblems instead of scanning — on TPU the "atomic add into the global
array" becomes a tree reduction over the per-tile histogram matrix (no
atomics; DESIGN.md §2). ``histogram_even`` / ``histogram_range`` mirror
CUB's HistogramEven / HistogramRange used as the paper's comparison.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import multisplit as ms
from repro.core.identifiers import BucketIdentifier, even_buckets, range_buckets

Array = jnp.ndarray

HIST_TILE = 4096


def histogram(
    keys: Array,
    bucket_fn: BucketIdentifier,
    *,
    tile: int = HIST_TILE,
    use_pallas: bool = False,
    interpret: bool = True,
) -> Array:
    """Global bucket counts: prescan tiles, then reduce (no global scan)."""
    m = bucket_fn.num_buckets
    ids = bucket_fn(keys)
    n = ids.shape[0]
    ids_p, n_pad = ms._pad_to_tiles(ids, tile, m - 1)
    ids_tiled = ids_p.reshape(-1, tile)
    if use_pallas:
        from repro.kernels import ops as kops

        hist = kops.tile_histograms(ids_tiled, m, interpret=interpret)
    else:
        hist = ms.prescan(ids_tiled, m)
    counts = hist.sum(axis=0).astype(jnp.int32)
    return counts.at[m - 1].add(-n_pad)


def histogram_even(
    keys: Array, lo: float, hi: float, num_buckets: int, **kw
) -> Array:
    """Evenly spaced bins (paper §7.3 scenario 1)."""
    return histogram(keys, even_buckets(lo, hi, num_buckets), **kw)


def histogram_range(keys: Array, splitters: Array, **kw) -> Array:
    """Arbitrary splitter bins via binary search (paper §7.3 scenario 2)."""
    return histogram(keys, range_buckets(splitters), **kw)
