"""Graceful degradation + runtime verification for kernel dispatch
(DESIGN.md §17).

PR 8 made ``backend="pallas"`` mean *compiled-when-available*, but ROADMAP
item 1 is honest about what host CI cannot prove: no CPU runner can show
that Mosaic accepts every kernel body on a real TPU, that the VMEM cost
constants hold, or that a compiled kernel never miscompiles.  Until then —
and on real hardware after then — any lowering failure, resource exhaustion
or silent wrong answer would surface as an unhandled exception (or worse,
wrong data) in the middle of a serving step.  This module is the safety
net between the plan layer and its callers:

* **Failure taxonomy.** :func:`classify` wraps raw XLA/Mosaic/runtime
  exceptions into :class:`KernelLoweringError` (persistent — the body will
  never lower), :class:`KernelResourceError` (persistent but
  tile-shrinkable — VMEM/HBM exhaustion scales with the tile working set),
  or a *transient* :class:`KernelDispatchError` (preemption, link flap —
  worth retrying in place).  Programming errors (``ValueError`` from shape
  validation etc.) classify as ``None`` and always propagate untouched:
  the ladder degrades EXECUTION failures, never masks caller bugs.
* **Degradation ladder.** :func:`dispatch` runs one operation with bounded
  fallback: transient errors retry in place; a resource error first
  halves the tile (down to ``_MIN_TILE``, pinning the survivor in the tile
  cache so the shape class never re-learns the lesson); persistent errors
  demote the backend along :data:`DEMOTION_ORDER`
  (``pallas → pallas-interpret → vmap → reference``).  The reference
  oracle is the floor — a failure there re-raises.  ``REPRO_STRICT=1`` /
  :func:`set_strict` disables all fallback (CI/debug: fail loud).
* **Circuit breaker.** Per ``(spec, shape, backend)`` plan class, repeated
  persistent failures (:data:`BREAKER_THRESHOLD`) quarantine the class in
  a persistent autotune-style JSON sidecar (same directory, same atomic
  write/lazy-load/fingerprint discipline as
  :mod:`repro.core.pipeline.autotune`), so later *processes* skip the
  doomed attempt and start one rung down.
  ``clear_tile_cache()`` drops only the in-memory snapshot — the
  quarantine survives the reload, like a fresh process against a warm
  cache file; ``clear_tile_cache(disk=True)`` deletes it.
* **Runtime verification.** :func:`set_verify` / ``REPRO_VERIFY`` arm
  opt-in output checking: level 1 is O(m) — counts conservation
  (Σcounts == n) and offset monotonicity (starts == exclusive cumsum);
  level 2 is O(n log n) — the output is a true permutation of the input
  with non-decreasing bucket ids and a valid permutation vector.  On
  mismatch the op re-runs on the reference backend (the returned result is
  always trustworthy), emits a minimal structured repro report
  (spec, shape, backend, seed), counts a ``verify_mismatch``, and strikes
  the breaker so the lying backend demotes like any other failure.
* **Fault injection.** :func:`set_fault_injector` arms a
  :class:`~repro.runtime.supervisor.FaultInjector` at the dispatch site
  (seeded, per-backend), so the whole ladder is exercisable without a TPU
  — the chaos suite (``tests/test_resilience.py``) and the CI chaos-smoke
  step drive it at rate 0.05.

Everything here is host-side and eager: exceptions cannot cross a jit
trace, so the facade (:mod:`repro.ops`) bypasses the ladder under tracing
and the serving loop (:mod:`repro.serving.engine`) applies it at its own
eager flush boundary.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import re
import tempfile
import threading
from collections import deque
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

log = logging.getLogger("repro.resilience")

SCHEMA_VERSION = 1

# The fallback chain, best first.  Backends outside the chain (future
# registrations) demote straight to the oracle.
DEMOTION_ORDER = ("pallas", "pallas-interpret", "vmap", "reference")

# Persistent failures per plan class before the breaker trips and the
# class is quarantined on disk.
BREAKER_THRESHOLD = 3

# In-place retries per rung for transient failures before demoting anyway.
MAX_TRANSIENT_RETRIES = 2

_ENV_STRICT = "REPRO_STRICT"
_ENV_VERIFY = "REPRO_VERIFY"

_TRUE = ("1", "true", "yes", "on")


# ---------------------------------------------------------------------------
# Failure taxonomy
# ---------------------------------------------------------------------------

class KernelDispatchError(RuntimeError):
    """A classified kernel-dispatch failure wrapping the raw exception.

    ``transient`` marks failures worth retrying in place (preemption,
    link flap); persistent failures go straight to tile-shrink/demotion.
    ``original`` is the exception as raised; ``backend``/``plan_class``
    locate the failure for the breaker and the repro report.
    """

    transient = False

    def __init__(self, message: str, *, original: Optional[BaseException] = None,
                 backend: Optional[str] = None,
                 plan_class: Optional[Tuple] = None):
        super().__init__(message)
        self.original = original
        self.backend = backend
        self.plan_class = plan_class
        self.__cause__ = original


class KernelLoweringError(KernelDispatchError):
    """The kernel body does not lower (Mosaic rejection, unimplemented
    primitive): persistent — retrying the same program cannot succeed."""


class KernelResourceError(KernelDispatchError):
    """Resource exhaustion (VMEM/HBM OOM): persistent for THIS tile, but
    the working set scales with the tile — halve-and-retry first."""


class KernelResultError(KernelDispatchError):
    """The kernel ran but produced a wrong answer (runtime verification
    mismatch): the most dangerous class — recover via the oracle."""


class TransientDispatchError(KernelDispatchError):
    """Environmental failure (preemption, DEADLINE_EXCEEDED, link flap):
    worth a bounded in-place retry before degrading."""

    transient = True


# Marker → class tables.  XLA/Mosaic error surfaces are strings, not types;
# the injected-fault messages deliberately carry the same markers so the
# chaos suite exercises the real classifier, not a test-only side door.
_RESOURCE_MARKERS = (
    "resource_exhausted", "out of memory", "oom", "vmem", "smem",
    "scratch limit", "allocat",
)
_LOWERING_MARKERS = (
    "mosaic", "lowering", "unsupported", "not implemented", "unimplemented",
    "internal: failed to compile", "does not lower",
)
_TRANSIENT_MARKERS = (
    "deadline_exceeded", "unavailable", "aborted", "cancelled", "preempt",
    "connection reset", "transient",
)


def _marked(msg: str, markers: Tuple[str, ...]) -> bool:
    # left word boundary only: "oom" must not match "boom", but "allocat"
    # must still match "allocating"/"allocation"
    return any(re.search(r"(?<![a-z0-9])" + re.escape(m), msg)
               for m in markers)


def classify(exc: BaseException, *, backend: Optional[str] = None,
             plan_class: Optional[Tuple] = None) -> Optional[KernelDispatchError]:
    """Wrap a raw dispatch exception into the taxonomy, or return ``None``
    for exceptions the ladder must NOT handle (programming/validation
    errors — ``ValueError``/``TypeError`` raised by our own argument
    checks propagate untouched, on every rung)."""
    if isinstance(exc, KernelDispatchError):
        return exc
    msg = f"{type(exc).__name__}: {exc}".lower()
    kw: Dict[str, Any] = dict(original=exc, backend=backend, plan_class=plan_class)
    if isinstance(exc, (ValueError, TypeError)) and not _marked(
            msg, _RESOURCE_MARKERS + _LOWERING_MARKERS):
        return None
    if isinstance(exc, MemoryError) or _marked(msg, _RESOURCE_MARKERS):
        return KernelResourceError(f"[{backend}] {exc}", **kw)
    if isinstance(exc, NotImplementedError) or _marked(msg, _LOWERING_MARKERS):
        return KernelLoweringError(f"[{backend}] {exc}", **kw)
    if _marked(msg, _TRANSIENT_MARKERS):
        return TransientDispatchError(f"[{backend}] {exc}", **kw)
    # Unknown runtime failure: treat as a persistent dispatch error — the
    # ladder degrades it, the breaker learns it, strict mode re-raises it.
    if isinstance(exc, (RuntimeError, OSError)):
        return KernelDispatchError(f"[{backend}] {exc}", **kw)
    return None


# ---------------------------------------------------------------------------
# Configuration: strict + verify (env-resolved, override via setters)
# ---------------------------------------------------------------------------

_STRICT_OVERRIDE: Optional[bool] = None
_VERIFY_OVERRIDE: Optional[int] = None


def set_strict(enabled: Optional[bool]) -> None:
    """Disable (``True``) all fallback: no ladder, no quarantine skip, no
    verify recovery — the original exception propagates.  ``None`` defers
    back to the ``REPRO_STRICT`` environment variable."""
    global _STRICT_OVERRIDE
    _STRICT_OVERRIDE = None if enabled is None else bool(enabled)


def strict() -> bool:
    if _STRICT_OVERRIDE is not None:
        return _STRICT_OVERRIDE
    return os.environ.get(_ENV_STRICT, "").strip().lower() in _TRUE


def set_verify(level: Optional[int]) -> None:
    """Arm runtime output verification: 0 off, 1 = O(m) counts conservation
    + offset monotonicity, 2 = full permutation + bucket-order check
    (DESIGN.md §17).  ``None`` defers back to ``REPRO_VERIFY``."""
    global _VERIFY_OVERRIDE
    if level is None:
        _VERIFY_OVERRIDE = None
        return
    level = int(level)
    if not 0 <= level <= 2:
        raise ValueError(f"verify level must be 0, 1 or 2, got {level}")
    _VERIFY_OVERRIDE = level


def verify_level() -> int:
    if _VERIFY_OVERRIDE is not None:
        return _VERIFY_OVERRIDE
    raw = os.environ.get(_ENV_VERIFY, "").strip()
    if not raw:
        return 0
    try:
        return max(0, min(2, int(raw)))
    except ValueError:
        return 1 if raw.lower() in _TRUE else 0


# ---------------------------------------------------------------------------
# Counters, events, repro reports
# ---------------------------------------------------------------------------

_COUNTER_KEYS = (
    "degradations", "tile_shrinks", "backend_demotions", "transient_retries",
    "quarantine_skips", "breaker_trips", "verify_checks", "verify_mismatches",
    "reference_reruns",
)
_STATS: Dict[str, int] = {k: 0 for k in _COUNTER_KEYS}
_EVENTS: deque = deque(maxlen=256)
_REPORTS: deque = deque(maxlen=32)
_LOCK = threading.Lock()


def stats() -> Dict[str, int]:
    """Snapshot of the degradation/verification counters since process
    start (or :func:`reset_stats`)."""
    with _LOCK:
        return dict(_STATS)


def reset_stats() -> None:
    with _LOCK:
        for k in _COUNTER_KEYS:
            _STATS[k] = 0
        _EVENTS.clear()
        _REPORTS.clear()


def _count(key: str, n: int = 1) -> None:
    with _LOCK:
        _STATS[key] += n


def _event(kind: str, **fields) -> None:
    with _LOCK:
        _EVENTS.append({"kind": kind, **fields})


def events() -> Tuple[Dict[str, Any], ...]:
    """The last ≤256 degradation events (the CI chaos-smoke step renders
    these as the markdown step summary)."""
    with _LOCK:
        return tuple(dict(e) for e in _EVENTS)


def reports() -> Tuple[Dict[str, Any], ...]:
    """The last ≤32 structured verify-mismatch repro reports."""
    with _LOCK:
        return tuple(dict(r) for r in _REPORTS)


def last_report() -> Optional[Dict[str, Any]]:
    with _LOCK:
        return dict(_REPORTS[-1]) if _REPORTS else None


def _emit_report(ctx: "DispatchContext", backend: str, detail: str) -> Dict[str, Any]:
    """The minimal structured repro report of one verify mismatch: enough
    to rebuild the failing plan (spec, shape, backend, seed), nothing
    process-local."""
    report = {
        "spec": ctx.spec_name,
        "shape": ctx.shape,
        "num_buckets": ctx.num_buckets,
        "method": ctx.method,
        "key_value": ctx.key_value,
        "mode": ctx.mode,
        "layout": ctx.layout,
        "backend": backend,
        "seed": ctx.seed,
        "detail": detail,
    }
    with _LOCK:
        _REPORTS.append(report)
    log.error("verify mismatch: %s", json.dumps(report, sort_keys=True, default=str))
    return report


# ---------------------------------------------------------------------------
# Circuit breaker + persistent quarantine (the autotune-cache discipline)
# ---------------------------------------------------------------------------

_BREAKER: Dict[str, int] = {}        # class key -> persistent-failure strikes
_QUAR_MEM: Dict[str, str] = {}       # class key -> reason (process-local view)
_QUAR_LOADED: Optional[Dict[str, str]] = None   # lazy disk snapshot


def quarantine_path():
    """The quarantine sidecar lives next to the autotune cache (same
    ``REPRO_AUTOTUNE_DIR`` / ``set_autotune(cache_dir=...)`` override), but
    in its OWN file: tuning facts and failure facts have different
    lifetimes and clearing one must not clear the other."""
    from repro.core.pipeline import autotune as _at

    return _at.cache_path().parent / "multisplit_resilience.json"


def _q_entries() -> Dict[str, str]:
    """Lazily-loaded disk snapshot; missing/corrupt/stale-version files
    load as empty (clean fallback, mirroring the autotune layer)."""
    global _QUAR_LOADED
    if _QUAR_LOADED is None:
        _QUAR_LOADED = {}
        try:
            with open(quarantine_path()) as f:
                raw = json.load(f)
            if (isinstance(raw, dict)
                    and raw.get("version") == SCHEMA_VERSION
                    and isinstance(raw.get("entries"), dict)):
                _QUAR_LOADED = {str(k): str(v) for k, v in raw["entries"].items()}
        except (OSError, ValueError):
            pass
    return _QUAR_LOADED


def _q_flush(entries: Dict[str, str]) -> None:
    """Atomic tempfile + ``os.replace`` write; best-effort (an unwritable
    dir degrades to in-memory quarantine, never an error)."""
    path = quarantine_path()
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), prefix=".resilience-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": SCHEMA_VERSION, "entries": entries},
                          f, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
    except OSError:
        pass


def class_key(plan_class: Tuple, backend: str) -> str:
    """fingerprint | quarantine | plan-class parts | backend — the same
    key discipline as the autotune disk layer, so a quarantine entry is a
    per-host fact like a tuned tile."""
    from repro.core.pipeline import autotune as _at

    parts = "|".join(str(x) for x in plan_class)
    return f"{_at.host_fingerprint()}|quarantine|{parts}|{backend}"


def quarantine(key: str, reason: str) -> None:
    """Quarantine one (plan class, backend): in memory AND on disk, so a
    later process skips the doomed attempt."""
    _QUAR_MEM[key] = reason
    ent = dict(_q_entries())
    ent[key] = reason
    _q_flush(ent)
    global _QUAR_LOADED
    _QUAR_LOADED = ent


def is_quarantined(key: str) -> Optional[str]:
    """The quarantine reason for a class key, or None.  Consults the
    process-local view first, then the (lazily loaded) disk snapshot —
    the survival path across ``clear_tile_cache()`` / process restarts."""
    hit = _QUAR_MEM.get(key)
    if hit is not None:
        return hit
    return _q_entries().get(key)


def record_failure(key: str, err: KernelDispatchError) -> bool:
    """One persistent failure strike against a plan class; trips the
    breaker (and quarantines) at :data:`BREAKER_THRESHOLD`.  Returns True
    when this strike tripped it."""
    strikes = _BREAKER.get(key, 0) + 1
    _BREAKER[key] = strikes
    if strikes >= BREAKER_THRESHOLD and key not in _QUAR_MEM:
        reason = f"{type(err).__name__} x{strikes}: {err}"
        quarantine(key, reason)
        _count("breaker_trips")
        _event("breaker_trip", key=key, reason=reason)
        log.warning("circuit breaker tripped: %s", reason)
        return True
    return False


def breaker_strikes() -> Dict[str, int]:
    return dict(_BREAKER)


def quarantine_snapshot() -> Dict[str, str]:
    """Every quarantined class visible right now (memory ∪ disk)."""
    merged = dict(_q_entries())
    merged.update(_QUAR_MEM)
    return merged


def drop_loaded() -> None:
    """Forget the in-process quarantine view; the next check re-reads the
    file (what a fresh process would see).  Called by
    ``clear_tile_cache()`` so the quarantine *survives* the reload."""
    global _QUAR_LOADED
    _QUAR_LOADED = None
    _QUAR_MEM.clear()
    _BREAKER.clear()


def clear_quarantine(disk: bool = False) -> None:
    """Drop the quarantine: memory always; ``disk=True`` deletes the
    sidecar file too (``clear_tile_cache(disk=True)``)."""
    global _QUAR_LOADED
    _QUAR_MEM.clear()
    _BREAKER.clear()
    if disk:
        _QUAR_LOADED = {}
        try:
            os.remove(quarantine_path())
        except OSError:
            pass
    else:
        _QUAR_LOADED = None


# ---------------------------------------------------------------------------
# Dispatch-level fault injection (exercising the ladder without a TPU)
# ---------------------------------------------------------------------------

_FAULT_INJECTOR: Optional[Any] = None


def set_fault_injector(injector: Optional[Any]) -> None:
    """Arm a :class:`~repro.runtime.supervisor.FaultInjector` (anything
    with ``check_dispatch(backend)``) at the kernel-dispatch site; ``None``
    disarms.  Injected exceptions carry classifiable messages, so the real
    classifier — not a test-only door — routes them down the ladder."""
    global _FAULT_INJECTOR
    _FAULT_INJECTOR = injector


def fault_injector() -> Optional[Any]:
    return _FAULT_INJECTOR


def check_faults(backend: str) -> None:
    """The injection site: called once per dispatch attempt (facade AND
    serving launch) with the attempt's backend."""
    if _FAULT_INJECTOR is not None:
        _FAULT_INJECTOR.check_dispatch(backend)


# ---------------------------------------------------------------------------
# The degradation ladder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DispatchContext:
    """The plan-class identity of one dispatch: what the breaker keys on
    and the repro report serializes.  ``spec_name`` is the bucket spec's
    stable name (never an object id), ``shape`` the input key shape."""

    spec_name: str
    shape: Tuple[int, ...]
    num_buckets: int
    method: str = "bms"
    key_value: bool = False
    mode: str = "reorder"
    layout: str = "flat"            # flat | batched | segmented
    seed: Optional[int] = None

    def plan_class(self) -> Tuple:
        return (self.spec_name, self.shape, self.num_buckets, self.method,
                self.key_value, self.mode, self.layout)


def demote(backend: str) -> Optional[str]:
    """The next rung down, or None at (or below) the reference floor."""
    if backend == "reference":
        return None
    try:
        i = DEMOTION_ORDER.index(backend)
    except ValueError:
        return "reference"          # unknown/future backend: fall to the oracle
    return DEMOTION_ORDER[i + 1]


def _block(result: Any) -> Any:
    """Force async dispatch errors to surface inside the try (jax errors
    are lazy; an unconsumed result can fail after dispatch returns)."""
    import jax

    jax.block_until_ready(jax.tree.leaves(result))
    return result


def dispatch(
    run: Callable[[str, Optional[int]], Any],
    ctx: DispatchContext,
    *,
    backend: str,
    tile: Optional[int] = None,
    resolved_tile: Optional[Callable[[str], int]] = None,
    pin_tile: Optional[Callable[[str, int], None]] = None,
    verifier: Optional[Callable[[Any, str], None]] = None,
) -> Any:
    """Execute ``run(backend, tile)`` under the degradation ladder.

    ``run`` must be re-invocable with any (backend, tile) pair;
    ``resolved_tile(backend)`` reports the tile the plan would auto-resolve
    (the halve-and-retry starting point); ``pin_tile(backend, tile)`` pins
    a shrink survivor in the tile cache; ``verifier(result, backend)``
    raises :class:`KernelResultError` on an output-invariant violation
    (skipped on the reference rung — the oracle defines correctness).

    Strict mode runs the requested config once, verifying if armed, and
    re-raises everything.  Otherwise: quarantined rungs are skipped
    (statically — no attempt), transient failures retry in place
    (:data:`MAX_TRANSIENT_RETRIES`), resource failures halve the tile to
    ``_MIN_TILE`` then demote, other persistent failures demote, verify
    mismatches recover via one reference re-run.  Only a failure on the
    reference rung itself propagates.
    """
    level = verify_level()
    if strict():
        check_faults(backend)
        result = run(backend, tile)
        if verifier is not None and level > 0 and backend != "reference":
            _count("verify_checks")
            verifier(_block(result), backend)
        return result

    from repro.core.pipeline.tiles import _MIN_TILE

    b, t = backend, tile
    transient_left = MAX_TRANSIENT_RETRIES
    shrunk = False
    degraded = False
    # sync inside the try whenever a failure is plausible or must be caught
    # here: verification armed, faults armed, or already degraded once.
    while True:
        key = class_key(ctx.plan_class(), b)
        if b != "reference" and is_quarantined(key):
            _count("quarantine_skips")
            _event("quarantine_skip", key=key, backend=b)
            nb = demote(b)
            _count("backend_demotions")
            _count("degradations")
            b, t, shrunk = nb, None, False
            transient_left = MAX_TRANSIENT_RETRIES
            degraded = True
            continue
        try:
            check_faults(b)
            result = run(b, t)
            sync = degraded or (level > 0) or (_FAULT_INJECTOR is not None)
            if sync:
                _block(result)
            if verifier is not None and level > 0 and b != "reference":
                _count("verify_checks")
                verifier(result, b)
            if shrunk and t is not None and pin_tile is not None:
                pin_tile(b, t)
            return result
        except Exception as exc:  # noqa: BLE001 — the resilience boundary
            err = classify(exc, backend=b, plan_class=ctx.plan_class())
            if err is None or b == "reference":
                raise
            if isinstance(err, KernelResultError):
                _count("verify_mismatches")
                _emit_report(ctx, b, str(err))
                record_failure(key, err)
                _count("reference_reruns")
                _count("degradations")
                _event("verify_fallback", backend=b, spec=ctx.spec_name,
                       shape=ctx.shape, detail=str(err))
                log.warning("verify mismatch on %r; recovering via reference", b)
                return _block(run("reference", None))
            if err.transient and transient_left > 0:
                transient_left -= 1
                _count("transient_retries")
                log.info("transient dispatch failure on %r, retrying: %s", b, err)
                degraded = True
                continue
            record_failure(key, err)
            if isinstance(err, KernelResourceError):
                base = t if t is not None else (
                    resolved_tile(b) if resolved_tile is not None else None)
                if base is not None and base // 2 >= _MIN_TILE:
                    t = base // 2
                    shrunk = True
                    degraded = True
                    _count("tile_shrinks")
                    _count("degradations")
                    _event("tile_shrink", backend=b, tile=t,
                           spec=ctx.spec_name, shape=ctx.shape)
                    log.warning("resource failure on %r; retrying tile=%d", b, t)
                    continue
            nb = demote(b)
            if nb is None:
                raise
            _count("backend_demotions")
            _count("degradations")
            _event("backend_demotion", frm=b, to=nb, spec=ctx.spec_name,
                   shape=ctx.shape, error=type(err).__name__)
            log.warning("demoting backend %r -> %r after %s: %s",
                        b, nb, type(err).__name__, err)
            b, t, shrunk = nb, None, False
            transient_left = MAX_TRANSIENT_RETRIES
            degraded = True


# ---------------------------------------------------------------------------
# Runtime verification (the level-1/level-2 invariants)
# ---------------------------------------------------------------------------

def _fail(detail: str, backend: Optional[str], ctx: Optional[DispatchContext]):
    raise KernelResultError(
        f"[{backend}] output verification failed: {detail}",
        backend=backend,
        plan_class=None if ctx is None else ctx.plan_class(),
    )


def verify_result(
    result: Any,
    *,
    keys: Any,
    spec: Any,
    n: int,
    values: Any = None,
    segment_starts: Any = None,
    mode: str = "reorder",
    level: Optional[int] = None,
    backend: Optional[str] = None,
    ctx: Optional[DispatchContext] = None,
) -> None:
    """Check a :class:`~repro.core.pipeline.stages.MultisplitResult`
    against the paper's invariants (host-side, on concrete arrays).

    Level 1 (O(m)): every counts row sums to its row's element count and
    ``bucket_starts`` is the exclusive cumsum of counts (hence monotone
    non-decreasing).  Level 2 (O(n log n)) additionally proves the output
    keys are a true permutation of the input with non-decreasing bucket
    ids (per row / per segment) and that ``permutation`` is a valid
    (segment-local) permutation vector.  Raises :class:`KernelResultError`
    on the first violated invariant.
    """
    level = verify_level() if level is None else level
    if level <= 0:
        return
    counts = np.asarray(result.bucket_counts)
    starts = np.asarray(result.bucket_starts)
    seg = None if segment_starts is None else np.asarray(segment_starts)

    # ---- level 1: conservation + monotonicity (O(m)) ----
    if (counts < 0).any():
        _fail(f"negative bucket counts: min={counts.min()}", backend, ctx)
    if counts.ndim == 1:                      # flat
        if int(counts.sum()) != n:
            _fail(f"counts conservation: sum={int(counts.sum())} != n={n}",
                  backend, ctx)
    elif seg is not None:                     # segmented: rows are segments
        seg_len = np.diff(np.append(seg, n))
        row_sums = counts.sum(axis=1)
        if not np.array_equal(row_sums, seg_len):
            _fail(f"segment counts conservation: row sums {row_sums.tolist()} "
                  f"!= segment lengths {seg_len.tolist()}", backend, ctx)
    else:                                     # batched: every row is one n
        if not (counts.sum(axis=1) == n).all():
            _fail(f"batched counts conservation: row sums "
                  f"{counts.sum(axis=1).tolist()} != n={n}", backend, ctx)
    expect_starts = np.cumsum(counts, axis=-1) - counts
    if not np.array_equal(starts, expect_starts):
        _fail("bucket_starts is not the exclusive cumsum of counts "
              "(offset monotonicity violated)", backend, ctx)
    if level == 1 or mode == "counts_only":
        return

    # ---- level 2: true permutation + non-decreasing bucket ids ----
    keys_in = np.asarray(keys)
    if result.permutation is not None:
        perm = np.asarray(result.permutation)
        if seg is None:
            flatp = perm.reshape(-1, perm.shape[-1])
            for row in flatp:
                if not np.array_equal(np.sort(row), np.arange(row.shape[0])):
                    _fail("permutation is not a permutation of arange(n)",
                          backend, ctx)
        else:
            bounds = np.append(seg, n)
            for s0, s1 in zip(bounds[:-1], bounds[1:]):
                p = perm[s0:s1]
                if not np.array_equal(np.sort(p), np.arange(s1 - s0)):
                    _fail(f"segment [{s0}:{s1}] permutation is not "
                          "segment-local arange", backend, ctx)
    if mode != "reorder" or result.keys is None:
        return
    keys_out = np.asarray(result.keys)
    ids_out = np.asarray(spec(result.keys))
    ids_in = np.asarray(spec(keys))

    def _check_span(kin, kout, iin, iout, what):
        if not np.array_equal(np.sort(kin), np.sort(kout)):
            _fail(f"{what}: output keys are not a permutation of the input",
                  backend, ctx)
        if iout.shape[0] > 1 and (np.diff(iout) < 0).any():
            _fail(f"{what}: output bucket ids are not non-decreasing",
                  backend, ctx)
        del kin, iin

    if seg is not None:
        bounds = np.append(seg, n)
        for s0, s1 in zip(bounds[:-1], bounds[1:]):
            _check_span(keys_in[s0:s1], keys_out[s0:s1],
                        ids_in[s0:s1], ids_out[s0:s1], f"segment [{s0}:{s1}]")
    elif keys_in.ndim > 1:
        for r in range(keys_in.shape[0]):
            _check_span(keys_in[r], keys_out[r], ids_in[r], ids_out[r],
                        f"batch row {r}")
    else:
        _check_span(keys_in, keys_out, ids_in, ids_out, "flat")
    if values is not None and result.values is not None \
            and result.permutation is not None and seg is None \
            and keys_in.ndim == 1:
        vals_in = np.asarray(values)
        vals_out = np.asarray(result.values)
        perm = np.asarray(result.permutation)
        if not np.array_equal(vals_out[perm], vals_in):
            _fail("values were not carried by the key permutation",
                  backend, ctx)


def verify_routing(out: Any, ids: Any, starts: Any, num_experts: int,
                   capacity: int, *, level: Optional[int] = None,
                   backend: Optional[str] = None) -> None:
    """The serving-step variant (DESIGN.md §16/§17): check one
    ``route_tokens_segmented`` output ``(slot, keep, counts)``.  Level 1:
    per-request expert loads conserve every token.  Level 2: kept slots
    are unique, in range, and each (request, expert) keeps exactly
    ``min(load, capacity)`` tokens.  Raises :class:`KernelResultError`."""
    level = verify_level() if level is None else level
    if level <= 0:
        return
    slot, keep, counts = (np.asarray(x) for x in out)
    ids = np.asarray(ids)
    n = int(ids.shape[0])
    if (counts < 0).any():
        _fail(f"negative routing counts: min={counts.min()}", backend, None)
    if int(counts.sum()) != n:
        _fail(f"routing counts conservation: sum={int(counts.sum())} "
              f"!= tokens={n}", backend, None)
    if level == 1:
        return
    s = counts.shape[0]
    kept = slot[keep.astype(bool)]
    if kept.size != np.unique(kept).size:
        _fail("kept dispatch slots collide", backend, None)
    if kept.size and (kept.min() < 0 or kept.max() >= s * num_experts * capacity):
        _fail("kept dispatch slot out of range", backend, None)
    expect_kept = np.minimum(counts, capacity).sum()
    if int(keep.sum()) != int(expect_kept):
        _fail(f"kept token count {int(keep.sum())} != "
              f"sum(min(load, capacity))={int(expect_kept)}", backend, None)
