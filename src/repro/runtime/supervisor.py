"""Training-loop supervisor: checkpoint/restart, failure retry, elastic
re-mesh, straggler detection.

Fault-tolerance model (designed for 1000+ nodes, exercised here at
container scale — the mechanisms are the deliverable):

* **Checkpoint/restart**: async sharded checkpoints every
  ``checkpoint_every`` steps; on ANY step failure the supervisor restores
  the last committed checkpoint and replays. The data pipeline is
  deterministic in (seed, step), so replayed batches are identical.
* **Step retry with backoff**: transient failures (preemption, ICI link
  flap — simulated via fault injection hooks) retry the step; persistent
  failures trigger restore-and-replay; repeated persistent failures
  trigger elastic re-mesh.
* **Elastic re-mesh**: on device loss the supervisor rebuilds the mesh
  from surviving devices (shrinking the data axis), re-shards the restored
  state with ``jax.device_put``, and recompiles. Throughput degrades
  proportionally instead of halting.
* **Straggler mitigation**: per-step wall times are tracked in a rolling
  window; steps slower than ``straggler_factor`` x median are logged with
  the step fingerprint. At pod scale the same hook feeds the scheduler
  that re-shards data away from slow hosts; here it logs and counts.
"""

from __future__ import annotations

import dataclasses
import logging
import statistics
import time
import zlib
from collections import deque
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.supervisor")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    max_retries_per_step: int = 2
    max_restores: int = 3
    max_remeshes: int = 2
    straggler_window: int = 32
    straggler_factor: float = 2.0
    log_every: int = 10
    # Seeded exponential backoff between step retries (DESIGN.md §17):
    # sleep = min(cap, base * 2**attempt) * (0.5 + u), u ~ U[0, 1) seeded —
    # back-to-back retries against a flapping device just burn the retry
    # budget inside the same failure window.
    retry_backoff_base: float = 0.05
    retry_backoff_cap: float = 2.0
    retry_backoff_seed: int = 0


# Injected dispatch-fault flavors map onto the resilience taxonomy
# (DESIGN.md §17) THROUGH the real classifier: the messages carry the same
# markers real XLA/Mosaic failures do, so the chaos suite exercises
# classification, not a test-only side door.
_DISPATCH_FAULT_MESSAGES = {
    "resource": ("injected dispatch fault: RESOURCE_EXHAUSTED: out of memory "
                 "allocating VMEM scratch"),
    "lowering": ("injected dispatch fault: Mosaic lowering failed: "
                 "unsupported primitive in kernel body"),
    "transient": ("injected dispatch fault: UNAVAILABLE: transient backend "
                  "interruption"),
}


class FaultInjector:
    """Deterministic fault injection for tests: raise at given steps, or (for
    sustained-load benchmarks) at a seeded Bernoulli ``rate`` per check —
    reproducible across runs, independent of wall clock.

    ``dispatch_rate`` arms the second injection site — INSIDE kernel
    dispatch (:func:`repro.runtime.resilience.check_faults`), seeded
    per-backend so every backend sees an independent reproducible fault
    stream.  Injected dispatch faults rotate through ``dispatch_kinds``
    (resource / lowering / transient) with messages the resilience
    classifier recognizes.  The ``reference`` rung is exempt unless
    explicitly listed in ``dispatch_backends`` — the oracle is the ladder's
    floor and must stay trustworthy for results to remain bitwise-correct
    under chaos.
    """

    def __init__(self, fail_at: Dict[int, int] = None, *,
                 rate: float = 0.0, seed: int = 0,
                 dispatch_rate: float = 0.0,
                 dispatch_backends: Optional[Tuple[str, ...]] = None,
                 dispatch_kinds: Tuple[str, ...] = ("resource", "lowering",
                                                    "transient")):
        if not 0.0 <= rate < 1.0:
            raise ValueError(f"rate must be in [0, 1), got {rate}")
        if not 0.0 <= dispatch_rate < 1.0:
            raise ValueError(
                f"dispatch_rate must be in [0, 1), got {dispatch_rate}")
        unknown = set(dispatch_kinds) - set(_DISPATCH_FAULT_MESSAGES)
        if unknown:
            raise ValueError(
                f"unknown dispatch fault kinds {sorted(unknown)}; expected a "
                f"subset of {sorted(_DISPATCH_FAULT_MESSAGES)}")
        self.fail_at = dict(fail_at or {})   # step -> how many times to fail
        self.rate = rate
        self.seed = seed
        self._rng = np.random.RandomState(seed)
        self.injected = 0
        self.dispatch_rate = dispatch_rate
        self.dispatch_backends = (None if dispatch_backends is None
                                  else tuple(dispatch_backends))
        self.dispatch_kinds = tuple(dispatch_kinds)
        self._dispatch_rngs: Dict[str, np.random.RandomState] = {}
        self.dispatch_injected = 0

    def check(self, step: int):
        n = self.fail_at.get(step, 0)
        if n > 0:
            self.fail_at[step] = n - 1
            self.injected += 1
            raise RuntimeError(f"injected fault at step {step}")
        if self.rate and self._rng.random_sample() < self.rate:
            self.injected += 1
            raise RuntimeError(f"injected fault (rate={self.rate}) at step {step}")

    def _backend_rng(self, backend: str) -> np.random.RandomState:
        rng = self._dispatch_rngs.get(backend)
        if rng is None:
            # crc32, not hash(): stable across processes (PYTHONHASHSEED)
            mix = (self.seed ^ zlib.crc32(backend.encode())) & 0x7FFFFFFF
            rng = self._dispatch_rngs[backend] = np.random.RandomState(mix)
        return rng

    def check_dispatch(self, backend: str) -> None:
        """The kernel-dispatch injection site (DESIGN.md §17): seeded
        Bernoulli per (backend, attempt), raising a classifiable fault."""
        if not self.dispatch_rate:
            return
        if self.dispatch_backends is not None:
            if backend not in self.dispatch_backends:
                return
        elif backend == "reference":
            return
        rng = self._backend_rng(backend)
        if rng.random_sample() < self.dispatch_rate:
            kind = self.dispatch_kinds[rng.randint(len(self.dispatch_kinds))]
            self.dispatch_injected += 1
            self.injected += 1
            raise RuntimeError(
                f"{_DISPATCH_FAULT_MESSAGES[kind]} [backend={backend}]")


class Supervisor:
    """Drives (state, batch) -> (state, metrics) with full fault tolerance."""

    def __init__(
        self,
        train_step: Callable,
        batch_fn: Callable[[int], Any],
        loop_cfg: TrainLoopConfig,
        fault_injector: Optional[FaultInjector] = None,
        remesh_fn: Optional[Callable[[Any], Any]] = None,
        sleep_fn: Callable[[float], None] = time.sleep,
    ):
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.cfg = loop_cfg
        self.ckpt = CheckpointManager(loop_cfg.checkpoint_dir, async_saves=True)
        self.faults = fault_injector
        self.remesh_fn = remesh_fn
        self.sleep_fn = sleep_fn          # injectable: tests pass a recorder
        self._backoff_rng = np.random.RandomState(loop_cfg.retry_backoff_seed)
        self.step_times: deque = deque(maxlen=loop_cfg.straggler_window)
        self.stats = {"retries": 0, "restores": 0, "stragglers": 0, "remeshes": 0}
        self.history = []

    def _backoff(self, attempt: int) -> float:
        """Seeded, capped exponential backoff with jitter: deterministic
        given ``retry_backoff_seed``, never above ``retry_backoff_cap``."""
        cfg = self.cfg
        base = min(cfg.retry_backoff_cap, cfg.retry_backoff_base * (2 ** attempt))
        return base * (0.5 + self._backoff_rng.random_sample())

    def run(self, state) -> Any:
        cfg = self.cfg
        start = self.ckpt.latest_step()
        step = 0
        if start is not None:
            state, step = self.ckpt.restore(state, start)
            log.info("resumed from checkpoint step %d", step)
        restores = 0

        while step < cfg.total_steps:
            batch = self.batch_fn(step)
            ok = False
            for attempt in range(cfg.max_retries_per_step + 1):
                try:
                    t0 = time.time()
                    if self.faults is not None:
                        self.faults.check(step)
                    state, metrics = self.train_step(state, batch)
                    jax.block_until_ready(jax.tree.leaves(metrics)[0])
                    dt = time.time() - t0
                    self._track_straggler(step, dt)
                    ok = True
                    break
                except Exception as e:  # noqa: BLE001 — supervisor boundary
                    from repro.runtime import resilience

                    self.stats["retries"] += 1
                    log.warning("step %d attempt %d failed: %s", step, attempt, e)
                    kerr = resilience.classify(e)
                    if isinstance(kerr, (resilience.KernelLoweringError,
                                         resilience.KernelResourceError)):
                        # persistent lowering/resource failure: the same
                        # program cannot succeed on retry — go straight to
                        # restore instead of burning the retry budget
                        log.warning(
                            "step %d: persistent %s; skipping remaining retries",
                            step, type(kerr).__name__)
                        break
                    if attempt < cfg.max_retries_per_step:
                        self.sleep_fn(self._backoff(attempt))
            if not ok:
                restores += 1
                self.stats["restores"] += 1
                if restores > cfg.max_restores:
                    if (self.remesh_fn is not None
                            and self.stats["remeshes"] < cfg.max_remeshes):
                        log.error("restore budget exhausted; elastic re-mesh")
                        state = self.remesh_fn(state)
                        self.stats["remeshes"] += 1
                        restores = 0
                        continue
                    raise RuntimeError("restore + re-mesh budgets exhausted")
                self.ckpt.wait()              # drain in-flight async saves first
                last = self.ckpt.latest_step()
                if last is not None:
                    state, step = self.ckpt.restore(state, last)
                    log.warning("restored checkpoint step %d, replaying", step)
                continue

            if step % cfg.log_every == 0:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                self.history.append({"step": step, **m})
                log.info("step %d: %s", step, {k: round(v, 4) for k, v in m.items()})
            step += 1
            if step % cfg.checkpoint_every == 0 or step == cfg.total_steps:
                self.ckpt.save(step, state)

        self.ckpt.wait()
        return state

    def _track_straggler(self, step: int, dt: float):
        if len(self.step_times) >= 8:
            med = statistics.median(self.step_times)
            if dt > self.cfg.straggler_factor * med:
                self.stats["stragglers"] += 1
                log.warning(
                    "straggler: step %d took %.3fs (median %.3fs)", step, dt, med
                )
        self.step_times.append(dt)
