"""Training-loop supervisor: checkpoint/restart, failure retry, elastic
re-mesh, straggler detection.

Fault-tolerance model (designed for 1000+ nodes, exercised here at
container scale — the mechanisms are the deliverable):

* **Checkpoint/restart**: async sharded checkpoints every
  ``checkpoint_every`` steps; on ANY step failure the supervisor restores
  the last committed checkpoint and replays. The data pipeline is
  deterministic in (seed, step), so replayed batches are identical.
* **Step retry with backoff**: transient failures (preemption, ICI link
  flap — simulated via fault injection hooks) retry the step; persistent
  failures trigger restore-and-replay; repeated persistent failures
  trigger elastic re-mesh.
* **Elastic re-mesh**: on device loss the supervisor rebuilds the mesh
  from surviving devices (shrinking the data axis), re-shards the restored
  state with ``jax.device_put``, and recompiles. Throughput degrades
  proportionally instead of halting.
* **Straggler mitigation**: per-step wall times are tracked in a rolling
  window; steps slower than ``straggler_factor`` x median are logged with
  the step fingerprint. At pod scale the same hook feeds the scheduler
  that re-shards data away from slow hosts; here it logs and counts.
"""

from __future__ import annotations

import dataclasses
import logging
import statistics
import time
from collections import deque
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.supervisor")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    max_retries_per_step: int = 2
    max_restores: int = 3
    max_remeshes: int = 2
    straggler_window: int = 32
    straggler_factor: float = 2.0
    log_every: int = 10


class FaultInjector:
    """Deterministic fault injection for tests: raise at given steps, or (for
    sustained-load benchmarks) at a seeded Bernoulli ``rate`` per check —
    reproducible across runs, independent of wall clock."""

    def __init__(self, fail_at: Dict[int, int] = None, *,
                 rate: float = 0.0, seed: int = 0):
        if not 0.0 <= rate < 1.0:
            raise ValueError(f"rate must be in [0, 1), got {rate}")
        self.fail_at = dict(fail_at or {})   # step -> how many times to fail
        self.rate = rate
        self._rng = np.random.RandomState(seed)
        self.injected = 0

    def check(self, step: int):
        n = self.fail_at.get(step, 0)
        if n > 0:
            self.fail_at[step] = n - 1
            self.injected += 1
            raise RuntimeError(f"injected fault at step {step}")
        if self.rate and self._rng.random_sample() < self.rate:
            self.injected += 1
            raise RuntimeError(f"injected fault (rate={self.rate}) at step {step}")


class Supervisor:
    """Drives (state, batch) -> (state, metrics) with full fault tolerance."""

    def __init__(
        self,
        train_step: Callable,
        batch_fn: Callable[[int], Any],
        loop_cfg: TrainLoopConfig,
        fault_injector: Optional[FaultInjector] = None,
        remesh_fn: Optional[Callable[[Any], Any]] = None,
    ):
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.cfg = loop_cfg
        self.ckpt = CheckpointManager(loop_cfg.checkpoint_dir, async_saves=True)
        self.faults = fault_injector
        self.remesh_fn = remesh_fn
        self.step_times: deque = deque(maxlen=loop_cfg.straggler_window)
        self.stats = {"retries": 0, "restores": 0, "stragglers": 0, "remeshes": 0}
        self.history = []

    def run(self, state) -> Any:
        cfg = self.cfg
        start = self.ckpt.latest_step()
        step = 0
        if start is not None:
            state, step = self.ckpt.restore(state, start)
            log.info("resumed from checkpoint step %d", step)
        restores = 0

        while step < cfg.total_steps:
            batch = self.batch_fn(step)
            ok = False
            for attempt in range(cfg.max_retries_per_step + 1):
                try:
                    t0 = time.time()
                    if self.faults is not None:
                        self.faults.check(step)
                    state, metrics = self.train_step(state, batch)
                    jax.block_until_ready(jax.tree.leaves(metrics)[0])
                    dt = time.time() - t0
                    self._track_straggler(step, dt)
                    ok = True
                    break
                except Exception as e:  # noqa: BLE001 — supervisor boundary
                    self.stats["retries"] += 1
                    log.warning("step %d attempt %d failed: %s", step, attempt, e)
            if not ok:
                restores += 1
                self.stats["restores"] += 1
                if restores > cfg.max_restores:
                    if (self.remesh_fn is not None
                            and self.stats["remeshes"] < cfg.max_remeshes):
                        log.error("restore budget exhausted; elastic re-mesh")
                        state = self.remesh_fn(state)
                        self.stats["remeshes"] += 1
                        restores = 0
                        continue
                    raise RuntimeError("restore + re-mesh budgets exhausted")
                self.ckpt.wait()              # drain in-flight async saves first
                last = self.ckpt.latest_step()
                if last is not None:
                    state, step = self.ckpt.restore(state, last)
                    log.warning("restored checkpoint step %d, replaying", step)
                continue

            if step % cfg.log_every == 0:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                self.history.append({"step": step, **m})
                log.info("step %d: %s", step, {k: round(v, 4) for k, v in m.items()})
            step += 1
            if step % cfg.checkpoint_every == 0 or step == cfg.total_steps:
                self.ckpt.save(step, state)

        self.ckpt.wait()
        return state

    def _track_straggler(self, step: int, dt: float):
        if len(self.step_times) >= 8:
            med = statistics.median(self.step_times)
            if dt > self.cfg.straggler_factor * med:
                self.stats["stragglers"] += 1
                log.warning(
                    "straggler: step %d took %.3fs (median %.3fs)", step, dt, med
                )
        self.step_times.append(dt)
