from repro.runtime.supervisor import Supervisor, TrainLoopConfig  # noqa: F401
