from repro.runtime.supervisor import FaultInjector, Supervisor, TrainLoopConfig  # noqa: F401
from repro.runtime.resilience import (  # noqa: F401
    DEMOTION_ORDER,
    DispatchContext,
    KernelDispatchError,
    KernelLoweringError,
    KernelResourceError,
    KernelResultError,
    TransientDispatchError,
    classify,
    dispatch,
    set_fault_injector,
    set_strict,
    set_verify,
    verify_level,
)
from repro.runtime import resilience  # noqa: F401
