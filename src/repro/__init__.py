"""repro: TPU-native reproduction of GPU Multisplit (see ROADMAP.md).

Importing the package installs the jax version-compat shims (``repro.compat``)
so code written against the modern mesh API runs on the pinned jax.
"""

from repro import compat as _compat

_compat.install()
