"""Declarative parameters with logical sharding axes.

Every model parameter is declared as a :class:`ParamDecl` carrying its shape
and a tuple of *logical* axis names. ``logical_to_mesh`` maps logical names
to mesh axes under a :class:`repro.configs.base.ParallelConfig`; from one
declaration tree we derive (a) materialized params, (b) NamedShardings for
pjit, (c) ``ShapeDtypeStruct`` stand-ins for the dry-run — no allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import get_abstract_mesh
from repro.configs.base import ParallelConfig


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names (None = replicated)
    dtype: Any = jnp.float32
    init: str = "normal"                      # normal | zeros | ones
    scale: Optional[float] = None             # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


# Logical axes. "model"-sharded: tensor-parallel dims. "fsdp"-sharded: the
# ZeRO-3 dim (only when ParallelConfig.fsdp). Everything else replicated.
TP_AXES = frozenset({"heads", "kv_heads", "ff", "vocab", "experts", "inner", "state_heads"})
FSDP_AXES = frozenset({"embed", "embed_fsdp"})


def spec_for_decl(decl: ParamDecl, pcfg: ParallelConfig, mesh) -> P:
    """Divisibility-aware logical->mesh assignment.

    jax requires input dims to divide evenly over their mesh axes. When the
    nominated TP dim doesn't divide (e.g. minicpm's 36 heads over model=16,
    GQA kv=8 over 16), the model sharding FALLS BACK to the next dim to the
    right that divides (typically head_dim) — contractions over a sharded
    inner dim become psums under GSPMD, which is correct and usually cheap.
    """
    tp = pcfg.tp_axis if pcfg.tp_axis in mesh.axis_names else None
    tp_size = mesh.shape[tp] if tp else 1
    dp_size = 1
    for a in pcfg.dp_axes:
        dp_size *= mesh.shape[a]
    dp_entry = pcfg.dp_axes if len(pcfg.dp_axes) > 1 else pcfg.dp_axes[0]

    entries = [None] * len(decl.shape)
    # FSDP (ZeRO-3) dims first
    if pcfg.fsdp:
        for i, ax in enumerate(decl.axes):
            if ax in FSDP_AXES and decl.shape[i] % dp_size == 0 and decl.shape[i] >= dp_size:
                entries[i] = dp_entry
                break
    # TP dim: first nominated dim that divides; else fall back rightward
    tp_dims = [i for i, ax in enumerate(decl.axes) if ax in TP_AXES] if tp else []
    if tp_dims:
        placed = False
        for i in tp_dims:
            if entries[i] is None and decl.shape[i] % tp_size == 0 and decl.shape[i] >= tp_size:
                entries[i] = tp
                placed = True
                break
        if not placed:
            for i in range(tp_dims[0] + 1, len(decl.shape)):
                if entries[i] is None and decl.shape[i] % tp_size == 0 and decl.shape[i] >= tp_size:
                    entries[i] = tp
                    break
    return P(*entries)


def decl_to_sharding(decls, pcfg: ParallelConfig, mesh):
    """Declaration tree -> NamedSharding tree (same structure)."""
    return jax.tree.map(
        lambda d: NamedSharding(mesh, spec_for_decl(d, pcfg, mesh)),
        decls,
        is_leaf=is_decl,
    )


def constrain(x, *entries):
    """Divisibility-aware ``with_sharding_constraint`` for activations.

    Entries: "dp" (the data-parallel axes: pod+data), "model", or None.
    No-op outside a mesh context, and per-dim no-op when the dim doesn't
    divide. Used to pin GSPMD's layout for attention and MoE dispatch —
    without these anchors the partitioner sometimes replicates the batch
    dim of 5-D einsums (observed on GQA fallback shardings).
    """
    mesh = get_abstract_mesh()
    names = getattr(mesh, "axis_names", ()) or ()
    if not names:
        return x
    resolved = []
    for dim, e in enumerate(entries):
        if e is None:
            resolved.append(None)
            continue
        if e == "dp":
            axes = tuple(a for a in names if a in ("pod", "data"))
        elif e == "model":
            axes = ("model",) if "model" in names else ()
        else:
            axes = (e,) if e in names else ()
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if not axes or size <= 1 or x.shape[dim] % size != 0 or x.shape[dim] < size:
            resolved.append(None)
        else:
            resolved.append(axes if len(axes) > 1 else axes[0])
    if all(r is None for r in resolved):
        return x
    return jax.lax.with_sharding_constraint(x, P(*resolved))


def tp_size() -> int:
    mesh = get_abstract_mesh()
    names = getattr(mesh, "axis_names", ()) or ()
    return mesh.shape["model"] if "model" in names else 1


def decl_to_abstract(decls):
    """Declaration tree -> ShapeDtypeStruct tree (dry-run; no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), decls, is_leaf=is_decl
    )


def init_params(decls, rng_key):
    """Materialize a declaration tree (smoke tests / real training only)."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=is_decl)
    keys = jax.random.split(rng_key, len(leaves))

    def one(decl: ParamDecl, key):
        if decl.init == "zeros":
            return jnp.zeros(decl.shape, decl.dtype)
        if decl.init == "ones":
            return jnp.ones(decl.shape, decl.dtype)
        fan_in = decl.shape[-2] if len(decl.shape) >= 2 else decl.shape[-1]
        scale = decl.scale if decl.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, decl.shape, jnp.float32) * scale).astype(decl.dtype)

    return jax.tree.unflatten(treedef, [one(d, k) for d, k in zip(leaves, keys)])


def param_count(decls) -> int:
    leaves = jax.tree.leaves(decls, is_leaf=is_decl)
    return sum(int(np.prod(d.shape)) for d in leaves)


def param_bytes(decls) -> int:
    leaves = jax.tree.leaves(decls, is_leaf=is_decl)
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves)
