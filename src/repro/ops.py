"""repro.ops — the stable, transform-native public API (DESIGN.md §11).

This is the namespace models, pipelines and downstream PRs program against:
declarative hashable bucket specs plus the multisplit operator family, with
JAX transforms wired in as first-class citizens rather than afterthoughts:

* ``jit``  — specs are value-hashable, leafless pytrees, so equal spec
  instances share ONE trace (zero retraces across ``delta_buckets(32)``
  calls, whether the spec rides as a static argument or a pytree argument).
* ``vmap`` — :func:`multisplit` carries a ``jax.custom_batching.custom_vmap``
  rule that routes ``jax.vmap(ops.multisplit)`` onto a BATCHED plan
  (DESIGN.md §9): ONE kernel launch for the whole batch, bitwise equal to
  the per-row loop it replaces.  Without the rule, vmap would silently
  trace the flat pipeline per element and miss the batched layout.
* ``grad`` — :func:`multisplit_key_value` is a ``jax.custom_vjp``: the
  backward pass of the value permutation is the INVERSE GATHER of the
  forward permutation (one ``take`` — no scatter transpose, no dense
  one-hot), so routing/bucketing sits inside ``grad`` end-to-end.

Execution is unchanged underneath: every op resolves a
:class:`~repro.core.pipeline.MultisplitPlan` through the backend registry.
Ops are cached per (spec, shape, config) — hashable specs make the cache
exact, not identity-based.

Stability policy: everything in ``__all__`` is covered by the API snapshot
test (``tests/test_api_surface.py``); changing a signature here is a
deliberate, test-visible act.
"""

from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import custom_batching

from repro.core.identifiers import (
    BitfieldSpec,
    BucketIdentifier,
    BucketSpec,
    CallableSpec,
    DeltaSpec,
    EvenSpec,
    IdentitySpec,
    RangeSpec,
    as_spec,
    delta_buckets,
    even_buckets,
    from_fn,
    identity_buckets,
    radix_buckets,
    range_buckets,
)
from repro.core.pipeline import (
    MultisplitResult,
    make_batched_plan,
    make_plan,
    make_segmented_plan,
    set_autotune,
)
from repro.core.multisplit import _empty_segmented_result
from repro.core.sort import radix_sort, segmented_radix_sort
from repro.runtime import resilience as _rz
from repro.runtime.resilience import set_strict, set_verify

Array = jnp.ndarray

__all__ = [
    # bucket specs (hashable, pytree-static, kernel-fusable)
    "BucketSpec", "BitfieldSpec", "CallableSpec", "DeltaSpec", "EvenSpec",
    "IdentitySpec", "RangeSpec", "BucketIdentifier",
    "as_spec", "delta_buckets", "even_buckets", "from_fn",
    "identity_buckets", "radix_buckets", "range_buckets",
    # results
    "MultisplitResult",
    # operators
    "multisplit", "multisplit_key_value", "segmented_multisplit",
    "histogram", "radix_sort", "segmented_radix_sort",
    # tuning
    "set_autotune",
    # resilience (DESIGN.md §17)
    "set_strict", "set_verify",
]


def _out_batched(res: MultisplitResult) -> MultisplitResult:
    """out_batched pytree for a custom_vmap rule: True per present field."""
    return MultisplitResult(
        None if res.keys is None else True,
        None if res.values is None else True,
        True, True,
        None if res.permutation is None else True,
    )


def _broadcast_unbatched(x: Array, batched: bool, axis_size: int) -> Array:
    if batched:
        return x
    return jnp.broadcast_to(x[None], (axis_size,) + x.shape)


def _build_flat_op(spec: BucketSpec, n: int, method: str, backend: str,
                   tile: Optional[int], mode: str, family: Optional[str]):
    """The key-only op for one (spec, n, config): a custom_vmap-wrapped flat
    plan whose vmap rule IS the batched plan (one launch, DESIGN.md §9)."""
    plan = make_plan(
        n, spec.num_buckets, method=method, backend=backend, tile=tile,
        bucket_fn=spec, mode=mode, family=family,
    )

    @custom_batching.custom_vmap
    def op(keys):
        return plan(keys)

    @op.def_vmap
    def _rule(axis_size, in_batched, keys):  # noqa: ANN001 - jax rule signature
        keys = _broadcast_unbatched(keys, in_batched[0], axis_size)
        bplan = make_batched_plan(
            axis_size, n, spec.num_buckets, method=method, backend=backend,
            tile=tile, bucket_fn=spec, mode=mode, family=family,
        )
        res = bplan(keys)
        return res, _out_batched(res)

    return op


# Declarative specs hash by VALUE, so the cache is exact and bounded by the
# distinct (spec, shape, config) set.  CallableSpec hashes by function
# identity — caching it would both miss for per-call closures and pin the
# closure (and anything it captures) for the module lifetime — so callables
# take the uncached builder.
_flat_op_cached = functools.lru_cache(maxsize=512)(_build_flat_op)


def _flat_op(spec, n, method, backend, tile, mode, family):
    if isinstance(spec, CallableSpec):
        return _build_flat_op(spec, n, method, backend, tile, mode, family)
    return _flat_op_cached(spec, n, method, backend, tile, mode, family)


def _ct_gather(ct_leaf, perm):
    """One cotangent leaf of the kv backward pass: the inverse gather of the
    forward permutation (``d_in[i] = ct_out[perm[i]]``); integer primals get
    their mandated float0 zero."""
    if ct_leaf.dtype == jax.dtypes.float0:
        return np.zeros(np.shape(ct_leaf), jax.dtypes.float0)
    return jnp.take_along_axis(ct_leaf, perm, axis=-1)


def _build_kv_op(spec: BucketSpec, n: int, method: str, backend: str,
                 tile: Optional[int], family: Optional[str]):
    """The key-value op: custom_vjp (backward = inverse gather of the
    forward permutation) over a custom_vmap inner (batched-plan vmap rule),
    so grad, vmap, and vmap-of-grad all hit the intended paths."""
    plan = make_plan(
        n, spec.num_buckets, method=method, key_value=True, backend=backend,
        tile=tile, bucket_fn=spec, family=family,
    )

    @custom_batching.custom_vmap
    def inner(keys, values):
        return plan(keys, values)

    @inner.def_vmap
    def _rule(axis_size, in_batched, keys, values):  # noqa: ANN001
        keys = _broadcast_unbatched(keys, in_batched[0], axis_size)
        values = _broadcast_unbatched(values, in_batched[1], axis_size)
        bplan = make_batched_plan(
            axis_size, n, spec.num_buckets, method=method, key_value=True,
            backend=backend, tile=tile, bucket_fn=spec, family=family,
        )
        res = bplan(keys, values)
        return res, _out_batched(res)

    @jax.custom_vjp
    def op(keys, values):
        return inner(keys, values)

    def fwd(keys, values):
        res = inner(keys, values)
        return res, (res.permutation,)

    def bwd(residuals, ct):
        (perm,) = residuals
        # out[perm[i]] = in[i]  =>  d_in[i] = ct_out[perm[i]]: ONE gather.
        # Cotangents of the integer outputs (counts/starts/perm) are float0
        # and contribute nothing by construction.
        return _ct_gather(ct.keys, perm), _ct_gather(ct.values, perm)

    op.defvjp(fwd, bwd)
    return op


_kv_op_cached = functools.lru_cache(maxsize=512)(_build_kv_op)


def _kv_op(spec, n, method, backend, tile, family):
    if isinstance(spec, CallableSpec):               # see _flat_op
        return _build_kv_op(spec, n, method, backend, tile, family)
    return _kv_op_cached(spec, n, method, backend, tile, family)


def _check_flat(keys: Array, what: str) -> None:
    if keys.ndim != 1:
        raise ValueError(
            f"{what} takes rank-1 keys (got shape {keys.shape}); batch with "
            f"jax.vmap({what}) — it dispatches to ONE batched-plan launch"
        )


def _traced(*arrays) -> bool:
    return any(isinstance(a, jax.core.Tracer) for a in arrays if a is not None)


def _resilient(
    run, keys: Array, values: Optional[Array], spec: BucketSpec, *,
    n: int, method: str, backend: str, tile: Optional[int], key_value: bool,
    mode: str, segments: Optional[int] = None, segment_starts=None,
):
    """Route one eager facade call through the degradation ladder + runtime
    verification (DESIGN.md §17): ``run(backend, tile)`` re-executes the op
    on any rung.  Under a jax trace the ladder is bypassed — exceptions
    cannot cross a trace, and the transform rules (vmap/jit/grad) must see
    the plain op."""
    if _traced(keys, values, segment_starts):
        return run(backend, tile)
    m_eff = spec.num_buckets * (segments or 1)
    ctx = _rz.DispatchContext(
        spec_name=getattr(spec, "name", type(spec).__name__),
        shape=tuple(keys.shape), num_buckets=spec.num_buckets,
        method=method, key_value=key_value, mode=mode,
        layout="segmented" if segments is not None else "flat",
    )

    def resolved_tile(be: str) -> int:
        from repro.core.pipeline.tiles import resolve_tile

        return resolve_tile(n, m_eff, method, key_value, be)

    def pin_tile(be: str, t: int) -> None:
        from repro.core.pipeline.tiles import pin_tile as _pin

        _pin(n, m_eff, method, key_value, be, t)

    def verifier(res, be: str) -> None:
        _rz.verify_result(
            res, keys=keys, spec=spec, n=n, values=values,
            segment_starts=segment_starts, mode=mode, backend=be, ctx=ctx,
        )

    return _rz.dispatch(
        run, ctx, backend=backend, tile=tile, resolved_tile=resolved_tile,
        pin_tile=pin_tile, verifier=verifier,
    )




def multisplit(
    keys: Array,
    spec: BucketSpec,
    values: Optional[Array] = None,
    *,
    method: str = "bms",
    backend: str = "vmap",
    tile: Optional[int] = None,
    mode: str = "reorder",
    family: Optional[str] = None,
) -> MultisplitResult:
    """Stable multisplit of ``keys`` (and optional ``values``) into the
    buckets of a declarative ``spec`` (paper §3.1).

    Transform-native: ``jax.vmap(ops.multisplit)`` runs the whole batch as
    ONE batched-plan launch (bitwise equal to the per-row loop); with
    ``values`` the op is differentiable (see :func:`multisplit_key_value`);
    equal specs share one trace under ``jit``.  ``mode`` selects a partial
    pipeline (``counts_only`` / ``positions_only``, key-only — DESIGN.md
    §10); ``family`` pins the kernel family (``"onehot"``/``"packed"``,
    DESIGN.md §12 — bitwise identical, cost only; ``None`` auto-resolves).
    """
    spec = as_spec(spec)
    _check_flat(keys, "ops.multisplit")
    if values is not None:
        if mode != "reorder":
            raise ValueError(f"mode={mode!r} never touches values")
        return multisplit_key_value(
            keys, values, spec, method=method, backend=backend, tile=tile,
            family=family,
        )
    n = keys.shape[0]
    return _resilient(
        lambda be, tl: _flat_op(spec, n, method, be, tl, mode, family)(keys),
        keys, None, spec, n=n, method=method, backend=backend, tile=tile,
        key_value=False, mode=mode,
    )


def multisplit_key_value(
    keys: Array,
    values: Array,
    spec: BucketSpec,
    *,
    method: str = "bms",
    backend: str = "vmap",
    tile: Optional[int] = None,
    family: Optional[str] = None,
) -> MultisplitResult:
    """Key-value multisplit, differentiable in ``values`` (and in ``keys``
    when they are inexact): the backward pass is the INVERSE GATHER of the
    forward permutation — ``d_in[i] = ct_out[perm[i]]``, one ``take`` per
    operand, no dense one-hot and no scatter transpose.

    ``jax.vmap`` of this op (with or without ``jax.grad``) also dispatches
    to ONE batched-plan launch via the inner custom-vmap rule.
    """
    spec = as_spec(spec)
    _check_flat(keys, "ops.multisplit_key_value")
    n = keys.shape[0]
    return _resilient(
        lambda be, tl: _kv_op(spec, n, method, be, tl, family)(keys, values),
        keys, values, spec, n=n, method=method, backend=backend, tile=tile,
        key_value=True, mode="reorder",
    )


def segmented_multisplit(
    keys: Array,
    spec: BucketSpec,
    segment_starts,
    values: Optional[Array] = None,
    *,
    method: str = "bms",
    backend: str = "vmap",
    tile: Optional[int] = None,
    mode: str = "reorder",
    family: Optional[str] = None,
) -> MultisplitResult:
    """Multisplit every ragged segment of flat ``keys`` independently in ONE
    plan launch (DESIGN.md §9): ``segment_starts`` is the (s,) ascending
    start-offset vector (``segment_starts[0] == 0``; empty segments
    allowed, and ``s == 0`` with empty keys — a zero-request serving step —
    returns (0, m) counts).  Bitwise identical to per-segment
    :func:`multisplit` calls; counts/starts come back (s, m)
    segment-local."""
    spec = as_spec(spec)
    _check_flat(keys, "ops.segmented_multisplit")
    if values is not None and mode != "reorder":
        raise ValueError(f"mode={mode!r} never touches values")
    seg = jnp.asarray(segment_starts, jnp.int32)
    if seg.shape[0] == 0:        # zero-request step (ISSUE 9 S1)
        return _empty_segmented_result(keys, values, spec.num_buckets, mode)
    n, s = keys.shape[0], int(seg.shape[0])

    def run(be, tl):
        plan = make_segmented_plan(
            n, s, spec.num_buckets, method=method,
            key_value=values is not None, backend=be, tile=tl,
            bucket_fn=spec, mode=mode, family=family,
        )
        return plan(keys, values, segment_starts=seg)

    return _resilient(
        run, keys, values, spec, n=n, method=method, backend=backend,
        tile=tile, key_value=values is not None, mode=mode, segments=s,
        segment_starts=seg,
    )


def histogram(
    keys: Array,
    spec: BucketSpec,
    *,
    backend: str = "vmap",
    tile: Optional[int] = None,
    family: Optional[str] = None,
) -> Array:
    """Device-wide bucket counts (paper §7.3): the ``counts_only`` partial
    pipeline — {prescan, tree-reduce}, no scan, no scatter."""
    spec = as_spec(spec)
    _check_flat(keys, "ops.histogram")
    return multisplit(
        keys, spec, backend=backend, tile=tile, mode="counts_only",
        family=family,
    ).bucket_counts
