"""Deterministic, shard-aware synthetic token pipeline with multisplit
length bucketing.

Production shape: each data-parallel host pulls only its shard (deterministic
from (seed, step, host)); a background thread prefetches; variable-length
documents are packed into fixed (batch, seq) windows after being
length-bucketed — the bucketing is a multisplit (buckets = length ranges),
which is the paper's technique applied to the input pipeline (DESIGN.md §4).

The bucketing runs DEVICE-SIDE as a segmented counts+positions pipeline
(DESIGN.md §10): one ``positions_only`` plan call buckets the length vectors
of MANY prefetch steps at once (one ragged segment per step) and only the
int32 permutation + per-step bucket counts come back to the host — the
reordered length array is never materialized anywhere.

Synthetic text: a mixture of Zipf-distributed unigrams with doc-level topic
drift — enough structure that a LM's loss meaningfully decreases.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro import ops

import jax.numpy as jnp


class DataPipeline:
    def __init__(
        self,
        vocab: int,
        seq_len: int,
        batch_per_host: int,
        seed: int = 0,
        host_index: int = 0,
        n_hosts: int = 1,
        bucket_lengths: tuple = (64, 256, 1024, 4096),
        frontend_stub_dim: Optional[int] = None,
    ):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch_per_host
        self.seed = seed
        self.host = host_index
        self.n_hosts = n_hosts
        self.bucket_lengths = bucket_lengths
        self.frontend_stub_dim = frontend_stub_dim

    # -- synthetic documents ------------------------------------------------
    def _docs(self, step: int, n_docs: int):
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 131 + self.host) % (2**31 - 1)
        )
        lengths = np.clip(
            (rng.pareto(1.2, size=n_docs) * 64).astype(np.int64) + 8, 8, self.seq_len
        )
        docs = []
        for ln in lengths:
            topic = rng.randint(0, 64)
            # Zipf unigrams, shifted per topic: structured enough to learn
            z = rng.zipf(1.6, size=int(ln)).astype(np.int64)
            toks = (z * 769 + topic * 31) % max(self.vocab - 2, 1) + 1
            docs.append(toks.astype(np.int32))
        return docs, lengths

    # -- multisplit length bucketing (the paper's primitive in the pipeline) -
    def _bucket_orders(self, lengths_list) -> List[np.ndarray]:
        """Bucket-major doc order for MANY steps in ONE device launch.

        ``lengths_list`` holds one per-step length vector; the concatenation
        is one segmented ``positions_only`` ``repro.ops`` call (segment =
        step) over a hashable :class:`~repro.ops.RangeSpec` — equal bucket
        boundaries share one trace across pipelines and prefetch windows.
        Only the segment-local eq. (2) permutation comes back host-side —
        ``order[perm[i]] = i`` inverts it into the stable bucket-major doc
        visit order per step (bitwise what the old per-step full-reorder
        multisplit produced, without materializing any reordered array).
        """
        bf = ops.range_buckets(self.bucket_lengths[:-1])
        sizes = [len(ln) for ln in lengths_list]
        flat = np.concatenate([np.asarray(ln, np.int32) for ln in lengths_list])
        starts = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)
        perm = np.asarray(
            ops.segmented_multisplit(
                jnp.asarray(flat), bf, jnp.asarray(starts), method="dms",
                mode="positions_only",
            ).permutation
        )
        orders = []
        for a, sz in zip(starts, sizes):
            order = np.empty(sz, np.int64)
            order[perm[a : a + sz]] = np.arange(sz)
            orders.append(order)
        return orders

    def _pack(self, docs, order) -> np.ndarray:
        # pack bucket-ordered docs (similar lengths adjacent => little padding)
        out = np.zeros((self.batch, self.seq_len), np.int32)
        row, col = 0, 0
        for di in order:
            d = docs[int(di)]
            while d.size and row < self.batch:
                take = min(d.size, self.seq_len - col)
                out[row, col : col + take] = d[:take]
                d = d[take:]
                col += take
                if col >= self.seq_len:
                    row, col = row + 1, 0
            if row >= self.batch:
                break
        return out

    def _finalize(self, step: int, tokens: np.ndarray) -> Dict[str, np.ndarray]:
        labels = np.concatenate(
            [tokens[:, 1:], np.full((self.batch, 1), -1, np.int32)], axis=1
        )
        labels = np.where(tokens > 0, labels, -1)
        batch = {"tokens": tokens, "labels": labels}
        if self.frontend_stub_dim:
            rng = np.random.RandomState((self.seed + step) % (2**31 - 1))
            batch["embeds"] = rng.randn(
                self.batch, self.seq_len, self.frontend_stub_dim
            ).astype(np.float32)
            del batch["tokens"]
        return batch

    def batches_at(self, start_step: int, num_steps: int) -> List[Dict[str, np.ndarray]]:
        """Deterministic batches for ``num_steps`` consecutive steps, with the
        length bucketing of ALL steps done in one segmented pipeline launch.
        ``batches_at(s, k)[i]`` is bitwise identical to ``batch_at(s + i)``
        (segmented == independent flat plans, DESIGN.md §9)."""
        n_docs = self.batch * max(self.seq_len // 256, 4)
        per_step = [self._docs(start_step + i, n_docs) for i in range(num_steps)]
        orders = self._bucket_orders([lengths for _, lengths in per_step])
        return [
            self._finalize(start_step + i, self._pack(docs, order))
            for i, ((docs, _), order) in enumerate(zip(per_step, orders))
        ]

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-safe)."""
        return self.batches_at(step, 1)[0]


def make_batch_iterator(pipeline: DataPipeline, start_step: int = 0, prefetch: int = 2
                        ) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetching iterator, resumable at ``start_step``.

    The worker generates ``prefetch`` steps at a time through
    :meth:`DataPipeline.batches_at`, so the length bucketing of a whole
    prefetch window is one segmented pipeline launch."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()
    chunk = max(prefetch, 1)

    def worker():
        step = start_step
        while not stop.is_set():
            for batch in pipeline.batches_at(step, chunk):
                q.put(batch)
                if stop.is_set():
                    return
            step += chunk

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
