"""Deterministic, shard-aware synthetic token pipeline with multisplit
length bucketing.

Production shape: each data-parallel host pulls only its shard (deterministic
from (seed, step, host)); a background thread prefetches; variable-length
documents are packed into fixed (batch, seq) windows after being
length-bucketed — the bucketing is a multisplit (buckets = length ranges),
which is the paper's technique applied to the input pipeline (DESIGN.md §4).

Synthetic text: a mixture of Zipf-distributed unigrams with doc-level topic
drift — enough structure that a LM's loss meaningfully decreases.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.identifiers import range_buckets
from repro.core.multisplit import multisplit

import jax.numpy as jnp


class DataPipeline:
    def __init__(
        self,
        vocab: int,
        seq_len: int,
        batch_per_host: int,
        seed: int = 0,
        host_index: int = 0,
        n_hosts: int = 1,
        bucket_lengths: tuple = (64, 256, 1024, 4096),
        frontend_stub_dim: Optional[int] = None,
    ):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch_per_host
        self.seed = seed
        self.host = host_index
        self.n_hosts = n_hosts
        self.bucket_lengths = bucket_lengths
        self.frontend_stub_dim = frontend_stub_dim

    # -- synthetic documents ------------------------------------------------
    def _docs(self, step: int, n_docs: int):
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 131 + self.host) % (2**31 - 1)
        )
        lengths = np.clip(
            (rng.pareto(1.2, size=n_docs) * 64).astype(np.int64) + 8, 8, self.seq_len
        )
        docs = []
        for ln in lengths:
            topic = rng.randint(0, 64)
            # Zipf unigrams, shifted per topic: structured enough to learn
            z = rng.zipf(1.6, size=int(ln)).astype(np.int64)
            toks = (z * 769 + topic * 31) % max(self.vocab - 2, 1) + 1
            docs.append(toks.astype(np.int32))
        return docs, lengths

    # -- multisplit length bucketing (the paper's primitive in the pipeline) -
    def _bucket_and_pack(self, docs, lengths):
        splitters = jnp.asarray(self.bucket_lengths[:-1], jnp.int32)
        bf = range_buckets(splitters)
        order = multisplit(jnp.asarray(lengths, jnp.int32), bf,
                           jnp.arange(len(docs), dtype=jnp.int32)).values
        order = np.asarray(order)
        # pack bucket-ordered docs (similar lengths adjacent => little padding)
        out = np.zeros((self.batch, self.seq_len), np.int32)
        row, col = 0, 0
        for di in order:
            d = docs[int(di)]
            while d.size and row < self.batch:
                take = min(d.size, self.seq_len - col)
                out[row, col : col + take] = d[:take]
                d = d[take:]
                col += take
                if col >= self.seq_len:
                    row, col = row + 1, 0
            if row >= self.batch:
                break
        return out

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-safe)."""
        n_docs = self.batch * max(self.seq_len // 256, 4)
        docs, lengths = self._docs(step, n_docs)
        tokens = self._bucket_and_pack(docs, lengths)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((self.batch, 1), -1, np.int32)], axis=1
        )
        labels = np.where(tokens > 0, labels, -1)
        batch = {"tokens": tokens, "labels": labels}
        if self.frontend_stub_dim:
            rng = np.random.RandomState((self.seed + step) % (2**31 - 1))
            batch["embeds"] = rng.randn(
                self.batch, self.seq_len, self.frontend_stub_dim
            ).astype(np.float32)
            del batch["tokens"]
        return batch


def make_batch_iterator(pipeline: DataPipeline, start_step: int = 0, prefetch: int = 2
                        ) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetching iterator, resumable at ``start_step``."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            q.put(pipeline.batch_at(step))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
