from repro.data.pipeline import DataPipeline, make_batch_iterator  # noqa: F401
