"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets the virtual device count before
first jax init, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods in multi-pod mode (TPU v5e target)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def data_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh (includes 'pod')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh(n_devices: int = 0, axes=("data",)):
    """Small local mesh for tests/examples on whatever devices exist."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), axes, axis_types=(jax.sharding.AxisType.Auto,))
