"""Abstract input stand-ins (ShapeDtypeStruct) for every (arch x shape) cell.

The four assigned input shapes:

    train_4k      seq=4,096    global_batch=256   -> train_step
    prefill_32k   seq=32,768   global_batch=32    -> prefill_step
    decode_32k    seq=32,768   global_batch=128   -> decode_step (KV cache of seq)
    long_500k     seq=524,288  global_batch=1     -> decode_step, sub-quadratic archs only

No device allocation anywhere — weak-type-correct ShapeDtypeStructs only.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M

SHAPES: Dict[str, dict] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k only runs on sub-quadratic archs (DESIGN.md §6)."""
    if shape == "long_500k":
        return cfg.is_subquadratic()
    return True


def batch_specs(cfg: ModelConfig, shape: str, with_labels: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    dt = jnp.dtype(cfg.dtype)
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.embed_frontend_stub:
        batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.n_vis_tokens:
        batch["vis_embeds"] = jax.ShapeDtypeStruct((b, cfg.n_vis_tokens, cfg.d_model), dt)
    return batch


def decode_specs(cfg: ModelConfig, shape: str):
    """(cache, token_or_embed, position) abstract args for decode shapes."""
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    cache = M.cache_decl(cfg, b, max_len=s)
    dt = jnp.dtype(cfg.dtype)
    if cfg.embed_frontend_stub:
        token = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)
    else:
        token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    position = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, token, position


def input_specs(cfg: ModelConfig, shape: str):
    """The complete abstract argument tuple for the cell's step function
    (excluding model/optimizer state, which comes from steps.abstract_state)."""
    kind = SHAPES[shape]["kind"]
    if kind == "train":
        return (batch_specs(cfg, shape, with_labels=True),)
    if kind == "prefill":
        return (batch_specs(cfg, shape, with_labels=False),)
    if kind == "decode":
        return decode_specs(cfg, shape)
    raise ValueError(shape)
