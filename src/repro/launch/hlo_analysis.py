"""Post-SPMD HLO analysis: collective byte accounting + roofline terms.

``compiled.cost_analysis()`` gives HLO FLOPs and bytes accessed but not
collective traffic, so we parse the optimized HLO text: build a table of
instruction result shapes, then for each collective op sum its operands'
sizes (the brief's definition of collective_bytes).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "all-reduce-start",
    "all-gather-start", "collective-permute-start",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s+([\w\-]+)\(")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[2,16,512]' or tuple '(f32[2], s32[4])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, dict]:
    """Returns {op_kind: {"count": int, "operand_bytes": int, "result_bytes": int}}."""
    # pass 1: result shapes of all instructions
    shapes: Dict[str, str] = {}
    defs = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.group(1).lstrip("%"), m.group(2), m.group(3)
        shapes[name] = shape_str
        defs.append((name, shape_str, op, line))

    out: Dict[str, dict] = defaultdict(lambda: {"count": 0, "operand_bytes": 0, "result_bytes": 0})
    for name, shape_str, op, line in defs:
        kind = op.replace("-start", "")
        if kind not in COLLECTIVES:
            continue
        # operands: %refs inside the parens
        call = line.split(op + "(", 1)[1]
        depth, args = 1, ""
        for ch in call:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        operand_bytes = 0
        for ref in re.findall(r"%?([\w.\-]+)", args):
            if ref in shapes:
                operand_bytes += _shape_bytes(shapes[ref])
        rec = out[kind]
        rec["count"] += 1
        rec["operand_bytes"] += operand_bytes
        rec["result_bytes"] += _shape_bytes(shape_str)
    return dict(out)


def roofline_terms(flops: float, bytes_accessed: float, collective_bytes: float, n_chips: int):
    """The three roofline terms, in seconds (brief's formulas)."""
    return {
        "compute_s": flops / (n_chips * PEAK_FLOPS),
        "memory_s": bytes_accessed / (n_chips * HBM_BW),
        "collective_s": collective_bytes / (n_chips * ICI_BW),
    }


def dominant_term(terms: dict) -> str:
    return max(
        (("compute", terms["compute_s"]), ("memory", terms["memory_s"]),
         ("collective", terms["collective_s"])),
        key=lambda kv: kv[1],
    )[0]
