"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128

``--smoke`` uses the reduced config + a host-sized mesh (runs on this
container); without it the production mesh/config is used (real pod). The
loop always runs under the fault-tolerant Supervisor (checkpoint/restart,
retry, straggler tracking).
"""

from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data import DataPipeline
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import adamw_init
from repro.parallel.sharding import decl_to_sharding, init_params, param_count
from repro.runtime import Supervisor, TrainLoopConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default=None, choices=[None, "cosine", "wsd"])
    ap.add_argument("--dispatch", default=None, choices=[None, "dense", "sort", "multisplit"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh()
    if args.dispatch and cfg.moe.num_experts:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch=args.dispatch))
    schedule = args.schedule or ("wsd" if cfg.name.startswith("minicpm") else "cosine")
    tc = TrainConfig(
        global_batch=args.batch, seq_len=args.seq, lr=args.lr, schedule=schedule,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 5), seed=args.seed,
    )
    pcfg = ParallelConfig(dp_axes=tuple(a for a in mesh.axis_names if a in ("pod", "data")))

    decls = M.decl_model(cfg)
    print(f"[train] {cfg.name}: {param_count(decls)/1e6:.1f}M params, mesh {dict(mesh.shape)}")
    params = init_params(decls, jax.random.PRNGKey(tc.seed))
    state = S.TrainState(params=params, opt=adamw_init(params, tc))

    pipeline = DataPipeline(
        vocab=cfg.vocab, seq_len=tc.seq_len, batch_per_host=tc.global_batch,
        seed=tc.seed, frontend_stub_dim=cfg.d_model if cfg.embed_frontend_stub else None,
    )

    def batch_fn(step: int):
        b = pipeline.batch_at(step)
        if cfg.n_vis_tokens:
            rng = np.random.RandomState(step)
            b["vis_embeds"] = rng.randn(
                tc.global_batch, cfg.n_vis_tokens, cfg.d_model
            ).astype(np.float32)
        return jax.tree.map(jnp.asarray, b)

    train_step = S.make_train_step(cfg, tc)
    with jax.set_mesh(mesh):
        st_sh = S.state_shardings(decls, pcfg, mesh, tc)
        jitted = jax.jit(
            train_step, in_shardings=(st_sh, None), out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
        sup = Supervisor(
            jitted, batch_fn,
            TrainLoopConfig(
                total_steps=tc.total_steps, checkpoint_every=args.ckpt_every,
                checkpoint_dir=args.ckpt_dir,
            ),
        )
        state = sup.run(state)
    print(f"[train] done; stats={sup.stats}")
    if sup.history:
        print(f"[train] first loss={sup.history[0]['loss']:.4f} "
              f"last loss={sup.history[-1]['loss']:.4f}")
    return sup


if __name__ == "__main__":
    main()
