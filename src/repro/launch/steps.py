"""Jit-able step functions (train / prefill / decode) + their shardings.

These are the exact graphs the dry-run lowers and the launchers execute.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models import model as M
from repro.optim import AdamWState, adamw_init, adamw_update, make_schedule
from repro.parallel.sharding import decl_to_abstract, decl_to_sharding


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    sched = make_schedule(tc)

    def grads_of(params, batch):
        return jax.value_and_grad(M.loss_fn, has_aux=True)(params, cfg, batch)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if tc.accum_steps <= 1:
            (loss, metrics), grads = grads_of(state.params, batch)
        else:
            # gradient-accumulation microbatching: the global batch is split
            # on the batch dim into accum_steps microbatches scanned
            # sequentially — activation temps scale by 1/accum_steps while
            # the optimizer math (and the dry-run's train semantics) are
            # unchanged. This is the documented path that fits the >16 GiB
            # train cells onto v5e HBM (EXPERIMENTS.md §Dry-run).
            a = tc.accum_steps
            micro = jax.tree.map(
                lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]), batch
            )

            def acc_body(carry, mb):
                g_acc, l_acc, m_acc = carry
                (loss, metrics), grads = grads_of(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                m_acc = jax.tree.map(jnp.add, m_acc, metrics)
                return (g_acc, l_acc + loss, m_acc), None

            zeros_like_f32 = lambda t: jax.tree.map(
                lambda x: jnp.zeros(x.shape, x.dtype), t
            )
            g0 = zeros_like_f32(jax.eval_shape(lambda p: grads_of(p, jax.tree.map(
                lambda x: x[0], micro))[1], state.params))
            m0 = zeros_like_f32(jax.eval_shape(lambda p: grads_of(p, jax.tree.map(
                lambda x: x[0], micro))[0][1], state.params))
            (grads, loss, metrics), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros(()), m0), micro
            )
            inv = 1.0 / a
            grads = jax.tree.map(lambda g: (g * inv).astype(g.dtype), grads)
            metrics = jax.tree.map(lambda m: m * inv, metrics)
            loss = loss * inv
        lr = sched(state.opt.step.astype(jnp.float32))
        new_params, new_opt, om = adamw_update(grads, state.opt, state.params, tc, lr)
        metrics = dict(metrics, **om)
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """Full-sequence forward, returning ONLY last-position logits (the
    (B, S, V) tensor is never materialized — serving-realistic)."""

    def prefill_step(params, batch):
        hidden, _, _ = M._forward_trunk(params, cfg, batch)
        from repro.models.layers import lm_head

        last = hidden[:, -1:]
        return lm_head(params["embed"], last, cfg)[:, 0]

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, token_or_embed, position):
        return M.decode_step(params, cfg, cache, token_or_embed, position)

    return decode_step


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def state_shardings(decls, pcfg: ParallelConfig, mesh, tc: TrainConfig):
    p_sh = decl_to_sharding(decls, pcfg, mesh)
    rep = NamedSharding(mesh, P())
    master = p_sh if jnp.dtype(tc.params_dtype) != jnp.float32 else None
    return TrainState(
        params=p_sh, opt=AdamWState(step=rep, mu=p_sh, nu=p_sh, master=master)
    )


def abstract_state(decls, tc: TrainConfig):
    params = decl_to_abstract(decls)
    pdt = jnp.dtype(tc.params_dtype)
    params = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, pdt), params)
    mdt = jnp.dtype(tc.moments_dtype)
    mom = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), params)
    master = None
    if pdt != jnp.float32:
        master = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params)
    return TrainState(
        params=params,
        opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=mom, nu=mom,
                       master=master),
    )


def batch_sharding(cfg: ModelConfig, mesh, batch_tree):
    """Batch dict -> shardings: batch dim over (pod, data); rest replicated.
    Batch dims that don't divide the dp axes (long_500k's batch=1) replicate."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_entry = dp if len(dp) > 1 else dp[0]
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]

    def spec(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % n_dp == 0 and leaf.shape[0] >= n_dp:
            return NamedSharding(mesh, P(*((dp_entry,) + (None,) * (leaf.ndim - 1))))
        return NamedSharding(mesh, P(*((None,) * leaf.ndim)))

    return jax.tree.map(spec, batch_tree)


def _block_cache_spec(kind: str, cfg: ModelConfig, batch_entry):
    """PartitionSpecs for one block's decode cache. Self-attention caches are
    TIME-sharded over the model axis (always divisible; decode attention
    reduces over time with a psum — flash-decoding style)."""
    b = batch_entry
    if kind in ("attn", "attn_moe", "shared_attn"):
        return {
            "k": P(b, "model", None, None),
            "v": P(b, "model", None, None),
            "positions": P(None),
            "pos": P(),
        }
    if kind == "cross":
        return {"k": P(b, "model", None, None), "v": P(b, "model", None, None)}
    if kind == "mamba":
        return {"conv": P(b, None, "model"), "ssm": P(b, "model", None, None), "pos": P()}
    if kind == "mlstm":
        return {"c": P(b, None, "model", None), "n": P(b, None, "model"), "m": P(b, None), "pos": P()}
    if kind == "slstm":
        return {k: P(b, None, "model") for k in ("c", "n", "h", "m")} | {"pos": P()}
    raise ValueError(kind)


def cache_shardings(cfg: ModelConfig, mesh, batch: int):
    """Sharding tree parallel to model.cache_decl(cfg, batch, max_len)."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    batch_entry = (dp if len(dp) > 1 else dp[0]) if batch % n_dp == 0 and batch >= n_dp else None

    pattern, n_super, tail = M.block_pattern(cfg)

    def stack_spec(spec_tree):
        return jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    tree = {
        "pattern": [stack_spec(_block_cache_spec(k, cfg, batch_entry)) for k in pattern],
        "tail": [_block_cache_spec(k, cfg, batch_entry) for k in tail],
    }
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )
