"""Serving launcher: two entry points behind one CLI.

Batched incremental decoding with a KV/state cache (the model demo)::

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 32 --gen-len 32

``--smoke`` runs the reduced config on the host devices. Prompts are
consumed through the decode path (single-token steps), then generation
continues greedily — one jitted ``decode_step``, shapes static throughout.

Continuous-batching traffic over the segmented routing plan (DESIGN.md
§16) — many concurrent synthetic users coalesced into ONE segmented
multisplit launch per step::

    PYTHONPATH=src python -m repro.launch.serve --traffic \
        --requests 5000 --qps 2000 --fault-rate 0.01

Open-loop Poisson arrivals drive a :class:`repro.serving.ServerLoop`;
the run prints the exported metrics (p50/p95/p99 latency, sustained QPS,
occupancy, shed/failed/retry counters) and conservation-checks that no
request was silently dropped.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.parallel.sharding import init_params, param_count


def run_traffic(args) -> dict:
    """The continuous-batching path: open-loop Poisson traffic through a
    prewarmed :class:`~repro.serving.ServerLoop` (ONE segmented plan launch
    per step), with optional seeded fault injection exercising the
    retry/requeue/shed machinery under load."""
    from repro.runtime.supervisor import FaultInjector
    from repro.serving import (
        ServerLoop, ServingConfig, open_loop, poisson_arrivals,
        synthetic_requests,
    )

    cfg = ServingConfig(
        num_experts=args.num_experts,
        capacity=args.capacity,
        max_batch_requests=args.max_batch_requests,
        max_batch_tokens=args.max_batch_tokens,
        max_wait=args.max_wait,
        backend=args.backend,
    )
    faults = None
    if args.fault_rate:
        faults = FaultInjector(rate=args.fault_rate, seed=args.seed)
    loop = ServerLoop(cfg, fault_injector=faults)
    t0 = time.monotonic()
    loop.prewarm()
    print(f"[serve] prewarm {time.monotonic() - t0:.2f}s "
          f"(shape classes {cfg.token_pad_classes}, backend {cfg.backend})")

    reqs = synthetic_requests(args.requests, cfg.num_experts, seed=args.seed)
    arrivals = poisson_arrivals(args.requests, args.qps, seed=args.seed)
    print(f"[serve] open loop: {args.requests} requests at {args.qps:.0f} QPS "
          f"(Poisson), fault rate {args.fault_rate}")
    open_loop(loop, reqs, arrivals)

    s = loop.metrics_summary()
    assert s["dropped_by_bug"] == 0, f"request accounting violated: {s}"
    print(f"[serve] completed {s['completed']}/{s['submitted']} "
          f"(shed {s['shed']}, failed {s['failed']}, retries {s['retries']})")
    print(f"[serve] latency ms: p50 {s['latency_p50_ms']:.2f}  "
          f"p95 {s['latency_p95_ms']:.2f}  p99 {s['latency_p99_ms']:.2f}")
    print(f"[serve] sustained {s['qps_sustained']:.0f} QPS over {s['steps']} steps, "
          f"occupancy {s['batch_token_occupancy']:.2f}, "
          f"mean batch {s['batch_requests_mean']:.1f} requests")
    return s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="model arch for the decode demo (required unless --traffic)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    # continuous-batching traffic mode (DESIGN.md §16)
    ap.add_argument("--traffic", action="store_true",
                    help="serve synthetic open-loop traffic through the "
                         "continuous-batching ServerLoop instead of the decode demo")
    ap.add_argument("--requests", type=int, default=5000)
    ap.add_argument("--qps", type=float, default=2000.0)
    ap.add_argument("--num-experts", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--max-batch-requests", type=int, default=64)
    ap.add_argument("--max-batch-tokens", type=int, default=4096)
    ap.add_argument("--max-wait", type=float, default=0.02)
    ap.add_argument("--backend", default="vmap")
    ap.add_argument("--fault-rate", type=float, default=0.0)
    args = ap.parse_args(argv)

    if args.traffic:
        return run_traffic(args)
    if args.arch is None:
        ap.error("--arch is required unless --traffic is given")

    cfg = get_config(args.arch).smoke() if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    max_len = args.prompt_len + args.gen_len

    decls = M.decl_model(cfg)
    print(f"[serve] {cfg.name}: {param_count(decls)/1e6:.1f}M params")
    params = init_params(decls, jax.random.PRNGKey(args.seed))

    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(1, cfg.vocab, size=(args.batch, args.prompt_len), dtype=np.int32)

    @jax.jit
    def step(params, cache, tok, pos):
        logits, cache = M.decode_step(params, cfg, cache, tok, pos)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    with jax.set_mesh(mesh):
        vis = None
        if cfg.n_vis_tokens:
            vis = jnp.asarray(rng.randn(args.batch, cfg.n_vis_tokens, cfg.d_model),
                              jnp.dtype(cfg.dtype))
        cache = M.init_cache(params, cfg, args.batch, max_len=max_len, vis_embeds=vis)
        tokens = jnp.asarray(prompts)
        # prompt consumption (token-by-token through the decode path)
        nxt = None
        t0 = time.time()
        for t in range(args.prompt_len):
            if cfg.embed_frontend_stub:
                emb = jax.random.normal(
                    jax.random.PRNGKey(t), (args.batch, 1, cfg.d_model),
                    jnp.dtype(cfg.dtype))
                nxt, cache = step(params, cache, emb, jnp.asarray(t, jnp.int32))
            else:
                nxt, cache = step(params, cache, tokens[:, t:t + 1], jnp.asarray(t, jnp.int32))
        generated = [np.asarray(nxt)]
        for t in range(args.prompt_len, max_len - 1):
            if cfg.embed_frontend_stub:
                emb = params["embed"]  # audio stub has no token embedding table
                raise SystemExit("generation loop for frontend-stub archs needs "
                                 "external frame embeddings; serve supports "
                                 "token archs")
            nxt, cache = step(params, cache, generated[-1][:, None], jnp.asarray(t, jnp.int32))
            generated.append(np.asarray(nxt))
        dt = time.time() - t0
        gen = np.stack(generated, axis=1)
    n_steps = args.prompt_len + len(generated) - 1
    print(f"[serve] {n_steps} decode steps, batch {args.batch}: "
          f"{1000 * dt / n_steps:.1f} ms/step, {args.batch * n_steps / dt:.1f} tok/s")
    print(f"[serve] sample continuation: {gen[0, :16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
