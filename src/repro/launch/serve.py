"""Serving launcher: batched incremental decoding with a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 32 --gen-len 32

``--smoke`` runs the reduced config on the host devices. Prompts are
consumed through the decode path (single-token steps), then generation
continues greedily — one jitted ``decode_step``, shapes static throughout.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.parallel.sharding import init_params, param_count


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).smoke() if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    max_len = args.prompt_len + args.gen_len

    decls = M.decl_model(cfg)
    print(f"[serve] {cfg.name}: {param_count(decls)/1e6:.1f}M params")
    params = init_params(decls, jax.random.PRNGKey(args.seed))

    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(1, cfg.vocab, size=(args.batch, args.prompt_len), dtype=np.int32)

    @jax.jit
    def step(params, cache, tok, pos):
        logits, cache = M.decode_step(params, cfg, cache, tok, pos)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    with jax.set_mesh(mesh):
        vis = None
        if cfg.n_vis_tokens:
            vis = jnp.asarray(rng.randn(args.batch, cfg.n_vis_tokens, cfg.d_model),
                              jnp.dtype(cfg.dtype))
        cache = M.init_cache(params, cfg, args.batch, max_len=max_len, vis_embeds=vis)
        tokens = jnp.asarray(prompts)
        # prompt consumption (token-by-token through the decode path)
        nxt = None
        t0 = time.time()
        for t in range(args.prompt_len):
            if cfg.embed_frontend_stub:
                emb = jax.random.normal(
                    jax.random.PRNGKey(t), (args.batch, 1, cfg.d_model),
                    jnp.dtype(cfg.dtype))
                nxt, cache = step(params, cache, emb, jnp.asarray(t, jnp.int32))
            else:
                nxt, cache = step(params, cache, tokens[:, t:t + 1], jnp.asarray(t, jnp.int32))
        generated = [np.asarray(nxt)]
        for t in range(args.prompt_len, max_len - 1):
            if cfg.embed_frontend_stub:
                emb = params["embed"]  # audio stub has no token embedding table
                raise SystemExit("generation loop for frontend-stub archs needs "
                                 "external frame embeddings; serve supports "
                                 "token archs")
            nxt, cache = step(params, cache, generated[-1][:, None], jnp.asarray(t, jnp.int32))
            generated.append(np.asarray(nxt))
        dt = time.time() - t0
        gen = np.stack(generated, axis=1)
    n_steps = args.prompt_len + len(generated) - 1
    print(f"[serve] {n_steps} decode steps, batch {args.batch}: "
          f"{1000 * dt / n_steps:.1f} ms/step, {args.batch * n_steps / dt:.1f} tok/s")
    print(f"[serve] sample continuation: {gen[0, :16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
