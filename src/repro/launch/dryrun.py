import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below may import jax.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single                       # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all       # everything

Artifacts: artifacts/dryrun/{arch}__{shape}__{mesh}.json — consumed by
benchmarks/roofline.py and EXPERIMENTS.md.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, ALIASES, get_config
from repro.configs.base import ParallelConfig, TrainConfig
from repro.launch import steps as S
from repro.launch.hlo_analysis import dominant_term, parse_collectives, roofline_terms
from repro.launch.input_specs import SHAPES, batch_specs, decode_specs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.parallel.sharding import param_count

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

FSDP_THRESHOLD = 50e9      # params; larger archs shard params/opt over data
BF16_MOMENTS_THRESHOLD = 300e9


def parallel_config(cfg, mesh, n_params: int) -> ParallelConfig:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return ParallelConfig(fsdp=n_params > FSDP_THRESHOLD, dp_axes=dp)


def train_config(n_params: int) -> TrainConfig:
    return TrainConfig(
        moments_dtype="bfloat16" if n_params > BF16_MOMENTS_THRESHOLD else "float32"
    )


def model_flops_estimate(cfg, decls, shape: str) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for inference."""
    n_total = param_count(decls)
    n_active = active_param_count(cfg, decls)
    info = SHAPES[shape]
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        return 6.0 * n_active * tokens
    if info["kind"] == "prefill":
        tokens = info["batch"] * info["seq"]
        return 2.0 * n_active * tokens
    return 2.0 * n_active * info["batch"]          # decode: one token per row


def active_param_count(cfg, decls) -> float:
    n_total = param_count(decls)
    if not cfg.moe.num_experts:
        return float(n_total)
    # subtract non-routed fraction of expert params
    import numpy as np

    expert_params = 0
    for blk in decls["blocks"]:
        if "moe" in blk:
            for key in ("w_gate", "w_up", "w_down"):
                expert_params += int(np.prod(blk["moe"][key].shape))
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return float(n_total - expert_params * (1.0 - frac))


def _lower_and_compile(cfg, shape, mesh, pcfg, tc, capture_hlo_to=None):
    """Lower + compile one graph; return (cost, mem, collectives, timings)."""
    decls = M.decl_model(cfg)
    kind = SHAPES[shape]["kind"]
    t0 = time.time()
    with jax.set_mesh(mesh):
        if kind == "train":
            step = S.make_train_step(cfg, tc)
            st_sh = S.state_shardings(decls, pcfg, mesh, tc)
            st_abs = S.abstract_state(decls, tc)
            batch_abs = batch_specs(cfg, shape, with_labels=True)
            b_sh = S.batch_sharding(cfg, mesh, batch_abs)
            jitted = jax.jit(
                step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(st_abs, batch_abs)
        elif kind == "prefill":
            step = S.make_prefill_step(cfg)
            p_sh = S.state_shardings(decls, pcfg, mesh, tc).params
            p_abs = S.abstract_state(decls, tc).params
            batch_abs = batch_specs(cfg, shape, with_labels=False)
            b_sh = S.batch_sharding(cfg, mesh, batch_abs)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_abs, batch_abs)
        else:  # decode
            step = S.make_decode_step(cfg)
            p_sh = S.state_shardings(decls, pcfg, mesh, tc).params
            p_abs = S.abstract_state(decls, tc).params
            cache_abs, token_abs, pos_abs = decode_specs(cfg, shape)
            c_sh = S.cache_shardings(cfg, mesh, SHAPES[shape]["batch"])
            t_sh = S.batch_sharding(cfg, mesh, token_abs)
            jitted = jax.jit(
                step, in_shardings=(p_sh, c_sh, t_sh, None),
                out_shardings=(None, c_sh), donate_argnums=(1,),
            )
            lowered = jitted.lower(p_abs, cache_abs, token_abs, pos_abs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    from repro.compat import cost_analysis_dict

    cost = cost_analysis_dict(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            k: int(getattr(mem, k))
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        mem_rec = {"error": str(e)}
    hlo = compiled.as_text()
    if capture_hlo_to:
        Path(capture_hlo_to).write_text(hlo)
    colls = parse_collectives(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": sum(v["operand_bytes"] for v in colls.values()),
        "collectives": colls,
        "memory": mem_rec,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }


# Inner-scan chunk overrides so the unit lowerings can unroll everything:
# cost_analysis counts while bodies once, so every loop in the unit graphs
# must be unrolled for exact accounting (DESIGN.md §8, EXPERIMENTS.md §Dry-run).
_UNIT_OVERRIDES = {
    "train_4k": {"attn_chunk": 1024, "ssd_chunk": 1024, "loss_chunk": 1024},
    "prefill_32k": {"attn_chunk": 4096, "ssd_chunk": 4096, "loss_chunk": 8192},
    "decode_32k": {},
    "long_500k": {},
}


def lower_cell(arch: str, shape: str, mesh_kind: str, capture_hlo_to=None,
               cfg_overrides=None, tc_overrides=None):
    """Lower + compile one cell.

    Two accountings:
      * FULL graph (scanned layers): the deployable artifact — proves the
        sharding compiles, gives memory_analysis and the collective schedule.
      * COST via two-point delta: XLA's cost_analysis counts while-loop
        bodies once, so we compile unit graphs at 1x and 2x the layer
        pattern with ALL inner scans unrolled; per-superblock cost =
        unit2 - unit1, total = unit1 + (n_layers/pattern - 1) * delta.
        (sLSTM's time recurrence stays a loop — its FLOPs are analytically
        folded into MODEL_FLOPS instead; see EXPERIMENTS.md.)
    """
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        moe_over = {k[4:]: v for k, v in cfg_overrides.items() if k.startswith("moe_")}
        plain = {k: v for k, v in cfg_overrides.items() if not k.startswith("moe_")}
        if plain:
            cfg = _dc.replace(cfg, **plain)
        if moe_over:
            cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, **moe_over))
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape, "mesh": mesh_kind, "status": "skipped",
                "reason": "full-attention arch; long_500k requires sub-quadratic attention"}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    decls = M.decl_model(cfg)
    n_params = param_count(decls)
    pcfg = parallel_config(cfg, mesh, n_params)
    tc = train_config(n_params)
    if tc_overrides:
        tc = _dc.replace(tc, **tc_overrides)

    full = _lower_and_compile(cfg, shape, mesh, pcfg, tc, capture_hlo_to=capture_hlo_to)

    if mesh_kind == "multi":
        # The multi-pod pass proves the "pod" axis shards (full compile
        # above); the roofline table is single-pod only per the brief —
        # skip the unit-accounting compiles.
        return {
            "arch": arch, "shape": shape, "mesh": mesh_kind, "status": "ok",
            "n_chips": int(mesh.devices.size), "n_params": int(n_params),
            "fsdp": pcfg.fsdp, "moments_dtype": tc.moments_dtype,
            "lower_s": full["lower_s"], "compile_s": full["compile_s"],
            "collectives_full_graph": full["collectives"],
            "memory_analysis": full["memory"],
            "roofline": None, "dominant": None,
        }

    import dataclasses

    pattern, n_super, tail = M.block_pattern(cfg)
    plen = len(pattern)
    over = dict(_UNIT_OVERRIDES[shape], unroll_scans=True)
    cfg1 = dataclasses.replace(cfg, n_layers=plen, **over)
    cfg2 = dataclasses.replace(cfg, n_layers=2 * plen, **over)
    unit1 = _lower_and_compile(cfg1, shape, mesh, pcfg, tc)
    unit2 = _lower_and_compile(cfg2, shape, mesh, pcfg, tc)

    n_chips = mesh.devices.size
    mult = cfg.n_layers / plen          # fractional superblocks cover the tail
    corrected = {}
    for key in ("flops", "bytes", "collective_bytes"):
        delta = unit2[key] - unit1[key]
        # cost_analysis / HLO shapes are PER-DEVICE post-partitioning;
        # scale to global so HLO_FLOPs / (chips * peak) is the per-chip time.
        corrected[key] = (unit1[key] + (mult - 1.0) * delta) * n_chips
    terms = roofline_terms(
        corrected["flops"], corrected["bytes"], corrected["collective_bytes"], n_chips
    )
    mflops = model_flops_estimate(cfg, decls, shape)

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "status": "ok",
        "n_chips": int(n_chips),
        "n_params": int(n_params),
        "n_params_active": int(active_param_count(cfg, decls)),
        "fsdp": pcfg.fsdp,
        "moments_dtype": tc.moments_dtype,
        "lower_s": full["lower_s"], "compile_s": full["compile_s"],
        "unit_compile_s": [unit1["compile_s"], unit2["compile_s"]],
        "unit_raw": {
            "unit1": {k: unit1[k] for k in ("flops", "bytes", "collective_bytes")},
            "unit2": {k: unit2[k] for k in ("flops", "bytes", "collective_bytes")},
        },
        "hlo_flops": corrected["flops"], "hlo_bytes": corrected["bytes"],
        "collective_bytes": corrected["collective_bytes"],
        "hlo_flops_scanned_raw": full["flops"],
        "collectives_full_graph": full["collectives"],
        "collectives_per_superblock": {
            k: {
                "count": unit2["collectives"].get(k, {}).get("count", 0)
                - unit1["collectives"].get(k, {}).get("count", 0),
                "operand_bytes": unit2["collectives"].get(k, {}).get("operand_bytes", 0)
                - unit1["collectives"].get(k, {}).get("operand_bytes", 0),
            }
            for k in set(unit1["collectives"]) | set(unit2["collectives"])
        },
        "memory_analysis": full["memory"],
        "roofline": terms,
        "dominant": dominant_term(terms),
        "model_flops": mflops,
        "useful_fraction": (mflops / corrected["flops"]) if corrected["flops"] else None,
    }
    return rec


def run_cells(cells, out_dir: Path, hlo_dir=None, variant: str = "",
              cfg_overrides=None, tc_overrides=None):
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    for arch, shape, mesh_kind in cells:
        name = f"{arch}__{shape}__{mesh_kind}{suffix}"
        out_path = out_dir / f"{name}.json"
        if out_path.exists():
            print(f"[skip cached] {name}")
            continue
        print(f"[dryrun] {name} ...", flush=True)
        try:
            hlo_to = (Path(hlo_dir) / f"{name}.hlo.txt") if hlo_dir else None
            rec = lower_cell(arch, shape, mesh_kind, capture_hlo_to=hlo_to,
                             cfg_overrides=cfg_overrides, tc_overrides=tc_overrides)
            if variant:
                rec["variant"] = variant
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        out_path.write_text(json.dumps(rec, indent=2, default=str))
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = f" compile={rec.get('compile_s')}s"
            if rec.get("hlo_flops") is not None:
                extra += f" dominant={rec['dominant']} flops={rec['hlo_flops']:.3g}"
        print(f"[done] {name}: {status}{extra}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", help="architecture id (repeatable)")
    ap.add_argument("--shape", action="append", choices=list(SHAPES))
    ap.add_argument("--mesh", action="append", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ART_DIR))
    ap.add_argument("--hlo-dir", default=None, help="also dump optimized HLO text")
    ap.add_argument("--variant", default="", help="artifact suffix for perf variants")
    ap.add_argument("--cfg-set", action="append", default=[],
                    help="ModelConfig override k=v (moe_* targets the MoE sub-config)")
    ap.add_argument("--tc-set", action="append", default=[],
                    help="TrainConfig override k=v")
    args = ap.parse_args()

    def parse_kv(items):
        out = {}
        for it in items:
            k, v = it.split("=", 1)
            if v in ("true", "True"):
                v = True
            elif v in ("false", "False"):
                v = False
            else:
                try:
                    v = int(v)
                except ValueError:
                    try:
                        v = float(v)
                    except ValueError:
                        pass
            out[k] = v
        return out

    archs = args.arch or (list(ALIASES) if args.all or not args.arch else [])
    shapes = args.shape or list(SHAPES)
    meshes = args.mesh or ["single", "multi"]
    cells = [(a, s, m) for a in archs for s in shapes for m in meshes]
    run_cells(cells, Path(args.out), hlo_dir=args.hlo_dir, variant=args.variant,
              cfg_overrides=parse_kv(args.cfg_set) or None,
              tc_overrides=parse_kv(args.tc_set) or None)


if __name__ == "__main__":
    main()
