"""minicpm-2b [dense]: llama-like arch trained with the WSD schedule
(arXiv:2404.06395). 40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.
Tied embeddings; train with TrainConfig(schedule="wsd")."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv=36,
    d_ff=5760,
    vocab=122753,
    tie_embeddings=True,
)
