"""stablelm-1.6b [dense] (hf:stabilityai/stablelm-2-1_6b). 24L d_model=2048
32H (kv=32) d_ff=5632 vocab=100352. LayerNorm + partial rotary (25%)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=5632,
    vocab=100352,
    norm="layernorm",
    rope_pct=0.25,
    rope_theta=10000.0,
)
