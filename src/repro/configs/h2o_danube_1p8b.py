"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention
(arXiv:2401.16818). 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000,
window=4096. The SWA window makes this arch sub-quadratic: it runs the
long_500k decode shape with an O(window) ring-buffer cache."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv=8,
    d_ff=6912,
    vocab=32000,
    window=4096,
)
