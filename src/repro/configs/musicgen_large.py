"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens
(arXiv:2306.05284). 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.
The EnCodec frontend is a STUB: inputs are precomputed frame embeddings
(B, S, d_model); the backbone + LM head over the 2048-codebook vocab are real.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=2048,
    norm="layernorm",
    embed_frontend_stub=True,
)
