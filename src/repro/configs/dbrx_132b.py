"""dbrx-132b [moe]: fine-grained MoE, 16 experts top-4
(hf:databricks/dbrx-base). 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352. Every layer is MoE; dispatch = multisplit (the paper's
technique; see repro.models.moe)."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(num_experts=16, top_k=4, every=1, dispatch="multisplit",
                  capacity_factor=1.25),
)
