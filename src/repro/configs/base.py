"""Configuration dataclasses: model architecture, training, parallelism.

Every assigned architecture is a ``ModelConfig`` instance in its own module
under ``repro.configs``; reduced smoke variants derive from the full config
via ``smoke()``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    every: int = 1              # every k-th block is MoE (1 = all)
    shared_expert: bool = False  # llama4-style always-on shared expert
    dispatch: str = "dense"      # "dense" | "sort" | "multisplit"
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 0              # d_state (zamba2: 64)
    conv: int = 4               # conv1d width
    headdim: int = 64
    expand: int = 2
    attn_every: int = 0         # hybrid: a (shared) attention block every k blocks
    shared_attn: bool = False   # zamba2: ONE attention block's params reused


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    rope_theta: float = 1e4
    rope_pct: float = 1.0       # stablelm-2 uses partial rotary (25%)
    window: Optional[int] = None  # sliding-window attention (h2o-danube)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()

    # xLSTM: every k-th block is sLSTM, the rest mLSTM (0 = no lstm blocks)
    slstm_every: int = 0
    # VLM: every k-th block gets cross-attention to vision embeddings
    cross_attn_every: int = 0
    n_vis_tokens: int = 0
    # audio: input is precomputed frame embeddings (frontend stubbed)
    embed_frontend_stub: bool = False

    dtype: str = "bfloat16"
    remat: bool = True
    scan_blocks: bool = True
    attn_chunk: int = 1024      # KV block size for chunked (flash-style) attention
    loss_chunk: int = 512       # sequence block size for chunked cross-entropy
    ssd_chunk: int = 256        # SSD / mLSTM chunk length
    # Dry-run cost accounting: XLA cost_analysis counts while-loop bodies
    # once, so the roofline lowering unrolls every inner scan (see
    # launch/dryrun.py two-point delta method).
    unroll_scans: bool = False
    # perf lever (§Perf): attention probabilities cast to bf16 for the
    # p@V matmul (softmax stats stay fp32)
    attn_probs_bf16: bool = False
    # perf lever (§Perf): pad the vocab dim of embedding/head to a multiple
    # of 2048 so it shards over TP even for awkward vocabs (minicpm: 122753)
    pad_vocab: bool = False
    # perf lever (§Perf): zero-pad attention heads to a multiple of TP at
    # runtime when the head count doesn't divide (minicpm: 36 over 16) —
    # 1.33x head compute vs 16x replicated attention memory
    pad_attn_heads: bool = False
    # perf lever (§Perf): keep logits in bf16; cross-entropy accumulates the
    # logsumexp in fp32 without materializing fp32 logits
    loss_bf16_logits: bool = False

    def padded_vocab(self) -> int:
        if not self.pad_vocab:
            return self.vocab
        return -(-self.vocab // 2048) * 2048

    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def is_subquadratic(self) -> bool:
        """May run the long_500k shape (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        scale = {
            "d_model": 64,
            "n_heads": 4,
            "n_kv": min(self.n_kv, 4) if self.n_kv < self.n_heads else 4,
            "d_ff": 128 if self.d_ff else 0,
            "vocab": 512,
            "head_dim": 16,
            "n_vis_tokens": 16 if self.n_vis_tokens else 0,
            "window": 64 if self.window else None,
            "attn_chunk": 64,
            "loss_chunk": 64,
            "dtype": "float32",
        }
        # keep the structural pattern but only a couple of super-blocks
        pat = _pattern_period(self)
        scale["n_layers"] = 2 * pat
        moe = self.moe
        if moe.num_experts:
            # high capacity factor: smoke tests check decode == forward, which
            # requires no capacity drops
            moe = dataclasses.replace(
                moe, num_experts=8, top_k=min(moe.top_k, 2), capacity_factor=4.0
            )
        ssm = self.ssm
        if ssm.state:
            ssm = dataclasses.replace(ssm, state=16, headdim=16, expand=2)
        return dataclasses.replace(self, name=self.name + "-smoke", moe=moe, ssm=ssm, **scale)


def _pattern_period(cfg: ModelConfig) -> int:
    """Length of one structural super-block (see models/model.py)."""
    if cfg.family == "hybrid" and cfg.ssm.attn_every:
        return cfg.ssm.attn_every
    if cfg.family == "vlm" and cfg.cross_attn_every:
        return cfg.cross_attn_every
    if cfg.family == "moe" and cfg.moe.every > 1:
        return cfg.moe.every
    if cfg.slstm_every:
        return cfg.slstm_every
    return 1


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    lr: float = 3e-4
    schedule: str = "cosine"    # cosine | wsd (minicpm's Warmup-Stable-Decay)
    warmup_steps: int = 100
    decay_start: float = 0.8    # WSD: fraction of total steps where decay begins
    total_steps: int = 10000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    clip_norm: float = 1.0
    moments_dtype: str = "float32"  # float32 | bfloat16 (memory-bound archs)
    # "bfloat16": train-state params are bf16 (halved weight reads + bf16
    # gradient reductions); the fp32 master copy lives in the optimizer state
    params_dtype: str = "float32"
    accum_steps: int = 1
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    fsdp: bool = False           # shard params/opt-state over the data axis
    seq_shard_prefill: bool = False  # sequence parallelism for long prefill
    grad_compress: bool = False  # int8 + error-feedback cross-pod gradients
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
