"""Architecture registry: one module per assigned architecture."""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "zamba2_1p2b",
    "musicgen_large",
    "xlstm_350m",
    "tinyllama_1p1b",
    "stablelm_1p6b",
    "h2o_danube_1p8b",
    "minicpm_2b",
    "llama32_vision_90b",
    "dbrx_132b",
    "llama4_maverick_400b",
]

# canonical external names -> module names
ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "musicgen-large": "musicgen_large",
    "xlstm-350m": "xlstm_350m",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "stablelm-1.6b": "stablelm_1p6b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "minicpm-2b": "minicpm_2b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
}


def get_config(arch: str):
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
