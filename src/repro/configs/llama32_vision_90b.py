"""llama-3.2-vision-90b [vlm]: cross-attention image layers every 5th layer
(hf:meta-llama/Llama-3.2-11B-Vision scaled per assignment). 100L
d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The ViT frontend is a
STUB: inputs include precomputed patch embeddings (B, n_vis, d_model)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=28672,
    vocab=128256,
    cross_attn_every=5,
    n_vis_tokens=256,
)
