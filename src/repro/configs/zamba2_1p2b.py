"""zamba2-1.2b [hybrid]: Mamba2 backbone + ONE shared attention block applied
every 6th layer (arXiv:2411.15242). 38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000 ssm_state=64. Pattern: (5 mamba + shared_attn) x 6 + 2 mamba."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(state=64, conv=4, headdim=64, expand=2, attn_every=6, shared_attn=True),
)
