"""xlstm-350m [ssm]: alternating mLSTM / sLSTM blocks (arXiv:2405.04517).
24L d_model=1024 4H (kv=4) d_ff=0 (feed-forward lives inside the blocks)
vocab=50304. Pattern: (mLSTM, sLSTM) x 12."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    norm="layernorm",
    slstm_every=2,
)
