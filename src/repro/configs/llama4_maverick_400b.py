"""llama4-maverick-400b-a17b [moe]: 128 experts top-1 + shared expert,
interleaved MoE layers, early-fusion multimodal (text path built here)
(hf:meta-llama/Llama-4 family). 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048. m=128 buckets is the paper's large-m regime; dispatch =
multisplit. bf16 optimizer moments (memory: 400B params on one pod)."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_ff=8192,
    vocab=202048,
    moe=MoEConfig(num_experts=128, top_k=1, every=2, shared_expert=True,
                  dispatch="multisplit", capacity_factor=1.25),
)
