"""tinyllama-1.1b [dense]: llama2-architecture small model (arXiv:2401.02385).
22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    d_ff=5632,
    vocab=32000,
)
