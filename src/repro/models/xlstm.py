"""xLSTM blocks (mLSTM + sLSTM) for the xlstm-350m architecture.

* mLSTM: matrix-memory LSTM with exponential gating. Training uses the
  chunkwise-parallel quadratic form (same scan-over-chunks skeleton as the
  SSD Mamba2 kernel — MXU matmuls within chunks, O(1) state across chunks).
* sLSTM: scalar-memory LSTM with per-head recurrent weights — inherently
  sequential, trained with a time scan (this is faithful to the paper: the
  sLSTM's recurrence is not parallelizable over time).

Both blocks carry their own up/down projections (the assigned config has
``d_ff = 0``: there is no separate MLP).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm, norm_decl
from repro.parallel.sharding import ParamDecl

Array = jnp.ndarray

MLSTM_CHUNK = 256
MLSTM_EXPAND = 2
SLSTM_FF = 4 / 3


def _mdims(cfg: ModelConfig):
    d_inner = MLSTM_EXPAND * cfg.d_model
    nh = cfg.n_heads
    hd = d_inner // nh
    return d_inner, nh, hd


def mlstm_decl(cfg: ModelConfig):
    d = cfg.d_model
    d_inner, nh, hd = _mdims(cfg)
    return {
        "norm": norm_decl(cfg),
        "up_proj": ParamDecl((d, 2 * d_inner), ("embed", "inner")),
        "wq": ParamDecl((d_inner, d_inner), ("inner", None)),
        "wk": ParamDecl((d_inner, d_inner), ("inner", None)),
        "wv": ParamDecl((d_inner, d_inner), ("inner", None)),
        "w_if": ParamDecl((d_inner, 2 * nh), ("inner", None), scale=0.1),
        "b_if": ParamDecl((2 * nh,), (None,), init="zeros"),
        "norm_h": norm_decl(cfg, d_inner),
        "down_proj": ParamDecl((d_inner, d), ("inner", "embed_fsdp")),
    }


def mlstm_block(
    p, x: Array, cfg: ModelConfig, cache: Optional[dict] = None
) -> Tuple[Array, Optional[dict]]:
    d_inner, nh, hd = _mdims(cfg)
    dtype = x.dtype
    b, s, _ = x.shape
    xn = apply_norm(p["norm"], x, cfg)
    up = jnp.einsum("bsd,dk->bsk", xn, p["up_proj"].astype(dtype))
    xin, z = up[..., :d_inner], up[..., d_inner:]
    q = jnp.einsum("bsk,kj->bsj", xin, p["wq"].astype(dtype)).reshape(b, s, nh, hd)
    k = jnp.einsum("bsk,kj->bsj", xin, p["wk"].astype(dtype)).reshape(b, s, nh, hd)
    v = jnp.einsum("bsk,kj->bsj", xin, p["wv"].astype(dtype)).reshape(b, s, nh, hd)
    gates = jnp.einsum("bsk,kj->bsj", xin, p["w_if"].astype(dtype)).astype(jnp.float32) + p["b_if"]
    log_i = gates[..., :nh]                                   # pre-activation input gate
    log_f = jax.nn.log_sigmoid(gates[..., nh:])               # (B, S, nh) <= 0

    if cache is None:
        h, _, _, _ = _mlstm_chunked(q, k, v, log_i, log_f, nh, hd,
                                    chunk=cfg.ssd_chunk, unroll=cfg.unroll_scans)
        new_cache = None
    else:
        c0, n0, m0 = cache["c"], cache["n"], cache["m"]       # (B,nh,hd,hd),(B,nh,hd),(B,nh)
        li, lf = log_i[:, 0], log_f[:, 0]                     # (B, nh)
        m1 = jnp.maximum(lf + m0, li)
        fg = jnp.exp(lf + m0 - m1)
        ig = jnp.exp(li - m1)
        kf = k[:, 0].astype(jnp.float32) / np.sqrt(hd)
        c1 = c0 * fg[..., None, None] + ig[..., None, None] * jnp.einsum(
            "bnd,bne->bnde", kf, v[:, 0].astype(jnp.float32)
        )
        n1 = n0 * fg[..., None] + ig[..., None] * kf
        qf = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bnd,bnde->bne", qf, c1)
        den = jnp.maximum(jnp.abs(jnp.einsum("bnd,bnd->bn", qf, n1)), jnp.exp(-m1))
        h = (num / den[..., None])[:, None]                   # (B,1,nh,hd)
        new_cache = {"c": c1, "n": n1, "m": m1, "pos": cache["pos"] + s}

    h = h.reshape(b, s, d_inner).astype(dtype)
    h = apply_norm(p["norm_h"], h, cfg) * jax.nn.silu(z.astype(jnp.float32)).astype(dtype)
    return jnp.einsum("bsk,kd->bsd", h, p["down_proj"].astype(dtype)), new_cache


def _mlstm_chunked(q, k, v, log_i, log_f, nh, hd, chunk: int = MLSTM_CHUNK,
                   unroll: bool = False):
    """Chunkwise-parallel stabilized mLSTM. Shapes (B,S,nh,hd)/(B,S,nh)."""
    b, s = q.shape[0], q.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, pad4) for t in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)
    nc = q.shape[1] // chunk
    scale = 1.0 / np.sqrt(hd)

    def per_chunk(carry, inp):
        c, n, m = carry                                        # (B,nh,hd,hd),(B,nh,hd),(B,nh)
        qc, kc, vc, lic, lfc = inp
        qc = qc.astype(jnp.float32)
        kc = kc.astype(jnp.float32) * scale
        vc = vc.astype(jnp.float32)
        cum_f = jnp.cumsum(lfc, axis=1)                        # (B,C,nh) inclusive
        # stabilizer within the chunk
        log_a = cum_f + 0.0                                    # decay from chunk start to t
        # intra: D[i,j] = exp(cum_f_i - cum_f_j + li_j), j <= i
        dmat = cum_f[:, :, None, :] - cum_f[:, None, :, :] + lic[:, None, :, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
        m_intra = dmat.max(axis=2)                             # (B,C,nh)
        m_inter = log_a + m[:, None, :]                        # carried max decayed
        m_new_t = jnp.maximum(m_intra, m_inter)                # (B,C,nh) per-step stabilizer
        dw = jnp.exp(dmat - m_new_t[:, :, None, :])            # (B,C,C,nh)
        sc = jnp.einsum("bind,bjnd->bijn", qc, kc)
        num_intra = jnp.einsum("bijn,bjne->bine", sc * dw, vc)
        # denominator tracked via the n vector (stabilized mLSTM)
        n_intra = jnp.einsum("bijn,bjnd->bind", dw, kc)        # (B,C,nh,hd)
        inter_w = jnp.exp(log_a + m[:, None, :] - m_new_t)     # (B,C,nh)
        num_inter = jnp.einsum("bind,bnde->bine", qc, c) * inter_w[..., None]
        n_tot = n_intra + n[:, None] * inter_w[..., None]
        num = num_intra + num_inter
        den = jnp.maximum(jnp.abs(jnp.einsum("bind,bind->bin", qc, n_tot)), jnp.exp(-m_new_t))
        h = num / den[..., None]                               # (B,C,nh,hd)

        # state across the chunk boundary
        tot_f = cum_f[:, -1]                                   # (B,nh)
        m_next = jnp.maximum(tot_f + m, (tot_f[:, None, :] - cum_f + lic).max(axis=1))
        upd_w = jnp.exp(tot_f[:, None, :] - cum_f + lic - m_next[:, None, :])  # (B,C,nh)
        c_next = c * jnp.exp(tot_f + m - m_next)[..., None, None] + jnp.einsum(
            "bin,bind,bine->bnde", upd_w, kc, vc
        )
        n_next = n * jnp.exp(tot_f + m - m_next)[..., None] + jnp.einsum(
            "bin,bind->bnd", upd_w, kc
        )
        return (c_next, n_next, m_next), h

    c0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, nh, hd), jnp.float32)
    m0 = jnp.full((b, nh), -1e30, jnp.float32)
    reshape = lambda t: t.reshape((b, nc, chunk) + t.shape[2:]).swapaxes(0, 1)
    (c, n, m), hs = jax.lax.scan(
        per_chunk, (c0, n0, m0),
        (reshape(q), reshape(k), reshape(v), reshape(log_i), reshape(log_f)),
        unroll=unroll,
    )
    h = hs.swapaxes(0, 1).reshape(b, nc * chunk, nh, -1)[:, :s]
    return h, c, n, m


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_decl(cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    f = int(SLSTM_FF * d) // 128 * 128 or int(SLSTM_FF * d)
    return {
        "norm": norm_decl(cfg),
        "w_in": ParamDecl((d, 4 * d), ("embed", "inner")),       # i, f, z, o pre-acts
        "r": ParamDecl((nh, hd, 4 * hd), ("state_heads", None, None), scale=0.5 / np.sqrt(hd)),
        "b": ParamDecl((4 * d,), (None,), init="zeros"),
        "norm_h": norm_decl(cfg, d),
        "ff_norm": norm_decl(cfg),
        "ff_up": ParamDecl((d, 2 * f), ("embed", "ff")),
        "ff_down": ParamDecl((f, d), ("ff", "embed_fsdp")),
    }


def _slstm_step(p_r, carry, gates_x, nh, hd):
    """One sLSTM time step. gates_x: (B, 4d) input contribution."""
    c, n, h, m = carry                                          # each (B, nh, hd); m (B,nh,hd)
    b = gates_x.shape[0]
    rec = jnp.einsum("bnd,ndk->bnk", h, p_r)                    # (B, nh, 4hd)
    gx = gates_x.reshape(b, nh, 4 * hd) + rec
    li, lf, z, o = jnp.split(gx, 4, axis=-1)                    # (B, nh, hd)
    m_new = jnp.maximum(jax.nn.log_sigmoid(lf) + m, li)
    ig = jnp.exp(li - m_new)
    fg = jnp.exp(jax.nn.log_sigmoid(lf) + m - m_new)
    c_new = fg * c + ig * jnp.tanh(z)
    n_new = jnp.maximum(fg * n + ig, 1e-6)
    h_new = jax.nn.sigmoid(o) * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def slstm_block(
    p, x: Array, cfg: ModelConfig, cache: Optional[dict] = None
) -> Tuple[Array, Optional[dict]]:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    dtype = x.dtype
    b, s, _ = x.shape
    xn = apply_norm(p["norm"], x, cfg)
    gates_x = (jnp.einsum("bsd,dk->bsk", xn, p["w_in"].astype(dtype)).astype(jnp.float32)
               + p["b"])
    p_r = p["r"].astype(jnp.float32)

    if cache is None:
        init = tuple(jnp.zeros((b, nh, hd), jnp.float32) for _ in range(3)) + (
            jnp.full((b, nh, hd), -1e30, jnp.float32),
        )

        def step(carry, gx):
            new = _slstm_step(p_r, carry, gx, nh, hd)
            return new, new[2]

        _, hs = jax.lax.scan(step, init, gates_x.swapaxes(0, 1))
        h = hs.swapaxes(0, 1)                                   # (B, S, nh, hd)
        new_cache = None
    else:
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
        new = _slstm_step(p_r, carry, gates_x[:, 0], nh, hd)
        h = new[2][:, None]
        new_cache = {"c": new[0], "n": new[1], "h": new[2], "m": new[3], "pos": cache["pos"] + s}

    h = h.reshape(b, s, d).astype(dtype)
    y = apply_norm(p["norm_h"], h, cfg)
    # GEGLU feed-forward (the sLSTM block's own FF, d_ff = 4/3 d)
    yn = apply_norm(p["ff_norm"], x + y, cfg)
    up = jnp.einsum("bsd,dk->bsk", yn, p["ff_up"].astype(dtype))
    f = up.shape[-1] // 2
    act = jax.nn.gelu(up[..., :f].astype(jnp.float32)).astype(dtype) * up[..., f:]
    ff = jnp.einsum("bsf,fd->bsd", act, p["ff_down"].astype(dtype))
    return y + ff, new_cache
