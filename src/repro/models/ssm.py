"""Mamba2 (SSD) block for the zamba2 hybrid architecture.

Training uses the chunked state-space-dual form: intra-chunk work is a
masked quadratic form (MXU matmuls), inter-chunk state is carried by a
scan — the TPU-idiomatic parallelization of the selective scan. Decode is
the O(1)-state recurrence.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm, norm_decl
from repro.parallel.sharding import ParamDecl

Array = jnp.ndarray

SSD_CHUNK = 256


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm.headdim
    return d_inner, n_heads, cfg.ssm.headdim, cfg.ssm.state


def mamba2_decl(cfg: ModelConfig):
    d = cfg.d_model
    d_inner, nh, hd, st = _dims(cfg)
    conv_dim = d_inner + 2 * st                       # x, B, C go through the conv
    return {
        "norm": norm_decl(cfg),
        "in_proj": ParamDecl((d, 2 * d_inner + 2 * st + nh), ("embed", "inner")),
        "conv_w": ParamDecl((cfg.ssm.conv, conv_dim), (None, "inner")),
        "conv_b": ParamDecl((conv_dim,), ("inner",), init="zeros"),
        "a_log": ParamDecl((nh,), ("state_heads",), init="zeros"),
        "dt_bias": ParamDecl((nh,), ("state_heads",), init="zeros"),
        "d_skip": ParamDecl((nh,), ("state_heads",), init="ones"),
        "norm_gate": norm_decl(cfg, d_inner),
        "out_proj": ParamDecl((d_inner, d), ("inner", "embed_fsdp")),
    }


def _causal_conv(x: Array, w: Array, b: Array, state: Optional[Array] = None):
    """Depthwise causal conv1d. x: (B, S, C); w: (K, C). Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)                      # (B, S+K-1, C)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return jax.nn.silu((y + b).astype(jnp.float32)).astype(x.dtype), new_state


def _split_proj(z_xbc_dt: Array, cfg: ModelConfig):
    d_inner, nh, hd, st = _dims(cfg)
    z = z_xbc_dt[..., :d_inner]
    xbc = z_xbc_dt[..., d_inner : 2 * d_inner + 2 * st]
    dt = z_xbc_dt[..., 2 * d_inner + 2 * st :]
    return z, xbc, dt


def mamba2_block(
    p, x: Array, cfg: ModelConfig, cache: Optional[dict] = None
) -> Tuple[Array, Optional[dict]]:
    """x: (B, S, d) -> (residual delta, updated cache)."""
    d_inner, nh, hd, st = _dims(cfg)
    dtype = x.dtype
    b, s, _ = x.shape

    xn = apply_norm(p["norm"], x, cfg)
    proj = jnp.einsum("bsd,dk->bsk", xn, p["in_proj"].astype(dtype))
    z, xbc, dt_raw = _split_proj(proj, cfg)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"].astype(dtype), p["conv_b"].astype(dtype), conv_state)
    xs = xbc[..., :d_inner].reshape(b, s, nh, hd)
    b_in = xbc[..., d_inner : d_inner + st]                     # (B, S, st)
    c_in = xbc[..., d_inner + st :]                             # (B, S, st)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B, S, nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                      # (nh,)
    log_decay = dt * a[None, None, :]                                 # (B, S, nh)  <= 0

    if cache is None:
        y, last_state = _ssd_chunked(xs, b_in, c_in, dt, log_decay, nh, hd, st,
                                     chunk=cfg.ssd_chunk, unroll=cfg.unroll_scans)
        new_cache = None
    else:
        h0 = cache["ssm"]                                             # (B, nh, hd, st)
        decay = jnp.exp(log_decay[:, 0])                              # (B, nh)
        dbx = jnp.einsum("bn,bs,bnd->bnds", dt[:, 0], b_in[:, 0], xs[:, 0].astype(jnp.float32))
        h1 = h0 * decay[..., None, None] + dbx
        y = jnp.einsum("bs,bnds->bnd", c_in[:, 0].astype(jnp.float32), h1)[:, None]
        y = y.reshape(b, 1, nh, hd)
        new_cache = {"conv": new_conv, "ssm": h1, "pos": cache["pos"] + s}
        last_state = h1

    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(dtype)
    y = apply_norm(p["norm_gate"], y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype), cfg)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dtype))
    return out, new_cache


def _ssd_chunked(xs, b_in, c_in, dt, log_decay, nh, hd, st, chunk: int = SSD_CHUNK,
                 unroll: bool = False):
    """Chunked SSD: scan over chunks, quadratic (MXU) form within chunks.

    xs: (B,S,nh,hd); b_in/c_in: (B,S,st); dt/log_decay: (B,S,nh).
    Returns y (B,S,nh,hd) fp32 and final state (B,nh,hd,st).
    """
    b, s = xs.shape[0], xs.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
    nc = xs.shape[1] // chunk

    def per_chunk(h, inputs):
        xc, bc, cc, dtc, ldc = inputs            # (B,C,...) one chunk
        cum = jnp.cumsum(ldc, axis=1)            # (B,C,nh) inclusive
        # intra-chunk quadratic form: L[i,j] = exp(cum_i - cum_j) * dt_j, i>=j
        li = cum[:, :, None, :] - cum[:, None, :, :]          # (B,C,C,nh)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        lmat = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0) * dtc[:, None, :, :]
        cb = jnp.einsum("bis,bjs->bij", cc, bc).astype(jnp.float32)   # (B,C,C)
        y_intra = jnp.einsum("bij,bijn,bjnd->bind", cb, lmat, xc.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bis,bnds,bin->bind", cc.astype(jnp.float32), h, jnp.exp(cum))
        # state update
        seg = jnp.exp(cum[:, -1:, :] - cum)                   # decay from i to chunk end
        dbx = jnp.einsum("bin,bis,bind->bnds", dtc * seg, bc.astype(jnp.float32), xc.astype(jnp.float32))
        h_new = h * jnp.exp(cum[:, -1])[:, :, None, None] + dbx
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, nh, hd, st), jnp.float32)
    reshape = lambda t: t.reshape((b, nc, chunk) + t.shape[2:]).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(
        per_chunk, h0,
        (reshape(xs), reshape(b_in), reshape(c_in), reshape(dt), reshape(log_decay)),
        unroll=unroll,
    )
    y = ys.swapaxes(0, 1).reshape(b, nc * chunk, nh, hd)[:, :s]
    return y, h_last


def mamba2_cache_decl(cfg: ModelConfig, batch: int):
    d_inner, nh, hd, st = _dims(cfg)
    conv_dim = d_inner + 2 * st
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm.conv - 1, conv_dim), jnp.dtype(cfg.dtype)),
        "ssm": jax.ShapeDtypeStruct((batch, nh, hd, st), jnp.float32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
