"""Model assembly: super-block patterns, scanned layer stacks, caches.

Every assigned architecture is expressed as a repeating *super-block*
pattern (list of block kinds) scanned ``n_super`` times, plus an optional
unrolled tail — this keeps compiled HLO size O(pattern) instead of
O(n_layers) and uniformly handles heterogeneous stacks:

    dense           ["attn"]                        x n_layers
    dbrx            ["attn_moe"]                    x 40
    llama4-maverick ["attn", "attn_moe"]            x 24   (interleaved MoE)
    zamba2          ["mamba"]*5 + ["shared_attn"]   x 6  + ["mamba"]*2
    xlstm           ["mlstm", "slstm"]              x 12
    llama3.2-vision ["attn"]*4 + ["cross"]          x 20

zamba2's shared attention block reuses ONE parameter set at every
occurrence (closed over by the scan body — weight sharing is free under
scan). Modality frontends (EnCodec/ViT) are stubs per the brief:
``embed_frontend_stub`` architectures take precomputed frame embeddings.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    attention_block,
    attention_decl,
    embed_decl,
    embed_tokens,
    lm_head,
    mlp_block,
    mlp_decl,
    apply_norm,
)
from repro.parallel.sharding import ParamDecl, is_decl

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Patterns
# ---------------------------------------------------------------------------

def block_pattern(cfg: ModelConfig) -> Tuple[List[str], int, List[str]]:
    """Returns (pattern, n_super, tail)."""
    if cfg.family == "hybrid" and cfg.ssm.attn_every:
        per = cfg.ssm.attn_every
        pattern = ["mamba"] * (per - 1) + ["shared_attn" if cfg.ssm.shared_attn else "attn"]
        n_super = cfg.n_layers // per
        tail = ["mamba"] * (cfg.n_layers - n_super * per)
        return pattern, n_super, tail
    if cfg.family == "ssm" and cfg.slstm_every:
        per = cfg.slstm_every
        pattern = ["mlstm"] * (per - 1) + ["slstm"]
        n_super = cfg.n_layers // per
        tail = ["mlstm"] * (cfg.n_layers - n_super * per)
        return pattern, n_super, tail
    if cfg.family == "vlm" and cfg.cross_attn_every:
        per = cfg.cross_attn_every
        pattern = ["attn"] * (per - 1) + ["cross"]
        n_super = cfg.n_layers // per
        tail = ["attn"] * (cfg.n_layers - n_super * per)
        return pattern, n_super, tail
    if cfg.family == "moe":
        per = cfg.moe.every
        if per <= 1:
            return ["attn_moe"], cfg.n_layers, []
        pattern = ["attn"] * (per - 1) + ["attn_moe"]
        n_super = cfg.n_layers // per
        tail = ["attn"] * (cfg.n_layers - n_super * per)
        return pattern, n_super, tail
    return ["attn"], cfg.n_layers, []


def _block_decl(kind: str, cfg: ModelConfig):
    if kind == "attn":
        return {"attn": attention_decl(cfg), "mlp": mlp_decl(cfg)}
    if kind == "attn_moe":
        return {"attn": attention_decl(cfg), "moe": moe_mod.moe_decl(cfg)}
    if kind == "cross":
        return {"cross": attention_decl(cfg, cross=True), "mlp": mlp_decl(cfg)}
    if kind == "mamba":
        return ssm_mod.mamba2_decl(cfg)
    if kind == "mlstm":
        return xlstm_mod.mlstm_decl(cfg)
    if kind == "slstm":
        return xlstm_mod.slstm_decl(cfg)
    if kind == "shared_attn":
        return None  # parameters live once in params["shared_attn"]
    raise ValueError(kind)


def _stack_decl(decl, n: int):
    return jax.tree.map(
        lambda d: ParamDecl((n,) + d.shape, (None,) + d.axes, d.dtype, d.init, d.scale),
        decl,
        is_leaf=is_decl,
    )


def decl_model(cfg: ModelConfig):
    """Full declaration tree for one architecture."""
    pattern, n_super, tail = block_pattern(cfg)
    decl: Dict[str, Any] = {"embed": embed_decl(cfg)}
    decl["blocks"] = [
        _stack_decl(_block_decl(kind, cfg), n_super)
        for kind in pattern
        if _block_decl(kind, cfg) is not None
    ]
    # map from pattern index -> blocks list index (shared_attn has no stack)
    decl["tail"] = [_block_decl(kind, cfg) for kind in tail]
    if "shared_attn" in pattern:
        decl["shared_attn"] = {"attn": attention_decl(cfg), "mlp": mlp_decl(cfg)}
    return decl


def _pattern_param_slots(pattern: List[str]) -> List[Optional[int]]:
    """pattern position -> index into params['blocks'] (None for shared)."""
    slots, i = [], 0
    for kind in pattern:
        if kind == "shared_attn":
            slots.append(None)
        else:
            slots.append(i)
            i += 1
    return slots


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _attn_cache_decl(cfg: ModelConfig, batch: int, max_len: int, window: Optional[int]):
    k, hd = cfg.n_kv, cfg.hd()
    size = min(window, max_len) if window else max_len
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jax.ShapeDtypeStruct((batch, size, k, hd), dt),
        "v": jax.ShapeDtypeStruct((batch, size, k, hd), dt),
        "positions": jax.ShapeDtypeStruct((size,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _block_cache_decl(kind: str, cfg: ModelConfig, batch: int, max_len: int):
    if kind in ("attn", "attn_moe", "shared_attn"):
        return _attn_cache_decl(cfg, batch, max_len, cfg.window)
    if kind == "cross":
        k, hd = cfg.n_kv, cfg.hd()
        dt = jnp.dtype(cfg.dtype)
        return {
            "k": jax.ShapeDtypeStruct((batch, cfg.n_vis_tokens, k, hd), dt),
            "v": jax.ShapeDtypeStruct((batch, cfg.n_vis_tokens, k, hd), dt),
        }
    if kind == "mamba":
        return ssm_mod.mamba2_cache_decl(cfg, batch)
    if kind == "mlstm":
        d_inner, nh, hd = xlstm_mod._mdims(cfg)
        return {
            "c": jax.ShapeDtypeStruct((batch, nh, hd, hd), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32),
            "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    if kind == "slstm":
        nh = cfg.n_heads
        hd = cfg.d_model // nh
        shp = (batch, nh, hd)
        return {
            "c": jax.ShapeDtypeStruct(shp, jnp.float32),
            "n": jax.ShapeDtypeStruct(shp, jnp.float32),
            "h": jax.ShapeDtypeStruct(shp, jnp.float32),
            "m": jax.ShapeDtypeStruct(shp, jnp.float32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(kind)


def cache_decl(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract cache tree (ShapeDtypeStruct; no allocation)."""
    pattern, n_super, tail = block_pattern(cfg)
    stack = lambda tree, n: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree
    )
    return {
        "pattern": [
            stack(_block_cache_decl(kind, cfg, batch, max_len), n_super) for kind in pattern
        ],
        "tail": [_block_cache_decl(kind, cfg, batch, max_len) for kind in tail],
    }


def init_cache(params, cfg: ModelConfig, batch: int, max_len: int, vis_embeds=None):
    """Concrete zero-initialized cache. Cross-attention K/V are precomputed
    from the (stub) vision embeddings once, here."""
    decl = cache_decl(cfg, batch, max_len)

    def zeros(s):
        if s.shape[-1:] == (0,):
            return jnp.zeros(s.shape, s.dtype)
        z = jnp.zeros(s.shape, s.dtype)
        return z

    cache = jax.tree.map(zeros, decl)
    # positions arrays start at -1 (invalid)
    cache = _map_named(cache, "positions", lambda z: z - 1)
    pattern, n_super, tail = block_pattern(cfg)
    slots = _pattern_param_slots(pattern)
    if vis_embeds is not None:
        for pi, kind in enumerate(pattern):
            if kind != "cross":
                continue
            pstack = params["blocks"][slots[pi]]

            def fill(layer_p, _):
                from repro.models.layers import apply_norm as an

                src = an(layer_p["cross"]["norm_kv"], vis_embeds, cfg)
                kk = jnp.einsum("bsd,dhk->bshk", src, layer_p["cross"]["wk"].astype(vis_embeds.dtype))
                vv = jnp.einsum("bsd,dhk->bshk", src, layer_p["cross"]["wv"].astype(vis_embeds.dtype))
                return {"k": kk, "v": vv}

            filled = jax.lax.map(lambda lp: fill(lp, None), pstack)
            cache["pattern"][pi] = {"k": filled["k"], "v": filled["v"]}
    return cache


def _map_named(tree, name, fn):
    def walk(t):
        if isinstance(t, dict):
            return {k: (fn(v) if k == name else walk(v)) for k, v in t.items()}
        if isinstance(t, list):
            return [walk(v) for v in t]
        return t

    return walk(tree)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def apply_block(
    kind: str,
    p,
    x: Array,
    cfg: ModelConfig,
    *,
    positions: Array,
    cache=None,
    vis_embeds=None,
    shared_params=None,
):
    """Returns (x_out, new_cache, aux)."""
    aux = _zero_aux()
    if kind in ("attn", "attn_moe", "shared_attn"):
        pp = shared_params if kind == "shared_attn" else p
        dx, new_cache = attention_block(
            pp["attn"], x, cfg, positions=positions, cache=cache, window=cfg.window
        )
        x = x + dx
        if kind == "attn_moe":
            dx, aux = moe_mod.moe_block(p["moe"], x, cfg)
            x = x + dx
        else:
            x = x + mlp_block(pp["mlp"], x, cfg)
        return x, new_cache, aux
    if kind == "cross":
        dx, new_cache = attention_block(
            p["cross"], x, cfg, positions=positions, cross=True,
            kv_src=vis_embeds if cache is None else None, cache=cache,
        )
        x = x + dx
        x = x + mlp_block(p["mlp"], x, cfg)
        return x, new_cache, aux
    if kind == "mamba":
        dx, new_cache = ssm_mod.mamba2_block(p, x, cfg, cache=cache)
        return x + dx, new_cache, aux
    if kind == "mlstm":
        dx, new_cache = xlstm_mod.mlstm_block(p, x, cfg, cache=cache)
        return x + dx, new_cache, aux
    if kind == "slstm":
        dx, new_cache = xlstm_mod.slstm_block(p, x, cfg, cache=cache)
        return x + dx, new_cache, aux
    raise ValueError(kind)


def _zero_aux():
    z = jnp.zeros((), jnp.float32)
    return moe_mod.MoEAux(z, z, z)


def _add_aux(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def forward(
    params,
    cfg: ModelConfig,
    *,
    tokens: Optional[Array] = None,       # (B, S) int32
    embeds: Optional[Array] = None,       # (B, S, d) for frontend-stub archs
    positions: Optional[Array] = None,    # (S,)
    cache=None,
    vis_embeds: Optional[Array] = None,   # (B, n_vis, d)
):
    """Returns (logits, new_cache, aux)."""
    pattern, n_super, tail = block_pattern(cfg)
    slots = _pattern_param_slots(pattern)
    dtype = jnp.dtype(cfg.dtype)

    if embeds is None:
        x = embed_tokens(params["embed"], tokens, cfg).astype(dtype)
    else:
        x = embeds.astype(dtype)
    s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    if vis_embeds is not None:
        vis_embeds = vis_embeds.astype(dtype)

    shared = params.get("shared_attn")
    has_cache = cache is not None

    def superblock(x, block_params, block_cache):
        aux = _zero_aux()
        new_caches = []
        for pi, kind in enumerate(pattern):
            p = block_params[slots[pi]] if slots[pi] is not None else None
            c = block_cache[pi] if has_cache else None
            x, nc, a = apply_block(
                kind, p, x, cfg,
                positions=positions, cache=c, vis_embeds=vis_embeds, shared_params=shared,
            )
            aux = _add_aux(aux, a)
            new_caches.append(nc)
        return x, new_caches, aux

    if cfg.remat:
        superblock = jax.checkpoint(superblock)

    if has_cache:
        def scan_body(carry, xs):
            x, aux = carry
            block_params, block_cache = xs
            x, new_caches, a = superblock(x, block_params, block_cache)
            return (x, _add_aux(aux, a)), new_caches

        (x, aux), new_pattern_cache = jax.lax.scan(
            scan_body, (x, _zero_aux()), (params["blocks"], cache["pattern"]),
            unroll=cfg.unroll_scans,
        )
    else:
        def scan_body(carry, block_params):
            x, aux = carry
            x, _, a = superblock(x, block_params, None)
            return (x, _add_aux(aux, a)), None

        (x, aux), new_pattern_cache = jax.lax.scan(
            scan_body, (x, _zero_aux()), params["blocks"], unroll=cfg.unroll_scans
        )

    new_tail = []
    for ti, kind in enumerate(tail):
        c = cache["tail"][ti] if has_cache else None
        x, nc, a = apply_block(
            kind, params["tail"][ti], x, cfg,
            positions=positions, cache=c, vis_embeds=vis_embeds, shared_params=shared,
        )
        aux = _add_aux(aux, a)
        new_tail.append(nc)

    logits = lm_head(params["embed"], x, cfg)
    new_cache = {"pattern": new_pattern_cache, "tail": new_tail} if has_cache else None
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# Loss (chunked over sequence so (B, S, V) logits are never materialized)
# ---------------------------------------------------------------------------

def loss_fn(
    params,
    cfg: ModelConfig,
    batch: Dict[str, Array],
):
    """Causal LM loss. batch: tokens/embeds + labels (+ vis_embeds)."""
    pattern, n_super, tail = block_pattern(cfg)
    dtype = jnp.dtype(cfg.dtype)
    labels = batch["labels"]

    # run the trunk (without the head), then chunked softmax-xent
    trunk_out, _, aux = _forward_trunk(params, cfg, batch)
    b, s, d = trunk_out.shape
    chunk = min(cfg.loss_chunk, s)
    pad = (-s) % chunk
    if pad:
        trunk_out = jnp.pad(trunk_out, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = trunk_out.shape[1] // chunk
    h_c = trunk_out.reshape(b, nc, chunk, d).swapaxes(0, 1)
    l_c = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        h, lab = xs
        if cfg.loss_bf16_logits:
            # bf16 logits; the logsumexp accumulates in fp32 WITHOUT ever
            # materializing an fp32 (B, chunk, V) tensor (§Perf iter 6: the
            # fp32 logits were the largest buffers of every train cell)
            logits = lm_head(params["embed"], h, cfg)
            m = jnp.max(logits, axis=-1)
            s = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1, dtype=jnp.float32)
            lse = m.astype(jnp.float32) + jnp.log(s)
        else:
            logits = lm_head(params["embed"], h, cfg).astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1
        )[..., 0].astype(jnp.float32)
        valid = lab >= 0
        nll = jnp.where(valid, lse - picked, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    body = jax.checkpoint(chunk_loss) if cfg.remat else chunk_loss
    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (h_c, l_c),
        unroll=cfg.unroll_scans,
    )
    loss = total / jnp.maximum(count, 1)
    if cfg.moe.num_experts:
        loss = loss + cfg.moe.aux_loss * aux.load_balance + cfg.moe.router_z_loss * aux.router_z
    metrics = {
        "loss": loss,
        "aux_load_balance": aux.load_balance,
        "aux_router_z": aux.router_z,
        "moe_drop_fraction": aux.drop_fraction,
        "tokens": count,
    }
    return loss, metrics


def _forward_trunk(params, cfg: ModelConfig, batch):
    """forward() minus the LM head (returns final hidden states)."""
    pattern, n_super, tail = block_pattern(cfg)
    slots = _pattern_param_slots(pattern)
    dtype = jnp.dtype(cfg.dtype)
    if cfg.embed_frontend_stub:
        x = batch["embeds"].astype(dtype)
    else:
        x = embed_tokens(params["embed"], batch["tokens"], cfg).astype(dtype)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    vis_embeds = batch.get("vis_embeds")
    if vis_embeds is not None:
        vis_embeds = vis_embeds.astype(dtype)
    shared = params.get("shared_attn")

    def superblock(x, block_params):
        aux = _zero_aux()
        for pi, kind in enumerate(pattern):
            p = block_params[slots[pi]] if slots[pi] is not None else None
            x, _, a = apply_block(
                kind, p, x, cfg, positions=positions, vis_embeds=vis_embeds,
                shared_params=shared,
            )
            aux = _add_aux(aux, a)
        return x, aux

    if cfg.remat:
        superblock = jax.checkpoint(superblock)

    def scan_body(carry, block_params):
        x, aux = carry
        x, a = superblock(x, block_params)
        return (x, _add_aux(aux, a)), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, _zero_aux()), params["blocks"],
                               unroll=cfg.unroll_scans)
    for ti, kind in enumerate(tail):
        x, _, a = apply_block(
            kind, params["tail"][ti], x, cfg, positions=positions,
            vis_embeds=vis_embeds, shared_params=shared,
        )
        aux = _add_aux(aux, a)
    return x, None, aux


def decode_step(params, cfg: ModelConfig, cache, token_or_embed, position):
    """One serving step: (B, 1) token (or (B, 1, d) embed) + cache -> logits.

    ``position``: scalar int32 absolute position of the new token.
    """
    positions = position[None] if position.ndim == 0 else position
    if cfg.embed_frontend_stub:
        logits, new_cache, _ = forward(
            params, cfg, embeds=token_or_embed, positions=positions, cache=cache
        )
    else:
        logits, new_cache, _ = forward(
            params, cfg, tokens=token_or_embed, positions=positions, cache=cache
        )
    return logits, new_cache
