"""Core neural layers, pure functional JAX.

Attention is implemented as a *triangular block scan*: a flash-style
two-level chunking where, for causal masks, only the ~T²/2 visible
(q-chunk, kv-chunk) block pairs are scheduled (statically), so compiled HLO
FLOPs match useful work — this matters because the roofline analysis reads
``compiled.cost_analysis()`` and a rectangular mask-based implementation
would inflate the compute term ~2x at long sequence length.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.parallel.sharding import ParamDecl, constrain, tp_size

Array = jnp.ndarray

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_decl(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": ParamDecl((d,), ("embed",), init="ones"),
            "bias": ParamDecl((d,), ("embed",), init="zeros"),
        }
    return {"scale": ParamDecl((d,), ("embed",), init="ones")}


def apply_norm(p, x: Array, cfg: ModelConfig) -> Array:
    """Stats in fp32, elementwise math in the activation dtype.

    Computing the whole normalization on an fp32 COPY of x materializes
    activation-sized fp32 tensors per layer (measured: among the largest
    buffers in the dry-run HLO); keeping only the (..., 1) statistics in
    fp32 is the standard mixed-precision formulation.
    """
    if cfg.norm == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
        var = jnp.mean(
            jnp.square(x.astype(jnp.float32) - mu), axis=-1, keepdims=True
        )
        inv = jax.lax.rsqrt(var + 1e-5).astype(x.dtype)
        return (x - mu.astype(x.dtype)) * inv * p["scale"].astype(x.dtype) \
            + p["bias"].astype(x.dtype)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + 1e-6).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (with partial-rotary support for stablelm-2)
# ---------------------------------------------------------------------------

def apply_rope(x: Array, positions: Array, theta: float, pct: float = 1.0) -> Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    rot = int(hd * pct) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = theta ** (-jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                                # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention: declarations
# ---------------------------------------------------------------------------

def attention_decl(cfg: ModelConfig, cross: bool = False):
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd()
    decl = {
        "wq": ParamDecl((d, h, hd), ("embed", "heads", None)),
        "wk": ParamDecl((d, k, hd), ("embed", "kv_heads", None)),
        "wv": ParamDecl((d, k, hd), ("embed", "kv_heads", None)),
        "wo": ParamDecl((h, hd, d), ("heads", None, "embed_fsdp")),
        "norm": norm_decl(cfg),
    }
    if cross:
        decl["norm_kv"] = norm_decl(cfg)
    return decl


# ---------------------------------------------------------------------------
# Flash-style triangular block-scan attention
# ---------------------------------------------------------------------------

def _block_pairs(n_q: int, n_kv: int, causal: bool, window_chunks: Optional[int]):
    """Static schedule of visible (q_chunk, kv_chunk) pairs."""
    pairs = []
    for qi in range(n_q):
        for kj in range(n_kv):
            if causal and kj > qi:
                continue
            if window_chunks is not None and kj < qi - window_chunks:
                continue
            pairs.append((qi, kj))
    return np.array(pairs, dtype=np.int32)


def multihead_attention(
    q: Array,                    # (B, S, H, hd)
    k: Array,                    # (B, T, K, hd)
    v: Array,                    # (B, T, K, hd)
    *,
    causal: bool,
    chunk: int = 1024,
    window: Optional[int] = None,
    q_offset: int = 0,
    unroll: bool = False,
    probs_bf16: bool = False,
    pad_heads: bool = False,
) -> Array:
    """Chunked online-softmax attention with a triangular block schedule.

    GQA: H must be a multiple of K. ``window`` enables sliding-window
    masking (h2o-danube). Returns (B, S, H, hd).
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    n_kv_heads = k.shape[2]
    g = h // n_kv_heads
    chunk = min(chunk, s, t)

    # ---- explicit TP layout (Megatron-style; see parallel.sharding.constrain)
    # Prefer sharding the kv-head dim; if the GQA kv count doesn't divide TP
    # but the q-head count does, expand kv -> q heads (g=1) so heads shard
    # cleanly; otherwise fall back to head_dim sharding (psum contractions).
    # Without these anchors GSPMD sometimes replicates the batch dim of the
    # 5-D score einsums (observed as "involuntary full rematerialization").
    tp = tp_size()
    h_orig = h
    if tp > 1:
        if n_kv_heads % tp != 0 and h % tp == 0 and g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
            n_kv_heads, g = h, 1
        if pad_heads and n_kv_heads % tp != 0:
            # §Perf iter 7: zero-pad heads to the next TP multiple. Padded
            # heads attend uniformly over valid kv (scores 0), and their
            # outputs are sliced away before the output projection — 1.33x
            # head compute instead of TP-x replicated attention memory.
            if g > 1:
                k = jnp.repeat(k, g, axis=2)
                v = jnp.repeat(v, g, axis=2)
                n_kv_heads, g = h, 1
            hp = -(-h // tp) * tp
            padh = ((0, 0), (0, 0), (0, hp - h), (0, 0))
            q, k, v = jnp.pad(q, padh), jnp.pad(k, padh), jnp.pad(v, padh)
            h = n_kv_heads = hp
        head_entry = "model" if n_kv_heads % tp == 0 else None
        hd_entry = None if head_entry else "model"
        q = constrain(q, "dp", None, head_entry if g == 1 else None, hd_entry)
        k = constrain(k, "dp", None, head_entry, hd_entry)
        v = constrain(v, "dp", None, head_entry, hd_entry)

    s_pad = (-s) % chunk
    t_pad = (-t) % chunk
    qp = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    n_q, n_kv = qp.shape[1] // chunk, kp.shape[1] // chunk

    window_chunks = None
    if window is not None:
        window_chunks = (window + chunk - 1) // chunk + 1
    pairs = _block_pairs(n_q, n_kv, causal, window_chunks)

    qp = qp.reshape(b, n_q, chunk, n_kv_heads, g, hd)
    kp = kp.reshape(b, n_kv, chunk, n_kv_heads, hd)
    vp = vp.reshape(b, n_kv, chunk, n_kv_heads, hd)
    scale = 1.0 / np.sqrt(hd)

    acc0 = jnp.zeros((b, n_q, chunk, n_kv_heads, g, hd), jnp.float32)
    m0 = jnp.full((b, n_q, chunk, n_kv_heads, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_q, chunk, n_kv_heads, g), jnp.float32)
    if tp > 1:
        acc0 = constrain(acc0, "dp", None, None, head_entry, None, None)
        m0 = constrain(m0, "dp", None, None, head_entry, None)
        l0 = constrain(l0, "dp", None, None, head_entry, None)

    q_pos_all = q_offset + jnp.arange(n_q * chunk).reshape(n_q, chunk)
    k_pos_all = jnp.arange(n_kv * chunk).reshape(n_kv, chunk)

    def step(carry, pair):
        acc, m, l = carry
        qi, kj = pair[0], pair[1]
        qc = jax.lax.dynamic_index_in_dim(qp, qi, 1, keepdims=False)    # (B,C,K,G,hd)
        kc = jax.lax.dynamic_index_in_dim(kp, kj, 1, keepdims=False)    # (B,C,K,hd)
        vc = jax.lax.dynamic_index_in_dim(vp, kj, 1, keepdims=False)
        qpos = jax.lax.dynamic_index_in_dim(q_pos_all, qi, 0, keepdims=False)  # (C,)
        kpos = jax.lax.dynamic_index_in_dim(k_pos_all, kj, 0, keepdims=False)

        scores = jnp.einsum("bikgd,bjkd->bkgij", qc, kc).astype(jnp.float32) * scale
        mask = jnp.ones((chunk, chunk), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        mask &= (kpos < t)[None, :]                                    # kv padding
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)

        mc = jnp.max(scores, axis=-1)                                   # (B,K,G,C)
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 1, keepdims=False).transpose(0, 2, 3, 1)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 1, keepdims=False).transpose(0, 2, 3, 1)
        acc_old = jax.lax.dynamic_index_in_dim(acc, qi, 1, keepdims=False).transpose(0, 2, 3, 1, 4)

        m_new = jnp.maximum(m_old, mc)
        p = jnp.exp(scores - m_new[..., None])                          # (B,K,G,C,C)
        corr = jnp.exp(m_old - m_new)
        l_new = l_old * corr + p.sum(-1)
        if probs_bf16:
            pv = jnp.einsum("bkgij,bjkd->bkgid", p.astype(jnp.bfloat16), vc)
            pv = pv.astype(jnp.float32)
        else:
            pv = jnp.einsum("bkgij,bjkd->bkgid", p, vc.astype(jnp.float32))
        acc_new = acc_old * corr[..., None] + pv

        acc = jax.lax.dynamic_update_index_in_dim(
            acc, acc_new.transpose(0, 3, 1, 2, 4), qi, 1
        )
        m = jax.lax.dynamic_update_index_in_dim(m, m_new.transpose(0, 3, 1, 2), qi, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new.transpose(0, 3, 1, 2), qi, 1)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), jnp.asarray(pairs), unroll=unroll)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(b, n_q * chunk, n_kv_heads * g, hd)[:, :s, :h_orig]
    return out.astype(q.dtype)


def decode_attention(
    q: Array,                    # (B, 1, H, hd)
    k_cache: Array,              # (B, T, K, hd)  (already roped)
    v_cache: Array,              # (B, T, K, hd)
    kv_positions: Array,         # (T,) or (B, T) absolute positions, -1 = invalid
    q_position: Array,           # scalar int32 — position of the new token
    *,
    window: Optional[int] = None,
) -> Array:
    """Single-token attention over a (ring-buffered) cache."""
    b, _, h, hd = q.shape
    n_kv_heads = k_cache.shape[2]
    g = h // n_kv_heads
    qg = q.reshape(b, 1, n_kv_heads, g, hd)
    scores = jnp.einsum("bikgd,bjkd->bkgj", qg, k_cache).astype(jnp.float32)
    scores /= np.sqrt(hd)
    if kv_positions.ndim == 1:
        kv_positions = kv_positions[None, :]
    valid = (kv_positions >= 0) & (kv_positions <= q_position)
    if window is not None:
        valid &= q_position - kv_positions < window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def attention_block(
    p,
    x: Array,                    # (B, S, d)
    cfg: ModelConfig,
    *,
    positions: Array,            # (S,) absolute positions of x
    kv_src: Optional[Array] = None,   # cross-attention source (B, Skv, d)
    cache: Optional[dict] = None,     # decode cache for this layer
    window: Optional[int] = None,
    cross: bool = False,
) -> Tuple[Array, Optional[dict]]:
    """Pre-norm attention block: returns (residual delta, updated cache).

    ``cross=True`` attends to ``kv_src`` (or, during decode, to the
    precomputed K/V held in ``cache``) with no causal mask.
    """
    dtype = x.dtype
    xn = apply_norm(p["norm"], x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"].astype(dtype))
    is_cross = cross
    if is_cross and cache is not None:
        k = v = None                      # K/V precomputed in the cache
    else:
        src = apply_norm(p["norm_kv"], kv_src, cfg) if is_cross else xn
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(dtype))
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(dtype))

    if not is_cross:
        q = apply_rope(q, positions[None, :], cfg.rope_theta, cfg.rope_pct)
        kv_pos = positions if cache is None else positions  # self-attn positions
        k = apply_rope(k, kv_pos[None, :], cfg.rope_theta, cfg.rope_pct)

    if cache is not None and not is_cross:
        # decode: append to (ring) cache and attend over it
        slot = cache["pos"] % cache["k"].shape[1] if window is not None else cache["pos"]
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
        kv_positions = jax.lax.dynamic_update_slice_in_dim(
            cache["positions"], positions.astype(jnp.int32), slot, 0
        )
        out = decode_attention(
            q, k_cache, v_cache, kv_positions, positions[0], window=window
        )
        new_cache = {
            "k": k_cache, "v": v_cache, "positions": kv_positions,
            "pos": cache["pos"] + x.shape[1],
        }
    elif cache is not None and is_cross:
        out = multihead_attention(q, cache["k"], cache["v"], causal=False,
                                  chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
                                  probs_bf16=cfg.attn_probs_bf16,
                                  pad_heads=cfg.pad_attn_heads)
        new_cache = cache
    else:
        out = multihead_attention(
            q, k, v, causal=not is_cross, chunk=cfg.attn_chunk, window=window,
            q_offset=0, unroll=cfg.unroll_scans, probs_bf16=cfg.attn_probs_bf16,
            pad_heads=cfg.pad_attn_heads,
        )
        new_cache = None
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_decl(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm": norm_decl(cfg),
        "w_gate": ParamDecl((d, f), ("embed", "ff")),
        "w_up": ParamDecl((d, f), ("embed", "ff")),
        "w_down": ParamDecl((f, d), ("ff", "embed_fsdp")),
    }


def mlp_block(p, x: Array, cfg: ModelConfig) -> Array:
    dtype = x.dtype
    xn = apply_norm(p["norm"], x, cfg)
    gate = jnp.einsum("bsd,df->bsf", xn, p["w_gate"].astype(dtype))
    up = jnp.einsum("bsd,df->bsf", xn, p["w_up"].astype(dtype))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    return jnp.einsum("bsf,fd->bsd", act, p["w_down"].astype(dtype))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_decl(cfg: ModelConfig):
    decl = {}
    vp = cfg.padded_vocab()
    if not cfg.embed_frontend_stub:
        decl["tok"] = ParamDecl((vp, cfg.d_model), ("vocab", "embed"), scale=0.02)
    if not cfg.tie_embeddings:
        decl["head"] = ParamDecl((cfg.d_model, vp), ("embed", "vocab"))
    decl["norm_f"] = norm_decl(cfg)
    return decl


def embed_tokens(p, tokens: Array, cfg: ModelConfig) -> Array:
    emb = p["tok"].astype(_dt(cfg))
    return emb[tokens]


def lm_head(p, x: Array, cfg: ModelConfig) -> Array:
    """Final norm + projection to vocab. x: (B, S, d) -> (B, S, V_padded)."""
    xn = apply_norm(p["norm_f"], x, cfg)
    w = (p["tok"].T if cfg.tie_embeddings else p["head"]).astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", xn, w)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    vp = cfg.padded_vocab()
    if vp != cfg.vocab:
        # mask padded vocab columns so they never win softmax/argmax
        col = jax.lax.broadcasted_iota(jnp.int32, (vp,), 0)
        logits = jnp.where(col[None, None, :] < cfg.vocab, logits,
                           jnp.asarray(-1e9, logits.dtype))
    return logits


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)
