"""Mixture-of-Experts with multisplit token dispatch (the paper's technique
as a first-class framework feature — DESIGN.md §4).

Routing a token to an expert IS a multisplit: keys = token indices, bucket
identifier = router argmax, and the dispatch permutation is exactly paper
eq. (2). Three dispatch modes:

* ``dense``      — no permutation at all: every expert runs on every token,
                   combined with router weights. The "compute instead of
                   move" strawman (paper §3.2 scan-based-split analogue).
                   O(n·E) FLOPs; only viable for tiny configs/tests.
* ``sort``       — ranks from a stable argsort of expert ids (the paper's
                   RB-sort baseline: sorting log n-bit payloads when log E
                   bits suffice).
* ``multisplit`` — ranks from the {prescan, scan, postscan} multisplit
                   machinery: tile histograms + ONE exclusive scan +
                   tile-local offsets. No sort network anywhere.

All modes produce identical outputs (up to dropped-token sets, which are
identical between sort and multisplit since both are stable).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import get_abstract_mesh
from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm, mlp_block, mlp_decl, norm_decl
from repro.parallel.sharding import ParamDecl, constrain as _constrain

Array = jnp.ndarray

DISPATCH_TILE = 2048


class MoEAux(NamedTuple):
    load_balance: Array
    router_z: Array
    drop_fraction: Array


def moe_decl(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    decl = {
        "norm": norm_decl(cfg),
        "router": ParamDecl((d, e), ("embed", "experts"), scale=0.02),
        "w_gate": ParamDecl((e, d, f), ("experts", "embed", "ff")),
        "w_up": ParamDecl((e, d, f), ("experts", "embed", "ff")),
        "w_down": ParamDecl((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.moe.shared_expert:
        decl["shared"] = mlp_decl(cfg)
    return decl


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    cap = int(math.ceil(n_tokens * k / e * cfg.moe.capacity_factor))
    return max(8, -(-cap // 8) * 8)


def _router(p, xn: Array, cfg: ModelConfig):
    """xn: (n, d) -> (gates (n, k), experts (n, k), aux parts)."""
    logits = jnp.einsum("nd,de->ne", xn, p["router"].astype(xn.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + z-loss. The top-1 dispatch fraction ce
    # is a counts_only pipeline (the §7.3 histogram applied to routing) —
    # exact integer counts, gradient-free like the one-hot mean it replaces.
    e = cfg.moe.num_experts
    me = probs.mean(0)
    counts, _ = expert_load_stats(experts[:, 0], e)
    ce = counts.astype(jnp.float32) / experts.shape[0]
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return gates, experts, lb, z


def expert_load_stats(
    expert_ids: Array,
    num_experts: int,
    capacity: Optional[int] = None,
    segment_starts: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Per-expert token load via ``repro.ops`` ``counts_only`` calls
    (DESIGN.md §10/§11): {prescan, tree-reduce}, no scan and no permutation
    — the §7.3 histogram machinery pointed at the router output.  The
    :class:`~repro.ops.IdentitySpec` is hashable, so every MoE layer and
    every step shares ONE trace of the dispatch op.

    Returns ``(counts, overflow_fraction)``: ``counts`` is the (e,) — or
    (s, e) with ``segment_starts`` — expert histogram, and
    ``overflow_fraction`` the fraction of tokens beyond ``capacity`` per
    expert (0.0 when ``capacity`` is None), i.e. the drop rate a
    capacity-bounded dispatch of these assignments would incur.
    """
    from repro import ops

    n = expert_ids.shape[0]
    tile = min(DISPATCH_TILE, max(int(n), 1))
    spec = ops.identity_buckets(num_experts)
    if segment_starts is None:
        counts = ops.multisplit(
            expert_ids, spec, method="dms", tile=tile, mode="counts_only"
        ).bucket_counts
    else:
        counts = ops.segmented_multisplit(
            expert_ids, spec, segment_starts, method="dms", tile=tile,
            mode="counts_only",
        ).bucket_counts
    if capacity is None or n == 0:
        return counts, jnp.zeros((), jnp.float32)
    dropped = jnp.maximum(counts - capacity, 0).sum()
    return counts, dropped.astype(jnp.float32) / n


def _ranks_multisplit(
    expert_ids: Array, num_experts: int, segment_starts: Optional[Array] = None
) -> Tuple[Array, Array]:
    """Stable rank of each virtual token within its expert + expert counts.

    THE paper technique, executed as ONE ``positions_only``
    ``repro.ops.multisplit`` call (DESIGN.md §10: prescan, one global scan,
    postscan positions — the reordered-keys stage never runs, and nothing
    but the eq. (2) permutation is materialized). With ``segment_starts``
    the call is a single SEGMENTED multisplit (DESIGN.md §9): ranks restart
    per segment and ``counts`` is the (s, e) per-segment expert histogram —
    per-request routing in one launch instead of a host loop over requests.
    """
    from repro import ops

    n = expert_ids.shape[0]
    tile = min(DISPATCH_TILE, max(int(n), 1))
    if segment_starts is None:
        res = ops.multisplit(
            expert_ids, ops.identity_buckets(num_experts), method="dms",
            tile=tile, mode="positions_only",
        )
        ranks = res.permutation - res.bucket_starts[expert_ids]
        return ranks.astype(jnp.int32), res.bucket_counts
    ranks, counts, _ = _segmented_ranks(
        expert_ids, jnp.asarray(segment_starts, jnp.int32), num_experts, tile
    )
    return ranks, counts


def _segmented_ranks(
    expert_ids: Array, seg: Array, num_experts: int, tile: int,
    backend: str = "vmap",
) -> Tuple[Array, Array, Array]:
    """One segmented ``positions_only`` ``repro.ops`` call -> (ranks, (s, e)
    counts, seg_ids); the derived per-token segment id is returned so
    hot-path callers don't recompute the searchsorted."""
    from repro import ops
    from repro.core.pipeline import segment_ids_from_starts

    n = expert_ids.shape[0]
    res = ops.segmented_multisplit(
        expert_ids, ops.identity_buckets(num_experts), seg, method="dms",
        tile=tile, mode="positions_only", backend=backend,
    )
    seg_ids = segment_ids_from_starts(seg, n)
    ranks = res.permutation - res.bucket_starts[seg_ids, expert_ids]
    return ranks.astype(jnp.int32), res.bucket_counts, seg_ids


def route_tokens_segmented(
    expert_ids: Array,
    segment_starts: Array,
    num_experts: int,
    capacity: int,
    *,
    backend: str = "vmap",
) -> Tuple[Array, Array, Array]:
    """Per-request token routing: ONE segmented multisplit call assigns every
    virtual token a slot in its request's (expert, capacity) block.

    ``expert_ids`` is the flat concatenation of per-request expert
    assignments; ``segment_starts`` the (s,) request boundaries. Returns
    ``(slot, keep, counts)``: ``slot[i] = (seg_i·E + expert_i)·capacity +
    rank_i`` for kept tokens (an index into a (s·E·capacity,) dispatch
    buffer; dropped tokens point one past the end), the per-token keep mask
    (rank < capacity, stable within each (request, expert) pair), and the
    (s, E) per-request expert load. This is the building block for
    capacity-per-request batched serving — :class:`repro.serving.ServerLoop`
    calls it once per step (ROADMAP "heavy traffic"). ``s == 0`` (a
    zero-request step) returns empty slots and (0, E) counts; zero-length
    segments (a user with no tokens this step) get all-zero count rows.
    ``backend`` selects the plan backend of the one segmented launch.
    """
    n = expert_ids.shape[0]
    seg = jnp.asarray(segment_starts, jnp.int32)
    s = int(seg.shape[0])
    tile = min(DISPATCH_TILE, max(int(n), 1))
    ranks, counts, seg_ids = _segmented_ranks(
        expert_ids, seg, num_experts, tile, backend=backend
    )
    keep = ranks < capacity
    slot = jnp.where(
        keep,
        (seg_ids * num_experts + expert_ids) * capacity + ranks,
        s * num_experts * capacity,
    )
    return slot.astype(jnp.int32), keep, counts


def _ranks_sort(expert_ids: Array, num_experts: int) -> Tuple[Array, Array]:
    """Baseline: ranks via stable argsort (RB-sort analogue)."""
    n = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    one_hot = jax.nn.one_hot(expert_ids, num_experts, dtype=jnp.int32)
    counts = one_hot.sum(0)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(n, dtype=jnp.int32)
    ranks_sorted = pos_sorted - starts[expert_ids[order]]
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(ranks_sorted)
    return ranks, counts.astype(jnp.int32)


def _expert_ffn(p, x: Array, dtype) -> Array:
    """x: (E, C, d) -> (E, C, d), SwiGLU per expert (batched over E)."""
    gate = jnp.einsum("ecd,edf->ecf", x, p["w_gate"].astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", x, p["w_up"].astype(dtype))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    return jnp.einsum("ecf,efd->ecd", act, p["w_down"].astype(dtype))


def _dispatch_multisplit_ep(p, xn, gates, experts, cfg: ModelConfig, cap: int, dtype):
    """Manual expert-parallel dispatch under shard_map (dispatch="multisplit_ep").

    The hillclimbed path (EXPERIMENTS.md §Perf): GSPMD's automatic plan for
    the dispatch gathers materializes full-size fp32 partial outputs on every
    model rank and all-reduces them. Here the paper's {local, global, local}
    model is mapped by hand:

      * local:  each (data, model) device multisplits ITS token shard by
                expert id restricted to ITS model-rank's expert group
                (prescan/scan/postscan on a (n_loc,) shard — pure local math);
      * global: the ONLY collective is one bf16 psum of the combined output
                over the model axis (tokens are replicated across "model",
                experts are sharded across it — no token movement at all);
      * local:  capacity-bounded gather + grouped FFN + weighted combine.

    Capacity is per-data-shard (cap / DP), the standard local-capacity MoE
    semantics. Output matches the GSPMD path exactly when nothing drops.
    """
    mesh = get_abstract_mesh()
    names = getattr(mesh, "axis_names", ()) or ()
    if "model" not in names:
        return None  # no mesh context (smoke tests): caller falls back
    from jax.sharding import PartitionSpec as P

    dp_axes = tuple(a for a in names if a in ("pod", "data"))
    dp_entry = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    n, d = xn.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    tp = mesh.shape["model"]
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    if e % tp != 0 or n % n_dp != 0:
        return None
    e_loc = e // tp
    cap_loc = max(8, ((-(-cap // n_dp) + 7) // 8) * 8)

    wg_spec = P("model", None, None)
    fsdp = False  # expert weights dp-gathered inside if their decl is fsdp-sharded

    def body(xn_l, gates_l, experts_l, wg_l, wu_l, wd_l):
        j = jax.lax.axis_index("model")
        n_loc = xn_l.shape[0]
        lo = j * e_loc
        flat_e = experts_l.reshape(-1)                        # (n_loc·k,)
        in_group = (flat_e >= lo) & (flat_e < lo + e_loc)
        sub_ids = jnp.where(in_group, flat_e - lo, e_loc)     # bucket e_loc = foreign
        ranks, _ = _ranks_multisplit(sub_ids, e_loc + 1)      # paper machinery
        keep = in_group & (ranks < cap_loc)
        slot = jnp.where(keep, sub_ids * cap_loc + ranks, e_loc * cap_loc)
        token_idx = jnp.arange(n_loc * k, dtype=jnp.int32) // k
        token_for_slot = jnp.full((e_loc * cap_loc,), n_loc, jnp.int32).at[slot].set(
            token_idx, mode="drop"
        )
        valid = (token_for_slot < n_loc)[:, None].astype(dtype)
        expert_in = jnp.take(
            xn_l, jnp.minimum(token_for_slot, n_loc - 1), axis=0, mode="clip"
        ) * valid
        expert_out = _expert_ffn(
            {"w_gate": wg_l, "w_up": wu_l, "w_down": wd_l},
            expert_in.reshape(e_loc, cap_loc, d), dtype,
        ).reshape(e_loc * cap_loc, d)
        w = (gates_l * keep.reshape(n_loc, k)).astype(dtype)
        slot_nk = jnp.minimum(slot.reshape(n_loc, k), e_loc * cap_loc - 1)
        y = jnp.zeros((n_loc, d), dtype)
        for kk in range(k):
            y = y + jnp.take(expert_out, slot_nk[:, kk], axis=0, mode="clip") \
                * w[:, kk:kk + 1]
        # the ONE global op: combine partial outputs across expert groups
        y = jax.lax.psum(y, "model")
        # each virtual token is kept on exactly one model rank =>
        # global kept fraction = tp * mean(keep); drop = 1 - that
        drop_l = 1.0 - tp * keep.mean()
        return y, jax.lax.pmean(drop_l, ("model",) + dp_axes)[None]

    y, drop = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp_entry, None), P(dp_entry, None), P(dp_entry, None),
                  wg_spec, wg_spec, wg_spec),
        out_specs=(P(dp_entry, None), P(None)),
        check_vma=False,
    )(xn, gates, experts,
      p["w_gate"].astype(dtype), p["w_up"].astype(dtype), p["w_down"].astype(dtype))
    return y, drop[0]


def moe_block(p, x: Array, cfg: ModelConfig) -> Tuple[Array, MoEAux]:
    """x: (B, S, d) -> (residual delta, aux losses)."""
    b, s, d = x.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    dtype = x.dtype
    # the (b, s) -> (n,) flatten merges the dp-sharded batch dim; without an
    # explicit anchor GSPMD replicates the flat activations (observed 24 GiB
    # fp32 copies + full-size scatter-add gradients on dbrx)
    xn = _constrain(apply_norm(p["norm"], x, cfg).reshape(b * s, d), "dp", None)
    n = b * s
    gates, experts, lb, z = _router(p, xn, cfg)

    if cfg.moe.dispatch == "dense":
        # run every expert on every token (no data movement, O(n·E) compute)
        all_out = _expert_ffn(p, jnp.broadcast_to(xn[None], (e, n, d)), dtype)  # (E, n, d)
        combine = jnp.zeros((n, e), jnp.float32)
        combine = jax.vmap(lambda c, ex, g: c.at[ex].add(g))(combine, experts, gates)
        y = jnp.einsum("ne,end->nd", combine.astype(dtype), all_out)
        drop = jnp.zeros((), jnp.float32)
    elif cfg.moe.dispatch == "multisplit_ep":
        out = _dispatch_multisplit_ep(p, xn, gates, experts, cfg, _capacity(n, cfg), dtype)
        if out is None:   # no mesh in scope: fall back to the GSPMD path
            import dataclasses as _dc

            return moe_block(
                p, x, _dc.replace(cfg, moe=_dc.replace(cfg.moe, dispatch="multisplit"))
            )
        y, drop = out
        y = y.reshape(b, s, d)
        if cfg.moe.shared_expert:
            y = y + mlp_block(p["shared"], x, cfg)
        return y, MoEAux(lb, z, drop)
    else:
        cap = _capacity(n, cfg)
        flat_experts = experts.reshape(-1)                          # (n·k,) virtual tokens
        if cfg.moe.dispatch == "multisplit":
            ranks, counts = _ranks_multisplit(flat_experts, e)
        elif cfg.moe.dispatch == "sort":
            ranks, counts = _ranks_sort(flat_experts, e)
        else:
            raise ValueError(f"unknown dispatch {cfg.moe.dispatch!r}")

        keep = ranks < cap
        slot = jnp.where(keep, flat_experts * cap + ranks, e * cap)  # OOB -> dropped
        token_idx = jnp.arange(n * k, dtype=jnp.int32) // k
        token_for_slot = jnp.full((e * cap,), n, jnp.int32).at[slot].set(
            token_idx, mode="drop"
        )
        # Sharding hygiene: NO +1-row pad concatenates — a (n+1, d) tensor
        # can't keep the batch sharding (n+1 doesn't divide) and GSPMD then
        # replicates the gather operand AND all-reduces its fp32 gradient at
        # full (n·k, d) size (observed: 96 GiB/op on dbrx). Clamp + mask
        # keeps every tensor shardable; masks zero out invalid lanes.
        valid_slot = (token_for_slot < n)[:, None].astype(dtype)     # (E·C, 1)
        expert_in = jnp.take(
            xn, jnp.minimum(token_for_slot, n - 1), axis=0,
            mode="clip",  # pre-clamped: no OOB fill/select machinery
        ) * valid_slot
        expert_in = expert_in.reshape(e, cap, d)
        # EP over model axis x DP over the capacity dim: expert compute is
        # 2-D sharded like everything else (tokens reach their expert shard
        # via the all-to-all GSPMD inserts for the gather).
        expert_in = _constrain(expert_in, "model", "dp", None)
        expert_out = _expert_ffn(p, expert_in, dtype)                # (E, C, d)
        expert_out = _constrain(expert_out, "model", "dp", None)
        flat_out = expert_out.reshape(e * cap, d)
        # Combine as a static loop over the k routed experts: one (n, d)
        # bf16 gather each, dp-anchored. (An einsum over a materialized
        # (n, k, d) tensor gets upcast to fp32 accumulation by XLA and
        # the reshape-merged sharding is lost — observed 96 GiB fp32
        # replicated tensors; the k-loop form stays bf16 and sharded.
        # Dropped slots: gate x keep == 0 kills the clamped garbage row.)
        w = (gates * keep.reshape(n, k)).astype(dtype)               # (n, k)
        slot_nk = jnp.minimum(slot.reshape(n, k), e * cap - 1)
        y = jnp.zeros((n, d), dtype)
        for kk in range(k):
            pick = jnp.take(
                flat_out, _constrain(slot_nk[:, kk], "dp"), axis=0,
                mode="clip",
            )
            y = y + _constrain(pick, "dp", None) * w[:, kk:kk + 1]
        y = _constrain(y, "dp", None)
        drop = 1.0 - keep.mean()

    y = y.reshape(b, s, d)
    if cfg.moe.shared_expert:
        y = y + mlp_block(p["shared"], x, cfg)   # always-on shared expert (own pre-norm)

    return y, MoEAux(lb, z, drop)
