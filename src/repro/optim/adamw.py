"""AdamW with dtype-configurable moments and global-norm clipping.

Moments may be stored in bf16 (``TrainConfig.moments_dtype``) — at
400B-parameter scale (llama4-maverick on one 256-chip pod) fp32 m/v do not
fit; bf16 moments + fp32 master weights is the deployed configuration
(EXPERIMENTS.md discusses the memory budget). Optimizer state inherits each
parameter's sharding (ZeRO: the state is sharded exactly like its param).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: Any
    mu: Any
    nu: Any
    master: Any = None       # fp32 master copy when params are bf16


def adamw_init(params, tc: TrainConfig) -> AdamWState:
    mdt = jnp.dtype(tc.moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    master = None
    if jnp.dtype(tc.params_dtype) != jnp.float32:
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        master=master,
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(grads, state: AdamWState, params, tc: TrainConfig, lr):
    """Returns (new_params, new_state, metrics).

    With ``params_dtype="bfloat16"`` the update reads/writes the fp32
    MASTER weights held in the optimizer state and re-emits bf16 params —
    the train graph's weight traffic (and gradient reduction) is bf16.
    """
    mdt = jnp.dtype(tc.moments_dtype)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tc.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        gf = g.astype(jnp.float32) * scale
        m1 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v1 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m1 / bc1
        vhat = v1 / bc2
        w = master if master is not None else p.astype(jnp.float32)
        delta = mhat / (jnp.sqrt(vhat) + tc.eps) + tc.weight_decay * w
        w1 = w - lr * delta
        return w1.astype(p.dtype), m1.astype(mdt), v1.astype(mdt), w1

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    has_master = state.master is not None
    flat_w = tdef.flatten_up_to(state.master) if has_master else [None] * len(flat_p)
    out = [upd(p, g, m, v, w) for p, g, m, v, w in zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_w = tdef.unflatten([o[3] for o in out]) if has_master else None
    return new_p, AdamWState(step, new_m, new_v, new_w), {"grad_norm": gnorm, "lr": lr}
