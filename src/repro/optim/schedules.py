"""LR schedules: cosine and WSD (Warmup-Stable-Decay, minicpm arXiv:2404.06395)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def make_schedule(tc: TrainConfig):
    warmup = max(tc.warmup_steps, 1)
    total = tc.total_steps

    def cosine(step):
        warm = jnp.minimum(step / warmup, 1.0)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return tc.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))

    def wsd(step):
        """Warmup -> Stable (flat) -> Decay (exponential-ish tail)."""
        warm = jnp.minimum(step / warmup, 1.0)
        decay_start = int(total * tc.decay_start)
        frac = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
        decay = 0.5 ** (frac * 8.0)   # ~2^-8 at the end, per minicpm's sharp tail
        return tc.lr * warm * jnp.where(step < decay_start, 1.0, decay)

    return {"cosine": cosine, "wsd": wsd}[tc.schedule]
