"""Int8 gradient compression with error feedback, for cross-pod reduction.

At 2-pod scale the pod axis crosses DCN/optical links an order of magnitude
slower than intra-pod ICI; compressing the cross-pod leg of the gradient
all-reduce 4x (fp32 -> int8 + per-block scales) trades a little optimizer
noise (bounded by error feedback) for link time.

Design: hierarchical reduction —
    1. intra-pod psum in full precision (fast links),
    2. int8-quantize (per 256-block absmax scales) + error-feedback residual,
    3. cross-pod psum of the int8 payload (as int32 to avoid overflow),
    4. dequantize.

``compressed_psum`` is written against ``shard_map`` axis names so it drops
into the manual-collective train step; ``quantize``/``dequantize`` are pure
and unit-tested on CPU.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

BLOCK = 256


class Quantized(NamedTuple):
    q: Array        # int8 payload
    scale: Array    # (n_blocks,) fp32 absmax scales
    n: int          # original length


def quantize(x: Array) -> Tuple[Quantized, Array]:
    """Returns (quantized, residual). x is flattened; blocks of 256."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(fp / scale[:, None]), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    residual = (flat - deq).reshape(x.shape).astype(x.dtype)
    return Quantized(q, scale, n), residual


def dequantize(qt: Quantized, shape, dtype) -> Array:
    deq = (qt.q.astype(jnp.float32) * qt.scale[:, None]).reshape(-1)[: qt.n]
    return deq.reshape(shape).astype(dtype)


def compressed_psum(grad: Array, error: Array, *, fast_axis: str, slow_axis: str):
    """Hierarchical error-feedback psum. Call inside shard_map.

    ``error`` is this worker's running error-feedback buffer (same shape as
    ``grad``); returns (reduced_grad, new_error).

    Pods must agree on ONE scale per block before summing int8 payloads
    (Σ q_p·s_p ≠ (Σ q_p)·mean s_p): a pmax of the block absmaxes (a tiny
    fp32 vector, n/256 elements) establishes the shared scale.
    """
    g = jax.lax.psum(grad, fast_axis)                    # full precision intra-pod
    g = g + error                                        # error feedback
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    # shared per-block scale across pods (small collective)
    absmax = jnp.max(jnp.abs(fp), axis=1)
    scale = jax.lax.pmax(absmax, slow_axis) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(fp / scale[:, None]), -127, 127).astype(jnp.int8)
    local_deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    residual = (flat - local_deq).reshape(grad.shape).astype(grad.dtype)
    qsum = jax.lax.psum(q.astype(jnp.int32), slow_axis)  # compressed cross-pod
    deq = (qsum.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return deq.reshape(grad.shape).astype(grad.dtype), residual
