from repro.optim.adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedules import make_schedule  # noqa: F401
