"""The continuous-batching step engine (DESIGN.md §16).

One :meth:`ServerLoop.step` = one admission decision + ONE segmented plan
launch for every admitted request:

    queue -> admit (RangeSpec length bucketing) -> pad to a shape class ->
    route_tokens_segmented (ONE segmented positions_only multisplit) ->
    per-request completion + metrics

Warm-plan reuse is structural, not incidental: admitted batches are padded
to a small ladder of ``(tokens, segments)`` shape classes, the step function
is one ``jax.jit`` callable, and the plan layer underneath hashes by value —
so after the first step of each shape class NOTHING retraces and NO plan is
rebuilt, step after step (counter-tested). ``REPRO_AUTOTUNE=1`` +
:meth:`ServerLoop.prewarm` moves even the first-miss autotune search out of
the serving path.

Robustness reuses the :class:`~repro.runtime.supervisor.FaultInjector`
pattern: a failed launch retries in-step (bounded), then requeues the batch
at the queue head (bounded per request, then counted ``failed``); submit
past the queue bound sheds (counted); :meth:`ServerLoop.drain` flushes the
queue ignoring the batching deadline on shutdown. Request accounting is
conservation-checked: ``dropped_by_bug`` must be zero always.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.runtime import resilience as _rz
from repro.serving.admission import AdmissionConfig, AdmissionPolicy
from repro.serving.metrics import ServingMetrics, StepRecord
from repro.serving.request import Request, RequestQueue

log = logging.getLogger("repro.serving")

__all__ = ["ServingConfig", "ServerLoop"]


@dataclasses.dataclass
class _Inflight:
    """One asynchronously launched, not-yet-finalized serving step."""

    batch: List["Request"]
    ids: np.ndarray
    starts: np.ndarray
    idx: int
    depth_at_admit: int
    n_tok: int
    t0: float
    attempts: int
    out: Any                 # device output (None if the launch itself raised)
    err: Optional[Exception]


@functools.lru_cache(maxsize=32)
def _routing_op(num_experts: int, capacity: int, backend: str):
    """(eager_fn, jitted_fn) for the default routing step, shared across
    ServerLoop instances — a second loop with the same (experts, capacity,
    backend) reuses the trace/compile cache instead of rebuilding it."""
    def run(expert_ids, segment_starts):
        from repro.models.moe import route_tokens_segmented

        return route_tokens_segmented(
            expert_ids, segment_starts, num_experts, capacity, backend=backend,
        )

    return run, jax.jit(run)


def _default_token_classes(max_batch_tokens: int) -> Tuple[int, ...]:
    """Padded flat-buffer ladder: x4 steps up to the batch-token cap, so a
    lightly loaded step doesn't pay the full-batch buffer and the jit/plan
    cache stays at a handful of shapes."""
    classes = []
    c = min(256, max_batch_tokens)
    while c < max_batch_tokens:
        classes.append(c)
        c *= 4
    classes.append(max_batch_tokens)
    return tuple(classes)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Continuous-batching server configuration (hashable, all-static)."""

    num_experts: int = 8
    capacity: int = 64               # per-(request, expert) dispatch slots
    max_batch_requests: int = 64
    max_batch_tokens: int = 4096
    max_wait: float = 0.02           # flush deadline (s)
    length_splitters: Tuple[int, ...] = (32, 128)
    token_pad_classes: Tuple[int, ...] = ()     # () -> derived ladder
    backend: str = "vmap"
    max_step_attempts: int = 3       # in-step launch tries (1 = no retry)
    max_requeues: int = 1            # failed-step requeues before a request fails
    max_queue_depth: int = 4096
    lookahead_batches: int = 4       # admission window, in max-size batches
    verify_sample_rate: float = 1.0  # launch-sampling rate once REPRO_VERIFY
    verify_seed: int = 0             # is armed (DESIGN.md §17)

    def __post_init__(self) -> None:
        if not self.token_pad_classes:
            object.__setattr__(
                self, "token_pad_classes",
                _default_token_classes(self.max_batch_tokens),
            )
        classes = self.token_pad_classes
        if list(classes) != sorted(set(classes)):
            raise ValueError(f"token_pad_classes must ascend, got {classes}")
        if classes[-1] < self.max_batch_tokens:
            raise ValueError(
                f"largest token class {classes[-1]} < max_batch_tokens "
                f"{self.max_batch_tokens}: a full batch has no shape class"
            )
        if self.max_step_attempts < 1:
            raise ValueError("max_step_attempts must be >= 1")
        if self.lookahead_batches < 1:
            raise ValueError("lookahead_batches must be >= 1")
        if list(self.length_splitters) != sorted(set(self.length_splitters)):
            raise ValueError(
                f"length_splitters must be strictly ascending, got "
                f"{self.length_splitters}"
            )
        if not 0.0 <= self.verify_sample_rate <= 1.0:
            raise ValueError(
                f"verify_sample_rate must be in [0, 1], got "
                f"{self.verify_sample_rate}"
            )

    def admission(self) -> AdmissionConfig:
        return AdmissionConfig(
            max_batch_requests=self.max_batch_requests,
            max_batch_tokens=self.max_batch_tokens,
            max_wait=self.max_wait,
            length_splitters=self.length_splitters,
            backend=self.backend,
            lookahead_batches=self.lookahead_batches,
        )


class ServerLoop:
    """Request-level continuous batching over the segmented plan layer.

    ``step_fn(expert_ids, segment_starts)`` is the per-step device program
    (default: :func:`~repro.models.moe.route_tokens_segmented` with this
    config's experts/capacity/backend); it always sees the PADDED shapes.
    ``fault_injector`` follows the
    :class:`~repro.runtime.supervisor.FaultInjector` protocol (``check(step)``
    raises to simulate a failure); ``clock`` is injectable for deterministic
    tests.
    """

    def __init__(
        self,
        cfg: ServingConfig,
        *,
        step_fn: Optional[Callable[[Any, Any], Any]] = None,
        fault_injector: Optional[Any] = None,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.cfg = cfg
        self.clock = clock
        self.queue = RequestQueue(cfg.max_queue_depth)
        self.policy = AdmissionPolicy(cfg.admission())
        self.metrics = ServingMetrics()
        self.faults = fault_injector
        self._default_step = step_fn is None
        if step_fn is None:
            self._step_fn, self._jit_step = _routing_op(
                cfg.num_experts, cfg.capacity, cfg.backend)
        else:
            self._step_fn, self._jit_step = step_fn, jax.jit(step_fn)
        self._verify_rng = np.random.RandomState(cfg.verify_seed)
        self._step_idx = 0
        self._next_rid = 0
        self._inflight: Optional[_Inflight] = None
        self.completed: List[Tuple[int, float]] = []   # (rid, latency_s)

    # -- shape classes ------------------------------------------------------
    @property
    def _s_pad(self) -> int:
        # +1: the trailing PAD segment that absorbs pad tokens — a full
        # batch must never leak its padding into a real request's counts
        return self.cfg.max_batch_requests + 1

    def _token_class(self, n_tok: int) -> int:
        for c in self.cfg.token_pad_classes:
            if c >= n_tok:
                return c
        return self.cfg.token_pad_classes[-1]

    def _pack(self, batch: List[Request]) -> Tuple[np.ndarray, np.ndarray, int]:
        """Coalesce a batch into the padded flat buffer + segment starts.

        Pad tokens carry expert ``E-1`` and live in the pad segment (rows
        ``>= len(batch)`` of the counts are synthetic and ignored); empty
        requests are zero-length segments — both exercised every step, which
        is why their plan-layer behavior is regression-pinned (ISSUE 9 S1).
        """
        lengths = [r.length for r in batch]
        n_tok = int(sum(lengths))
        n_pad = self._token_class(n_tok)
        ids = np.full((n_pad,), self.cfg.num_experts - 1, np.int32)
        if n_tok:
            ids[:n_tok] = np.concatenate([r.expert_ids for r in batch])
        starts = np.full((self._s_pad,), n_tok, np.int32)
        starts[0] = 0
        if len(lengths) > 1:
            starts[1:len(lengths)] = np.cumsum(lengths[:-1])
        return ids, starts, n_tok

    # -- ingress -------------------------------------------------------------
    def submit(self, expert_ids, *, arrival: Optional[float] = None,
               rid: Optional[int] = None) -> bool:
        """Enqueue one request; False = load-shed (queue full / oversized)."""
        arrival = self.clock() if arrival is None else arrival
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        req = Request(rid, expert_ids, arrival)
        self.metrics.observe_submit(arrival)
        if req.length > self.cfg.max_batch_tokens:
            self.metrics.observe_shed()          # can never fit a batch
            return False
        ok = self.queue.submit(req)
        if not ok:
            self.metrics.observe_shed()
        self.metrics.observe_queue_depth(self.queue.depth)
        return ok

    # -- one serving step ----------------------------------------------------
    def step(self, force: bool = False) -> Optional[Dict[str, Any]]:
        """Admit + launch once (PIPELINED). Returns None when nothing was
        admissible (not ready and not forced), else a launch report.

        The launch is asynchronous: step ``k``'s dispatch happens BEFORE
        step ``k-1`` is blocked on, so admission/packing host work overlaps
        device execution and the device never idles between steps. The
        previous step's completions (and failure handling) are finalized
        here; call :meth:`flush` to finalize the last in-flight step when
        going idle."""
        now = self.clock()
        batch = self.policy.admit(self.queue, now, force=force)
        if not batch:
            self.metrics.observe_empty_step()
            return None
        depth_at_admit = self.queue.depth + self.policy.pending() + len(batch)
        ids, starts, n_tok = self._pack(batch)
        idx = self._step_idx
        self._step_idx += 1
        t0 = self.clock()
        out, launch_err = None, None
        try:
            out = self._launch(ids, starts, idx)     # async dispatch
        except Exception as e:  # noqa: BLE001 — serving boundary
            launch_err = e
            log.warning("step %d attempt 1 failed at launch: %s", idx, e)
        self.flush()             # block on the PREVIOUS step while this one runs
        self._inflight = _Inflight(batch, ids, starts, idx, depth_at_admit,
                                   n_tok, t0, 1, out, launch_err)
        return {"step": idx, "ok": True, "requests": len(batch),
                "tokens": n_tok, "tokens_padded": int(ids.shape[0])}

    def _launch(self, ids, starts, idx: int):
        """Fault-injection check + asynchronous device dispatch."""
        if self.faults is not None:
            self.faults.check(idx)
        _rz.check_faults(self.cfg.backend)   # dispatch-level injection (§17)
        return self._jit_step(ids, starts)

    def _reference_rerun(self, p: "_Inflight"):
        """Re-run one step EAGERLY on the reference backend (the last rung
        of the §17 ladder at the serving boundary)."""
        ref_run, _ = _routing_op(
            self.cfg.num_experts, self.cfg.capacity, "reference")
        out = ref_run(p.ids, p.starts)
        jax.block_until_ready(out)
        self.metrics.degradations += 1
        _rz._count("degradations")
        return out

    def _degrade(self, p: "_Inflight", err: Exception):
        """Persistent kernel failures (lowering / resource) never heal by
        requeueing — the step re-runs on the reference backend instead so
        its requests still complete (degraded, counted). Transient faults
        and non-kernel errors keep the requeue path; a custom ``step_fn``
        has no reference twin; ``REPRO_STRICT`` disables all fallback."""
        if not self._default_step or _rz.strict():
            return None
        kerr = _rz.classify(err, backend=self.cfg.backend)
        if not isinstance(kerr, (_rz.KernelLoweringError,
                                 _rz.KernelResourceError)):
            return None
        try:
            out = self._reference_rerun(p)
        except Exception as ref_e:  # noqa: BLE001 — fall back to requeue
            log.warning("step %d reference fallback failed: %s", p.idx, ref_e)
            return None
        _rz._count("backend_demotions")
        _rz._event("serving_degrade", step=p.idx, frm=self.cfg.backend,
                   to="reference", error=type(kerr).__name__)
        log.warning("step %d degraded to reference after %s: %s",
                    p.idx, type(kerr).__name__, err)
        return out

    def _verify_ctx(self, p: "_Inflight") -> _rz.DispatchContext:
        return _rz.DispatchContext(
            spec_name="route_tokens_segmented", shape=(int(p.ids.shape[0]),),
            num_buckets=self.cfg.num_experts, mode="positions",
            layout="segmented", seed=self.cfg.verify_seed,
        )

    def _maybe_verify(self, p: "_Inflight", out):
        """Sampled runtime verification of one routing launch (§17): on a
        mismatch, count it, emit the structured repro report, and return
        the reference re-run so the degraded result is still correct."""
        if (not self._default_step or _rz.verify_level() <= 0
                or self.cfg.backend == "reference"
                or self._verify_rng.random_sample()
                >= self.cfg.verify_sample_rate):
            return out
        _rz._count("verify_checks")
        try:
            _rz.verify_routing(out, p.ids, p.starts, self.cfg.num_experts,
                               self.cfg.capacity, backend=self.cfg.backend)
            return out
        except _rz.KernelResultError as ve:
            if _rz.strict():
                raise
            self.metrics.verify_mismatches += 1
            _rz._count("verify_mismatches")
            _rz._count("reference_reruns")
            _rz._event("serving_verify_mismatch", step=p.idx,
                       backend=self.cfg.backend, detail=str(ve))
            _rz._emit_report(self._verify_ctx(p), self.cfg.backend, str(ve))
            log.warning("step %d verify mismatch, re-running on reference: %s",
                        p.idx, ve)
            return self._reference_rerun(p)

    def flush(self) -> None:
        """Finalize the in-flight step: block for its completion, retry its
        launch in place on failure (bounded), then record completions or
        requeue/fail its batch."""
        p = self._inflight
        if p is None:
            return
        self._inflight = None
        out, attempts, err = p.out, p.attempts, p.err
        while True:
            if out is None and err is not None:       # last attempt failed
                if attempts >= self.cfg.max_step_attempts:
                    break
                attempts += 1
                self.metrics.retries += 1
            try:
                if out is None:
                    out = self._launch(p.ids, p.starts, p.idx)
                jax.block_until_ready(out)
                err = None
                break
            except Exception as e:  # noqa: BLE001 — serving boundary
                err, out = e, None
                log.warning("step %d attempt %d failed: %s", p.idx, attempts, e)

        if err is not None:
            out = self._degrade(p, err)      # §17: reference rung, not requeue
            if out is not None:
                err = None
        if err is not None:
            # bounded requeue: the batch goes back to the queue HEAD in
            # order; requests over their requeue budget fail (counted).
            kept, dead = [], []
            for r in p.batch:
                r.requeues += 1
                (kept if r.requeues <= self.cfg.max_requeues else dead).append(r)
            # plan back first, then the failed batch AHEAD of it (it is older)
            self.policy.invalidate(self.queue)
            self.queue.requeue_front(kept)
            self.metrics.requeued += len(kept)
            self.metrics.failed += len(dead)
            rec = StepRecord(p.idx, len(p.batch), p.n_tok, p.ids.shape[0],
                             p.depth_at_admit, self.clock() - p.t0,
                             attempts=attempts, ok=False)
            self.metrics.observe_step(rec)
            return

        out = self._maybe_verify(p, out)     # §17: sampled output checking
        done = self.clock()
        for r in p.batch:
            self.metrics.observe_completion(r.arrival, done)
            self.completed.append((r.rid, done - r.arrival))
        rec = StepRecord(p.idx, len(p.batch), p.n_tok, p.ids.shape[0],
                         p.depth_at_admit, done - p.t0, attempts=attempts)
        self.metrics.observe_step(rec)

    # -- lifecycle -----------------------------------------------------------
    def prewarm(self) -> None:
        """Trace/compile every shape class before traffic, and — when
        autotuning is armed (``REPRO_AUTOTUNE=1`` /
        ``repro.ops.set_autotune(True)``) — run each class EAGERLY first so
        the measured (tile, family) resolution happens here, not under the
        first user-visible step (autotune defers inside a trace)."""
        from repro.core.pipeline import autotune as _at

        starts = np.zeros((self._s_pad,), np.int32)
        for c in self.cfg.token_pad_classes:
            ids = np.zeros((c,), np.int32)
            if _at.armed():
                # autotune defers under a trace: one EAGER pass per class
                # lets the measured (tile, family) search run here
                jax.block_until_ready(self._step_fn(ids, starts))
            jax.block_until_ready(self._jit_step(ids, starts))   # compile
        # the admission-side length-bucketing op, over the queue-depth
        # padding ladder (powers of two) up to the admission window, so a
        # depth class first seen under traffic doesn't compile mid-step
        window = self.cfg.lookahead_batches * self.cfg.max_batch_requests
        depth, probes = 8, []
        while depth <= min(self.cfg.max_queue_depth, window):
            probes.append(depth)
            depth *= 2
        for d in probes:
            dummy = [Request(-1, np.zeros((1,), np.int32), 0.0)] * d
            self.policy.length_groups(dummy)
        log.info("prewarmed %d shape classes, %d admission depths",
                 len(self.cfg.token_pad_classes), len(probes))

    def drain(self) -> Dict[str, float]:
        """Graceful shutdown: flush the queue ignoring the batching deadline
        (bounded — failing requests exhaust their requeue budget and are
        counted), finalize the last in-flight step, then return the final
        metrics summary."""
        while True:
            while self.queue.depth or self.policy.pending():
                self.step(force=True)
            self.flush()          # may requeue a failed in-flight batch
            if not (self.queue.depth or self.policy.pending()):
                return self.metrics_summary()

    # -- observability -------------------------------------------------------
    def metrics_summary(self) -> Dict[str, float]:
        """The exported metrics dict (+ live queue depth and the
        conservation check — ``dropped_by_bug`` MUST be 0)."""
        s = self.metrics.summary()
        queued = self.queue.depth + self.policy.pending()
        if self._inflight is not None:
            queued += len(self._inflight.batch)
        s["queued"] = queued
        s["dropped_by_bug"] = self.metrics.dropped_by_bug(queued)
        return s
