"""Serving observability: exact small-sample percentiles and the per-step /
per-request counters the continuous-batching loop exports (DESIGN.md §16).

Everything here is host-side bookkeeping — nothing touches jax. The summary
dict is the unit the serving bench appends (git-stamped through
``benchmarks/common.py``) to ``BENCH_multisplit.json``, so its keys are part
of the trajectory schema: latency percentiles in milliseconds, sustained
QPS, queue/batch occupancy, and the robustness counters (shed / retried /
requeued / failed).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Sequence, Tuple

__all__ = ["percentiles", "ServingMetrics", "StepRecord"]


def percentiles(
    samples: Iterable[float], ps: Sequence[float] = (50.0, 95.0, 99.0)
) -> Dict[float, float]:
    """Exact nearest-rank percentiles (no interpolation): percentile ``p`` of
    ``n`` sorted samples is element ``ceil(p/100 * n) - 1`` (0-indexed), i.e.
    the smallest sample >= at least ``p`` percent of the data — numpy's
    ``method="inverted_cdf"``, which the unit tests pin.

    Interpolating estimators (numpy's default ``linear``) invent values
    between observations, which misleads exactly where serving percentiles
    matter: small tails. With 100 latency samples the p99 here IS an
    observed request latency, not a blend of the two slowest.  Empty input
    returns NaNs (a drained loop that never completed a request has no
    latency distribution).
    """
    xs = sorted(float(x) for x in samples)
    out: Dict[float, float] = {}
    for p in ps:
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile must be in [0, 100], got {p}")
        if not xs:
            out[p] = float("nan")
            continue
        rank = max(1, math.ceil(p / 100.0 * len(xs)))     # p=0 -> the minimum
        out[p] = xs[rank - 1]
    return out


@dataclasses.dataclass
class StepRecord:
    """One executed serving step (one segmented plan launch)."""

    step: int
    requests: int
    tokens: int
    tokens_padded: int
    queue_depth: int          # depth BEFORE admission
    wall_s: float
    attempts: int = 1         # 1 = clean; >1 = in-step fault retries happened
    ok: bool = True


class ServingMetrics:
    """Counters + distributions for one :class:`~repro.serving.ServerLoop`.

    Request accounting is conservative by construction and checked by
    :meth:`dropped_by_bug`: every submitted request ends in exactly one of
    ``completed`` / ``shed`` / ``failed`` / still-queued.  Anything else is
    a lost request — the serving acceptance criterion is that this never
    happens under sustained load.
    """

    def __init__(self) -> None:
        self.submitted = 0
        self.completed = 0
        self.shed = 0               # load-shedding rejections at submit time
        self.failed = 0             # requeue budget exhausted (dropped ON PURPOSE)
        self.retries = 0            # in-step launch retries
        self.requeued = 0           # requests put back after a failed step
        self.degradations = 0       # steps re-run on the reference backend (§17)
        self.verify_mismatches = 0  # sampled runtime-verification failures (§17)
        self.steps = 0
        self.empty_steps = 0        # step() polled with nothing admissible
        self.queue_depth_max = 0
        self.latencies_s: List[float] = []
        self.step_records: List[StepRecord] = []
        self.first_arrival: float | None = None
        self.last_completion: float | None = None

    # -- observation hooks -------------------------------------------------
    def observe_submit(self, arrival: float) -> None:
        self.submitted += 1
        if self.first_arrival is None or arrival < self.first_arrival:
            self.first_arrival = arrival

    def observe_shed(self) -> None:
        self.shed += 1

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depth_max = max(self.queue_depth_max, depth)

    def observe_step(self, rec: StepRecord) -> None:
        self.steps += 1
        self.step_records.append(rec)

    def observe_empty_step(self) -> None:
        self.empty_steps += 1

    def observe_completion(self, arrival: float, completion: float) -> None:
        self.completed += 1
        self.latencies_s.append(max(0.0, completion - arrival))
        if self.last_completion is None or completion > self.last_completion:
            self.last_completion = completion

    # -- derived -----------------------------------------------------------
    def dropped_by_bug(self, still_queued: int) -> int:
        """Requests unaccounted for: MUST be zero (acceptance criterion)."""
        return (self.submitted - self.completed - self.shed - self.failed
                - still_queued)

    def occupancy(self) -> Tuple[float, float]:
        """(mean token occupancy of the padded buffer, mean request
        occupancy of the segment axis' admission cap) over executed steps."""
        recs = [r for r in self.step_records if r.ok]
        if not recs:
            return 0.0, 0.0
        tok = sum(r.tokens / max(r.tokens_padded, 1) for r in recs) / len(recs)
        req = sum(r.requests for r in recs) / len(recs)
        return tok, req

    def summary(self) -> Dict[str, float]:
        """The exported metrics dict (the BENCH trajectory unit)."""
        pct = percentiles(self.latencies_s)
        lat = self.latencies_s
        wall = 0.0
        if self.first_arrival is not None and self.last_completion is not None:
            wall = max(self.last_completion - self.first_arrival, 0.0)
        qps = self.completed / wall if wall > 0 else float("nan")
        tok_occ, req_mean = self.occupancy()
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "shed": self.shed,
            "failed": self.failed,
            "retries": self.retries,
            "requeued": self.requeued,
            "degradations": self.degradations,
            "verify_mismatches": self.verify_mismatches,
            "steps": self.steps,
            "empty_steps": self.empty_steps,
            "queue_depth_max": self.queue_depth_max,
            "latency_p50_ms": pct[50.0] * 1e3,
            "latency_p95_ms": pct[95.0] * 1e3,
            "latency_p99_ms": pct[99.0] * 1e3,
            "latency_mean_ms": (sum(lat) / len(lat) * 1e3) if lat else float("nan"),
            "qps_sustained": qps,
            "wall_s": wall,
            "batch_token_occupancy": tok_occ,
            "batch_requests_mean": req_mean,
        }
