"""Requests and the bounded request queue (DESIGN.md §16).

A :class:`Request` is one user's token stream for one serving step: the
per-token expert assignments its router produced (routing/dispatch IS the
multisplit workload — the paper's building-block thesis at request level).
The queue is a plain FIFO with a depth bound; overflowing it is the
load-shedding signal, not an error.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence

import numpy as np

__all__ = ["Request", "RequestQueue"]


@dataclasses.dataclass
class Request:
    """One queued unit of work.

    ``expert_ids`` is the (length,) int32 per-token expert assignment —
    host-side numpy on purpose: queued requests live outside any trace, and
    the engine concatenates them into ONE padded device buffer per step.
    ``arrival`` is the request's open-loop arrival time (latency is measured
    from here, so a slow driver shows up as queueing delay, faithfully).
    ``requeues`` counts failed-step requeues; the engine drops the request
    (counted, deliberate) when it exceeds the configured budget.
    """

    rid: int
    expert_ids: np.ndarray
    arrival: float
    requeues: int = 0

    def __post_init__(self) -> None:
        self.expert_ids = np.asarray(self.expert_ids, np.int32).reshape(-1)
        self._n = int(self.expert_ids.shape[0])

    @property
    def length(self) -> int:
        return self._n


class RequestQueue:
    """Bounded FIFO of :class:`Request`.

    ``submit`` returns False (shed) past ``max_depth`` — admission control
    belongs to the caller's policy; the queue only enforces the hard bound
    that keeps an overloaded server's memory finite.
    """

    def __init__(self, max_depth: int) -> None:
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = max_depth
        self._q: Deque[Request] = deque()
        self._tokens = 0                    # maintained incrementally: O(1) reads

    def __len__(self) -> int:
        return len(self._q)

    @property
    def depth(self) -> int:
        return len(self._q)

    def total_tokens(self) -> int:
        return self._tokens

    def submit(self, req: Request) -> bool:
        if len(self._q) >= self.max_depth:
            return False
        self._q.append(req)
        self._tokens += req.length
        return True

    def oldest(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def snapshot(self) -> List[Request]:
        """FIFO-ordered view (oldest first); does not pop."""
        return list(self._q)

    def remove(self, reqs: Sequence[Request]) -> None:
        """Pop an admitted subset (identity-matched; order-preserving for
        the rest). Scans from the HEAD only until every request is found —
        admission selects within a bounded head window, so this is O(window)
        regardless of backlog depth."""
        gone = {id(r) for r in reqs}
        kept: List[Request] = []
        while gone and self._q:
            r = self._q.popleft()
            if id(r) in gone:
                gone.discard(id(r))
                self._tokens -= r.length
            else:
                kept.append(r)
        for r in reversed(kept):
            self._q.appendleft(r)

    def requeue_front(self, reqs: Sequence[Request]) -> None:
        """Put a failed step's batch back at the HEAD in original order, so
        retried requests keep their age (and their place) over younger
        traffic. Bypasses ``max_depth``: these requests were already
        admitted once — shedding them here would turn a transient fault
        into silent request loss."""
        for r in reversed(list(reqs)):
            self._q.appendleft(r)
            self._tokens += r.length
