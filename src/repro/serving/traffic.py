"""Synthetic traffic generation + the open-loop simulation driver.

Open-loop means arrivals are EXOGENOUS (a Poisson process at a target rate,
independent of server progress) — the honest serving benchmark regime: a
saturated server's queue grows and latency explodes instead of the
arrival process politely slowing down, so "sustained QPS at a p99 SLO"
measures real capacity. Closed-loop (:func:`closed_loop`) saturates the
queue up front and drains — the throughput-only regime the offline-oracle
CI floor compares against.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import ServerLoop

__all__ = [
    "poisson_arrivals", "synthetic_requests", "open_loop", "closed_loop",
]


def poisson_arrivals(n: int, qps: float, seed: int = 0) -> np.ndarray:
    """(n,) ascending arrival offsets (s) of a Poisson process at ``qps``."""
    if qps <= 0:
        raise ValueError(f"qps must be > 0, got {qps}")
    rng = np.random.RandomState(seed)
    return np.cumsum(rng.exponential(1.0 / qps, size=n))


def synthetic_requests(
    n: int,
    num_experts: int,
    seed: int = 0,
    mean_len: int = 16,
    max_len: int = 128,
    empty_fraction: float = 0.02,
) -> List[np.ndarray]:
    """n per-request expert-id streams with geometric-ish ragged lengths.

    A small ``empty_fraction`` of requests carry ZERO tokens this step (a
    user idling mid-stream) — the zero-length-segment path the plan layer
    pins (ISSUE 9 S1) must be hit by normal traffic, not only by tests.
    """
    rng = np.random.RandomState(seed)
    lengths = np.minimum(
        rng.geometric(1.0 / max(mean_len, 1), size=n), max_len
    ).astype(np.int64)
    lengths[rng.uniform(size=n) < empty_fraction] = 0
    return [
        rng.randint(0, num_experts, size=int(l)).astype(np.int32)
        for l in lengths
    ]


def open_loop(
    loop: ServerLoop,
    requests: Sequence[np.ndarray],
    arrivals: Sequence[float],
    *,
    sleep=time.sleep,
    poll_s: float = 2e-4,
) -> Dict[str, float]:
    """Drive ``loop`` with the given arrival schedule, then drain.

    Requests are stamped with their SCHEDULED arrival time, so driver lag
    shows up as queueing latency (it is). Between events the driver sleeps
    until the next arrival or the batching deadline, whichever is sooner.
    Returns the final metrics summary.
    """
    if len(requests) != len(arrivals):
        raise ValueError("requests and arrivals must align")
    t0 = loop.clock()
    i, n = 0, len(requests)
    while i < n:
        now = loop.clock() - t0
        while i < n and arrivals[i] <= now:
            loop.submit(requests[i], arrival=t0 + float(arrivals[i]))
            i += 1
        if loop.step() is not None:
            continue
        loop.flush()     # going idle: finalize the in-flight step's completions
        # idle: sleep to the next actionable instant
        waits = []
        if i < n:
            waits.append(arrivals[i] - (loop.clock() - t0))
        oldest = loop.queue.oldest()
        if oldest is not None:
            waits.append(loop.cfg.max_wait - (loop.clock() - oldest.arrival))
        wait = min(waits) if waits else 0.0
        if wait > 0:
            sleep(min(wait, 0.005))
        elif not waits:
            break
        else:
            sleep(poll_s)
    return loop.drain()


def closed_loop(
    loop: ServerLoop, requests: Sequence[np.ndarray],
    arrival: Optional[float] = None,
) -> Dict[str, float]:
    """Saturation regime: everything arrives at once, drain at full batches.
    The loop's queue bound must admit the whole set (size it accordingly)."""
    t0 = loop.clock() if arrival is None else arrival
    for r in requests:
        loop.submit(r, arrival=t0)
    return loop.drain()
