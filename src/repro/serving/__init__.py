"""repro.serving — continuous-batching serving over the plan layer
(DESIGN.md §16, ISSUE 9).

The request-level consumer of the batched/segmented/autotuned multisplit
machinery: a bounded :class:`RequestQueue`, a RangeSpec-length-bucketing
:class:`AdmissionPolicy`, the :class:`ServerLoop` step engine (ONE segmented
plan launch per step, warm shapes, fault retry/requeue, load shedding,
graceful drain), :class:`ServingMetrics` (p50/p95/p99, sustained QPS,
occupancy, conservation check), and the open-loop traffic simulator.
"""

from repro.serving.admission import AdmissionConfig, AdmissionPolicy
from repro.serving.engine import ServerLoop, ServingConfig
from repro.serving.metrics import ServingMetrics, StepRecord, percentiles
from repro.serving.request import Request, RequestQueue
from repro.serving.traffic import (
    closed_loop,
    open_loop,
    poisson_arrivals,
    synthetic_requests,
)

__all__ = [
    "AdmissionConfig", "AdmissionPolicy",
    "ServerLoop", "ServingConfig",
    "ServingMetrics", "StepRecord", "percentiles",
    "Request", "RequestQueue",
    "closed_loop", "open_loop", "poisson_arrivals", "synthetic_requests",
]
