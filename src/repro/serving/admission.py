"""Admission/batching policy: which queued requests form the next step's
batch (DESIGN.md §16).

Three controls, all standard continuous-batching levers:

* ``max_batch_tokens`` / ``max_batch_requests`` — the step budget (the
  padded flat buffer and the segment axis of the ONE segmented plan launch).
* ``max_wait`` — the flush deadline: a step fires as soon as the batch is
  full OR the oldest queued request has waited this long (tail latency
  control under light load).
* **Length bucketing via** :class:`~repro.ops.RangeSpec` — the admission
  ORDER. Queued request lengths are bucketed by ONE splitter-based
  ``repro.ops.multisplit`` call (the same splitter-bucketing primitive that
  opens GPU sample sort), so each batch is built from length-similar
  requests and the padded buffer wastes as little as possible. The
  multisplit is stable, so FIFO order survives within a length class, and
  admission starts from the OLDEST request's class (rotating through the
  rest), so bucketing can never starve a class.

The policy is pure host-side selection: it never launches device work
beyond the (small, padded, plan-cached) length-bucketing call.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.serving.request import Request, RequestQueue

__all__ = ["AdmissionConfig", "AdmissionPolicy"]

# Queue-depth padding classes for the length-bucketing multisplit: the
# lengths vector is padded to the next power of two so the plan cache (and
# jit trace count) stays logarithmic in the observed depths, not linear.
_MIN_BUCKETING_PAD = 8

# Admission looks at a bounded FIFO window of the queue, not the whole
# backlog: a few batches' worth is enough to group by length, and it caps
# both the host-side packing cost per step and the bucketing shape ladder.
# (Default for AdmissionConfig.lookahead_batches; a saturation benchmark
# may raise it — a wider window packs closer to the offline oracle.)
LOOKAHEAD_BATCHES = 4


@functools.lru_cache(maxsize=64)
def _bucketing_op(spec, backend: str):
    """The jitted (lengths, idx) -> bucket-major reorder for one (spec,
    backend): specs hash by value, jit retraces only per padded depth —
    admission pays microseconds per step, not an eager pipeline walk."""
    from repro import ops

    def run(lengths, idx):
        return ops.multisplit(lengths, spec, idx, backend=backend)

    return jax.jit(run)


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    max_batch_requests: int = 64
    max_batch_tokens: int = 4096
    max_wait: float = 0.02                       # seconds
    # RangeSpec splitters over request LENGTH (ascending). () disables
    # bucketing (pure FIFO admission).
    length_splitters: Tuple[int, ...] = (32, 128)
    backend: str = "vmap"
    lookahead_batches: int = LOOKAHEAD_BATCHES

    def __post_init__(self) -> None:
        if self.max_batch_requests < 1:
            raise ValueError("max_batch_requests must be >= 1")
        if self.max_batch_tokens < 1:
            raise ValueError("max_batch_tokens must be >= 1")
        if self.lookahead_batches < 1:
            raise ValueError("lookahead_batches must be >= 1")
        if list(self.length_splitters) != sorted(set(self.length_splitters)):
            raise ValueError(
                f"length_splitters must be strictly ascending, got "
                f"{self.length_splitters}"
            )


class AdmissionPolicy:
    def __init__(self, cfg: AdmissionConfig) -> None:
        self.cfg = cfg
        self._spec = None                   # lazily-built length RangeSpec
        # Batches carved but not yet admitted: ONE bucketing call plans the
        # whole lookahead window (popped from the queue in ONE scan), then
        # consecutive steps pop from the plan — the per-step admission cost
        # amortizes over the window.
        self._plan: Deque[List[Request]] = deque()

    def pending(self) -> int:
        """Requests already popped from the queue into the pending plan
        (still owned by admission, not yet admitted to a step)."""
        return sum(len(b) for b in self._plan)

    def invalidate(self, queue: RequestQueue) -> None:
        """Return the pending plan's requests to the queue HEAD in order
        (call when the head must change under the plan — e.g. a failed step
        requeued its batch; planned requests must not be lost OR jumped)."""
        if self._plan:
            queue.requeue_front([r for b in self._plan for r in b])
            self._plan.clear()

    # -- flush condition ---------------------------------------------------
    def ready(self, queue: RequestQueue, now: float) -> bool:
        """A step should fire: full batch available, or deadline expired."""
        if self._plan:
            return True               # planned batches were admitted-ready
        oldest = queue.oldest()
        if oldest is None:
            return False
        if now - oldest.arrival >= self.cfg.max_wait:
            return True
        if queue.depth >= self.cfg.max_batch_requests:
            return True
        return queue.total_tokens() >= self.cfg.max_batch_tokens

    # -- length bucketing --------------------------------------------------
    def length_groups(self, reqs: Sequence[Request]) -> List[List[int]]:
        """Bucket request indices by length class via ONE ``repro.ops``
        splitter multisplit (stable: FIFO preserved within a class).
        Returns the non-empty groups in ascending-class order."""
        from repro import ops

        if not reqs:
            return []
        if not self.cfg.length_splitters:
            return [list(range(len(reqs)))]
        depth = len(reqs)
        pad = _MIN_BUCKETING_PAD
        while pad < depth:
            pad *= 2
        if self._spec is None:
            self._spec = ops.range_buckets(
                np.asarray(self.cfg.length_splitters, np.int32)
            )
        spec = self._spec
        lengths = np.full((pad,), np.int32(spec.pad_key(np.dtype(np.int32))))
        lengths[:depth] = [r.length for r in reqs]
        idx = np.arange(pad, dtype=np.int32)
        res = _bucketing_op(spec, self.cfg.backend)(np.asarray(lengths), idx)
        order = np.asarray(res.values)
        counts = np.asarray(res.bucket_counts)
        groups: List[List[int]] = []
        at = 0
        for c in counts:
            grp = [int(i) for i in order[at:at + int(c)] if i < depth]
            at += int(c)
            if grp:
                groups.append(grp)
        return groups

    # -- batch selection ---------------------------------------------------
    def _carve_batch(self, remaining: List[Request]) -> List[Request]:
        """Greedy skip-fill of one batch from ``remaining`` (in admission
        order), consuming the chosen requests."""
        batch: List[Request] = []
        tokens = 0
        left: List[Request] = []
        for r in remaining:
            if (len(batch) >= self.cfg.max_batch_requests
                    or (batch and tokens + r.length > self.cfg.max_batch_tokens)):
                left.append(r)        # skip-fill: later short requests may fit
                continue
            batch.append(r)
            tokens += r.length
        remaining[:] = left
        return batch

    def admit(self, queue: RequestQueue, now: float,
              force: bool = False) -> List[Request]:
        """Pop and return the next batch (possibly empty).

        ``force=True`` skips the :meth:`ready` gate (drain path). Selection
        walks the length groups starting from the oldest request's class —
        the deadline that fired belongs to that request, so its class leads
        — and greedily fills the token/request budget in stable FIFO order
        within each class. The whole lookahead window is carved into batches
        at once (one bucketing call) and later steps pop from that plan."""
        if not force and not self.ready(queue, now):
            return []
        if self._plan:
            return self._plan.popleft()   # already popped from the queue
        window = self.cfg.lookahead_batches * self.cfg.max_batch_requests
        reqs = queue.snapshot()[:window]
        if not reqs:
            return []
        groups = self.length_groups(reqs)
        # rotate: the group containing index 0 (the OLDEST request) first
        lead = next(i for i, g in enumerate(groups) if 0 in g)
        groups = groups[lead:] + groups[:lead]
        # pop the WHOLE window in one head scan; carve it into batches
        queue.remove(reqs)
        remaining = [reqs[i] for g in groups for i in g]
        batch = self._carve_batch(remaining)
        while remaining:
            b = self._carve_batch(remaining)
            if (remaining or len(b) >= self.cfg.max_batch_requests
                    or sum(r.length for r in b) >= self.cfg.max_batch_tokens):
                self._plan.append(b)
            else:
                # trailing underfull remainder: back to the queue HEAD so the
                # next window rebatches it densely with younger arrivals —
                # otherwise every window ships one partial batch and steady-
                # state occupancy is capped by the window size
                queue.requeue_front(b)
        return batch
