from repro.checkpoint.manager import CheckpointManager, load_checkpoint, save_checkpoint  # noqa: F401
