"""Sharded, async checkpointing with step management and integrity marks.

Fault-tolerance contract (runtime/supervisor.py):
  * saves are atomic (write to tmp dir, fsync manifest, rename);
  * an interrupted save never corrupts the previous checkpoint;
  * ``latest_step`` only reports checkpoints whose COMMIT mark exists;
  * async mode overlaps serialization with the next train steps and is
    drained before the process exits (or before the next save).

On a real multi-host pod each host writes only the shards it owns
(``jax.experimental.multihost_utils`` barriers around the rename); in this
single-host container that loop degenerates to local writes — the layout
(one .npz per host + manifest.json) is the multi-host layout.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


def save_checkpoint(ckpt_dir: str | Path, step: int, state: Any) -> Path:
    """Atomic synchronous save."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    if (final / "COMMIT").exists():
        # idempotent: this step is already durably saved (replay after a
        # restore re-reaches the same checkpoint boundary deterministically)
        return final
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    host = jax.process_index()
    flat, _ = _flatten_with_paths(state)
    arrays = {}
    meta = {"step": step, "leaves": [], "time": time.time(), "n_hosts": jax.process_count()}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i:05d}"
        arrays[key] = arr
        meta["leaves"].append({"key": key, "path": path, "shape": list(arr.shape),
                               "dtype": str(arr.dtype)})
    np.savez(tmp / f"host_{host:05d}.npz", **arrays)
    with open(tmp / "manifest.json", "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    (tmp / "COMMIT").touch()
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def load_checkpoint(ckpt_dir: str | Path, like: Any, step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (used for dtype/shape checks)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    meta = json.loads((d / "manifest.json").read_text())
    host = jax.process_index()
    data = np.load(d / f"host_{host:05d}.npz")
    flat_like, treedef = jax.tree.flatten(like)
    leaves = []
    for i, rec in enumerate(meta["leaves"]):
        arr = data[rec["key"]]
        want = flat_like[i]
        assert tuple(arr.shape) == tuple(want.shape), (rec["path"], arr.shape, want.shape)
        leaves.append(jax.numpy.asarray(arr, dtype=want.dtype))
    return jax.tree.unflatten(treedef, leaves), step


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "COMMIT").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


class CheckpointManager:
    """Keeps the last ``max_to_keep`` checkpoints; optional async saves."""

    def __init__(self, ckpt_dir: str | Path, max_to_keep: int = 3, async_saves: bool = True):
        self.dir = Path(ckpt_dir)
        self.max_to_keep = max_to_keep
        self.async_saves = async_saves
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: Any):
        self.wait()
        # device_get on the main thread (safe), file IO on the worker thread
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        if self.async_saves:
            def work():
                try:
                    save_checkpoint(self.dir, step, host_state)
                    self._gc()
                except BaseException as e:  # pragma: no cover
                    self._error = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.dir, step, host_state)
            self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, like: Any, step: Optional[int] = None):
        return load_checkpoint(self.dir, like, step)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.dir)

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.dir.iterdir()
            if p.name.startswith("step_") and (p / "COMMIT").exists()
        )
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
