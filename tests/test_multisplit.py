"""Core multisplit: oracle equivalence + hypothesis property tests."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.identifiers import (
    delta_buckets, even_buckets, from_fn, identity_buckets, range_buckets,
)
from repro.core.multisplit import multisplit, multisplit_ref


def _random_keys(n, seed=0, hi=2**30):
    return jnp.asarray(np.random.RandomState(seed).randint(0, hi, size=n, dtype=np.uint32))


@pytest.mark.parametrize("method", ["dms", "wms", "bms"])
@pytest.mark.parametrize("m", [2, 3, 8, 32, 256])
def test_methods_match_oracle(method, m):
    keys = _random_keys(4096 + 37, seed=m)       # non-tile-multiple on purpose
    vals = jnp.arange(keys.shape[0], dtype=jnp.int32)
    bf = delta_buckets(m, 2**30)
    ref = multisplit_ref(keys, bf, vals)
    out = multisplit(keys, bf, vals, method=method, tile=512)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.values), np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(out.bucket_counts), np.asarray(ref.bucket_counts))
    np.testing.assert_array_equal(np.asarray(out.permutation), np.asarray(ref.permutation))


@settings(max_examples=25, deadline=None)
@given(
    data=st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=600),
    m=st.integers(2, 64),
    seed=st.integers(0, 3),
)
def test_property_permutation_stable_contiguous(data, m, seed):
    """For ANY input and bucket count: output is a stable bucket-contiguous
    permutation of the input (the definition in paper §3.1)."""
    keys = jnp.asarray(np.array(data, dtype=np.uint32))
    bf = delta_buckets(m, 2**31)
    out = multisplit(keys, bf, jnp.arange(len(data), dtype=jnp.int32), tile=128)
    k_out, v_out = np.asarray(out.keys), np.asarray(out.values)
    ids_out = np.asarray(bf(out.keys))
    # (1) permutation: multiset of keys preserved
    np.testing.assert_array_equal(np.sort(k_out), np.sort(np.asarray(keys)))
    # (2) contiguous, ascending bucket ids
    assert np.all(np.diff(ids_out) >= 0)
    # (3) stability: original indices increase within each bucket
    for b in range(m):
        seg = v_out[ids_out == b]
        assert np.all(np.diff(seg) > 0) if seg.size > 1 else True
    # (4) counts/starts consistent
    counts = np.asarray(out.bucket_counts)
    assert counts.sum() == len(data)
    np.testing.assert_array_equal(
        np.asarray(out.bucket_starts), np.concatenate([[0], np.cumsum(counts)[:-1]])
    )


def test_arbitrary_bucket_function():
    """Keys need not be comparable — e.g. prime/composite style predicates."""
    keys = _random_keys(2000, seed=7, hi=1000)
    bf = from_fn(lambda u: (u % 7 == 0).astype(jnp.int32) + (u % 3 == 0) * 2, 4)
    out = multisplit(keys, bf, tile=256)
    ref = multisplit_ref(keys, bf)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))


def test_identity_and_range_and_even_buckets():
    keys = jnp.asarray(np.random.RandomState(1).randint(0, 16, 512, dtype=np.uint32))
    out = multisplit(keys, identity_buckets(16), tile=64)
    np.testing.assert_array_equal(np.asarray(out.keys), np.sort(np.asarray(keys)))

    fkeys = jnp.asarray(np.random.RandomState(2).uniform(0, 100, 512).astype(np.float32))
    bf = even_buckets(0.0, 100.0, 10)
    out = multisplit(fkeys, bf)
    assert np.all(np.diff(np.asarray(bf(out.keys))) >= 0)

    splitters = jnp.asarray([10.0, 30.0, 70.0])
    bf = range_buckets(splitters)
    out = multisplit(fkeys, bf)
    assert np.all(np.diff(np.asarray(bf(out.keys))) >= 0)


def test_pallas_backed_path_matches():
    keys = _random_keys(4096, seed=3)
    vals = jnp.arange(4096, dtype=jnp.int32)
    bf = delta_buckets(32, 2**30)
    ref = multisplit_ref(keys, bf, vals)
    out = multisplit(keys, bf, vals, method="bms", tile=512, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.values), np.asarray(ref.values))


def test_backend_arg_overrides_use_pallas():
    """`backend=` is the plan-layer spelling; it must agree with the legacy
    use_pallas knob it supersedes (see repro.core.plan)."""
    keys = _random_keys(1024 + 5, seed=9)
    bf = delta_buckets(8, 2**30)
    legacy = multisplit(keys, bf, method="wms", tile=256, use_pallas=True)
    modern = multisplit(keys, bf, method="wms", tile=256, backend="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(legacy.keys), np.asarray(modern.keys))
    ref = multisplit_ref(keys, bf)
    np.testing.assert_array_equal(np.asarray(modern.keys), np.asarray(ref.keys))


@pytest.mark.parametrize("backend", ["reference", "vmap", "pallas-interpret"])
def test_nan_keys_route_to_last_bucket(backend):
    """ISSUE 7 S1: NaN fails every comparison, so the pre-fix EvenSpec clip
    left NaN keys in an arbitrary bucket. They must all land in the LAST
    bucket (where the +inf pad key lives), on every backend."""
    from repro import ops

    rng = np.random.RandomState(2)
    keys = rng.uniform(0.0, 1.0, 1024).astype(np.float32)
    keys[rng.choice(1024, 50, replace=False)] = np.nan
    out = ops.multisplit(
        jnp.asarray(keys), ops.even_buckets(0.0, 1.0, 8), backend=backend
    )
    counts = np.asarray(out.bucket_counts)
    assert counts.sum() == 1024
    got = np.asarray(out.keys)
    last = int(np.asarray(out.bucket_starts)[-1])
    assert np.isnan(got[:last]).sum() == 0
    assert np.isnan(got[last:last + counts[-1]]).sum() == 50


def test_nan_keys_segmented_route_to_last_bucket_per_segment():
    """S1 on the segmented layout: every segment's NaNs land in that
    segment's OWN last bucket."""
    from repro import ops

    rng = np.random.RandomState(3)
    keys = rng.uniform(0.0, 1.0, 1024).astype(np.float32)
    keys[rng.choice(1024, 60, replace=False)] = np.nan
    starts = np.array([0, 512], np.int32)
    out = ops.segmented_multisplit(
        jnp.asarray(keys), ops.even_buckets(0.0, 1.0, 8), jnp.asarray(starts)
    )
    got = np.asarray(out.keys)
    s_starts = np.asarray(out.bucket_starts)       # (s, m) segment-local
    s_counts = np.asarray(out.bucket_counts)
    for s, (lo, hi) in enumerate(((0, 512), (512, 1024))):
        seg_nans = np.isnan(keys[lo:hi]).sum()
        b0 = lo + s_starts[s, -1]
        span = got[b0:b0 + s_counts[s, -1]]
        assert np.isnan(span).sum() == seg_nans
        assert np.isnan(got[lo:b0]).sum() == 0


def test_binomial_distribution_inputs():
    """Paper §6.4: extreme non-uniform distributions must still be exact."""
    rng = np.random.RandomState(0)
    m = 64
    ids = rng.binomial(m - 1, 0.5, size=5000).astype(np.uint32)
    keys = ids * 1000 + rng.randint(0, 1000, 5000).astype(np.uint32)
    bf = delta_buckets(m, 64000)
    out = multisplit(jnp.asarray(keys), bf, tile=512)
    ref = multisplit_ref(jnp.asarray(keys), bf)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
