"""The plan/dispatch layer (repro.core.plan): backend x method x key-value
equivalence against the reference oracle, fused-pipeline acceptance checks,
tile resolution cache, and the fused radix path."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import plan as msplan
from repro.core.identifiers import delta_buckets
from repro.core.multisplit import multisplit, multisplit_ref, multisplit_unfused
from repro.core.sort import radix_sort

BACKENDS = ["reference", "vmap", "pallas-interpret"]


def _keys(n, seed=0, hi=2**30):
    return jnp.asarray(np.random.RandomState(seed).randint(0, hi, size=n, dtype=np.uint32))


# ---------------------------------------------------------------------------
# Plan resolution
# ---------------------------------------------------------------------------

def test_make_plan_resolves_tile_and_caches():
    msplan.clear_tile_cache()
    p1 = msplan.make_plan(1 << 16, 32, method="bms", backend="vmap")
    p2 = msplan.make_plan(1 << 16, 32, method="bms", backend="vmap")
    assert p1.tile == p2.tile
    assert (1 << 16, 32, "bms", False, "vmap") in msplan._TILE_CACHE
    # explicit tile overrides the cache
    p3 = msplan.make_plan(1 << 16, 32, method="bms", backend="vmap", tile=512)
    assert p3.tile == 512


def test_tile_heuristic_respects_vmem_budget_on_pallas():
    # large m on a pallas backend must shrink the ONE-HOT-family tile below
    # the BMS default; the corrected PR-5 cost model charges both T×m̄
    # planes (one-hot + cumsum) and both T×T matrices (tril + permutation)
    msplan.clear_tile_cache()
    p = msplan.make_plan(1 << 20, 256, method="bms", backend="pallas", family="onehot")
    m_pad = 256
    t = p.tile
    assert 4 * (2 * t * m_pad + 2 * t * t + 8 * t) <= msplan._VMEM_BUDGET_BYTES
    assert t >= msplan._MIN_TILE
    # the packed family's near-flat-in-m working set keeps the full BMS tile
    pk = msplan.make_plan(1 << 20, 256, method="bms", backend="pallas", family="packed")
    assert pk.tile > p.tile


def test_small_input_gets_small_tile():
    p = msplan.make_plan(300, 8, method="bms", backend="vmap")
    assert p.tile <= 512


def test_plan_validates_inputs():
    with pytest.raises(ValueError):
        msplan.make_plan(100, 4, method="zms")
    with pytest.raises(ValueError):
        msplan.make_plan(100, 4, backend="cuda")
    p = msplan.make_plan(100, 4, key_value=True, bucket_fn=delta_buckets(4))
    with pytest.raises(ValueError):
        p(_keys(100))                      # resolved key-value, called key-only
    with pytest.raises(ValueError):
        p(_keys(64), jnp.arange(64))       # wrong n


def test_stages_description():
    from repro.core.identifiers import from_fn

    # m=4 sits below PACKED_MIN_BUCKETS, so the stage names carry no
    # family tag (the packed variants are asserted in test_packed.py)
    bf = delta_buckets(4)
    vm = msplan.make_plan(1024, 4, method="bms", backend="vmap", bucket_fn=bf)
    assert vm.stages()[-2] == "postscan:fused-reorder-vmap"
    # fusable specs label-fuse on kernel backends (PR-4): ids in-register
    pk = msplan.make_plan(1024, 4, method="wms", backend="pallas-interpret", bucket_fn=bf)
    assert pk.stages()[0] == "prescan:fused-label-kernel"
    assert pk.stages()[-2] == "postscan:fused-label-reorder-kernel"
    # the callable escape hatch keeps the materialized-labels stages
    cb = msplan.make_plan(
        1024, 4, method="wms", backend="pallas-interpret",
        bucket_fn=from_fn(lambda u: u.astype("int32") % 4, 4),
    )
    assert cb.stages()[0] == "prescan:kernel"
    assert cb.stages()[-2] == "postscan:fused-reorder-kernel"
    rx = msplan.make_radix_plan(
        1024, 0, 8, method="bms", backend="pallas-interpret", family="onehot"
    )
    assert rx.stages()[0] == "prescan:radix-fused-kernel"
    assert rx.stages()[-2] == "postscan:radix-fused-reorder-kernel"
    # the 256-bucket digit auto-resolves to the packed family (PR-5), which
    # tags the local-solve stages
    rx_auto = msplan.make_radix_plan(1024, 0, 8, method="bms", backend="pallas-interpret")
    assert rx_auto.family == "packed"
    assert rx_auto.stages()[0] == "prescan:radix-fused-kernel-packed"
    assert rx_auto.stages()[-2] == "postscan:radix-fused-reorder-kernel-packed"


# ---------------------------------------------------------------------------
# Equivalence sweep: backends x methods x key-only/key-value x ragged n
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", ["dms", "wms", "bms"])
@pytest.mark.parametrize("key_value", [False, True])
@pytest.mark.parametrize("n", [2048, 2048 + 37])        # tile-divisible and not
def test_plan_backends_match_reference(backend, method, key_value, n):
    m = 13
    keys = _keys(n, seed=(sum(map(ord, method)) * 1009 + n) % 100003)  # deterministic per case
    vals = jnp.arange(n, dtype=jnp.int32) if key_value else None
    bf = delta_buckets(m, 2**30)
    ref = multisplit_ref(keys, bf, vals)
    out = multisplit(keys, bf, vals, method=method, tile=256, backend=backend)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.bucket_counts), np.asarray(ref.bucket_counts))
    np.testing.assert_array_equal(np.asarray(out.bucket_starts), np.asarray(ref.bucket_starts))
    np.testing.assert_array_equal(np.asarray(out.permutation), np.asarray(ref.permutation))
    if key_value:
        np.testing.assert_array_equal(np.asarray(out.values), np.asarray(ref.values))


@pytest.mark.parametrize("method", ["dms", "wms", "bms"])
def test_fused_matches_legacy_unfused(method):
    n, m = 4096 + 17, 32
    keys = _keys(n, seed=5)
    vals = jnp.arange(n, dtype=jnp.int32)
    bf = delta_buckets(m, 2**30)
    legacy = multisplit_unfused(keys, bf, vals, method=method, tile=512)
    fused = multisplit(keys, bf, vals, method=method, tile=512)
    for a, b in zip(fused[:4], legacy[:4]):
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(fused.permutation), np.asarray(legacy.permutation))


# ---------------------------------------------------------------------------
# Acceptance: the fused kernel is the ONLY postscan/reorder entry point
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["wms", "bms"])
def test_pallas_postscan_goes_only_through_fused_kernel(method, monkeypatch):
    from repro.kernels import ops as kops

    def boom(*a, **k):
        raise AssertionError("unfused postscan/reorder kernel was called")

    monkeypatch.setattr(kops, "tile_positions", boom)
    monkeypatch.setattr(kops, "tile_reorder", boom)
    keys = _keys(2048 + 9, seed=2)
    vals = jnp.arange(keys.shape[0], dtype=jnp.int32)
    bf = delta_buckets(16, 2**30)
    out = multisplit(keys, bf, vals, method=method, tile=256, use_pallas=True)
    ref = multisplit_ref(keys, bf, vals)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.values), np.asarray(ref.values))


def test_radix_sort_pallas_never_materializes_labels(monkeypatch):
    """radix_sort(use_pallas=True): digit extraction happens inside the fused
    kernels — no BucketIdentifier is ever evaluated host-side."""
    from repro.core import identifiers

    calls = []
    orig = identifiers.BucketIdentifier.__call__

    def spy(self, keys):
        calls.append(self.name)
        return orig(self, keys)

    monkeypatch.setattr(identifiers.BucketIdentifier, "__call__", spy)
    rng = np.random.RandomState(0)
    keys = jnp.asarray(rng.randint(0, 2**32, 3000, dtype=np.uint32))
    vals = jnp.arange(3000, dtype=jnp.int32)
    ks, vs = radix_sort(keys, vals, radix_bits=8, use_pallas=True, tile=512)
    assert calls == [], f"host-side label materialization via {calls}"
    order = np.argsort(np.asarray(keys), kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(keys)[order])
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vals)[order])


# ---------------------------------------------------------------------------
# Fused radix path vs the platform sort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["vmap", "pallas-interpret"])
@pytest.mark.parametrize("method", ["dms", "bms"])
def test_radix_plan_backends_vs_jnp_sort(backend, method):
    rng = np.random.RandomState(7)
    keys = jnp.asarray(rng.randint(0, 2**32, 2500, dtype=np.uint32))
    ks, _ = radix_sort(keys, radix_bits=8, method=method, backend=backend, tile=512)
    np.testing.assert_array_equal(np.asarray(ks), np.sort(np.asarray(keys)))


def test_radix_key_value_pallas_vs_jnp_sort():
    rng = np.random.RandomState(11)
    keys = jnp.asarray(rng.randint(0, 2**32, 1500, dtype=np.uint32))
    vals = jnp.asarray(rng.randint(0, 2**31, 1500, dtype=np.int32))
    ks, vs = radix_sort(keys, vals, radix_bits=4, backend="pallas-interpret", tile=256)
    order = np.argsort(np.asarray(keys), kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(keys)[order])
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vals)[order])


# ---------------------------------------------------------------------------
# Autotune cache
# ---------------------------------------------------------------------------

def test_autotune_pins_tile_in_cache():
    msplan.clear_tile_cache()
    bf = delta_buckets(8, 2**30)
    tile = msplan.autotune_tile(
        4096, bf, method="bms", backend="vmap", candidates=(256, 1024), trials=1
    )
    assert tile in (256, 1024)
    assert msplan._TILE_CACHE[(4096, 8, "bms", False, "vmap")] == tile
    # subsequent plans pick up the tuned tile
    assert msplan.make_plan(4096, 8, method="bms", backend="vmap", bucket_fn=bf).tile == tile
