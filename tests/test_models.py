"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape + finiteness assertions, and decode==forward consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, ALIASES, get_config
from repro.models import model as M
from repro.parallel.sharding import init_params, param_count

ALL_ARCHS = list(ALIASES.keys())
DECODE_ARCHS = [
    "tinyllama-1.1b", "zamba2-1.2b", "xlstm-350m", "dbrx-132b",
    "h2o-danube-1.8b", "llama-3.2-vision-90b", "musicgen-large",
]


def _smoke_batch(sc, B=2, S=64, seed=0):
    rs = np.random.RandomState(seed)
    batch = {"labels": jnp.asarray(rs.randint(0, sc.vocab, (B, S)))}
    kwargs = {}
    if sc.embed_frontend_stub:
        batch["embeds"] = jax.random.normal(jax.random.PRNGKey(1), (B, S, sc.d_model))
        kwargs["embeds"] = batch["embeds"]
    else:
        batch["tokens"] = jnp.asarray(rs.randint(0, sc.vocab, (B, S)))
        kwargs["tokens"] = batch["tokens"]
    if sc.n_vis_tokens:
        vis = jax.random.normal(jax.random.PRNGKey(2), (B, sc.n_vis_tokens, sc.d_model))
        batch["vis_embeds"] = vis
        kwargs["vis_embeds"] = vis
    return batch, kwargs


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_grad(arch):
    sc = get_config(arch).smoke()
    decls = M.decl_model(sc)
    assert param_count(decls) > 0
    params = init_params(decls, jax.random.PRNGKey(0))
    batch, kwargs = _smoke_batch(sc)

    logits, _, _ = M.forward(params, sc, **kwargs)
    assert logits.shape == (2, 64, sc.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"

    (loss, metrics), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(params, sc, batch)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0, f"{arch}: bad grads"


@pytest.mark.slow
@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    sc = get_config(arch).smoke()
    params = init_params(M.decl_model(sc), jax.random.PRNGKey(0))
    B, S = 1, 20
    batch, kwargs = _smoke_batch(sc, B=B, S=S)
    logits, _, _ = M.forward(params, sc, **kwargs)
    vis = batch.get("vis_embeds")
    cache = M.init_cache(params, sc, B, max_len=S, vis_embeds=vis)
    dec = []
    for t in range(S):
        tok = (batch["embeds"][:, t:t + 1] if sc.embed_frontend_stub
               else batch["tokens"][:, t:t + 1])
        lg, cache = M.decode_step(params, sc, cache, tok, jnp.asarray(t, jnp.int32))
        dec.append(np.asarray(lg[:, 0]))
    dec = np.stack(dec, axis=1)
    ref = np.asarray(logits)
    err = np.abs(dec - ref).max() / (np.abs(ref).max() + 1e-9)
    assert err < 2e-2, f"{arch}: decode/forward mismatch rel err {err:.3e}"


def test_sliding_window_ring_cache():
    """SWA decode beyond the window must match forward (ring buffer wrap)."""
    sc = get_config("h2o-danube-1.8b").smoke()     # window = 64
    import dataclasses
    sc = dataclasses.replace(sc, window=16)
    params = init_params(M.decl_model(sc), jax.random.PRNGKey(0))
    B, S = 1, 40                                   # 2.5x the window
    batch, kwargs = _smoke_batch(sc, B=B, S=S)
    logits, _, _ = M.forward(params, sc, **kwargs)
    cache = M.init_cache(params, sc, B, max_len=S)
    dec = []
    for t in range(S):
        lg, cache = M.decode_step(
            params, sc, cache, batch["tokens"][:, t:t + 1], jnp.asarray(t, jnp.int32)
        )
        dec.append(np.asarray(lg[:, 0]))
    dec = np.stack(dec, axis=1)
    err = np.abs(dec - np.asarray(logits)).max() / (np.abs(np.asarray(logits)).max() + 1e-9)
    assert err < 2e-2, f"ring-cache mismatch {err:.3e}"


def test_block_patterns():
    from repro.models.model import block_pattern

    pat, n, tail = block_pattern(get_config("zamba2-1.2b"))
    assert pat == ["mamba"] * 5 + ["shared_attn"] and n == 6 and tail == ["mamba"] * 2
    pat, n, tail = block_pattern(get_config("llama4-maverick-400b-a17b"))
    assert pat == ["attn", "attn_moe"] and n == 24 and tail == []
    pat, n, tail = block_pattern(get_config("llama-3.2-vision-90b"))
    assert pat == ["attn"] * 4 + ["cross"] and n == 20 and tail == []
    pat, n, tail = block_pattern(get_config("xlstm-350m"))
    assert pat == ["mlstm", "slstm"] and n == 12


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters."""
    specs = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }
    for arch, (L, d, h, kv, ff, v) in specs.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (L, d, h, kv, ff, v), arch
    assert get_config("zamba2-1.2b").ssm.state == 64
    assert get_config("dbrx-132b").moe.num_experts == 16
    assert get_config("dbrx-132b").moe.top_k == 4
    assert get_config("llama4-maverick-400b-a17b").moe.num_experts == 128
    assert get_config("llama4-maverick-400b-a17b").moe.top_k == 1


def test_param_counts_in_expected_range():
    """Total parameters should be within ~25% of the arch's nameplate."""
    expect = {
        "tinyllama-1.1b": 1.1e9, "stablelm-1.6b": 1.6e9, "h2o-danube-1.8b": 1.8e9,
        "minicpm-2b": 2.4e9, "dbrx-132b": 132e9, "llama4-maverick-400b-a17b": 400e9,
        "llama-3.2-vision-90b": 90e9, "zamba2-1.2b": 1.2e9, "musicgen-large": 3.3e9,
        "xlstm-350m": 0.35e9,
    }
    for arch, n in expect.items():
        got = param_count(M.decl_model(get_config(arch)))
        assert 0.7 * n < got < 1.45 * n, f"{arch}: {got/1e9:.2f}B vs nameplate {n/1e9:.2f}B"
