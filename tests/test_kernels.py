"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SWEEP = [
    (1, 128, 2), (4, 256, 8), (2, 1024, 32), (3, 512, 256), (2, 384, 13),
]


@pytest.mark.parametrize("L,T,m", SWEEP)
def test_tile_histograms(L, T, m):
    ids = jnp.asarray(np.random.RandomState(L * T).randint(0, m, (L, T), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(ops.tile_histograms(ids, m)), np.asarray(ref.tile_histograms(ids, m))
    )


@pytest.mark.parametrize("L,T,m", SWEEP)
def test_tile_positions(L, T, m):
    rng = np.random.RandomState(m)
    ids = jnp.asarray(rng.randint(0, m, (L, T), dtype=np.int32))
    g = jnp.asarray(rng.randint(0, 100000, (L, m), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(ops.tile_positions(ids, g, m)),
        np.asarray(ref.tile_positions(ids, g, m)),
    )


@pytest.mark.parametrize("L,T,m", SWEEP)
@pytest.mark.parametrize("kdtype", [np.uint32, np.int32])
def test_tile_reorder(L, T, m, kdtype):
    rng = np.random.RandomState(T)
    ids = jnp.asarray(rng.randint(0, m, (L, T), dtype=np.int32))
    keys = jnp.asarray(rng.randint(0, 2**31 - 1, (L, T)).astype(kdtype))
    vals = jnp.asarray(rng.randint(0, 2**31 - 1, (L, T), dtype=np.int32))
    kk, vk, dk = ops.tile_reorder(ids, keys, vals, m)
    kr, vr, dr = ref.tile_reorder(ids, keys, vals, m)
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


@pytest.mark.parametrize("L,T,m", SWEEP)
@pytest.mark.parametrize("key_value", [False, True])
def test_fused_postscan_reorder(L, T, m, key_value):
    """THE fused kernel == composition of positions + reorder oracles."""
    rng = np.random.RandomState(L * T + m)
    ids = jnp.asarray(rng.randint(0, m, (L, T), dtype=np.int32))
    keys = jnp.asarray(rng.randint(0, 2**31 - 1, (L, T)).astype(np.uint32))
    vals = jnp.asarray(rng.randint(0, 2**31 - 1, (L, T), dtype=np.int32)) if key_value else None
    g = jnp.asarray(rng.randint(0, 100000, (L, m), dtype=np.int32))
    kk, vk, pk, permk = ops.fused_postscan_reorder(ids, g, keys, vals, m)
    kr, vr, pr, permr = ref.fused_postscan_reorder(ids, g, keys, vals, m)
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(permk), np.asarray(permr))
    if key_value:
        np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))


@pytest.mark.parametrize("shift,bits", [(0, 8), (8, 8), (28, 4), (12, 6)])
@pytest.mark.parametrize("key_value", [False, True])
def test_radix_fused_postscan_reorder(shift, bits, key_value):
    """Fused radix postscan: in-kernel digits == host digits + fused oracle."""
    rng = np.random.RandomState(shift * 31 + bits)
    keys = jnp.asarray(rng.randint(0, 2**31 - 1, (3, 256)).astype(np.uint32))
    vals = jnp.asarray(rng.randint(0, 2**31 - 1, (3, 256), dtype=np.int32)) if key_value else None
    m = 1 << bits
    g = jnp.asarray(rng.randint(0, 10000, (3, m), dtype=np.int32))
    kk, vk, pk, permk = ops.radix_fused_postscan_reorder(keys, g, vals, shift, bits)
    kr, vr, pr, permr = ref.radix_fused_postscan_reorder(keys, g, vals, shift, bits)
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(permk), np.asarray(permr))
    if key_value:
        np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))


@pytest.mark.parametrize("L,T,m", SWEEP)
def test_device_histogram(L, T, m):
    ids = jnp.asarray(np.random.RandomState(7).randint(0, m, (L, T), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(ops.device_histogram(ids, m)),
        np.asarray(ref.device_histogram(ids, m)),
    )


@pytest.mark.parametrize("shift,bits", [(0, 8), (8, 8), (24, 8), (0, 4), (12, 6), (28, 4)])
def test_radix_kernels(shift, bits):
    rng = np.random.RandomState(shift + bits)
    keys = jnp.asarray(rng.randint(0, 2**31 - 1, (3, 512)).astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(ops.radix_tile_histograms(keys, shift, bits)),
        np.asarray(ref.radix_tile_histograms(keys, shift, bits)),
    )
    m = 1 << bits
    g = jnp.asarray(rng.randint(0, 10000, (3, m), dtype=np.int32))
    pos_k = ops.radix_tile_positions(keys, g, shift, bits)
    ids = ((np.asarray(keys) >> shift) & (m - 1)).astype(np.int32)
    pos_r = ref.tile_positions(jnp.asarray(ids), g, m)
    np.testing.assert_array_equal(np.asarray(pos_k), np.asarray(pos_r))


def test_even_bucket_ids_kernel():
    keys = jnp.asarray(np.random.RandomState(0).uniform(0, 1024, (2, 512)).astype(np.float32))
    ids = ops.even_bucket_ids(keys, 0.0, 1024.0, 64)
    expect = np.clip(np.floor(np.asarray(keys) / 16.0), 0, 63).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(ids), expect)


@settings(max_examples=15, deadline=None)
@given(
    t_exp=st.integers(7, 10),
    m=st.integers(2, 256),
    seed=st.integers(0, 100),
)
def test_property_kernels_match_oracle(t_exp, m, seed):
    """Histogram+positions kernels == oracle for arbitrary (T, m)."""
    t = 1 << t_exp
    rng = np.random.RandomState(seed)
    ids = jnp.asarray(rng.randint(0, m, (2, t), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(ops.tile_histograms(ids, m)), np.asarray(ref.tile_histograms(ids, m))
    )
    g = jnp.asarray(rng.randint(0, 1000, (2, m), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(ops.tile_positions(ids, g, m)), np.asarray(ref.tile_positions(ids, g, m))
    )


@pytest.mark.parametrize("s,hd,causal,bq,bk", [
    (256, 64, True, 64, 64),
    (512, 128, True, 128, 64),
    (256, 64, False, 64, 128),
    (512, 32, True, 256, 256),
])
def test_flash_attention_kernel(s, hd, causal, bq, bk):
    rng = np.random.RandomState(s + hd)
    q = jnp.asarray(rng.randn(3, s, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(3, s, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(3, s, hd).astype(np.float32))
    out = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(2, 256, 64)).astype(jnp.bfloat16)
    k = jnp.asarray(rng.randn(2, 256, 64)).astype(jnp.bfloat16)
    v = jnp.asarray(rng.randn(2, 256, 64)).astype(jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=5e-2
    )
