"""Multisplit-sort (paper §7.1) and device histogram (paper §7.3)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.histogram import histogram_even, histogram_range
from repro.core.identifiers import delta_buckets
from repro.core.multisplit import multisplit_ref
from repro.core.sort import direct_sort_multisplit, radix_sort, rb_sort_multisplit


@pytest.mark.parametrize("radix_bits", [4, 6, 7, 8])
def test_radix_sort_vs_numpy(radix_bits):
    rng = np.random.RandomState(radix_bits)
    keys = rng.randint(0, 2**32, size=5000, dtype=np.uint32)
    vals = np.arange(5000, dtype=np.int32)
    ks, vs = radix_sort(jnp.asarray(keys), jnp.asarray(vals), radix_bits=radix_bits)
    order = np.argsort(keys, kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), keys[order])
    np.testing.assert_array_equal(np.asarray(vs), vals[order])


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=400))
def test_property_radix_sort(data):
    keys = np.array(data, dtype=np.uint32)
    ks, _ = radix_sort(jnp.asarray(keys), radix_bits=8)
    np.testing.assert_array_equal(np.asarray(ks), np.sort(keys))


@pytest.mark.parametrize("radix_bits", [4, 8])
@pytest.mark.parametrize("method", ["dms", "bms"])
def test_radix_sort_fused_pallas(radix_bits, method):
    """The fused in-kernel digit path (no host labels) vs numpy stable sort."""
    rng = np.random.RandomState(radix_bits + 100)
    keys = rng.randint(0, 2**32, size=3000, dtype=np.uint32)
    vals = np.arange(3000, dtype=np.int32)
    ks, vs = radix_sort(
        jnp.asarray(keys), jnp.asarray(vals),
        radix_bits=radix_bits, method=method, use_pallas=True, tile=512,
    )
    order = np.argsort(keys, kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), keys[order])
    np.testing.assert_array_equal(np.asarray(vs), vals[order])


def test_radix_sort_rejects_float_keys():
    """ISSUE 7 S4: BitfieldSpec digit extraction on float keys silently
    produced garbage (its pad_key cast to -1). Radix plans must refuse
    non-integer key dtypes with an actionable error instead."""
    from repro.core.sort import segmented_radix_sort

    f = jnp.ones((64,), jnp.float32)
    with pytest.raises(TypeError, match="integer keys"):
        radix_sort(f)
    with pytest.raises(TypeError, match="integer keys"):
        segmented_radix_sort(f, jnp.asarray([0, 32], jnp.int32))
    with pytest.raises(TypeError, match="integer keys"):
        radix_sort(f, fuse_digits=True)


def test_rb_sort_baseline_matches_multisplit():
    rng = np.random.RandomState(0)
    keys = jnp.asarray(rng.randint(0, 2**30, 4096, dtype=np.uint32))
    vals = jnp.arange(4096, dtype=jnp.int32)
    bf = delta_buckets(32, 2**30)
    ref = multisplit_ref(keys, bf, vals)
    rb = rb_sort_multisplit(keys, bf, vals)
    np.testing.assert_array_equal(np.asarray(rb.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(rb.values), np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(rb.bucket_counts), np.asarray(ref.bucket_counts))


def test_direct_sort_baseline():
    keys = jnp.asarray(np.random.RandomState(0).randint(0, 2**30, 1000, dtype=np.uint32))
    ks, _ = direct_sort_multisplit(keys)
    np.testing.assert_array_equal(np.asarray(ks), np.sort(np.asarray(keys)))


@pytest.mark.parametrize("m", [2, 16, 64, 256])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_histogram_even(m, use_pallas):
    keys = jnp.asarray(np.random.RandomState(m).uniform(0, 1024, 20000).astype(np.float32))
    h = histogram_even(keys, 0.0, 1024.0, m, use_pallas=use_pallas)
    expect, _ = np.histogram(np.asarray(keys), bins=m, range=(0, 1024))
    np.testing.assert_array_equal(np.asarray(h), expect)


def test_histogram_range():
    rng = np.random.RandomState(1)
    keys = jnp.asarray(rng.uniform(0, 1000, 10000).astype(np.float32))
    splitters = jnp.asarray(np.sort(rng.uniform(0, 1000, 15)).astype(np.float32))
    h = histogram_range(keys, splitters)
    expect, _ = np.histogram(
        np.asarray(keys), bins=np.concatenate([[-np.inf], np.asarray(splitters), [np.inf]])
    )
    np.testing.assert_array_equal(np.asarray(h), expect)


# ---------------------------------------------------------------------------
# Histogram edges (ISSUE 3 satellite: the counts_only migration must handle
# the degenerate shapes the old private-_pad_to_tiles path special-cased)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_histogram_empty_input(use_pallas):
    h = histogram_even(jnp.zeros((0,), jnp.float32), 0.0, 1.0, 8, use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(h), np.zeros(8))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_histogram_single_bucket(use_pallas):
    keys = jnp.asarray(np.random.RandomState(0).uniform(0, 9, 777).astype(np.float32))
    h = histogram_even(keys, 0.0, 9.0, 1, use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(h), np.asarray([777]))


@pytest.mark.parametrize("n", [1, 255, 257, 4096 + 37])
def test_histogram_non_multiple_of_tile(n):
    keys = jnp.asarray(np.random.RandomState(n).uniform(0, 32, n).astype(np.float32))
    for use_pallas in (False, True):
        h = histogram_even(keys, 0.0, 32.0, 8, tile=256, use_pallas=use_pallas)
        expect, _ = np.histogram(np.asarray(keys), bins=8, range=(0, 32))
        np.testing.assert_array_equal(np.asarray(h), expect)
        assert int(h.sum()) == n


def test_histogram_resolves_tile_through_shared_cache():
    """The old code hardcoded HIST_TILE=4096 and reached into
    ms._pad_to_tiles; now tile=None goes through resolve_tile and lands in
    the shared per-shape cache."""
    from repro.core.pipeline import tiles

    tiles.clear_tile_cache()
    keys = jnp.asarray(np.random.RandomState(2).uniform(0, 8, 20000).astype(np.float32))
    histogram_even(keys, 0.0, 8.0, 8)
    assert (20000, 8, "bms", False, "vmap") in tiles._TILE_CACHE
