"""multisplit_ep (manual shard_map expert-parallel dispatch) equivalence."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_multisplit_ep_matches_gspmd_dispatch():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    code = textwrap.dedent("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.models import moe
        from repro.parallel.sharding import init_params

        cfg = ModelConfig(
            name="t", family="moe", n_layers=2, d_model=64, n_heads=4, n_kv=4,
            d_ff=128, vocab=128, dtype="float32",
            moe=MoEConfig(num_experts=8, top_k=2, dispatch="multisplit",
                          capacity_factor=8.0),
        )
        params = init_params(moe.moe_decl(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 64), jnp.float32)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            y_ref, aux_ref = jax.jit(
                lambda p, x: moe.moe_block(p, x, cfg)
            )(params, x)
            cfg_ep = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, dispatch="multisplit_ep"))
            y_ep, aux_ep = jax.jit(
                lambda p, x: moe.moe_block(p, x, cfg_ep)
            )(params, x)
        err = np.abs(np.asarray(y_ep) - np.asarray(y_ref)).max()
        rel = err / (np.abs(np.asarray(y_ref)).max() + 1e-9)
        assert rel < 1e-4, f"multisplit_ep mismatch rel={rel}"
        assert float(aux_ep.drop_fraction) < 1e-6
        # grads flow through the shard_map dispatch
        g = jax.grad(lambda p: jnp.sum(moe.moe_block(p, x, cfg_ep)[0] ** 2))
        with jax.set_mesh(mesh):
            grads = g(params)
        gn = sum(float(jnp.abs(t).sum()) for t in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0
        print("OK", rel)
    """)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"STDOUT:{proc.stdout}\nSTDERR:{proc.stderr[-3000:]}"
    assert "OK" in proc.stdout
