"""Optimizer, schedules, checkpointing, data pipeline, supervisor."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.manager import latest_step
from repro.configs.base import TrainConfig
from repro.data import DataPipeline
from repro.optim import adamw_init, adamw_update, make_schedule
from repro.runtime.supervisor import FaultInjector, Supervisor, TrainLoopConfig


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    tc = TrainConfig(lr=0.1, weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    state = adamw_init(params, tc)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(grads, state, params, tc, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


@pytest.mark.parametrize("mdt", ["float32", "bfloat16"])
def test_adamw_moments_dtype(mdt):
    tc = TrainConfig(moments_dtype=mdt)
    params = {"w": jnp.ones((4, 4))}
    state = adamw_init(params, tc)
    assert state.mu["w"].dtype == jnp.dtype(mdt)
    params2, state2, m = adamw_update({"w": jnp.ones((4, 4))}, state, params, tc, 1e-3)
    assert state2.mu["w"].dtype == jnp.dtype(mdt)
    assert params2["w"].dtype == params["w"].dtype
    assert float(m["grad_norm"]) == pytest.approx(4.0)


def test_grad_clipping():
    tc = TrainConfig(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params, tc)
    big = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    small = {"w": jnp.asarray([1e-3, 0.0, 0.0])}
    p_big, _, _ = adamw_update(big, state, params, tc, 0.1)
    p_small, _, _ = adamw_update(small, state, params, tc, 0.1)
    # after clipping, both steps are bounded by lr-scale, not grad-scale
    assert float(jnp.abs(p_big["w"]).max()) < 1.0


def test_schedules():
    tc = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100, schedule="cosine")
    cos = make_schedule(tc)
    assert float(cos(0)) < float(cos(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(cos(99)) < 1e-4
    tcw = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100, schedule="wsd",
                      decay_start=0.8)
    wsd = make_schedule(tcw)
    assert float(wsd(50)) == pytest.approx(1e-3, rel=1e-3)   # stable plateau
    assert float(wsd(99)) < 2e-5                              # sharp decay tail


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, 7, st)
    restored, step = load_checkpoint(tmp_path, st)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_checkpoint_atomicity(tmp_path):
    """An uncommitted (interrupted) save must be invisible."""
    st = _state()
    save_checkpoint(tmp_path, 1, st)
    # simulate an interrupted save: tmp dir without COMMIT
    broken = tmp_path / "step_00000002"
    broken.mkdir()
    (broken / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 1


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, max_to_keep=2, async_saves=True)
    st = _state()
    for s in (10, 20, 30, 40):
        mgr.save(s, st)
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [30, 40]
    _, latest = mgr.restore(st)
    assert latest == 40


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_restart_safe():
    p1 = DataPipeline(vocab=512, seq_len=128, batch_per_host=4, seed=3)
    p2 = DataPipeline(vocab=512, seq_len=128, batch_per_host=4, seed=3)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_shard_disjoint():
    a = DataPipeline(vocab=512, seq_len=64, batch_per_host=2, seed=0, host_index=0, n_hosts=2)
    b = DataPipeline(vocab=512, seq_len=64, batch_per_host=2, seed=0, host_index=1, n_hosts=2)
    assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])


def test_pipeline_labels_shifted():
    p = DataPipeline(vocab=512, seq_len=64, batch_per_host=2, seed=1)
    b = p.batch_at(0)
    tok, lab = b["tokens"], b["labels"]
    live = (tok[:, :-1] > 0) & (tok[:, 1:] > 0)
    np.testing.assert_array_equal(lab[:, :-1][live], tok[:, 1:][live])


# ---------------------------------------------------------------------------
# supervisor: fault tolerance end to end (tiny problem)
# ---------------------------------------------------------------------------

def _toy_step(state, batch):
    w = state["w"] - 0.1 * (state["w"] - batch)
    return {"w": w}, {"loss": jnp.mean((w - batch) ** 2)}


def test_supervisor_retries_and_restores(tmp_path):
    faults = FaultInjector(fail_at={5: 1, 12: 10})   # transient at 5, persistent at 12
    sup = Supervisor(
        _toy_step,
        lambda step: jnp.asarray(float(step)),
        TrainLoopConfig(total_steps=20, checkpoint_every=4,
                        checkpoint_dir=str(tmp_path), max_retries_per_step=2,
                        max_restores=30, log_every=100),
        fault_injector=faults,
    )
    state = sup.run({"w": jnp.asarray(0.0)})
    assert sup.stats["retries"] >= 1
    assert sup.stats["restores"] >= 1           # persistent fault forced a restore
    assert latest_step(tmp_path) == 20
    assert np.isfinite(float(state["w"]))


def test_supervisor_resumes_from_checkpoint(tmp_path):
    cfgs = TrainLoopConfig(total_steps=10, checkpoint_every=5,
                           checkpoint_dir=str(tmp_path), log_every=100)
    sup1 = Supervisor(_toy_step, lambda s: jnp.asarray(float(s)), cfgs)
    sup1.run({"w": jnp.asarray(0.0)})
    # second run starts at the final checkpoint and is a no-op
    sup2 = Supervisor(_toy_step, lambda s: jnp.asarray(float(s)), cfgs)
    state = sup2.run({"w": jnp.asarray(123.0)})
    assert float(state["w"]) != 123.0           # restored, not reinitialized


def test_supervisor_elastic_remesh(tmp_path):
    calls = []

    def remesh(state):
        calls.append(1)
        return state

    faults = FaultInjector(fail_at={3: 999})
    sup = Supervisor(
        _toy_step, lambda s: jnp.asarray(float(s)),
        TrainLoopConfig(total_steps=6, checkpoint_every=100,
                        checkpoint_dir=str(tmp_path), max_retries_per_step=0,
                        max_restores=1, log_every=100),
        fault_injector=faults, remesh_fn=remesh,
    )
    with pytest.raises(Exception):
        # remesh is called, but the injected fault persists -> eventually raises
        sup.run({"w": jnp.asarray(0.0)})
    assert calls, "elastic re-mesh hook was never invoked"


def test_adamw_bf16_params_master_weights():
    """params_dtype=bfloat16: fp32 master in the opt state drives updates."""
    tc = TrainConfig(lr=0.1, weight_decay=0.0, clip_norm=10.0, params_dtype="bfloat16")
    params = {"w": jnp.asarray([3.0, -2.0, 5.0], jnp.bfloat16)}
    state = adamw_init(params, tc)
    assert state.master is not None and state.master["w"].dtype == jnp.float32
    for _ in range(300):
        grads = {"w": 2 * state.master["w"].astype(jnp.bfloat16)}
        params, state, _ = adamw_update(grads, state, params, tc, lr=0.05)
        assert params["w"].dtype == jnp.bfloat16
    assert float(jnp.abs(state.master["w"]).max()) < 5e-2


def test_grad_accumulation_equivalent():
    """accum_steps=4 must produce the same update as the full batch."""
    import numpy as np
    from repro.configs import get_config
    from repro.launch import steps as S
    from repro.models import model as M
    from repro.parallel.sharding import init_params

    cfg = get_config("tinyllama-1.1b").smoke()
    params = init_params(M.decl_model(cfg), jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rs.randint(0, cfg.vocab, (4, 64))),
             "labels": jnp.asarray(rs.randint(0, cfg.vocab, (4, 64)))}
    from repro.optim import adamw_init

    tc1, tc4 = TrainConfig(accum_steps=1), TrainConfig(accum_steps=4)
    s1 = S.TrainState(params, adamw_init(params, tc1))
    s4 = S.TrainState(params, adamw_init(params, tc4))
    n1, m1 = S.make_train_step(cfg, tc1)(s1, batch)
    n4, m4 = S.make_train_step(cfg, tc4)(s4, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(n1.params), jax.tree.leaves(n4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
