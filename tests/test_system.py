"""End-to-end behaviour: training actually learns; serving actually decodes."""

import numpy as np
import pytest


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    """Full stack: config -> params -> data -> supervisor -> loss decreases."""
    from repro.launch.train import main

    sup = main([
        "--arch", "tinyllama-1.1b", "--smoke", "--steps", "30",
        "--batch", "4", "--seq", "96", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "100",
    ])
    losses = [h["loss"] for h in sup.history]
    assert len(losses) >= 2
    assert losses[-1] < losses[0] - 0.2, f"no learning: {losses}"
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.slow
def test_train_moe_multisplit_dispatch(tmp_path):
    from repro.launch.train import main

    sup = main([
        "--arch", "dbrx-132b", "--smoke", "--steps", "50", "--batch", "4",
        "--seq", "64", "--lr", "3e-3", "--dispatch", "multisplit",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "100",
    ])
    losses = [h["loss"] for h in sup.history]
    assert losses[-1] < losses[0] - 0.1, f"MoE not learning: {losses}"


@pytest.mark.slow
def test_serve_generates(capsys):
    from repro.launch.serve import main

    gen = main(["--arch", "tinyllama-1.1b", "--smoke", "--batch", "2",
                "--prompt-len", "8", "--gen-len", "8"])
    assert gen.shape[0] == 2
    assert (gen >= 0).all()
