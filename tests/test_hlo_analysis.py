"""HLO collective parsing + roofline term math (pure string/number tests)."""

import pytest

from repro.launch.hlo_analysis import (
    ICI_BW, PEAK_FLOPS, HBM_BW, _shape_bytes, dominant_term, parse_collectives,
    roofline_terms,
)

HLO = """
HloModule jit_step

ENTRY %main (p0: f32[16,512]) -> f32[16,512] {
  %p0 = f32[16,512]{1,0} parameter(0)
  %ag = f32[256,512]{1,0} all-gather(%p0), replica_groups={{0,1}}, dimensions={0}
  %c = bf16[256,512]{1,0} convert(%ag)
  %ar = bf16[256,512]{1,0} all-reduce(%c), to_apply=%add
  %a2a = bf16[256,512]{1,0} all-to-all(%ar), dimensions={0}
  %rs = bf16[16,512]{1,0} reduce-scatter(%a2a), dimensions={0}
  %cp = bf16[16,512]{1,0} collective-permute(%rs), source_target_pairs={{0,1}}
  ROOT %out = f32[16,512]{1,0} convert(%cp)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,512]") == 16 * 512 * 4
    assert _shape_bytes("bf16[256,512]") == 256 * 512 * 2
    assert _shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert _shape_bytes("pred[8]") == 8
    assert _shape_bytes("token[]") == 0


def test_parse_collectives():
    colls = parse_collectives(HLO)
    assert colls["all-gather"]["count"] == 1
    assert colls["all-gather"]["operand_bytes"] == 16 * 512 * 4
    assert colls["all-gather"]["result_bytes"] == 256 * 512 * 4
    assert colls["all-reduce"]["operand_bytes"] == 256 * 512 * 2
    assert colls["all-to-all"]["count"] == 1
    assert colls["reduce-scatter"]["operand_bytes"] == 256 * 512 * 2
    assert colls["collective-permute"]["count"] == 1
    total = sum(v["operand_bytes"] for v in colls.values())
    assert total > 0


def test_roofline_terms_and_dominant():
    terms = roofline_terms(
        flops=256 * PEAK_FLOPS,          # exactly 1 s of compute on 256 chips
        bytes_accessed=256 * HBM_BW * 2,  # 2 s of HBM
        collective_bytes=256 * ICI_BW * 0.5,
        n_chips=256,
    )
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(2.0)
    assert terms["collective_s"] == pytest.approx(0.5)
    assert dominant_term(terms) == "memory"
