"""Chaos + resilience suite (ISSUE 10, DESIGN.md §17): the failure
taxonomy, the graceful-degradation ladder (tile shrink → backend demotion →
reference), the persistent circuit breaker, strict mode, runtime output
verification, and seeded dispatch-level fault injection — everything the
host CI can prove about surviving kernel failures without a TPU."""

import json
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import ops
from repro.core.identifiers import EvenSpec
from repro.core.pipeline import autotune as at
from repro.core.pipeline import clear_tile_cache, set_autotune
from repro.kernels import ops as kops
from repro.runtime import resilience as rz
from repro.runtime.supervisor import FaultInjector, Supervisor, TrainLoopConfig

N = 1024
M = 8
FAULT_RATE = 0.05

BACKENDS = ("reference", "vmap", "pallas-interpret", "pallas")


def _spec(m=M):
    return EvenSpec(0.0, float(1 << 20), m)


def _keys(n=N, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, 1 << 20, n, dtype=np.uint32))


@pytest.fixture(autouse=True)
def iso(tmp_path):
    """Every test runs against a throwaway quarantine/autotune directory
    with clean counters, no injector, and default strict/verify."""
    prev = at._CONFIG
    set_autotune(cache_dir=str(tmp_path))
    rz.clear_quarantine(disk=True)
    rz.reset_stats()
    rz.set_fault_injector(None)
    rz.set_strict(None)
    rz.set_verify(None)
    clear_tile_cache()
    yield tmp_path
    rz.set_fault_injector(None)
    rz.set_strict(None)
    rz.set_verify(None)
    rz.clear_quarantine(disk=True)
    rz.reset_stats()
    at._CONFIG = prev
    at._LOADED = None
    clear_tile_cache()


# ---------------------------------------------------------------------------
# Failure taxonomy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("exc,cls", [
    (RuntimeError("RESOURCE_EXHAUSTED: out of memory allocating VMEM scratch"),
     rz.KernelResourceError),
    (MemoryError("oom"), rz.KernelResourceError),
    (RuntimeError("Mosaic lowering failed: unsupported primitive"),
     rz.KernelLoweringError),
    (NotImplementedError("no kernel for this"), rz.KernelLoweringError),
    (RuntimeError("UNAVAILABLE: transient backend interruption"),
     rz.TransientDispatchError),
    (RuntimeError("DEADLINE_EXCEEDED: preempted"), rz.TransientDispatchError),
    (RuntimeError("something else entirely"), rz.KernelDispatchError),
    (OSError("device file vanished"), rz.KernelDispatchError),
])
def test_classify_taxonomy(exc, cls):
    err = rz.classify(exc, backend="pallas", plan_class=("s", (N,)))
    assert type(err) is cls
    assert err.original is exc and err.__cause__ is exc
    assert err.backend == "pallas"
    assert err.transient == (cls is rz.TransientDispatchError)


def test_classify_programming_errors_propagate():
    """Validation errors are caller bugs, not execution failures."""
    assert rz.classify(ValueError("keys must be rank-1")) is None
    assert rz.classify(TypeError("expected a BucketSpec")) is None
    assert rz.classify(KeyError("nope")) is None
    # ...unless the message proves a kernel-side failure
    assert isinstance(rz.classify(ValueError("mosaic lowering rejected op")),
                      rz.KernelLoweringError)


def test_classify_word_boundary_markers():
    """'oom' must not classify 'boom' (the marker is a word, not a
    substring) while 'allocating' still hits the 'allocat' prefix."""
    assert type(rz.classify(RuntimeError("boom"))) is rz.KernelDispatchError
    assert isinstance(rz.classify(RuntimeError("OOM on device 0")),
                      rz.KernelResourceError)
    assert isinstance(rz.classify(RuntimeError("failed allocating 4MiB")),
                      rz.KernelResourceError)


def test_classify_passthrough_and_demote_chain():
    err = rz.KernelLoweringError("x")
    assert rz.classify(err) is err
    chain = []
    b = "pallas"
    while b is not None:
        chain.append(b)
        b = rz.demote(b)
    assert chain == list(rz.DEMOTION_ORDER)
    assert rz.demote("some-future-backend") == "reference"


# ---------------------------------------------------------------------------
# Tentpole acceptance: chaos at rate 0.05 across the backend x layout
# matrix — every facade call returns bitwise-reference-identical results
# with zero unhandled exceptions.
# ---------------------------------------------------------------------------

def _assert_bitwise(got, want):
    for field in got._fields:
        g, w = getattr(got, field), getattr(want, field)
        assert (g is None) == (w is None), field
        if g is not None:
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=field)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kv", [False, True])
def test_chaos_flat_bitwise_identical(backend, kv):
    spec, keys = _spec(), _keys()
    vals = jnp.arange(N, dtype=jnp.int32)
    want = (ops.multisplit_key_value(keys, vals, spec, backend="reference")
            if kv else ops.multisplit(keys, spec, backend="reference"))
    rz.set_fault_injector(FaultInjector(dispatch_rate=FAULT_RATE, seed=3))
    for trial in range(12):
        got = (ops.multisplit_key_value(keys, vals, spec, backend=backend)
               if kv else ops.multisplit(keys, spec, backend=backend))
        _assert_bitwise(got, want)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kv", [False, True])
def test_chaos_segmented_bitwise_identical(backend, kv):
    spec, keys = _spec(), _keys()
    vals = jnp.arange(N, dtype=jnp.int32) if kv else None
    seg = jnp.asarray([0, 100, 100, 700], jnp.int32)   # incl. an empty segment
    want = ops.segmented_multisplit(keys, spec, seg, vals, backend="reference")
    rz.set_fault_injector(FaultInjector(dispatch_rate=FAULT_RATE, seed=5))
    for trial in range(12):
        got = ops.segmented_multisplit(keys, spec, seg, vals, backend=backend)
        _assert_bitwise(got, want)


@pytest.mark.parametrize("backend", ["vmap", "pallas-interpret"])
def test_chaos_batched_vmap_bitwise_identical(backend):
    """Batched layout reaches the plan layer via jax.vmap: the ladder is
    bypassed under tracing (exceptions cannot cross a jit trace), so faults
    never fire inside the trace and results stay bitwise-correct."""
    spec = _spec()
    rng = np.random.RandomState(1)
    keys = jnp.asarray(rng.randint(0, 1 << 20, (4, 256), dtype=np.uint32))
    want = jax.vmap(lambda k: ops.multisplit(k, spec, backend="reference"))(keys)
    rz.set_fault_injector(FaultInjector(dispatch_rate=FAULT_RATE, seed=7))
    got = jax.vmap(lambda k: ops.multisplit(k, spec, backend=backend))(keys)
    _assert_bitwise(got, want)


def test_chaos_verify2_still_bitwise_identical():
    """Faults + full verification together: the ladder heals, the verifier
    never fires (the kernels are honest), results stay reference-exact."""
    spec, keys = _spec(), _keys(seed=11)
    want = ops.multisplit(keys, spec, backend="reference")
    ops.set_verify(2)
    rz.set_fault_injector(FaultInjector(dispatch_rate=FAULT_RATE, seed=13))
    for trial in range(8):
        _assert_bitwise(ops.multisplit(keys, spec, backend="pallas"), want)
    assert rz.stats()["verify_mismatches"] == 0
    assert rz.stats()["verify_checks"] > 0


# ---------------------------------------------------------------------------
# The ladder, rung by rung (driven through rz.dispatch directly)
# ---------------------------------------------------------------------------

def _ctx(**kw):
    base = dict(spec_name="even", shape=(N,), num_buckets=M)
    base.update(kw)
    return rz.DispatchContext(**base)


def test_demotion_order_respected():
    attempts = []

    def run(backend, tile):
        attempts.append(backend)
        if backend != "reference":
            raise RuntimeError("Mosaic lowering failed: unsupported primitive")
        return "ok"

    assert rz.dispatch(run, _ctx(), backend="pallas") == "ok"
    assert attempts == list(rz.DEMOTION_ORDER)
    s = rz.stats()
    assert s["backend_demotions"] == 3 and s["degradations"] == 3


def test_resource_error_halves_tile_and_pins_survivor():
    tried, pinned = [], []

    def run(backend, tile):
        tried.append((backend, tile))
        if tile is None or tile > 512:
            raise RuntimeError("RESOURCE_EXHAUSTED: out of memory in VMEM")
        return "ok"

    out = rz.dispatch(run, _ctx(), backend="pallas",
                      resolved_tile=lambda b: 2048,
                      pin_tile=lambda b, t: pinned.append((b, t)))
    assert out == "ok"
    assert tried == [("pallas", None), ("pallas", 1024), ("pallas", 512)]
    assert pinned == [("pallas", 512)]
    s = rz.stats()
    assert s["tile_shrinks"] == 2 and s["backend_demotions"] == 0


def test_resource_error_demotes_below_min_tile():
    """When the shrink ladder bottoms out, the rung demotes like any other
    persistent failure."""
    def run(backend, tile):
        if backend == "pallas":
            raise RuntimeError("RESOURCE_EXHAUSTED: out of memory in VMEM")
        return backend

    out = rz.dispatch(run, _ctx(), backend="pallas",
                      resolved_tile=lambda b: 512)
    assert out == "pallas-interpret"
    s = rz.stats()
    assert s["tile_shrinks"] == 1 and s["backend_demotions"] == 1


def test_transient_retries_in_place_then_demotes():
    calls = {"pallas": 0}

    def run(backend, tile):
        if backend == "pallas":
            calls["pallas"] += 1
            raise RuntimeError("UNAVAILABLE: transient link flap")
        return backend

    out = rz.dispatch(run, _ctx(), backend="pallas")
    assert out == "pallas-interpret"
    assert calls["pallas"] == 1 + rz.MAX_TRANSIENT_RETRIES
    assert rz.stats()["transient_retries"] == rz.MAX_TRANSIENT_RETRIES


def test_transient_recovery_no_demotion():
    calls = {"n": 0}

    def run(backend, tile):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("UNAVAILABLE: preempted")
        return backend

    assert rz.dispatch(run, _ctx(), backend="pallas") == "pallas"
    assert rz.stats()["backend_demotions"] == 0


def test_programming_error_propagates_on_every_rung():
    def run(backend, tile):
        raise ValueError("keys must be rank-1")

    with pytest.raises(ValueError, match="rank-1"):
        rz.dispatch(run, _ctx(), backend="pallas")
    assert rz.stats()["degradations"] == 0


def test_reference_failure_propagates():
    def run(backend, tile):
        raise RuntimeError("Mosaic lowering failed everywhere")

    with pytest.raises(RuntimeError):
        rz.dispatch(run, _ctx(), backend="reference")


# ---------------------------------------------------------------------------
# Circuit breaker + persistent quarantine
# ---------------------------------------------------------------------------

def _always_lowering(backend, tile):
    if backend == "pallas":
        raise RuntimeError("Mosaic lowering failed: unsupported primitive")
    return backend


def test_breaker_trips_after_threshold_then_skips_statically():
    ctx = _ctx()
    for i in range(rz.BREAKER_THRESHOLD):
        assert rz.dispatch(_always_lowering, ctx, backend="pallas") \
            == "pallas-interpret"
    s = rz.stats()
    assert s["breaker_trips"] == 1 and s["quarantine_skips"] == 0

    attempts = []

    def spy(backend, tile):
        attempts.append(backend)
        return _always_lowering(backend, tile)

    assert rz.dispatch(spy, ctx, backend="pallas") == "pallas-interpret"
    assert attempts == ["pallas-interpret"]        # pallas never attempted
    assert rz.stats()["quarantine_skips"] == 1


def test_breaker_keys_are_per_plan_class():
    for i in range(rz.BREAKER_THRESHOLD):
        rz.dispatch(_always_lowering, _ctx(), backend="pallas")
    other = _ctx(shape=(2 * N,))
    key_hit = rz.class_key(_ctx().plan_class(), "pallas")
    key_other = rz.class_key(other.plan_class(), "pallas")
    assert rz.is_quarantined(key_hit) and not rz.is_quarantined(key_other)


def test_quarantine_survives_clear_tile_cache_roundtrip(iso):
    """The acceptance round-trip: plain clear_tile_cache() drops only the
    in-memory view — the disk sidecar rehydrates the quarantine like a
    fresh process against a warm cache file; disk=True deletes it."""
    ctx = _ctx()
    for i in range(rz.BREAKER_THRESHOLD):
        rz.dispatch(_always_lowering, ctx, backend="pallas")
    key = rz.class_key(ctx.plan_class(), "pallas")
    assert rz.is_quarantined(key)
    path = rz.quarantine_path()
    assert path.exists() and str(path).startswith(str(iso))
    raw = json.loads(path.read_text())
    assert raw["version"] == rz.SCHEMA_VERSION and key in raw["entries"]

    clear_tile_cache()                    # memory dropped, disk kept
    assert not rz.breaker_strikes()
    assert rz.is_quarantined(key)         # rehydrated from disk
    assert key in rz.quarantine_snapshot()

    clear_tile_cache(disk=True)           # sidecar deleted too
    assert not rz.is_quarantined(key)
    assert not path.exists()


def test_quarantine_unwritable_dir_degrades_to_memory():
    set_autotune(cache_dir="/proc/definitely/not/writable")
    at._LOADED = None
    rz.drop_loaded()
    rz.quarantine("some|key", "reason")   # must not raise
    assert rz.is_quarantined("some|key")


# ---------------------------------------------------------------------------
# Strict mode
# ---------------------------------------------------------------------------

def test_strict_reraises_original():
    ops.set_strict(True)
    boom = RuntimeError("Mosaic lowering failed: unsupported primitive")

    def run(backend, tile):
        raise boom

    with pytest.raises(RuntimeError) as ei:
        rz.dispatch(run, _ctx(), backend="pallas")
    assert ei.value is boom               # the ORIGINAL, unwrapped
    assert rz.stats()["degradations"] == 0


def test_strict_env_var(monkeypatch):
    monkeypatch.setenv("REPRO_STRICT", "1")
    rz.set_strict(None)                   # defer to the environment
    assert rz.strict()

    def run(backend, tile):
        raise RuntimeError("RESOURCE_EXHAUSTED: out of memory")

    with pytest.raises(RuntimeError):
        rz.dispatch(run, _ctx(), backend="pallas", resolved_tile=lambda b: 2048)


def test_strict_facade_reraises_injected_fault():
    spec, keys = _spec(), _keys()
    ops.multisplit(keys, spec, backend="vmap")       # warm the plan cache
    ops.set_strict(True)
    inj = FaultInjector(dispatch_rate=0.999999, seed=0)
    rz.set_fault_injector(inj)
    with pytest.raises(RuntimeError, match="injected dispatch fault"):
        ops.multisplit(keys, spec, backend="vmap")
    assert inj.dispatch_injected == 1


# ---------------------------------------------------------------------------
# Runtime verification
# ---------------------------------------------------------------------------

def test_verify_level1_catches_count_tampering():
    spec, keys = _spec(), _keys()
    good = ops.multisplit(keys, spec, backend="vmap")
    rz.verify_result(good, keys=keys, spec=spec, n=N, level=2)   # clean passes
    bad_counts = np.asarray(good.bucket_counts).copy()
    bad_counts[0] += 1
    with pytest.raises(rz.KernelResultError, match="conservation"):
        rz.verify_result(good._replace(bucket_counts=jnp.asarray(bad_counts)),
                         keys=keys, spec=spec, n=N, level=1)
    bad_starts = np.asarray(good.bucket_starts).copy()
    bad_starts[-1] -= 1
    with pytest.raises(rz.KernelResultError, match="monotonicity"):
        rz.verify_result(good._replace(bucket_starts=jnp.asarray(bad_starts)),
                         keys=keys, spec=spec, n=N, level=1)


def test_verify_level2_catches_key_and_perm_tampering():
    spec, keys = _spec(), _keys()
    good = ops.multisplit(keys, spec, backend="vmap")
    swapped = np.asarray(good.keys).copy()
    swapped[[0, -1]] = swapped[[-1, 0]]              # breaks bucket order
    with pytest.raises(rz.KernelResultError):
        rz.verify_result(good._replace(keys=jnp.asarray(swapped)),
                         keys=keys, spec=spec, n=N, level=2)
    bad_perm = np.asarray(good.permutation).copy()
    bad_perm[0] = bad_perm[1]                        # no longer a permutation
    with pytest.raises(rz.KernelResultError, match="permutation"):
        rz.verify_result(good._replace(permutation=jnp.asarray(bad_perm)),
                         keys=keys, spec=spec, n=N, level=2)


def test_verify_segmented_segment_local_invariants():
    spec, keys = _spec(), _keys()
    seg = jnp.asarray([0, 100, 700], jnp.int32)
    good = ops.segmented_multisplit(keys, spec, seg, backend="vmap")
    rz.verify_result(good, keys=keys, spec=spec, n=N, segment_starts=seg,
                     level=2)
    bad = np.asarray(good.bucket_counts).copy()
    bad[1, 0] += 1                                   # breaks one segment's sum
    with pytest.raises(rz.KernelResultError, match="segment"):
        rz.verify_result(good._replace(bucket_counts=jnp.asarray(bad)),
                         keys=keys, spec=spec, n=N, segment_starts=seg, level=1)


def test_verify2_recovers_corrupted_backend_via_reference(monkeypatch):
    """The acceptance scenario: a lying backend (monkeypatched to corrupt
    its output) is caught at REPRO_VERIFY=2, the call transparently
    returns the reference answer, and a structured repro report exists."""
    spec, keys = _spec(), _keys()
    want = ops.multisplit(keys, spec, backend="reference")
    real_flat_op = ops._flat_op

    def corrupting_flat_op(spec_, n_, method_, backend_, tile_, mode_, family_):
        inner = real_flat_op(spec_, n_, method_, backend_, tile_, mode_, family_)
        if backend_ == "reference":
            return inner

        def corrupted(k):
            r = inner(k)
            return r._replace(keys=r.keys[::-1])     # silent wrong answer
        return corrupted

    monkeypatch.setattr(ops, "_flat_op", corrupting_flat_op)
    ops.set_verify(2)
    got = ops.multisplit(keys, spec, backend="vmap")
    _assert_bitwise(got, want)                       # healed via reference
    s = rz.stats()
    assert s["verify_mismatches"] == 1 and s["reference_reruns"] == 1
    report = rz.last_report()
    assert report is not None
    assert report["backend"] == "vmap" and report["shape"] == (N,)
    assert report["spec"] == getattr(spec, "name", type(spec).__name__)
    assert report["num_buckets"] == M


def test_verify_strict_raises_instead_of_recovering():
    ops.set_strict(True)
    ops.set_verify(2)

    def run(backend, tile):
        spec, keys = _spec(), _keys()
        r = ops.multisplit(keys, spec, backend="reference")
        return r._replace(keys=r.keys[::-1])

    def verifier(result, backend):
        spec, keys = _spec(), _keys()
        rz.verify_result(result, keys=keys, spec=spec, n=N, backend=backend)

    with pytest.raises(rz.KernelResultError):
        rz.dispatch(run, _ctx(), backend="vmap", verifier=verifier)


def test_verify_routing_invariants():
    from repro.models.moe import route_tokens_segmented

    ids = jnp.asarray(np.random.RandomState(0).randint(0, 4, 64, dtype=np.int64)
                      .astype(np.int32))
    starts = jnp.asarray([0, 16, 48], jnp.int32)
    out = route_tokens_segmented(ids, starts, 4, 8, backend="vmap")
    rz.verify_routing(out, ids, starts, 4, 8, level=2)           # clean passes
    slot, keep, counts = out
    bad_counts = np.asarray(counts).copy()
    bad_counts[0, 0] += 1
    with pytest.raises(rz.KernelResultError, match="conservation"):
        rz.verify_routing((slot, keep, jnp.asarray(bad_counts)), ids, starts,
                          4, 8, level=1)
    bad_keep = np.asarray(keep).copy()
    flip = int(np.flatnonzero(bad_keep)[0])
    bad_keep[flip] = 0
    with pytest.raises(rz.KernelResultError):
        rz.verify_routing((slot, jnp.asarray(bad_keep), counts), ids, starts,
                          4, 8, level=2)


def test_set_verify_validation_and_env(monkeypatch):
    with pytest.raises(ValueError):
        ops.set_verify(3)
    ops.set_verify(2)
    assert rz.verify_level() == 2
    ops.set_verify(None)
    monkeypatch.setenv("REPRO_VERIFY", "2")
    assert rz.verify_level() == 2
    monkeypatch.setenv("REPRO_VERIFY", "true")
    assert rz.verify_level() == 1
    monkeypatch.setenv("REPRO_VERIFY", "garbage")
    assert rz.verify_level() == 0


# ---------------------------------------------------------------------------
# Registry capability summary (tentpole observability)
# ---------------------------------------------------------------------------

def test_capability_summary_exposes_resilience():
    from repro.core.pipeline.registry import capability_summary

    ops.set_verify(1)
    s = capability_summary()
    assert set(s["backends"]) == set(BACKENDS)
    assert s["backends"]["pallas"]["demotes_to"] == "pallas-interpret"
    assert s["backends"]["reference"]["demotes_to"] is None
    r = s["resilience"]
    assert r["verify"] == 1 and r["strict"] is False
    assert tuple(r["demotion_order"]) == rz.DEMOTION_ORDER
    assert r["breaker_threshold"] == rz.BREAKER_THRESHOLD
    assert set(r["counters"]) == set(rz._COUNTER_KEYS)


# ---------------------------------------------------------------------------
# S1: REPRO_INTERPRET unrecognized-value warning (once per value)
# ---------------------------------------------------------------------------

def test_interpret_env_unrecognized_warns_once(monkeypatch):
    monkeypatch.setenv("REPRO_INTERPRET", "ture")    # the classic typo
    monkeypatch.setattr(kops, "_WARNED_INTERPRET", set())
    with pytest.warns(RuntimeWarning, match="unrecognized REPRO_INTERPRET"):
        assert kops.resolve_interpret(True) is True  # treated as unset, no TPU
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        kops.resolve_interpret(True)                 # same value: silent
    assert not caught
    monkeypatch.setenv("REPRO_INTERPRET", "yse")     # NEW typo warns again
    with pytest.warns(RuntimeWarning):
        kops.resolve_interpret(True)


def test_interpret_env_recognized_values_silent(monkeypatch):
    monkeypatch.setattr(kops, "_WARNED_INTERPRET", set())
    for val, expect in (("1", True), ("true", True), ("0", False),
                        ("no", False), ("", None)):
        monkeypatch.setenv("REPRO_INTERPRET", val)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            got = kops.resolve_interpret(True)
        assert not caught, val
        if expect is not None:
            assert got is expect


# ---------------------------------------------------------------------------
# S2: supervisor — seeded capped backoff + taxonomy-aware retry skip
# ---------------------------------------------------------------------------

def _toy_step(state, batch):
    return {"w": state["w"] + batch}, {"loss": jnp.asarray(0.0)}


def _sup(tmp_path, *, step=None, faults=None, sleeps=None, **cfg_kw):
    cfg = dict(total_steps=4, checkpoint_every=2, checkpoint_dir=str(tmp_path),
               max_retries_per_step=2, max_restores=2, log_every=100)
    cfg.update(cfg_kw)
    return Supervisor(
        step or _toy_step, lambda s: jnp.asarray(1.0), TrainLoopConfig(**cfg),
        fault_injector=faults,
        sleep_fn=(sleeps.append if sleeps is not None else (lambda dt: None)),
    )


def test_backoff_between_retries_seeded_and_capped(tmp_path):
    sleeps = []
    sup = _sup(tmp_path, faults=FaultInjector(fail_at={1: 2}), sleeps=sleeps)
    sup.run({"w": jnp.asarray(0.0)})
    cfg = sup.cfg
    assert len(sleeps) == 2                          # one per failed attempt
    for i, dt in enumerate(sleeps):
        hi = min(cfg.retry_backoff_cap, cfg.retry_backoff_base * 2 ** i) * 1.5
        assert 0.0 < dt <= hi
    # deterministic: the same seed replays the same backoff schedule
    sleeps2 = []
    sup2 = _sup(tmp_path / "b", faults=FaultInjector(fail_at={1: 2}),
                sleeps=sleeps2)
    sup2.run({"w": jnp.asarray(0.0)})
    assert sleeps2 == sleeps


def test_backoff_never_exceeds_cap(tmp_path):
    sup = _sup(tmp_path, max_retries_per_step=8)
    for attempt in range(32):
        assert 0.0 < sup._backoff(attempt) <= sup.cfg.retry_backoff_cap * 1.5


def test_persistent_lowering_skips_straight_to_restore(tmp_path):
    """A Mosaic-style persistent failure must not burn the retry budget:
    no backoff sleeps, one attempt per restore cycle."""
    def bad_step(state, batch):
        raise NotImplementedError("unsupported primitive in kernel body")

    sleeps = []
    sup = _sup(tmp_path, step=bad_step, sleeps=sleeps, max_restores=1)
    with pytest.raises(RuntimeError, match="budgets exhausted"):
        sup.run({"w": jnp.asarray(0.0)})
    assert sleeps == []                              # retries were skipped
    assert sup.stats["retries"] == sup.stats["restores"]  # 1 attempt per cycle


def test_transient_fault_still_uses_retry_budget(tmp_path):
    """Generic/transient step failures keep the pre-§17 retry behavior."""
    sleeps = []
    sup = _sup(tmp_path, faults=FaultInjector(fail_at={2: 1}), sleeps=sleeps)
    sup.run({"w": jnp.asarray(0.0)})
    assert sup.stats["retries"] == 1 and sup.stats["restores"] == 0
    assert len(sleeps) == 1
