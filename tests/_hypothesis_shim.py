"""Minimal deterministic stand-in for the ``hypothesis`` API our tests use.

The container image does not ship ``hypothesis`` (and we must not install
packages). When the real library is absent, ``conftest.py`` registers this
module as ``hypothesis`` so the property tests still *run* — each ``@given``
test executes ``max_examples`` deterministic seeded draws instead of
hypothesis's adaptive search. No shrinking, no database: strictly a fallback
so the tier-1 suite collects and exercises the properties. With the real
dependency installed (see requirements.txt) this file is never imported.

Only the surface used in this repo is implemented:
``given`` (positional or keyword strategies), ``settings(max_examples,
deadline)``, ``strategies.integers``, ``strategies.lists``,
``strategies.sampled_from``, ``strategies.booleans``, ``strategies.tuples``.
"""

from __future__ import annotations

import functools
import types

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng) -> object:
        return self._draw(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    span = max_value - min_value
    # RandomState.randint is bounded at int64; draw via uniform for huge spans.
    def draw(rng):
        return min_value + int(rng.randint(0, span + 1, dtype=np.int64)) if span < 2**62 \
            else min_value + int(rng.random_sample() * span)

    return _Strategy(draw)


def _lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        size = int(rng.randint(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(size)]

    return _Strategy(draw)


def _sampled_from(elements) -> _Strategy:
    choices = list(elements)
    if not choices:
        raise ValueError("sampled_from requires a non-empty collection")

    def draw(rng):
        return choices[int(rng.randint(0, len(choices)))]

    return _Strategy(draw)


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.randint(0, 2)))


def _tuples(*element_strategies: _Strategy) -> _Strategy:
    def draw(rng):
        return tuple(s.example(rng) for s in element_strategies)

    return _Strategy(draw)


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.lists = _lists
strategies.sampled_from = _sampled_from
strategies.booleans = _booleans
strategies.tuples = _tuples


_DEFAULT_MAX_EXAMPLES = 10


def given(*pos_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_settings", {}).get(
                "max_examples", _DEFAULT_MAX_EXAMPLES
            )
            for i in range(n):
                rng = np.random.RandomState(1_000_003 * i + 17)
                drawn_pos = tuple(s.example(rng) for s in pos_strategies)
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn_pos, **drawn_kw, **kwargs)

        # Strategy-bound parameters are filled by the wrapper, not by pytest
        # fixtures — hide the original signature from collection.
        import inspect as _inspect

        del wrapper.__wrapped__
        wrapper.__signature__ = _inspect.Signature(parameters=[])
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_settings = {"max_examples": max_examples}
        return fn

    return deco
