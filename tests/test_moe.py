"""MoE dispatch: the three modes must agree; drops must be stable-consistent."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe
from repro.parallel.sharding import init_params


def _cfg(dispatch="multisplit", e=8, k=2, capf=4.0):
    return ModelConfig(
        name="t", family="moe", n_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=128, vocab=128, dtype="float32",
        moe=MoEConfig(num_experts=e, top_k=k, dispatch=dispatch, capacity_factor=capf),
    )


@pytest.mark.parametrize("e,k", [(8, 1), (8, 2), (16, 4)])
def test_dispatch_modes_agree(e, k):
    cfg = _cfg(e=e, k=k)
    params = init_params(moe.moe_decl(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.float32)
    outs = {}
    for disp in ("multisplit", "sort", "dense"):
        c = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch=disp))
        y, aux = moe.moe_block(params, x, c)
        outs[disp] = np.asarray(y)
        assert np.isfinite(outs[disp]).all()
    np.testing.assert_array_equal(outs["multisplit"], outs["sort"])  # bit-identical
    np.testing.assert_allclose(outs["multisplit"], outs["dense"], atol=1e-4)


def test_capacity_drops_identical_between_sort_and_multisplit():
    """Both are STABLE -> the dropped token set must be identical."""
    cfg = _cfg(capf=0.5)   # force drops
    params = init_params(moe.moe_decl(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 64), jnp.float32)
    y_ms, aux_ms = moe.moe_block(params, x, _cfg("multisplit", capf=0.5))
    y_srt, aux_srt = moe.moe_block(params, x, _cfg("sort", capf=0.5))
    assert float(aux_ms.drop_fraction) > 0
    assert float(aux_ms.drop_fraction) == float(aux_srt.drop_fraction)
    np.testing.assert_array_equal(np.asarray(y_ms), np.asarray(y_srt))


def test_ranks_multisplit_vs_sort():
    for seed in range(3):
        ids = jnp.asarray(np.random.RandomState(seed).randint(0, 16, 5000, dtype=np.int32))
        r_ms, c_ms = moe._ranks_multisplit(ids, 16)
        r_srt, c_srt = moe._ranks_sort(ids, 16)
        np.testing.assert_array_equal(np.asarray(r_ms), np.asarray(r_srt))
        np.testing.assert_array_equal(np.asarray(c_ms), np.asarray(c_srt))


def test_shared_expert():
    cfg = dataclasses.replace(
        _cfg(), moe=dataclasses.replace(_cfg().moe, shared_expert=True)
    )
    params = init_params(moe.moe_decl(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64), jnp.float32)
    y, _ = moe.moe_block(params, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_aux_losses_reasonable():
    cfg = _cfg()
    params = init_params(moe.moe_decl(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.float32)
    _, aux = moe.moe_block(params, x, cfg)
    # balanced-ish routing at init: load-balance loss ~= 1, z-loss finite
    assert 0.5 < float(aux.load_balance) < 4.0
    assert np.isfinite(float(aux.router_z))
