"""Fused two-digit radix passes (DESIGN.md §13): the pairing schedule,
bitwise identity of fused vs chained vs per-pass execution on every backend
and layout, the fused2 stage strings/sweep counts, and the recorded
label-fusion decisions (ISSUE 6).

The whole feature is a COST transform: ``fuse_digits=True`` must never
change a single output bit anywhere — the LSD identity (two chained stable
passes over digits (lo, hi) == one stable pass over the combined
``hi·2^r_lo + lo`` bitfield) is what every equivalence test here pins, on
uniform keys, adversarial all-one-bucket keys, odd/partial bit schedules
(r=7 → 4×7+4, r=5 → 6×5+2), key-only and key-value, flat/batched/segmented.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.pipeline import (
    RadixPipeline,
    clear_tile_cache,
    fusion_decision,
    get_backend,
    radix_pass_pairs,
    radix_passes,
)
from repro.core.pipeline.radix import MAX_PAIR_BITS
from repro.core.sort import radix_sort, radix_sort_per_pass, segmented_radix_sort

TILED_BACKENDS = ("vmap", "pallas-interpret")
ALL_BACKENDS = ("reference",) + TILED_BACKENDS


def _keys(n, seed=0, hi=2**32, dtype=np.uint32):
    return jnp.asarray(
        np.random.RandomState(seed % (2**31 - 1)).randint(0, hi, n).astype(dtype)
    )


# ---------------------------------------------------------------------------
# The pairing schedule: greedy adjacent merge with a trailing single
# ---------------------------------------------------------------------------

def test_radix_pass_pairs_even_schedule():
    # r=8 over 32-bit keys: four digits -> two 16-bit pairs
    assert radix_pass_pairs(8, 32) == [(0, 16, 8), (16, 16, 8)]


def test_radix_pass_pairs_trailing_single():
    # r=7: 4x7 + 4 -> two 14-bit pairs + the odd 4-bit digit runs UNPAIRED
    assert radix_pass_pairs(7, 32) == [(0, 14, 7), (14, 14, 7), (28, 4, None)]
    # r=5: 6x5 + 2 -> three 10-bit pairs + an unpaired 2-bit tail
    assert radix_pass_pairs(5, 32) == [
        (0, 10, 5), (10, 10, 5), (20, 10, 5), (30, 2, None)]


def test_radix_pass_pairs_uneven_tail_pair():
    # r=4 over 30-bit keys ends in a 4+2 pair
    assert radix_pass_pairs(4, 30)[-1] == (24, 6, 4)


def test_radix_pass_pairs_width_ceiling():
    # a pair that would exceed max_pair_bits stays two singles
    assert radix_pass_pairs(12, 24) == [(0, 12, None), (12, 12, None)]
    assert radix_pass_pairs(8, 32, max_pair_bits=8) == [
        (s, b, None) for s, b in radix_passes(8, 32)]
    assert MAX_PAIR_BITS == 16


def test_radix_pass_pairs_covers_every_bit_once():
    for r in range(2, 13):
        for kb in (24, 30, 32):
            covered = []
            for shift, bits, split in radix_pass_pairs(r, kb):
                covered.extend(range(shift, shift + bits))
                if split is not None:
                    assert 0 < split < bits
            assert covered == list(range(kb)), (r, kb)


# ---------------------------------------------------------------------------
# Bitwise identity: fused == chained == per-pass, everywhere
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("radix_bits", [8, 7, 5])
def test_fused_bitwise_identical_flat_kv(backend, radix_bits):
    n = 20000 if backend != "reference" else 2500
    keys = _keys(n, seed=radix_bits)
    vals = jnp.arange(n, dtype=jnp.int32)
    kf, vf = radix_sort(keys, vals, radix_bits=radix_bits, backend=backend,
                        fuse_digits=True)
    kc, vc = radix_sort(keys, vals, radix_bits=radix_bits, backend=backend,
                        fuse_digits=False)
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(kc))
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vc))
    if backend != "reference":
        kp, vp = radix_sort_per_pass(keys, vals, radix_bits=radix_bits,
                                     backend=backend)
        np.testing.assert_array_equal(np.asarray(kf), np.asarray(kp))
        np.testing.assert_array_equal(np.asarray(vf), np.asarray(vp))


@pytest.mark.parametrize("backend", TILED_BACKENDS)
def test_fused_segmented_kv(backend):
    n = 12000
    keys = _keys(n, seed=11)
    vals = jnp.arange(n, dtype=jnp.int32)
    starts = jnp.asarray([0, 7, 7, 900, 11000], jnp.int32)  # empty seg included
    kf, vf = segmented_radix_sort(keys, starts, vals, radix_bits=8,
                                  backend=backend, fuse_digits=True)
    kc, vc = segmented_radix_sort(keys, starts, vals, radix_bits=8,
                                  backend=backend, fuse_digits=False)
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(kc))
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vc))


def test_fused_batched_rows_sort_independently():
    keys = _keys(3 * 5000, seed=13).reshape(3, 5000)
    kf, _ = radix_sort(keys, radix_bits=8, backend="vmap", fuse_digits=True)
    kc, _ = radix_sort(keys, radix_bits=8, backend="vmap", fuse_digits=False)
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(kc))


def test_fused_adversarial_single_pair_bucket():
    # every key lands in ONE combined pair bucket in every sweep — the
    # in-tile LSD sweep degenerates to identity stages; pads must still
    # sort to the tail (the all-ones sentinel shares no bucket only if the
    # constant differs from it, so test both)
    for const in (0xDEADBEEF, 0xFFFFFFFF):
        ka = jnp.full((9000,), np.uint32(const))
        kf, _ = radix_sort(ka, radix_bits=8, backend="vmap", fuse_digits=True)
        np.testing.assert_array_equal(np.asarray(kf), np.asarray(ka))


@given(
    st.integers(min_value=1, max_value=6000),
    st.integers(min_value=0, max_value=2**31 - 1),
    st.sampled_from([8, 7, 5, 4]),
    st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_fused_equals_chained_property(n, seed, radix_bits, key_value):
    keys = _keys(n, seed=seed)
    vals = jnp.arange(n, dtype=jnp.int32) if key_value else None
    kf, vf = radix_sort(keys, vals, radix_bits=radix_bits, backend="vmap",
                        fuse_digits=True)
    kc, vc = radix_sort(keys, vals, radix_bits=radix_bits, backend="vmap",
                        fuse_digits=False)
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(kc))
    if key_value:
        np.testing.assert_array_equal(np.asarray(vf), np.asarray(vc))


# ---------------------------------------------------------------------------
# Schedule/stage introspection: sweeps halve, stage strings mark the pairs
# ---------------------------------------------------------------------------

def test_fused_pipeline_sweep_counts_and_stages():
    p = RadixPipeline(1 << 16, radix_bits=8, backend="vmap", fuse_digits=True)
    assert p.n_passes == 4            # logical digits: schedule-invariant
    assert p.n_sweeps == 2            # executed sweeps: one per pair
    assert p.schedule == [(0, 16, 8), (16, 16, 8)]
    st_ = p.plans[0].stages()
    assert st_[0].startswith("prescan:fused2-pair-")
    assert any(s.startswith("postscan:fused2-pair-reorder-") for s in st_)
    # odd schedule: the r=7 trailing 4-bit digit stays a single sweep
    p7 = RadixPipeline(1 << 16, radix_bits=7, backend="vmap", fuse_digits=True)
    assert p7.n_passes == 5 and p7.n_sweeps == 3
    assert p7.schedule[-1] == (28, 4, None)


def test_fused_flag_is_inert_on_non_fusing_backends():
    # the untiled oracle keeps the single-digit schedule: a pair-wide direct
    # solve would be O(n*m^2) with nothing to save
    p = RadixPipeline(4096, radix_bits=8, backend="reference", fuse_digits=True)
    assert p.n_sweeps == p.n_passes == 4
    assert all(split is None for _, _, split in p.schedule)
    assert not get_backend("reference").fuses_digits
    assert get_backend("vmap").fuses_digits


def test_fused_tile_resolves_large():
    # a pair's G traffic is L*m^2 words: the digits=2 heuristic must grow
    # the tile far past the single-digit base so L stays small
    p = RadixPipeline(1 << 18, radix_bits=8, backend="vmap", fuse_digits=True)
    p1 = RadixPipeline(1 << 18, radix_bits=8, backend="vmap", fuse_digits=False)
    assert p.tile >= 16 * p1.tile


# ---------------------------------------------------------------------------
# Label-fusion decisions (ISSUE 6 satellite): measured threshold + reasons
# ---------------------------------------------------------------------------

def test_label_fusion_threshold_is_recorded_with_reason():
    from repro import ops

    clear_tile_cache()
    keys = _keys(4096, seed=17, hi=2**30)
    ops.multisplit(keys, ops.delta_buckets(256, 2**30), backend="vmap")
    ops.multisplit(keys, ops.delta_buckets(512, 2**30), backend="vmap")
    fused, why = fusion_decision("vmap", "DeltaSpec", 256)
    assert fused and "m_eff=256" in why
    unfused, why512 = fusion_decision("vmap", "DeltaSpec", 512)
    assert not unfused and "re-evaluate" in why512
    # the radix digit NEVER materializes, at any width, on any fusing backend
    ops.radix_sort(keys, radix_bits=8, backend="vmap")
    fused_rx, why_rx = fusion_decision("vmap", "BitfieldSpec", 256)
    assert fused_rx and "shift-and-mask" in why_rx


def test_label_fusion_decision_respects_backend():
    from repro import ops

    clear_tile_cache()
    keys = _keys(4096, seed=19, hi=2**30)
    # kernel backends keep fusing at every width: labels live in-register
    ops.multisplit(keys, ops.delta_buckets(512, 2**30), backend="pallas-interpret")
    fused, why = fusion_decision("pallas-interpret", "DeltaSpec", 512)
    assert fused and "in-register" in why
