"""Self-tuning layer (ISSUE 7, DESIGN.md §14): autotune-on-first-miss, the
persistent on-disk cache (round-trip, corruption, invalidation), the
fused-pair (tile, family, sub_bits) joint search, the measured label-fusion
choice — and the cache-key regression tests (the digits slot that keeps
fused-pair family decisions off digits=1 plans, the stage_m slot that keeps
pair schedules with equal combined m apart)."""

import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.identifiers import EvenSpec
from repro.core.pipeline import (
    clear_tile_cache,
    family_decision,
    fusion_decision,
    make_plan,
    make_radix_plan,
    resolve_kernel_family,
    resolve_tile,
    set_autotune,
)
from repro.core.pipeline import autotune as at
from repro.core.pipeline import tiles

N = 4096
M = 32


def _spec(m=M):
    return EvenSpec(0.0, float(1 << 20), m)


def _keys(n=N, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, 1 << 20, n, dtype=np.uint32))


@pytest.fixture
def armed(tmp_path):
    """Arm autotuning against a throwaway disk cache; restore after."""
    prev = at._CONFIG
    set_autotune(True, cache_dir=str(tmp_path), trials=1,
                 candidates=(256, 1024))
    clear_tile_cache()
    yield tmp_path / "multisplit_autotune.json"
    at._CONFIG = prev
    at._LOADED = None
    clear_tile_cache()


def _disk(path):
    with open(path) as f:
        return json.load(f)


def _disk_kinds(path):
    return sorted({k.split("|")[1] for k in _disk(path)["entries"]})


# ---------------------------------------------------------------------------
# Disarmed default: the layer is inert
# ---------------------------------------------------------------------------

def test_disarmed_by_default_no_search_runs(monkeypatch):
    clear_tile_cache()

    def boom(*a, **kw):                              # pragma: no cover
        raise AssertionError("search ran while autotune is off")

    monkeypatch.setattr(tiles, "autotune_tile", boom)
    monkeypatch.setattr(at, "autotune_fused2", boom)
    monkeypatch.setattr(at, "autotune_label_fusion", boom)
    p = make_plan(N, M, bucket_fn=_spec())
    r = p(_keys())
    assert int(r.bucket_counts.sum()) == N
    fam, reason = family_decision(N, M, "bms", "vmap")
    assert "autotuned" not in reason
    clear_tile_cache()


# ---------------------------------------------------------------------------
# Tentpole: miss -> joint search -> pin + persist -> warm-disk rehydrate
# ---------------------------------------------------------------------------

def test_miss_runs_joint_search_and_persists(armed):
    p = make_plan(N, M, bucket_fn=_spec())
    fam, reason = family_decision(N, M, "bms", "vmap")
    assert "autotuned" in reason
    assert p.tile in (256, 1024)                     # a measured candidate
    data = _disk(armed)
    assert data["version"] == at.SCHEMA_VERSION
    assert {"family", "tile"} <= set(_disk_kinds(armed))
    # the disk key embeds the in-memory cache key verbatim
    fp = at.host_fingerprint()
    assert f"{fp}|tile|{N}|{M}|bms|False|vmap" in data["entries"]


def test_fresh_process_warm_disk_resolves_without_timing(armed, monkeypatch):
    p = make_plan(N, M, bucket_fn=_spec())
    tuned_tile = p.tile
    tuned_fam = p.family

    # simulate a fresh process against the warm cache file
    clear_tile_cache()
    calls = {"n": 0}

    def counting(*a, **kw):
        calls["n"] += 1
        raise AssertionError("timing search ran despite a warm disk cache")

    monkeypatch.setattr(tiles, "autotune_tile", counting)
    monkeypatch.setattr(at, "autotune_fused2", counting)
    p2 = make_plan(N, M, bucket_fn=_spec())
    assert calls["n"] == 0
    assert (p2.tile, p2.family) == (tuned_tile, tuned_fam)
    assert family_decision(N, M, "bms", "vmap")[1] == at._DISK_REASON


def test_corrupt_cache_file_falls_back_to_heuristic(armed):
    armed.write_text("{ not json !!")
    clear_tile_cache()
    # a corrupt file loads as empty: the miss re-searches (trials=1) and
    # REWRITES a valid file rather than erroring
    p = make_plan(N, M, bucket_fn=_spec())
    assert int(p(_keys()).bucket_counts.sum()) == N
    assert _disk(armed)["version"] == at.SCHEMA_VERSION


def test_stale_schema_version_is_ignored(armed):
    armed.parent.mkdir(parents=True, exist_ok=True)
    fp = at.host_fingerprint()
    armed.write_text(json.dumps({
        "version": at.SCHEMA_VERSION + 1,
        "entries": {f"{fp}|tile|{N}|{M}|bms|False|vmap": 64},
    }))
    clear_tile_cache()
    set_autotune(persist=True)
    assert at.lookup("tile", (N, M, "bms", False, "vmap")) is None


def test_clear_tile_cache_disk_deletes_the_file(armed):
    make_plan(N, M, bucket_fn=_spec())
    assert armed.exists()
    clear_tile_cache(disk=True)
    assert not armed.exists()
    assert at._entries() == {}


def test_unwritable_cache_dir_degrades_to_memory_only(armed):
    set_autotune(cache_dir="/proc/definitely/not/writable")
    p = make_plan(N, M, bucket_fn=_spec())             # must not raise
    assert family_decision(N, M, "bms", "vmap")[1].startswith("autotuned")
    assert p.tile in (256, 1024)


def test_set_autotune_snapshot_and_env_arming(monkeypatch):
    cfg = set_autotune()                               # no-op: current state
    assert cfg == at._CONFIG
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    assert at._env_enabled()
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert not at._env_enabled()
    status = at.autotune_status()
    assert {"config", "cache_path", "disk_entries", "fingerprint"} <= set(status)


# ---------------------------------------------------------------------------
# S2 regression: the fused-pair tile key carries stage_m
# ---------------------------------------------------------------------------

def test_fused_tile_key_includes_stage_m():
    k1 = tiles._tile_key(N, 256, "bms", False, "vmap", 2, 16)
    k2 = tiles._tile_key(N, 256, "bms", False, "vmap", 2, 4)
    assert k1 != k2
    # digits=1 keeps the pre-ISSUE-7 5-tuple shape (pinned by older tests)
    assert tiles._tile_key(N, 256, "bms", False, "vmap", 1, None) == (
        N, 256, "bms", False, "vmap"
    )


def test_pair_schedules_same_m_different_stage_m_get_own_tiles():
    clear_tile_cache()
    # two pair schedules with EQUAL combined m=256: 4+4 bits vs 2+6 bits
    t_44 = resolve_tile(1 << 16, 256, "bms", False, "vmap",
                        digits=2, stage_m=16)
    t_26 = resolve_tile(1 << 16, 256, "bms", False, "vmap",
                        digits=2, stage_m=4)
    keys = [k for k in tiles._TILE_CACHE if len(k) == 7]
    assert len(keys) == 2, keys
    assert {k[-1] for k in keys} == {16, 4}
    # both resolve independently afterwards (no cross-contamination)
    assert resolve_tile(1 << 16, 256, "bms", False, "vmap",
                        digits=2, stage_m=16) == t_44
    assert resolve_tile(1 << 16, 256, "bms", False, "vmap",
                        digits=2, stage_m=4) == t_26
    clear_tile_cache()


# ---------------------------------------------------------------------------
# S3 regression: fused-pair family decisions live in their own key slot
# ---------------------------------------------------------------------------

def test_flat_family_pin_does_not_leak_into_fused_pairs():
    clear_tile_cache()
    # heuristic would say "packed" at m=16; pin the digits=1 slot to onehot
    tiles._FAMILY_CACHE[(N, 16, "bms", "vmap")] = ("onehot", "test pin")
    fam2 = resolve_kernel_family(N, 16, "bms", "vmap", digits=2, pair_m=256)
    assert fam2 == "packed"                        # its own (heuristic) call
    assert tiles._FAMILY_CACHE[(N, 16, "bms", "vmap", 2)][0] == "packed"
    clear_tile_cache()


def test_fused_pair_family_pin_does_not_leak_into_flat():
    clear_tile_cache()
    tiles._FAMILY_CACHE[(N, 16, "bms", "vmap", 2)] = ("onehot", "test pin")
    fam1 = resolve_kernel_family(N, 16, "bms", "vmap")
    assert fam1 == "packed"
    assert tiles._FAMILY_CACHE[(N, 16, "bms", "vmap")][0] == "packed"
    clear_tile_cache()


def test_fused_plan_family_isolated_end_to_end():
    clear_tile_cache()
    # stage_m of an 8-bit 4+4 pair is 16: pin the FLAT m=16 class ...
    tiles._FAMILY_CACHE[(N, 16, "bms", "vmap")] = ("onehot", "test pin")
    plan = make_radix_plan(N, 0, 8, digit_split=4)
    # ... and the fused pair still resolves through its own digits=2 slot
    assert plan.family == "packed"
    r = plan(_keys())
    got = np.asarray(r.keys)
    assert np.array_equal(np.sort(got & 0xFF), np.sort(np.asarray(_keys()) & 0xFF))
    assert (np.diff(got & 0xFF) >= 0).all()        # sorted by the low byte
    clear_tile_cache()


# ---------------------------------------------------------------------------
# Fused-pair joint search: tile x family x sub_bits
# ---------------------------------------------------------------------------

def test_fused2_joint_search_pins_all_three_axes(armed):
    out = at.autotune_fused2(
        N, 0, 8, 4, candidates=(1024,), sub_bits_candidates=(4,), trials=1
    )
    assert out == (1024, out[1], 4)
    stage_m = 16
    assert tiles._TILE_CACHE[
        tiles._tile_key(N, 256, "bms", False, "vmap", 2, stage_m)
    ] == 1024
    fam, reason = tiles._FAMILY_CACHE[(N, stage_m, "bms", "vmap", 2)]
    assert fam == out[1] and "autotuned over fused-pair grid" in reason
    assert tiles._SUB_BITS_CACHE[(N, 256, "bms", False, "vmap", stage_m)] == 4
    assert {"family", "sub_bits", "tile"} <= set(_disk_kinds(armed))


def test_radix_plan_rehydrates_fused2_axes_from_disk(armed, monkeypatch):
    at.autotune_fused2(
        N, 0, 8, 4, candidates=(1024,), sub_bits_candidates=(4,), trials=1
    )
    clear_tile_cache()                               # fresh-process simulation

    def boom(*a, **kw):                              # pragma: no cover
        raise AssertionError("fused2 search ran despite a warm disk cache")

    monkeypatch.setattr(at, "autotune_fused2", boom)
    monkeypatch.setattr(tiles, "autotune_tile", boom)
    plan = make_radix_plan(N, 0, 8, digit_split=4)
    assert plan.tile == 1024 and plan.sub_bits == 4
    assert family_decision(N, 16, "bms", "vmap", digits=2)[1] == at._DISK_REASON


def test_sub_bits_only_moves_cost_never_results():
    clear_tile_cache()
    k = _keys()
    ref = None
    for sb in (2, 4, 8):
        r = make_radix_plan(N, 0, 8, digit_split=4, sub_bits=sb)(k)
        got = np.asarray(r.keys)
        if ref is None:
            ref = got
        np.testing.assert_array_equal(got, ref)
    clear_tile_cache()


# ---------------------------------------------------------------------------
# Measured label-fusion choice (vmap generic path)
# ---------------------------------------------------------------------------

def test_label_fusion_is_measured_and_rehydrated(armed):
    p = make_plan(N, M, bucket_fn=_spec())
    k = _keys()
    p.label_fusion(k)                                # eager: may measure
    dec = fusion_decision("vmap", "EvenSpec", M)
    assert dec is not None and "autotuned" in dec[1]
    assert "fusion" in _disk_kinds(armed)

    clear_tile_cache()                               # fresh-process simulation
    p.label_fusion(k)
    assert fusion_decision("vmap", "EvenSpec", M)[1] == at._DISK_REASON


def test_traced_consult_defers_without_caching(armed):
    import jax

    p = make_plan(N, M, bucket_fn=_spec())
    clear_tile_cache(disk=True)                      # no fusion decision yet

    @jax.jit
    def run(k):
        return p(k).keys

    run(_keys())
    # under the trace the heuristic answered UNCACHED: the shape stays
    # measurable by a later eager consult
    assert fusion_decision("vmap", "EvenSpec", M) is None
    p.label_fusion(_keys())
    assert "autotuned" in fusion_decision("vmap", "EvenSpec", M)[1]


# ---------------------------------------------------------------------------
# Explicit segmented / batched searches pin their real shape classes
# ---------------------------------------------------------------------------

def test_segmented_search_pins_the_combined_shape_class(armed):
    from repro.core.pipeline import autotune_tile

    tile = autotune_tile(
        1024, _spec(8), segments=2, candidates=(256,), trials=1
    )
    assert tile == 256
    # the segmented plan resolves through m_eff = s * m = 16
    assert tiles._TILE_CACHE[(1024, 16, "bms", False, "vmap")] == 256


def test_batched_search_pins_the_per_row_shape_class(armed):
    from repro.core.pipeline import autotune_tile

    tile = autotune_tile(1024, _spec(8), batch=2, candidates=(256,), trials=1)
    assert tile == 256
    assert tiles._TILE_CACHE[(1024, 8, "bms", False, "vmap")] == 256
