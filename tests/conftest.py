import os
import sys

# Tests must see the default single CPU device (the dry-run sets its own
# virtual device count in a separate process). Keep threads tame on CI.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
