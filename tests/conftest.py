import os
import sys

# Tests must see the default single CPU device (the dry-run sets its own
# virtual device count in a separate process). Keep threads tame on CI.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Install the jax version-compat shims (jax.set_mesh, get_abstract_mesh, ...)
# before any test module touches jax — tests are written against the modern
# mesh API and the pinned jax 0.4.x lacks parts of it.
import repro  # noqa: F401  (side effect: repro.compat.install())

# Property tests use hypothesis; fall back to the deterministic shim when the
# real library is not baked into the image (see tests/_hypothesis_shim.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim

    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies
