"""Packed-counter kernel family (DESIGN.md §12): overflow safety, bitwise
equivalence with the dense one-hot family, and (tile, family) resolution.

The packed family's correctness argument rests on one invariant — no
subword counter ever exceeds ``2^bits − 1`` inside a level-1 subtile — so
these tests drive exactly the inputs that stress it: adversarial
all-one-bucket strips that max a counter lane out, subtile heights at the
cap, and property-sampled (tile, m, dtype) grids cross-checked bitwise
against the dense family on every backend.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

import repro.core.plan as msplan
from repro.core.identifiers import delta_buckets, from_fn
from repro.core.multisplit import (
    batched_multisplit,
    multisplit,
    multisplit_ref,
    segmented_multisplit,
)
from repro.core.pipeline import (
    FAMILIES,
    clear_tile_cache,
    family_decision,
    family_decisions,
    make_plan,
    packed_tile_local_offsets,
    resolve_kernel_family,
    tile_local_offsets,
)
from repro.core.pipeline.tiles import PACKED_MIN_BUCKETS, _FAMILY_CACHE
from repro.core.sort import radix_sort
from repro.kernels.common import (
    packed_layout,
    packed_local_offsets,
    packed_counts,
)

TILED_BACKENDS = ("vmap", "pallas-interpret")
ALL_BACKENDS = ("reference",) + TILED_BACKENDS


def _keys(n, seed=0, hi=2**30, dtype=np.uint32):
    return jnp.asarray(
        np.random.RandomState(seed % (2**31 - 1)).randint(0, hi, n).astype(dtype)
    )


# ---------------------------------------------------------------------------
# The overflow guard (satellite): packed_layout must reject any
# (tile, bits, subtile) combination that could wrap a subword counter.
# ---------------------------------------------------------------------------

def test_packed_layout_guard_rejects_overflowable_combos():
    # a 512-row subtile can put 512 > 255 equal ids into one 8-bit lane
    with pytest.raises(ValueError, match="overflow"):
        packed_layout(1024, 256, bits=8, subtile=512)
    with pytest.raises(ValueError, match="overflow"):
        packed_layout(1024, 256, bits=4, subtile=16)
    with pytest.raises(ValueError, match="bits-per-counter"):
        packed_layout(1024, 256, bits=5)
    with pytest.raises(ValueError, match="bits-per-counter"):
        packed_layout(1024, 256, bits=32)
    # the cap itself is legal: counts can reach exactly 2^bits - 1
    assert packed_layout(1024, 256, bits=8, subtile=255).subtile == 255
    assert packed_layout(1024, 256, bits=4, subtile=15).subtile == 15


def test_packed_layout_auto_subtile_is_always_safe():
    for bits in (1, 2, 4, 8, 16):
        for tile in (1, 37, 128, 1024, 4096):
            lay = packed_layout(tile, 256, bits=bits)
            assert lay.subtile <= (1 << bits) - 1
            assert lay.subtile <= 128
            assert lay.k * bits == 32
            assert lay.w == -(-256 // lay.k)


def test_packed_counter_saturates_at_cap_without_wrapping():
    """Adversarial all-one-bucket input maxing a subword counter out at
    exactly 2^bits - 1 (= subtile height 255) stays exact."""
    t, m = 510, 7
    ids = jnp.full((t,), m - 1, jnp.int32)
    lay = packed_layout(t, m, bits=8, subtile=255)
    local, hist = packed_local_offsets(ids, lay)
    np.testing.assert_array_equal(np.asarray(local), np.arange(t))
    assert int(hist[m - 1]) == t
    np.testing.assert_array_equal(np.asarray(packed_counts(ids, lay)), np.asarray(hist))


# ---------------------------------------------------------------------------
# Bitwise equivalence: packed == dense local solve (the property the whole
# family rests on), then end-to-end across backends/layouts/dtypes.
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    t=st.sampled_from((128, 192, 256, 510, 1024)),
    m=st.sampled_from((1, 2, 7, 64, 200, 256, 1000)),
    bits=st.sampled_from((4, 8, 16)),
    adversarial=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**20),
)
def test_packed_local_solve_bitwise_equals_dense(t, m, bits, adversarial, seed):
    if adversarial:
        ids = jnp.full((t,), m - 1, jnp.int32)       # maxes one counter lane
    else:
        ids = jnp.asarray(
            np.random.RandomState(seed % (2**31 - 1)).randint(0, m, t, dtype=np.int32)
        )
    ref_local, ref_hist = tile_local_offsets(ids, m)
    lay = packed_layout(t, m, bits=bits)
    local, hist = packed_local_offsets(ids, lay)
    np.testing.assert_array_equal(np.asarray(local), np.asarray(ref_local))
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(ref_hist))
    np.testing.assert_array_equal(np.asarray(packed_counts(ids, lay)), np.asarray(ref_hist))
    # the stage-primitive wrapper resolves the same layout
    local2, hist2 = packed_tile_local_offsets(ids, m)
    np.testing.assert_array_equal(np.asarray(local2), np.asarray(ref_local))
    np.testing.assert_array_equal(np.asarray(hist2), np.asarray(ref_hist))


def _assert_equal(out, ref, key_value):
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.bucket_counts), np.asarray(ref.bucket_counts))
    np.testing.assert_array_equal(np.asarray(out.bucket_starts), np.asarray(ref.bucket_starts))
    np.testing.assert_array_equal(np.asarray(out.permutation), np.asarray(ref.permutation))
    if key_value:
        np.testing.assert_array_equal(np.asarray(out.values), np.asarray(ref.values))


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from((256, 1000, 2048 + 37)),
    m=st.sampled_from((1, 13, 64, 256)),
    method=st.sampled_from(("dms", "wms", "bms")),
    backend=st.sampled_from(ALL_BACKENDS),
    key_value=st.booleans(),
    signed=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**20),
)
def test_packed_family_bitwise_equals_onehot_end_to_end(
    n, m, method, backend, key_value, signed, seed
):
    dtype = np.int32 if signed else np.uint32
    keys = _keys(n, seed=seed, dtype=dtype)
    vals = jnp.arange(n, dtype=jnp.int32) if key_value else None
    bf = delta_buckets(m, 2**30)
    ref = multisplit(keys, bf, vals, method=method, tile=256, family="onehot",
                     backend=backend)
    out = multisplit(keys, bf, vals, method=method, tile=256, family="packed",
                     backend=backend)
    _assert_equal(out, ref, key_value)
    _assert_equal(out, multisplit_ref(keys, bf, vals), key_value)


def test_packed_family_adversarial_single_bucket_end_to_end():
    """Every key in ONE bucket across full tiles: level-1 lanes saturate in
    every subtile on every tiled backend."""
    n, m = 4096, 256
    keys = jnp.full((n,), 5, jnp.uint32)             # delta bucket 0 for all
    bf = delta_buckets(m, 2**30)
    ref = multisplit_ref(keys, bf, None)
    for backend in ALL_BACKENDS:
        out = multisplit(keys, bf, method="bms", tile=1024, family="packed",
                         backend=backend)
        _assert_equal(out, ref, False)


def test_packed_callable_spec_ids_path():
    """CallableSpec plans feed the packed kernels a precomputed ids strip."""
    n, m = 1500, 64
    keys = _keys(n, seed=3)
    bf = delta_buckets(m, 2**30)
    opaque = from_fn(bf.emit, m, name="opaque")
    ref = multisplit_ref(keys, bf, None)
    for backend in TILED_BACKENDS:
        out = multisplit(keys, opaque, tile=256, family="packed", backend=backend)
        _assert_equal(out, ref, False)


def test_packed_partial_modes_and_layouts():
    m = 64
    bf = delta_buckets(m, 2**30)
    keys = _keys(1000, seed=11)
    ref = multisplit_ref(keys, bf, None)
    for backend in ALL_BACKENDS:
        co = multisplit(keys, bf, mode="counts_only", tile=256, family="packed",
                        backend=backend)
        np.testing.assert_array_equal(
            np.asarray(co.bucket_counts), np.asarray(ref.bucket_counts))
        po = multisplit(keys, bf, mode="positions_only", tile=256, family="packed",
                        backend=backend)
        np.testing.assert_array_equal(
            np.asarray(po.permutation), np.asarray(ref.permutation))
    # batched rows == independent flat calls
    keys2 = _keys(4 * 512, seed=12).reshape(4, 512)
    for backend in ALL_BACKENDS:
        out = batched_multisplit(keys2, bf, tile=256, family="packed", backend=backend)
        for i in range(4):
            ref_i = multisplit_ref(keys2[i], bf, None)
            np.testing.assert_array_equal(np.asarray(out.keys[i]), np.asarray(ref_i.keys))
            np.testing.assert_array_equal(
                np.asarray(out.bucket_counts[i]), np.asarray(ref_i.bucket_counts))
    # ragged segments == independent per-segment flat calls
    keys = _keys(1000, seed=13)
    starts = [0, 100, 400, 400, 900]
    bounds = starts + [1000]
    for backend in ALL_BACKENDS:
        out = segmented_multisplit(keys, bf, starts, tile=256, family="packed",
                                   backend=backend)
        for i in range(len(starts)):
            lo, hi = bounds[i], bounds[i + 1]
            ref_i = multisplit_ref(keys[lo:hi], bf, None)
            np.testing.assert_array_equal(np.asarray(out.keys[lo:hi]), np.asarray(ref_i.keys))
            np.testing.assert_array_equal(
                np.asarray(out.bucket_counts[i]), np.asarray(ref_i.bucket_counts))


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_packed_radix_sort_matches_onehot(backend):
    keys = _keys(4096 + 17, seed=7, hi=2**31)
    vals = jnp.arange(keys.shape[0], dtype=jnp.int32)
    k1, v1 = radix_sort(keys, vals, radix_bits=8, backend=backend, family="onehot")
    k2, v2 = radix_sort(keys, vals, radix_bits=8, backend=backend, family="packed")
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(k1), np.sort(np.asarray(keys)))


# ---------------------------------------------------------------------------
# (tile, family) resolution: heuristics, reasons, caches, plan hashing.
# ---------------------------------------------------------------------------

def test_family_heuristic_and_reasons():
    clear_tile_cache()
    for backend in TILED_BACKENDS:
        fam, reason = family_decision(1 << 16, 256, "bms", backend)
        assert fam == "packed" and "m_eff=256" in reason
        fam, reason = family_decision(1 << 16, 4, "bms", backend)
        assert fam == "onehot" and "m_eff=4" in reason
    fam, reason = family_decision(1 << 16, 256, "bms", "reference")
    assert fam == "onehot" and "untiled" in reason
    assert ((1 << 16, 256, "bms", "vmap") in family_decisions())
    # explicit requests are validated but never cached
    clear_tile_cache()
    assert resolve_kernel_family(4096, 8, "bms", "vmap", "packed") == "packed"
    assert (4096, 8, "bms", "vmap") not in _FAMILY_CACHE
    with pytest.raises(ValueError, match="unknown kernel family"):
        resolve_kernel_family(4096, 8, "bms", "vmap", "dense")


def test_family_capability_is_validated_per_backend():
    from repro.core.pipeline.registry import _REGISTRY, Backend, register_backend

    name = "test-onehot-only"
    register_backend(Backend(name=name, description="test", families=("onehot",)))
    try:
        with pytest.raises(ValueError, match="supports kernel families"):
            resolve_kernel_family(4096, 256, "bms", name, "packed")
        assert resolve_kernel_family(4096, 256, "bms", name) == "onehot"
    finally:
        _REGISTRY.pop(name)


def test_heuristic_tile_regression_n1m_m256():
    """Satellite pin: the corrected cost model's tiles for (n=1M, m=256).

    The pre-PR-5 model under-counted the one-hot working set (one T×m̄
    plane, one T×T matrix) and chose tile=1024, whose true fused-postscan
    footprint (two T×m̄ planes + two T×T matrices ≈ 10.5 MB) blows the 8 MB
    budget. The corrected model halves it to 512. Since PR-8 kernel
    backends trace the OBLIVIOUS packed body (DESIGN.md §15), whose T×T
    permutation matrix caps the packed tile at 1024 (vmap keeps 4096)."""
    clear_tile_cache()
    assert msplan._heuristic_tile(1 << 20, 256, "bms", "pallas", family="onehot") == 512
    assert msplan._heuristic_tile(1 << 20, 256, "bms", "pallas", family="packed") == 1024
    assert msplan._heuristic_tile(1 << 20, 256, "bms", "vmap", family="packed") == 4096
    p = make_plan(1 << 20, 256, method="bms", backend="pallas")
    assert (p.family, p.tile) == ("packed", 1024)
    p1h = make_plan(1 << 20, 256, method="bms", backend="pallas", family="onehot")
    assert (p1h.family, p1h.tile) == ("onehot", 512)


def test_explicit_family_does_not_poison_tile_cache():
    """An off-heuristic family override computes its tile under its own cost
    model WITHOUT writing the shape's cache entry (mirrors the explicit-tile
    rule)."""
    clear_tile_cache()
    shape = (1 << 20, 256, "bms", False, "pallas")
    p_pk = make_plan(1 << 20, 256, method="bms", backend="pallas")          # auto: packed
    assert msplan._TILE_CACHE[shape] == p_pk.tile == 1024
    p_1h = make_plan(1 << 20, 256, method="bms", backend="pallas", family="onehot")
    assert p_1h.tile == 512
    assert msplan._TILE_CACHE[shape] == 1024        # auto entry untouched
    assert make_plan(1 << 20, 256, method="bms", backend="pallas").tile == 1024


def test_family_is_a_hashable_plan_axis():
    clear_tile_cache()
    bf = delta_buckets(256, 2**30)
    a = make_plan(4096, 256, bucket_fn=bf)
    b = make_plan(4096, 256, bucket_fn=bf)
    assert a == b and hash(a) == hash(b) and a.family == "packed"
    c = make_plan(4096, 256, bucket_fn=bf, family="onehot")
    assert c != a                                    # family is part of the value


def test_autotune_searches_tile_family_jointly_and_records_reason():
    clear_tile_cache()
    bf = delta_buckets(64, 2**30)
    tuned = msplan.autotune_tile(
        4096, bf, method="bms", backend="vmap", candidates=(512, 1024), trials=1
    )
    assert tuned in (512, 1024)
    assert msplan._TILE_CACHE[(4096, 64, "bms", False, "vmap")] == tuned
    fam, reason = family_decision(4096, 64, "bms", "vmap")
    assert fam in FAMILIES
    assert "autotuned" in reason and str(tuned) in reason
    # the pinned winner is what later plans resolve to
    p = make_plan(4096, 64, method="bms", backend="vmap", bucket_fn=bf)
    assert (p.tile, p.family) == (tuned, fam)


def test_packed_stage_tags():
    clear_tile_cache()
    bf = delta_buckets(256, 2**30)
    vm = make_plan(4096, 256, backend="vmap", bucket_fn=bf)
    assert vm.family == "packed"
    assert vm.stages()[0] == "prescan:vmap-packed"
    assert vm.stages()[-2] == "postscan:fused-reorder-vmap-packed"
    pk = make_plan(4096, 256, backend="pallas-interpret", bucket_fn=bf)
    assert pk.stages()[0] == "prescan:fused-label-kernel-packed"
    # the reference oracle has no tile local solve: no family tag
    rf = make_plan(4096, 256, backend="reference", bucket_fn=bf, family="packed")
    assert rf.stages() == ("direct-solve:reference",)


def test_autotune_family_flip_invalidates_other_kv_tile():
    """Regression: the family decision is shared by both key-value variants
    of a shape, but autotune only measures one — the OTHER variant's cached
    tile (sized under the previous family's cost model) must be dropped,
    not silently served under the flipped family."""
    clear_tile_cache()
    bf = delta_buckets(256, 2**30)
    # key-only plan caches tile 1024 under the heuristic 'packed' family
    # (the oblivious T×T term caps kernel-backend packed tiles; DESIGN.md §15)
    p0 = make_plan(1 << 14, 256, method="bms", backend="pallas-interpret")
    assert (p0.family, p0.tile) == ("packed", 1024)
    # force an autotuned family flip via the kv variant (onehot only)
    msplan.autotune_tile(
        1 << 14, bf, method="bms", backend="pallas-interpret", key_value=True,
        candidates=(512,), families=("onehot",), trials=1,
    )
    assert family_decision(1 << 14, 256, "bms", "pallas-interpret")[0] == "onehot"
    # the key-only shape must now re-resolve its tile under 'onehot' — the
    # stale packed-model 1024 (a VMEM blowout for the one-hot) is gone
    p1 = make_plan(1 << 14, 256, method="bms", backend="pallas-interpret")
    assert (p1.family, p1.tile) == ("onehot", 512)


def test_packed_min_buckets_threshold_is_the_flip_point():
    clear_tile_cache()
    lo = resolve_kernel_family(1 << 16, PACKED_MIN_BUCKETS - 1, "bms", "vmap")
    hi = resolve_kernel_family(1 << 16, PACKED_MIN_BUCKETS, "bms", "vmap")
    assert (lo, hi) == ("onehot", "packed")


def test_packed_min_buckets_matches_measured_crossover():
    """Regression pin for the MEASURED family crossover (ISSUE 6 satellite).

    The original flip point (64) was a working-set argument; the host-bench
    packed_vs_onehot sweep (BENCH_multisplit.json, key-value flat multisplit
    re-measured at n ∈ {2^18, 2^20}) shows packed winning from m=8 up
    (1.12–1.25× at m=8, ≥1.5× at m=16) and only tying at m=4. If this pin
    fails, re-run ``benchmarks/bench_multisplit.py`` packed_vs_onehot and
    move the constant to the new measured crossover — don't guess."""
    assert PACKED_MIN_BUCKETS == 8
