"""Gradient compression: quantization error bounds + error feedback."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim.compress import BLOCK, compressed_psum, dequantize, quantize


def test_quantize_roundtrip_error_bound():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(1000).astype(np.float32) * 5)
    qt, residual = quantize(x)
    deq = dequantize(qt, x.shape, x.dtype)
    # per-block error bounded by scale/2 = absmax/254
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert err.max() <= float(np.abs(np.asarray(x)).max()) / 127.0
    np.testing.assert_allclose(np.asarray(x - deq), np.asarray(residual), atol=1e-6)


def test_error_feedback_converges():
    """Repeated compression of the SAME gradient with error feedback must sum
    to the true gradient (the bias is eliminated over steps)."""
    rng = np.random.RandomState(1)
    g = jnp.asarray(rng.randn(512).astype(np.float32))
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        qt, err = quantize(g + err)
        acc = acc + dequantize(qt, g.shape, g.dtype)
    mean = np.asarray(acc) / 50
    np.testing.assert_allclose(mean, np.asarray(g), atol=2e-2)


@pytest.mark.slow
def test_compressed_psum_two_pods():
    import os, subprocess, sys, textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum
        mesh = jax.make_mesh((2, 4), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        rng = np.random.RandomState(0)
        grads = jnp.asarray(rng.randn(8, 512).astype(np.float32))
        def f(g):
            g = g.reshape(512)
            out, err = compressed_psum(g, jnp.zeros_like(g),
                                       fast_axis="data", slow_axis="pod")
            return out[None], err[None]
        fm = jax.shard_map(f, mesh=mesh, in_specs=(P(("pod", "data")),),
                           out_specs=(P(("pod", "data")), P(("pod", "data"))),
                           check_vma=False)
        with jax.set_mesh(mesh):
            out, err = fm(grads)
        true = np.asarray(grads).reshape(2, 4, 512).sum((0, 1))
        got = np.asarray(out)[0]
        rel = np.abs(got - true).max() / (np.abs(true).max() + 1e-9)
        assert rel < 0.02, rel
        print("OK", rel)
    """)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout
