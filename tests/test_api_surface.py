"""Public-API snapshot (ISSUE 4 satellite): `repro.ops` is the stable
surface downstream PRs (sharded/multi-host, new backends) program against.
This test pins ``__all__`` and the operator signatures — changing either is
a deliberate, reviewed act, not a side effect."""

import inspect

import pytest

from repro import ops

EXPECTED_ALL = (
    "BucketSpec", "BitfieldSpec", "CallableSpec", "DeltaSpec", "EvenSpec",
    "IdentitySpec", "RangeSpec", "BucketIdentifier",
    "as_spec", "delta_buckets", "even_buckets", "from_fn",
    "identity_buckets", "radix_buckets", "range_buckets",
    "MultisplitResult",
    "multisplit", "multisplit_key_value", "segmented_multisplit",
    "histogram", "radix_sort", "segmented_radix_sort",
    "set_autotune",
    "set_strict", "set_verify",
)

EXPECTED_SIGNATURES = {
    # PR-5 additively appended keyword-only ``family`` (kernel family,
    # DESIGN.md §12) to every plan-backed op, per the §11 stability policy.
    # ISSUE 6 additively appended keyword-only ``fuse_digits`` (fused
    # two-digit radix pairs, DESIGN.md §13) to the two radix sorts.
    "multisplit": (
        "(keys, spec, values=None, *, method='bms', backend='vmap', "
        "tile=None, mode='reorder', family=None)"
    ),
    "multisplit_key_value": (
        "(keys, values, spec, *, method='bms', backend='vmap', tile=None, "
        "family=None)"
    ),
    "segmented_multisplit": (
        "(keys, spec, segment_starts, values=None, *, method='bms', "
        "backend='vmap', tile=None, mode='reorder', family=None)"
    ),
    "histogram": "(keys, spec, *, backend='vmap', tile=None, family=None)",
    "radix_sort": (
        "(keys, values=None, *, radix_bits=8, key_bits=32, method='bms', "
        "use_pallas=False, interpret=True, backend=None, tile=None, "
        "family=None, fuse_digits=False)"
    ),
    "segmented_radix_sort": (
        "(keys, segment_starts, values=None, *, radix_bits=8, key_bits=32, "
        "method='bms', use_pallas=False, interpret=True, backend=None, "
        "tile=None, family=None, fuse_digits=False)"
    ),
    "delta_buckets": "(num_buckets, key_max=1073741824)",
    "identity_buckets": "(num_buckets)",
    "radix_buckets": "(pass_idx, radix_bits)",
    "range_buckets": "(splitters)",
    "even_buckets": "(lo, hi, num_buckets)",
    "from_fn": "(fn, num_buckets, name='user')",
    # ISSUE 7 additively appended the self-tuning opt-in (DESIGN.md §14).
    "set_autotune": (
        "(enabled=None, *, cache_dir=None, persist=None, trials=None, "
        "candidates=None)"
    ),
    # ISSUE 10 additively appended the resilience opt-ins (DESIGN.md §17).
    "set_strict": "(enabled)",
    "set_verify": "(level)",
}


def _normalize(sig: inspect.Signature) -> str:
    # strip annotations; keep names, kinds and defaults
    params = [p.replace(annotation=inspect.Parameter.empty)
              for p in sig.parameters.values()]
    return str(inspect.Signature(params))


def test_all_is_pinned():
    assert tuple(ops.__all__) == EXPECTED_ALL
    for name in ops.__all__:
        assert hasattr(ops, name), f"__all__ names missing symbol {name}"


@pytest.mark.parametrize("name", sorted(EXPECTED_SIGNATURES))
def test_operator_signatures_are_pinned(name):
    got = _normalize(inspect.signature(getattr(ops, name)))
    assert got == EXPECTED_SIGNATURES[name], (
        f"ops.{name} signature changed:\n  pinned: {EXPECTED_SIGNATURES[name]}"
        f"\n  actual: {got}\nUpdate the public-API stability policy "
        "(DESIGN.md §11) and this snapshot together."
    )


def test_result_contract():
    fields = ops.MultisplitResult._fields
    assert fields == ("keys", "values", "bucket_starts", "bucket_counts", "permutation")


def test_specs_in_all_are_hashable_types():
    import dataclasses

    for name in ("DeltaSpec", "BitfieldSpec", "RangeSpec", "EvenSpec",
                 "IdentitySpec", "CallableSpec", "BucketIdentifier"):
        cls = getattr(ops, name)
        assert issubclass(cls, ops.BucketSpec)
    s = ops.DeltaSpec(8, 1 << 20)
    assert dataclasses.is_dataclass(s) and hash(s) == hash(ops.DeltaSpec(8, 1 << 20))
