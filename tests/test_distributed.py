"""Distributed multisplit over a mesh axis (runs subprocesses with virtual
devices: the main pytest process must keep seeing exactly 1 CPU device)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_with_devices(n_devices: int, body: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True, timeout=600
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.slow
def test_multisplit_sharded_equal_shards():
    out = _run_with_devices(8, """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import make_multisplit_sharded
        from repro.core.multisplit import multisplit_ref
        from repro.core.identifiers import delta_buckets
        mesh = jax.make_mesh((8,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
        for m in (2, 11, 64, 256):
            rng = np.random.RandomState(m)
            keys = jnp.asarray(rng.randint(0, 2**30, 8 * 512, dtype=np.uint32))
            vals = jnp.arange(keys.shape[0], dtype=jnp.int32)
            bf = delta_buckets(m, 2**30)
            with jax.set_mesh(mesh):
                f = make_multisplit_sharded(bf, mesh, "x", key_value=True)
                out = f(keys, vals)
            ref = multisplit_ref(keys, bf, vals)
            assert np.array_equal(np.asarray(out.keys), np.asarray(ref.keys)), m
            assert np.array_equal(np.asarray(out.values), np.asarray(ref.values)), m
            assert np.array_equal(np.asarray(out.bucket_counts), np.asarray(ref.bucket_counts)), m
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_multisplit_bucket_sharded():
    out = _run_with_devices(8, """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.distributed import multisplit_bucket_sharded, BucketShardedResult
        from repro.core.multisplit import multisplit_ref
        from repro.core.identifiers import delta_buckets
        D = 8
        mesh = jax.make_mesh((D,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
        for m in (8, 64, 256):
            rng = np.random.RandomState(m)
            n = D * 256
            cap = 2 * n // D
            keys = jnp.asarray(rng.randint(0, 2**30, n, dtype=np.uint32))
            vals = jnp.arange(n, dtype=jnp.int32)
            bf = delta_buckets(m, 2**30)
            fn = lambda k, v: multisplit_bucket_sharded(k, bf, v, axis_name="x", capacity=cap)
            f = jax.shard_map(fn, mesh=mesh, in_specs=(P("x"), P("x")),
                out_specs=BucketShardedResult(P("x"), P("x"), P("x"), P("x"), P()),
                check_vma=False)
            with jax.set_mesh(mesh):
                out = f(keys, vals)
            ref = multisplit_ref(keys, bf, vals)
            ko = np.asarray(out.keys).reshape(D, cap)
            cnt = np.asarray(out.count).reshape(D)
            rk = np.concatenate([ko[d, :cnt[d]] for d in range(D)])
            assert np.array_equal(rk, np.asarray(ref.keys)), m
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_one_cell_both_meshes():
    """End-to-end: the real dryrun driver — multi-pod compile proof + the
    single-pod roofline accounting."""
    out = _run_with_devices(512, """
        from repro.launch.dryrun import lower_cell
        rec = lower_cell("xlstm-350m", "decode_32k", "multi")
        assert rec["status"] == "ok", rec
        assert rec["n_chips"] == 512
        assert rec["compile_s"] > 0            # pod-axis shard proof
        rec1 = lower_cell("xlstm-350m", "decode_32k", "single")
        assert rec1["status"] == "ok", rec1
        assert rec1["hlo_flops"] > 0 and rec1["collective_bytes"] >= 0
        print("OK", rec1["dominant"])
    """)
    assert "OK" in out
