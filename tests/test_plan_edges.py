"""Plan-layer edge-case matrix + tile-cache regression (ISSUE 2 satellites).

Covers the degenerate shapes every consumer eventually hits: empty inputs,
single elements, inputs smaller than ``_MIN_TILE``, non-tile-multiple n,
single-bucket and 256-bucket problems, all-elements-one-bucket skew, and
empty segments in the segmented path — on every CPU-testable backend.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import plan as msplan
from repro.core.identifiers import delta_buckets, from_fn, identity_buckets
from repro.core.multisplit import (
    batched_multisplit,
    multisplit,
    multisplit_ref,
    segmented_multisplit,
)
from repro.core.sort import radix_sort, segmented_radix_sort

BACKENDS = ["reference", "vmap", "pallas-interpret"]


def _keys(n, seed=0, hi=2**30):
    return jnp.asarray(np.random.RandomState(seed).randint(0, hi, size=n, dtype=np.uint32))


# ---------------------------------------------------------------------------
# Edge-case matrix: n x m x backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [0, 1, 7, 100, 255, 256, 257, 2048 + 37])
def test_edge_sizes_match_oracle(backend, n):
    """n spans: empty, single, < _MIN_TILE, == tile, tile+1, non-multiple."""
    m = 13
    keys = _keys(n, seed=n + 1)
    vals = jnp.arange(n, dtype=jnp.int32)
    bf = delta_buckets(m, 2**30)
    ref = multisplit_ref(keys, bf, vals)
    out = multisplit(keys, bf, vals, backend=backend)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.values), np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(out.bucket_counts), np.asarray(ref.bucket_counts))
    np.testing.assert_array_equal(np.asarray(out.bucket_starts), np.asarray(ref.bucket_starts))
    np.testing.assert_array_equal(np.asarray(out.permutation), np.asarray(ref.permutation))
    assert int(out.bucket_counts.sum()) == n


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("m", [1, 2, 256])
def test_edge_bucket_counts(backend, m):
    """m spans: degenerate single bucket, minimal, paper's large-m regime."""
    n = 600 + m
    keys = _keys(n, seed=m)
    bf = delta_buckets(m, 2**30)
    ref = multisplit_ref(keys, bf)
    out = multisplit(keys, bf, tile=256, backend=backend)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.bucket_counts), np.asarray(ref.bucket_counts))
    np.testing.assert_array_equal(np.asarray(out.permutation), np.asarray(ref.permutation))


@pytest.mark.parametrize("backend", BACKENDS)
def test_all_elements_one_bucket(backend):
    """Maximal skew: the entire input lands in a single middle bucket."""
    n, m = 777, 16
    keys = jnp.full((n,), 5, jnp.uint32)
    bf = identity_buckets(m)
    out = multisplit(keys, bf, jnp.arange(n, dtype=jnp.int32), tile=128, backend=backend)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(keys))
    np.testing.assert_array_equal(np.asarray(out.values), np.arange(n))  # stable
    counts = np.zeros(m, np.int64)
    counts[5] = n
    np.testing.assert_array_equal(np.asarray(out.bucket_counts), counts)
    np.testing.assert_array_equal(np.asarray(out.permutation), np.arange(n))


def test_n_zero_radix_sort():
    for backend in ("vmap", "pallas-interpret"):
        ks, vs = radix_sort(
            _keys(0), jnp.zeros((0,), jnp.int32), radix_bits=8, backend=backend
        )
        assert ks.shape == (0,) and vs.shape == (0,)


# ---------------------------------------------------------------------------
# Batched / segmented edges
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_edge_rows(backend):
    """b=1 and n in {0, 1}: batched plans on degenerate shapes."""
    bf = delta_buckets(4, 2**30)
    for b, n in [(1, 0), (1, 1), (3, 0), (3, 1)]:
        keys = _keys(b * n, seed=b * 10 + n).reshape(b, n)
        out = batched_multisplit(keys, bf, backend=backend)
        assert out.keys.shape == (b, n)
        assert out.bucket_counts.shape == (b, 4)
        assert out.permutation.shape == (b, n)
        np.testing.assert_array_equal(
            np.asarray(out.bucket_counts).sum(axis=1), np.full(b, n)
        )


@pytest.mark.parametrize("backend", BACKENDS)
def test_segmented_empty_segments(backend):
    """Empty segments anywhere — first, middle, consecutive, last — must
    yield zero count rows and leave neighbours bit-exact."""
    m = 8
    bf = delta_buckets(m, 2**30)
    n = 500
    keys = _keys(n, seed=11)
    vals = jnp.arange(n, dtype=jnp.int32)
    # segment 0 empty (starts[0]==starts[1]==0), two consecutive empties in
    # the middle, and an empty last segment (start == n)
    starts = [0, 0, 200, 200, 200, 500]
    ends = starts[1:] + [n]
    out = segmented_multisplit(keys, bf, starts, vals, tile=128, backend=backend)
    assert out.bucket_counts.shape == (len(starts), m)
    for i, (a, e) in enumerate(zip(starts, ends)):
        if a == e:
            np.testing.assert_array_equal(np.asarray(out.bucket_counts[i]), np.zeros(m))
            np.testing.assert_array_equal(np.asarray(out.bucket_starts[i]), np.zeros(m))
            continue
        ref = multisplit_ref(keys[a:e], bf, vals[a:e])
        np.testing.assert_array_equal(np.asarray(out.keys[a:e]), np.asarray(ref.keys))
        np.testing.assert_array_equal(np.asarray(out.values[a:e]), np.asarray(ref.values))
        np.testing.assert_array_equal(
            np.asarray(out.bucket_counts[i]), np.asarray(ref.bucket_counts)
        )
        np.testing.assert_array_equal(
            np.asarray(out.permutation[a:e]), np.asarray(ref.permutation)
        )


@pytest.mark.parametrize("backend", BACKENDS)
def test_segmented_single_segment_equals_flat(backend):
    """s=1 segmented == flat, with (1, m) shaped counts."""
    n, m = 300, 8
    keys = _keys(n, seed=4)
    bf = delta_buckets(m, 2**30)
    flat = multisplit(keys, bf, tile=128, backend=backend)
    seg = segmented_multisplit(keys, bf, [0], tile=128, backend=backend)
    np.testing.assert_array_equal(np.asarray(seg.keys), np.asarray(flat.keys))
    np.testing.assert_array_equal(np.asarray(seg.bucket_counts[0]), np.asarray(flat.bucket_counts))
    np.testing.assert_array_equal(np.asarray(seg.permutation), np.asarray(flat.permutation))


def test_segmented_all_segments_empty():
    """n=0 with several (necessarily empty) segments."""
    bf = delta_buckets(4, 2**30)
    for backend in BACKENDS:
        out = segmented_multisplit(_keys(0), bf, [0, 0, 0], backend=backend)
        assert out.keys.shape == (0,)
        np.testing.assert_array_equal(np.asarray(out.bucket_counts), np.zeros((3, 4)))


def test_segmented_radix_sort_empty_segments():
    keys = _keys(300, seed=9, hi=2**16)
    starts = [0, 0, 150, 300]
    ks, _ = segmented_radix_sort(keys, starts, radix_bits=4, key_bits=16, tile=128)
    np.testing.assert_array_equal(np.asarray(ks[0:150]), np.sort(np.asarray(keys[0:150])))
    np.testing.assert_array_equal(np.asarray(ks[150:300]), np.sort(np.asarray(keys[150:300])))


# ---------------------------------------------------------------------------
# Plan validation of the new layouts
# ---------------------------------------------------------------------------

def test_layout_validation():
    with pytest.raises(ValueError):
        msplan.make_plan(100, 4, batch=2, segments=2)        # mutually exclusive
    with pytest.raises(ValueError):
        msplan.make_plan(100, 4, batch=0)
    with pytest.raises(ValueError):
        msplan.make_plan(100, 4, segments=0)
    bf = delta_buckets(4)
    p = msplan.make_plan(100, 4, bucket_fn=bf)
    with pytest.raises(ValueError):                          # not segmented
        p(_keys(100), segment_starts=jnp.zeros((1,), jnp.int32))
    ps = msplan.make_plan(100, 4, bucket_fn=bf, segments=2)
    with pytest.raises(ValueError):                          # starts required
        ps(_keys(100))
    with pytest.raises(ValueError):                          # wrong starts shape
        ps(_keys(100), segment_starts=jnp.zeros((3,), jnp.int32))
    pb = msplan.make_plan(50, 4, bucket_fn=bf, batch=2)
    with pytest.raises(ValueError):                          # wrong batch shape
        pb(_keys(100).reshape(4, 25))


def test_stages_mark_layouts():
    bf = delta_buckets(8)
    fl = msplan.make_plan(256, 8, bucket_fn=bf)
    bt = msplan.make_plan(256, 8, bucket_fn=bf, batch=4)
    sg = msplan.make_plan(256, 8, bucket_fn=bf, segments=4)
    assert not fl.stages()[0].startswith("layout:")
    assert bt.stages()[0] == "layout:batched[4]"
    assert sg.stages()[0] == "layout:segmented[4]"
    assert bt.stages()[1:] == fl.stages()
    assert sg.stages()[1:] == fl.stages()


# ---------------------------------------------------------------------------
# _TILE_CACHE regression: explicit tile= must not poison the autotune cache
# ---------------------------------------------------------------------------

def test_explicit_tile_does_not_poison_cache():
    """Regression: a one-off ``tile=`` override must leave subsequent
    same-shape plans resolving to the heuristic/autotuned tile."""
    msplan.clear_tile_cache()
    shape = (1 << 16, 32, "bms", False, "vmap")
    heuristic = msplan._heuristic_tile(1 << 16, 32, "bms", "vmap")
    assert heuristic != 64  # the override below must be distinguishable

    p_override = msplan.make_plan(1 << 16, 32, method="bms", backend="vmap", tile=64)
    assert p_override.tile == 64
    # the override was honored but NOT cached
    assert shape not in msplan._TILE_CACHE or msplan._TILE_CACHE[shape] != 64

    p_after = msplan.make_plan(1 << 16, 32, method="bms", backend="vmap")
    assert p_after.tile == heuristic
    assert msplan._TILE_CACHE[shape] == heuristic

    # and an override AFTER the cache is warm neither reads nor clobbers it
    p_again = msplan.make_plan(1 << 16, 32, method="bms", backend="vmap", tile=128)
    assert p_again.tile == 128
    assert msplan._TILE_CACHE[shape] == heuristic


def test_autotuned_tile_survives_override():
    """An autotune-pinned winner stays pinned across explicit overrides."""
    msplan.clear_tile_cache()
    bf = delta_buckets(8, 2**30)
    tuned = msplan.autotune_tile(
        4096, bf, method="bms", backend="vmap", candidates=(256, 1024), trials=1
    )
    msplan.make_plan(4096, 8, method="bms", backend="vmap", bucket_fn=bf, tile=32)
    assert msplan._TILE_CACHE[(4096, 8, "bms", False, "vmap")] == tuned
    assert msplan.make_plan(4096, 8, method="bms", backend="vmap", bucket_fn=bf).tile == tuned


def test_segmented_tile_cache_keyed_on_combined_width():
    """Segmented plans budget VMEM for the COMBINED (s*m) scan width, so
    their cache entries must not collide with the flat (n, m) shape."""
    msplan.clear_tile_cache()
    bf = delta_buckets(4)
    flat = msplan.make_plan(1 << 18, 4, backend="pallas-interpret", bucket_fn=bf)
    seg = msplan.make_plan(
        1 << 18, 4, backend="pallas-interpret", bucket_fn=bf, segments=64
    )
    assert (1 << 18, 4, "bms", False, "pallas-interpret") in msplan._TILE_CACHE
    assert (1 << 18, 256, "bms", False, "pallas-interpret") in msplan._TILE_CACHE
    # the combined width flips the 256-wide shape into the PACKED family
    # (PR-5), whose near-flat-in-m working set KEEPS a larger tile than the
    # narrow flat shape allows the dense one-hot — the pre-PR-5 "wider scan
    # => strictly smaller tile" rule only survives within one family
    assert seg.family == "packed" and flat.family == "onehot"
    assert seg.tile > flat.tile
    # within the one-hot family the old rule still holds at a width that
    # pushes the working set past the budget floor
    seg1h = msplan.make_plan(
        1 << 18, 4, backend="pallas-interpret", bucket_fn=bf, segments=1024,
        family="onehot",
    )
    assert seg1h.tile < flat.tile
