"""repro.ops transform acceptance (ISSUE 4): vmap dispatches to ONE batched
plan bitwise-equal to the per-row loop, grad through the key-value op
matches a dense one-hot permutation reference, equal specs never retrace,
and non-callable specs run end-to-end with ZERO materialized labels."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import ops
from repro.core.multisplit import multisplit_ref
from repro.core.pipeline import spec as plan_spec
from repro.core.pipeline.tiles import _TILE_CACHE, clear_tile_cache

TILED_BACKENDS = ("vmap", "pallas-interpret")
ALL_BACKENDS = ("reference",) + TILED_BACKENDS

FUSABLE_SPECS = [
    ops.delta_buckets(13, 2**30),
    ops.range_buckets([1000, 50_000, 2**20, 2**29]),
    ops.radix_buckets(1, 4),
    ops.identity_buckets(8),
]


def _keys(n, seed=0, hi=2**30):
    return jnp.asarray(np.random.RandomState(seed).randint(0, hi, size=n, dtype=np.uint32))


def _spec_keys(spec, n, seed=0):
    hi = spec.num_buckets if spec.name.startswith("identity") else 2**30
    return _keys(n, seed, hi)


# ---------------------------------------------------------------------------
# vmap: ONE batched-plan launch, bitwise equal to the per-row loop
# ---------------------------------------------------------------------------

def _count_plan_calls(monkeypatch):
    """Count plan EXECUTIONS on concrete arrays. custom_vmap additionally
    traces the flat op once with abstract tracers to recover the output
    structure — that probe does no work and is excluded."""
    calls = {"flat": 0, "batched": 0}
    orig = plan_spec.MultisplitPlan.__call__

    def spy(self, keys, *a, **k):
        if not isinstance(keys, jax.core.Tracer):
            calls["batched" if self.batch is not None else "flat"] += 1
        return orig(self, keys, *a, **k)

    monkeypatch.setattr(plan_spec.MultisplitPlan, "__call__", spy)
    return calls


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_vmap_is_one_batched_plan_launch_bitwise(backend, monkeypatch):
    """THE acceptance criterion: jax.vmap(ops.multisplit) routes onto
    make_batched_plan — ONE batched launch — and is bitwise equal to
    per-row flat calls."""
    b, n, spec = 6, 700, ops.delta_buckets(13, 2**30)
    keys = _keys(b * n, seed=1).reshape(b, n)
    f = lambda k: ops.multisplit(k, spec, tile=128, backend=backend)

    calls = _count_plan_calls(monkeypatch)
    vm = jax.vmap(f)(keys)
    assert calls == {"flat": 0, "batched": 1}, calls

    for i in range(b):
        fl = f(keys[i])
        np.testing.assert_array_equal(np.asarray(vm.keys[i]), np.asarray(fl.keys))
        np.testing.assert_array_equal(np.asarray(vm.permutation[i]), np.asarray(fl.permutation))
        np.testing.assert_array_equal(np.asarray(vm.bucket_counts[i]), np.asarray(fl.bucket_counts))
        np.testing.assert_array_equal(np.asarray(vm.bucket_starts[i]), np.asarray(fl.bucket_starts))


@pytest.mark.parametrize("mode", ["counts_only", "positions_only"])
def test_vmap_partial_modes(mode, monkeypatch):
    b, n, spec = 4, 300, ops.delta_buckets(8, 2**30)
    keys = _keys(b * n, seed=2).reshape(b, n)
    f = lambda k: ops.multisplit(k, spec, tile=128, mode=mode)
    calls = _count_plan_calls(monkeypatch)
    vm = jax.vmap(f)(keys)
    assert calls == {"flat": 0, "batched": 1}
    assert vm.keys is None and vm.values is None
    for i in range(b):
        fl = f(keys[i])
        np.testing.assert_array_equal(np.asarray(vm.bucket_counts[i]), np.asarray(fl.bucket_counts))
        if mode == "positions_only":
            np.testing.assert_array_equal(np.asarray(vm.permutation[i]), np.asarray(fl.permutation))


def test_vmap_key_value_single_launch(monkeypatch):
    b, n, spec = 5, 400, ops.delta_buckets(8, 2**30)
    keys = _keys(b * n, seed=3).reshape(b, n)
    vals = jnp.asarray(np.random.RandomState(4).rand(b, n).astype(np.float32))
    calls = _count_plan_calls(monkeypatch)
    vm = jax.vmap(lambda k, v: ops.multisplit(k, spec, v, tile=128))(keys, vals)
    assert calls == {"flat": 0, "batched": 1}
    for i in range(b):
        fl = ops.multisplit(keys[i], spec, vals[i], tile=128)
        np.testing.assert_array_equal(np.asarray(vm.keys[i]), np.asarray(fl.keys))
        np.testing.assert_array_equal(np.asarray(vm.values[i]), np.asarray(fl.values))


def test_vmap_inside_jit():
    b, n, spec = 3, 256, ops.delta_buckets(8, 2**30)
    keys = _keys(b * n, seed=5).reshape(b, n)
    jf = jax.jit(jax.vmap(lambda k: ops.multisplit(k, spec, tile=128).bucket_counts))
    counts = jf(keys)
    for i in range(b):
        np.testing.assert_array_equal(
            np.asarray(counts[i]),
            np.asarray(ops.multisplit(keys[i], spec, tile=128).bucket_counts),
        )


def test_rank2_keys_rejected_with_vmap_hint():
    with pytest.raises(ValueError, match="jax.vmap"):
        ops.multisplit(_keys(20).reshape(4, 5), ops.delta_buckets(4))


# ---------------------------------------------------------------------------
# grad: the key-value op vs a dense one-hot permutation reference
# ---------------------------------------------------------------------------

def test_grad_matches_dense_one_hot_reference():
    """d(values)/dL of the fused key-value multisplit == the gradient of an
    explicit dense permutation-matrix apply (out = P^T v, P = one_hot(perm))."""
    n, spec = 600, ops.delta_buckets(16, 2**30)
    keys = _keys(n, seed=7)
    vals = jnp.asarray(np.random.RandomState(8).rand(n).astype(np.float32))
    w = jnp.asarray(np.random.RandomState(9).rand(n).astype(np.float32))

    loss = lambda v: (ops.multisplit_key_value(keys, v, spec, tile=128).values * w).sum()
    g = jax.grad(loss)(vals)

    perm = ops.multisplit(keys, spec, tile=128).permutation
    P = jax.nn.one_hot(perm, n, dtype=jnp.float32)            # out = P^T @ v
    dense_loss = lambda v: (jnp.einsum("ij,i->j", P, v) * w).sum()
    g_ref = jax.grad(dense_loss)(vals)

    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-6)
    # and the closed form: d_in[i] = w[perm[i]]
    np.testing.assert_allclose(np.asarray(g), np.asarray(w)[np.asarray(perm)], rtol=1e-6)


def test_grad_through_float_keys_reorder():
    """Float KEYS are differentiated through the same inverse gather."""
    n = 300
    spec = ops.even_buckets(0.0, 1.0, 8)
    fkeys = jnp.asarray(np.random.RandomState(10).rand(n).astype(np.float32))
    vals = jnp.ones((n,), jnp.float32)
    w = jnp.asarray(np.random.RandomState(11).rand(n).astype(np.float32))
    g = jax.grad(
        lambda k: (ops.multisplit_key_value(k, vals, spec, tile=128).keys * w).sum()
    )(fkeys)
    perm = np.asarray(ops.multisplit(fkeys, spec, tile=128).permutation)
    np.testing.assert_allclose(np.asarray(g), np.asarray(w)[perm], rtol=1e-6)


def test_vmap_of_grad():
    b, n, spec = 4, 256, ops.delta_buckets(8, 2**30)
    keys = _keys(b * n, seed=12).reshape(b, n)
    vals = jnp.asarray(np.random.RandomState(13).rand(b, n).astype(np.float32))
    w = jnp.asarray(np.random.RandomState(14).rand(b, n).astype(np.float32))
    g = jax.vmap(
        jax.grad(lambda v, k, ww: (ops.multisplit_key_value(k, v, spec, tile=128).values * ww).sum()),
    )(vals, keys, w)
    for i in range(b):
        perm = np.asarray(ops.multisplit(keys[i], spec, tile=128).permutation)
        np.testing.assert_allclose(np.asarray(g[i]), np.asarray(w[i])[perm], rtol=1e-6)


def test_grad_under_jit():
    n, spec = 512, ops.delta_buckets(8, 2**30)
    keys = _keys(n, seed=15)
    vals = jnp.asarray(np.random.RandomState(16).rand(n).astype(np.float32))
    g = jax.jit(jax.grad(
        lambda v: (ops.multisplit_key_value(keys, v, spec, tile=128).values ** 2).sum()
    ))(vals)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(vals), rtol=1e-6)


# ---------------------------------------------------------------------------
# zero retraces across equal spec instances (the jit-retrace satellite)
# ---------------------------------------------------------------------------

def test_ops_multisplit_zero_retrace_across_equal_specs():
    keys = _keys(512, seed=17)
    traces = []

    @jax.jit
    def f(keys, spec):
        traces.append(1)
        return ops.multisplit(keys, spec, tile=128).bucket_counts

    c1 = f(keys, ops.delta_buckets(16, 2**30))
    c2 = f(keys, ops.delta_buckets(16, 2**30))    # a DIFFERENT equal instance
    assert len(traces) == 1, f"equal specs retraced: {len(traces)} traces"
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))

    f(keys, ops.delta_buckets(8, 2**30))          # unequal spec: new trace
    assert len(traces) == 2


def test_tile_cache_keyed_by_spec_value_not_object_id():
    """Equal spec instances must resolve through ONE tile-cache entry — the
    cache key derives from the spec VALUE (shape), never from id(spec)."""
    clear_tile_cache()
    from repro.core.pipeline import make_plan

    tiles = set()
    for _ in range(10):
        p = make_plan(1 << 15, 32, backend="vmap",
                      bucket_fn=ops.delta_buckets(32, 2**30))
        tiles.add(p.tile)
    assert len(tiles) == 1
    assert len(_TILE_CACHE) == 1, dict(_TILE_CACHE)


# ---------------------------------------------------------------------------
# zero materialized labels for non-callable specs (the tentpole guarantee)
# ---------------------------------------------------------------------------

def _forbid_host_labels(monkeypatch):
    def boom(self, keys):
        raise AssertionError(
            f"plan materialized host-side labels for spec {self.bucket_fn!r}"
        )

    monkeypatch.setattr(plan_spec.MultisplitPlan, "_host_labels", boom)


@pytest.mark.parametrize("backend", TILED_BACKENDS)
@pytest.mark.parametrize("spec", FUSABLE_SPECS, ids=lambda s: s.name)
def test_non_callable_specs_never_materialize_labels(backend, spec, monkeypatch):
    """Acceptance: on label-fusing backends, every declarative spec runs the
    FULL pipeline — flat, key-value, batched (via vmap), segmented, partial
    modes — without the n-sized label array ever existing."""
    _forbid_host_labels(monkeypatch)
    n = 1100
    keys = _spec_keys(spec, n, seed=21)
    vals = jnp.arange(n, dtype=jnp.int32)
    ref = multisplit_ref(keys, spec, vals)

    out = ops.multisplit(keys, spec, vals, tile=256, backend=backend)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.values), np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(out.permutation), np.asarray(ref.permutation))

    for mode in ("counts_only", "positions_only"):
        pm = ops.multisplit(keys, spec, tile=256, backend=backend, mode=mode)
        np.testing.assert_array_equal(
            np.asarray(pm.bucket_counts), np.asarray(ref.bucket_counts)
        )

    b = 4
    kb = _spec_keys(spec, b * 256, seed=22).reshape(b, 256)
    vm = jax.vmap(lambda k: ops.multisplit(k, spec, tile=128, backend=backend))(kb)
    assert vm.keys.shape == (b, 256)

    seg = ops.segmented_multisplit(
        keys, spec, [0, 400, 400, 900], tile=256, backend=backend
    )
    assert seg.bucket_counts.shape == (4, spec.num_buckets)


@pytest.mark.parametrize("backend", TILED_BACKENDS)
def test_chained_radix_sort_never_materializes_labels(backend, monkeypatch):
    """The RadixPipeline digit loop is one BitfieldSpec per pass with zero
    label traffic — on EVERY label-fusing backend (vmap included; pre-PR-4
    only the pallas kernels fused the digit)."""
    _forbid_host_labels(monkeypatch)
    keys = _keys(3000, seed=23, hi=2**32)
    vals = jnp.arange(3000, dtype=jnp.int32)
    ks, vs = ops.radix_sort(keys, vals, radix_bits=8, backend=backend, tile=512)
    order = np.argsort(np.asarray(keys), kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(keys)[order])
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vals)[order])


def test_callable_spec_does_materialize_labels(monkeypatch):
    """Sanity for the counter above: the CallableSpec escape hatch IS routed
    through the single _host_labels door."""
    calls = []
    orig = plan_spec.MultisplitPlan._host_labels

    def spy(self, keys):
        calls.append(self.bucket_fn.name)
        return orig(self, keys)

    monkeypatch.setattr(plan_spec.MultisplitPlan, "_host_labels", spy)
    keys = _keys(500, seed=24)
    spec = ops.from_fn(lambda u: (u % 5).astype(jnp.int32), 5, name="mod5")
    out = ops.multisplit(keys, spec, tile=128)
    assert calls == ["mod5"]
    ref = multisplit_ref(keys, spec)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))


def test_segmented_values_with_partial_mode_raises_cleanly():
    """The public op's own guard, not the plan layer's key_value message
    (key_value is not a parameter of the facade)."""
    with pytest.raises(ValueError, match="never touches values"):
        ops.segmented_multisplit(
            _keys(100), ops.delta_buckets(4), [0, 50],
            jnp.arange(100, dtype=jnp.int32), mode="counts_only",
        )
    with pytest.raises(ValueError, match="never touches values"):
        ops.multisplit(
            _keys(100), ops.delta_buckets(4),
            jnp.arange(100, dtype=jnp.int32), mode="counts_only",
        )


def test_callable_specs_are_not_pinned_in_the_op_cache():
    """CallableSpec hashes by function identity: caching it would pin the
    closure (and captured arrays) while never hitting — callables take the
    uncached builder."""
    from repro.ops import _flat_op_cached

    keys = _keys(256, seed=30)
    before = _flat_op_cached.cache_info()
    for _ in range(3):
        spec = ops.from_fn(lambda u: (u % 3).astype(jnp.int32), 3)
        ops.multisplit(keys, spec, tile=128)
    after = _flat_op_cached.cache_info()
    assert after.currsize == before.currsize
    # ...while value-hashable specs hit the cache across instances
    ops.multisplit(keys, ops.delta_buckets(5), tile=128)
    ops.multisplit(keys, ops.delta_buckets(5), tile=128)
    info = _flat_op_cached.cache_info()
    assert info.currsize == after.currsize + 1 and info.hits > before.hits


def test_off_width_keys_fall_back_to_host_labels_in_partial_modes():
    """Kernel backends are 32-bit-lane programs: fusable specs over non-32-bit
    keys silently fall back to materialized labels in the partial modes
    (reorder still raises, as before)."""
    keys = jnp.asarray(np.random.RandomState(25).randint(0, 8, 600, dtype=np.uint16))
    spec = ops.identity_buckets(8)
    co = ops.multisplit(keys, spec, tile=128, backend="pallas-interpret",
                        mode="counts_only")
    np.testing.assert_array_equal(
        np.asarray(co.bucket_counts), np.bincount(np.asarray(keys), minlength=8)
    )
    with pytest.raises(ValueError):
        ops.multisplit(keys, spec, tile=128, backend="pallas-interpret")
