"""Property-based cross-backend equivalence harness (DESIGN.md §9).

Hypothesis strategies draw over the whole plan-layer configuration space —
``(n, m, method, backend, key-only/key-value, batch/segment shapes)`` — and
assert the algebraic properties that define multisplit (paper §3.1):

* the output is a PERMUTATION of the input (multiset preserved, the
  ``permutation`` field is a bijection);
* the permutation is STABLE and bucket-contiguous;
* ``bucket_counts`` equals the input histogram, ``bucket_starts`` its
  exclusive prefix sum;
* every backend (reference ↔ vmap ↔ pallas-interpret) produces bitwise
  identical results;
* batched / segmented plans are bitwise identical to running each row /
  ragged segment through an independent flat plan.

Runs under the real ``hypothesis`` package when installed, and under the
deterministic fallback ``tests/_hypothesis_shim.py`` otherwise (CI exercises
both).
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.identifiers import (
    delta_buckets,
    even_buckets,
    identity_buckets,
    radix_buckets,
    range_buckets,
)
from repro.core.multisplit import (
    batched_multisplit,
    multisplit,
    multisplit_ref,
    segmented_multisplit,
)
from repro.core.sort import radix_sort, segmented_radix_sort

TILED_BACKENDS = ("vmap", "pallas-interpret")
ALL_BACKENDS = ("reference",) + TILED_BACKENDS
METHODS = ("dms", "wms", "bms")


def _keys(n, seed, hi=2**30):
    return jnp.asarray(
        np.random.RandomState(seed % (2**31 - 1)).randint(0, hi, size=n, dtype=np.uint32)
    )


def _assert_result_equal(out, ref, key_value):
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.bucket_counts), np.asarray(ref.bucket_counts))
    np.testing.assert_array_equal(np.asarray(out.bucket_starts), np.asarray(ref.bucket_starts))
    np.testing.assert_array_equal(np.asarray(out.permutation), np.asarray(ref.permutation))
    if key_value:
        np.testing.assert_array_equal(np.asarray(out.values), np.asarray(ref.values))
    else:
        assert out.values is None


def _assert_invariants(out, keys, bf):
    """The §3.1 definition, checked against numpy from scratch."""
    m = bf.num_buckets
    keys_np = np.asarray(keys)
    ids_np = np.asarray(bf(keys))
    n = keys_np.shape[0]
    perm = np.asarray(out.permutation)
    counts = np.asarray(out.bucket_counts)
    starts = np.asarray(out.bucket_starts)
    # permutation: a bijection of [0, n)
    np.testing.assert_array_equal(np.sort(perm), np.arange(n))
    # counts == histogram; starts == exclusive prefix
    np.testing.assert_array_equal(counts, np.bincount(ids_np, minlength=m))
    np.testing.assert_array_equal(starts, np.cumsum(counts) - counts)
    # stable bucket-major output: exactly the stable argsort by bucket id
    order = np.argsort(ids_np, kind="stable")
    np.testing.assert_array_equal(np.asarray(out.keys), keys_np[order])
    # permutation consistent with the reordered keys
    np.testing.assert_array_equal(keys_np, np.asarray(out.keys)[perm])


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(0, 700),
    m=st.integers(1, 40),
    method=st.sampled_from(METHODS),
    key_value=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_flat_invariants_and_backend_agreement(n, m, method, key_value, seed):
    keys = _keys(n, seed)
    vals = jnp.arange(n, dtype=jnp.int32) if key_value else None
    bf = delta_buckets(m, 2**30)
    ref = multisplit_ref(keys, bf, vals)
    _assert_invariants(ref, keys, bf)
    for backend in TILED_BACKENDS:
        out = multisplit(keys, bf, vals, method=method, tile=128, backend=backend)
        _assert_result_equal(out, ref, key_value)


@settings(max_examples=12, deadline=None)
@given(
    kind=st.sampled_from(("delta", "range", "bitfield", "identity", "even")),
    n=st.integers(0, 600),
    m=st.integers(1, 32),
    splitters=st.lists(st.integers(0, 2**30), min_size=1, max_size=8),
    bits=st.integers(1, 6),
    pass_idx=st.integers(0, 3),
    method=st.sampled_from(METHODS),
    seed=st.integers(0, 2**16),
)
def test_sampled_bucketspecs_invariants_and_backend_agreement(
    kind, n, m, splitters, bits, pass_idx, method, seed
):
    """ISSUE 4: the §3.1 invariants and bitwise backend agreement hold for
    EVERY declarative BucketSpec kind — delta, splitter/range, radix
    bitfield, identity, and even float buckets — all of which run
    label-fused (no materialized label array) on the tiled backends."""
    keys = _keys(n, seed)
    if kind == "delta":
        bf = delta_buckets(m, 2**30)
    elif kind == "range":
        bf = range_buckets(splitters)
    elif kind == "bitfield":
        bf = radix_buckets(pass_idx, bits)
    elif kind == "identity":
        bf = identity_buckets(m)
        keys = (keys % jnp.uint32(m)).astype(jnp.uint32)
    else:
        bf = even_buckets(0.0, float(2**30), m)
        keys = keys.astype(jnp.float32)
    ref = multisplit_ref(keys, bf)
    _assert_invariants(ref, keys, bf)
    for backend in TILED_BACKENDS:
        out = multisplit(keys, bf, method=method, tile=128, backend=backend)
        _assert_result_equal(out, ref, False)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 5),
    n=st.integers(0, 300),
    m=st.integers(1, 16),
    method=st.sampled_from(METHODS),
    backend=st.sampled_from(ALL_BACKENDS),
    key_value=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_batched_matches_independent_rows(b, n, m, method, backend, key_value, seed):
    keys = _keys(b * n, seed).reshape(b, n)
    vals = (
        jnp.arange(b * n, dtype=jnp.int32).reshape(b, n) if key_value else None
    )
    bf = delta_buckets(m, 2**30)
    out = batched_multisplit(keys, bf, vals, method=method, tile=128, backend=backend)
    assert out.keys.shape == (b, n)
    assert out.bucket_counts.shape == (b, m)
    for i in range(b):
        ref = multisplit_ref(keys[i], bf, vals[i] if key_value else None)
        np.testing.assert_array_equal(np.asarray(out.keys[i]), np.asarray(ref.keys))
        np.testing.assert_array_equal(
            np.asarray(out.bucket_counts[i]), np.asarray(ref.bucket_counts)
        )
        np.testing.assert_array_equal(
            np.asarray(out.bucket_starts[i]), np.asarray(ref.bucket_starts)
        )
        np.testing.assert_array_equal(
            np.asarray(out.permutation[i]), np.asarray(ref.permutation)
        )
        if key_value:
            np.testing.assert_array_equal(np.asarray(out.values[i]), np.asarray(ref.values))


@settings(max_examples=8, deadline=None)
@given(
    lengths=st.lists(st.integers(0, 200), min_size=1, max_size=6),
    m=st.integers(1, 16),
    method=st.sampled_from(METHODS),
    backend=st.sampled_from(ALL_BACKENDS),
    key_value=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_segmented_matches_independent_segments(lengths, m, method, backend, key_value, seed):
    """The acceptance criterion: a segmented multisplit over ragged segments
    (empty ones included) is bitwise identical to independent flat calls."""
    lengths = np.asarray(lengths, np.int64)
    n = int(lengths.sum())
    starts = np.concatenate([[0], np.cumsum(lengths)[:-1]]).astype(np.int32)
    ends = np.concatenate([starts[1:], [n]])
    keys = _keys(n, seed)
    vals = jnp.arange(n, dtype=jnp.int32) if key_value else None
    bf = delta_buckets(m, 2**30)
    out = segmented_multisplit(
        keys, bf, starts, vals, method=method, tile=128, backend=backend
    )
    assert out.bucket_counts.shape == (len(lengths), m)
    for i, (a, e) in enumerate(zip(starts, ends)):
        ref = multisplit_ref(keys[a:e], bf, vals[a:e] if key_value else None)
        np.testing.assert_array_equal(np.asarray(out.keys[a:e]), np.asarray(ref.keys))
        np.testing.assert_array_equal(
            np.asarray(out.bucket_counts[i]), np.asarray(ref.bucket_counts)
        )
        np.testing.assert_array_equal(
            np.asarray(out.bucket_starts[i]), np.asarray(ref.bucket_starts)
        )
        np.testing.assert_array_equal(
            np.asarray(out.permutation[a:e]), np.asarray(ref.permutation)
        )
        if key_value:
            np.testing.assert_array_equal(np.asarray(out.values[a:e]), np.asarray(ref.values))


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(0, 700),
    m=st.integers(1, 24),
    method=st.sampled_from(METHODS),
    backend=st.sampled_from(ALL_BACKENDS),
    seed=st.integers(0, 2**16),
)
def test_counts_and_positions_only_match_full_flat(n, m, method, backend, seed):
    """Partial-pipeline invariants (DESIGN.md §10): counts_only returns the
    full pipeline's counts/starts bitwise (and nothing else); the
    positions_only permutation applied host-side reproduces the fused
    reorder — on every CPU-testable backend."""
    keys = _keys(n, seed)
    bf = delta_buckets(m, 2**30)
    full = multisplit(keys, bf, method=method, tile=128, backend=backend)

    co = multisplit(keys, bf, method=method, tile=128, backend=backend,
                    mode="counts_only")
    assert co.keys is None and co.values is None and co.permutation is None
    np.testing.assert_array_equal(np.asarray(co.bucket_counts), np.asarray(full.bucket_counts))
    np.testing.assert_array_equal(np.asarray(co.bucket_starts), np.asarray(full.bucket_starts))

    po = multisplit(keys, bf, method=method, tile=128, backend=backend,
                    mode="positions_only")
    assert po.keys is None and po.values is None
    np.testing.assert_array_equal(np.asarray(po.permutation), np.asarray(full.permutation))
    np.testing.assert_array_equal(np.asarray(po.bucket_counts), np.asarray(full.bucket_counts))
    reordered = np.zeros(n, dtype=np.asarray(keys).dtype)
    reordered[np.asarray(po.permutation)] = np.asarray(keys)   # host-side apply
    np.testing.assert_array_equal(reordered, np.asarray(full.keys))


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 4),
    n=st.integers(0, 250),
    m=st.integers(1, 16),
    backend=st.sampled_from(ALL_BACKENDS),
    seed=st.integers(0, 2**16),
)
def test_counts_and_positions_only_match_full_batched(b, n, m, backend, seed):
    keys = _keys(b * n, seed).reshape(b, n)
    bf = delta_buckets(m, 2**30)
    full = batched_multisplit(keys, bf, tile=128, backend=backend)
    co = batched_multisplit(keys, bf, tile=128, backend=backend, mode="counts_only")
    assert co.keys is None and co.permutation is None
    np.testing.assert_array_equal(np.asarray(co.bucket_counts), np.asarray(full.bucket_counts))
    np.testing.assert_array_equal(np.asarray(co.bucket_starts), np.asarray(full.bucket_starts))
    po = batched_multisplit(keys, bf, tile=128, backend=backend, mode="positions_only")
    np.testing.assert_array_equal(np.asarray(po.permutation), np.asarray(full.permutation))
    for i in range(b):
        reordered = np.zeros(n, dtype=np.asarray(keys).dtype)
        reordered[np.asarray(po.permutation[i])] = np.asarray(keys[i])
        np.testing.assert_array_equal(reordered, np.asarray(full.keys[i]))


@settings(max_examples=8, deadline=None)
@given(
    lengths=st.lists(st.integers(0, 150), min_size=1, max_size=5),
    m=st.integers(1, 16),
    backend=st.sampled_from(ALL_BACKENDS),
    seed=st.integers(0, 2**16),
)
def test_counts_and_positions_only_match_full_segmented(lengths, m, backend, seed):
    lengths = np.asarray(lengths, np.int64)
    n = int(lengths.sum())
    starts = np.concatenate([[0], np.cumsum(lengths)[:-1]]).astype(np.int32)
    ends = np.concatenate([starts[1:], [n]])
    keys = _keys(n, seed)
    bf = delta_buckets(m, 2**30)
    full = segmented_multisplit(keys, bf, starts, tile=128, backend=backend)
    co = segmented_multisplit(keys, bf, starts, tile=128, backend=backend,
                              mode="counts_only")
    assert co.keys is None and co.permutation is None
    np.testing.assert_array_equal(np.asarray(co.bucket_counts), np.asarray(full.bucket_counts))
    np.testing.assert_array_equal(np.asarray(co.bucket_starts), np.asarray(full.bucket_starts))
    po = segmented_multisplit(keys, bf, starts, tile=128, backend=backend,
                              mode="positions_only")
    np.testing.assert_array_equal(np.asarray(po.permutation), np.asarray(full.permutation))
    keys_np = np.asarray(keys)
    perm = np.asarray(po.permutation)
    for a, e in zip(starts, ends):                 # segment-local host-side apply
        reordered = np.zeros(e - a, dtype=keys_np.dtype)
        reordered[perm[a:e]] = keys_np[a:e]
        np.testing.assert_array_equal(reordered, np.asarray(full.keys[a:e]))


@settings(max_examples=6, deadline=None)
@given(
    lengths=st.lists(st.integers(0, 150), min_size=1, max_size=5),
    backend=st.sampled_from(TILED_BACKENDS),
    seed=st.integers(0, 2**16),
)
def test_segmented_radix_sort_property(lengths, backend, seed):
    """Every ragged segment independently stable-sorted in one pass
    sequence, for ANY segment shape."""
    lengths = np.asarray(lengths, np.int64)
    n = int(lengths.sum())
    starts = np.concatenate([[0], np.cumsum(lengths)[:-1]]).astype(np.int32)
    ends = np.concatenate([starts[1:], [n]])
    keys = _keys(n, seed, hi=2**16)
    vals = jnp.arange(n, dtype=jnp.int32)
    ks, vs = segmented_radix_sort(
        keys, starts, vals, radix_bits=4, key_bits=16, tile=128, backend=backend
    )
    for a, e in zip(starts, ends):
        seg = np.asarray(keys[a:e])
        order = np.argsort(seg, kind="stable")
        np.testing.assert_array_equal(np.asarray(ks[a:e]), seg[order])
        np.testing.assert_array_equal(np.asarray(vs[a:e]), np.asarray(vals[a:e])[order])


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(1, 4),
    n=st.integers(0, 200),
    backend=st.sampled_from(TILED_BACKENDS),
    seed=st.integers(0, 2**16),
)
def test_batched_radix_sort_property(b, n, backend, seed):
    """2-D radix_sort row-sorts == numpy row-sorts, for ANY batch shape."""
    keys = _keys(b * n, seed, hi=2**16).reshape(b, n)
    ks, _ = radix_sort(keys, radix_bits=4, key_bits=16, tile=128, backend=backend)
    np.testing.assert_array_equal(np.asarray(ks), np.sort(np.asarray(keys), axis=1))
