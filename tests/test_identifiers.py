"""BucketSpec layer (ISSUE 4): value hashing / equality, pytree staticness,
the range_buckets validation + dtype-max fixes, pad-key invariants, and the
BucketIdentifier deprecation shim."""

import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.identifiers import (
    BitfieldSpec,
    BucketIdentifier,
    BucketSpec,
    CallableSpec,
    DeltaSpec,
    EvenSpec,
    IdentitySpec,
    RangeSpec,
    as_spec,
    delta_buckets,
    even_buckets,
    from_fn,
    identity_buckets,
    radix_buckets,
    range_buckets,
)

ALL_SPECS = [
    delta_buckets(32, 2**30),
    identity_buckets(16),
    radix_buckets(1, 8),
    range_buckets([100, 10_000, 2**29]),
    even_buckets(0.0, 1024.0, 64),
]


# ---------------------------------------------------------------------------
# Value hashing / equality (the jit-retrace satellite)
# ---------------------------------------------------------------------------

def test_equal_constructions_are_equal_and_hash_equal():
    pairs = [
        (delta_buckets(32, 2**30), DeltaSpec(32, 2**30)),
        (identity_buckets(16), IdentitySpec(16)),
        (radix_buckets(2, 7), BitfieldSpec(14, 7)),
        (range_buckets([3, 1, 2]), RangeSpec((1, 2, 3))),
        (even_buckets(0, 10, 5), EvenSpec(0.0, 10.0, 5)),
    ]
    for a, b in pairs:
        assert a == b and hash(a) == hash(b), (a, b)
    assert delta_buckets(32) != delta_buckets(16)
    assert BitfieldSpec(0, 8) != BitfieldSpec(8, 8)


def test_specs_are_frozen_and_pytree_static():
    for spec in ALL_SPECS:
        with pytest.raises(dataclasses.FrozenInstanceError):
            spec.num_buckets = 3  # type: ignore[misc]
        leaves, treedef = jax.tree_util.tree_flatten(spec)
        assert leaves == []                      # no traced children
        assert jax.tree_util.tree_unflatten(treedef, []) == spec


def test_equal_specs_share_one_jit_trace():
    """THE retrace regression: two equal spec instances must not retrace,
    whether the spec rides as a pytree argument or a static argument."""
    keys = jnp.asarray(np.random.RandomState(0).randint(0, 2**30, 512, dtype=np.uint32))

    traces = []

    @jax.jit
    def as_pytree(keys, spec):
        traces.append(1)
        return spec.emit(keys).sum()

    as_pytree(keys, delta_buckets(32))
    as_pytree(keys, DeltaSpec(32, 2**30))
    assert len(traces) == 1

    traces2 = []

    def g(keys, spec):
        traces2.append(1)
        return spec.emit(keys).sum()

    jg = jax.jit(g, static_argnums=1)
    jg(keys, range_buckets([10, 20]))
    jg(keys, range_buckets([20, 10]))             # sorted-equal
    assert len(traces2) == 1

    # distinct specs DO retrace (sanity that the counter works)
    jg(keys, range_buckets([10, 30]))
    assert len(traces2) == 2


# ---------------------------------------------------------------------------
# range_buckets: validation, sorting, dtype-max keys (ISSUE 4 satellite)
# ---------------------------------------------------------------------------

def test_range_buckets_sorts_splitters():
    assert range_buckets([70, 10, 30]).splitters == (10, 30, 70)
    u = jnp.asarray([0, 10, 29, 30, 69, 70, 95], jnp.uint32)
    got = range_buckets([70, 10, 30])(u)
    want = range_buckets([10, 30, 70])(u)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), [0, 1, 1, 2, 2, 3, 3])


def test_range_buckets_validates():
    with pytest.raises(ValueError):
        range_buckets(np.zeros((2, 2)))
    with pytest.raises(ValueError):
        range_buckets([1.0, float("nan")])


def test_range_buckets_dtype_max_keys_no_overflow():
    """uint32 keys above the last splitter — all the way to the dtype max —
    must land in the LAST bucket (the pre-PR-4 searchsorted promoted mixed
    dtypes and wrapped large uint32 keys negative)."""
    spec = range_buckets([100, 1000])
    u = jnp.asarray([99, 100, 1000, 2**31, 2**32 - 1], jnp.uint32)
    np.testing.assert_array_equal(np.asarray(spec(u)), [0, 1, 2, 2, 2])
    # signed keys with the same spec
    i = jnp.asarray([-5, 99, 2**31 - 1], jnp.int32)
    np.testing.assert_array_equal(np.asarray(spec(i)), [0, 0, 2])


def test_range_buckets_matches_searchsorted_on_floats():
    rng = np.random.RandomState(3)
    keys = jnp.asarray(rng.uniform(0, 1000, 5000).astype(np.float32))
    sp = np.sort(rng.uniform(0, 1000, 15)).astype(np.float32)
    got = range_buckets(sp)(keys)
    want = jnp.searchsorted(jnp.asarray(sp), keys, side="right").astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_range_emit_in_kernel_matches_emit():
    """The unrolled in-kernel form and the host-side binary search are the
    same function (incl. duplicate splitters and dtype-extreme keys)."""
    spec = range_buckets([10, 10, 30, 70, 70])
    for keys in (
        jnp.asarray([0, 9, 10, 11, 30, 69, 70, 71, 2**32 - 1], jnp.uint32),
        jnp.asarray(np.random.RandomState(0).randint(0, 100, 500), jnp.int32),
        jnp.asarray(np.random.RandomState(1).uniform(0, 100, 500), jnp.float32),
    ):
        np.testing.assert_array_equal(
            np.asarray(spec.emit(keys)), np.asarray(spec.emit_in_kernel(keys))
        )


def test_range_splitters_above_int32_max_on_uint32_keys():
    """Splitters in the upper half of the uint32 domain must not weak-type
    into an int32 overflow on either emit form (regression)."""
    spec = range_buckets([2**31 + 5])
    u = jnp.asarray([5, 2**31 + 4, 2**31 + 5, 2**32 - 1], jnp.uint32)
    np.testing.assert_array_equal(np.asarray(spec.emit(u)), [0, 0, 1, 1])
    np.testing.assert_array_equal(np.asarray(spec.emit_in_kernel(u)), [0, 0, 1, 1])


def test_range_splitters_out_of_key_dtype_range_rejected():
    """A splitter no key can reach would make the last bucket unreachable
    (and break the pad invariant): rejected at emit, not silently clamped."""
    spec = range_buckets([2**33])
    with pytest.raises(ValueError, match="out of range"):
        spec.emit(jnp.asarray([0, 2**32 - 1], jnp.uint32))
    with pytest.raises(ValueError, match="out of range"):
        range_buckets([-1]).emit(jnp.asarray([0], jnp.uint32))
    # float keys: representable, no rejection
    assert int(spec.emit(jnp.asarray([1.0], jnp.float32))[0]) == 0


def test_range_buckets_fractional_splitters_int_keys():
    """Fractional splitters with integer keys compare in float (old
    promotion semantics), not by truncated-integer splitters."""
    spec = range_buckets([10.5])
    np.testing.assert_array_equal(
        np.asarray(spec(jnp.asarray([10, 11], jnp.int32))), [0, 1]
    )


# ---------------------------------------------------------------------------
# pad keys: every spec's pad lands in the LAST bucket
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
@pytest.mark.parametrize("dtype", [jnp.uint32, jnp.int32, jnp.float32])
def test_pad_key_lands_in_last_bucket(spec, dtype):
    if spec.name.startswith("even") and dtype != jnp.float32:
        pytest.skip("even buckets are float specs")
    if spec.name.startswith(("delta", "radix")) and dtype == jnp.float32:
        pytest.skip("integer-domain specs")
    pad = jnp.full((4,), spec.pad_key(dtype), dtype)
    np.testing.assert_array_equal(
        np.asarray(spec.emit(pad)), np.full(4, spec.num_buckets - 1)
    )


def test_bitfield_pad_key_is_all_ones_every_pass():
    """The chained-radix invariant: ONE pad key whose digit is m-1 in EVERY
    pass of the schedule."""
    for dtype in (jnp.uint32, jnp.int32):
        pad = jnp.full((1,), BitfieldSpec(0, 8).pad_key(dtype), dtype)
        for shift in range(0, 32, 8):
            assert int(BitfieldSpec(shift, 8).emit(pad)[0]) == 255


# ---------------------------------------------------------------------------
# ISSUE 7 satellites: NaN routing (S1) and float-key rejection (S4)
# ---------------------------------------------------------------------------

def test_even_spec_nan_routes_to_last_bucket():
    """NaN fails every comparison, so the old clip left it wherever the
    scaled id landed (ISSUE 7 S1). It must route DETERMINISTICALLY to the
    last bucket — the same one the +inf pad key lands in."""
    s = EvenSpec(0.0, 1.0, 8)
    keys = jnp.asarray(
        [0.1, float("nan"), 2.0, -1.0, float("inf"), float("-inf")],
        jnp.float32,
    )
    np.testing.assert_array_equal(np.asarray(s.emit(keys)), [0, 7, 7, 0, 7, 0])
    pad = jnp.full((2,), s.pad_key(jnp.float32), jnp.float32)
    np.testing.assert_array_equal(np.asarray(s.emit(pad)), [7, 7])


def test_bitfield_spec_rejects_float_keys():
    """BitfieldSpec.pad_key on a float dtype used to return -1 (the int cast
    of the float max) and emit produced garbage digits (ISSUE 7 S4): both
    must refuse float keys loudly."""
    s = BitfieldSpec(0, 8)
    with pytest.raises(TypeError, match="integer keys"):
        s.pad_key(jnp.float32)
    with pytest.raises(TypeError, match="integer keys"):
        s.emit(jnp.ones((4,), jnp.float32))
    # integer dtypes keep working
    assert int(s.pad_key(jnp.uint32)) == 0xFFFFFFFF


# ---------------------------------------------------------------------------
# the BucketIdentifier deprecation shim + as_spec
# ---------------------------------------------------------------------------

def test_bucket_identifier_shim_warning_clean():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        from repro.core.identifiers import BucketIdentifier as BI  # noqa: F401

        bi = BI(lambda u: (u % 3).astype(jnp.int32), 3, name="mod3")
        out = bi(jnp.arange(9, dtype=jnp.uint32))
    assert not caught, [str(w.message) for w in caught]
    assert isinstance(bi, CallableSpec) and isinstance(bi, BucketSpec)
    assert bi.name == "mod3" and bi.num_buckets == 3 and not bi.fusable
    np.testing.assert_array_equal(np.asarray(out), np.arange(9) % 3)


def test_bucket_identifier_shim_runs_through_multisplit():
    from repro.core.multisplit import multisplit, multisplit_ref

    keys = jnp.asarray(np.random.RandomState(1).randint(0, 1000, 700, dtype=np.uint32))
    bi = BucketIdentifier(lambda u: (u % 7).astype(jnp.int32), 7)
    out = multisplit(keys, bi, tile=128)
    ref = multisplit_ref(keys, bi)
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))


def test_as_spec():
    s = delta_buckets(4)
    assert as_spec(s) is s
    assert as_spec(from_fn(lambda u: u, 4)).num_buckets == 4
    with pytest.raises(TypeError):
        as_spec(lambda u: u)                     # bare callable: no num_buckets
    with pytest.raises(TypeError):
        as_spec(7)


def test_callable_spec_pad_key_raises():
    """An arbitrary fn cannot honor the pad-lands-in-bucket-m-1 contract:
    pad_key must refuse loudly, not silently pad with the dtype max (the
    layout pads CallableSpec plans on the label side only)."""
    with pytest.raises(NotImplementedError):
        from_fn(lambda u: u % 3, 3).pad_key(jnp.uint32)


def test_callable_specs_hash_by_function_identity():
    fn = lambda u: (u & 1).astype(jnp.int32)     # noqa: E731
    assert from_fn(fn, 2) == from_fn(fn, 2)
    assert from_fn(fn, 2) != from_fn(lambda u: (u & 1).astype(jnp.int32), 2)
