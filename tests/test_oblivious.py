"""Gather-free compiled-path kernels (DESIGN.md §15).

Three proof obligations, none of which needs a TPU:

1. **The jaxpr lint** — every Pallas kernel entry point, traced with its
   compiled-path (oblivious) defaults, contains no gather/scatter/tensor-
   indexed-slice primitive inside any ``pallas_call`` body; and the lint
   itself is trustworthy because it FAILS on fixture kernels that
   deliberately gather and scatter.
2. **Bitwise identity** — the oblivious bodies (one-hot selects, 16-bit
   rank planes, permutation matmuls) return exactly the arrays the gather
   forms return, across families × layouts × digit splits × key-value.
3. **Dispatch** — ``pallas`` means compiled-when-available:
   ``Backend.compiled`` × TPU presence × ``REPRO_INTERPRET`` resolve the
   per-call ``interpret`` flag; ``pallas-interpret`` stays pinned.
"""

import functools

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.identifiers import BitfieldSpec, RangeSpec
from repro.core.pipeline import get_backend
from repro.kernels import lint as klint
from repro.kernels import multisplit_tile as mst
from repro.kernels import ops as kops
from repro.kernels.common import (
    _dense_local_offsets,
    fused2_counts_body,
    fused2_postscan_body,
    packed_layout,
    packed_local_offsets,
    packed_positions_body,
    packed_postscan_body,
)


def _ids(t, m, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randint(0, m, t, dtype=np.int32))


def _keys(t, seed=0, hi=2**32):
    return jnp.asarray(
        np.random.RandomState(seed).randint(0, hi, t, dtype=np.uint64).astype(np.uint32)
    )


# ---------------------------------------------------------------------------
# 1a. The lint passes on every registered entry point
# ---------------------------------------------------------------------------

_ENTRY_POINTS = sorted(klint.kernel_entry_points())


@pytest.mark.parametrize("name", _ENTRY_POINTS)
def test_lint_entry_point_is_gather_free(name):
    r = klint.kernel_entry_points()[name]()
    assert r.pallas_calls >= 1, f"{name}: no pallas_call traced"
    assert not r.violations, f"{name}: forbidden primitives {r.violations}"


def test_lint_registry_covers_every_family():
    prefixes = {n.split("/")[0] for n in _ENTRY_POINTS}
    assert {"dense", "seg", "spec", "seg_spec", "packed", "fused2",
            "radix", "seg_radix"} <= prefixes


def test_lint_report_lists_primitives():
    rep = klint.lint_report()
    assert "dense/histograms" in rep and "fused2/fused_kv_packed" in rep
    assert "FORBIDDEN" not in rep


# ---------------------------------------------------------------------------
# 1b. The lint FAILS on kernels that really gather / scatter (satellite 3)
# ---------------------------------------------------------------------------

def _gather_fixture_kernel(ids_ref, incl_ref, out_ref):
    ids = ids_ref[0, :]
    incl = incl_ref[0, :]
    out_ref[0, :] = jnp.take_along_axis(incl, ids, axis=0)


def _scatter_fixture_kernel(ids_ref, keys_ref, out_ref):
    ids = ids_ref[0, :]
    out_ref[0, :] = jnp.zeros_like(keys_ref[0, :]).at[ids].set(keys_ref[0, :])


def _fixture_call(kernel, *args):
    t = args[0].shape[1]
    row = pl.BlockSpec((1, t), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(args[0].shape[0],),
        in_specs=[row] * len(args),
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct(args[0].shape, args[-1].dtype),
        interpret=True,
    )(*args)


def test_lint_catches_in_kernel_gather():
    ids = jnp.zeros((1, 128), jnp.int32)
    r = klint.lint_fn(
        lambda i, x: _fixture_call(_gather_fixture_kernel, i, x),
        ids, jnp.zeros((1, 128), jnp.int32), name="fixture/gather",
    )
    assert r.pallas_calls == 1
    assert "gather" in r.violations


def test_lint_catches_in_kernel_scatter():
    ids = jnp.zeros((1, 128), jnp.int32)
    r = klint.lint_fn(
        lambda i, k: _fixture_call(_scatter_fixture_kernel, i, k),
        ids, jnp.zeros((1, 128), jnp.uint32), name="fixture/scatter",
    )
    assert r.pallas_calls == 1
    assert any(v.startswith("scatter") for v in r.violations)


def test_lint_ignores_host_side_gathers():
    # gathers OUTSIDE pallas_call are the legitimate host path: not flagged
    def host_gather_then_kernel(i):
        g = jnp.cumsum(jnp.ones(16, jnp.int32))[i[0, :16] % 16]  # host gather
        h = mst.tile_histograms_pallas(i, 16)
        return h, g

    r = klint.lint_fn(host_gather_then_kernel, jnp.zeros((1, 128), jnp.int32),
                      name="fixture/host-gather")
    assert r.pallas_calls == 1 and not r.violations


# ---------------------------------------------------------------------------
# 2. Bitwise identity: oblivious bodies == gather bodies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,m,bits", [
    (128, 8, 8), (256, 256, 8), (512, 37, 4), (96, 16, 8), (1024, 256, 8),
])
def test_packed_local_offsets_oblivious_bitwise(t, m, bits):
    lay_g = packed_layout(t, m, bits=bits)
    lay_o = packed_layout(t, m, bits=bits, rank16=True)
    ids = _ids(t, m, seed=t + m)
    lg, hg = packed_local_offsets(ids, lay_g, oblivious=False)
    lo, ho = packed_local_offsets(ids, lay_o, oblivious=True)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lo))
    np.testing.assert_array_equal(np.asarray(hg), np.asarray(ho))


def test_packed_local_offsets_oblivious_adversarial_saturation():
    # all-one-bucket strip maxes the subword counters AND the rank planes
    lay = packed_layout(1024, 256, rank16=True)
    ids = jnp.zeros((1024,), jnp.int32)
    lg, hg = packed_local_offsets(ids, packed_layout(1024, 256), oblivious=False)
    lo, ho = packed_local_offsets(ids, lay, oblivious=True)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lo))
    np.testing.assert_array_equal(np.asarray(hg), np.asarray(ho))


@pytest.mark.parametrize("t,m", [(256, 16), (512, 256)])
def test_packed_positions_and_postscan_oblivious_bitwise(t, m):
    ids = _ids(t, m, seed=3)
    keys = _keys(t, seed=4)
    vals = jnp.arange(t, dtype=jnp.uint32)
    g_row = jnp.asarray(np.random.RandomState(5).randint(0, 1 << 20, m, dtype=np.int32))
    lay_g = packed_layout(t, m)
    lay_o = packed_layout(t, m, rank16=True)
    pg = packed_positions_body(ids, g_row, lay_g, oblivious=False)
    po = packed_positions_body(ids, g_row, lay_o, oblivious=True)
    np.testing.assert_array_equal(np.asarray(pg), np.asarray(po))
    for v in (vals, None):
        outs_g = packed_postscan_body(ids, g_row, keys, v, lay_g, oblivious=False)
        outs_o = packed_postscan_body(ids, g_row, keys, v, lay_o, oblivious=True)
        for a, b in zip(outs_g, outs_o):
            if a is None:
                assert b is None
            else:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("t,m", [(256, 16), (128, 100)])
def test_dense_local_offsets_oblivious_bitwise(t, m):
    ids = _ids(t, m, seed=9)
    lg, hg = _dense_local_offsets(ids, m, oblivious=False)
    lo, ho = _dense_local_offsets(ids, m, oblivious=True)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lo))
    np.testing.assert_array_equal(np.asarray(hg), np.asarray(ho))


@pytest.mark.parametrize("bits,num_segments", [(8, 1), (8, 4), (6, 1), (4, 3)])
def test_fused2_counts_oblivious_bitwise(bits, num_segments):
    t = 256
    keys = _keys(t, seed=bits)
    seg = None
    if num_segments > 1:
        seg = jnp.sort(jnp.asarray(
            np.random.RandomState(7).randint(0, num_segments, t, dtype=np.int32)))
    hg = fused2_counts_body(keys, 0, bits, seg=seg, num_segments=num_segments,
                            oblivious=False)
    ho = fused2_counts_body(keys, 0, bits, seg=seg, num_segments=num_segments,
                            oblivious=True)
    np.testing.assert_array_equal(np.asarray(hg), np.asarray(ho))


@pytest.mark.parametrize("t,bits,split,family,num_segments,kv", [
    (256, 8, 4, "onehot", 1, True),
    (256, 8, 4, "packed", 1, True),
    (256, 6, 3, "onehot", 4, False),
    (512, 8, 5, "packed", 3, True),      # asymmetric digit_split
    (128, 4, 2, "onehot", 1, False),
])
def test_fused2_postscan_oblivious_bitwise(t, bits, split, family, num_segments, kv):
    keys = _keys(t, seed=t + bits)
    vals = jnp.arange(t, dtype=jnp.uint32) if kv else None
    seg = None
    if num_segments > 1:
        seg = jnp.sort(jnp.asarray(
            np.random.RandomState(2).randint(0, num_segments, t, dtype=np.int32)))
    m_eff = (1 << bits) * num_segments
    g_row = jnp.asarray(
        np.random.RandomState(6).randint(0, 1 << 20, m_eff, dtype=np.int32))
    kw = dict(seg=seg, num_segments=num_segments, family=family)
    outs_g = fused2_postscan_body(keys, g_row, vals, 0, split, bits,
                                  oblivious=False, **kw)
    outs_o = fused2_postscan_body(keys, g_row, vals, 0, split, bits,
                                  oblivious=True, **kw)
    for a, b in zip(outs_g, outs_o):
        if a is None:
            assert b is None
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_wrappers_oblivious_matches_gather_end_to_end():
    """Through the actual pallas_call doors (interpret), both flag values."""
    t, m = 256, 16
    ids = jnp.stack([_ids(t, m, seed=s) for s in (0, 1)])
    g = jnp.asarray(np.random.RandomState(3).randint(0, 1 << 20, (2, m), dtype=np.int32))
    pg = mst.packed_tile_positions_pallas(ids, g, m, oblivious=False)
    po = mst.packed_tile_positions_pallas(ids, g, m, oblivious=True)
    np.testing.assert_array_equal(np.asarray(pg), np.asarray(po))

    pair = BitfieldSpec(0, 8)
    keys = jnp.stack([_keys(t, seed=s) for s in (4, 5)])
    vals = jnp.stack([jnp.arange(t, dtype=jnp.uint32)] * 2)
    gp = jnp.asarray(np.random.RandomState(8).randint(0, 1 << 20, (2, 256), dtype=np.int32))
    outs_g = mst.fused2_fused_postscan_reorder_pallas(
        keys, gp, vals, spec=pair, split=4, oblivious=False)
    outs_o = mst.fused2_fused_postscan_reorder_pallas(
        keys, gp, vals, spec=pair, split=4, oblivious=True)
    for a, b in zip(outs_g, outs_o):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 2b. The rank16 overflow guard (satellite 6)
# ---------------------------------------------------------------------------

def test_packed_layout_rank16_guard_rejects_big_tiles():
    # two 16-bit ranks per int32 lane: a rank can reach tile, so tile > 2^16-1
    # must be rejected AT LAYOUT TIME when the oblivious body will run
    with pytest.raises(ValueError, match="rank"):
        packed_layout(1 << 17, 16, rank16=True)
    # the boundary tile is legal ...
    assert packed_layout(0xFFFF, 16, rank16=True).tile == 0xFFFF
    # ... and the gather path keeps accepting big tiles (the vmap oracle)
    assert packed_layout(1 << 17, 16).tile == 1 << 17


def test_packed_local_offsets_oblivious_runtime_guard():
    # a layout built WITHOUT rank16 must still refuse the oblivious body
    lay = packed_layout(1 << 17, 16)
    ids = jnp.zeros((1 << 17,), jnp.int32)
    with pytest.raises(ValueError, match="rank"):
        packed_local_offsets(ids, lay, oblivious=True)


# ---------------------------------------------------------------------------
# 3. RangeSpec: balanced-tree emit == serial chain == searchsorted (sat. 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2, 3, 31, 255])
@pytest.mark.parametrize("dtype", [np.uint32, np.int32])
def test_rangespec_tree_matches_chain_and_searchsorted(s, dtype):
    rng = np.random.RandomState(s)
    splitters = np.unique(rng.randint(0, 1 << 30, s).astype(dtype))
    spec = RangeSpec(tuple(splitters.tolist()))
    keys_np = rng.randint(0, 1 << 30, 4096).astype(dtype)
    keys_np[:s] = splitters[: min(s, 4096)]         # exact splitter hits
    keys = jnp.asarray(keys_np)
    tree = np.asarray(spec.emit_in_kernel(keys))
    chain = np.asarray(spec._emit_chain(keys))
    ref = np.searchsorted(splitters, keys_np, side="right")
    np.testing.assert_array_equal(tree, chain)
    np.testing.assert_array_equal(tree, ref)


def test_rangespec_tree_traces_log_depth_adds():
    # s=255 splitters: 255 ge-compares but only ~s adds in a log-depth tree;
    # the WHOLE kernel jaxpr stays free of gathers (linted above) and small
    spec = RangeSpec(tuple(range(1, 256)))
    jx = jax.make_jaxpr(spec.emit_in_kernel)(jnp.zeros((128,), jnp.uint32))
    names = [e.primitive.name for e in jx.jaxpr.eqns]
    assert names.count("ge") == 255
    assert "gather" not in names and "scatter" not in names


# ---------------------------------------------------------------------------
# 4. Interpret resolution: Backend.compiled × TPU × REPRO_INTERPRET
# ---------------------------------------------------------------------------

@pytest.fixture(autouse=True)
def _fresh_tpu_probe():
    kops._tpu_available.cache_clear()
    yield
    kops._tpu_available.cache_clear()


def test_resolve_interpret_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    assert kops.resolve_interpret(True) is True
    assert kops.resolve_interpret(False) is True
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    assert kops.resolve_interpret(True) is False
    assert kops.resolve_interpret(False) is False


def test_resolve_interpret_defaults_follow_tpu_presence(monkeypatch):
    monkeypatch.delenv("REPRO_INTERPRET", raising=False)
    monkeypatch.setattr(kops, "_tpu_available", lambda: False)
    assert kops.resolve_interpret(True) is True       # no TPU -> interpret
    assert kops.resolve_interpret(False) is True
    monkeypatch.setattr(kops, "_tpu_available", lambda: True)
    assert kops.resolve_interpret(True) is False      # compiled target + TPU
    assert kops.resolve_interpret(False) is True      # debug target pinned


def test_backend_compiled_capability():
    assert get_backend("pallas").compiled
    assert not get_backend("pallas-interpret").compiled
    assert not get_backend("vmap").compiled
    # the dynamic property consults the resolver every time
    assert get_backend("pallas").stages.interpret == kops.resolve_interpret(True)
    assert get_backend("pallas-interpret").stages.interpret is True


def test_repro_interpret_env_reaches_backend_stages(monkeypatch):
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    assert get_backend("pallas").stages.interpret is False
    assert get_backend("pallas-interpret").stages.interpret is False
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    assert get_backend("pallas").stages.interpret is True
